//! Hybrid-plan demo (paper §3 "Distributed Operations"): the same DML
//! script runs single-node when the data fits the driver budget, and
//! flips to the distributed blocked backend when it does not — with no
//! change to the script.
//!
//! ```bash
//! cargo run --release --example distributed_batch
//! ```

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::util::metrics;

/// Full-batch gradient descent for linear regression: the paper's
/// `train_algo="batch"` shape, dominated by two big matmults per step.
const BATCH_GD: &str = r#"
w = matrix(0, rows=ncol(X), cols=1)
for (i in 1:steps) {
  pred = X %*% w
  grad = t(X) %*% (pred - y) / nrow(X)
  w = w - 0.05 * grad
}
final_loss = sum((X %*% w - y)^2) / nrow(X)
"#;

fn run(driver_mem: usize, rows: usize) -> (f64, u64, u64) {
    let (x, ylab) = synthetic_classification(rows, 64, 2, 17);
    // Regression target: first column of the one-hot labels.
    let y = systemml::runtime::matrix::reorg::slice(&ylab, 0, rows, 0, 1).unwrap();
    let mut config = SystemConfig::default();
    config.driver_memory = driver_mem;
    config.block_size = 256;
    let ctx = MLContext::with_config(config);
    let before = metrics::global().snapshot();
    let script = Script::from_str(BATCH_GD)
        .input("X", x)
        .input("y", y)
        .input_scalar("steps", 5.0)
        .output("final_loss");
    let res = ctx.execute(script).expect("batch GD failed");
    let d = metrics::global().snapshot().delta(&before);
    (res.double("final_loss").unwrap(), d.dist_tasks, d.broadcast_bytes + d.shuffle_bytes)
}

fn main() {
    let rows = 2048;
    println!("full-batch GD on {rows}x64 synthetic data, 5 steps\n");

    let (loss_cp, tasks_cp, comm_cp) = run(512 * 1024 * 1024, rows);
    println!("driver=512MB  -> plan: CP     | dist tasks {tasks_cp:4} | comm {comm_cp:8} B | loss {loss_cp:.5}");

    let (loss_dist, tasks_dist, comm_dist) = run(700 * 1024, rows);
    println!("driver=700KB  -> plan: DIST   | dist tasks {tasks_dist:4} | comm {comm_dist:8} B | loss {loss_dist:.5}");

    assert_eq!(tasks_cp, 0, "CP plan must not launch distributed tasks");
    assert!(tasks_dist > 0, "tiny driver must force the distributed plan");
    let rel = (loss_cp - loss_dist).abs() / loss_cp.abs().max(1e-12);
    assert!(rel < 1e-12, "both plans compute the same algorithm: {loss_cp} vs {loss_dist}");
    println!("\nsame script, same numerics, different physical plan — hybrid-plan OK");
}
