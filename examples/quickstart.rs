//! Quickstart: the paper's §2 DML listing — a softmax classifier trained
//! with minibatch SGD using the NN library — run verbatim through the
//! MLContext API on synthetic data.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use systemml::api::{MLContext, Script};
use systemml::runtime::matrix::agg;
use systemml::runtime::matrix::randgen::synthetic_classification;

const PAPER_SCRIPT: &str = r#"
source("nn/layers/affine.dml") as affine
source("nn/layers/cross_entropy_loss.dml") as cross_entropy_loss
source("nn/layers/softmax.dml") as softmax
source("nn/optim/sgd.dml") as sgd

train = function(matrix[double] X, matrix[double] Y)
    return (matrix[double] W, matrix[double] b) {
  D = ncol(X) # num features
  K = ncol(Y) # num classes
  lr = 0.1; batch_size = 32; num_iter = nrow(X) / batch_size
  [W, b] = affine::init(D, K)
  for (i in 1:num_iter) {
    # Get batch
    beg = (i-1)*batch_size + 1; end = beg + batch_size - 1
    X_batch = X[beg:end,]; y_batch = Y[beg:end,]
    # Perform forward pass
    scores = affine::forward(X_batch, W, b)
    probs = softmax::forward(scores)
    loss = cross_entropy_loss::forward(probs, y_batch)
    if (i %% 4 == 1) { print("iter " + i + ": loss = " + loss) }
    # Perform backward pass
    dprobs = cross_entropy_loss::backward(probs, y_batch)
    dscores = softmax::backward(dprobs, scores)
    [dX_batch, dW, db] = affine::backward(dscores, X_batch, W, b)
    # Perform update
    W = sgd::update(W, dW, lr)
    b = sgd::update(b, db, lr)
  }
}

[W, b] = train(X, Y)
scores = X %*% W + b
"#;

fn main() {
    let (x, y) = synthetic_classification(1024, 32, 5, 2024);
    let mut ctx = MLContext::new();
    ctx.echo = true;

    let script = Script::from_str(PAPER_SCRIPT)
        .input("X", x)
        .input("Y", y.clone())
        .output("W")
        .output("b")
        .output("scores");
    let res = ctx.execute(script).expect("training failed");

    // Accuracy of the trained classifier.
    let scores = res.matrix("scores").unwrap();
    let pred = agg::row_index_max(&scores);
    let truth = agg::row_index_max(&y);
    let correct = (0..pred.rows()).filter(|r| pred.get(*r, 0) == truth.get(*r, 0)).count();
    println!(
        "\ntrained softmax classifier: {}/{} correct ({:.1}%)",
        correct,
        pred.rows(),
        100.0 * correct as f64 / pred.rows() as f64
    );
    assert!(correct * 2 > pred.rows(), "model should beat chance");
}
