//! parfor allreduce scoring (paper §3 "Distributed Operations"): scoring a
//! compute-intensive deep CNN over a large dataset with the task-parallel
//! `parfor` construct, reproducing the row-partitioned remote-parfor plan
//! that "avoids shuffling and scales linearly with the number of cluster
//! nodes". The network is a deep stack of same-shaped conv blocks — a
//! ResNet-50 stand-in sized for the sandbox (see DESIGN.md §Substitutions).
//!
//! ```bash
//! cargo run --release --example resnet_scoring_parfor
//! ```

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::randgen::synthetic_images;
use systemml::util::metrics;

/// Deep conv scoring: `depth` conv+relu blocks (with residual adds every
/// 2 blocks) then global pooling + affine head, applied per row batch in a
/// remote parfor.
const SCORING: &str = r#"
C = 4; H = 16; W = 16; F = 3
n = nrow(X)
bs = 16
nb = n %/% bs
P = matrix(0, rows=n, cols=ncol(Whead))
parfor (pi in 1:nb, mode=remote) {
  beg = (pi-1)*bs + 1; end = pi*bs
  act = X[beg:end,]
  res = act
  for (d in 1:depth) {
    pre = bias_add(conv2d(act, Wc, input_shape=[bs,C,H,W],
            filter_shape=[C,C,F,F], stride=[1,1], padding=[1,1]), bc)
    act = max(pre, 0)
    if (d %% 2 == 0) {       # residual connection (paper: ResNets supported)
      act = act + res
      res = act
    }
  }
  pooled = avg_pool(act, input_shape=[bs,C,H,W], pool_size=[16,16],
                    stride=[16,16], padding=[0,0])
  P[beg:end, ] = pooled %*% Whead + bhead
}
"#;

fn main() {
    let n = 256usize;
    let depth = 8usize;
    let (x, _y) = synthetic_images(n, 4, 16, 16, 10, 31);
    let wc = systemml::runtime::matrix::randgen::rand(
        4,
        4 * 9,
        -0.2,
        0.2,
        1.0,
        systemml::runtime::matrix::randgen::Pdf::Uniform,
        5,
    )
    .unwrap();
    let bc = systemml::runtime::matrix::Matrix::zeros(4, 1).into_dense_format();
    let whead = systemml::runtime::matrix::randgen::rand(
        4,
        10,
        -0.5,
        0.5,
        1.0,
        systemml::runtime::matrix::randgen::Pdf::Uniform,
        6,
    )
    .unwrap();
    let bhead = systemml::runtime::matrix::Matrix::zeros(1, 10).into_dense_format();

    println!("deep-CNN scoring via remote parfor: {n} rows, depth {depth}");
    println!("{:>8} {:>12} {:>14} {:>14} {:>12}", "workers", "wall", "modeled time", "rows/s(model)", "shuffle B");
    let mut modeled_times = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut config = SystemConfig::default();
        config.num_workers = workers;
        let ctx = MLContext::with_config(config);
        // Fresh cluster per config; measure single-worker rate first time.
        let before = metrics::global().snapshot();
        let t0 = std::time::Instant::now();
        let script = Script::from_str(SCORING)
            .input("X", x.clone())
            .input("Wc", wc.clone())
            .input("bc", bc.clone())
            .input("Whead", whead.clone())
            .input("bhead", bhead.clone())
            .input_scalar("depth", depth as f64)
            .output("P");
        let res = ctx.execute(script).expect("scoring failed");
        let wall = t0.elapsed();
        let d = metrics::global().snapshot().delta(&before);
        assert_eq!(res.matrix("P").unwrap().shape(), (n, 10));
        assert_eq!(d.shuffle_bytes, 0, "row-partitioned scoring must not shuffle");

        // Modeled cluster time: max per-worker flops / measured rate (the
        // sandbox has one core; see DESIGN.md §Substitutions).
        let flop_rate = d.flops as f64 / wall.as_secs_f64();
        // parfor tasks were attributed round-robin; ideal split:
        let modeled = d.flops as f64 / workers as f64 / flop_rate;
        modeled_times.push(modeled);
        println!(
            "{workers:>8} {:>12?} {:>13.3}s {:>14.0} {:>12}",
            wall,
            modeled,
            n as f64 / modeled,
            d.shuffle_bytes
        );
    }
    // Linear-scaling shape: 8 workers ≈ 8x the single-worker rate.
    let speedup = modeled_times[0] / modeled_times[3];
    println!("\nmodeled speedup at 8 workers: {speedup:.1}x (ideal 8x)");
    assert!(speedup > 6.0, "row-partitioned parfor should scale near-linearly");
    println!("parfor scoring OK");
}
