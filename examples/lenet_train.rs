//! End-to-end driver (DESIGN.md §5.2): train a LeNet-style CNN on a
//! synthetic MNIST-shaped corpus for a few hundred minibatch steps through
//! the FULL stack — DML source → compiler (constant folding, exec-type
//! selection) → hybrid runtime (builtin conv operators; ACCEL offload when
//! `--accel` and the conv artifacts match) — and log the loss curve.
//!
//! ```bash
//! cargo run --release --example lenet_train            # CP backend
//! cargo run --release --example lenet_train -- --accel # PJRT offload
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::agg;
use systemml::runtime::matrix::randgen::synthetic_images;
use systemml::util::metrics;

/// LeNet-ish: conv(8@3x3, same) → relu → maxpool 2x2 → conv(16@3x3, same)
/// → relu → maxpool 2x2 → affine → softmax. Images are 1x28x28 like MNIST.
const LENET: &str = r#"
source("nn/layers/softmax.dml") as softmax
source("nn/layers/cross_entropy_loss.dml") as ce
source("nn/optim/sgd.dml") as sgd

# ---- hyperparameters -----------------------------------------------------
C = 1; Hin = 28; Win = 28
K1 = 8; K2 = 16; F = 3
lr = 0.05
batch_size = 16
N = nrow(X)
num_classes = ncol(Y)

# ---- init ------------------------------------------------------------
W1 = rand(rows=K1, cols=C*F*F, min=-1, max=1, seed=1) * sqrt(2.0 / (C*F*F))
b1 = matrix(0, rows=K1, cols=1)
W2 = rand(rows=K2, cols=K1*F*F, min=-1, max=1, seed=2) * sqrt(2.0 / (K1*F*F))
b2 = matrix(0, rows=K2, cols=1)
D3 = K2 * 7 * 7
W3 = rand(rows=D3, cols=num_classes, min=-1, max=1, seed=3) * sqrt(2.0 / D3)
b3 = matrix(0, rows=1, cols=num_classes)

num_iter = (N %/% batch_size) * epochs
loss_curve = matrix(0, rows=num_iter, cols=1)
iter = 0
for (ep in 1:epochs) {
  for (bi in 1:(N %/% batch_size)) {
    iter = iter + 1
    beg = (bi-1)*batch_size + 1; end = bi*batch_size
    Xb = X[beg:end,]; Yb = Y[beg:end,]

    # ---- forward ----------------------------------------------------
    c1pre = bias_add(conv2d(Xb, W1, input_shape=[batch_size,C,Hin,Win],
              filter_shape=[K1,C,F,F], stride=[1,1], padding=[1,1]), b1)
    c1 = max(c1pre, 0)
    p1 = max_pool(c1, input_shape=[batch_size,K1,28,28], pool_size=[2,2],
                  stride=[2,2], padding=[0,0])
    c2pre = bias_add(conv2d(p1, W2, input_shape=[batch_size,K1,14,14],
              filter_shape=[K2,K1,F,F], stride=[1,1], padding=[1,1]), b2)
    c2 = max(c2pre, 0)
    p2 = max_pool(c2, input_shape=[batch_size,K2,14,14], pool_size=[2,2],
                  stride=[2,2], padding=[0,0])
    scores = p2 %*% W3 + b3
    probs = softmax::forward(scores)
    loss = ce::forward(probs, Yb)
    loss_curve[iter, 1] = loss

    # ---- backward -----------------------------------------------------
    dscores = (probs - Yb) / batch_size
    dW3 = t(p2) %*% dscores
    db3 = colSums(dscores)
    dp2 = dscores %*% t(W3)
    dc2 = max_pool_backward(c2, dp2, input_shape=[batch_size,K2,14,14],
                            pool_size=[2,2], stride=[2,2], padding=[0,0])
    dc2pre = dc2 * (c2pre > 0)
    dW2 = conv2d_backward_filter(p1, dc2pre, input_shape=[batch_size,K1,14,14],
            filter_shape=[K2,K1,F,F], stride=[1,1], padding=[1,1])
    db2 = matrix(0, rows=K2, cols=1)
    for (k in 1:K2) { db2[k, 1] = sum(dc2pre[, ((k-1)*196+1):(k*196)]) }
    dp1 = conv2d_backward_data(W2, dc2pre, input_shape=[batch_size,K1,14,14],
            filter_shape=[K2,K1,F,F], stride=[1,1], padding=[1,1])
    dc1 = max_pool_backward(c1, dp1, input_shape=[batch_size,K1,28,28],
                            pool_size=[2,2], stride=[2,2], padding=[0,0])
    dc1pre = dc1 * (c1pre > 0)
    dW1 = conv2d_backward_filter(Xb, dc1pre, input_shape=[batch_size,C,Hin,Win],
            filter_shape=[K1,C,F,F], stride=[1,1], padding=[1,1])
    db1 = matrix(0, rows=K1, cols=1)
    for (k in 1:K1) { db1[k, 1] = sum(dc1pre[, ((k-1)*784+1):(k*784)]) }

    # ---- update ----------------------------------------------------
    W1 = sgd::update(W1, dW1, lr); b1 = sgd::update(b1, db1, lr)
    W2 = sgd::update(W2, dW2, lr); b2 = sgd::update(b2, db2, lr)
    W3 = sgd::update(W3, dW3, lr); b3 = sgd::update(b3, db3, lr)
  }
}

# ---- final training accuracy over the first 256 rows ---------------------
Xa = X[1:256,]
na = 256
a1pre = bias_add(conv2d(Xa, W1, input_shape=[na,C,Hin,Win],
          filter_shape=[K1,C,F,F], stride=[1,1], padding=[1,1]), b1)
a1 = max(a1pre, 0)
ap1 = max_pool(a1, input_shape=[na,K1,28,28], pool_size=[2,2], stride=[2,2], padding=[0,0])
a2pre = bias_add(conv2d(ap1, W2, input_shape=[na,K1,14,14],
          filter_shape=[K2,K1,F,F], stride=[1,1], padding=[1,1]), b2)
a2 = max(a2pre, 0)
ap2 = max_pool(a2, input_shape=[na,K2,14,14], pool_size=[2,2], stride=[2,2], padding=[0,0])
final_scores = ap2 %*% W3 + b3
acc = mean(rowIndexMax(final_scores) == rowIndexMax(Y[1:256,]))
"#;

fn main() {
    let accel = std::env::args().any(|a| a == "--accel");
    let steps_arg: Option<usize> = std::env::args()
        .skip_while(|a| a != "--epochs")
        .nth(1)
        .and_then(|s| s.parse().ok());

    // 512 images x (1*28*28), 10 classes; 32 batches/epoch * 10 epochs =
    // 320 minibatch steps by default.
    let n = 512usize;
    let epochs = steps_arg.unwrap_or(10);
    let (x, y) = synthetic_images(n, 1, 28, 28, 10, 7);

    let mut config = SystemConfig::default();
    config.accel_enabled = accel;
    let ctx = MLContext::with_config(config);

    println!(
        "LeNet e2e: {} images, {} epochs ({} minibatch steps), backend: {}",
        n,
        epochs,
        epochs * (n / 16),
        if accel { "CP+ACCEL(PJRT)" } else { "CP" }
    );
    let before = metrics::global().snapshot();
    let t0 = std::time::Instant::now();
    let script = Script::from_str(LENET)
        .input("X", x)
        .input("Y", y)
        .input_scalar("epochs", epochs as f64)
        .output("loss_curve")
        .output("acc");
    let res = ctx.execute(script).expect("training failed");
    let wall = t0.elapsed();
    let d = metrics::global().snapshot().delta(&before);

    let lc = res.matrix("loss_curve").unwrap();
    let total = lc.rows();
    println!("\nloss curve ({total} steps):");
    for i in (0..total).step_by((total / 16).max(1)) {
        let bars = (lc.get(i, 0) * 20.0).round() as usize;
        println!("  step {:4}  loss {:.4}  {}", i + 1, lc.get(i, 0), "#".repeat(bars.min(60)));
    }
    let first = lc.get(0, 0);
    let last = lc.get(total - 1, 0);
    let acc = res.double("acc").unwrap();
    println!("\nfirst loss {first:.4} -> last loss {last:.4} | train accuracy {:.1}%", acc * 100.0);
    println!(
        "wallclock {wall:?} | {:.1} steps/s | flops {:.2e} | accel launches {}",
        total as f64 / wall.as_secs_f64(),
        d.flops as f64,
        d.accel_launches
    );
    let mean_first: f64 = (0..4).map(|i| lc.get(i, 0)).sum::<f64>() / 4.0;
    let mean_last: f64 = (total - 4..total).map(|i| lc.get(i, 0)).sum::<f64>() / 4.0;
    assert!(
        mean_last < mean_first * 0.5,
        "loss must drop by >2x: {mean_first:.4} -> {mean_last:.4}"
    );
    let _ = agg::full_agg(&lc, agg::AggOp::Min);
    println!("E2E OK");
}
