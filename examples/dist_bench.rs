//! Distributed benchmark, run by CI's `bench` job.
//!
//! Four iterative workloads — conjugate-gradient linear regression, a
//! Lloyd's k-means loop, a **mini-batch SGD epoch loop** (batched
//! slice → broadcast normalize → matmult → aggregate), and a **LeNet
//! training epoch** (batched slice → conv2d → max_pool → affine →
//! backward, the paper's distributed deep-learning scenario) — run on
//! synthetic data with a driver budget small enough that every X-sized
//! operator compiles to the distributed backend. Each workload is measured twice with different iteration
//! counts, so the **marginal blockify/collect cost per iteration** falls
//! out exactly — warmup repartitions cancel. With the lineage-keyed
//! block cache the loop-invariant feature matrix is blockified **once**
//! for the whole loop, mini-batch slices are block-range selections of
//! the resident partitions (derived `X[..]#v` entries), and row/col
//! vector normalizers are map-side broadcast joins.
//!
//! With first-class blocked values (`Value::Blocked`) the loops' updates
//! stay distributed end-to-end: every workload performs **zero** driver
//! collects per iteration — scalars come back as per-block aggregate
//! partials or single-block job outputs, never as a collect of a blocked
//! matrix.
//!
//! Emits `BENCH_dist.json` (blockify/collect counts, shuffle/broadcast
//! bytes, cache hit rates, wall time) and exits non-zero when
//! - lm_cg's marginal blockify-per-iteration exceeds 1 (the invariant
//!   operand is being re-partitioned — a cache regression), or
//! - kmeans' marginal blockify-per-iteration exceeds 3 (the slice /
//!   broadcast / argmax path stopped staying blocked), or
//! - any workload's marginal collects-per-iteration exceeds 0 (a blocked
//!   value is being materialized inside the loop — a laziness
//!   regression; for kmeans and minibatch this is the distributed
//!   indexing + broadcast-cellwise acceptance gate), or
//! - caching stops reducing blockify volume vs. a cache-off run, or
//! - cached and uncached runs disagree numerically, or
//! - (PR 6) the wall-sized LeNet epoch misses its parallel-speedup bar —
//!   `dist_threads=4` vs the serial escape hatch, 1.5x on 4+ hardware
//!   threads, 1.15x on 2-3, reported-only on 1 — or
//! - (PR 6) the packed GEMM kernel fails to beat the previous
//!   cache-blocked kernel's GFLOP/s (best of 3 at 384^3), or
//! - (PR 7) the fully-resident multi-epoch momentum LeNet performs any
//!   driver collect at all (the gate is **0 for the whole job**, warmup
//!   included), diverges bitwise across worker counts, or its
//!   tree-allreduce byte volume misses the exact 1:2:3 ratio across
//!   2/4/8 workers that the ceil(log2(W))-rounds model predicts, or
//! - (PR 8) the sparse logistic epoch's communication volume exceeds
//!   25% of its dense twin's — mini-batch slices and broadcasts must be
//!   charged by *encoded* (CSR) bytes, not dense dimensions — or the
//!   sparse run stops going through the blocked backend at all, or
//! - (PR 9) the micro-batched scoring service misses its serving bars —
//!   p99 queueing latency above 5x p50 in simulated ticks at the default
//!   knobs, sustained rows/sec not strictly above the batch=1 baseline
//!   scoring the same request rows, any driver collect after warmup, or
//!   more than one plan compile for the single padded batch geometry.
//!
//! ```bash
//! cargo run --release --example dist_bench
//! ```

use std::time::Instant;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::dense::DenseMatrix;
use systemml::runtime::matrix::randgen::{rand, synthetic_classification, Pdf};
use systemml::runtime::matrix::{mult, reorg, Matrix};
use systemml::runtime::serve::batcher::ArrivalProcess;
use systemml::runtime::serve::run_simulation;
use systemml::util::metrics;
use systemml::util::prng::Prng;
use systemml::util::stats::OpStat;

/// Conjugate gradient on the normal equations (scripts/algorithms/lm_cg
/// inlined with a fixed iteration count): `X` and `t(X)` are
/// loop-invariant DIST operands, `p` rebinds every iteration.
const LM_CG: &str = r#"
w = matrix(0, rows=ncol(X), cols=1)
r = t(X) %*% y
p = r
norm_r2 = sum(r^2)
i = 0
while (i < max_iter) {
  i = i + 1
  q = t(X) %*% (X %*% p) + lambda * p
  alpha = norm_r2 / as.scalar(t(p) %*% q)
  w = w + alpha * p
  r = r - alpha * q
  old_norm = norm_r2
  norm_r2 = sum(r^2)
  p = r + (norm_r2 / old_norm) * p
}
final_norm = norm_r2
"#;

/// Lloyd iterations (scripts/algorithms/kmeans inlined, seeded centroids):
/// `X` is loop-invariant, the centroids `C` rebind every iteration. The
/// distance line is a broadcast-cellwise chain (col + row vector
/// operands) over the blocked `X %*% t(C)`, and the assignment step is
/// the blocked rowIndexMax — zero collects per iteration.
const KMEANS: &str = r#"
C = X[1:k, ]
N = nrow(X)
for (it in 1:max_iter) {
  D2 = (-2) * (X %*% t(C)) + rowSums(X^2) + t(rowSums(C^2))
  assign = rowIndexMax(-D2)
  members = table(seq(1, N), assign, N, k)
  counts = colSums(members)
  C = (t(members) %*% X) / t(max(counts, 1))
}
D2 = (-2) * (X %*% t(C)) + rowSums(X^2) + t(rowSums(C^2))
wcss = sum(rowMins(D2))
"#;

/// Mini-batch SGD epoch loop (the paper's deep-learning scenario in
/// miniature): every epoch reads block-aligned `X[beg:end,]` batches from
/// the resident blocked `X` (derived `X[..]#v` slice entries reuse across
/// epochs), normalizes with broadcast row vectors (`- mu`, `/ sigma`),
/// and runs the matmult chain blocked — the only per-batch repartition is
/// the freshly rebound weight vector `w`, and nothing collects.
const MINIBATCH: &str = r#"
w = matrix(0.001, rows=ncol(X), cols=1)
mu = colMeans(X)
sigma = sqrt(colMeans(X^2) - mu^2) + 0.1
nb = nrow(X) / bsize
for (e in 1:max_iter) {
  for (b in 1:nb) {
    beg = (b - 1) * bsize + 1
    end = b * bsize
    Xb = X[beg:end, ]
    Xn = (Xb - mu) / sigma
    g = t(Xn) %*% (Xn %*% w)
    w = w - (0.01 / bsize) * g
  }
}
wnorm = sum(w ^ 2)
"#;

/// LeNet-style training epoch (the paper's distributed deep-learning
/// scenario): each 128-image mini-batch — one flattened 1x8x8 image per
/// row — is a block-aligned slice of the resident blocked `X` spanning
/// two 64-row blocks, and the whole conv2d → max_pool → affine →
/// backward chain runs worker-side over row bands: conv/pool outputs
/// bind as blocked values, the filter ships as a broadcast variable, and
/// the filter gradient returns with the job as per-band K×CRS partials.
/// `max_iter` counts epochs. Gate: **zero driver collects per
/// iteration**.
const LENET: &str = r#"
W1 = rand(rows=4, cols=9, min=-0.1, max=0.1, seed=7)
W2 = rand(rows=64, cols=1, min=-0.1, max=0.1, seed=8)
nb = nrow(X) / bsize
for (e in 1:max_iter) {
  for (b in 1:nb) {
    beg = (b - 1) * bsize + 1
    end = b * bsize
    Xb = X[beg:end, ]
    Yb = y[beg:end, ]
    C1 = conv2d(Xb, W1, input_shape=[bsize,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])
    H1 = max_pool(C1, input_shape=[bsize,4,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])
    P = H1 %*% W2
    dP = (P - Yb) / bsize
    dW2 = t(H1) %*% dP
    dH1 = dP %*% t(W2)
    dC1 = max_pool_backward(C1, dH1, input_shape=[bsize,4,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])
    dW1 = conv2d_backward_filter(Xb, dC1, input_shape=[bsize,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])
    W1 = W1 - 0.05 * dW1
    W2 = W2 - 0.05 * dW2
  }
}
wnorm2 = sum(W1 ^ 2) + sum(W2 ^ 2)
"#;

/// Multi-epoch **fully-resident** LeNet training (the PR 7 tentpole
/// scenario): SGD with momentum, where the weights `W1`/`W2` and the
/// momentum buffers `vW1`/`vW2` live on the cluster as replicated
/// blocked values for the whole job. Both filter gradients come back
/// through the modeled tree-allreduce (`conv2d_backward_filter` band
/// partials; the `t(H1) %*% dP` contraction), the update chain stays
/// replicated worker-side, and the final norms are blocked aggregates —
/// so the **entire multi-epoch job runs at 0 driver collects**, and the
/// allreduce traffic grows exactly ∝ ceil(log2(workers)).
const LENET_RESIDENT: &str = r#"
W1 = rand(rows=4, cols=9, min=-0.1, max=0.1, seed=7)
W2 = rand(rows=64, cols=1, min=-0.1, max=0.1, seed=8)
vW1 = matrix(0, rows=4, cols=9)
vW2 = matrix(0, rows=64, cols=1)
nb = nrow(X) / bsize
for (e in 1:max_iter) {
  for (b in 1:nb) {
    beg = (b - 1) * bsize + 1
    end = b * bsize
    Xb = X[beg:end, ]
    Yb = y[beg:end, ]
    C1 = conv2d(Xb, W1, input_shape=[bsize,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])
    H1 = max_pool(C1, input_shape=[bsize,4,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])
    P = H1 %*% W2
    dP = (P - Yb) / bsize
    dW2 = t(H1) %*% dP
    dH1 = dP %*% t(W2)
    dC1 = max_pool_backward(C1, dH1, input_shape=[bsize,4,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])
    dW1 = conv2d_backward_filter(Xb, dC1, input_shape=[bsize,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])
    vW1 = 0.9 * vW1 - 0.05 * dW1
    vW2 = 0.9 * vW2 - 0.05 * dW2
    W1 = W1 + vW1
    W2 = W2 + vW2
  }
}
wnorm2 = sum(W1 ^ 2) + sum(W2 ^ 2)
"#;

/// LeNet epoch sized for **wall-clock** scaling (not marginal-cost
/// accounting): 1024 flattened 1x16x16 images, 16 filters, bsize 512
/// over 64-row blocks — 8 row bands per mini-batch, so the banded
/// conv/pool tasks actually fan out across the worker threads.
const LENET_WALL: &str = r#"
W1 = rand(rows=16, cols=9, min=-0.1, max=0.1, seed=7)
W2 = rand(rows=1024, cols=1, min=-0.1, max=0.1, seed=8)
nb = nrow(X) / bsize
for (e in 1:max_iter) {
  for (b in 1:nb) {
    beg = (b - 1) * bsize + 1
    end = b * bsize
    Xb = X[beg:end, ]
    Yb = y[beg:end, ]
    C1 = conv2d(Xb, W1, input_shape=[bsize,1,16,16], filter_shape=[16,1,3,3], stride=[1,1], padding=[1,1])
    H1 = max_pool(C1, input_shape=[bsize,16,16,16], pool_size=[2,2], stride=[2,2], padding=[0,0])
    P = H1 %*% W2
    dP = (P - Yb) / bsize
    dW2 = t(H1) %*% dP
    dH1 = dP %*% t(W2)
    dC1 = max_pool_backward(C1, dH1, input_shape=[bsize,16,16,16], pool_size=[2,2], stride=[2,2], padding=[0,0])
    dW1 = conv2d_backward_filter(Xb, dC1, input_shape=[bsize,1,16,16], filter_shape=[16,1,3,3], stride=[1,1], padding=[1,1])
    W1 = W1 - 0.05 * dW1
    W2 = W2 - 0.05 * dW2
  }
}
wnorm2 = sum(W1 ^ 2) + sum(W2 ^ 2)
"#;

/// Sparse logistic mini-batch SGD (the PR 8 sparse-backend scenario):
/// `X` is ~1%-dense — the one-hot/bag-of-words regime — and far too big
/// for the driver even *encoded*, so the whole epoch runs blocked over a
/// mixed dense/CSR grid. The batch size (100) is deliberately misaligned
/// with the 64-cell block grid: every batch slice takes the general
/// (shuffled) rightIndex path, whose traffic is charged by the batch's
/// encoded CSR bytes — the quantity the ≤25%-of-dense gate watches.
const SPARSE_LOGISTIC: &str = r#"
w = matrix(0, rows=ncol(X), cols=1)
nb = nrow(X) / bsize
for (e in 1:max_iter) {
  for (b in 1:nb) {
    beg = (b - 1) * bsize + 1
    end = b * bsize
    Xb = X[beg:end, ]
    yb = y[beg:end, ]
    p = 1 / (1 + exp((-1) * (Xb %*% w)))
    g = t(Xb) %*% (p - yb)
    w = w - (0.1 / bsize) * g
  }
}
wnorm = sum(w ^ 2)
"#;

struct RunStats {
    result: f64,
    blockify: u64,
    collects: u64,
    cache_hits: u64,
    cache_misses: u64,
    shuffle_bytes: u64,
    broadcast_bytes: u64,
    wall_ms: f64,
    /// Top-5 heavy-hitter rows from the session's `-stats` table.
    heavy: Vec<OpStat>,
    /// Max/mean worker busy-time ratio (always finite; 1.0 when idle).
    skew: f64,
}

// X (400x64 doubles = 200 KB) must not fit the driver budget, so all
// X-sized operators place DIST.
fn config_with(cache: bool, threads: usize, workers: usize) -> SystemConfig {
    SystemConfig::builder()
        .driver_memory(128 * 1024)
        .block_size(64)
        .num_workers(workers)
        .dist_threads(threads)
        .cache_enabled(cache)
        .build()
}

/// Same knobs as [`config_with`]`(cache, 0, 4)`, with the `-stats`
/// registry on: the accounting runs feed each workload's heavy-hitter
/// table and worker-skew ratio into `BENCH_dist.json`.
fn stats_config(cache: bool) -> SystemConfig {
    SystemConfig::builder()
        .driver_memory(128 * 1024)
        .block_size(64)
        .num_workers(4)
        .dist_threads(0)
        .cache_enabled(cache)
        .stats_enabled(true)
        .build()
}

fn run(src: &str, iters: usize, cache: bool, output: &str) -> RunStats {
    let (x, ylab) = synthetic_classification(400, 64, 4, 42);
    let y = reorg::slice(&ylab, 0, 400, 0, 1).unwrap();
    let ctx = MLContext::with_config(stats_config(cache));
    let script = Script::from_str(src)
        .input("X", x)
        .input("y", y)
        .input_scalar("k", 4.0)
        .input_scalar("lambda", 0.001)
        .input_scalar("bsize", 128.0)
        .input_scalar("max_iter", iters as f64)
        .output(output);
    let before = metrics::global().snapshot();
    let t0 = Instant::now();
    let res = ctx.execute(script).expect("workload failed");
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let d = metrics::global().snapshot().delta(&before);
    let report = ctx.stats().expect("accounting runs keep -stats enabled");
    RunStats {
        result: res.double(output).unwrap(),
        blockify: d.blockify_ops,
        collects: d.dist_collects,
        cache_hits: d.cache_hits,
        cache_misses: d.cache_misses,
        shuffle_bytes: d.shuffle_bytes,
        broadcast_bytes: d.broadcast_bytes,
        wall_ms,
        heavy: report.heavy_hitters(5),
        skew: report.skew_ratio,
    }
}

struct Bench {
    name: &'static str,
    iters: usize,
    per_iter_cached: f64,
    per_iter_uncached: f64,
    collects_per_iter: f64,
    long_cached: RunStats,
}

/// Marginal blockify/iteration from two runs of different lengths —
/// warmup repartitions (outside the loop) cancel exactly.
fn marginal(short: &RunStats, long: &RunStats, di: usize) -> f64 {
    (long.blockify - short.blockify) as f64 / di as f64
}

/// Marginal driver collects per iteration (same two-run cancellation).
fn marginal_collects(short: &RunStats, long: &RunStats, di: usize) -> f64 {
    (long.collects - short.collects) as f64 / di as f64
}

fn bench(name: &'static str, src: &str, short_iters: usize, long_iters: usize, output: &str) -> Bench {
    let di = long_iters - short_iters;
    let sc = run(src, short_iters, true, output);
    let lc = run(src, long_iters, true, output);
    let su = run(src, short_iters, false, output);
    let lu = run(src, long_iters, false, output);
    let rel = (lc.result - lu.result).abs() / lu.result.abs().max(1e-12);
    assert!(
        rel < 1e-9,
        "{name}: cached and uncached runs must agree: {} vs {}",
        lc.result,
        lu.result
    );
    Bench {
        name,
        iters: long_iters,
        per_iter_cached: marginal(&sc, &lc, di),
        per_iter_uncached: marginal(&su, &lu, di),
        collects_per_iter: marginal_collects(&sc, &lc, di),
        long_cached: lc,
    }
}

// ---- wall-clock: serial escape hatch vs worker thread pool -------------

struct Wall {
    name: &'static str,
    serial_ms: f64,
    parallel_ms: f64,
}

impl Wall {
    fn speedup(&self) -> f64 {
        self.serial_ms / self.parallel_ms.max(1e-9)
    }
}

/// One timed end-to-end run of `src` under `dist_threads = threads`;
/// returns (elapsed ms, result). Results must be byte-identical across
/// thread counts — asserted by the caller.
fn timed_run(
    src: &str,
    x: &Matrix,
    y: &Matrix,
    bsize: f64,
    iters: usize,
    output: &str,
    threads: usize,
) -> (f64, f64) {
    let ctx = MLContext::with_config(config_with(true, threads, 4));
    let script = Script::from_str(src)
        .input("X", x.clone())
        .input("y", y.clone())
        .input_scalar("k", 4.0)
        .input_scalar("lambda", 0.001)
        .input_scalar("bsize", bsize)
        .input_scalar("max_iter", iters as f64)
        .output(output);
    let t0 = Instant::now();
    let res = ctx.execute(script).expect("wall workload failed");
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    (ms, res.double(output).unwrap())
}

/// Serial (threads=1) vs parallel (threads=4) wall clock, best of `reps`
/// runs each (alternating, so thermal/noise drift hits both sides).
fn wall_bench(
    name: &'static str,
    src: &str,
    x: &Matrix,
    y: &Matrix,
    bsize: f64,
    iters: usize,
    output: &str,
    reps: usize,
) -> Wall {
    let mut serial_ms = f64::INFINITY;
    let mut parallel_ms = f64::INFINITY;
    for _ in 0..reps {
        let (sm, sr) = timed_run(src, x, y, bsize, iters, output, 1);
        let (pm, pr) = timed_run(src, x, y, bsize, iters, output, 4);
        assert_eq!(
            sr.to_bits(),
            pr.to_bits(),
            "{name}: threads=1 vs threads=4 results diverged: {sr} vs {pr}"
        );
        serial_ms = serial_ms.min(sm);
        parallel_ms = parallel_ms.min(pm);
    }
    Wall { name, serial_ms, parallel_ms }
}

// ---- fully-resident multi-epoch LeNet (tree-allreduce) ------------------

/// Per-session accounting of one resident-LeNet job, read off the
/// session cluster's own counters (collects/allreduce are **totals for
/// the whole job**, not marginals — the gate is absolute zero).
struct ResidentRun {
    workers: usize,
    result: f64,
    collects: u64,
    allreduce_rounds: u64,
    allreduce_bytes: u64,
    comm_bytes: u64,
    blockify: u64,
    wall_ms: f64,
}

fn resident_lenet(workers: usize, epochs: usize) -> ResidentRun {
    let (x, ylab) = synthetic_classification(400, 64, 4, 42);
    let y = reorg::slice(&ylab, 0, 400, 0, 1).unwrap();
    let ctx = MLContext::with_config(config_with(true, 0, workers));
    let script = Script::from_str(LENET_RESIDENT)
        .input("X", x)
        .input("y", y)
        .input_scalar("bsize", 128.0)
        .input_scalar("max_iter", epochs as f64)
        .output("wnorm2");
    let t0 = Instant::now();
    let res = ctx.execute(script).expect("resident lenet failed");
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cluster = ctx.cluster().expect("resident lenet needs the dist backend");
    ResidentRun {
        workers,
        result: res.double("wnorm2").unwrap(),
        collects: cluster.collect_count(),
        allreduce_rounds: cluster.allreduce_round_count(),
        allreduce_bytes: cluster.allreduce_byte_count(),
        comm_bytes: cluster.comm_bytes(),
        blockify: cluster.blockify_count(),
        wall_ms,
    }
}

// ---- sparse logistic: encoded-byte communication accounting --------------

/// One sparse-logistic job at the given feature density, accounted on
/// the session cluster's own counters. `comm_bytes` is the whole job's
/// broadcast + shuffle + allreduce volume — with per-block CSR encoding
/// that volume shrinks with the data, which is exactly what the gate
/// compares across the sparse run and its dense twin.
struct SparseRun {
    density: f64,
    result: f64,
    comm_bytes: u64,
    shuffle_bytes: u64,
    broadcast_bytes: u64,
    collects: u64,
    blockify: u64,
    wall_ms: f64,
}

fn sparse_logistic(density: f64) -> SparseRun {
    // 2000x600 at 1% density still encodes to ~160 KB of CSR — above the
    // 128 KB driver budget, so even the *sparse-sized* placement
    // estimates keep every X-sized operator on the blocked backend.
    let x = rand(2000, 600, -1.0, 1.0, density, Pdf::Uniform, 4242).unwrap();
    let y = rand(2000, 1, 0.0, 1.0, 1.0, Pdf::Uniform, 4243).unwrap();
    let ctx = MLContext::with_config(config_with(true, 0, 4));
    let script = Script::from_str(SPARSE_LOGISTIC)
        .input("X", x)
        .input("y", y)
        .input_scalar("bsize", 100.0)
        .input_scalar("max_iter", 2.0)
        .output("wnorm");
    let before = metrics::global().snapshot();
    let t0 = Instant::now();
    let res = ctx.execute(script).expect("sparse logistic failed");
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let d = metrics::global().snapshot().delta(&before);
    let cluster = ctx.cluster().expect("sparse logistic needs the dist backend");
    SparseRun {
        density,
        result: res.double("wnorm").unwrap(),
        comm_bytes: cluster.comm_bytes(),
        shuffle_bytes: d.shuffle_bytes,
        broadcast_bytes: d.broadcast_bytes,
        collects: cluster.collect_count(),
        blockify: cluster.blockify_count(),
        wall_ms,
    }
}

// ---- serving: micro-batched scoring with latency percentiles -------------

/// Two-layer MLP forward pass served one row per request. Every model
/// dimension fits a single 64-wide block, so the batched forward is
/// single-k-block matmults against the session-resident replicated
/// weights — no partial-sum reassociation, no per-batch re-broadcast.
const SERVING: &str = "H = max(X %*% W1 + b1, 0)\n\
                       S = H %*% W2 + b2";

struct ServingRun {
    requests: usize,
    batches: usize,
    compiles: u64,
    collects: u64,
    p50_ticks: u64,
    p99_ticks: u64,
    p50_wall_ms: f64,
    p99_wall_ms: f64,
    rows_per_sec: f64,
    batch1_rows_per_sec: f64,
    comm_bytes: u64,
    wall_ms: f64,
}

/// One serving session at the default micro-batch knobs
/// (`serve_max_batch=64`, `serve_max_wait_ticks=8`): warm the plan cache
/// with one partial batch, zero the cluster counters, then drive `requests`
/// seeded arrivals through admission → batch → forward → scatter with two
/// micro-batches in flight. Queueing latency is measured in simulated
/// ticks — a pure function of (seed, max_gap, knobs), so the p99/p50 gate
/// cannot flake on a shared runner — alongside per-batch wall clock. The
/// batch=1 baseline then scores the **same** request rows one
/// `score_batch` call each, on the same warm service (same padded
/// geometry, same resident weights), so the throughput ratio isolates
/// exactly what dynamic micro-batching buys.
fn serving_bench(requests: usize, seed: u64, max_gap: u64) -> ServingRun {
    const FEATS: usize = 64;
    let ctx = MLContext::with_config(config_with(true, 4, 4));
    let script = Script::from_str(SERVING)
        .input("W1", rand(FEATS, 64, -0.5, 0.5, 1.0, Pdf::Uniform, 91).unwrap())
        .input("b1", rand(1, 64, -0.1, 0.1, 1.0, Pdf::Uniform, 92).unwrap())
        .input("W2", rand(64, 8, -0.5, 0.5, 1.0, Pdf::Uniform, 93).unwrap())
        .input("b2", rand(1, 8, -0.1, 0.1, 1.0, Pdf::Uniform, 94).unwrap())
        .output("S");
    let svc = ctx.score_service(&script, "X", FEATS).expect("serving needs the dist backend");
    let cluster = ctx.cluster().expect("serving needs the dist backend");

    // Warmup compiles the (only) padded geometry — with block size 64 and
    // max_batch 64, every batch in this bench pads to one 64-row block.
    let warm: Vec<Vec<f64>> = (0..8).map(|i| vec![0.5 + i as f64 * 0.01; FEATS]).collect();
    svc.score_batch(&warm).expect("serving warmup failed");
    cluster.reset_accounting();

    let t0 = Instant::now();
    let report = run_simulation(&svc, requests, seed, max_gap, 2).expect("serving run failed");
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let collects = cluster.collect_count();
    let comm_bytes = cluster.comm_bytes();

    // Batch=1 baseline over the same rows (same seeded arrival process).
    let mut arrivals = ArrivalProcess::new(seed, FEATS, max_gap);
    let rows: Vec<Vec<f64>> = (0..requests).map(|_| arrivals.next_request().row).collect();
    let t1 = Instant::now();
    for row in &rows {
        svc.score_batch(std::slice::from_ref(row)).expect("batch-1 scoring failed");
    }
    let batch1_secs = t1.elapsed().as_secs_f64();

    ServingRun {
        requests,
        batches: report.flushes.len(),
        compiles: svc.compile_count(),
        collects,
        p50_ticks: report.latency_percentile_ticks(50.0),
        p99_ticks: report.latency_percentile_ticks(99.0),
        p50_wall_ms: report.wall_percentile_secs(50.0) * 1e3,
        p99_wall_ms: report.wall_percentile_secs(99.0) * 1e3,
        rows_per_sec: requests as f64 / report.exec_secs.max(1e-9),
        batch1_rows_per_sec: requests as f64 / batch1_secs.max(1e-9),
        comm_bytes,
        wall_ms,
    }
}

// ---- packed GEMM vs reference kernel ------------------------------------

/// Best-of-3 GFLOP/s of a dense GEMM kernel at `size`^3.
fn gemm_gflops(kernel: &dyn Fn(&DenseMatrix, &DenseMatrix) -> DenseMatrix, size: usize) -> f64 {
    let mut rng = Prng::new(123);
    let mk = |rng: &mut Prng| {
        let mut d = DenseMatrix::zeros(size, size);
        for v in d.data.iter_mut() {
            *v = rng.uniform(-1.0, 1.0);
        }
        d
    };
    let a = mk(&mut rng);
    let b = mk(&mut rng);
    let flops = 2.0 * (size * size * size) as f64;
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let c = kernel(&a, &b);
        best = best.min(t0.elapsed().as_secs_f64());
        std::hint::black_box(c.data[size / 2]);
    }
    flops / best.max(1e-9) / 1e9
}

/// Top-k heavy-hitter rows as a JSON array (counts/FLOPs/bytes are
/// deterministic; `time_ms` is wall clock and varies run to run).
fn heavy_json(rows: &[OpStat]) -> String {
    if rows.is_empty() {
        return "[]".to_string();
    }
    let body = rows
        .iter()
        .map(|o| {
            format!(
                "      {{ \"op\": \"{}\", \"pos\": \"{}\", \"exec\": \"{}\", \"count\": {}, \
                 \"time_ms\": {:.3}, \"gflop\": {:.6}, \"comm_kb\": {:.3} }}",
                o.op,
                o.pos,
                o.exec,
                o.count,
                o.nanos as f64 / 1e6,
                o.flops as f64 / 1e9,
                o.comm_bytes as f64 / 1024.0,
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    format!("[\n{body}\n    ]")
}

fn json_entry(b: &Bench) -> String {
    let s = &b.long_cached;
    format!(
        concat!(
            "  \"{}\": {{\n",
            "    \"iterations\": {},\n",
            "    \"blockify_per_iter\": {:.4},\n",
            "    \"blockify_per_iter_uncached\": {:.4},\n",
            "    \"collects_per_iter\": {:.4},\n",
            "    \"blockify_total\": {},\n",
            "    \"collects_total\": {},\n",
            "    \"cache_hits\": {},\n",
            "    \"cache_misses\": {},\n",
            "    \"shuffle_bytes\": {},\n",
            "    \"broadcast_bytes\": {},\n",
            "    \"wall_ms\": {:.2},\n",
            "    \"worker_skew\": {:.4},\n",
            "    \"heavy_hitters\": {},\n",
            "    \"result\": {}\n",
            "  }}"
        ),
        b.name,
        b.iters,
        b.per_iter_cached,
        b.per_iter_uncached,
        b.collects_per_iter,
        s.blockify,
        s.collects,
        s.cache_hits,
        s.cache_misses,
        s.shuffle_bytes,
        s.broadcast_bytes,
        s.wall_ms,
        s.skew,
        heavy_json(&s.heavy),
        s.result,
    )
}

fn main() {
    println!("dist_bench: iterative workloads on the blocked backend (DIST-forced)\n");
    let lm = bench("lm_cg", LM_CG, 6, 26, "final_norm");
    let km = bench("kmeans", KMEANS, 3, 13, "wcss");
    // Mini-batch epochs: 400 rows / bsize 128 = 3 block-aligned batches
    // per epoch; `max_iter` counts epochs.
    let mb = bench("minibatch", MINIBATCH, 2, 10, "wnorm");
    // LeNet epochs over the same 400x64 batch layout (1x8x8 images):
    // conv → pool → affine → backward, gated at 0 collects/iteration.
    let ln = bench("lenet", LENET, 2, 10, "wnorm2");

    // Fully-resident multi-epoch LeNet with momentum: weights and
    // optimizer state never leave the cluster, gradients tree-allreduce.
    // Three cluster widths check the log2(workers) traffic model
    // exactly — rounds per allreduce are 1 / 2 / 3 for 2 / 4 / 8
    // workers over the same job-determined byte volume, so the total
    // allreduce bytes must land on an exact 1:2:3 ratio.
    println!("\nresident lenet: multi-epoch momentum training, weights stay on the cluster");
    let resident = [resident_lenet(2, 3), resident_lenet(4, 3), resident_lenet(8, 3)];
    for r in &resident {
        println!(
            "  workers={} collects={} allreduce_rounds={} allreduce_bytes={} wall={:.1} ms",
            r.workers, r.collects, r.allreduce_rounds, r.allreduce_bytes, r.wall_ms
        );
    }

    // Sparse logistic epoch vs its dense twin: identical script, shapes
    // and batch layout — only the feature density differs, so the comm
    // ratio isolates what per-block CSR encoding saves on the wire.
    println!("\nsparse logistic: encoded-byte comm accounting at 1% density vs dense twin");
    let sp_run = sparse_logistic(0.01);
    let dn_run = sparse_logistic(1.0);
    for r in [&sp_run, &dn_run] {
        println!(
            "  density={:>4} comm={:>9} B (shuffle {} B, broadcast {} B) blockify={} collects={} wall={:.1} ms",
            r.density, r.comm_bytes, r.shuffle_bytes, r.broadcast_bytes, r.blockify, r.collects, r.wall_ms
        );
    }

    // Micro-batched scoring service at the default serving knobs: p50/p99
    // queueing latency in simulated ticks (deterministic) plus wall clock,
    // and sustained rows/sec vs a batch=1 baseline over the same rows.
    println!("\nserving: dynamic micro-batched scoring, 256 seeded arrivals, 2 in flight");
    let sv = serving_bench(256, 2026, 2);
    println!(
        "  p50 {} / p99 {} ticks | p50 {:.2} / p99 {:.2} ms | {:.0} rows/s batched vs {:.0} rows/s batch=1 | batches={} compiles={} collects={}",
        sv.p50_ticks,
        sv.p99_ticks,
        sv.p50_wall_ms,
        sv.p99_wall_ms,
        sv.rows_per_sec,
        sv.batch1_rows_per_sec,
        sv.batches,
        sv.compiles,
        sv.collects
    );

    // Wall clock, threads=1 (serial escape hatch) vs threads=4 (worker
    // pool). The small accounting workloads are reported for visibility;
    // the speedup gate runs on the wall-sized LeNet epoch, whose 8-band
    // batches give the pool real per-task work.
    println!("\nwall clock: dist_threads=1 vs dist_threads=4");
    let (x4, ylab4) = synthetic_classification(400, 64, 4, 42);
    let y4 = reorg::slice(&ylab4, 0, 400, 0, 1).unwrap();
    let (xw, ylabw) = synthetic_classification(1024, 256, 4, 43);
    let yw = reorg::slice(&ylabw, 0, 1024, 0, 1).unwrap();
    let walls = [
        wall_bench("lm_cg", LM_CG, &x4, &y4, 128.0, 20, "final_norm", 1),
        wall_bench("kmeans", KMEANS, &x4, &y4, 128.0, 10, "wcss", 1),
        wall_bench("minibatch", MINIBATCH, &x4, &y4, 128.0, 8, "wnorm", 1),
        wall_bench("lenet", LENET_WALL, &xw, &yw, 512.0, 3, "wnorm2", 2),
    ];
    for w in &walls {
        println!(
            "{:9} serial {:8.1} ms | parallel {:8.1} ms | speedup {:.2}x",
            w.name,
            w.serial_ms,
            w.parallel_ms,
            w.speedup()
        );
    }

    // Packed GEMM vs the previous cache-blocked kernel, best of 3 at
    // 384^3 (large enough that packing pays for itself, small enough for
    // a CI bench job).
    const GEMM_N: usize = 384;
    let packed_gflops = gemm_gflops(&|a, b| mult::mm_dense_dense(a, b), GEMM_N);
    let reference_gflops = gemm_gflops(&|a, b| mult::mm_dense_dense_reference(a, b), GEMM_N);
    println!(
        "\ngemm {GEMM_N}^3: packed {:.2} GFLOP/s vs reference {:.2} GFLOP/s ({:.2}x)",
        packed_gflops,
        reference_gflops,
        packed_gflops / reference_gflops.max(1e-9)
    );

    for b in [&lm, &km, &mb, &ln] {
        println!(
            "{:9} blockify/iter: {:.2} cached vs {:.2} uncached | collects/iter: {:.2} | hits {} | shuffle {} B | {:.1} ms",
            b.name,
            b.per_iter_cached,
            b.per_iter_uncached,
            b.collects_per_iter,
            b.long_cached.cache_hits,
            b.long_cached.shuffle_bytes,
            b.long_cached.wall_ms
        );
    }

    // Regression gate: the loop-invariant operand must stay resident.
    // lm_cg's only per-iteration repartition is the freshly rebound
    // direction vector p — anything above 1 means X (or t(X)) is being
    // re-blockified inside the loop.
    let mut pass = true;
    if lm.per_iter_cached > 1.0 + 1e-9 {
        eprintln!(
            "FAIL: lm_cg blockify-per-iteration {} > 1 — loop-invariant operand no longer cached",
            lm.per_iter_cached
        );
        pass = false;
    }
    // kmeans repartitions at most the three freshly rebound driver
    // intermediates per Lloyd iteration (t(C), X^2, t(members)); anything
    // above 3 means the slice / broadcast / argmax path fell off the
    // blocked plan.
    if km.per_iter_cached > 3.0 + 1e-9 {
        eprintln!(
            "FAIL: kmeans blockify-per-iteration {} > 3 — distributed indexing/broadcast regressed",
            km.per_iter_cached
        );
        pass = false;
    }
    // Blocked-value gate (the tentpole acceptance): every loop's updates
    // must stay distributed — zero driver collects per iteration. For
    // kmeans this requires the broadcast cellwise join and blocked
    // rowIndexMax; for minibatch the block-range batch slice; for lenet
    // the blocked conv/pool operators (outputs bound blocked, filter
    // gradients returning with the job).
    for b in [&lm, &km, &mb, &ln] {
        if b.collects_per_iter > 1e-9 {
            eprintln!(
                "FAIL: {} collects-per-iteration {} > 0 — blocked values are being materialized inside the loop",
                b.name, b.collects_per_iter
            );
            pass = false;
        }
        if b.per_iter_cached >= b.per_iter_uncached {
            eprintln!(
                "FAIL: {} cached blockify/iter {} is not below uncached {}",
                b.name, b.per_iter_cached, b.per_iter_uncached
            );
            pass = false;
        }
    }

    // Statistics gates (the PR 10 observability acceptance): with
    // `-stats` on, every accounting workload must surface a non-empty
    // heavy-hitter table and a finite worker-skew ratio (max/mean busy
    // time is >= 1 by construction, 1.0 exactly when idle).
    for b in [&lm, &km, &mb, &ln] {
        let s = &b.long_cached;
        if s.heavy.is_empty() {
            eprintln!(
                "FAIL: {} produced an empty heavy-hitter table with stats enabled",
                b.name
            );
            pass = false;
        }
        if !s.skew.is_finite() || s.skew < 1.0 {
            eprintln!(
                "FAIL: {} worker-skew ratio {} is not a finite value >= 1",
                b.name, s.skew
            );
            pass = false;
        }
    }

    // Resident-training gates (the PR 7 tentpole acceptance): the whole
    // multi-epoch job must run at **0 driver collects** — not 0 marginal,
    // absolute zero including warmup — with byte-identical results at
    // every cluster width, and the allreduce shuffle volume must grow
    // exactly with ceil(log2(workers)).
    for r in &resident {
        if r.collects != 0 {
            eprintln!(
                "FAIL: resident lenet at {} workers performed {} driver collects (must be 0 for the whole job)",
                r.workers, r.collects
            );
            pass = false;
        }
        if r.result.to_bits() != resident[0].result.to_bits() {
            eprintln!(
                "FAIL: resident lenet result diverged across worker counts: {} vs {}",
                r.result, resident[0].result
            );
            pass = false;
        }
    }
    let base_ar = resident[0].allreduce_bytes;
    if base_ar == 0 {
        eprintln!("FAIL: resident lenet recorded no allreduce traffic — gradients are not tree-reduced");
        pass = false;
    } else if resident[1].allreduce_bytes != 2 * base_ar
        || resident[2].allreduce_bytes != 3 * base_ar
    {
        eprintln!(
            "FAIL: allreduce bytes off the log2(workers) model: w2={} w4={} w8={} (want exact 1:2:3)",
            base_ar, resident[1].allreduce_bytes, resident[2].allreduce_bytes
        );
        pass = false;
    }
    if resident.iter().any(|r| r.allreduce_bytes > r.comm_bytes) {
        eprintln!("FAIL: allreduce bytes exceed the comm volume — not charged to shuffle accounting");
        pass = false;
    }

    // Sparse-backend gates (the PR 8 tentpole acceptance): the sparse
    // run must actually exercise the blocked backend (nonzero blockify
    // and comm volume — a silently-CP run would pass any ratio), and its
    // communication must come in at ≤25% of the dense twin's, which only
    // happens when broadcast/shuffle volume is charged by encoded CSR
    // bytes rather than dense dimensions.
    if sp_run.blockify == 0 || sp_run.comm_bytes == 0 {
        eprintln!(
            "FAIL: sparse logistic did not run on the blocked backend (blockify={}, comm={})",
            sp_run.blockify, sp_run.comm_bytes
        );
        pass = false;
    }
    if sp_run.comm_bytes * 4 > dn_run.comm_bytes {
        eprintln!(
            "FAIL: sparse logistic comm {} B exceeds 25% of the dense twin's {} B — \
             communication is not being charged by encoded bytes",
            sp_run.comm_bytes, dn_run.comm_bytes
        );
        pass = false;
    }
    if !sp_run.result.is_finite() || !dn_run.result.is_finite() {
        eprintln!(
            "FAIL: sparse logistic produced a non-finite result (sparse {}, dense {})",
            sp_run.result, dn_run.result
        );
        pass = false;
    }

    // Serving gates (the PR 9 tentpole acceptance): tail queueing latency
    // within 5x the median at the default knobs — nearest-rank over
    // simulated ticks, a pure function of (seed, knobs), so this cannot
    // flake — sustained throughput strictly above the batch=1 baseline
    // over the same rows, zero driver collects after warmup, and one plan
    // compile for the single padded batch geometry.
    if sv.p99_ticks > 5 * sv.p50_ticks {
        eprintln!(
            "FAIL: serving p99 {} ticks exceeds 5x p50 {} ticks — the wait bound is not capping tail latency",
            sv.p99_ticks, sv.p50_ticks
        );
        pass = false;
    }
    if sv.rows_per_sec <= sv.batch1_rows_per_sec {
        eprintln!(
            "FAIL: batched serving throughput {:.0} rows/s does not beat the batch=1 baseline {:.0} rows/s",
            sv.rows_per_sec, sv.batch1_rows_per_sec
        );
        pass = false;
    }
    if sv.collects != 0 {
        eprintln!(
            "FAIL: warm serving run performed {} driver collects (must be 0 after warmup)",
            sv.collects
        );
        pass = false;
    }
    if sv.compiles != 1 {
        eprintln!(
            "FAIL: serving compiled {} plans — plans must be cached per padded geometry, not per batch",
            sv.compiles
        );
        pass = false;
    }

    // Parallel-speedup gate (the PR 6 tentpole acceptance), adaptive to
    // the runner: a 4-thread pool cannot beat 1.5x on fewer than 4
    // hardware threads, so the bar drops to 1.15x on 2-3 cores and the
    // gate is skipped (reported, not enforced) on a single core. The
    // thresholds are deliberately generous vs the ideal 4x/2x to absorb
    // shared-runner noise.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let lenet_wall = &walls[3];
    let (min_speedup, gate_status) = if hw >= 4 {
        (1.5, if lenet_wall.speedup() >= 1.5 { "pass" } else { "fail" })
    } else if hw >= 2 {
        (1.15, if lenet_wall.speedup() >= 1.15 { "pass" } else { "fail" })
    } else {
        (0.0, "skipped")
    };
    if gate_status == "fail" {
        eprintln!(
            "FAIL: lenet wall speedup {:.2}x < {min_speedup}x on {hw} hardware threads — \
             the worker pool is not delivering parallel wall-clock wins",
            lenet_wall.speedup()
        );
        pass = false;
    } else if gate_status == "skipped" {
        println!("speedup gate skipped: single hardware thread (speedup {:.2}x reported only)", lenet_wall.speedup());
    }

    // Packed-kernel gate: the packed GEMM must beat the old kernel's
    // throughput (best-of-3 each, so a single scheduler hiccup cannot
    // flip the comparison).
    if packed_gflops <= reference_gflops {
        eprintln!(
            "FAIL: packed GEMM {packed_gflops:.2} GFLOP/s does not beat the reference kernel {reference_gflops:.2} GFLOP/s"
        );
        pass = false;
    }

    let wall_fields = walls
        .iter()
        .map(|w| {
            format!(
                "    \"{}_serial_ms\": {:.2},\n    \"{}_parallel_ms\": {:.2},\n    \"{}_speedup\": {:.3}",
                w.name, w.serial_ms, w.name, w.parallel_ms, w.name,
                w.speedup()
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let wall_json = format!(
        "  \"wall\": {{\n    \"threads\": 4,\n    \"hw_threads\": {hw},\n{wall_fields},\n    \"lenet_gate_min_speedup\": {min_speedup},\n    \"lenet_gate\": \"{gate_status}\"\n  }}"
    );
    let gemm_json = format!(
        "  \"gemm\": {{\n    \"size\": {GEMM_N},\n    \"packed_gflops\": {packed_gflops:.3},\n    \"reference_gflops\": {reference_gflops:.3},\n    \"speedup\": {:.3}\n  }}",
        packed_gflops / reference_gflops.max(1e-9)
    );
    let r4 = &resident[1];
    let resident_json = format!(
        concat!(
            "  \"lenet_resident\": {{\n",
            "    \"epochs\": 3,\n",
            "    \"workers\": {},\n",
            "    \"total_collects\": {},\n",
            "    \"allreduce_rounds\": {},\n",
            "    \"allreduce_bytes\": {},\n",
            "    \"allreduce_bytes_w2\": {},\n",
            "    \"allreduce_bytes_w4\": {},\n",
            "    \"allreduce_bytes_w8\": {},\n",
            "    \"comm_bytes\": {},\n",
            "    \"blockify_total\": {},\n",
            "    \"wall_ms\": {:.2},\n",
            "    \"result\": {}\n",
            "  }}"
        ),
        r4.workers,
        r4.collects,
        r4.allreduce_rounds,
        r4.allreduce_bytes,
        resident[0].allreduce_bytes,
        resident[1].allreduce_bytes,
        resident[2].allreduce_bytes,
        r4.comm_bytes,
        r4.blockify,
        r4.wall_ms,
        r4.result,
    );
    let sparse_json = format!(
        concat!(
            "  \"sparse_logistic\": {{\n",
            "    \"density\": {},\n",
            "    \"sparse_comm_bytes\": {},\n",
            "    \"dense_comm_bytes\": {},\n",
            "    \"comm_ratio\": {:.4},\n",
            "    \"sparse_shuffle_bytes\": {},\n",
            "    \"sparse_broadcast_bytes\": {},\n",
            "    \"sparse_blockify_total\": {},\n",
            "    \"sparse_collects_total\": {},\n",
            "    \"sparse_wall_ms\": {:.2},\n",
            "    \"dense_wall_ms\": {:.2},\n",
            "    \"result\": {}\n",
            "  }}"
        ),
        sp_run.density,
        sp_run.comm_bytes,
        dn_run.comm_bytes,
        sp_run.comm_bytes as f64 / (dn_run.comm_bytes as f64).max(1.0),
        sp_run.shuffle_bytes,
        sp_run.broadcast_bytes,
        sp_run.blockify,
        sp_run.collects,
        sp_run.wall_ms,
        dn_run.wall_ms,
        sp_run.result,
    );
    let serving_json = format!(
        concat!(
            "  \"serving\": {{\n",
            "    \"requests\": {},\n",
            "    \"batches\": {},\n",
            "    \"compiles\": {},\n",
            "    \"collects_after_warmup\": {},\n",
            "    \"p50_latency_ticks\": {},\n",
            "    \"p99_latency_ticks\": {},\n",
            "    \"p50_wall_ms\": {:.4},\n",
            "    \"p99_wall_ms\": {:.4},\n",
            "    \"rows_per_sec\": {:.1},\n",
            "    \"batch1_rows_per_sec\": {:.1},\n",
            "    \"throughput_ratio\": {:.3},\n",
            "    \"comm_bytes\": {},\n",
            "    \"wall_ms\": {:.2}\n",
            "  }}"
        ),
        sv.requests,
        sv.batches,
        sv.compiles,
        sv.collects,
        sv.p50_ticks,
        sv.p99_ticks,
        sv.p50_wall_ms,
        sv.p99_wall_ms,
        sv.rows_per_sec,
        sv.batch1_rows_per_sec,
        sv.rows_per_sec / sv.batch1_rows_per_sec.max(1e-9),
        sv.comm_bytes,
        sv.wall_ms,
    );
    let json = format!(
        "{{\n{},\n{},\n{},\n{},\n{},\n{},\n{},\n{},\n{},\n  \"gate\": {{ \"max_blockify_per_iter\": 1.0, \"kmeans_max_blockify_per_iter\": 3.0, \"max_collects_per_iter\": 0.0, \"resident_max_collects_total\": 0.0, \"sparse_max_comm_ratio\": 0.25, \"serving_max_p99_over_p50\": 5.0, \"serving_max_collects\": 0.0, \"pass\": {} }}\n}}\n",
        json_entry(&lm),
        json_entry(&km),
        json_entry(&mb),
        json_entry(&ln),
        resident_json,
        sparse_json,
        serving_json,
        wall_json,
        gemm_json,
        pass
    );
    std::fs::write("BENCH_dist.json", &json).expect("write BENCH_dist.json");
    println!("\nwrote BENCH_dist.json");
    // Self-check that the emitted report is well-formed JSON.
    systemml::util::json::Json::parse(&json).expect("BENCH_dist.json must parse");

    // Structured-trace artifact: one short traced lm_cg run writes
    // TRACE_lm_cg.jsonl (JSON-lines session/script/statement/operator
    // spans plus blockify/broadcast/shuffle/cache events) for CI to
    // upload, and its `-stats` table goes to the log.
    {
        let (x, ylab) = synthetic_classification(400, 64, 4, 42);
        let y = reorg::slice(&ylab, 0, 400, 0, 1).unwrap();
        let cfg = SystemConfig::builder()
            .driver_memory(128 * 1024)
            .block_size(64)
            .num_workers(4)
            .cache_enabled(true)
            .stats_enabled(true)
            .trace_path("TRACE_lm_cg.jsonl")
            .build();
        let ctx = MLContext::with_config(cfg);
        let script = Script::from_str(LM_CG)
            .input("X", x)
            .input("y", y)
            .input_scalar("lambda", 0.001)
            .input_scalar("max_iter", 4.0)
            .output("final_norm");
        ctx.execute(script).expect("traced lm_cg failed");
        println!("\nwrote TRACE_lm_cg.jsonl; lm_cg statistics:");
        print!("{}", ctx.statistics());
    }

    // Keep the empty-matrix regression visible where CI watches perf: a
    // 0-row slice must blockify to an empty handle, not an error.
    let empty = Matrix::zeros(0, 8);
    let cluster = systemml::runtime::dist::Cluster::new(2, 4);
    let handle = cluster.blockify(&empty).expect("empty blockify must succeed");
    assert_eq!(handle.shape(), (0, 8));

    if !pass {
        std::process::exit(1);
    }
    println!(
        "bench gate OK: loop-invariant operands stay resident, batch slices, \
         broadcast cellwise and conv/pool stay blocked, zero collects per iteration, \
         resident momentum training runs whole multi-epoch jobs at zero collects with \
         log2-scaling allreduce traffic, sparse logistic moves ≤25% of the dense \
         twin's bytes, micro-batched serving holds p99 within 5x p50 and beats \
         the batch=1 baseline at zero warm collects, worker pool delivers its \
         wall-clock bar, packed GEMM beats the reference kernel"
    );
}
