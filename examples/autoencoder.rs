//! Autoencoder training (paper §3: "we support a variety of deep learning
//! models in SystemML such as LeNet, feedforward nets, ResNets,
//! autoencoders, ..."): a 2-layer tied-width autoencoder on synthetic
//! images, trained with Adam from the DML optimizer library, plus PCA
//! (scripts/algorithms) as the classic-ML baseline on the same data —
//! the unified ML+DL framework in one script.
//!
//! ```bash
//! cargo run --release --example autoencoder
//! ```

use systemml::api::{MLContext, Script};
use systemml::runtime::matrix::randgen::synthetic_images;

const AE: &str = r#"
source("nn/layers/affine.dml") as affine
source("nn/layers/sigmoid.dml") as sigmoid
source("nn/layers/l2_loss.dml") as l2
source("nn/optim/adam.dml") as adam
source("algorithms/pca.dml") as pca

D = ncol(X)
H = 16
lr = 0.005
batch_size = 32
N = nrow(X)

[W1, b1] = affine::init(D, H)
[W2, b2] = affine::init(H, D)
[mW1, vW1] = adam::init(W1); [mb1, vb1] = adam::init(b1)
[mW2, vW2] = adam::init(W2); [mb2, vb2] = adam::init(b2)

iters = (N %/% batch_size) * epochs
losses = matrix(0, rows=iters, cols=1)
t = 0
for (ep in 1:epochs) {
  for (bi in 1:(N %/% batch_size)) {
    t = t + 1
    beg = (bi-1)*batch_size + 1; end = bi*batch_size
    Xb = X[beg:end,]
    # encode / decode
    hpre = affine::forward(Xb, W1, b1)
    h = sigmoid::forward(hpre)
    rec = affine::forward(h, W2, b2)
    losses[t, 1] = l2::forward(rec, Xb)
    # backward
    drec = l2::backward(rec, Xb)
    [dh, dW2, db2] = affine::backward(drec, h, W2, b2)
    dhpre = sigmoid::backward(dh, hpre)
    [dXb, dW1, db1] = affine::backward(dhpre, Xb, W1, b1)
    # adam updates
    [W1, mW1, vW1] = adam::update(W1, dW1, lr, 0.9, 0.999, 1e-8, t, mW1, vW1)
    [b1, mb1, vb1] = adam::update(b1, db1, lr, 0.9, 0.999, 1e-8, t, mb1, vb1)
    [W2, mW2, vW2] = adam::update(W2, dW2, lr, 0.9, 0.999, 1e-8, t, mW2, vW2)
    [b2, mb2, vb2] = adam::update(b2, db2, lr, 0.9, 0.999, 1e-8, t, mb2, vb2)
  }
}
first_loss = as.scalar(losses[1, 1])
last_loss = as.scalar(losses[iters, 1])

# Classic-ML baseline on the same data: PCA reconstruction error with the
# same latent width.
[components, evalues] = pca::train(X, H, 40)
Z = pca::transform(X, components)
Xrec = Z %*% t(components) + colMeans(X)
pca_mse = 0.5 * sum((Xrec - X)^2) / nrow(X)
"#;

fn main() {
    let (x, _y) = synthetic_images(256, 1, 12, 12, 6, 77);
    let ctx = MLContext::new();
    let t0 = std::time::Instant::now();
    let res = ctx
        .execute(
            Script::from_str(AE)
                .input("X", x)
                .input_scalar("epochs", 20.0)
                .output("first_loss")
                .output("last_loss")
                .output("pca_mse"),
        )
        .expect("autoencoder failed");
    let first = res.double("first_loss").unwrap();
    let last = res.double("last_loss").unwrap();
    let pca = res.double("pca_mse").unwrap();
    println!("autoencoder (Adam, 160 steps) in {:?}", t0.elapsed());
    println!("  reconstruction loss: {first:.4} -> {last:.4}");
    println!("  PCA (same latent width) reconstruction mse: {pca:.4}");
    assert!(last < first * 0.2, "AE loss must drop 5x: {first} -> {last}");
    println!("autoencoder OK");
}
