//! Quick perf probe for the dense matmult kernel variants.
use systemml::runtime::matrix::mult;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::util::bench::bench;

fn main() {
    for n in [256usize, 512, 768] {
        let a = rand(n, n, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
        let b = rand(n, n, -1.0, 1.0, 1.0, Pdf::Uniform, 2).unwrap();
        let m = bench(&format!("mm{n}"), || {
            mult::matmult(&a, &b).unwrap();
        });
        let gf = 2.0 * (n * n * n) as f64 / m.median.as_secs_f64() / 1e9;
        println!("{n}: {:?} -> {gf:.2} GFLOP/s", m.median);
    }
}
