//! Keras2DML path (paper §2 Python listing): define a sequential model as
//! a Keras-style JSON config, let the system generate the DML, and drive
//! fit/predict with `train_algo="minibatch"`, `test_algo="allreduce"`.
//!
//! ```bash
//! cargo run --release --example keras2dml_mlp
//! ```

use systemml::nn::keras2dml::{Keras2DML, SequentialModel};
use systemml::runtime::matrix::agg;
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::util::metrics;
use systemml::MLContext;

const MODEL_JSON: &str = r#"{
    "name": "mnist_mlp",
    "input_dim": 64,
    "layers": [
        {"type": "dense", "units": 128, "activation": "relu"},
        {"type": "dropout", "rate": 0.2},
        {"type": "dense", "units": 32, "activation": "relu"},
        {"type": "dense", "units": 8, "activation": "softmax"}
    ],
    "optimizer": {"type": "sgd", "lr": 0.05}
}"#;

fn main() {
    // Equivalent of the paper's:
    //   model = Sequential(); model.add(Dense(...)); ...
    //   sysml_model = Keras2DML(spark, model, input_shape=(D,1,1))
    //   sysml_model.set(train_algo="minibatch", test_algo="allreduce")
    //   sysml_model.fit(X, Y)
    let model = SequentialModel::from_json(MODEL_JSON).expect("model json");
    let mut k2d = Keras2DML::new(MLContext::new(), model);
    k2d.set("minibatch", "allreduce");
    k2d.fit_config.epochs = 3;

    println!("generated training DML:\n---");
    let dml = k2d.model.to_dml(&k2d.fit_config).unwrap();
    for line in dml.lines().take(18) {
        println!("{line}");
    }
    println!("... ({} lines total)\n---", dml.lines().count());

    let (x, y) = synthetic_classification(2048, 64, 8, 99);
    let t0 = std::time::Instant::now();
    let trained = k2d.fit(x.clone(), y.clone()).expect("fit");
    println!(
        "fit: {} iterations in {:?}; loss {:.4} -> {:.4}",
        trained.loss_curve.len(),
        t0.elapsed(),
        trained.loss_curve.first().unwrap(),
        trained.loss_curve.last().unwrap()
    );

    // allreduce scoring: row-partitioned parfor, no shuffle.
    let before = metrics::global().snapshot();
    let probs = k2d.predict(&trained, x).expect("predict");
    let d = metrics::global().snapshot().delta(&before);
    let pred = agg::row_index_max(&probs);
    let truth = agg::row_index_max(&y);
    let correct = (0..pred.rows()).filter(|r| pred.get(*r, 0) == truth.get(*r, 0)).count();
    println!(
        "predict (test_algo=allreduce): {} parfor tasks, {} shuffle bytes, accuracy {:.1}%",
        d.parfor_tasks,
        d.shuffle_bytes,
        100.0 * correct as f64 / pred.rows() as f64
    );
    assert_eq!(d.shuffle_bytes, 0);
    assert!(correct * 3 > pred.rows(), "model should beat chance comfortably");
    println!("keras2dml OK");
}
