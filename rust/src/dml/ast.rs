//! Abstract syntax tree for DML (Declarative Machine Learning language).
//!
//! DML is the R-like language of the paper's §2: matrix-typed variables,
//! user-defined functions with multiple returns, `source(...) as ns`
//! imports, control flow (`if`/`for`/`while`/`parfor`), 1-based inclusive
//! matrix indexing, and a large builtin library.

/// Source position (1-based line/col) for error reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Pos {
    pub line: usize,
    pub col: usize,
}

/// DML value types (scalars are double/int/boolean/string; `matrix[double]`
/// is the only matrix type, as in SystemML 1.x).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueType {
    Double,
    Int,
    Boolean,
    Str,
    Matrix,
    /// Unknown until validation (e.g. untyped function args in practice).
    Unknown,
}

/// A parsed program: top-level statements plus function definitions
/// (possibly inside namespaces populated by `source`).
#[derive(Clone, Debug, Default)]
pub struct Program {
    /// `source("path") as ns` imports discovered during parsing.
    pub imports: Vec<Import>,
    /// Functions defined at top level of this file.
    pub functions: Vec<FunctionDef>,
    /// Executable top-level statements.
    pub body: Vec<Stmt>,
}

/// A `source("file.dml") as ns` import.
#[derive(Clone, Debug, PartialEq)]
pub struct Import {
    pub path: String,
    pub namespace: String,
    pub pos: Pos,
}

/// Function definition: `name = function(args) return (rets) { body }`.
#[derive(Clone, Debug)]
pub struct FunctionDef {
    pub name: String,
    pub params: Vec<Param>,
    pub returns: Vec<Param>,
    pub body: Vec<Stmt>,
    pub pos: Pos,
}

/// Typed parameter, optionally with a default (DML allows `int x = 5`).
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub vtype: ValueType,
    pub default: Option<Expr>,
}

/// Statements.
#[derive(Clone, Debug)]
pub enum Stmt {
    /// `x = expr` or `X[i:j, k:l] = expr` (left indexing).
    Assign { target: AssignTarget, value: Expr, pos: Pos },
    /// `[a, b] = f(...)` multi-assignment from a multi-return function.
    MultiAssign { targets: Vec<String>, value: Expr, pos: Pos },
    /// `if (cond) { .. } else { .. }`.
    If { cond: Expr, then_branch: Vec<Stmt>, else_branch: Vec<Stmt>, pos: Pos },
    /// `for (i in from:to)` or `for (i in seq(a,b,c))`.
    For { var: String, range: RangeExpr, body: Vec<Stmt>, pos: Pos },
    /// `parfor (i in from:to, opts)` — task-parallel loop (paper §3).
    ParFor {
        var: String,
        range: RangeExpr,
        body: Vec<Stmt>,
        /// Parsed options: check=, par=, mode=, resultmerge=.
        opts: ParForOpts,
        pos: Pos,
    },
    /// `while (cond) { .. }`.
    While { cond: Expr, body: Vec<Stmt>, pos: Pos },
    /// Bare expression statement (e.g. `print(...)`).
    ExprStmt { expr: Expr, pos: Pos },
}

/// parfor options (subset of SystemML's).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParForOpts {
    /// check=0 disables the dependency analysis (expert mode).
    pub check: bool,
    /// Requested degree of parallelism (0 = let the optimizer pick).
    pub par: usize,
    /// Execution mode hint: "local", "remote", or "" (optimizer decides).
    pub mode: String,
}

impl ParForOpts {
    pub fn new() -> Self {
        ParForOpts { check: true, par: 0, mode: String::new() }
    }
}

/// Assignment target: scalar/matrix variable or an indexed region.
#[derive(Clone, Debug)]
pub enum AssignTarget {
    Var(String),
    /// X[rows, cols] = ... with optional ranges (None = all).
    Indexed { name: String, rows: IndexRange, cols: IndexRange },
}

/// One dimension of an indexing expression.
#[derive(Clone, Debug)]
pub enum IndexRange {
    /// `X[, j]` — whole dimension.
    All,
    /// `X[i, _]` — single index.
    Single(Box<Expr>),
    /// `X[a:b, _]` — inclusive range.
    Range(Box<Expr>, Box<Expr>),
}

/// Loop range: `from:to` (step 1) or general seq with step.
#[derive(Clone, Debug)]
pub struct RangeExpr {
    pub from: Box<Expr>,
    pub to: Box<Expr>,
    pub step: Option<Box<Expr>>,
}

/// Binary operators at the AST level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AstBinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Mod,
    IntDiv,
    MatMul,
    Eq,
    Neq,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// Unary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AstUnOp {
    Neg,
    Not,
}

/// Expressions.
#[derive(Clone, Debug)]
pub enum Expr {
    /// Numeric literal.
    Num(f64, Pos),
    /// Integer literal (kept separate for loop semantics).
    Int(i64, Pos),
    /// String literal.
    Str(String, Pos),
    /// `TRUE` / `FALSE`.
    Bool(bool, Pos),
    /// Variable reference.
    Var(String, Pos),
    /// `ns::name` reference (function value in call position only).
    Binary { op: AstBinOp, lhs: Box<Expr>, rhs: Box<Expr>, pos: Pos },
    Unary { op: AstUnOp, operand: Box<Expr>, pos: Pos },
    /// Function or builtin call: `f(args)` or `ns::f(args)`. Named args
    /// (`rows=10`) are kept separately.
    Call { namespace: Option<String>, name: String, args: Vec<Arg>, pos: Pos },
    /// Right indexing `X[r, c]`.
    Index { base: Box<Expr>, rows: IndexRange, cols: IndexRange, pos: Pos },
    /// List literal `[a, b, c]` — used for shape arguments of the NN
    /// builtins (e.g. `conv2d(X, W, input_shape=[N,C,H,W], ...)`).
    List(Vec<Expr>, Pos),
}

/// Call argument, optionally named.
#[derive(Clone, Debug)]
pub struct Arg {
    pub name: Option<String>,
    pub value: Expr,
}

impl Expr {
    pub fn pos(&self) -> Pos {
        match self {
            Expr::Num(_, p)
            | Expr::Int(_, p)
            | Expr::Str(_, p)
            | Expr::Bool(_, p)
            | Expr::Var(_, p) => *p,
            Expr::List(_, p) => *p,
            Expr::Binary { pos, .. }
            | Expr::Unary { pos, .. }
            | Expr::Call { pos, .. }
            | Expr::Index { pos, .. } => *pos,
        }
    }
}

impl Stmt {
    pub fn pos(&self) -> Pos {
        match self {
            Stmt::Assign { pos, .. }
            | Stmt::MultiAssign { pos, .. }
            | Stmt::If { pos, .. }
            | Stmt::For { pos, .. }
            | Stmt::ParFor { pos, .. }
            | Stmt::While { pos, .. }
            | Stmt::ExprStmt { pos, .. } => *pos,
        }
    }
}
