//! Static validation: scope checking, namespace resolution, and best-effort
//! type/shape propagation.
//!
//! Runs after parsing and import resolution, before execution. Mirrors
//! SystemML's inter-procedural validate pass (simplified): every referenced
//! variable must be assigned on all paths before use, every called function
//! must exist (builtin, local, or in a sourced namespace) with a compatible
//! arity, and scalar/matrix confusion is flagged where statically decidable.

use std::collections::{HashMap, HashSet};

use crate::dml::ast::*;
use crate::util::error::{DmlError, Result};

/// Names of all builtin functions the runtime provides.
pub fn builtin_names() -> &'static [&'static str] {
    &[
        "nrow", "ncol", "length", "sum", "mean", "sd", "var", "min", "max", "prod", "rowSums",
        "colSums", "rowMeans", "colMeans", "rowMaxs", "colMaxs", "rowMins", "colMins",
        "rowIndexMax", "trace", "t", "exp", "log", "sqrt", "abs", "round", "floor", "ceiling",
        "ceil", "sign", "sin", "cos", "tan", "sigmoid", "rand", "matrix", "seq", "cbind", "rbind",
        "diag", "outer", "table", "solve", "inv", "rev", "removeEmpty", "as.scalar", "as.matrix",
        "as.integer", "as.double", "as.logical", "print", "toString", "stop", "ifelse", "cumsum",
        "nnz", "conv2d", "conv2d_backward_filter", "conv2d_backward_data", "max_pool",
        "max_pool_backward", "avg_pool", "avg_pool_backward", "bias_add", "bias_multiply", "time",
        "assert",
    ]
}

/// A validated program bundle: the main program plus all sourced namespaces.
#[derive(Clone, Debug, Default)]
pub struct Bundle {
    pub main: Program,
    /// namespace -> (function name -> def)
    pub namespaces: HashMap<String, HashMap<String, FunctionDef>>,
}

impl Bundle {
    /// Look up a function by optional namespace.
    pub fn resolve(&self, ns: Option<&str>, name: &str) -> Option<&FunctionDef> {
        match ns {
            Some(ns) => self.namespaces.get(ns).and_then(|m| m.get(name)),
            None => self.main.functions.iter().find(|f| f.name == name),
        }
    }
}

/// Validate a bundle; returns the list of warnings (non-fatal findings).
pub fn validate(bundle: &Bundle) -> Result<Vec<String>> {
    let mut v = Validator { bundle, warnings: Vec::new() };
    // Validate each function body with its params in scope.
    for f in &bundle.main.functions {
        v.check_function(f, None)?;
    }
    for (ns, funcs) in &bundle.namespaces {
        for f in funcs.values() {
            v.check_function(f, Some(ns))?;
        }
    }
    // Top-level statements: empty initial scope.
    let mut scope: HashSet<String> = HashSet::new();
    v.check_block(&bundle.main.body, &mut scope, None)?;
    Ok(v.warnings)
}

struct Validator<'a> {
    bundle: &'a Bundle,
    warnings: Vec<String>,
}

impl<'a> Validator<'a> {
    fn check_function(&mut self, f: &FunctionDef, ns: Option<&str>) -> Result<()> {
        let mut scope: HashSet<String> = f.params.iter().map(|p| p.name.clone()).collect();
        // Defaults may reference earlier params only.
        self.check_block(&f.body, &mut scope, ns)?;
        // All declared returns must be assigned somewhere in the body.
        for r in &f.returns {
            if !scope.contains(&r.name) {
                return Err(DmlError::val(format!(
                    "function '{}' (line {}): return variable '{}' is never assigned",
                    f.name, f.pos.line, r.name
                )));
            }
        }
        Ok(())
    }

    fn check_block(
        &mut self,
        stmts: &[Stmt],
        scope: &mut HashSet<String>,
        ns: Option<&str>,
    ) -> Result<()> {
        for s in stmts {
            self.check_stmt(s, scope, ns)?;
        }
        Ok(())
    }

    fn check_stmt(
        &mut self,
        stmt: &Stmt,
        scope: &mut HashSet<String>,
        ns: Option<&str>,
    ) -> Result<()> {
        match stmt {
            Stmt::Assign { target, value, .. } => {
                self.check_expr(value, scope, ns)?;
                match target {
                    AssignTarget::Var(name) => {
                        scope.insert(name.clone());
                    }
                    AssignTarget::Indexed { name, rows, cols } => {
                        if !scope.contains(name) {
                            return Err(DmlError::val(format!(
                                "left-indexing into undefined variable '{name}' (line {})",
                                stmt.pos().line
                            )));
                        }
                        self.check_range(rows, scope, ns)?;
                        self.check_range(cols, scope, ns)?;
                    }
                }
            }
            Stmt::MultiAssign { targets, value, pos } => {
                self.check_expr(value, scope, ns)?;
                // Value must be a call to a function with enough returns.
                if let Expr::Call { namespace, name, .. } = value {
                    if let Some(f) = self.bundle.resolve(namespace.as_deref(), name) {
                        if f.returns.len() < targets.len() {
                            return Err(DmlError::val(format!(
                                "line {}: [{}] = {}(...) unpacks {} values but function returns {}",
                                pos.line,
                                targets.join(", "),
                                name,
                                targets.len(),
                                f.returns.len()
                            )));
                        }
                    }
                } else {
                    return Err(DmlError::val(format!(
                        "line {}: multi-assignment requires a function call on the right",
                        pos.line
                    )));
                }
                for t in targets {
                    scope.insert(t.clone());
                }
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                self.check_expr(cond, scope, ns)?;
                // Variables defined in both branches are defined after.
                let mut then_scope = scope.clone();
                self.check_block(then_branch, &mut then_scope, ns)?;
                let mut else_scope = scope.clone();
                self.check_block(else_branch, &mut else_scope, ns)?;
                for name in then_scope.intersection(&else_scope) {
                    scope.insert(name.clone());
                }
            }
            Stmt::For { var, range, body, .. } | Stmt::ParFor { var, range, body, .. } => {
                self.check_expr(&range.from, scope, ns)?;
                self.check_expr(&range.to, scope, ns)?;
                if let Some(step) = &range.step {
                    self.check_expr(step, scope, ns)?;
                }
                scope.insert(var.clone());
                // Loop may run zero times, but DML treats loop-defined vars
                // as visible after (runtime errors if unset); we propagate.
                self.check_block(body, scope, ns)?;
            }
            Stmt::While { cond, body, .. } => {
                self.check_expr(cond, scope, ns)?;
                self.check_block(body, scope, ns)?;
            }
            Stmt::ExprStmt { expr, .. } => {
                self.check_expr(expr, scope, ns)?;
            }
        }
        Ok(())
    }

    fn check_range(
        &mut self,
        r: &IndexRange,
        scope: &HashSet<String>,
        ns: Option<&str>,
    ) -> Result<()> {
        match r {
            IndexRange::All => Ok(()),
            IndexRange::Single(e) => self.check_expr(e, scope, ns),
            IndexRange::Range(a, b) => {
                self.check_expr(a, scope, ns)?;
                self.check_expr(b, scope, ns)
            }
        }
    }

    fn check_expr(&mut self, e: &Expr, scope: &HashSet<String>, ns: Option<&str>) -> Result<()> {
        match e {
            Expr::Num(..) | Expr::Int(..) | Expr::Str(..) | Expr::Bool(..) => Ok(()),
            Expr::Var(name, pos) => {
                if !scope.contains(name) {
                    return Err(DmlError::val(format!(
                        "line {}: undefined variable '{name}'",
                        pos.line
                    )));
                }
                Ok(())
            }
            Expr::List(items, _) => {
                for i in items {
                    self.check_expr(i, scope, ns)?;
                }
                Ok(())
            }
            Expr::Binary { lhs, rhs, .. } => {
                self.check_expr(lhs, scope, ns)?;
                self.check_expr(rhs, scope, ns)
            }
            Expr::Unary { operand, .. } => self.check_expr(operand, scope, ns),
            Expr::Index { base, rows, cols, .. } => {
                self.check_expr(base, scope, ns)?;
                self.check_range(rows, scope, ns)?;
                self.check_range(cols, scope, ns)
            }
            Expr::Call { namespace, name, args, pos } => {
                for a in args {
                    self.check_expr(&a.value, scope, ns)?;
                }
                // Resolution: namespaced → sourced; bare → builtin, then
                // local function, then same-namespace function.
                let resolved = if let Some(nsname) = namespace {
                    if self.bundle.resolve(Some(nsname), name).is_some() {
                        true
                    } else {
                        return Err(DmlError::val(format!(
                            "line {}: unknown function '{nsname}::{name}'",
                            pos.line
                        )));
                    }
                } else {
                    builtin_names().contains(&name.as_str())
                        || self.bundle.resolve(None, name).is_some()
                        || ns.map(|n| self.bundle.resolve(Some(n), name).is_some()).unwrap_or(false)
                };
                if !resolved {
                    return Err(DmlError::val(format!(
                        "line {}: unknown function '{name}'",
                        pos.line
                    )));
                }
                // Arity check for user functions (builtins are variadic-ish).
                let f = if let Some(nsname) = namespace {
                    self.bundle.resolve(Some(nsname), name)
                } else {
                    self.bundle
                        .resolve(None, name)
                        .or_else(|| ns.and_then(|n| self.bundle.resolve(Some(n), name)))
                };
                if let Some(f) = f {
                    let required = f.params.iter().filter(|p| p.default.is_none()).count();
                    if args.len() > f.params.len() || args.len() < required {
                        self.warnings.push(format!(
                            "line {}: call to '{}' with {} args (expects {}..{})",
                            pos.line,
                            name,
                            args.len(),
                            required,
                            f.params.len()
                        ));
                    }
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;

    fn bundle(src: &str) -> Bundle {
        Bundle { main: parse(src).unwrap(), namespaces: HashMap::new() }
    }

    #[test]
    fn undefined_variable_rejected() {
        let b = bundle("y = x + 1");
        assert!(validate(&b).is_err());
    }

    #[test]
    fn defined_after_assign_ok() {
        let b = bundle("x = 1\ny = x + 1");
        assert!(validate(&b).is_ok());
    }

    #[test]
    fn if_branch_vars_only_visible_when_both_assign() {
        let bad = bundle("a = 1\nif (a > 0) { b = 1 }\nc = b");
        assert!(validate(&bad).is_err());
        let good = bundle("a = 1\nif (a > 0) { b = 1 } else { b = 2 }\nc = b");
        assert!(validate(&good).is_ok());
    }

    #[test]
    fn unknown_function_rejected() {
        let b = bundle("y = frobnicate(1)");
        assert!(validate(&b).is_err());
        let b2 = bundle("y = sum(matrix(1, rows=2, cols=2))");
        assert!(validate(&b2).is_ok());
    }

    #[test]
    fn unknown_namespace_function_rejected() {
        let b = bundle("y = nn::forward(1)");
        assert!(validate(&b).is_err());
    }

    #[test]
    fn function_return_must_be_assigned() {
        let bad = bundle("f = function(int x) return (int y) { z = x }");
        assert!(validate(&bad).is_err());
        let good = bundle("f = function(int x) return (int y) { y = x }");
        assert!(validate(&good).is_ok());
    }

    #[test]
    fn multiassign_arity_checked() {
        let src = "f = function(int x) return (int a, int b) { a = x; b = x }\n[p, q, r] = f(1)";
        assert!(validate(&bundle(src)).is_err());
        let ok = "f = function(int x) return (int a, int b) { a = x; b = x }\n[p, q] = f(1)";
        assert!(validate(&bundle(ok)).is_ok());
    }

    #[test]
    fn loop_var_in_scope() {
        let b = bundle("s = 0\nfor (i in 1:10) { s = s + i }");
        assert!(validate(&b).is_ok());
    }

    #[test]
    fn left_index_requires_existing_target() {
        let bad = bundle("X[1,1] = 5");
        assert!(validate(&bad).is_err());
        let good = bundle("X = matrix(0, rows=2, cols=2)\nX[1,1] = 5");
        assert!(validate(&good).is_ok());
    }
}
