//! Recursive-descent parser for DML.
//!
//! Operator precedence follows R (which DML mirrors):
//! `||` < `&&` < `!` < comparisons < `+ -` < `* / %% %/%` < `%*%` <
//! unary `-` < `^` < postfix (indexing, calls).

use crate::dml::ast::*;
use crate::dml::lexer::{lex, Tok, Token};
use crate::util::error::{DmlError, Result};

/// Parse a DML source string into a [`Program`].
pub fn parse(src: &str) -> Result<Program> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.program()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }
    fn peek_at(&self, n: usize) -> &Tok {
        &self.toks[(self.pos + n).min(self.toks.len() - 1)].tok
    }
    fn here(&self) -> Pos {
        let t = &self.toks[self.pos];
        Pos { line: t.line, col: t.col }
    }
    fn advance(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }
    fn err(&self, msg: impl Into<String>) -> DmlError {
        let t = &self.toks[self.pos];
        DmlError::Parse { line: t.line, col: t.col, msg: msg.into() }
    }
    fn expect(&mut self, tok: Tok, what: &str) -> Result<()> {
        if *self.peek() == tok {
            self.advance();
            Ok(())
        } else {
            Err(self.err(format!("expected {what}, found {:?}", self.peek())))
        }
    }
    fn eat(&mut self, tok: &Tok) -> bool {
        if self.peek() == tok {
            self.advance();
            true
        } else {
            false
        }
    }
    fn skip_semis(&mut self) {
        while self.eat(&Tok::Semi) {}
    }

    // ---- program structure ------------------------------------------------

    fn program(&mut self) -> Result<Program> {
        let mut prog = Program::default();
        self.skip_semis();
        while *self.peek() != Tok::Eof {
            if *self.peek() == Tok::KwSource {
                prog.imports.push(self.import()?);
            } else if self.is_function_def() {
                prog.functions.push(self.function_def()?);
            } else {
                prog.body.push(self.statement()?);
            }
            self.skip_semis();
        }
        Ok(prog)
    }

    fn import(&mut self) -> Result<Import> {
        let pos = self.here();
        self.expect(Tok::KwSource, "'source'")?;
        self.expect(Tok::LParen, "'('")?;
        let path = match self.advance() {
            Tok::Str(s) => s,
            _ => return Err(self.err("expected string path in source(...)")),
        };
        self.expect(Tok::RParen, "')'")?;
        self.expect(Tok::KwAs, "'as'")?;
        let namespace = self.ident("namespace")?;
        Ok(Import { path, namespace, pos })
    }

    /// Lookahead: `ident = function` (or `ident <- function`).
    fn is_function_def(&self) -> bool {
        matches!(self.peek(), Tok::Ident(_))
            && *self.peek_at(1) == Tok::Assign
            && *self.peek_at(2) == Tok::KwFunction
    }

    fn function_def(&mut self) -> Result<FunctionDef> {
        let pos = self.here();
        let name = self.ident("function name")?;
        self.expect(Tok::Assign, "'='")?;
        self.expect(Tok::KwFunction, "'function'")?;
        self.expect(Tok::LParen, "'('")?;
        let params = self.param_list(Tok::RParen)?;
        self.expect(Tok::RParen, "')'")?;
        let mut returns = Vec::new();
        if self.eat(&Tok::KwReturn) {
            self.expect(Tok::LParen, "'(' after return")?;
            returns = self.param_list(Tok::RParen)?;
            self.expect(Tok::RParen, "')'")?;
        }
        let body = self.block()?;
        Ok(FunctionDef { name, params, returns, body, pos })
    }

    fn param_list(&mut self, end: Tok) -> Result<Vec<Param>> {
        let mut params = Vec::new();
        if *self.peek() == end {
            return Ok(params);
        }
        loop {
            params.push(self.param()?);
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        Ok(params)
    }

    /// `matrix[double] X`, `double lr = 0.01`, or bare `X`.
    fn param(&mut self) -> Result<Param> {
        let first = self.ident("parameter")?;
        let (vtype, name) = match first.as_str() {
            "matrix" => {
                // optional [double] element type
                if self.eat(&Tok::LBracket) {
                    self.ident("element type")?;
                    self.expect(Tok::RBracket, "']'")?;
                }
                (ValueType::Matrix, self.ident("parameter name")?)
            }
            "double" => (ValueType::Double, self.ident("parameter name")?),
            "int" | "integer" => (ValueType::Int, self.ident("parameter name")?),
            "boolean" | "bool" => (ValueType::Boolean, self.ident("parameter name")?),
            "string" | "str" => (ValueType::Str, self.ident("parameter name")?),
            _ => (ValueType::Unknown, first),
        };
        let default = if self.eat(&Tok::Assign) { Some(self.expr()?) } else { None };
        Ok(Param { name, vtype, default })
    }

    fn ident(&mut self, what: &str) -> Result<String> {
        match self.advance() {
            Tok::Ident(s) => Ok(s),
            other => {
                self.pos = self.pos.saturating_sub(1);
                Err(self.err(format!("expected {what}, found {other:?}")))
            }
        }
    }

    // ---- statements ---------------------------------------------------

    fn block(&mut self) -> Result<Vec<Stmt>> {
        if self.eat(&Tok::LBrace) {
            let mut stmts = Vec::new();
            self.skip_semis();
            while *self.peek() != Tok::RBrace {
                if *self.peek() == Tok::Eof {
                    return Err(self.err("unexpected end of file in block"));
                }
                stmts.push(self.statement()?);
                self.skip_semis();
            }
            self.expect(Tok::RBrace, "'}'")?;
            Ok(stmts)
        } else {
            Ok(vec![self.statement()?])
        }
    }

    fn statement(&mut self) -> Result<Stmt> {
        let pos = self.here();
        match self.peek() {
            Tok::KwIf => self.if_stmt(),
            Tok::KwFor => self.for_stmt(false),
            Tok::KwParFor => self.for_stmt(true),
            Tok::KwWhile => {
                self.advance();
                self.expect(Tok::LParen, "'('")?;
                let cond = self.expr()?;
                self.expect(Tok::RParen, "')'")?;
                let body = self.block()?;
                Ok(Stmt::While { cond, body, pos })
            }
            Tok::LBracket => self.multi_assign(),
            _ => self.assign_or_expr(),
        }
    }

    fn if_stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        self.expect(Tok::KwIf, "'if'")?;
        self.expect(Tok::LParen, "'('")?;
        let cond = self.expr()?;
        self.expect(Tok::RParen, "')'")?;
        let then_branch = self.block()?;
        let else_branch = if self.eat(&Tok::KwElse) {
            if *self.peek() == Tok::KwIf {
                vec![self.if_stmt()?]
            } else {
                self.block()?
            }
        } else {
            Vec::new()
        };
        Ok(Stmt::If { cond, then_branch, else_branch, pos })
    }

    fn for_stmt(&mut self, parallel: bool) -> Result<Stmt> {
        let pos = self.here();
        self.advance(); // for / parfor
        self.expect(Tok::LParen, "'('")?;
        let var = self.ident("loop variable")?;
        self.expect(Tok::KwIn, "'in'")?;
        let range = self.range_expr()?;
        let mut opts = ParForOpts::new();
        while self.eat(&Tok::Comma) {
            let key = self.ident("loop option")?;
            self.expect(Tok::Assign, "'='")?;
            match key.as_str() {
                "check" => {
                    let v = self.expr()?;
                    opts.check = !matches!(v, Expr::Int(0, _));
                }
                "par" => {
                    if let Expr::Int(n, _) = self.expr()? {
                        opts.par = n.max(0) as usize;
                    }
                }
                "mode" | "opt" => {
                    opts.mode = match self.advance() {
                        Tok::Ident(s) | Tok::Str(s) => s.to_lowercase(),
                        _ => return Err(self.err("expected mode value")),
                    };
                }
                other => return Err(self.err(format!("unknown loop option '{other}'"))),
            }
        }
        self.expect(Tok::RParen, "')'")?;
        let body = self.block()?;
        if parallel {
            Ok(Stmt::ParFor { var, range, body, opts, pos })
        } else {
            Ok(Stmt::For { var, range, body, pos })
        }
    }

    /// `from:to` or `seq(from, to, step)`.
    fn range_expr(&mut self) -> Result<RangeExpr> {
        // seq(...) form
        if let Tok::Ident(name) = self.peek() {
            if name == "seq" && *self.peek_at(1) == Tok::LParen {
                self.advance();
                self.advance();
                let from = self.expr()?;
                self.expect(Tok::Comma, "','")?;
                let to = self.expr()?;
                let step = if self.eat(&Tok::Comma) { Some(Box::new(self.expr()?)) } else { None };
                self.expect(Tok::RParen, "')'")?;
                return Ok(RangeExpr { from: Box::new(from), to: Box::new(to), step });
            }
        }
        let from = self.expr()?;
        self.expect(Tok::Colon, "':' in loop range")?;
        let to = self.expr()?;
        Ok(RangeExpr { from: Box::new(from), to: Box::new(to), step: None })
    }

    /// `[a, b] = f(...)`.
    fn multi_assign(&mut self) -> Result<Stmt> {
        let pos = self.here();
        self.expect(Tok::LBracket, "'['")?;
        let mut targets = Vec::new();
        loop {
            targets.push(self.ident("assignment target")?);
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RBracket, "']'")?;
        self.expect(Tok::Assign, "'='")?;
        let value = self.expr()?;
        Ok(Stmt::MultiAssign { targets, value, pos })
    }

    /// Assignment (incl. left-indexed), or a bare expression statement.
    fn assign_or_expr(&mut self) -> Result<Stmt> {
        let pos = self.here();
        // Try: ident [index]? = expr
        if let Tok::Ident(name) = self.peek().clone() {
            // Plain `x = expr`
            if *self.peek_at(1) == Tok::Assign {
                self.advance();
                self.advance();
                let value = self.expr()?;
                return Ok(Stmt::Assign { target: AssignTarget::Var(name), value, pos });
            }
            // Left-indexed `X[...] = expr`: scan for matching ']' then '='.
            if *self.peek_at(1) == Tok::LBracket {
                if let Some(close) = self.matching_bracket(self.pos + 1) {
                    if self.toks[close + 1].tok == Tok::Assign {
                        self.advance(); // name
                        self.advance(); // [
                        let (rows, cols) = self.index_ranges()?;
                        self.expect(Tok::RBracket, "']'")?;
                        self.expect(Tok::Assign, "'='")?;
                        let value = self.expr()?;
                        return Ok(Stmt::Assign {
                            target: AssignTarget::Indexed { name, rows, cols },
                            value,
                            pos,
                        });
                    }
                }
            }
        }
        let expr = self.expr()?;
        Ok(Stmt::ExprStmt { expr, pos })
    }

    /// Index of the `]` matching the `[` at token index `open`.
    fn matching_bracket(&self, open: usize) -> Option<usize> {
        let mut depth = 0usize;
        for i in open..self.toks.len() {
            match self.toks[i].tok {
                Tok::LBracket | Tok::LParen => depth += 1,
                Tok::RBracket | Tok::RParen => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(i);
                    }
                }
                Tok::Eof => return None,
                _ => {}
            }
        }
        None
    }

    /// The two dimensions of an index expression `rows, cols` (either may
    /// be empty, single, or a:b).
    fn index_ranges(&mut self) -> Result<(IndexRange, IndexRange)> {
        let rows = self.index_range_dim()?;
        let cols = if self.eat(&Tok::Comma) { self.index_range_dim()? } else { IndexRange::All };
        Ok((rows, cols))
    }

    fn index_range_dim(&mut self) -> Result<IndexRange> {
        if matches!(self.peek(), Tok::Comma | Tok::RBracket) {
            return Ok(IndexRange::All);
        }
        let lo = self.expr()?;
        if self.eat(&Tok::Colon) {
            let hi = self.expr()?;
            Ok(IndexRange::Range(Box::new(lo), Box::new(hi)))
        } else {
            Ok(IndexRange::Single(Box::new(lo)))
        }
    }

    // ---- expressions -----------------------------------------------------

    fn expr(&mut self) -> Result<Expr> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.and_expr()?;
        while *self.peek() == Tok::Or {
            let pos = self.here();
            self.advance();
            let rhs = self.and_expr()?;
            lhs = Expr::Binary { op: AstBinOp::Or, lhs: Box::new(lhs), rhs: Box::new(rhs), pos };
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.not_expr()?;
        while *self.peek() == Tok::And {
            let pos = self.here();
            self.advance();
            let rhs = self.not_expr()?;
            lhs = Expr::Binary { op: AstBinOp::And, lhs: Box::new(lhs), rhs: Box::new(rhs), pos };
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> Result<Expr> {
        if *self.peek() == Tok::Not {
            let pos = self.here();
            self.advance();
            let operand = self.not_expr()?;
            return Ok(Expr::Unary { op: AstUnOp::Not, operand: Box::new(operand), pos });
        }
        self.compare_expr()
    }

    fn compare_expr(&mut self) -> Result<Expr> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Eq => AstBinOp::Eq,
            Tok::Neq => AstBinOp::Neq,
            Tok::Lt => AstBinOp::Lt,
            Tok::Le => AstBinOp::Le,
            Tok::Gt => AstBinOp::Gt,
            Tok::Ge => AstBinOp::Ge,
            _ => return Ok(lhs),
        };
        let pos = self.here();
        self.advance();
        let rhs = self.add_expr()?;
        Ok(Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs), pos })
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => AstBinOp::Add,
                Tok::Minus => AstBinOp::Sub,
                _ => break,
            };
            let pos = self.here();
            self.advance();
            let rhs = self.mul_expr()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs), pos };
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.matmul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => AstBinOp::Mul,
                Tok::Slash => AstBinOp::Div,
                Tok::Mod => AstBinOp::Mod,
                Tok::IntDiv => AstBinOp::IntDiv,
                _ => break,
            };
            let pos = self.here();
            self.advance();
            let rhs = self.matmul_expr()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs), pos };
        }
        Ok(lhs)
    }

    fn matmul_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.unary_expr()?;
        while *self.peek() == Tok::MatMul {
            let pos = self.here();
            self.advance();
            let rhs = self.unary_expr()?;
            lhs =
                Expr::Binary { op: AstBinOp::MatMul, lhs: Box::new(lhs), rhs: Box::new(rhs), pos };
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr> {
        if *self.peek() == Tok::Minus {
            let pos = self.here();
            self.advance();
            let operand = self.unary_expr()?;
            return Ok(Expr::Unary { op: AstUnOp::Neg, operand: Box::new(operand), pos });
        }
        if *self.peek() == Tok::Plus {
            self.advance();
            return self.unary_expr();
        }
        self.power_expr()
    }

    fn power_expr(&mut self) -> Result<Expr> {
        let base = self.postfix_expr()?;
        if *self.peek() == Tok::Caret {
            let pos = self.here();
            self.advance();
            // Right associative.
            let exp = self.unary_expr()?;
            return Ok(Expr::Binary {
                op: AstBinOp::Pow,
                lhs: Box::new(base),
                rhs: Box::new(exp),
                pos,
            });
        }
        Ok(base)
    }

    fn postfix_expr(&mut self) -> Result<Expr> {
        let mut e = self.atom()?;
        loop {
            match self.peek() {
                // Indexing must start on the same source line as the token
                // it follows — otherwise `x = y` + newline + `[W,b] = f()`
                // would misparse as `y[W, b]` (DML, like R, is
                // newline-sensitive here).
                Tok::LBracket if self.same_line_as_prev() => {
                    let pos = self.here();
                    self.advance();
                    let (rows, cols) = self.index_ranges()?;
                    self.expect(Tok::RBracket, "']'")?;
                    e = Expr::Index { base: Box::new(e), rows, cols, pos };
                }
                _ => break,
            }
        }
        Ok(e)
    }

    /// Is the current token on the same line as the previous one?
    fn same_line_as_prev(&self) -> bool {
        self.pos > 0 && self.toks[self.pos].line == self.toks[self.pos - 1].line
    }

    fn atom(&mut self) -> Result<Expr> {
        let pos = self.here();
        match self.advance() {
            Tok::Num(v) => Ok(Expr::Num(v, pos)),
            Tok::Int(v) => Ok(Expr::Int(v, pos)),
            Tok::Str(s) => Ok(Expr::Str(s, pos)),
            Tok::KwTrue => Ok(Expr::Bool(true, pos)),
            Tok::KwFalse => Ok(Expr::Bool(false, pos)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen, "')'")?;
                Ok(e)
            }
            Tok::LBracket => {
                // List literal [a, b, c] (shape args of NN builtins).
                let mut items = Vec::new();
                if !self.eat(&Tok::RBracket) {
                    loop {
                        items.push(self.expr()?);
                        if !self.eat(&Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(Tok::RBracket, "']'")?;
                }
                Ok(Expr::List(items, pos))
            }
            Tok::Ident(name) => {
                // namespace::func(...)
                if *self.peek() == Tok::DColon {
                    self.advance();
                    let fname = self.ident("function name after '::'")?;
                    self.expect(Tok::LParen, "'(' after namespaced function")?;
                    let args = self.call_args()?;
                    return Ok(Expr::Call { namespace: Some(name), name: fname, args, pos });
                }
                // func(...)
                if *self.peek() == Tok::LParen {
                    self.advance();
                    let args = self.call_args()?;
                    return Ok(Expr::Call { namespace: None, name, args, pos });
                }
                Ok(Expr::Var(name, pos))
            }
            other => Err(self.err(format!("unexpected token {other:?} in expression"))),
        }
    }

    fn call_args(&mut self) -> Result<Vec<Arg>> {
        let mut args = Vec::new();
        if self.eat(&Tok::RParen) {
            return Ok(args);
        }
        loop {
            // Named arg: ident = expr (but not ident == expr).
            let name = if matches!(self.peek(), Tok::Ident(_)) && *self.peek_at(1) == Tok::Assign {
                let n = self.ident("argument name")?;
                self.advance(); // =
                Some(n)
            } else {
                None
            };
            let value = self.expr()?;
            args.push(Arg { name, value });
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RParen, "')'")?;
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_softmax_script() {
        // The §2 listing (with its typos fixed as in the real nn examples).
        let src = r#"
source("nn/layers/affine.dml") as affine
source("nn/layers/cross_entropy_loss.dml") as cross_entropy_loss
source("nn/layers/softmax.dml") as softmax
source("nn/optim/sgd.dml") as sgd
train = function(matrix[double] X, matrix[double] Y) {
  D = ncol(X) # num features
  K = ncol(Y) # num classes
  lr = 0.01; batch_size = 32; num_iter = nrow(X) / batch_size
  [W, b] = affine::init(D, K)
  for (i in 1:num_iter) {
    beg = (i-1)*batch_size + 1; end = beg + batch_size
    X_batch = X[beg:end,]; y_batch = Y[beg:end,]
    scores = affine::forward(X_batch, W, b)
    probs = softmax::forward(scores)
    dprobs = cross_entropy_loss::backward(probs, y_batch)
    dscores = softmax::backward(dprobs, scores)
    [dX_batch, dW, db] = affine::backward(dscores, X_batch, W, b)
    W = sgd::update(W, dW, lr)
    b = sgd::update(b, db, lr)
  }
}
"#;
        let prog = parse(src).unwrap();
        assert_eq!(prog.imports.len(), 4);
        assert_eq!(prog.imports[0].namespace, "affine");
        assert_eq!(prog.functions.len(), 1);
        let f = &prog.functions[0];
        assert_eq!(f.name, "train");
        assert_eq!(f.params.len(), 2);
        assert_eq!(f.params[0].vtype, ValueType::Matrix);
        // body: D, K, lr, batch_size, num_iter, [W,b], for
        assert!(matches!(f.body.last().unwrap(), Stmt::For { .. }));
    }

    #[test]
    fn matmul_precedence_tighter_than_add() {
        let prog = parse("y = X %*% W + b").unwrap();
        match &prog.body[0] {
            Stmt::Assign { value: Expr::Binary { op: AstBinOp::Add, lhs, .. }, .. } => {
                assert!(matches!(**lhs, Expr::Binary { op: AstBinOp::MatMul, .. }));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn power_binds_tighter_than_unary_minus() {
        let prog = parse("y = -x^2").unwrap();
        match &prog.body[0] {
            Stmt::Assign { value: Expr::Unary { op: AstUnOp::Neg, operand, .. }, .. } => {
                assert!(matches!(**operand, Expr::Binary { op: AstBinOp::Pow, .. }));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn indexing_variants() {
        let prog = parse("a = X[1, 2]\nb = X[1:3,]\nc = X[, 2:4]\nd = X[i:j, k]").unwrap();
        assert_eq!(prog.body.len(), 4);
        match &prog.body[1] {
            Stmt::Assign { value: Expr::Index { rows, cols, .. }, .. } => {
                assert!(matches!(rows, IndexRange::Range(..)));
                assert!(matches!(cols, IndexRange::All));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn left_indexing_assignment() {
        let prog = parse("X[1:2, 3] = Y").unwrap();
        match &prog.body[0] {
            Stmt::Assign { target: AssignTarget::Indexed { name, rows, cols }, .. } => {
                assert_eq!(name, "X");
                assert!(matches!(rows, IndexRange::Range(..)));
                assert!(matches!(cols, IndexRange::Single(..)));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn parfor_with_options() {
        let prog = parse("parfor (i in 1:10, check=0, par=4, mode=remote) { y = i }").unwrap();
        match &prog.body[0] {
            Stmt::ParFor { opts, .. } => {
                assert!(!opts.check);
                assert_eq!(opts.par, 4);
                assert_eq!(opts.mode, "remote");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn if_else_chain() {
        let prog = parse("if (a > 1) { b = 1 } else if (a > 0) { b = 2 } else { b = 3 }").unwrap();
        match &prog.body[0] {
            Stmt::If { else_branch, .. } => {
                assert!(matches!(else_branch[0], Stmt::If { .. }));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn while_and_named_args() {
        let prog =
            parse("while (i < 10) { X = rand(rows=10, cols=5, sparsity=0.5); i = i + 1 }").unwrap();
        match &prog.body[0] {
            Stmt::While { body, .. } => match &body[0] {
                Stmt::Assign { value: Expr::Call { name, args, .. }, .. } => {
                    assert_eq!(name, "rand");
                    assert_eq!(args[0].name.as_deref(), Some("rows"));
                }
                other => panic!("unexpected {other:?}"),
            },
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn comparison_not_confused_with_named_arg() {
        let prog = parse("y = sum(a == b)").unwrap();
        match &prog.body[0] {
            Stmt::Assign { value: Expr::Call { args, .. }, .. } => {
                assert!(args[0].name.is_none());
                assert!(matches!(args[0].value, Expr::Binary { op: AstBinOp::Eq, .. }));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn parse_errors_have_positions() {
        let e = parse("x = ").unwrap_err();
        match e {
            DmlError::Parse { line, .. } => assert_eq!(line, 1),
            other => panic!("unexpected {other:?}"),
        }
        assert!(parse("if (x { }").is_err());
        assert!(parse("for i in 1:3 { }").is_err());
    }

    #[test]
    fn function_with_defaults_and_returns() {
        let src = "f = function(matrix[double] X, double lr = 0.01, int k = 5) return (matrix[double] W, double loss) { W = X; loss = lr * k }";
        let prog = parse(src).unwrap();
        let f = &prog.functions[0];
        assert_eq!(f.params.len(), 3);
        assert!(f.params[1].default.is_some());
        assert_eq!(f.returns.len(), 2);
        assert_eq!(f.returns[1].vtype, ValueType::Double);
    }
}
