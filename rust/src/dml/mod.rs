//! The DML language front end: lexer, parser, AST, and validation.

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod validate;

pub use ast::Program;
pub use parser::parse;
