//! DML lexer.
//!
//! Tokenizes the R-like DML syntax: `#` line comments, `/* */` block
//! comments, numbers (int/double/scientific), strings (double or single
//! quoted), identifiers (including dotted names like `cross_entropy.loss`
//! — dots are identifier characters in DML), and the operator set
//! including `%*%`, `%%`, `%/%`, `::`, `<-`.

use crate::util::error::{DmlError, Result};

/// Token kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Num(f64),
    Int(i64),
    Str(String),
    Ident(String),
    // keywords
    KwFunction,
    KwReturn,
    KwIf,
    KwElse,
    KwFor,
    KwParFor,
    KwWhile,
    KwIn,
    KwSource,
    KwAs,
    KwTrue,
    KwFalse,
    // punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Colon,
    DColon, // ::
    Assign, // = or <-
    Plus,
    Minus,
    Star,
    Slash,
    Caret,
    MatMul, // %*%
    Mod,    // %%
    IntDiv, // %/%
    Eq,     // ==
    Neq,    // !=
    Lt,
    Le,
    Gt,
    Ge,
    And, // & or &&
    Or,  // | or ||
    Not, // !
    Eof,
}

/// Token with position.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
    pub col: usize,
}

/// Tokenize a DML source string.
pub fn lex(src: &str) -> Result<Vec<Token>> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut col = 1usize;

    macro_rules! err {
        ($msg:expr) => {
            return Err(DmlError::Lex { line, col, msg: $msg.to_string() })
        };
    }

    while i < bytes.len() {
        let c = bytes[i] as char;
        let (tline, tcol) = (line, col);
        macro_rules! push {
            ($t:expr, $n:expr) => {{
                toks.push(Token { tok: $t, line: tline, col: tcol });
                i += $n;
                col += $n;
            }};
        }
        match c {
            '\n' => {
                i += 1;
                line += 1;
                col = 1;
            }
            ' ' | '\t' | '\r' => {
                i += 1;
                col += 1;
            }
            '#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < bytes.len() && bytes[i + 1] == b'*' => {
                i += 2;
                col += 2;
                loop {
                    if i + 1 >= bytes.len() {
                        err!("unterminated block comment");
                    }
                    if bytes[i] == b'*' && bytes[i + 1] == b'/' {
                        i += 2;
                        col += 2;
                        break;
                    }
                    if bytes[i] == b'\n' {
                        line += 1;
                        col = 1;
                    } else {
                        col += 1;
                    }
                    i += 1;
                }
            }
            '"' | '\'' => {
                let quote = bytes[i];
                let mut s = String::new();
                let mut j = i + 1;
                loop {
                    if j >= bytes.len() {
                        err!("unterminated string");
                    }
                    if bytes[j] == quote {
                        break;
                    }
                    if bytes[j] == b'\\' && j + 1 < bytes.len() {
                        match bytes[j + 1] {
                            b'n' => s.push('\n'),
                            b't' => s.push('\t'),
                            b'"' => s.push('"'),
                            b'\'' => s.push('\''),
                            b'\\' => s.push('\\'),
                            other => {
                                s.push('\\');
                                s.push(other as char);
                            }
                        }
                        j += 2;
                    } else {
                        s.push(bytes[j] as char);
                        j += 1;
                    }
                }
                let n = j + 1 - i;
                push!(Tok::Str(s), n);
            }
            '0'..='9' | '.' if c != '.' || (i + 1 < bytes.len() && bytes[i + 1].is_ascii_digit()) => {
                let start = i;
                let mut j = i;
                let mut is_double = false;
                while j < bytes.len() && bytes[j].is_ascii_digit() {
                    j += 1;
                }
                if j < bytes.len() && bytes[j] == b'.' {
                    is_double = true;
                    j += 1;
                    while j < bytes.len() && bytes[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                if j < bytes.len() && (bytes[j] == b'e' || bytes[j] == b'E') {
                    let mut k = j + 1;
                    if k < bytes.len() && (bytes[k] == b'+' || bytes[k] == b'-') {
                        k += 1;
                    }
                    if k < bytes.len() && bytes[k].is_ascii_digit() {
                        is_double = true;
                        j = k;
                        while j < bytes.len() && bytes[j].is_ascii_digit() {
                            j += 1;
                        }
                    }
                }
                let text = std::str::from_utf8(&bytes[start..j]).unwrap();
                let n = j - start;
                if is_double {
                    match text.parse::<f64>() {
                        Ok(v) => push!(Tok::Num(v), n),
                        Err(_) => err!(format!("bad number '{text}'")),
                    }
                } else {
                    match text.parse::<i64>() {
                        Ok(v) => push!(Tok::Int(v), n),
                        Err(_) => err!(format!("bad integer '{text}'")),
                    }
                }
            }
            'a'..='z' | 'A'..='Z' | '_' | '.' => {
                let start = i;
                let mut j = i;
                while j < bytes.len()
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'.')
                {
                    j += 1;
                }
                let word = std::str::from_utf8(&bytes[start..j]).unwrap().to_string();
                let n = j - start;
                let tok = match word.as_str() {
                    "function" => Tok::KwFunction,
                    "return" => Tok::KwReturn,
                    "if" => Tok::KwIf,
                    "else" => Tok::KwElse,
                    "for" => Tok::KwFor,
                    "parfor" => Tok::KwParFor,
                    "while" => Tok::KwWhile,
                    "in" => Tok::KwIn,
                    "source" => Tok::KwSource,
                    "as" => Tok::KwAs,
                    "TRUE" => Tok::KwTrue,
                    "FALSE" => Tok::KwFalse,
                    _ => Tok::Ident(word),
                };
                push!(tok, n);
            }
            '%' => {
                if bytes[i..].starts_with(b"%*%") {
                    push!(Tok::MatMul, 3);
                } else if bytes[i..].starts_with(b"%/%") {
                    push!(Tok::IntDiv, 3);
                } else if bytes[i..].starts_with(b"%%") {
                    push!(Tok::Mod, 2);
                } else {
                    err!("unexpected '%'");
                }
            }
            '(' => push!(Tok::LParen, 1),
            ')' => push!(Tok::RParen, 1),
            '{' => push!(Tok::LBrace, 1),
            '}' => push!(Tok::RBrace, 1),
            '[' => push!(Tok::LBracket, 1),
            ']' => push!(Tok::RBracket, 1),
            ',' => push!(Tok::Comma, 1),
            ';' => push!(Tok::Semi, 1),
            ':' => {
                if bytes[i..].starts_with(b"::") {
                    push!(Tok::DColon, 2);
                } else {
                    push!(Tok::Colon, 1);
                }
            }
            '=' => {
                if bytes[i..].starts_with(b"==") {
                    push!(Tok::Eq, 2);
                } else {
                    push!(Tok::Assign, 1);
                }
            }
            '<' => {
                if bytes[i..].starts_with(b"<-") {
                    push!(Tok::Assign, 2);
                } else if bytes[i..].starts_with(b"<=") {
                    push!(Tok::Le, 2);
                } else {
                    push!(Tok::Lt, 1);
                }
            }
            '>' => {
                if bytes[i..].starts_with(b">=") {
                    push!(Tok::Ge, 2);
                } else {
                    push!(Tok::Gt, 1);
                }
            }
            '!' => {
                if bytes[i..].starts_with(b"!=") {
                    push!(Tok::Neq, 2);
                } else {
                    push!(Tok::Not, 1);
                }
            }
            '&' => {
                let n = if bytes[i..].starts_with(b"&&") { 2 } else { 1 };
                push!(Tok::And, n);
            }
            '|' => {
                let n = if bytes[i..].starts_with(b"||") { 2 } else { 1 };
                push!(Tok::Or, n);
            }
            '+' => push!(Tok::Plus, 1),
            '-' => push!(Tok::Minus, 1),
            '*' => push!(Tok::Star, 1),
            '/' => push!(Tok::Slash, 1),
            '^' => push!(Tok::Caret, 1),
            other => err!(format!("unexpected character '{other}'")),
        }
    }
    toks.push(Token { tok: Tok::Eof, line, col });
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn numbers_ints_doubles() {
        assert_eq!(
            kinds("42 3.14 1e3 2.5e-2"),
            vec![Tok::Int(42), Tok::Num(3.14), Tok::Num(1000.0), Tok::Num(0.025), Tok::Eof]
        );
    }

    #[test]
    fn operators_including_matmul() {
        assert_eq!(
            kinds("X %*% Y %% 2 %/% 3"),
            vec![
                Tok::Ident("X".into()),
                Tok::MatMul,
                Tok::Ident("Y".into()),
                Tok::Mod,
                Tok::Int(2),
                Tok::IntDiv,
                Tok::Int(3),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn comments_stripped() {
        assert_eq!(
            kinds("a = 1 # comment\nb /* block\ncomment */ = 2"),
            vec![
                Tok::Ident("a".into()),
                Tok::Assign,
                Tok::Int(1),
                Tok::Ident("b".into()),
                Tok::Assign,
                Tok::Int(2),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn strings_with_escapes() {
        assert_eq!(
            kinds(r#""hello\n" 'world'"#),
            vec![Tok::Str("hello\n".into()), Tok::Str("world".into()), Tok::Eof]
        );
    }

    #[test]
    fn keywords_and_namespaced_idents() {
        assert_eq!(
            kinds("source(\"nn/layers/affine.dml\") as affine\naffine::init"),
            vec![
                Tok::KwSource,
                Tok::LParen,
                Tok::Str("nn/layers/affine.dml".into()),
                Tok::RParen,
                Tok::KwAs,
                Tok::Ident("affine".into()),
                Tok::Ident("affine".into()),
                Tok::DColon,
                Tok::Ident("init".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn dotted_identifiers() {
        assert_eq!(
            kinds("cross_entropy.loss"),
            vec![Tok::Ident("cross_entropy.loss".into()), Tok::Eof]
        );
    }

    #[test]
    fn arrow_assignment() {
        assert_eq!(kinds("x <- 3"), vec![Tok::Ident("x".into()), Tok::Assign, Tok::Int(3), Tok::Eof]);
    }

    #[test]
    fn positions_tracked() {
        let toks = lex("a =\n  b").unwrap();
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[2].line, toks[2].col), (2, 3));
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("a = @").is_err());
        assert!(lex("\"unterminated").is_err());
        assert!(lex("/* unterminated").is_err());
        assert!(lex("a % b").is_err());
    }

    #[test]
    fn true_false_literals() {
        assert_eq!(kinds("TRUE FALSE"), vec![Tok::KwTrue, Tok::KwFalse, Tok::Eof]);
    }
}
