//! System configuration — the knobs SystemML exposes through
//! SparkContext/JVM settings, mapped to this runtime.

use std::path::PathBuf;

/// Runtime configuration for compiler decisions and backends.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Driver ("JVM heap") memory budget in bytes. Operations whose
    /// estimated memory exceeds this are compiled to the distributed
    /// backend (paper §3 Distributed Operations).
    pub driver_memory: usize,
    /// Simulated cluster size (number of workers/executors).
    pub num_workers: usize,
    /// Per-worker memory budget in bytes.
    pub worker_memory: usize,
    /// Per-worker *storage* budget in bytes for resident (cached) block
    /// partitions — SystemML's executor storage fraction. The cluster's
    /// block-partition cache holds at most `worker_storage * num_workers`
    /// bytes before LRU eviction kicks in.
    pub worker_storage: usize,
    /// Keep blocked partitions resident across statements (lineage-keyed
    /// reuse, like Spark RDD caching). When false every DIST operator
    /// re-blockifies its inputs from the driver copy.
    pub cache_enabled: bool,
    /// Bind DIST operator outputs as first-class blocked values
    /// (`Value::Blocked`): results stay distributed across statements,
    /// function calls and parfor bodies, and are only collected to the
    /// driver when a CP operator, scalar cast, print or I/O actually
    /// needs dense data. When false every DIST result is collected
    /// eagerly after the operator (the pre-blocked-value behavior).
    pub blocked_values: bool,
    /// Block size (rows/cols) for blocked distributed matrices.
    pub block_size: usize,
    /// Worker threads executing blocked tasks concurrently. `0` means
    /// "one thread per simulated worker" (the default — `num_workers`
    /// becomes actual concurrency); `1` restores fully serial in-line
    /// execution for debugging. Results are byte-identical either way:
    /// the pool preserves the driver-side reduction order.
    pub dist_threads: usize,
    /// Per-block sparsity turn point for the blocked backend: a block
    /// whose `nnz / cells` ratio is strictly below this (and that is
    /// large enough for the CSR encoding to pay off — see
    /// `runtime::matrix::MIN_SPARSE_CELLS`) is stored CSR; denser
    /// blocks stay dense. Blockify inspects every block against this
    /// threshold and blocked operators re-examine their outputs, so
    /// representation follows the data through a plan. Mirrors
    /// SystemML's 0.4 sparsity turn point; `1.0` makes every eligible
    /// block sparse, `0.0` forces all-dense blocks.
    pub sparsity_threshold: f64,
    /// Enable the distributed backend (if false, everything runs CP and
    /// over-budget allocations are errors — like local-mode SystemML).
    pub dist_enabled: bool,
    /// Serving: maximum rows the micro-batcher packs into one scoring
    /// batch before flushing (the size bound). Batches are padded up to
    /// the next `block_size` multiple, so plans are compiled once per
    /// distinct padded geometry — keeping this a multiple of
    /// `block_size` means a single cached plan serves every full batch.
    pub serve_max_batch: usize,
    /// Serving: maximum simulated ticks the *oldest* admitted request may
    /// wait before the micro-batcher flushes a partial batch (the latency
    /// bound). The batcher flushes on whichever of the two bounds hits
    /// first.
    pub serve_max_wait_ticks: u64,
    /// Blocked rhs operands up to this size (bytes) memoize their
    /// worker-side gathered copy on the handle — the loop-invariant
    /// vector/filter case worth caching. Memoized gathers are charged to
    /// the cluster storage budget; larger operands gather transiently.
    pub gather_memo_bytes: usize,
    /// Enable the accelerator (PJRT) backend — the paper's GPU backend.
    pub accel_enabled: bool,
    /// Accelerator "device memory" budget in bytes (drives LRU eviction).
    pub accel_memory: usize,
    /// Directories searched by `source("...")`.
    pub script_paths: Vec<PathBuf>,
    /// Directory holding AOT artifacts (manifest.json + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Print plan/exec-type decisions (SystemML's `-explain`).
    pub explain: bool,
    /// Collect per-operator / per-worker execution statistics
    /// (SystemML's `-stats`). When false the stats path is compiled to
    /// `None` checks only: no locks, no allocation on dispatch hot
    /// paths. Reports render through `MLContext::statistics()`.
    pub stats_enabled: bool,
    /// Optional JSON-lines execution trace. When set, session / script /
    /// statement / operator spans plus blockify / broadcast / shuffle /
    /// allreduce / cache / spill / collect events (with byte counts) are
    /// appended to this file. Implies stats collection for the spans it
    /// records; deterministic except wall-time fields.
    pub trace_path: Option<PathBuf>,
}

impl Default for SystemConfig {
    fn default() -> Self {
        let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        SystemConfig {
            driver_memory: 512 * 1024 * 1024,
            num_workers: 4,
            worker_memory: 512 * 1024 * 1024,
            worker_storage: 256 * 1024 * 1024,
            cache_enabled: true,
            blocked_values: true,
            block_size: 1024,
            dist_threads: 0,
            sparsity_threshold: crate::runtime::matrix::SPARSITY_TURN_POINT,
            dist_enabled: true,
            serve_max_batch: 64,
            serve_max_wait_ticks: 8,
            gather_memo_bytes: 4 << 20,
            accel_enabled: false,
            accel_memory: 256 * 1024 * 1024,
            script_paths: vec![
                PathBuf::from("."),
                PathBuf::from("scripts"),
                manifest_dir.join("scripts"),
            ],
            artifacts_dir: manifest_dir.join("artifacts"),
            explain: false,
            stats_enabled: false,
            trace_path: None,
        }
    }
}

impl SystemConfig {
    /// A config with a tiny driver budget, forcing distributed plans
    /// (used by tests and the hybrid-plan experiments).
    pub fn tiny_driver(budget: usize) -> Self {
        SystemConfig { driver_memory: budget, ..Default::default() }
    }

    /// Fluent builder starting from the default configuration. Fields
    /// stay public, so direct struct mutation keeps working; the builder
    /// is the preferred way to derive configs in examples and tests:
    ///
    /// ```
    /// use systemml::conf::SystemConfig;
    /// let c = SystemConfig::builder()
    ///     .num_workers(8)
    ///     .dist_threads(4)
    ///     .worker_storage(64 * 1024 * 1024)
    ///     .build();
    /// assert_eq!(c.num_workers, 8);
    /// ```
    pub fn builder() -> SystemConfigBuilder {
        SystemConfigBuilder { config: SystemConfig::default() }
    }
}

/// Builder returned by [`SystemConfig::builder`].
#[derive(Clone, Debug)]
pub struct SystemConfigBuilder {
    config: SystemConfig,
}

macro_rules! builder_setters {
    ($($(#[$doc:meta])* $name:ident: $ty:ty),* $(,)?) => {
        $(
            $(#[$doc])*
            pub fn $name(mut self, v: $ty) -> Self {
                self.config.$name = v;
                self
            }
        )*
    };
}

impl SystemConfigBuilder {
    builder_setters! {
        /// Driver memory budget in bytes.
        driver_memory: usize,
        /// Simulated cluster size.
        num_workers: usize,
        /// Per-worker memory budget in bytes.
        worker_memory: usize,
        /// Per-worker storage budget for resident block partitions.
        worker_storage: usize,
        /// Keep blocked partitions resident across statements.
        cache_enabled: bool,
        /// Bind DIST outputs as first-class blocked values.
        blocked_values: bool,
        /// Block size for blocked distributed matrices.
        block_size: usize,
        /// Worker threads for blocked tasks (0 = one per worker).
        dist_threads: usize,
        /// Per-block sparsity turn point for CSR block encoding.
        sparsity_threshold: f64,
        /// Enable the distributed backend.
        dist_enabled: bool,
        /// Serving: micro-batcher size bound (rows per scoring batch).
        serve_max_batch: usize,
        /// Serving: micro-batcher wait bound in simulated ticks.
        serve_max_wait_ticks: u64,
        /// Memoization cap (bytes) for worker-side gathered rhs copies.
        gather_memo_bytes: usize,
        /// Enable the accelerator (PJRT) backend.
        accel_enabled: bool,
        /// Accelerator device-memory budget in bytes.
        accel_memory: usize,
        /// Print plan/exec-type decisions.
        explain: bool,
        /// Collect per-operator / per-worker statistics (`-stats`).
        stats_enabled: bool,
    }

    /// Write a JSON-lines execution trace to this path.
    pub fn trace_path(mut self, p: impl Into<PathBuf>) -> Self {
        self.config.trace_path = Some(p.into());
        self
    }

    /// Append a directory to the `source("...")` search path.
    pub fn script_path(mut self, p: impl Into<PathBuf>) -> Self {
        self.config.script_paths.push(p.into());
        self
    }

    /// Directory holding AOT artifacts.
    pub fn artifacts_dir(mut self, p: impl Into<PathBuf>) -> Self {
        self.config.artifacts_dir = p.into();
        self
    }

    pub fn build(self) -> SystemConfig {
        self.config
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_paths_include_manifest_scripts() {
        let c = SystemConfig::default();
        assert!(c.script_paths.iter().any(|p| p.ends_with("scripts")));
        assert!(c.dist_enabled);
    }

    #[test]
    fn builder_overrides_compose_with_defaults() {
        let c = SystemConfig::builder()
            .num_workers(7)
            .dist_threads(4)
            .worker_storage(1 << 20)
            .cache_enabled(false)
            .build();
        assert_eq!(c.num_workers, 7);
        assert_eq!(c.dist_threads, 4);
        assert_eq!(c.worker_storage, 1 << 20);
        assert!(!c.cache_enabled);
        // Untouched knobs keep their defaults; fields stay public.
        let mut c = c;
        c.block_size = 64;
        assert_eq!(c.block_size, 64);
        assert_eq!(c.driver_memory, SystemConfig::default().driver_memory);
    }

    #[test]
    fn serving_and_gather_knobs_build() {
        let c = SystemConfig::builder()
            .serve_max_batch(128)
            .serve_max_wait_ticks(4)
            .gather_memo_bytes(1 << 20)
            .build();
        assert_eq!(c.serve_max_batch, 128);
        assert_eq!(c.serve_max_wait_ticks, 4);
        assert_eq!(c.gather_memo_bytes, 1 << 20);
        let d = SystemConfig::default();
        assert_eq!(d.serve_max_batch, 64);
        assert_eq!(d.serve_max_wait_ticks, 8);
        assert_eq!(d.gather_memo_bytes, 4 << 20);
    }

    #[test]
    fn stats_knobs_default_off_and_build() {
        let d = SystemConfig::default();
        assert!(!d.stats_enabled);
        assert!(d.trace_path.is_none());
        let c = SystemConfig::builder()
            .stats_enabled(true)
            .trace_path("/tmp/trace.jsonl")
            .build();
        assert!(c.stats_enabled);
        assert_eq!(c.trace_path.as_deref(), Some(std::path::Path::new("/tmp/trace.jsonl")));
    }
}
