//! `sysml` — command-line launcher for the SystemML reproduction.
//!
//! Subcommands (hand-rolled arg parsing; `clap` is not in the offline
//! registry):
//!
//! ```text
//! sysml run <script.dml> [-stats] [-explain] [--accel] [--workers N]
//! sysml keras2dml <model.json> [--print-dml] [--train-algo A] [--test-algo A]
//! sysml explain <script.dml>
//! sysml artifacts
//! ```

use std::collections::HashMap;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::nn::keras2dml::{FitConfig, Keras2DML, SequentialModel};
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::util::metrics;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "usage:\n  sysml run <script.dml> [-stats] [-explain] [--accel] [--workers N] [--driver-mem BYTES]\n  sysml keras2dml <model.json> [--print-dml] [--train-algo minibatch|batch] [--test-algo naive|allreduce]\n  sysml explain <script.dml>\n  sysml artifacts".to_string()
}

fn run(args: &[String]) -> systemml::Result<()> {
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut positional: Vec<&String> = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = matches!(name, "workers" | "driver-mem" | "train-algo" | "test-algo");
            if takes_value {
                let v = it
                    .next()
                    .ok_or_else(|| systemml::DmlError::rt(format!("--{name} needs a value")))?;
                flags.insert(name.to_string(), v.clone());
            } else {
                flags.insert(name.to_string(), "true".into());
            }
        } else if let Some(name) = a.strip_prefix('-') {
            flags.insert(name.to_string(), "true".into());
        } else {
            positional.push(a);
        }
    }
    let Some(cmd) = positional.first() else {
        println!("{}", usage());
        return Ok(());
    };

    let mut config = SystemConfig::default();
    if let Some(w) = flags.get("workers") {
        config.num_workers = w.parse().unwrap_or(config.num_workers);
    }
    if let Some(m) = flags.get("driver-mem") {
        config.driver_memory = m.parse().unwrap_or(config.driver_memory);
    }
    if flags.contains_key("accel") {
        config.accel_enabled = true;
    }
    if flags.contains_key("explain") {
        config.explain = true;
    }

    match cmd.as_str() {
        "run" => {
            let path = positional
                .get(1)
                .ok_or_else(|| systemml::DmlError::rt("run: missing script path"))?;
            let mut ctx = MLContext::with_config(config);
            ctx.echo = true;
            let before = metrics::global().snapshot();
            let t0 = std::time::Instant::now();
            ctx.execute(Script::from_file(path)?)?;
            let wall = t0.elapsed();
            if flags.contains_key("stats") {
                let d = metrics::global().snapshot().delta(&before);
                println!("-- statistics ----------------------------------");
                println!("wallclock:        {wall:?}");
                println!("instructions:     {}", d.instructions);
                println!("flops:            {}", d.flops);
                println!("dist tasks:       {}", d.dist_tasks);
                println!("shuffle bytes:    {}", d.shuffle_bytes);
                println!("broadcast bytes:  {}", d.broadcast_bytes);
                println!("parfor tasks:     {}", d.parfor_tasks);
                println!("accel launches:   {}", d.accel_launches);
                println!("h2d/d2h bytes:    {}/{}", d.h2d_bytes, d.d2h_bytes);
            }
            Ok(())
        }
        "explain" => {
            let path = positional
                .get(1)
                .ok_or_else(|| systemml::DmlError::rt("explain: missing script path"))?;
            let ctx = MLContext::with_config(config);
            let script = Script::from_file(path)?;
            let compiled = ctx.compile(&script)?;
            println!(
                "{}",
                systemml::hop::explain::explain_bundle(&compiled.bundle, &ctx.config)
            );
            // The HOP plan with per-operator ExecType annotations
            // (SystemML's `explain(hops)`).
            println!("{}", systemml::hop::explain::explain_plan(&compiled.plan));
            for w in compiled.warnings {
                println!("warning: {w}");
            }
            Ok(())
        }
        "keras2dml" => {
            let path = positional
                .get(1)
                .ok_or_else(|| systemml::DmlError::rt("keras2dml: missing model.json"))?;
            let json = std::fs::read_to_string(path)?;
            let model = SequentialModel::from_json(&json)?;
            let mut fit = FitConfig::default();
            if let Some(t) = flags.get("train-algo") {
                fit.train_algo = t.clone();
            }
            if let Some(t) = flags.get("test-algo") {
                fit.test_algo = t.clone();
            }
            if flags.contains_key("print-dml") {
                println!("# ===== training script =====");
                println!("{}", model.to_dml(&fit)?);
                println!("# ===== scoring script =====");
                println!("{}", model.to_predict_dml(&fit)?);
                return Ok(());
            }
            // Demo fit on synthetic data matching the model's input width.
            let d = match model.input {
                systemml::nn::keras2dml::InputShape::Flat(d) => d,
                systemml::nn::keras2dml::InputShape::Volume { c, h, w } => c * h * w,
            };
            let k = model
                .layers
                .iter()
                .rev()
                .find_map(|l| match l {
                    systemml::nn::keras2dml::Layer::Dense { units, .. } => Some(*units),
                    _ => None,
                })
                .unwrap_or(2);
            let (x, y) = synthetic_classification(256, d, k, 7);
            let mut k2d = Keras2DML::new(MLContext::with_config(config), model);
            k2d.fit_config = fit;
            let trained = k2d.fit(x, y)?;
            println!(
                "trained '{}': first loss {:.4}, last loss {:.4} over {} iterations",
                k2d.model.name,
                trained.loss_curve.first().unwrap_or(&0.0),
                trained.loss_curve.last().unwrap_or(&0.0),
                trained.loss_curve.len()
            );
            Ok(())
        }
        "artifacts" => {
            config.accel_enabled = true;
            match systemml::runtime::accel::AccelBackend::open(&config) {
                Ok(b) => {
                    println!(
                        "{} artifacts in {}:",
                        b.artifacts().len(),
                        config.artifacts_dir.display()
                    );
                    for a in b.artifacts() {
                        println!("  {:40} op={:20} inputs={:?}", a.name, a.op, a.inputs);
                    }
                    Ok(())
                }
                Err(e) => Err(e),
            }
        }
        other => {
            eprintln!("unknown command '{other}'");
            println!("{}", usage());
            Ok(())
        }
    }
}
