//! Builtin function dispatch (paper §3 "Builtin NN Functions" plus the
//! standard DML builtin library).

use crate::dml::ast::Pos;
use crate::runtime::conv::{self, ConvOpKind, ConvShape};
use crate::runtime::dist::cache::LineageRef;
use crate::runtime::interp::{Interpreter, Value};
use crate::runtime::matrix::agg::{self, AggOp};
use crate::runtime::matrix::elementwise::{self, BinOp, UnaryOp};
use crate::runtime::matrix::{randgen, reorg, solve, Matrix};
use crate::util::error::{DmlError, Result};

type EArg = (Option<String>, Value);

/// Access helper over evaluated args.
struct Args<'a> {
    name: &'a str,
    args: &'a [EArg],
    /// Lineage references of the argument expressions (parallel to
    /// `args`; empty when the caller has no lineage context).
    hints: &'a [Option<LineageRef>],
}

impl<'a> Args<'a> {
    /// Index of the argument named `name`, else of the `pos`-th unnamed.
    fn index_of(&self, pos: usize, name: &str) -> Option<usize> {
        if let Some(i) = self.args.iter().position(|(n, _)| n.as_deref() == Some(name)) {
            return Some(i);
        }
        self.args
            .iter()
            .enumerate()
            .filter(|(_, (n, _))| n.is_none())
            .nth(pos)
            .map(|(i, _)| i)
    }

    /// Named arg, else positional index.
    fn get(&self, pos: usize, name: &str) -> Option<&Value> {
        self.index_of(pos, name).map(|i| &self.args[i].1)
    }

    /// Lineage reference of the argument, when the caller supplied one.
    fn hint(&self, pos: usize, name: &str) -> Option<&LineageRef> {
        self.hints.get(self.index_of(pos, name)?)?.as_ref()
    }
    fn require(&self, pos: usize, name: &str) -> Result<&Value> {
        self.get(pos, name).ok_or_else(|| {
            DmlError::rt(format!("{}: missing argument '{name}'", self.name))
        })
    }
    fn matrix(&self, pos: usize, name: &str) -> Result<Matrix> {
        Ok(self.require(pos, name)?.as_matrix()?.clone())
    }
    fn double(&self, pos: usize, name: &str, default: f64) -> Result<f64> {
        match self.get(pos, name) {
            Some(v) => v.as_double(),
            None => Ok(default),
        }
    }
    fn usize_or(&self, pos: usize, name: &str, default: usize) -> Result<usize> {
        match self.get(pos, name) {
            Some(v) => Ok(v.as_int()? as usize),
            None => Ok(default),
        }
    }
    fn str_or(&self, pos: usize, name: &str, default: &str) -> Result<String> {
        match self.get(pos, name) {
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(other) => Err(DmlError::rt(format!(
                "{}: argument '{name}' must be a string, found {}",
                self.name,
                other.type_name()
            ))),
            None => Ok(default.to_string()),
        }
    }
    fn shape_list(&self, name: &str) -> Result<Vec<usize>> {
        for (n, v) in self.args {
            if n.as_deref() == Some(name) {
                return v.as_usize_list();
            }
        }
        Err(DmlError::rt(format!("{}: missing shape argument '{name}'", self.name)))
    }
    fn count(&self) -> usize {
        self.args.len()
    }
}

/// Parse conv/pool geometry from the SystemML-style named arguments:
/// `input_shape=[N,C,H,W], filter_shape=[K,C,R,S], stride=[h,w], padding=[h,w]`.
fn conv_shape(a: &Args, need_filter: bool) -> Result<ConvShape> {
    let ins = a.shape_list("input_shape")?;
    if ins.len() != 4 {
        return Err(DmlError::rt(format!("{}: input_shape must be [N,C,H,W]", a.name)));
    }
    let (c, h, w) = (ins[1], ins[2], ins[3]);
    let (k, r, s) = if need_filter {
        let fs = a.shape_list("filter_shape")?;
        if fs.len() != 4 {
            return Err(DmlError::rt(format!("{}: filter_shape must be [K,C,R,S]", a.name)));
        }
        (fs[0], fs[2], fs[3])
    } else {
        // pooling: pool_size=[r,s] (a single entry means a square window)
        let ps = a.shape_list("pool_size")?;
        match ps.as_slice() {
            [r] => (c, *r, *r),
            [r, s, ..] => (c, *r, *s),
            [] => {
                return Err(DmlError::rt(format!("{}: pool_size must be [r,s]", a.name)))
            }
        }
    };
    let stride = a.shape_list("stride").unwrap_or_else(|_| vec![1, 1]);
    let padding = a.shape_list("padding").unwrap_or_else(|_| vec![0, 0]);
    let (s0, p0) = match (stride.first(), padding.first()) {
        (Some(s0), Some(p0)) => (*s0, *p0),
        _ => return Err(DmlError::rt(format!("{}: stride/padding must be non-empty", a.name))),
    };
    Ok(ConvShape {
        c,
        h,
        w,
        k,
        r,
        s,
        stride: (s0, stride.get(1).copied().unwrap_or(s0)),
        pad: (p0, padding.get(1).copied().unwrap_or(p0)),
    })
}

/// Dispatch a builtin call. Returns the (possibly empty) result list.
/// `pos` is the call site — aggregates use it to look up their compiled
/// ExecType placement through the interpreter's unified dispatch.
pub fn call_builtin(
    interp: &Interpreter,
    name: &str,
    args: &[EArg],
    hints: &[Option<LineageRef>],
    pos: Pos,
) -> Result<Vec<Value>> {
    let a = Args { name, args, hints };
    let one = |v: Value| Ok(vec![v]);
    let m1 = |m: Matrix| Ok(vec![Value::Matrix(m)]);

    match name {
        // ---- shape (metadata only — never forces a blocked value) -------
        "nrow" => one(Value::Int(a.require(0, "target")?.matrix_dims()?.0 as i64)),
        "ncol" => one(Value::Int(a.require(0, "target")?.matrix_dims()?.1 as i64)),
        "length" => {
            let (r, c) = a.require(0, "target")?.matrix_dims()?;
            one(Value::Int((r * c) as i64))
        }
        "nnz" => one(Value::Int(a.require(0, "target")?.matrix_nnz()? as i64)),

        // ---- aggregates (plan-aware dispatch: CP or distributed) --------
        "sum" => one(Value::Double(interp.dispatch_agg_full_value(
            a.require(0, "target")?,
            AggOp::Sum,
            Some(pos),
            a.hint(0, "target"),
        )?)),
        "mean" => one(Value::Double(interp.dispatch_agg_full_value(
            a.require(0, "target")?,
            AggOp::Mean,
            Some(pos),
            a.hint(0, "target"),
        )?)),
        "prod" => one(Value::Double(interp.dispatch_agg_full_value(
            a.require(0, "target")?,
            AggOp::Prod,
            Some(pos),
            a.hint(0, "target"),
        )?)),
        "var" => {
            let v = a.require(0, "target")?;
            let h = a.hint(0, "target");
            let mu = interp.dispatch_agg_full_value(v, AggOp::Mean, Some(pos), h)?;
            let ss = interp.dispatch_agg_full_value(v, AggOp::SumSq, Some(pos), h)?;
            let (r, c) = v.matrix_dims()?;
            let n = (r * c) as f64;
            one(Value::Double((ss - n * mu * mu) / (n - 1.0).max(1.0)))
        }
        "sd" => {
            let out = call_builtin(interp, "var", args, hints, pos)?;
            one(Value::Double(out[0].as_double()?.sqrt()))
        }
        "min" | "max" => {
            let op = if name == "min" { AggOp::Min } else { AggOp::Max };
            let bop = if name == "min" { BinOp::Min } else { BinOp::Max };
            if a.count() == 1 {
                match a.require(0, "target")? {
                    v if v.is_matrix() => one(Value::Double(interp.dispatch_agg_full_value(
                        v,
                        op,
                        Some(pos),
                        a.hint(0, "target"),
                    )?)),
                    other => one(Value::Double(other.as_double()?)),
                }
            } else {
                let x = a.require(0, "a")?;
                let y = a.require(1, "b")?;
                match (x.is_matrix(), y.is_matrix()) {
                    (true, true) => one(interp.dispatch_binary_values(
                        x,
                        y,
                        bop,
                        Some(pos),
                        a.hint(0, "a"),
                        a.hint(1, "b"),
                    )?),
                    (true, false) => {
                        one(interp.dispatch_scalar_value(x, y.as_double()?, bop, false)?)
                    }
                    (false, true) => {
                        one(interp.dispatch_scalar_value(y, x.as_double()?, bop, true)?)
                    }
                    (false, false) => {
                        one(Value::Double(bop.apply(x.as_double()?, y.as_double()?)))
                    }
                }
            }
        }
        "rowSums" | "rowMeans" | "rowMaxs" | "rowMins" | "colSums" | "colMeans" | "colMaxs"
        | "colMins" => {
            let op = match name {
                "rowSums" | "colSums" => AggOp::Sum,
                "rowMeans" | "colMeans" => AggOp::Mean,
                "rowMaxs" | "colMaxs" => AggOp::Max,
                _ => AggOp::Min,
            };
            let row_wise = name.starts_with("row");
            one(interp.dispatch_agg_axis_value(
                a.require(0, "target")?,
                op,
                row_wise,
                Some(pos),
                a.hint(0, "target"),
            )?)
        }
        // Blocked operands compute per-block argmaxes on the workers and
        // combine offsets at the driver — no collect (kmeans' assignment
        // step stays distributed).
        "rowIndexMax" => m1(interp.dispatch_row_index_max(a.require(0, "target")?)?),
        "trace" => one(Value::Double(agg::trace(&a.matrix(0, "target")?))),
        "cumsum" => m1(agg::cumsum(&a.matrix(0, "target")?)),

        // ---- unary cell ops --------------------------------------------
        _ if UnaryOp::from_builtin_name(name).is_some() => {
            let uop = UnaryOp::from_builtin_name(name).unwrap();
            match a.require(0, "target")? {
                v if v.is_matrix() => {
                    // log(X, base)
                    if name == "log" && a.count() > 1 {
                        let base = a.double(1, "base", std::f64::consts::E)?;
                        let ln = interp.dispatch_unary_value(v, UnaryOp::Log)?;
                        return one(interp.dispatch_scalar_value(
                            &ln,
                            base.ln(),
                            BinOp::Div,
                            false,
                        )?);
                    }
                    one(interp.dispatch_unary_value(v, uop)?)
                }
                sv => {
                    let x = sv.as_double()?;
                    if name == "log" && a.count() > 1 {
                        let base = a.double(1, "base", std::f64::consts::E)?;
                        return one(Value::Double(x.ln() / base.ln()));
                    }
                    one(Value::Double(uop.apply(x)))
                }
            }
        }

        // ---- construction ------------------------------------------------
        "matrix" => {
            let first = a.require(0, "data")?;
            let rows = a.usize_or(1, "rows", 0)?;
            let cols = a.usize_or(2, "cols", 0)?;
            match first {
                // reshape form (forces a blocked value — CP reorg)
                v if v.is_matrix() => m1(reorg::reshape(v.as_matrix()?, rows, cols)?),
                sv => m1(Matrix::filled(rows, cols, sv.as_double()?)), // fill form
            }
        }
        "rand" => {
            let rows = a.usize_or(0, "rows", 1)?;
            let cols = a.usize_or(1, "cols", 1)?;
            let min = a.double(2, "min", 0.0)?;
            let max = a.double(3, "max", 1.0)?;
            let sparsity = a.double(4, "sparsity", 1.0)?;
            let pdf = match a.str_or(5, "pdf", "uniform")?.as_str() {
                "uniform" => randgen::Pdf::Uniform,
                "normal" => randgen::Pdf::Normal,
                other => return Err(DmlError::rt(format!("rand: unknown pdf '{other}'"))),
            };
            let seed = a.double(6, "seed", 0.0)? as u64;
            m1(randgen::rand(rows, cols, min, max, sparsity, pdf, seed)?)
        }
        "seq" => {
            let from = a.double(0, "from", 1.0)?;
            let to = a.double(1, "to", 1.0)?;
            let incr = a.double(2, "incr", if from <= to { 1.0 } else { -1.0 })?;
            m1(randgen::seq(from, to, incr)?)
        }

        // ---- reorg ------------------------------------------------------
        "t" => one(interp.dispatch_transpose_value(
            a.require(0, "target")?,
            Some(pos),
            a.hint(0, "target"),
        )?),
        "rev" => m1(reorg::rev(&a.matrix(0, "target")?)),
        "cbind" => {
            let mut out = a.matrix(0, "a")?;
            for i in 1..a.count() {
                out = reorg::cbind(&out, &a.matrix(i, "_")?)?;
            }
            m1(out)
        }
        "rbind" => {
            let mut out = a.matrix(0, "a")?;
            for i in 1..a.count() {
                out = reorg::rbind(&out, &a.matrix(i, "_")?)?;
            }
            m1(out)
        }
        "diag" => m1(reorg::diag(&a.matrix(0, "target")?)),
        "outer" => {
            let u = a.matrix(0, "u")?;
            let v = a.matrix(1, "v")?;
            let opname = a.str_or(2, "op", "*")?;
            let bop = match opname.as_str() {
                "*" => BinOp::Mul,
                "+" => BinOp::Add,
                "-" => BinOp::Sub,
                "/" => BinOp::Div,
                "<" => BinOp::Lt,
                ">" => BinOp::Gt,
                "==" => BinOp::Eq,
                other => return Err(DmlError::rt(format!("outer: unknown op '{other}'"))),
            };
            m1(reorg::outer(&u, &v, bop)?)
        }
        "table" => {
            let i = a.matrix(0, "i")?;
            let j = a.matrix(1, "j")?;
            let odim1 = a.usize_or(2, "odim1", 0)?;
            let odim2 = a.usize_or(3, "odim2", 0)?;
            let rows = if odim1 > 0 {
                odim1
            } else {
                agg::full_agg(&i, AggOp::Max) as usize
            };
            let cols = if odim2 > 0 {
                odim2
            } else {
                agg::full_agg(&j, AggOp::Max) as usize
            };
            m1(reorg::table(&i, &j, rows, cols)?)
        }
        "removeEmpty" => {
            let t = a.matrix(0, "target")?;
            let margin = a.str_or(1, "margin", "rows")?;
            m1(reorg::remove_empty(&t, margin == "rows"))
        }
        "solve" => m1(solve::solve(&a.matrix(0, "a")?, &a.matrix(1, "b")?)?),
        "inv" => m1(solve::inverse(&a.matrix(0, "a")?)?),

        // ---- casts --------------------------------------------------------
        "as.scalar" => {
            // Check the (metadata-only) shape before forcing, so a
            // blocked non-1x1 errors cleanly without a driver collect.
            let v = a.require(0, "target")?;
            let (r, c) = v.matrix_dims()?;
            if (r, c) != (1, 1) {
                return Err(DmlError::rt(format!(
                    "as.scalar: matrix is {r}x{c}, expected 1x1"
                )));
            }
            one(Value::Double(v.as_double()?))
        }
        "as.matrix" => match a.require(0, "target")? {
            // A blocked value already is a matrix: pass the handle along
            // without collecting.
            v if v.is_matrix() => one(v.clone()),
            sv => m1(Matrix::scalar(sv.as_double()?)),
        },
        "as.integer" => one(Value::Int(a.require(0, "target")?.as_int()?)),
        "as.double" => one(Value::Double(a.require(0, "target")?.as_double()?)),
        "as.logical" => one(Value::Bool(a.require(0, "target")?.as_bool()?)),

        // ---- control / io ------------------------------------------------
        "print" => {
            let msg = a.require(0, "target")?.to_display_string();
            interp.emit(msg);
            Ok(vec![])
        }
        "toString" => one(Value::Str(a.require(0, "target")?.to_display_string())),
        "stop" => {
            let msg = a.require(0, "message")?.to_display_string();
            Err(DmlError::rt(format!("stop: {msg}")))
        }
        "assert" => {
            if !a.require(0, "condition")?.as_bool()? {
                return Err(DmlError::rt("assert failed"));
            }
            Ok(vec![])
        }
        "ifelse" => {
            let c = a.require(0, "condition")?;
            match c {
                c if c.is_matrix() => {
                    // Cell-wise select: c*a + (1-c)*b (forces blocked
                    // operands — the select runs CP).
                    let cm = c.as_matrix()?;
                    let x = a.require(1, "a")?.to_matrix()?;
                    let y = a.require(2, "b")?.to_matrix()?;
                    let ind = elementwise::scalar_op(cm, 0.0, BinOp::Neq, false)?;
                    let not_ind = elementwise::scalar_op(&ind, 1.0, BinOp::Sub, true)?;
                    let xa = elementwise::binary(&ind, &x, BinOp::Mul)?;
                    let xb = elementwise::binary(&not_ind, &y, BinOp::Mul)?;
                    m1(elementwise::binary(&xa, &xb, BinOp::Add)?)
                }
                sv => {
                    if sv.as_bool()? {
                        one(a.require(1, "a")?.clone())
                    } else {
                        one(a.require(2, "b")?.clone())
                    }
                }
            }
        }
        "time" => {
            let ns = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as i64)
                .unwrap_or(0);
            one(Value::Int(ns))
        }

        // ---- NN builtins (paper §3): plan-aware conv/pool dispatch -----
        // The seven conv/pool builtins flow through the unified
        // `dispatch_conv` value path: shapes are validated from handle
        // metadata before any force, blocked batches run worker-side over
        // row bands with the filter broadcast, outputs bind blocked, and
        // conv2d_backward_filter's small gradient returns with the job.
        _ if conv::conv_builtin(name).is_some() => {
            let op = conv::conv_builtin(name).unwrap();
            let sh = conv_shape(&a, op.needs_filter())?;
            // Operand roles: the batch-shaped operand leads; the filter
            // (or the companion dout batch) rides as aux. Note
            // conv2d_backward_data's batch operand is its *dout*
            // (second argument).
            let (x, hx, aux, haux) = match op {
                ConvOpKind::Conv2d => (
                    a.require(0, "input")?,
                    a.hint(0, "input"),
                    Some(a.require(1, "filter")?),
                    a.hint(1, "filter"),
                ),
                ConvOpKind::Conv2dBackwardData => (
                    a.require(1, "dout")?,
                    a.hint(1, "dout"),
                    Some(a.require(0, "filter")?),
                    a.hint(0, "filter"),
                ),
                ConvOpKind::Conv2dBackwardFilter
                | ConvOpKind::MaxPoolBackward
                | ConvOpKind::AvgPoolBackward => (
                    a.require(0, "input")?,
                    a.hint(0, "input"),
                    Some(a.require(1, "dout")?),
                    a.hint(1, "dout"),
                ),
                ConvOpKind::MaxPool | ConvOpKind::AvgPool => {
                    (a.require(0, "input")?, a.hint(0, "input"), None, None)
                }
            };
            one(interp.dispatch_conv_value(op, x, aux, &sh, Some(pos), hx, haux)?)
        }
        "bias_add" => {
            let x = a.require(0, "input")?;
            let b = a.require(1, "bias")?;
            one(interp.dispatch_bias_value(x, b, false, a.hint(1, "bias"))?)
        }
        "bias_multiply" => {
            let x = a.require(0, "input")?;
            let b = a.require(1, "bias")?;
            one(interp.dispatch_bias_value(x, b, true, a.hint(1, "bias"))?)
        }

        other => Err(DmlError::rt(format!("unknown builtin '{other}'"))),
    }
}
