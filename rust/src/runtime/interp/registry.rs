//! Script registry: resolves `source("path") as ns` imports, parses the
//! referenced files (searching the configured script paths), and builds a
//! validated [`Bundle`]. Sourced files may source further files; imports
//! are resolved transitively with cycle detection.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

use crate::conf::SystemConfig;
use crate::dml::ast::Program;
use crate::dml::parser::parse;
use crate::dml::validate::Bundle;
use crate::util::error::{DmlError, Result};

/// Build a [`Bundle`] for a parsed main program, loading its imports.
pub fn build_bundle(main: Program, config: &SystemConfig) -> Result<Bundle> {
    let mut bundle = Bundle { main, namespaces: HashMap::new() };
    let mut loading: HashSet<String> = HashSet::new();
    let imports = bundle.main.imports.clone();
    for imp in &imports {
        load_namespace(&imp.path, &imp.namespace, config, &mut bundle, &mut loading)?;
    }
    Ok(bundle)
}

fn load_namespace(
    path: &str,
    ns: &str,
    config: &SystemConfig,
    bundle: &mut Bundle,
    loading: &mut HashSet<String>,
) -> Result<()> {
    if bundle.namespaces.contains_key(ns) {
        return Ok(()); // already loaded under this namespace
    }
    if !loading.insert(path.to_string()) {
        return Err(DmlError::val(format!("cyclic source() import of '{path}'")));
    }
    let text = read_script(path, config)?;
    let prog = parse(&text).map_err(|e| {
        DmlError::val(format!("while parsing sourced file '{path}': {e}"))
    })?;
    // Register this namespace's functions.
    let mut funcs = HashMap::new();
    for f in prog.functions {
        funcs.insert(f.name.clone(), f);
    }
    bundle.namespaces.insert(ns.to_string(), funcs);
    // Transitive imports: loaded under their own namespace names; function
    // calls inside the sourced file resolve through those namespaces.
    for imp in &prog.imports {
        load_namespace(&imp.path, &imp.namespace, config, bundle, loading)?;
    }
    loading.remove(path);
    Ok(())
}

/// Locate and read a script by trying each configured search path.
pub fn read_script(path: &str, config: &SystemConfig) -> Result<String> {
    for base in &config.script_paths {
        let candidate: PathBuf = if Path::new(path).is_absolute() {
            PathBuf::from(path)
        } else {
            base.join(path)
        };
        if candidate.is_file() {
            return Ok(std::fs::read_to_string(&candidate)?);
        }
    }
    Err(DmlError::val(format!(
        "source: script '{path}' not found in search paths {:?}",
        config.script_paths
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config_with_tmp(dir: &Path) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.script_paths.insert(0, dir.to_path_buf());
        c
    }

    #[test]
    fn loads_imports_transitively() {
        let dir = std::env::temp_dir().join(format!("sysml_reg_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("lib")).unwrap();
        std::fs::write(
            dir.join("lib/a.dml"),
            "source(\"lib/b.dml\") as b\nfa = function(int x) return (int y) { y = b::fb(x) + 1 }",
        )
        .unwrap();
        std::fs::write(dir.join("lib/b.dml"), "fb = function(int x) return (int y) { y = x * 2 }")
            .unwrap();
        let main = parse("source(\"lib/a.dml\") as a\nz = a::fa(3)").unwrap();
        let bundle = build_bundle(main, &config_with_tmp(&dir)).unwrap();
        assert!(bundle.resolve(Some("a"), "fa").is_some());
        assert!(bundle.resolve(Some("b"), "fb").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_script_errors() {
        let main = parse("source(\"no/such/file.dml\") as x").unwrap();
        assert!(build_bundle(main, &SystemConfig::default()).is_err());
    }

    #[test]
    fn cyclic_imports_terminate() {
        // Mutually-sourcing files must not recurse forever; the second
        // visit of an already-registered namespace is a no-op.
        let dir = std::env::temp_dir().join(format!("sysml_cyc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("c1.dml"), "source(\"c2.dml\") as c2").unwrap();
        std::fs::write(dir.join("c2.dml"), "source(\"c1.dml\") as c1").unwrap();
        let main = parse("source(\"c1.dml\") as c1").unwrap();
        let bundle = build_bundle(main, &config_with_tmp(&dir)).unwrap();
        assert!(bundle.namespaces.contains_key("c1"));
        assert!(bundle.namespaces.contains_key("c2"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
