//! Runtime values for DML variables.

use crate::runtime::dist::BlockedHandle;
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};

/// A DML runtime value.
#[derive(Clone, Debug)]
pub enum Value {
    Double(f64),
    Int(i64),
    Bool(bool),
    Str(String),
    Matrix(Matrix),
    /// A first-class blocked matrix: the value *is* a handle into the
    /// distributed backend (`runtime::dist::BlockedHandle`). DIST
    /// consumers use the resident blocks directly; CP consumers force the
    /// lazy driver materialization through [`Value::as_matrix`] (one
    /// collect, memoized on the shared handle).
    Blocked(BlockedHandle),
    /// List literal (only flows into builtin shape arguments).
    List(Vec<Value>),
}

impl Value {
    /// Coerce to f64 (scalars and 1x1 matrices). A 1x1 blocked value is
    /// forced to the driver first; larger matrices are a clear error.
    pub fn as_double(&self) -> Result<f64> {
        match self {
            Value::Double(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            Value::Bool(b) => Ok(*b as i32 as f64),
            Value::Matrix(m) if m.shape() == (1, 1) => Ok(m.get(0, 0)),
            Value::Blocked(h) if h.shape() == (1, 1) => Ok(h.force()?.get(0, 0)),
            Value::Blocked(h) => Err(DmlError::rt(format!(
                "expected scalar, found a {}x{} blocked matrix (use as.scalar on a 1x1)",
                h.rows(),
                h.cols()
            ))),
            other => Err(DmlError::rt(format!("expected scalar, found {}", other.type_name()))),
        }
    }

    /// Coerce to integer (truncating doubles, like DML's implicit casts in
    /// loop bounds and index expressions).
    pub fn as_int(&self) -> Result<i64> {
        Ok(self.as_double()? as i64)
    }

    /// Coerce to boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Ok(other.as_double()? != 0.0),
        }
    }

    /// Borrow as a matrix; errors on scalars (DML requires as.matrix).
    /// Blocked values are *forced* here — this is the lazy collect every
    /// CP consumer funnels through (memoized per handle).
    pub fn as_matrix(&self) -> Result<&Matrix> {
        match self {
            Value::Matrix(m) => Ok(m),
            Value::Blocked(h) => h.force(),
            other => Err(DmlError::rt(format!("expected matrix, found {}", other.type_name()))),
        }
    }

    /// Matrix, scalar promoted to 1x1 (for cell-op operands). Forces
    /// blocked values.
    pub fn to_matrix(&self) -> Result<Matrix> {
        match self {
            Value::Matrix(m) => Ok(m.clone()),
            Value::Blocked(h) => Ok(h.force()?.clone()),
            other => Ok(Matrix::scalar(other.as_double()?)),
        }
    }

    /// Consume into a driver matrix, forcing blocked values (used by the
    /// matrix-typed compatibility APIs that predate blocked values).
    pub fn into_matrix(self) -> Result<Matrix> {
        match self {
            Value::Matrix(m) => Ok(m),
            Value::Blocked(h) => Ok(h.force()?.clone()),
            other => Err(DmlError::rt(format!("expected matrix, found {}", other.type_name()))),
        }
    }

    /// Matrix dimensions without forcing a blocked value (the handle
    /// carries its metadata).
    pub fn matrix_dims(&self) -> Result<(usize, usize)> {
        match self {
            Value::Matrix(m) => Ok(m.shape()),
            Value::Blocked(h) => Ok(h.shape()),
            other => Err(DmlError::rt(format!("expected matrix, found {}", other.type_name()))),
        }
    }

    /// Non-zero count without forcing a blocked value.
    pub fn matrix_nnz(&self) -> Result<usize> {
        match self {
            Value::Matrix(m) => Ok(m.nnz()),
            Value::Blocked(h) => Ok(h.nnz()),
            other => Err(DmlError::rt(format!("expected matrix, found {}", other.type_name()))),
        }
    }

    /// String representation for print/toString.
    pub fn to_display_string(&self) -> String {
        match self {
            Value::Double(v) => format_double(*v),
            Value::Int(v) => v.to_string(),
            Value::Bool(b) => if *b { "TRUE" } else { "FALSE" }.to_string(),
            Value::Str(s) => s.clone(),
            Value::Matrix(m) => display_matrix(m),
            Value::Blocked(h) => match h.force() {
                // Printing is a CP demand: force the driver copy.
                Ok(m) => display_matrix(m),
                Err(_) => format!("<blocked {}x{} matrix (unavailable)>", h.rows(), h.cols()),
            },
            Value::List(items) => {
                let parts: Vec<String> = items.iter().map(|v| v.to_display_string()).collect();
                format!("[{}]", parts.join(", "))
            }
        }
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Double(_) => "double",
            Value::Int(_) => "int",
            Value::Bool(_) => "boolean",
            Value::Str(_) => "string",
            Value::Matrix(_) => "matrix",
            Value::Blocked(_) => "matrix",
            Value::List(_) => "list",
        }
    }

    /// Is this a matrix-typed value (driver-resident or blocked)?
    pub fn is_matrix(&self) -> bool {
        matches!(self, Value::Matrix(_) | Value::Blocked(_))
    }

    /// List of usize (shape arguments like input_shape=[N,C,H,W]).
    /// Blocked items are forced through the scalar coercion, which gives
    /// a clear error (not a panic) for non-1x1 shapes.
    pub fn as_usize_list(&self) -> Result<Vec<usize>> {
        match self {
            Value::List(items) => items.iter().map(|v| Ok(v.as_int()? as usize)).collect(),
            other => Err(DmlError::rt(format!(
                "expected list (e.g. [N,C,H,W]), found {}",
                other.type_name()
            ))),
        }
    }
}

fn display_matrix(m: &Matrix) -> String {
    let (r, c) = m.shape();
    let mut out = String::new();
    for i in 0..r.min(10) {
        let cells: Vec<String> = (0..c.min(12)).map(|j| format_double(m.get(i, j))).collect();
        out.push_str(&cells.join(" "));
        if c > 12 {
            out.push_str(" ...");
        }
        out.push('\n');
    }
    if r > 10 {
        out.push_str(&format!("... ({r}x{c} matrix)\n"));
    }
    out
}

/// Format a double like DML's print (integral values without ".0...").
pub fn format_double(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::dist::Cluster;
    use std::sync::Arc;

    #[test]
    fn scalar_coercions() {
        assert_eq!(Value::Int(3).as_double().unwrap(), 3.0);
        assert_eq!(Value::Double(2.5).as_int().unwrap(), 2);
        assert!(Value::Bool(true).as_bool().unwrap());
        assert!(Value::Double(1.0).as_bool().unwrap());
        assert!(!Value::Double(0.0).as_bool().unwrap());
        assert!(Value::Str("x".into()).as_double().is_err());
    }

    #[test]
    fn one_by_one_matrix_is_scalar_coercible() {
        let v = Value::Matrix(Matrix::scalar(7.0));
        assert_eq!(v.as_double().unwrap(), 7.0);
        let m = Value::Matrix(Matrix::zeros(2, 2));
        assert!(m.as_double().is_err());
    }

    #[test]
    fn display_formats() {
        assert_eq!(Value::Double(3.0).to_display_string(), "3");
        assert_eq!(Value::Bool(false).to_display_string(), "FALSE");
        assert_eq!(Value::Int(-2).to_display_string(), "-2");
    }

    #[test]
    fn usize_list() {
        let l = Value::List(vec![Value::Int(1), Value::Int(28)]);
        assert_eq!(l.as_usize_list().unwrap(), vec![1, 28]);
        assert!(Value::Int(1).as_usize_list().is_err());
    }

    fn blocked_value(cluster: &Arc<Cluster>, m: &Matrix) -> Value {
        let b = Arc::new(cluster.blockify(m).unwrap());
        Value::Blocked(BlockedHandle::new(cluster.clone(), b))
    }

    #[test]
    fn blocked_value_is_matrix_typed_and_lazy() {
        let cluster = Arc::new(Cluster::new(2, 4));
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let v = blocked_value(&cluster, &m);
        assert!(v.is_matrix());
        assert_eq!(v.type_name(), "matrix");
        assert_eq!(v.matrix_dims().unwrap(), (2, 2));
        assert_eq!(v.matrix_nnz().unwrap(), 4);
        // Metadata queries must not collect.
        assert_eq!(cluster.collect_count(), 0);
        // Forcing collects exactly once, memoized across consumers.
        assert_eq!(*v.as_matrix().unwrap(), m);
        assert_eq!(*v.as_matrix().unwrap(), m);
        assert_eq!(cluster.collect_count(), 1);
    }

    #[test]
    fn blocked_scalar_casts_error_clearly_instead_of_panicking() {
        let cluster = Arc::new(Cluster::new(2, 4));
        let big = blocked_value(&cluster, &Matrix::filled(3, 2, 1.0));
        let err = big.as_double().unwrap_err().to_string();
        assert!(err.contains("3x2"), "{err}");
        let one = blocked_value(&cluster, &Matrix::scalar(5.0));
        assert_eq!(one.as_double().unwrap(), 5.0);
        // A blocked value inside a shape list coerces (or errors) cleanly.
        let l = Value::List(vec![blocked_value(&cluster, &Matrix::scalar(4.0))]);
        assert_eq!(l.as_usize_list().unwrap(), vec![4]);
        let bad = Value::List(vec![blocked_value(&cluster, &Matrix::filled(2, 2, 1.0))]);
        assert!(bad.as_usize_list().is_err());
    }
}
