//! Runtime values for DML variables.

use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};

/// A DML runtime value.
#[derive(Clone, Debug)]
pub enum Value {
    Double(f64),
    Int(i64),
    Bool(bool),
    Str(String),
    Matrix(Matrix),
    /// List literal (only flows into builtin shape arguments).
    List(Vec<Value>),
}

impl Value {
    /// Coerce to f64 (scalars and 1x1 matrices).
    pub fn as_double(&self) -> Result<f64> {
        match self {
            Value::Double(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            Value::Bool(b) => Ok(*b as i32 as f64),
            Value::Matrix(m) if m.shape() == (1, 1) => Ok(m.get(0, 0)),
            other => Err(DmlError::rt(format!("expected scalar, found {}", other.type_name()))),
        }
    }

    /// Coerce to integer (truncating doubles, like DML's implicit casts in
    /// loop bounds and index expressions).
    pub fn as_int(&self) -> Result<i64> {
        Ok(self.as_double()? as i64)
    }

    /// Coerce to boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Ok(other.as_double()? != 0.0),
        }
    }

    /// Borrow as a matrix; errors on scalars (DML requires as.matrix).
    pub fn as_matrix(&self) -> Result<&Matrix> {
        match self {
            Value::Matrix(m) => Ok(m),
            other => Err(DmlError::rt(format!("expected matrix, found {}", other.type_name()))),
        }
    }

    /// Matrix, scalar promoted to 1x1 (for cell-op operands).
    pub fn to_matrix(&self) -> Result<Matrix> {
        match self {
            Value::Matrix(m) => Ok(m.clone()),
            other => Ok(Matrix::scalar(other.as_double()?)),
        }
    }

    /// String representation for print/toString.
    pub fn to_display_string(&self) -> String {
        match self {
            Value::Double(v) => format_double(*v),
            Value::Int(v) => v.to_string(),
            Value::Bool(b) => if *b { "TRUE" } else { "FALSE" }.to_string(),
            Value::Str(s) => s.clone(),
            Value::Matrix(m) => {
                let (r, c) = m.shape();
                let mut out = String::new();
                for i in 0..r.min(10) {
                    let cells: Vec<String> =
                        (0..c.min(12)).map(|j| format_double(m.get(i, j))).collect();
                    out.push_str(&cells.join(" "));
                    if c > 12 {
                        out.push_str(" ...");
                    }
                    out.push('\n');
                }
                if r > 10 {
                    out.push_str(&format!("... ({r}x{c} matrix)\n"));
                }
                out
            }
            Value::List(items) => {
                let parts: Vec<String> = items.iter().map(|v| v.to_display_string()).collect();
                format!("[{}]", parts.join(", "))
            }
        }
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Double(_) => "double",
            Value::Int(_) => "int",
            Value::Bool(_) => "boolean",
            Value::Str(_) => "string",
            Value::Matrix(_) => "matrix",
            Value::List(_) => "list",
        }
    }

    pub fn is_matrix(&self) -> bool {
        matches!(self, Value::Matrix(_))
    }

    /// List of usize (shape arguments like input_shape=[N,C,H,W]).
    pub fn as_usize_list(&self) -> Result<Vec<usize>> {
        match self {
            Value::List(items) => items.iter().map(|v| Ok(v.as_int()? as usize)).collect(),
            other => Err(DmlError::rt(format!(
                "expected list (e.g. [N,C,H,W]), found {}",
                other.type_name()
            ))),
        }
    }
}

/// Format a double like DML's print (integral values without ".0...").
pub fn format_double(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_coercions() {
        assert_eq!(Value::Int(3).as_double().unwrap(), 3.0);
        assert_eq!(Value::Double(2.5).as_int().unwrap(), 2);
        assert!(Value::Bool(true).as_bool().unwrap());
        assert!(Value::Double(1.0).as_bool().unwrap());
        assert!(!Value::Double(0.0).as_bool().unwrap());
        assert!(Value::Str("x".into()).as_double().is_err());
    }

    #[test]
    fn one_by_one_matrix_is_scalar_coercible() {
        let v = Value::Matrix(Matrix::scalar(7.0));
        assert_eq!(v.as_double().unwrap(), 7.0);
        let m = Value::Matrix(Matrix::zeros(2, 2));
        assert!(m.as_double().is_err());
    }

    #[test]
    fn display_formats() {
        assert_eq!(Value::Double(3.0).to_display_string(), "3");
        assert_eq!(Value::Bool(false).to_display_string(), "FALSE");
        assert_eq!(Value::Int(-2).to_display_string(), "-2");
    }

    #[test]
    fn usize_list() {
        let l = Value::List(vec![Value::Int(1), Value::Int(28)]);
        assert_eq!(l.as_usize_list().unwrap(), vec![1, 28]);
        assert!(Value::Int(1).as_usize_list().is_err());
    }
}
