//! Unified heavy-operator dispatch: every matmult, cellwise binary
//! (cell-aligned or vector-broadcast), transpose, right-/left-index, and
//! aggregate flows through one placement path that
//! (1) consults the compiled plan's ExecType for the operator's source
//! position, (2) falls back to the same cost model at runtime when the
//! shape was unknown at compile time, and (3) dynamically "recompiles"
//! when the actual runtime estimate contradicts the planned placement
//! (paper §3's recompilation hook). Every decision is surfaced through
//! `EXPLAIN` — CP, DIST and ACCEL placements alike.
//!
//! Operands arrive as [`Operand`]s: either driver-resident matrices or
//! first-class blocked values (`Value::Blocked`). A blocked operand *is*
//! the handle — it needs no cache lookup and no guard fingerprint, and
//! it forces the operator DIST (collecting it to honor a CP placement
//! would cost more than the distributed op). DIST results are bound as
//! blocked values again (`bind_dist_result`), so chains of distributed
//! operators never round-trip through the driver. Single-block outputs
//! split two ways: an *aggregation-shaped* result (a gradient matmult
//! `t(X) %*% dout`, a `conv2d_backward_filter` gradient, a single-block
//! axis aggregate) is combined via a modeled tree-allreduce and bound
//! **replicated** on every worker (`bind_replicated_result`) so the
//! optimizer update that consumes it runs cluster-side with zero
//! collects; any other single-block output returns to the driver as part
//! of the job — SystemML's SINGLE_BLOCK aggregation. Operators over a
//! replicated operand (scalar/unary/cellwise maps, transpose) bind their
//! single-block result replicated again, which is what keeps model state
//! and optimizer moment buffers resident across a whole training job.

use std::borrow::Cow;
use std::sync::Arc;

use crate::dml::ast::Pos;
use crate::hop::dag::agg_name;
use crate::hop::estimate;
use crate::hop::plan::{choose_exec, ExecType, OpKind};
use crate::runtime::conv::{self, ConvOpKind, ConvShape};
use crate::runtime::dist::cache::{CacheOutcome, Guard, LineageRef};
use crate::runtime::dist::nn as dist_nn;
use crate::runtime::dist::ops as dist_ops;
use crate::runtime::dist::{BlockedHandle, BlockedMatrix, Cluster};
use crate::runtime::interp::{Interpreter, Value};
use crate::runtime::matrix::agg::{self, AggOp};
use crate::runtime::matrix::elementwise::{self, BinOp, UnaryOp};
use crate::runtime::matrix::{mult, reorg, Matrix};
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// A matrix operand as the dispatch layer sees it: driver-resident, or a
/// live blocked value whose metadata (dims/nnz/bytes) is available
/// without touching the driver.
pub(crate) enum Operand<'a> {
    Driver(&'a Matrix),
    Handle(&'a BlockedHandle),
}

impl<'a> Operand<'a> {
    pub(crate) fn of(v: &'a Value) -> Result<Operand<'a>> {
        match v {
            Value::Matrix(m) => Ok(Operand::Driver(m)),
            Value::Blocked(h) => Ok(Operand::Handle(h)),
            other => {
                Err(DmlError::rt(format!("expected matrix, found {}", other.type_name())))
            }
        }
    }

    fn rows(&self) -> usize {
        match self {
            Operand::Driver(m) => m.rows(),
            Operand::Handle(h) => h.rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            Operand::Driver(m) => m.cols(),
            Operand::Handle(h) => h.cols(),
        }
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    fn size_in_bytes(&self) -> usize {
        match self {
            Operand::Driver(m) => m.size_in_bytes(),
            Operand::Handle(h) => h.size_in_bytes(),
        }
    }

    fn nnz(&self) -> usize {
        match self {
            Operand::Driver(m) => m.nnz(),
            Operand::Handle(h) => h.nnz(),
        }
    }

    fn sparsity(&self) -> f64 {
        let cells = self.rows() * self.cols();
        if cells == 0 {
            return 0.0;
        }
        self.nnz() as f64 / cells as f64
    }

    /// Would the estimator size this operand as CSR? Drives the
    /// ` SPARSE` EXPLAIN tag — the runtime mirror of the planner's
    /// marker, so a sparse-sized placement decision is observable.
    fn plans_sparse(&self) -> bool {
        Matrix::prefers_sparse(self.rows(), self.cols(), self.nnz())
    }

    fn is_blocked(&self) -> bool {
        matches!(self, Operand::Handle(_))
    }

    /// Driver view of the operand (forces blocked values — the lazy
    /// collect).
    fn force(&self) -> Result<&'a Matrix> {
        match self {
            Operand::Driver(m) => Ok(*m),
            Operand::Handle(h) => h.force(),
        }
    }
}

/// A blocked rhs operand (broadcast-join vector, left-index patch, conv
/// filter) in driver form, plus whether its cells already live
/// cluster-side. A forced handle's memoized driver copy behaves like any
/// driver operand (it will be charged as a broadcast, resident = false);
/// an unforced handle is gathered worker-side — charged as a shuffle,
/// never a collect — through the handle's **memoized** gather
/// ([`BlockedHandle::gathered`]) when it fits `memo_cap` (one shuffle on
/// first use, free afterwards: a loop-invariant blocked rhs gathers once
/// per loop, not once per op; the memoized copy is charged to the
/// cluster's storage budget), or transiently when larger — pinning a
/// second full materialization on a big live handle would double its
/// footprint. The cap is `SystemConfig::gather_memo_bytes`. Either way
/// the operand is marked resident so the consuming op does not charge a
/// second broadcast of the same bytes.
fn gather_blocked_rhs(h: &BlockedHandle, memo_cap: usize) -> Result<(Cow<'_, Matrix>, bool)> {
    if h.is_forced() {
        Ok((Cow::Borrowed(h.force()?), false))
    } else if h.size_in_bytes() <= memo_cap {
        Ok((Cow::Borrowed(h.gathered()?), true))
    } else {
        h.cluster().record_shuffle(h.size_in_bytes() as u64);
        Ok((Cow::Owned(h.blocked()?.to_local()?), true))
    }
}

/// In-flight measurement of one dispatched operator: the baselines its
/// deltas are computed against. Created by [`Interpreter::op_begin`]
/// (`None` when stats are off — the zero-cost path) and consumed by
/// [`Interpreter::op_end`] on each success branch; error paths drop the
/// probe, so failed operators never pollute the heavy-hitter table.
pub(crate) struct OpProbe {
    op: String,
    t0: std::time::Instant,
    flops0: u64,
    comm0: u64,
}

/// SystemML's `CP`/`SP` instruction prefix for the heavy-hitter table.
fn exec_str(e: ExecType) -> &'static str {
    match e {
        ExecType::CP => "CP",
        ExecType::Dist => "DIST",
        ExecType::Accel => "ACCEL",
    }
}

impl Interpreter {
    fn cluster_ref(&self) -> Result<&Arc<Cluster>> {
        self.cluster
            .as_ref()
            .ok_or_else(|| DmlError::rt("distributed backend unavailable"))
    }

    /// Open an operator probe (and its trace span). The opcode closure
    /// runs only when stats are on, so the disabled path allocates
    /// nothing and costs a single pointer check.
    #[inline]
    pub(crate) fn op_begin<F: FnOnce() -> String>(&self, op: F) -> Option<OpProbe> {
        let stats = self.stats.as_ref()?;
        let op = op();
        stats.span_open("operator", &op);
        Some(OpProbe {
            op,
            t0: std::time::Instant::now(),
            flops0: metrics::global().flops.load(std::sync::atomic::Ordering::Relaxed),
            comm0: self.cluster.as_ref().map_or(0, |c| c.comm_bytes()),
        })
    }

    /// Close an operator probe: record invocation count, wall time, FLOP
    /// and communication deltas under `(opcode, position, exec type)`.
    /// All deltas except wall time are taken from driver-side accounting
    /// after the (barriered) op completed, so they are byte-identical
    /// across `dist_threads` settings.
    pub(crate) fn op_end(&self, probe: Option<OpProbe>, pos: Option<Pos>, exec: ExecType) {
        let (Some(p), Some(stats)) = (probe, self.stats.as_ref()) else {
            return;
        };
        let nanos = p.t0.elapsed().as_nanos() as u64;
        let flops = metrics::global()
            .flops
            .load(std::sync::atomic::Ordering::Relaxed)
            .saturating_sub(p.flops0);
        let comm =
            self.cluster.as_ref().map_or(0, |c| c.comm_bytes()).saturating_sub(p.comm0);
        let pos = pos.map_or_else(|| "-".to_string(), |p| format!("{}:{}", p.line, p.col));
        stats.record_op(&p.op, &pos, exec_str(exec), nanos, flops, comm);
    }

    /// Resolve the execution type for one heavy operator instance.
    ///
    /// `est` is the worst-case memory estimate from the *actual* runtime
    /// operands; the compiled placement (if any) wins unless it is no
    /// longer feasible, in which case the operator is re-placed with the
    /// same cost model (dynamic recompilation). `blocked_operand` short
    /// circuits to DIST: the operand's partitions are already resident
    /// on the cluster, so the blockify cost is zero and collecting it to
    /// run CP would be strictly worse.
    fn resolve_exec(
        &self,
        kind: OpKind,
        pos: Option<Pos>,
        est: usize,
        desc: &str,
        blocked_operand: bool,
    ) -> Result<ExecType> {
        if blocked_operand && self.cluster.is_some() {
            if self.config.explain {
                self.emit(format!(
                    "EXPLAIN: {desc} -> DIST (operand blocked, zero blockify cost, est {est} B)"
                ));
            }
            return Ok(ExecType::Dist);
        }
        let planned = pos
            .and_then(|p| self.plan.as_ref().and_then(|plan| plan.placement(p, kind)))
            .map(|p| p.exec);
        let mut exec = planned.unwrap_or_else(|| choose_exec(est, &self.config, false));
        let mut note = if planned.is_some() { " planned" } else { "" };
        // A planned ACCEL placement reaches this point only when the
        // accelerator declined the operator (no artifact / no backend):
        // fall back to the CP-vs-DIST decision.
        if exec == ExecType::Accel {
            exec = choose_exec(est, &self.config, false);
            note = " accel-fallback";
        }
        // Dynamic recompilation against the runtime estimate.
        if exec == ExecType::CP && est > self.config.driver_memory {
            if self.cluster.is_some() {
                exec = ExecType::Dist;
                if planned.is_some() {
                    note = " recompiled";
                }
            } else {
                return Err(DmlError::rt(format!(
                    "{desc}: memory estimate {est} B exceeds driver budget {} B and the \
                     distributed backend is disabled",
                    self.config.driver_memory
                )));
            }
        }
        if exec == ExecType::Dist && self.cluster.is_none() {
            if est <= self.config.driver_memory {
                exec = ExecType::CP;
                note = " recompiled";
            } else {
                return Err(DmlError::rt(format!(
                    "{desc}: memory estimate {est} B exceeds driver budget {} B and the \
                     distributed backend is disabled",
                    self.config.driver_memory
                )));
            }
        }
        if self.config.explain {
            let rel = if est > self.config.driver_memory { ">" } else { "<=" };
            self.emit(format!(
                "EXPLAIN: {desc} -> {exec} (est {est} B {rel} budget {} B{note})",
                self.config.driver_memory
            ));
        }
        Ok(exec)
    }

    /// Resolve a DIST operand to blocked form through the cluster's
    /// lineage-keyed block cache, emitting the `CACHE(hit|miss|evict)`
    /// EXPLAIN lines that make reuse observable.
    fn cache_acquire(
        &self,
        cluster: &Cluster,
        hint: Option<&LineageRef>,
        m: &Matrix,
        side: &str,
    ) -> Result<(Arc<BlockedMatrix>, CacheOutcome)> {
        let (blocked, outcome) = cluster.acquire_blocked(hint, m)?;
        if let Some(stats) = &self.stats {
            let kind = if outcome.is_hit() { "cache_hit" } else { "cache_miss" };
            stats.event(kind, blocked.size_in_bytes() as u64);
        }
        if self.config.explain {
            match &outcome {
                CacheOutcome::Hit { key } => self.emit(format!(
                    "EXPLAIN: CACHE(hit) {key} {side} ({}x{}, {} blocks resident)",
                    m.rows(),
                    m.cols(),
                    blocked.block_rows() * blocked.block_cols()
                )),
                CacheOutcome::Miss { key, evicted, evicted_bytes } => {
                    self.emit(format!(
                        "EXPLAIN: CACHE(miss) {key} {side} ({}x{}, blockify {} blocks)",
                        m.rows(),
                        m.cols(),
                        blocked.block_rows() * blocked.block_cols()
                    ));
                    if *evicted > 0 {
                        self.emit(format!(
                            "EXPLAIN: CACHE(evict) {evicted} entries, {evicted_bytes} B freed (budget {} B)",
                            cluster.cache().budget()
                        ));
                    }
                }
            }
        }
        Ok((blocked, outcome))
    }

    /// Resolve one DIST operand to its blocked form: a blocked value
    /// hands over its resident partitions directly (no cache lookup, no
    /// guard fingerprint — the value *is* the handle); a driver matrix
    /// goes through the guarded lineage cache. The bool reports whether
    /// the partitions were already resident (for communication
    /// accounting).
    fn acquire_operand(
        &self,
        cluster: &Cluster,
        op: &Operand,
        hint: Option<&LineageRef>,
        side: &str,
    ) -> Result<(Arc<BlockedMatrix>, bool)> {
        match op {
            Operand::Handle(h) => {
                let b = h.blocked()?;
                if self.config.explain {
                    self.emit(format!(
                        "EXPLAIN: BLOCKED(reuse) {side} ({}x{}, {} blocks resident)",
                        h.rows(),
                        h.cols(),
                        b.block_rows() * b.block_cols()
                    ));
                }
                Ok((b, true))
            }
            Operand::Driver(m) => {
                let (b, outcome) = self.cache_acquire(cluster, hint, m, side)?;
                Ok((b, outcome.is_hit()))
            }
        }
    }

    /// Bind a DIST operator's blocked output as a value. Multi-block
    /// outputs become first-class blocked values (no driver round trip);
    /// a single-block output returns to the driver as part of the job
    /// (SystemML's SINGLE_BLOCK aggregation — it is the job's result,
    /// not a collect of a distributed object). With `blocked_values`
    /// disabled, every output is eagerly collected as before.
    fn bind_dist_result(&self, cluster: &Arc<Cluster>, out: Arc<BlockedMatrix>) -> Result<Value> {
        if !self.config.blocked_values {
            let local = cluster.collect(&out)?;
            cluster.cache().offer_result(out, Guard::of(&local));
            return Ok(Value::Matrix(local));
        }
        if out.block_rows() * out.block_cols() <= 1 {
            let local = out.to_local()?;
            // Still offer the partition to the pending cache so a nested
            // DIST consumer (or the adopting assignment) reuses it
            // without re-blockifying the driver copy.
            cluster.cache().offer_result(out, Guard::of(&local));
            return Ok(Value::Matrix(local));
        }
        Ok(Value::Blocked(BlockedHandle::new(cluster.clone(), out)))
    }

    /// Bind an allreduce-combined single-block output **replicated** on
    /// every worker: the value stays cluster-side (one copy per worker,
    /// charged to storage accordingly), forces and gathers for free, and
    /// keeps downstream per-block maps — the optimizer update chain —
    /// distributed. With `blocked_values` disabled this falls back to the
    /// eager-collect legacy path of [`Self::bind_dist_result`].
    fn bind_replicated_result(
        &self,
        cluster: &Arc<Cluster>,
        out: Arc<BlockedMatrix>,
    ) -> Result<Value> {
        if !self.config.blocked_values {
            return self.bind_dist_result(cluster, out);
        }
        if self.config.explain {
            self.emit(format!(
                "EXPLAIN: ALLREDUCE result {}x{} replicated on {} worker(s)",
                out.rows(),
                out.cols(),
                cluster.num_workers()
            ));
        }
        Ok(Value::Blocked(BlockedHandle::replicated(cluster.clone(), out)))
    }

    // ---- matrix multiplication ---------------------------------------

    /// Heavy-operator dispatch for `%*%`: ACCEL when a compiled artifact
    /// matches, else CP vs DIST by placement/estimate (paper §3).
    pub fn dispatch_matmult(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        self.dispatch_matmult_at(a, b, None)
    }

    /// [`Self::dispatch_matmult`] with the operator's source position for
    /// compiled-placement lookup.
    pub fn dispatch_matmult_at(&self, a: &Matrix, b: &Matrix, pos: Option<Pos>) -> Result<Matrix> {
        self.matmult_operands(Operand::Driver(a), Operand::Driver(b), pos, None, None)?
            .into_matrix()
    }

    /// [`Self::dispatch_matmult_at`] with the operands' lineage
    /// references for block-cache reuse on DIST placements. Returns a
    /// driver matrix (forcing any blocked result) for pre-blocked-value
    /// callers.
    pub fn dispatch_matmult_hinted(
        &self,
        a: &Matrix,
        b: &Matrix,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Matrix> {
        self.matmult_operands(Operand::Driver(a), Operand::Driver(b), pos, ha, hb)?
            .into_matrix()
    }

    /// Value-level `%*%` dispatch: blocked operands stay on the cluster,
    /// and the result is bound blocked when it is multi-block.
    pub fn dispatch_matmult_values(
        &self,
        l: &Value,
        r: &Value,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Value> {
        self.matmult_operands(Operand::of(l)?, Operand::of(r)?, pos, ha, hb)
    }

    pub(crate) fn matmult_operands(
        &self,
        a: Operand,
        b: Operand,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Value> {
        let probe = self.op_begin(|| "ba+*".to_string());
        // Accelerator first: compiled artifacts handle specific shapes
        // (driver-resident operands only — blocked data stays cluster-side).
        if let (Operand::Driver(am), Operand::Driver(bm), Some(accel)) =
            (&a, &b, &self.accel)
        {
            if let Some(out) = accel.try_matmult(am, bm)? {
                if self.config.explain {
                    self.emit(format!(
                        "EXPLAIN: %*% ({}x{} @ {}x{}) -> ACCEL (artifact hit, device budget {} B)",
                        am.rows(),
                        am.cols(),
                        bm.rows(),
                        bm.cols(),
                        self.config.accel_memory
                    ));
                }
                self.op_end(probe, pos, ExecType::Accel);
                return Ok(Value::Matrix(out));
            }
        }
        let est = estimate::matmult_mem_parts(
            a.size_in_bytes(),
            a.rows(),
            a.cols(),
            a.sparsity(),
            b.size_in_bytes(),
            b.cols(),
            b.sparsity(),
        );
        let tag = if a.plans_sparse() || b.plans_sparse() { " SPARSE" } else { "" };
        let desc = format!("%*% ({}x{} @ {}x{}){tag}", a.rows(), a.cols(), b.rows(), b.cols());
        let blocked_in = a.is_blocked() || b.is_blocked();
        match self.resolve_exec(OpKind::MatMult, pos, est, &desc, blocked_in)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (ab, ra) = self.acquire_operand(cluster, &a, ha, "lhs")?;
                let (bb, rb) = self.acquire_operand(cluster, &b, hb, "rhs")?;
                let resident = dist_ops::Residency { lhs: ra, rhs: rb };
                let allreduce = dist_ops::is_allreduce_matmult(&ab, &bb);
                let out = dist_ops::matmult_blocked_reuse(cluster, &ab, &bb, resident)?;
                let bound = if allreduce {
                    // Gradient-shaped product (t(X) %*% dout): the k
                    // partials tree-allreduce into a single block that
                    // stays replicated on the workers.
                    self.bind_replicated_result(cluster, Arc::new(out))
                } else {
                    self.bind_dist_result(cluster, Arc::new(out))
                };
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let out = mult::matmult(a.force()?, b.force()?)?;
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    // ---- cellwise binaries -------------------------------------------

    /// Unified dispatch for matrix∘matrix cellwise binaries. Broadcasting
    /// pairs (row/col vector operands) stay CP; cell-aligned pairs over
    /// the driver budget — or with a blocked operand — run blocked on the
    /// cluster.
    pub fn dispatch_binary(
        &self,
        a: &Matrix,
        b: &Matrix,
        op: BinOp,
        pos: Option<Pos>,
    ) -> Result<Matrix> {
        self.dispatch_binary_hinted(a, b, op, pos, None, None)
    }

    /// [`Self::dispatch_binary`] with the operands' lineage references
    /// for block-cache reuse on DIST placements.
    pub fn dispatch_binary_hinted(
        &self,
        a: &Matrix,
        b: &Matrix,
        op: BinOp,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Matrix> {
        self.binary_operands(Operand::Driver(a), Operand::Driver(b), op, pos, ha, hb)?
            .into_matrix()
    }

    /// Value-level cellwise binary dispatch.
    pub fn dispatch_binary_values(
        &self,
        l: &Value,
        r: &Value,
        op: BinOp,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Value> {
        self.binary_operands(Operand::of(l)?, Operand::of(r)?, op, pos, ha, hb)
    }

    pub(crate) fn binary_operands(
        &self,
        a: Operand,
        b: Operand,
        op: BinOp,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Value> {
        if a.shape() != b.shape() {
            // Broadcasting pair (1x1 / row-vector / col-vector rhs):
            // map-side broadcast join on DIST placements (probed there).
            return self.binary_broadcast_operands(a, b, op, pos, ha, hb);
        }
        let probe = self.op_begin(|| format!("b({op:?})"));
        let est =
            estimate::binary_mem_parts(a.size_in_bytes(), b.size_in_bytes(), a.rows(), a.cols());
        let tag = if a.plans_sparse() || b.plans_sparse() { " SPARSE" } else { "" };
        let desc = format!("b({op:?}) ({}x{}){tag}", a.rows(), a.cols());
        let blocked_in = a.is_blocked() || b.is_blocked();
        match self.resolve_exec(OpKind::CellBinary, pos, est, &desc, blocked_in)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                // W + vW on resident model state: if either side is
                // replicated the (single-block) result is too — the
                // optimizer update runs as a per-block map on every
                // worker and the weights never leave the cluster.
                let replicated_in = matches!(&a, Operand::Handle(h) if h.is_replicated())
                    || matches!(&b, Operand::Handle(h) if h.is_replicated());
                let (ab, _) = self.acquire_operand(cluster, &a, ha, "lhs")?;
                let (bb, _) = self.acquire_operand(cluster, &b, hb, "rhs")?;
                let out = dist_ops::binary_blocked(cluster, &ab, &bb, op)?;
                let bound = if replicated_in && out.block_rows() * out.block_cols() <= 1 {
                    self.bind_replicated_result(cluster, Arc::new(out))
                } else {
                    self.bind_dist_result(cluster, Arc::new(out))
                };
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let out = elementwise::binary(a.force()?, b.force()?, op)?;
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    /// Shape-mismatched cellwise pair. A 1x1 rhs promotes to the scalar
    /// op (blocked operands map cluster-side); a row/col-vector rhs runs
    /// as a **map-side broadcast cellwise join** on DIST placements — the
    /// vector is broadcast to the workers (charged to broadcast
    /// accounting) and joined against each resident block, so
    /// `X - mu` / `X / sigma` keep `X` distributed. Everything else falls
    /// to the CP kernel, whose `DimMismatch` is the canonical error for
    /// truly incompatible shapes (the DIST path raises the identical
    /// error). Mirrors the CP kernel: only a *rhs* vector broadcasts.
    fn binary_broadcast_operands(
        &self,
        a: Operand,
        b: Operand,
        op: BinOp,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Value> {
        let ((lr, lc), (rr, rc)) = (a.shape(), b.shape());
        let probe = self.op_begin(|| format!("b({op:?})"));
        // 1x1 rhs promotion (the CP kernel's scalar broadcast).
        if (rr, rc) == (1, 1) && (lr, lc) != (1, 1) {
            let s = b.force()?.get(0, 0);
            return match &a {
                Operand::Handle(h) => {
                    let cluster = h.cluster();
                    let out = dist_ops::scalar_blocked(cluster, &h.blocked()?, s, op, false)?;
                    let bound = if h.is_replicated() {
                        self.bind_replicated_result(cluster, Arc::new(out))
                    } else {
                        self.bind_dist_result(cluster, Arc::new(out))
                    };
                    self.op_end(probe, pos, ExecType::Dist);
                    bound
                }
                Operand::Driver(m) => {
                    let out = elementwise::scalar_op(m, s, op, false)?;
                    self.op_end(probe, pos, ExecType::CP);
                    Ok(Value::Matrix(out))
                }
            };
        }
        let col = rr == lr && rc == 1;
        let row = rc == lc && rr == 1;
        if !(col || row) {
            // True mismatch (or a vector lhs, which the CP kernel also
            // rejects): the kernel raises the canonical DimMismatch.
            let out = elementwise::binary(a.force()?, b.force()?, op)?;
            self.op_end(probe, pos, ExecType::CP);
            return Ok(Value::Matrix(out));
        }
        let est =
            estimate::binary_mem_parts(a.size_in_bytes(), b.size_in_bytes(), lr, lc);
        let axis = if col { "col" } else { "row" };
        let desc = format!("b({op:?}) bcast-{axis} ({lr}x{lc} o {rr}x{rc})");
        match self.resolve_exec(OpKind::CellBinary, pos, est, &desc, a.is_blocked())? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (ab, _) = self.acquire_operand(cluster, &a, ha, "lhs")?;
                // Vector operand in driver form; a blocked vector
                // gathers worker-side (see gather_blocked_rhs — a
                // shuffle, never a collect). A *named* driver vector
                // registers in the block cache like matmult's small
                // side: a guarded hit means the workers already hold
                // the broadcast, so a loop-invariant `mu`/`sigma` is
                // charged once, not once per batch. Anonymous vectors
                // (fresh expressions) skip the cache — blockifying them
                // would cost more than it saves.
                let (vm, v_resident): (Cow<Matrix>, bool) = match &b {
                    Operand::Driver(m) => {
                        let resident = match hb {
                            Some(hint) => {
                                let (_, outcome) =
                                    self.cache_acquire(cluster, Some(hint), m, "rhs")?;
                                outcome.is_hit()
                            }
                            None => false,
                        };
                        (Cow::Borrowed(*m), resident)
                    }
                    Operand::Handle(h) => {
                        gather_blocked_rhs(h, self.config.gather_memo_bytes)?
                    }
                };
                if self.config.explain {
                    self.emit(format!(
                        "EXPLAIN: BCAST {axis}-vector {rr}x{rc} joined map-side ({} B per worker)",
                        vm.size_in_bytes()
                    ));
                }
                let out =
                    dist_ops::binary_broadcast_blocked(cluster, &ab, vm.as_ref(), op, v_resident)?;
                let bound = if matches!(&a, Operand::Handle(h) if h.is_replicated())
                    && out.block_rows() * out.block_cols() <= 1
                {
                    self.bind_replicated_result(cluster, Arc::new(out))
                } else {
                    self.bind_dist_result(cluster, Arc::new(out))
                };
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let out = elementwise::binary(a.force()?, b.force()?, op)?;
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    /// Matrix∘scalar cellwise op. Driver matrices stay CP (a scalar op
    /// never changes residency); a blocked operand maps over its resident
    /// blocks so the chain stays distributed.
    pub fn dispatch_scalar_value(
        &self,
        v: &Value,
        s: f64,
        op: BinOp,
        swapped: bool,
    ) -> Result<Value> {
        let probe = self.op_begin(|| format!("s({op:?})"));
        match v {
            Value::Blocked(h) => {
                let cluster = h.cluster();
                let out = dist_ops::scalar_blocked(cluster, &h.blocked()?, s, op, swapped)?;
                let bound = if h.is_replicated() {
                    // lr * dW on replicated gradient state: a per-block
                    // map on every worker's copy — stays replicated.
                    self.bind_replicated_result(cluster, Arc::new(out))
                } else {
                    self.bind_dist_result(cluster, Arc::new(out))
                };
                self.op_end(probe, None, ExecType::Dist);
                bound
            }
            _ => {
                let out = elementwise::scalar_op(v.as_matrix()?, s, op, swapped)?;
                self.op_end(probe, None, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    /// Unary cellwise op (exp, sqrt, neg, ...). Blocked operands map
    /// over resident blocks; driver matrices stay CP.
    pub fn dispatch_unary_value(&self, v: &Value, op: UnaryOp) -> Result<Value> {
        let probe = self.op_begin(|| format!("u({op:?})"));
        match v {
            Value::Blocked(h) => {
                let cluster = h.cluster();
                let out = dist_ops::unary_blocked(cluster, &h.blocked()?, op);
                let bound = if h.is_replicated() {
                    self.bind_replicated_result(cluster, Arc::new(out))
                } else {
                    self.bind_dist_result(cluster, Arc::new(out))
                };
                self.op_end(probe, None, ExecType::Dist);
                bound
            }
            _ => {
                let out = elementwise::unary(v.as_matrix()?, op);
                self.op_end(probe, None, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    // ---- transpose ----------------------------------------------------

    /// Transpose dispatch: CP reorg under the budget, blocked reorg
    /// (block-index swap + per-block transpose, shuffle-free under the
    /// symmetric placement) on DIST placements or blocked operands.
    /// For a driver operand with a lineage hint the derived `t(X)#v`
    /// entry is reused when the guarded base `X#v` hit, so iterative
    /// algorithms transpose their loop-invariant operand once.
    pub fn dispatch_transpose_value(
        &self,
        v: &Value,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<Value> {
        let a = Operand::of(v)?;
        let probe = self.op_begin(|| "r(t)".to_string());
        let est = a.size_in_bytes()
            + estimate::estimate_size(a.cols(), a.rows(), a.sparsity());
        let tag = if a.plans_sparse() { " SPARSE" } else { "" };
        let desc = format!("r(t) ({}x{}){tag}", a.rows(), a.cols());
        match self.resolve_exec(OpKind::Reorg, pos, est, &desc, a.is_blocked())? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let bound = match &a {
                    Operand::Handle(h) => {
                        let out = dist_ops::transpose_blocked(cluster, &h.blocked()?);
                        if h.is_replicated() {
                            self.bind_replicated_result(cluster, Arc::new(out))
                        } else {
                            self.bind_dist_result(cluster, Arc::new(out))
                        }
                    }
                    Operand::Driver(m) => {
                        let derived = hint.map(|h| {
                            LineageRef::derived(
                                format!("t({})", h.name),
                                h.version,
                                h.deps.clone(),
                            )
                        });
                        let (xb, outcome) = self.cache_acquire(cluster, hint, m, "arg")?;
                        // Note on accounting: a reused derived entry is
                        // charged both as a cache entry and (briefly) as
                        // the live handle wrapping the same Arc'd blocks.
                        // That over-counts shared storage in the
                        // conservative direction — at worst an early
                        // spill, never an overrun.
                        // Base guard-verified at this version: the
                        // derived transpose (if resident) is valid.
                        let mut reused = None;
                        if outcome.is_hit() {
                            if let Some(d) = &derived {
                                if let Some(tb) = cluster.cache().get_keyed(d) {
                                    if self.config.explain {
                                        self.emit(format!(
                                            "EXPLAIN: CACHE(hit) {} arg (derived transpose)",
                                            d.render()
                                        ));
                                    }
                                    reused = Some(tb);
                                }
                            }
                        }
                        match reused {
                            Some(tb) => self.bind_dist_result(cluster, tb),
                            None => {
                                let out =
                                    Arc::new(dist_ops::transpose_blocked(cluster, &xb));
                                if let Some(d) = &derived {
                                    cluster.cache().put_keyed(d, out.clone());
                                }
                                self.bind_dist_result(cluster, out)
                            }
                        }
                    }
                };
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let out = reorg::transpose(a.force()?);
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    // ---- indexing -----------------------------------------------------

    /// Right-index dispatch (`X[r1:r2, c1:c2]`, 0-based half-open
    /// bounds). Bounds are validated against the operand's metadata
    /// alone, so a blocked value with out-of-range bounds raises the
    /// *same* error as the CP path without any force or collect. On DIST
    /// placements a blocked operand selects/trims resident blocks
    /// (shuffle-free when the origin is block-aligned — the mini-batch
    /// `X[beg:end,]` case); a driver operand goes through the lineage
    /// cache with a derived `X[..]#v` entry reused after a guarded hit
    /// on `X#v` (invalidated, like every derived entry, when `X` is
    /// rebound or left-index-written).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_right_index_value(
        &self,
        v: &Value,
        rl: usize,
        ru: usize,
        cl: usize,
        cu: usize,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<Value> {
        let a = Operand::of(v)?;
        let (r, c) = a.shape();
        if ru > r || cu > c || rl >= ru || cl >= cu {
            return Err(reorg::slice_range_error(rl, ru, cl, cu, r, c));
        }
        let probe = self.op_begin(|| "rix".to_string());
        // The slice inherits the base's sparsity estimate (the planner's
        // rix rule): a slice of a sparse operand is costed at CSR bytes.
        let est = a.size_in_bytes()
            + estimate::estimate_size(ru - rl, cu - cl, a.sparsity());
        let tag = if a.plans_sparse() { " SPARSE" } else { "" };
        let desc = format!("rix ({}x{} -> {}x{}){tag}", r, c, ru - rl, cu - cl);
        match self.resolve_exec(OpKind::RightIndex, pos, est, &desc, a.is_blocked())? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                if self.config.explain {
                    let selection =
                        dist_ops::slice_selection_only(cluster.block_size, rl, ru, cl, cu);
                    self.emit(format!(
                        "EXPLAIN: IDX [{}:{},{}:{}] block-range select ({})",
                        rl + 1,
                        ru,
                        cl + 1,
                        cu,
                        if selection { "aligned, shuffle-free" } else { "realigned" }
                    ));
                }
                let bound = match &a {
                    Operand::Handle(h) => {
                        let out = dist_ops::slice_blocked(cluster, &h.blocked()?, rl, ru, cl, cu)?;
                        self.bind_dist_result(cluster, Arc::new(out))
                    }
                    Operand::Driver(m) => {
                        let derived = hint.map(|h| {
                            LineageRef::derived(
                                format!("{}[{}:{},{}:{}]", h.name, rl + 1, ru, cl + 1, cu),
                                h.version,
                                h.deps.clone(),
                            )
                        });
                        let (xb, outcome) = self.cache_acquire(cluster, hint, m, "base")?;
                        // Base guard-verified at this version: a
                        // resident derived slice is valid.
                        let mut reused = None;
                        if outcome.is_hit() {
                            if let Some(d) = &derived {
                                if let Some(sb) = cluster.cache().get_keyed(d) {
                                    if self.config.explain {
                                        self.emit(format!(
                                            "EXPLAIN: CACHE(hit) {} base (derived slice)",
                                            d.render()
                                        ));
                                    }
                                    reused = Some(sb);
                                }
                            }
                        }
                        match reused {
                            Some(sb) => self.bind_dist_result(cluster, sb),
                            None => {
                                let out = Arc::new(dist_ops::slice_blocked(
                                    cluster, &xb, rl, ru, cl, cu,
                                )?);
                                if let Some(d) = &derived {
                                    cluster.cache().put_keyed(d, out.clone());
                                }
                                self.bind_dist_result(cluster, out)
                            }
                        }
                    }
                };
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let out = reorg::slice(a.force()?, rl, ru, cl, cu)?;
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    /// Left-index write dispatch (`X[r1:r2, c1:c2] = rhs`). The region
    /// and the rhs shape are validated from metadata before anything is
    /// forced. On DIST placements only the touched blocks of the target
    /// are rewritten — a blocked target **stays on the cluster** (it no
    /// longer forces to the driver); the patch ships as a cluster-wide
    /// broadcast variable. `name` is the target variable (its lineage
    /// key addresses the block cache for driver-resident targets).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_left_index_value(
        &self,
        base: &Value,
        rhs: &Value,
        name: &str,
        rl: usize,
        ru: usize,
        cl: usize,
        cu: usize,
        pos: Option<Pos>,
    ) -> Result<Value> {
        let a = Operand::of(base)?;
        let (r, c) = a.shape();
        if ru > r || cu > c || rl >= ru || cl >= cu {
            // The interpreter's range translation already guards this;
            // direct callers get the canonical range error instead of a
            // usize underflow below.
            return Err(reorg::slice_range_error(rl, ru, cl, cu, r, c));
        }
        let region = (ru - rl, cu - cl);
        if rhs.is_matrix() {
            // Shape-check against metadata so a blocked rhs is never
            // forced just to discover the mismatch.
            let (sr, sc) = rhs.matrix_dims()?;
            if (sr, sc) != region {
                return Err(DmlError::rt(format!(
                    "left-indexing: rhs is {sr}x{sc} but target region is {}x{}",
                    region.0, region.1
                )));
            }
        }
        let probe = self.op_begin(|| "lix".to_string());
        // The patch region is costed at the target's sparsity: rewriting
        // a sparse target moves CSR-sized blocks, not dense ones.
        let est = a
            .size_in_bytes()
            .saturating_mul(2)
            .saturating_add(estimate::estimate_size(region.0, region.1, a.sparsity()));
        let tag = if a.plans_sparse() { " SPARSE" } else { "" };
        let desc = format!("lix ({}x{} <- {}x{}){tag}", r, c, region.0, region.1);
        match self.resolve_exec(OpKind::LeftIndex, pos, est, &desc, a.is_blocked())? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let hint = self
                    .lineage
                    .current(name)
                    .map(|ver| LineageRef::var(name, ver));
                let (tb, _) = self.acquire_operand(cluster, &a, hint.as_ref(), "target")?;
                if self.config.explain {
                    self.emit(format!(
                        "EXPLAIN: IDX write [{}:{},{}:{}] rewrites touched blocks only",
                        rl + 1,
                        ru,
                        cl + 1,
                        cu
                    ));
                }
                let out = if rhs.is_matrix() {
                    // The patch in driver form; a blocked rhs gathers
                    // worker-side (see gather_blocked_rhs — a shuffle,
                    // never a collect).
                    let (src, src_resident): (Cow<Matrix>, bool) = match rhs {
                        Value::Blocked(h) => {
                            gather_blocked_rhs(h, self.config.gather_memo_bytes)?
                        }
                        v => (Cow::Borrowed(v.as_matrix()?), false),
                    };
                    dist_ops::left_index_blocked(cluster, &tb, rl, cl, src.as_ref(), src_resident)?
                } else {
                    // Scalar fill: the constant rides the tasks — no
                    // region-sized broadcast, no driver materialization.
                    dist_ops::left_index_fill_blocked(
                        cluster,
                        &tb,
                        rl,
                        ru,
                        cl,
                        cu,
                        rhs.as_double()?,
                    )?
                };
                let bound = self.bind_dist_result(cluster, Arc::new(out));
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let src: Matrix = match rhs {
                    v if v.is_matrix() => v.to_matrix()?,
                    other => {
                        Matrix::filled(region.0, region.1, other.as_double()?).into_dense_format()
                    }
                };
                let out = reorg::left_index(a.force()?, rl, cl, &src)?;
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    /// rowIndexMax dispatch: a blocked operand computes per-block row
    /// argmaxes on the workers and combines offsets at the driver — the
    /// rows×1 output returns with the job, not as a collect.
    pub fn dispatch_row_index_max(&self, v: &Value) -> Result<Matrix> {
        let probe = self.op_begin(|| "uarimax".to_string());
        match v {
            Value::Blocked(h) => {
                let out = dist_ops::row_index_max_blocked(h.cluster(), &h.blocked()?)?;
                self.op_end(probe, None, ExecType::Dist);
                Ok(out)
            }
            _ => {
                let out = agg::row_index_max(v.as_matrix()?);
                self.op_end(probe, None, ExecType::CP);
                Ok(out)
            }
        }
    }

    // ---- NN operators (conv2d / pooling) ------------------------------

    /// A conv filter (or bias) rhs operand in driver form plus whether
    /// its cells already live on the workers. A *named* driver filter
    /// registers in the block cache like matmult's broadcast side — a
    /// guarded hit means the workers still hold the broadcast, so a
    /// loop-invariant filter is charged once per loop, not once per
    /// batch. A blocked filter gathers worker-side through the handle's
    /// memoized gather (a shuffle, never a collect).
    fn conv_rhs_operand<'v>(
        &self,
        cluster: &Cluster,
        v: &'v Value,
        hint: Option<&LineageRef>,
    ) -> Result<(Cow<'v, Matrix>, bool)> {
        match v {
            Value::Blocked(h) => gather_blocked_rhs(h, self.config.gather_memo_bytes),
            v => {
                let m = v.as_matrix()?;
                let resident = match hint {
                    Some(hint) => {
                        let (_, outcome) = self.cache_acquire(cluster, Some(hint), m, "filter")?;
                        outcome.is_hit()
                    }
                    None => false,
                };
                Ok((Cow::Borrowed(m), resident))
            }
        }
    }

    /// Unified dispatch for the seven conv/pool builtins (paper §3's NN
    /// functions). Every operand's dims are validated from **metadata**
    /// before anything is forced — through the same validators the CP
    /// kernels use, so a blocked operand with bad geometry (including a
    /// mismatched `dout` batch dimension, which the CP kernels used to
    /// discover only after a force) raises the byte-identical CP error
    /// with zero collects. On DIST placements the batch runs worker-side
    /// over row bands (`runtime::dist::nn`) with the filter shipped as a
    /// broadcast variable; conv/pool outputs bind as blocked values, and
    /// `conv2d_backward_filter` combines its small K×CRS gradient via
    /// tree-allreduce and binds it **replicated** on the workers — never
    /// a collect, and the weight update consumes it cluster-side.
    ///
    /// Operand roles: `x` is the batch-shaped operand (`input`, or
    /// `dout` for conv2d_backward_data); `aux` is the filter
    /// (broadcast rhs) or the companion `dout` batch, per
    /// [`ConvOpKind::has_dout`].
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_conv_value(
        &self,
        op: ConvOpKind,
        x: &Value,
        aux: Option<&Value>,
        sh: &ConvShape,
        pos: Option<Pos>,
        hx: Option<&LineageRef>,
        haux: Option<&LineageRef>,
    ) -> Result<Value> {
        let a = Operand::of(x)?;
        let aux_op = aux.map(Operand::of).transpose()?;
        let name = op.name();
        let probe = self.op_begin(|| name.to_string());
        if aux_op.is_none() && !matches!(op, ConvOpKind::MaxPool | ConvOpKind::AvgPool) {
            return Err(DmlError::rt(format!("{name}: missing matrix operand")));
        }
        let (n, xc) = a.shape();
        let (k, crs, chw) = (sh.k, sh.c * sh.r * sh.s, sh.c * sh.h * sh.w);
        // Metadata validation in the CP kernels' exact order (shared
        // validators → byte-identical messages, no force).
        match op {
            ConvOpKind::Conv2d => {
                sh.validate_input_dims(xc, name)?;
                let (fr, fc) = aux_op.as_ref().map(|o| o.shape()).unwrap_or((0, 0));
                sh.validate_filter_dims(fr, fc, name)?;
                sh.validate_window(name)?;
            }
            ConvOpKind::Conv2dBackwardFilter => {
                sh.validate_input_dims(xc, name)?;
                sh.validate_window(name)?;
                let (dr, dc) = aux_op.as_ref().map(|o| o.shape()).unwrap_or((0, 0));
                sh.validate_dout_dims(n, dr, dc, k * sh.p() * sh.q(), name)?;
            }
            ConvOpKind::Conv2dBackwardData => {
                // `x` is dout here; `aux` is the filter.
                let (fr, fc) = aux_op.as_ref().map(|o| o.shape()).unwrap_or((0, 0));
                sh.validate_filter_dims(fr, fc, name)?;
                sh.validate_window(name)?;
                sh.validate_dout_dims(n, n, xc, k * sh.p() * sh.q(), name)?;
            }
            ConvOpKind::MaxPool | ConvOpKind::AvgPool => {
                sh.validate_input_dims(xc, name)?;
                sh.validate_window(name)?;
            }
            ConvOpKind::MaxPoolBackward | ConvOpKind::AvgPoolBackward => {
                sh.validate_input_dims(xc, name)?;
                sh.validate_window(name)?;
                let (dr, dc) = aux_op.as_ref().map(|o| o.shape()).unwrap_or((0, 0));
                sh.validate_dout_dims(n, dr, dc, sh.c * sh.p() * sh.q(), name)?;
            }
        }
        let (p, q) = (sh.p(), sh.q()); // safe: the window was validated
        // Accelerator first for conv2d forward, like matmult: compiled
        // artifacts only serve driver-resident operands.
        if op == ConvOpKind::Conv2d {
            if let (Operand::Driver(xm), Some(Operand::Driver(fm)), Some(accel)) =
                (&a, &aux_op, &self.accel)
            {
                if let Some(out) = accel.try_conv2d(xm, fm, sh)? {
                    self.op_end(probe, pos, ExecType::Accel);
                    return Ok(Value::Matrix(out));
                }
            }
        }
        let out_dims = match op {
            ConvOpKind::Conv2d => (n, k * p * q),
            ConvOpKind::Conv2dBackwardFilter => (k, crs),
            ConvOpKind::Conv2dBackwardData => (n, chw),
            ConvOpKind::MaxPool | ConvOpKind::AvgPool => (n, sh.c * p * q),
            ConvOpKind::MaxPoolBackward | ConvOpKind::AvgPoolBackward => (n, chw),
        };
        // Worst-case memory: operands + output + the im2col-expanded
        // patch matrix ((P·Q)×(C·R·S), built one image at a time).
        let col_bytes =
            if op.needs_filter() { estimate::dense_size(p * q, crs) } else { 0 };
        let aux_bytes = aux_op.as_ref().map(|o| o.size_in_bytes()).unwrap_or(0);
        let est = a
            .size_in_bytes()
            .saturating_add(aux_bytes)
            .saturating_add(estimate::dense_size(out_dims.0, out_dims.1))
            .saturating_add(col_bytes);
        let desc = format!("{name} ({n}x{xc})");
        // Only *batch* operands force DIST (mirrors the planner's
        // eff_blocked rule): conv2d_backward_data's aux is its filter —
        // a blocked filter is gathered worker-side, it never forces the
        // op DIST.
        let aux_batch_blocked = op.has_dout()
            && op != ConvOpKind::Conv2dBackwardData
            && aux_op.as_ref().map(|o| o.is_blocked()).unwrap_or(false);
        let blocked_in = a.is_blocked() || aux_batch_blocked;
        match self.resolve_exec(OpKind::Conv, pos, est, &desc, blocked_in)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (xb, _) = self.acquire_operand(cluster, &a, hx, "input")?;
                if self.config.explain {
                    self.emit(format!(
                        "EXPLAIN: CONV {name} over {} row band(s) ({n}x{xc} batch, block {})",
                        xb.block_rows(),
                        xb.block_size()
                    ));
                }
                let out = match op {
                    ConvOpKind::Conv2d | ConvOpKind::Conv2dBackwardData => {
                        let (fm, f_res) =
                            self.conv_rhs_operand(cluster, aux.unwrap(), haux)?;
                        if op == ConvOpKind::Conv2d {
                            dist_nn::conv2d_blocked(cluster, &xb, fm.as_ref(), sh, f_res)?
                        } else {
                            dist_nn::conv2d_backward_data_blocked(
                                cluster,
                                fm.as_ref(),
                                &xb,
                                sh,
                                f_res,
                            )?
                        }
                    }
                    ConvOpKind::Conv2dBackwardFilter => {
                        let (db, _) = self.acquire_operand(
                            cluster,
                            aux_op.as_ref().unwrap(),
                            haux,
                            "dout",
                        )?;
                        // The K×CRS gradient is combined via tree-allreduce
                        // (charged inside the blocked kernel) — never a
                        // collect. When it fits one block it stays
                        // replicated on the workers so the weight update
                        // consumes it cluster-side.
                        let grad =
                            dist_nn::conv2d_backward_filter_blocked(cluster, &xb, &db, sh)?;
                        let bs = cluster.block_size;
                        if grad.rows() <= bs && grad.cols() <= bs {
                            let gb = BlockedMatrix::from_local(&grad, bs)?;
                            let bound = self.bind_replicated_result(cluster, Arc::new(gb));
                            self.op_end(probe, pos, ExecType::Dist);
                            return bound;
                        }
                        self.op_end(probe, pos, ExecType::Dist);
                        return Ok(Value::Matrix(grad));
                    }
                    ConvOpKind::MaxPool => dist_nn::max_pool_blocked(cluster, &xb, sh)?,
                    ConvOpKind::AvgPool => dist_nn::avg_pool_blocked(cluster, &xb, sh)?,
                    ConvOpKind::MaxPoolBackward | ConvOpKind::AvgPoolBackward => {
                        let (db, _) = self.acquire_operand(
                            cluster,
                            aux_op.as_ref().unwrap(),
                            haux,
                            "dout",
                        )?;
                        if op == ConvOpKind::MaxPoolBackward {
                            dist_nn::max_pool_backward_blocked(cluster, &xb, &db, sh)?
                        } else {
                            dist_nn::avg_pool_backward_blocked(cluster, &xb, &db, sh)?
                        }
                    }
                };
                let bound = self.bind_dist_result(cluster, Arc::new(out));
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let xm = a.force()?;
                let auxm = match &aux_op {
                    Some(o) => Some(o.force()?),
                    None => None,
                };
                let out = match op {
                    ConvOpKind::Conv2d => conv::conv2d(xm, auxm.unwrap(), sh)?,
                    ConvOpKind::Conv2dBackwardFilter => {
                        conv::conv2d_backward_filter(xm, auxm.unwrap(), sh)?
                    }
                    ConvOpKind::Conv2dBackwardData => {
                        conv::conv2d_backward_data(auxm.unwrap(), xm, sh)?
                    }
                    ConvOpKind::MaxPool => conv::max_pool2d(xm, sh)?,
                    ConvOpKind::MaxPoolBackward => {
                        conv::max_pool2d_backward(xm, auxm.unwrap(), sh)?
                    }
                    ConvOpKind::AvgPool => conv::avg_pool2d(xm, sh)?,
                    ConvOpKind::AvgPoolBackward => {
                        conv::avg_pool2d_backward(xm, auxm.unwrap(), sh)?
                    }
                };
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    /// bias_add / bias_multiply dispatch: a blocked input maps the K×1
    /// bias over its resident blocks (each block derives its channel from
    /// its global column offset — no band assembly, no collect); driver
    /// inputs run the CP kernels. The bias rides like the conv filter:
    /// a *named* driver bias registers in the block cache (a
    /// loop-invariant bias broadcasts once per loop, not once per batch)
    /// and a *blocked* bias gathers worker-side through the handle's
    /// memoized gather — a shuffle, never a collect.
    pub fn dispatch_bias_value(
        &self,
        v: &Value,
        bias: &Value,
        mul: bool,
        hint: Option<&LineageRef>,
    ) -> Result<Value> {
        let probe =
            self.op_begin(|| if mul { "bias_multiply" } else { "bias_add" }.to_string());
        match v {
            Value::Blocked(h) => {
                let cluster = h.cluster();
                let (bm, resident) = self.conv_rhs_operand(cluster, bias, hint)?;
                let out = dist_nn::bias_op_blocked(
                    cluster,
                    &h.blocked()?,
                    bm.as_ref(),
                    bm.rows(),
                    mul,
                    resident,
                )?;
                let bound = self.bind_dist_result(cluster, Arc::new(out));
                self.op_end(probe, None, ExecType::Dist);
                bound
            }
            _ => {
                let m = v.as_matrix()?;
                let b = bias.as_matrix()?;
                let out = if mul {
                    conv::bias_multiply(m, b, b.rows())?
                } else {
                    conv::bias_add(m, b, b.rows())?
                };
                self.op_end(probe, None, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }

    // ---- aggregates ---------------------------------------------------

    /// Unified dispatch for full aggregates (`sum`, `mean`, `min`, ...).
    pub fn dispatch_agg_full(&self, m: &Matrix, op: AggOp, pos: Option<Pos>) -> Result<f64> {
        self.agg_full_operand(Operand::Driver(m), op, pos, None)
    }

    /// [`Self::dispatch_agg_full`] with the operand's lineage reference.
    pub fn dispatch_agg_full_hinted(
        &self,
        m: &Matrix,
        op: AggOp,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<f64> {
        self.agg_full_operand(Operand::Driver(m), op, pos, hint)
    }

    /// Value-level full aggregate (blocked operands aggregate on the
    /// cluster, per-block partials reduced at the driver — no collect).
    pub fn dispatch_agg_full_value(
        &self,
        v: &Value,
        op: AggOp,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<f64> {
        self.agg_full_operand(Operand::of(v)?, op, pos, hint)
    }

    fn agg_full_operand(
        &self,
        m: Operand,
        op: AggOp,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<f64> {
        let probe = self.op_begin(|| format!("ua({})", agg_name(op)));
        let est = m.size_in_bytes() + estimate::dense_size(1, 1);
        let desc = format!("ua({}) ({}x{})", agg_name(op), m.rows(), m.cols());
        match self.resolve_exec(OpKind::Agg, pos, est, &desc, m.is_blocked())? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (mb, _) = self.acquire_operand(cluster, &m, hint, "arg")?;
                let out = dist_ops::full_agg_blocked(cluster, &mb, op);
                self.op_end(probe, pos, ExecType::Dist);
                Ok(out)
            }
            _ => {
                let out = agg::full_agg(m.force()?, op);
                self.op_end(probe, pos, ExecType::CP);
                Ok(out)
            }
        }
    }

    /// Unified dispatch for row-/column-wise aggregates (`rowSums`,
    /// `colMaxs`, ...). `row_wise` selects the reduction axis. Returns a
    /// driver matrix (forcing a replicated result — free, no collect).
    pub fn dispatch_agg_axis(
        &self,
        m: &Matrix,
        op: AggOp,
        row_wise: bool,
        pos: Option<Pos>,
    ) -> Result<Matrix> {
        self.agg_axis_operand(Operand::Driver(m), op, row_wise, pos, None)?
            .into_matrix()
    }

    /// [`Self::dispatch_agg_axis`] with the operand's lineage reference.
    pub fn dispatch_agg_axis_hinted(
        &self,
        m: &Matrix,
        op: AggOp,
        row_wise: bool,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<Matrix> {
        self.agg_axis_operand(Operand::Driver(m), op, row_wise, pos, hint)?
            .into_matrix()
    }

    /// Value-level axis aggregate: a single-block DIST result (the
    /// `colSums(dH)` bias gradient) binds replicated; anything else
    /// returns a driver matrix.
    pub fn dispatch_agg_axis_value(
        &self,
        v: &Value,
        op: AggOp,
        row_wise: bool,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<Value> {
        self.agg_axis_operand(Operand::of(v)?, op, row_wise, pos, hint)
    }

    fn agg_axis_operand(
        &self,
        m: Operand,
        op: AggOp,
        row_wise: bool,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<Value> {
        let out = if row_wise {
            estimate::dense_size(m.rows(), 1)
        } else {
            estimate::dense_size(1, m.cols())
        };
        let est = m.size_in_bytes() + out;
        let dir = if row_wise { "uar" } else { "uac" };
        let probe = self.op_begin(|| format!("{dir}({})", agg_name(op)));
        let desc = format!("{dir}({}) ({}x{})", agg_name(op), m.rows(), m.cols());
        match self.resolve_exec(OpKind::Agg, pos, est, &desc, m.is_blocked())? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (mb, _) = self.acquire_operand(cluster, &m, hint, "arg")?;
                let out = if row_wise {
                    dist_ops::row_agg_blocked(cluster, &mb, op)?
                } else {
                    dist_ops::col_agg_blocked(cluster, &mb, op)?
                };
                let bs = cluster.block_size;
                let bound = if out.rows() <= bs && out.cols() <= bs {
                    // Single-block aggregate: the per-block partials are
                    // combined via tree-allreduce and the vector stays
                    // replicated on the workers (the bias-update case).
                    cluster.record_allreduce(out.size_in_bytes() as u64);
                    let ob = BlockedMatrix::from_local(&out, bs)?;
                    self.bind_replicated_result(cluster, Arc::new(ob))
                } else {
                    Ok(Value::Matrix(out))
                };
                self.op_end(probe, pos, ExecType::Dist);
                bound
            }
            _ => {
                let out = if row_wise {
                    agg::row_agg(m.force()?, op)
                } else {
                    agg::col_agg(m.force()?, op)
                };
                self.op_end(probe, pos, ExecType::CP);
                Ok(Value::Matrix(out))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::SystemConfig;
    use crate::dml::parser::parse;
    use crate::runtime::matrix::randgen::{rand, Pdf};
    use crate::util::quickcheck::approx_eq_slice;

    fn interp(config: SystemConfig) -> Interpreter {
        let bundle = crate::dml::validate::Bundle {
            main: parse("x = 1").unwrap(),
            namespaces: Default::default(),
        };
        Interpreter::new(bundle, config)
    }

    #[test]
    fn binary_dispatch_distributes_over_budget() {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = 32;
        let it = interp(config);
        let a = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 31).unwrap();
        let b = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 32).unwrap();
        let before = crate::util::metrics::global().snapshot();
        let out = it.dispatch_binary(&a, &b, BinOp::Add, None).unwrap();
        let d = crate::util::metrics::global().snapshot().delta(&before);
        assert!(d.dist_tasks > 0, "over-budget cell op must distribute");
        let local = elementwise::binary(&a, &b, BinOp::Add).unwrap();
        assert!(approx_eq_slice(&out.to_row_major_vec(), &local.to_row_major_vec(), 1e-12));
    }

    #[test]
    fn agg_dispatch_matches_cp() {
        let mut config = SystemConfig::tiny_driver(8 * 1024);
        config.block_size = 16;
        let it = interp(config);
        let m = rand(64, 48, -2.0, 2.0, 0.7, Pdf::Uniform, 33).unwrap();
        for op in [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Mean] {
            let cp = agg::full_agg(&m, op);
            let dist = it.dispatch_agg_full(&m, op, None).unwrap();
            assert!((cp - dist).abs() < 1e-9, "{op:?}: {cp} vs {dist}");
        }
        let rs = it.dispatch_agg_axis(&m, AggOp::Sum, true, None).unwrap();
        assert!(approx_eq_slice(
            &rs.to_row_major_vec(),
            &agg::row_agg(&m, AggOp::Sum).to_row_major_vec(),
            1e-9
        ));
    }

    #[test]
    fn over_budget_without_cluster_errors() {
        let mut config = SystemConfig::tiny_driver(1024);
        config.dist_enabled = false;
        let it = interp(config);
        let a = Matrix::filled(128, 128, 1.0);
        assert!(it.dispatch_matmult(&a, &a).is_err());
        assert!(it.dispatch_binary(&a, &a, BinOp::Add, None).is_err());
        assert!(it.dispatch_agg_full(&a, AggOp::Sum, None).is_err());
    }

    #[test]
    fn explain_lines_cover_cp_and_dist() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        config.explain = true;
        let it = interp(config);
        let small = Matrix::filled(8, 8, 1.0);
        let big = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 34).unwrap();
        it.dispatch_matmult(&small, &small).unwrap();
        it.dispatch_matmult(&big, &big).unwrap();
        let out = it.output().join("\n");
        assert!(out.contains("-> CP"), "CP placements must be explained too:\n{out}");
        assert!(out.contains("-> DIST"), "{out}");
    }

    #[test]
    fn matmult_values_binds_blocked_and_allreduce_result_stays_replicated() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        let it = interp(config);
        let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 35).unwrap();
        let v = rand(96, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 36).unwrap();
        let lv = Value::Matrix(x.clone());
        let rv = Value::Matrix(v.clone());
        // 96x96 @ 96x1 -> 96x1 over 32-blocks = 3 blocks: stays blocked.
        let out = it.dispatch_matmult_values(&lv, &rv, None, None, None).unwrap();
        let cluster = it.cluster.as_ref().unwrap();
        assert!(matches!(out, Value::Blocked(_)), "{out:?}");
        assert_eq!(cluster.collect_count(), 0, "no collect for a blocked bind");
        // Feed the blocked value back in: 1x96 @ 96x1 is the
        // gradient-shaped (allreduce) matmult — the 1x1 result binds
        // replicated on the workers instead of returning to the driver.
        let before = crate::util::metrics::global().snapshot();
        let tv = it
            .dispatch_transpose_value(&out, None, None)
            .unwrap();
        let s = it.dispatch_matmult_values(&tv, &out, None, None, None).unwrap();
        match &s {
            Value::Blocked(h) => assert!(h.is_replicated(), "allreduce result is replicated"),
            other => panic!("allreduce result must bind blocked, got {other:?}"),
        }
        let d = crate::util::metrics::global().snapshot().delta(&before);
        assert!(d.allreduce_rounds > 0, "allreduce rounds are charged");
        assert_eq!(cluster.collect_count(), 0, "allreduce output is not a collect");
        // Numerics match CP end to end (forcing replicated state is free).
        let xv = mult::matmult(&x, &v).unwrap();
        let expected = mult::matmult(&reorg::transpose(&xv), &xv).unwrap();
        assert!(approx_eq_slice(
            &s.as_matrix().unwrap().to_row_major_vec(),
            &expected.to_row_major_vec(),
            1e-9
        ));
        assert_eq!(cluster.collect_count(), 0, "replicated force is free");
    }

    #[test]
    fn right_index_dispatch_selects_blocks_and_matches_cp() {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = 32;
        let it = interp(config);
        let m = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 38).unwrap();
        let v = Value::Matrix(m.clone());
        // Over-budget slice distributes; block-aligned origin → no comm.
        let out = it.dispatch_right_index_value(&v, 32, 96, 0, 96, None, None).unwrap();
        assert!(matches!(out, Value::Blocked(_)), "{out:?}");
        let cluster = it.cluster.as_ref().unwrap();
        assert_eq!(cluster.comm_bytes(), 0, "aligned slice is selection-only");
        let cp_sliced = reorg::slice(&m, 32, 96, 0, 96).unwrap();
        assert_eq!(out.as_matrix().unwrap().to_row_major_vec(), cp_sliced.to_row_major_vec());
        // Bounds failures match the CP error and never touch the driver.
        let collects = cluster.collect_count();
        let err = it.dispatch_right_index_value(&out, 0, 200, 0, 96, None, None).unwrap_err();
        let cp_err = reorg::slice(&cp_sliced, 0, 200, 0, 96).unwrap_err();
        assert_eq!(err.to_string(), cp_err.to_string());
        assert_eq!(cluster.collect_count(), collects, "failed slice must not collect");
    }

    #[test]
    fn left_index_dispatch_keeps_target_blocked() {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = 32;
        let it = interp(config);
        let m = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 39).unwrap();
        let base = it
            .dispatch_right_index_value(&Value::Matrix(m.clone()), 0, 96, 0, 96, None, None)
            .unwrap();
        assert!(matches!(base, Value::Blocked(_)));
        let patch = rand(4, 4, 9.0, 10.0, 1.0, Pdf::Uniform, 40).unwrap();
        let out = it
            .dispatch_left_index_value(
                &base,
                &Value::Matrix(patch.clone()),
                "m",
                10,
                14,
                10,
                14,
                None,
            )
            .unwrap();
        assert!(matches!(out, Value::Blocked(_)), "blocked target stays blocked: {out:?}");
        assert_eq!(it.cluster.as_ref().unwrap().collect_count(), 0);
        let expected = reorg::left_index(&m, 10, 10, &patch).unwrap();
        assert_eq!(out.as_matrix().unwrap().to_row_major_vec(), expected.to_row_major_vec());
        // A mismatched rhs is rejected from metadata (no force).
        let bad = it.dispatch_left_index_value(
            &out,
            &Value::Matrix(Matrix::filled(3, 3, 1.0)),
            "m",
            10,
            14,
            10,
            14,
            None,
        );
        assert!(bad.unwrap_err().to_string().contains("target region"), "shape-checked");
    }

    #[test]
    fn broadcast_dispatch_joins_map_side_and_stays_blocked() {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = 32;
        let it = interp(config);
        let m = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 41).unwrap();
        let mu = rand(1, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 42).unwrap();
        let lv = Value::Matrix(m.clone());
        let rv = Value::Matrix(mu.clone());
        let before = crate::util::metrics::global().snapshot();
        let out = it.dispatch_binary_values(&lv, &rv, BinOp::Sub, None, None, None).unwrap();
        let d = crate::util::metrics::global().snapshot().delta(&before);
        assert!(matches!(out, Value::Blocked(_)), "{out:?}");
        assert!(d.broadcast_bytes > 0, "the vector must be charged as a broadcast");
        assert_eq!(it.cluster.as_ref().unwrap().collect_count(), 0);
        let local = elementwise::binary(&m, &mu, BinOp::Sub).unwrap();
        assert_eq!(out.as_matrix().unwrap().to_row_major_vec(), local.to_row_major_vec());
    }

    #[test]
    fn dist_transpose_matches_local_and_shuffles_nothing() {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = 16;
        let it = interp(config);
        let m = rand(70, 33, -1.0, 1.0, 0.4, Pdf::Uniform, 37).unwrap();
        let before = crate::util::metrics::global().snapshot();
        let out = it
            .dispatch_transpose_value(&Value::Matrix(m.clone()), None, None)
            .unwrap();
        let d = crate::util::metrics::global().snapshot().delta(&before);
        assert!(d.dist_tasks > 0, "over-budget transpose must distribute");
        let local = reorg::transpose(&m);
        assert_eq!(out.as_matrix().unwrap().to_row_major_vec(), local.to_row_major_vec());
        // Block-index swap on the symmetric placement is shuffle-free.
        assert_eq!(it.cluster.as_ref().unwrap().comm_bytes(), 0);
    }
}
