//! Unified heavy-operator dispatch: every matmult, cellwise binary, and
//! aggregate flows through one placement path that (1) consults the
//! compiled plan's ExecType for the operator's source position, (2) falls
//! back to the same cost model at runtime when the shape was unknown at
//! compile time, and (3) dynamically "recompiles" when the actual
//! runtime estimate contradicts the planned placement (paper §3's
//! recompilation hook). Every decision is surfaced through `EXPLAIN` —
//! CP, DIST and ACCEL placements alike — with the estimate and budget
//! that produced it.

use std::sync::Arc;

use crate::dml::ast::Pos;
use crate::hop::dag::agg_name;
use crate::hop::estimate;
use crate::hop::plan::{choose_exec, ExecType, OpKind};
use crate::runtime::dist::cache::{CacheOutcome, Guard, LineageRef};
use crate::runtime::dist::ops as dist_ops;
use crate::runtime::dist::{BlockedMatrix, Cluster};
use crate::runtime::interp::Interpreter;
use crate::runtime::matrix::agg::{self, AggOp};
use crate::runtime::matrix::elementwise::{self, BinOp};
use crate::runtime::matrix::{mult, Matrix};
use crate::util::error::{DmlError, Result};

impl Interpreter {
    fn cluster_ref(&self) -> Result<&Cluster> {
        self.cluster
            .as_deref()
            .ok_or_else(|| DmlError::rt("distributed backend unavailable"))
    }

    /// Resolve the execution type for one heavy operator instance.
    ///
    /// `est` is the worst-case memory estimate from the *actual* runtime
    /// operands; the compiled placement (if any) wins unless it is no
    /// longer feasible, in which case the operator is re-placed with the
    /// same cost model (dynamic recompilation).
    fn resolve_exec(
        &self,
        kind: OpKind,
        pos: Option<Pos>,
        est: usize,
        desc: &str,
    ) -> Result<ExecType> {
        let planned = pos
            .and_then(|p| self.plan.as_ref().and_then(|plan| plan.placement(p, kind)))
            .map(|p| p.exec);
        let mut exec = planned.unwrap_or_else(|| choose_exec(est, &self.config, false));
        let mut note = if planned.is_some() { " planned" } else { "" };
        // A planned ACCEL placement reaches this point only when the
        // accelerator declined the operator (no artifact / no backend):
        // fall back to the CP-vs-DIST decision.
        if exec == ExecType::Accel {
            exec = choose_exec(est, &self.config, false);
            note = " accel-fallback";
        }
        // Dynamic recompilation against the runtime estimate.
        if exec == ExecType::CP && est > self.config.driver_memory {
            if self.cluster.is_some() {
                exec = ExecType::Dist;
                if planned.is_some() {
                    note = " recompiled";
                }
            } else {
                return Err(DmlError::rt(format!(
                    "{desc}: memory estimate {est} B exceeds driver budget {} B and the \
                     distributed backend is disabled",
                    self.config.driver_memory
                )));
            }
        }
        if exec == ExecType::Dist && self.cluster.is_none() {
            if est <= self.config.driver_memory {
                exec = ExecType::CP;
                note = " recompiled";
            } else {
                return Err(DmlError::rt(format!(
                    "{desc}: memory estimate {est} B exceeds driver budget {} B and the \
                     distributed backend is disabled",
                    self.config.driver_memory
                )));
            }
        }
        if self.config.explain {
            let rel = if est > self.config.driver_memory { ">" } else { "<=" };
            self.emit(format!(
                "EXPLAIN: {desc} -> {exec} (est {est} B {rel} budget {} B{note})",
                self.config.driver_memory
            ));
        }
        Ok(exec)
    }

    /// Resolve a DIST operand to blocked form through the cluster's
    /// lineage-keyed block cache, emitting the `CACHE(hit|miss|evict)`
    /// EXPLAIN lines that make reuse observable.
    fn cache_acquire(
        &self,
        cluster: &Cluster,
        hint: Option<&LineageRef>,
        m: &Matrix,
        side: &str,
    ) -> Result<(Arc<BlockedMatrix>, CacheOutcome)> {
        let (blocked, outcome) = cluster.acquire_blocked(hint, m)?;
        if self.config.explain {
            match &outcome {
                CacheOutcome::Hit { key } => self.emit(format!(
                    "EXPLAIN: CACHE(hit) {key} {side} ({}x{}, {} blocks resident)",
                    m.rows(),
                    m.cols(),
                    blocked.block_rows() * blocked.block_cols()
                )),
                CacheOutcome::Miss { key, evicted, evicted_bytes } => {
                    self.emit(format!(
                        "EXPLAIN: CACHE(miss) {key} {side} ({}x{}, blockify {} blocks)",
                        m.rows(),
                        m.cols(),
                        blocked.block_rows() * blocked.block_cols()
                    ));
                    if *evicted > 0 {
                        self.emit(format!(
                            "EXPLAIN: CACHE(evict) {evicted} entries, {evicted_bytes} B freed (budget {} B)",
                            cluster.cache().budget()
                        ));
                    }
                }
            }
        }
        Ok((blocked, outcome))
    }

    /// Run a DIST operator's blocked output back to the driver: the
    /// blocked handle is offered to the cache (dirty — its authoritative
    /// copy is the cluster's) so a nested consumer or the adopting
    /// assignment reuses it, and the driver copy is materialized for the
    /// CP world (the on-demand flush).
    fn flush_dist_result(&self, cluster: &Cluster, out: BlockedMatrix) -> Result<Matrix> {
        let out = Arc::new(out);
        let local = cluster.collect(&out)?;
        cluster.cache().offer_result(out, Guard::of(&local));
        Ok(local)
    }

    /// Heavy-operator dispatch for `%*%`: ACCEL when a compiled artifact
    /// matches, else CP vs DIST by placement/estimate (paper §3).
    pub fn dispatch_matmult(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        self.dispatch_matmult_at(a, b, None)
    }

    /// [`Self::dispatch_matmult`] with the operator's source position for
    /// compiled-placement lookup.
    pub fn dispatch_matmult_at(&self, a: &Matrix, b: &Matrix, pos: Option<Pos>) -> Result<Matrix> {
        self.dispatch_matmult_hinted(a, b, pos, None, None)
    }

    /// [`Self::dispatch_matmult_at`] with the operands' lineage
    /// references for block-cache reuse on DIST placements.
    pub fn dispatch_matmult_hinted(
        &self,
        a: &Matrix,
        b: &Matrix,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Matrix> {
        // Accelerator first: compiled artifacts handle specific shapes.
        if let Some(accel) = &self.accel {
            if let Some(out) = accel.try_matmult(a, b)? {
                if self.config.explain {
                    self.emit(format!(
                        "EXPLAIN: %*% ({}x{} @ {}x{}) -> ACCEL (artifact hit, device budget {} B)",
                        a.rows(),
                        a.cols(),
                        b.rows(),
                        b.cols(),
                        self.config.accel_memory
                    ));
                }
                return Ok(out);
            }
        }
        let est = estimate::matmult_mem_estimate(a, b);
        let desc =
            format!("%*% ({}x{} @ {}x{})", a.rows(), a.cols(), b.rows(), b.cols());
        match self.resolve_exec(OpKind::MatMult, pos, est, &desc)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (ab, oa) = self.cache_acquire(cluster, ha, a, "lhs")?;
                let (bb, ob) = self.cache_acquire(cluster, hb, b, "rhs")?;
                let resident =
                    dist_ops::Residency { lhs: oa.is_hit(), rhs: ob.is_hit() };
                let out = dist_ops::matmult_blocked_reuse(cluster, &ab, &bb, resident)?;
                self.flush_dist_result(cluster, out)
            }
            _ => mult::matmult(a, b),
        }
    }

    /// Unified dispatch for matrix∘matrix cellwise binaries. Broadcasting
    /// pairs (row/col vector operands) stay CP; cell-aligned pairs over
    /// the driver budget run blocked on the cluster.
    pub fn dispatch_binary(
        &self,
        a: &Matrix,
        b: &Matrix,
        op: BinOp,
        pos: Option<Pos>,
    ) -> Result<Matrix> {
        self.dispatch_binary_hinted(a, b, op, pos, None, None)
    }

    /// [`Self::dispatch_binary`] with the operands' lineage references
    /// for block-cache reuse on DIST placements.
    pub fn dispatch_binary_hinted(
        &self,
        a: &Matrix,
        b: &Matrix,
        op: BinOp,
        pos: Option<Pos>,
        ha: Option<&LineageRef>,
        hb: Option<&LineageRef>,
    ) -> Result<Matrix> {
        if a.shape() != b.shape() {
            return elementwise::binary(a, b, op);
        }
        let est = estimate::binary_mem_estimate(a, b);
        let desc = format!("b({op:?}) ({}x{})", a.rows(), a.cols());
        match self.resolve_exec(OpKind::CellBinary, pos, est, &desc)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (ab, _) = self.cache_acquire(cluster, ha, a, "lhs")?;
                let (bb, _) = self.cache_acquire(cluster, hb, b, "rhs")?;
                let out = dist_ops::binary_blocked(cluster, &ab, &bb, op)?;
                self.flush_dist_result(cluster, out)
            }
            _ => elementwise::binary(a, b, op),
        }
    }

    /// Unified dispatch for full aggregates (`sum`, `mean`, `min`, ...).
    pub fn dispatch_agg_full(&self, m: &Matrix, op: AggOp, pos: Option<Pos>) -> Result<f64> {
        self.dispatch_agg_full_hinted(m, op, pos, None)
    }

    /// [`Self::dispatch_agg_full`] with the operand's lineage reference.
    pub fn dispatch_agg_full_hinted(
        &self,
        m: &Matrix,
        op: AggOp,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<f64> {
        let est = m.size_in_bytes() + estimate::dense_size(1, 1);
        let desc = format!("ua({}) ({}x{})", agg_name(op), m.rows(), m.cols());
        match self.resolve_exec(OpKind::Agg, pos, est, &desc)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (mb, _) = self.cache_acquire(cluster, hint, m, "arg")?;
                Ok(dist_ops::full_agg_blocked(cluster, &mb, op))
            }
            _ => Ok(agg::full_agg(m, op)),
        }
    }

    /// Unified dispatch for row-/column-wise aggregates (`rowSums`,
    /// `colMaxs`, ...). `row_wise` selects the reduction axis.
    pub fn dispatch_agg_axis(
        &self,
        m: &Matrix,
        op: AggOp,
        row_wise: bool,
        pos: Option<Pos>,
    ) -> Result<Matrix> {
        self.dispatch_agg_axis_hinted(m, op, row_wise, pos, None)
    }

    /// [`Self::dispatch_agg_axis`] with the operand's lineage reference.
    pub fn dispatch_agg_axis_hinted(
        &self,
        m: &Matrix,
        op: AggOp,
        row_wise: bool,
        pos: Option<Pos>,
        hint: Option<&LineageRef>,
    ) -> Result<Matrix> {
        let out = if row_wise {
            estimate::dense_size(m.rows(), 1)
        } else {
            estimate::dense_size(1, m.cols())
        };
        let est = m.size_in_bytes() + out;
        let dir = if row_wise { "uar" } else { "uac" };
        let desc = format!("{dir}({}) ({}x{})", agg_name(op), m.rows(), m.cols());
        match self.resolve_exec(OpKind::Agg, pos, est, &desc)? {
            ExecType::Dist => {
                let cluster = self.cluster_ref()?;
                let (mb, _) = self.cache_acquire(cluster, hint, m, "arg")?;
                if row_wise {
                    dist_ops::row_agg_blocked(cluster, &mb, op)
                } else {
                    dist_ops::col_agg_blocked(cluster, &mb, op)
                }
            }
            _ => Ok(if row_wise { agg::row_agg(m, op) } else { agg::col_agg(m, op) }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::SystemConfig;
    use crate::dml::parser::parse;
    use crate::runtime::matrix::randgen::{rand, Pdf};
    use crate::util::quickcheck::approx_eq_slice;

    fn interp(config: SystemConfig) -> Interpreter {
        let bundle = crate::dml::validate::Bundle {
            main: parse("x = 1").unwrap(),
            namespaces: Default::default(),
        };
        Interpreter::new(bundle, config)
    }

    #[test]
    fn binary_dispatch_distributes_over_budget() {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = 32;
        let it = interp(config);
        let a = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 31).unwrap();
        let b = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 32).unwrap();
        let before = crate::util::metrics::global().snapshot();
        let out = it.dispatch_binary(&a, &b, BinOp::Add, None).unwrap();
        let d = crate::util::metrics::global().snapshot().delta(&before);
        assert!(d.dist_tasks > 0, "over-budget cell op must distribute");
        let local = elementwise::binary(&a, &b, BinOp::Add).unwrap();
        assert!(approx_eq_slice(&out.to_row_major_vec(), &local.to_row_major_vec(), 1e-12));
    }

    #[test]
    fn agg_dispatch_matches_cp() {
        let mut config = SystemConfig::tiny_driver(8 * 1024);
        config.block_size = 16;
        let it = interp(config);
        let m = rand(64, 48, -2.0, 2.0, 0.7, Pdf::Uniform, 33).unwrap();
        for op in [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Mean] {
            let cp = agg::full_agg(&m, op);
            let dist = it.dispatch_agg_full(&m, op, None).unwrap();
            assert!((cp - dist).abs() < 1e-9, "{op:?}: {cp} vs {dist}");
        }
        let rs = it.dispatch_agg_axis(&m, AggOp::Sum, true, None).unwrap();
        assert!(approx_eq_slice(
            &rs.to_row_major_vec(),
            &agg::row_agg(&m, AggOp::Sum).to_row_major_vec(),
            1e-9
        ));
    }

    #[test]
    fn over_budget_without_cluster_errors() {
        let mut config = SystemConfig::tiny_driver(1024);
        config.dist_enabled = false;
        let it = interp(config);
        let a = Matrix::filled(128, 128, 1.0);
        assert!(it.dispatch_matmult(&a, &a).is_err());
        assert!(it.dispatch_binary(&a, &a, BinOp::Add, None).is_err());
        assert!(it.dispatch_agg_full(&a, AggOp::Sum, None).is_err());
    }

    #[test]
    fn explain_lines_cover_cp_and_dist() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        config.explain = true;
        let it = interp(config);
        let small = Matrix::filled(8, 8, 1.0);
        let big = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 34).unwrap();
        it.dispatch_matmult(&small, &small).unwrap();
        it.dispatch_matmult(&big, &big).unwrap();
        let out = it.output().join("\n");
        assert!(out.contains("-> CP"), "CP placements must be explained too:\n{out}");
        assert!(out.contains("-> DIST"), "{out}");
    }
}
