//! The DML interpreter: executes validated programs over the matrix
//! runtime, honoring the compiler's execution-type decisions for heavy
//! operators (CP / distributed / accelerator) through the unified
//! [`dispatch`] path, which consults the compiled [`crate::hop::plan::Plan`]
//! and falls back to runtime estimates for shapes unknown at compile time.

pub mod builtins;
pub mod dispatch;
pub mod lineage;
pub mod registry;
pub mod value;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::conf::SystemConfig;
use crate::dml::ast::*;
use crate::dml::validate::Bundle;
use crate::hop::plan::Plan;
use crate::runtime::dist::cache::LineageRef;
use crate::runtime::matrix::elementwise::{self, BinOp, UnaryOp};
use crate::util::error::{DmlError, Result};
use crate::util::metrics;
use crate::util::stats::Stats;
pub use value::Value;

/// Variable scope (one frame; DML functions do not close over callers).
pub type Scope = HashMap<String, Value>;

/// Maximum user-function call depth. Kept conservative because each DML
/// frame costs several native interpreter frames (test threads default to
/// 2 MB stacks); ML scripts are iterative, not deeply recursive.
const MAX_CALL_DEPTH: usize = 48;

/// The interpreter. Cheap to share across threads (parfor workers hold
/// `&Interpreter`).
pub struct Interpreter {
    pub bundle: Arc<Bundle>,
    pub config: SystemConfig,
    /// Compiled execution plan (per-operator ExecType placements); None
    /// when running without the plan-compilation pass.
    pub plan: Option<Arc<Plan>>,
    /// Captured `print` output (also echoed to stdout when `echo` is set).
    pub sink: Arc<Mutex<Vec<String>>>,
    /// Echo prints to stdout.
    pub echo: bool,
    /// Distributed backend handle (simulated cluster), if enabled.
    pub cluster: Option<Arc<crate::runtime::dist::Cluster>>,
    /// Lineage versions of variable bindings (keys of the block cache).
    pub lineage: Arc<lineage::LineageTable>,
    /// Accelerator backend handle (PJRT), if enabled.
    pub accel: Option<Arc<crate::runtime::accel::AccelBackend>>,
    /// Execution statistics / trace registry (SystemML `-stats`); `None`
    /// when both stats knobs are off — the zero-cost disabled path.
    pub stats: Option<Arc<Stats>>,
}

/// Per-execution context: current namespace (for bare-call resolution in
/// sourced functions) and call depth.
#[derive(Clone, Default)]
pub struct Ctx {
    pub namespace: Option<String>,
    pub depth: usize,
}

/// Build the simulated cluster an interpreter would use for `config`, or
/// None when the distributed backend is disabled. Factored out so a
/// session-persistent `MLContext` can keep ONE cluster alive across
/// `execute` calls (resident blocked values and the block cache survive
/// between scripts) and hand it to each interpreter via
/// [`Interpreter::with_cluster`].
pub fn build_cluster(config: &SystemConfig) -> Option<Arc<crate::runtime::dist::Cluster>> {
    build_cluster_with_stats(config, None)
}

/// [`build_cluster`] with the session's statistics registry attached:
/// the cluster stamps per-worker task time against `stats` and emits
/// blockify / broadcast / shuffle / allreduce / spill trace events.
pub fn build_cluster_with_stats(
    config: &SystemConfig,
    stats: Option<Arc<Stats>>,
) -> Option<Arc<crate::runtime::dist::Cluster>> {
    if !config.dist_enabled {
        return None;
    }
    // The aggregate worker storage bounds both resident caches.
    // cache_enabled=false collapses only the *partition cache*
    // budget to 0 (no lineage reuse); live blocked values keep
    // the full budget, so disabling the cache does not force
    // every chained DIST result back to the driver.
    let storage = config.worker_storage.saturating_mul(config.num_workers.max(1));
    let cache_storage = if config.cache_enabled { storage } else { 0 };
    // dist_threads=0 means one pool thread per simulated worker;
    // dist_threads=1 is the serial escape hatch (see dist::pool).
    let threads = if config.dist_threads == 0 {
        config.num_workers.max(1)
    } else {
        config.dist_threads
    };
    Some(Arc::new(
        crate::runtime::dist::Cluster::with_budgets_threads(
            config.num_workers,
            config.block_size,
            cache_storage,
            storage,
            threads,
        )
        .with_sparsity_threshold(config.sparsity_threshold)
        .with_stats(stats),
    ))
}

impl Interpreter {
    pub fn new(bundle: Bundle, config: SystemConfig) -> Self {
        let stats = Stats::from_config(&config);
        let cluster = build_cluster_with_stats(&config, stats.clone());
        Interpreter::assemble(bundle, config, cluster, stats)
    }

    /// Like [`Interpreter::new`], but executing against a caller-owned
    /// cluster (the session-persistent MLContext path): blocked values
    /// bound on `cluster` by earlier scripts stay resident and can be
    /// passed in as inputs with zero blockify/collect cost. The stats
    /// registry is inherited from the cluster (keeping the session's
    /// heavy-hitter table accumulating across scripts); a stats-less
    /// cluster falls back to the config knobs.
    pub fn with_cluster(
        bundle: Bundle,
        config: SystemConfig,
        cluster: Option<Arc<crate::runtime::dist::Cluster>>,
    ) -> Self {
        let stats = match &cluster {
            Some(c) => c.stats().cloned(),
            None => Stats::from_config(&config),
        };
        Interpreter::assemble(bundle, config, cluster, stats)
    }

    /// [`Interpreter::with_cluster`] with an explicit stats registry:
    /// the MLContext owns ONE session-wide [`Stats`] and hands it to
    /// every interpreter, so the heavy-hitter table keeps accumulating
    /// across scripts even when the distributed backend is off (and no
    /// second trace file is ever opened).
    pub fn with_cluster_and_stats(
        bundle: Bundle,
        config: SystemConfig,
        cluster: Option<Arc<crate::runtime::dist::Cluster>>,
        stats: Option<Arc<Stats>>,
    ) -> Self {
        Interpreter::assemble(bundle, config, cluster, stats)
    }

    fn assemble(
        bundle: Bundle,
        config: SystemConfig,
        cluster: Option<Arc<crate::runtime::dist::Cluster>>,
        stats: Option<Arc<Stats>>,
    ) -> Self {
        let accel = if config.accel_enabled {
            crate::runtime::accel::AccelBackend::open(&config)
                .map(Arc::new)
                .map_err(|e| {
                    eprintln!("warning: accelerator backend unavailable: {e}");
                    e
                })
                .ok()
        } else {
            None
        };
        Interpreter {
            bundle: Arc::new(bundle),
            config,
            plan: None,
            sink: Arc::new(Mutex::new(Vec::new())),
            echo: false,
            cluster,
            lineage: Arc::new(lineage::LineageTable::default()),
            accel,
            stats,
        }
    }

    /// Execute the main program body with the given input bindings;
    /// returns the final top-level scope.
    pub fn run(&self, inputs: Scope) -> Result<Scope> {
        let mut scope = inputs;
        for name in scope.keys() {
            self.lineage.rebind(name);
        }
        let body = self.bundle.main.body.clone();
        self.exec_block(&body, &mut scope, &Ctx::default())?;
        Ok(scope)
    }

    /// Print-sink contents.
    pub fn output(&self) -> Vec<String> {
        self.sink.lock().unwrap().clone()
    }

    pub(crate) fn emit(&self, line: String) {
        if self.echo {
            println!("{line}");
        }
        self.sink.lock().unwrap().push(line);
    }

    // ---- statements ------------------------------------------------------

    pub fn exec_block(&self, stmts: &[Stmt], scope: &mut Scope, ctx: &Ctx) -> Result<()> {
        for s in stmts {
            self.exec_stmt(s, scope, ctx)?;
        }
        Ok(())
    }

    pub fn exec_stmt(&self, stmt: &Stmt, scope: &mut Scope, ctx: &Ctx) -> Result<()> {
        metrics::global().instructions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match &self.stats {
            Some(s) if s.trace_enabled() => {
                let kind = stmt_kind(stmt);
                s.span_open("statement", kind);
                let t0 = std::time::Instant::now();
                let r = self.exec_stmt_inner(stmt, scope, ctx);
                s.span_close("statement", kind, t0.elapsed().as_nanos() as u64);
                r
            }
            _ => self.exec_stmt_inner(stmt, scope, ctx),
        }
    }

    fn exec_stmt_inner(&self, stmt: &Stmt, scope: &mut Scope, ctx: &Ctx) -> Result<()> {
        match stmt {
            Stmt::Assign { target, value, pos } => {
                let v = self.eval(value, scope, ctx)?;
                match target {
                    AssignTarget::Var(name) => {
                        let version = self.note_rebind(name);
                        if let (Some(cl), Value::Matrix(m)) = (&self.cluster, &v) {
                            // The statement's DIST result stays resident
                            // under its new lineage key.
                            cl.cache().adopt(name, version, m);
                        }
                        scope.insert(name.clone(), v);
                    }
                    AssignTarget::Indexed { name, rows, cols } => {
                        // Bounds and rhs shape are checked against the
                        // target's metadata — a blocked target is never
                        // forced; DIST placements rewrite only the
                        // touched blocks (dispatch_left_index_value).
                        let base = scope
                            .get(name)
                            .cloned()
                            .ok_or_else(|| DmlError::rt(format!("undefined variable '{name}'")))?;
                        let (br, bc) = base.matrix_dims()?;
                        let (rl, ru) = self.range_bounds(rows, br, scope, ctx)?;
                        let (cl, cu) = self.range_bounds(cols, bc, scope, ctx)?;
                        let out = self.dispatch_left_index_value(
                            &base,
                            &v,
                            name,
                            rl,
                            ru,
                            cl,
                            cu,
                            Some(*pos),
                        )?;
                        self.note_rebind(name);
                        scope.insert(name.clone(), out);
                    }
                }
            }
            Stmt::MultiAssign { targets, value, .. } => {
                let results = match value {
                    Expr::Call { namespace, name, args, pos } => {
                        self.call_multi(namespace.as_deref(), name, args, *pos, scope, ctx)?
                    }
                    _ => return Err(DmlError::rt("multi-assignment requires a function call")),
                };
                if results.len() < targets.len() {
                    return Err(DmlError::rt(format!(
                        "function returned {} values, expected {}",
                        results.len(),
                        targets.len()
                    )));
                }
                for (t, v) in targets.iter().zip(results) {
                    let version = self.note_rebind(t);
                    if let (Some(cl), Value::Matrix(m)) = (&self.cluster, &v) {
                        cl.cache().adopt(t, version, m);
                    }
                    scope.insert(t.clone(), v);
                }
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                if self.eval(cond, scope, ctx)?.as_bool()? {
                    self.exec_block(then_branch, scope, ctx)?;
                } else {
                    self.exec_block(else_branch, scope, ctx)?;
                }
            }
            Stmt::For { var, range, body, .. } => {
                let _pins = self.pin_loop_reads(body);
                for v in self.range_values(range, scope, ctx)? {
                    self.note_rebind(var);
                    scope.insert(var.clone(), Value::Double(v));
                    self.exec_block(body, scope, ctx)?;
                }
            }
            Stmt::ParFor { var, range, body, opts, .. } => {
                let _pins = self.pin_loop_reads(body);
                let iters = self.range_values(range, scope, ctx)?;
                crate::runtime::parfor::execute_parfor(self, var, &iters, body, opts, scope, ctx)?;
            }
            Stmt::While { cond, body, .. } => {
                let _pins = self.pin_loop_reads(body);
                let mut guard = 0usize;
                while self.eval(cond, scope, ctx)?.as_bool()? {
                    self.exec_block(body, scope, ctx)?;
                    guard += 1;
                    if guard > 100_000_000 {
                        return Err(DmlError::rt("while loop exceeded iteration guard"));
                    }
                }
            }
            Stmt::ExprStmt { expr, .. } => {
                self.eval(expr, scope, ctx)?;
            }
        }
        Ok(())
    }

    /// Iteration values of a loop range.
    fn range_values(&self, range: &RangeExpr, scope: &mut Scope, ctx: &Ctx) -> Result<Vec<f64>> {
        let from = self.eval(&range.from, scope, ctx)?.as_double()?;
        let to = self.eval(&range.to, scope, ctx)?.as_double()?;
        let step = match &range.step {
            Some(s) => self.eval(s, scope, ctx)?.as_double()?,
            None => {
                if from <= to {
                    1.0
                } else {
                    -1.0
                }
            }
        };
        if step == 0.0 {
            return Err(DmlError::rt("loop range step must be nonzero"));
        }
        let mut vals = Vec::new();
        let mut v = from;
        if step > 0.0 {
            while v <= to + 1e-12 {
                vals.push(v);
                v += step;
            }
        } else {
            while v >= to - 1e-12 {
                vals.push(v);
                v += step;
            }
        }
        Ok(vals)
    }

    /// Translate a DML 1-based inclusive [`IndexRange`] to 0-based
    /// half-open bounds, checking limits.
    pub fn range_bounds(
        &self,
        r: &IndexRange,
        dim: usize,
        scope: &mut Scope,
        ctx: &Ctx,
    ) -> Result<(usize, usize)> {
        match r {
            IndexRange::All => Ok((0, dim)),
            IndexRange::Single(e) => {
                let i = self.eval(e, scope, ctx)?.as_int()?;
                if i < 1 || i as usize > dim {
                    return Err(DmlError::rt(format!("index {i} out of range [1,{dim}]")));
                }
                Ok((i as usize - 1, i as usize))
            }
            IndexRange::Range(a, b) => {
                let lo = self.eval(a, scope, ctx)?.as_int()?;
                let hi = self.eval(b, scope, ctx)?.as_int()?;
                if lo < 1 || hi < lo || hi as usize > dim {
                    return Err(DmlError::rt(format!(
                        "index range {lo}:{hi} out of range [1,{dim}]"
                    )));
                }
                Ok((lo as usize - 1, hi as usize))
            }
        }
    }

    // ---- expressions -------------------------------------------------

    pub fn eval(&self, expr: &Expr, scope: &mut Scope, ctx: &Ctx) -> Result<Value> {
        match expr {
            Expr::Num(v, _) => Ok(Value::Double(*v)),
            Expr::Int(v, _) => Ok(Value::Int(*v)),
            Expr::Str(s, _) => Ok(Value::Str(s.clone())),
            Expr::Bool(b, _) => Ok(Value::Bool(*b)),
            Expr::Var(name, pos) => scope.get(name).cloned().ok_or_else(|| {
                DmlError::rt(format!("line {}: undefined variable '{name}'", pos.line))
            }),
            Expr::List(items, _) => {
                let vals: Result<Vec<Value>> =
                    items.iter().map(|e| self.eval(e, scope, ctx)).collect();
                Ok(Value::List(vals?))
            }
            Expr::Unary { op, operand, .. } => {
                let v = self.eval(operand, scope, ctx)?;
                match (op, v) {
                    (AstUnOp::Neg, Value::Int(i)) => Ok(Value::Int(-i)),
                    (AstUnOp::Neg, Value::Double(d)) => Ok(Value::Double(-d)),
                    (AstUnOp::Neg, Value::Matrix(m)) => {
                        Ok(Value::Matrix(elementwise::unary(&m, UnaryOp::Neg)))
                    }
                    (AstUnOp::Not, Value::Matrix(m)) => {
                        Ok(Value::Matrix(elementwise::unary(&m, UnaryOp::Not)))
                    }
                    // Blocked values map on the cluster and stay blocked.
                    (AstUnOp::Neg, v @ Value::Blocked(_)) => {
                        self.dispatch_unary_value(&v, UnaryOp::Neg)
                    }
                    (AstUnOp::Not, v @ Value::Blocked(_)) => {
                        self.dispatch_unary_value(&v, UnaryOp::Not)
                    }
                    (AstUnOp::Not, v) => Ok(Value::Bool(!v.as_bool()?)),
                    (AstUnOp::Neg, v) => Ok(Value::Double(-v.as_double()?)),
                }
            }
            Expr::Binary { op, lhs, rhs, pos } => {
                // Short-circuit scalar && / ||.
                if matches!(op, AstBinOp::And | AstBinOp::Or) {
                    let l = self.eval(lhs, scope, ctx)?;
                    if !l.is_matrix() {
                        let lb = l.as_bool()?;
                        if *op == AstBinOp::And && !lb {
                            return Ok(Value::Bool(false));
                        }
                        if *op == AstBinOp::Or && lb {
                            return Ok(Value::Bool(true));
                        }
                        let rb = self.eval(rhs, scope, ctx)?.as_bool()?;
                        return Ok(Value::Bool(rb));
                    }
                    let r = self.eval(rhs, scope, ctx)?;
                    let hints = (self.lineage_hint(lhs), self.lineage_hint(rhs));
                    return self.binary_matrix_op(*op, &l, &r, pos, hints);
                }
                let l = self.eval(lhs, scope, ctx)?;
                let r = self.eval(rhs, scope, ctx)?;
                let hints = if l.is_matrix() || r.is_matrix() {
                    (self.lineage_hint(lhs), self.lineage_hint(rhs))
                } else {
                    (None, None)
                };
                self.binary_value_op(*op, &l, &r, pos, hints)
            }
            Expr::Index { base, rows, cols, pos } => {
                let b = self.eval(base, scope, ctx)?;
                // Bounds come from metadata (never forces a blocked
                // base); the unified dispatch picks CP slice vs blocked
                // block-range selection. A 1x1 slice stays a matrix in
                // DML (as.scalar converts).
                let (br, bc) = b.matrix_dims()?;
                let (rl, ru) = self.range_bounds(rows, br, scope, ctx)?;
                let (cl, cu) = self.range_bounds(cols, bc, scope, ctx)?;
                let hint = self.lineage_hint(base);
                self.dispatch_right_index_value(&b, rl, ru, cl, cu, Some(*pos), hint.as_ref())
            }
            Expr::Call { namespace, name, args, pos } => {
                let mut results =
                    self.call_multi(namespace.as_deref(), name, args, *pos, scope, ctx)?;
                if results.is_empty() {
                    // void builtins (print, stop targets) return empty; DML
                    // allows using them only as statements.
                    Ok(Value::Bool(true))
                } else {
                    Ok(results.remove(0))
                }
            }
        }
    }

    /// Scalar/matrix dispatch for binary operators. `hints` carry the
    /// operands' lineage references when they are plain variable reads
    /// (consumed by the block cache on DIST placements).
    fn binary_value_op(
        &self,
        op: AstBinOp,
        l: &Value,
        r: &Value,
        pos: &Pos,
        hints: (Option<LineageRef>, Option<LineageRef>),
    ) -> Result<Value> {
        // String concatenation with `+`.
        if op == AstBinOp::Add {
            if let (Value::Str(a), b) = (l, r) {
                return Ok(Value::Str(format!("{a}{}", b.to_display_string())));
            }
            if let (a, Value::Str(b)) = (l, r) {
                return Ok(Value::Str(format!("{}{b}", a.to_display_string())));
            }
        }
        if op == AstBinOp::Eq {
            if let (Value::Str(a), Value::Str(b)) = (l, r) {
                return Ok(Value::Bool(a == b));
            }
        }
        if op == AstBinOp::Neq {
            if let (Value::Str(a), Value::Str(b)) = (l, r) {
                return Ok(Value::Bool(a != b));
            }
        }
        if l.is_matrix() || r.is_matrix() {
            return self.binary_matrix_op(op, l, r, pos, hints);
        }
        // Pure scalar arithmetic; ints stay ints where DML does.
        let bop = ast_to_binop(op);
        if let (Value::Int(a), Value::Int(b)) = (l, r) {
            match op {
                AstBinOp::Add => return Ok(Value::Int(a + b)),
                AstBinOp::Sub => return Ok(Value::Int(a - b)),
                AstBinOp::Mul => return Ok(Value::Int(a * b)),
                AstBinOp::IntDiv if *b != 0 => return Ok(Value::Int(a.div_euclid(*b))),
                AstBinOp::Mod if *b != 0 => return Ok(Value::Int(a.rem_euclid(*b))),
                _ => {}
            }
        }
        let a = l.as_double()?;
        let b = r.as_double()?;
        let out = bop.apply(a, b);
        match op {
            AstBinOp::Eq
            | AstBinOp::Neq
            | AstBinOp::Lt
            | AstBinOp::Le
            | AstBinOp::Gt
            | AstBinOp::Ge
            | AstBinOp::And
            | AstBinOp::Or => Ok(Value::Bool(out != 0.0)),
            _ => Ok(Value::Double(out)),
        }
    }

    /// Matrix-typed binary ops route through the unified plan-aware
    /// dispatch (`dispatch.rs`): matmult and cell-aligned matrix∘matrix
    /// binaries are placed CP/DIST/ACCEL. Matrix∘scalar ops stay CP for
    /// driver matrices but map cluster-side for blocked operands, so a
    /// chain of distributed updates never round-trips through the driver.
    fn binary_matrix_op(
        &self,
        op: AstBinOp,
        l: &Value,
        r: &Value,
        pos: &Pos,
        hints: (Option<LineageRef>, Option<LineageRef>),
    ) -> Result<Value> {
        if op == AstBinOp::MatMul {
            return self.dispatch_matmult_values(
                l,
                r,
                Some(*pos),
                hints.0.as_ref(),
                hints.1.as_ref(),
            );
        }
        let bop = ast_to_binop(op);
        match (l.is_matrix(), r.is_matrix()) {
            (true, true) => self.dispatch_binary_values(
                l,
                r,
                bop,
                Some(*pos),
                hints.0.as_ref(),
                hints.1.as_ref(),
            ),
            (true, false) => self.dispatch_scalar_value(l, r.as_double()?, bop, false),
            (false, true) => self.dispatch_scalar_value(r, l.as_double()?, bop, true),
            _ => Err(DmlError::rt(format!(
                "line {}: invalid operands for {op:?}",
                pos.line
            ))),
        }
    }

    // ---- calls ---------------------------------------------------------

    /// Call a function or builtin; returns all results (multi-return).
    /// `pos` is the call site, used for compiled-placement lookups of
    /// aggregate builtins.
    pub fn call_multi(
        &self,
        namespace: Option<&str>,
        name: &str,
        args: &[Arg],
        pos: Pos,
        scope: &mut Scope,
        ctx: &Ctx,
    ) -> Result<Vec<Value>> {
        // Resolve user functions: explicit ns, local, then current ns.
        let func = if let Some(ns) = namespace {
            self.bundle.resolve(Some(ns), name).cloned().map(|f| (f, Some(ns.to_string())))
        } else {
            self.bundle
                .resolve(None, name)
                .cloned()
                .map(|f| (f, None))
                .or_else(|| {
                    ctx.namespace.as_ref().and_then(|ns| {
                        self.bundle
                            .resolve(Some(ns), name)
                            .cloned()
                            .map(|f| (f, Some(ns.clone())))
                    })
                })
        };
        if let Some((f, fns)) = func {
            return self.call_user_function(&f, fns, args, scope, ctx);
        }
        if namespace.is_none() {
            // Builtins: evaluate args (keeping names and lineage
            // references for the cache-aware aggregates) and dispatch.
            let mut eargs = Vec::with_capacity(args.len());
            let mut hints = Vec::with_capacity(args.len());
            for a in args {
                eargs.push((a.name.clone(), self.eval(&a.value, scope, ctx)?));
                hints.push(self.lineage_hint(&a.value));
            }
            return builtins::call_builtin(self, name, &eargs, &hints, pos);
        }
        Err(DmlError::rt(format!(
            "unknown function '{}{name}'",
            namespace.map(|n| format!("{n}::")).unwrap_or_default()
        )))
    }

    fn call_user_function(
        &self,
        f: &FunctionDef,
        fns: Option<String>,
        args: &[Arg],
        scope: &mut Scope,
        ctx: &Ctx,
    ) -> Result<Vec<Value>> {
        if ctx.depth >= MAX_CALL_DEPTH {
            return Err(DmlError::rt(format!(
                "maximum call depth {MAX_CALL_DEPTH} exceeded in '{}'",
                f.name
            )));
        }
        let mut frame: Scope = HashMap::new();
        let fctx = Ctx { namespace: fns, depth: ctx.depth + 1 };
        // Positional then named arguments.
        let mut positional = 0usize;
        for a in args {
            match &a.name {
                None => {
                    if positional >= f.params.len() {
                        return Err(DmlError::rt(format!(
                            "too many arguments to '{}' (takes {})",
                            f.name,
                            f.params.len()
                        )));
                    }
                    let v = self.eval(&a.value, scope, ctx)?;
                    self.note_rebind(&f.params[positional].name);
                    frame.insert(f.params[positional].name.clone(), v);
                    positional += 1;
                }
                Some(n) => {
                    if !f.params.iter().any(|p| &p.name == n) {
                        return Err(DmlError::rt(format!(
                            "unknown named argument '{n}' for '{}'",
                            f.name
                        )));
                    }
                    let v = self.eval(&a.value, scope, ctx)?;
                    self.note_rebind(n);
                    frame.insert(n.clone(), v);
                }
            }
        }
        // Defaults for unbound params.
        for p in &f.params {
            if !frame.contains_key(&p.name) {
                match &p.default {
                    Some(d) => {
                        let v = self.eval(d, &mut frame.clone(), &fctx)?;
                        self.note_rebind(&p.name);
                        frame.insert(p.name.clone(), v);
                    }
                    None => {
                        return Err(DmlError::rt(format!(
                            "missing argument '{}' in call to '{}'",
                            p.name, f.name
                        )))
                    }
                }
            }
        }
        self.exec_block(&f.body, &mut frame, &fctx)?;
        let mut out = Vec::with_capacity(f.returns.len());
        for r in &f.returns {
            let v = frame.remove(&r.name).ok_or_else(|| {
                DmlError::rt(format!(
                    "function '{}' did not assign return variable '{}'",
                    f.name, r.name
                ))
            })?;
            out.push(v);
        }
        Ok(out)
    }
}

/// Trace-span name of a statement kind.
fn stmt_kind(stmt: &Stmt) -> &'static str {
    match stmt {
        Stmt::Assign { .. } => "assign",
        Stmt::MultiAssign { .. } => "multi_assign",
        Stmt::If { .. } => "if",
        Stmt::For { .. } => "for",
        Stmt::ParFor { .. } => "parfor",
        Stmt::While { .. } => "while",
        Stmt::ExprStmt { .. } => "expr",
    }
}

fn ast_to_binop(op: AstBinOp) -> BinOp {
    match op {
        AstBinOp::Add => BinOp::Add,
        AstBinOp::Sub => BinOp::Sub,
        AstBinOp::Mul => BinOp::Mul,
        AstBinOp::Div => BinOp::Div,
        AstBinOp::Pow => BinOp::Pow,
        AstBinOp::Mod => BinOp::Mod,
        AstBinOp::IntDiv => BinOp::IntDiv,
        AstBinOp::Eq => BinOp::Eq,
        AstBinOp::Neq => BinOp::Neq,
        AstBinOp::Lt => BinOp::Lt,
        AstBinOp::Le => BinOp::Le,
        AstBinOp::Gt => BinOp::Gt,
        AstBinOp::Ge => BinOp::Ge,
        AstBinOp::And => BinOp::And,
        AstBinOp::Or => BinOp::Or,
        AstBinOp::MatMul => unreachable!("matmul handled separately"),
    }
}
