//! Variable lineage tracking for the block-partition cache.
//!
//! The interpreter stamps every variable binding event (assignment,
//! left-indexed mutation, multi-assign, function parameter binding,
//! parfor result merge) with a fresh **lineage version** from a global
//! counter. A DIST operand that is a plain variable read — or a simple
//! derived form like `t(X)` — carries a [`LineageRef`] built from that
//! version into the dispatch layer, which keys the cluster's resident
//! block cache with it. Rebinding a name bumps its version *and*
//! invalidates resident entries derived from it, so a stale cached
//! partition can never be addressed again (and the guard check in the
//! cache makes even version collisions across scopes safe).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::dml::ast::{Arg, AssignTarget, Expr, IndexRange, RangeExpr, Stmt};
use crate::runtime::dist::cache::LineageRef;
use crate::runtime::interp::Interpreter;

/// Name → current lineage version. Shared by all frames of one
/// interpreter (parfor workers included), so versions are unique per
/// binding event program-wide.
#[derive(Debug, Default)]
pub struct LineageTable {
    versions: Mutex<std::collections::HashMap<String, u64>>,
    next: AtomicU64,
}

impl LineageTable {
    /// Record a (re)binding of `name`; returns the fresh version.
    pub fn rebind(&self, name: &str) -> u64 {
        let v = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        self.versions.lock().unwrap().insert(name.to_string(), v);
        v
    }

    /// Current version of `name`, if it was ever bound.
    pub fn current(&self, name: &str) -> Option<u64> {
        self.versions.lock().unwrap().get(name).copied()
    }
}

impl Interpreter {
    /// Stamp a fresh lineage version for `name` and invalidate any
    /// resident block partitions derived from it. Every binding site in
    /// the interpreter funnels through here.
    pub(crate) fn note_rebind(&self, name: &str) -> u64 {
        let v = self.lineage.rebind(name);
        if let Some(cl) = &self.cluster {
            cl.cache().invalidate(name);
        }
        v
    }

    /// Lineage reference of an operand expression, when it has one: a
    /// plain variable read `X`, or the derived transpose `t(X)` (keyed
    /// separately but invalidated with `X`). Anything else is decided by
    /// the cache's pending-result matching alone.
    pub(crate) fn lineage_hint(&self, e: &Expr) -> Option<LineageRef> {
        match e {
            Expr::Var(name, _) => {
                Some(LineageRef::var(name, self.lineage.current(name)?))
            }
            Expr::Call { namespace: None, name, args, .. } if name == "t" && args.len() == 1 => {
                match &args[0].value {
                    Expr::Var(base, _) => Some(LineageRef::derived(
                        format!("t({base})"),
                        self.lineage.current(base)?,
                        vec![base.clone()],
                    )),
                    _ => None,
                }
            }
            _ => None,
        }
    }

    /// Pin every variable a loop body reads for the loop's duration so
    /// loop-carried resident partitions survive eviction pressure; the
    /// returned guard unpins on drop (including the error path).
    pub(crate) fn pin_loop_reads(&self, body: &[Stmt]) -> PinGuard {
        let Some(cluster) = self.cluster.clone() else {
            return PinGuard { cluster: None, names: Vec::new() };
        };
        let mut names: Vec<String> = read_vars(body).into_iter().collect();
        names.sort();
        cluster.cache().pin(&names);
        PinGuard { cluster: Some(cluster), names }
    }
}

/// RAII unpin for [`Interpreter::pin_loop_reads`].
pub(crate) struct PinGuard {
    cluster: Option<std::sync::Arc<crate::runtime::dist::Cluster>>,
    names: Vec<String>,
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        if let Some(cl) = &self.cluster {
            cl.cache().unpin(&self.names);
        }
    }
}

/// Every variable name read anywhere in a statement block (an
/// over-approximation: names written before read are included too, which
/// only pins a little more than strictly necessary).
pub fn read_vars(stmts: &[Stmt]) -> HashSet<String> {
    let mut out = HashSet::new();
    walk_stmts(stmts, &mut out);
    out
}

fn walk_stmts(stmts: &[Stmt], out: &mut HashSet<String>) {
    for s in stmts {
        match s {
            Stmt::Assign { target, value, .. } => {
                walk_expr(value, out);
                if let AssignTarget::Indexed { name, rows, cols } = target {
                    out.insert(name.clone());
                    walk_range(rows, out);
                    walk_range(cols, out);
                }
            }
            Stmt::MultiAssign { value, .. } => walk_expr(value, out),
            Stmt::If { cond, then_branch, else_branch, .. } => {
                walk_expr(cond, out);
                walk_stmts(then_branch, out);
                walk_stmts(else_branch, out);
            }
            Stmt::For { range, body, .. } | Stmt::ParFor { range, body, .. } => {
                walk_loop_range(range, out);
                walk_stmts(body, out);
            }
            Stmt::While { cond, body, .. } => {
                walk_expr(cond, out);
                walk_stmts(body, out);
            }
            Stmt::ExprStmt { expr, .. } => walk_expr(expr, out),
        }
    }
}

fn walk_loop_range(r: &RangeExpr, out: &mut HashSet<String>) {
    walk_expr(&r.from, out);
    walk_expr(&r.to, out);
    if let Some(s) = &r.step {
        walk_expr(s, out);
    }
}

fn walk_range(r: &IndexRange, out: &mut HashSet<String>) {
    match r {
        IndexRange::All => {}
        IndexRange::Single(e) => walk_expr(e, out),
        IndexRange::Range(a, b) => {
            walk_expr(a, out);
            walk_expr(b, out);
        }
    }
}

fn walk_expr(e: &Expr, out: &mut HashSet<String>) {
    match e {
        Expr::Var(name, _) => {
            out.insert(name.clone());
        }
        Expr::Unary { operand, .. } => walk_expr(operand, out),
        Expr::Binary { lhs, rhs, .. } => {
            walk_expr(lhs, out);
            walk_expr(rhs, out);
        }
        Expr::Index { base, rows, cols, .. } => {
            walk_expr(base, out);
            walk_range(rows, out);
            walk_range(cols, out);
        }
        Expr::Call { args, .. } => {
            for Arg { value, .. } in args {
                walk_expr(value, out);
            }
        }
        Expr::List(items, _) => {
            for i in items {
                walk_expr(i, out);
            }
        }
        Expr::Num(..) | Expr::Int(..) | Expr::Str(..) | Expr::Bool(..) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;

    #[test]
    fn versions_are_unique_and_monotone() {
        let t = LineageTable::default();
        let v1 = t.rebind("x");
        let v2 = t.rebind("y");
        let v3 = t.rebind("x");
        assert!(v1 < v2 && v2 < v3);
        assert_eq!(t.current("x"), Some(v3));
        assert_eq!(t.current("z"), None);
    }

    #[test]
    fn read_vars_covers_loops_and_indexing() {
        let prog = parse(
            "while (i < n) { q = t(X) %*% (X %*% p) \n A[1, j] = sum(B) \n i = i + 1 }",
        )
        .unwrap();
        let vars = read_vars(&prog.body);
        for v in ["i", "n", "X", "p", "A", "j", "B"] {
            assert!(vars.contains(v), "missing {v}: {vars:?}");
        }
    }
}
