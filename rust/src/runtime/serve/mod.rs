//! Scoring-as-a-service: dynamic micro-batched inference on the blocked
//! backend.
//!
//! The paper frames SystemML as one framework spanning model
//! preparation, training, and evaluation on a shared cluster; this
//! module adds the missing serving leg — the millions-of-users scoring
//! scenario — as a four-stage dataflow:
//!
//! ```text
//! admission ──▶ micro-batch ──▶ blocked forward ──▶ per-request scatter
//!  (queue of     (flush on        (session-resident    (metadata-only row
//!   1-row         size OR wait     weights, worker      slices; responses
//!   requests)     bound)           pool, zero collects) charged as shuffle)
//! ```
//!
//! * **Admission + batching** live in [`batcher`]: a FIFO queue of
//!   single-row requests flushed under
//!   `SystemConfig::{serve_max_batch, serve_max_wait_ticks}` — whichever
//!   bound hits first. Arrivals come from a seeded, wall-clock-free
//!   simulated process, so every run is deterministic.
//! * **Forward pass**: [`ScoreService`] keeps the model state
//!   cluster-resident for the whole session — session-carried blocked
//!   training outputs stay where they are, driver-local weight matrices
//!   are promoted to resident (replicated when single-block) handles at
//!   construction with **one** recorded broadcast of the model bytes.
//!   Each batch is zero-padded to the next `block_size` multiple and
//!   bound as a first-class blocked value, which pins the whole pipeline
//!   on the DIST path (no CP↔DIST placement thrash for small batches)
//!   and on the cluster's worker thread pool. Warm batches run with
//!   **zero driver collects**.
//! * **Plan cache**: compilation is amortized per padded batch
//!   *geometry*, not per request — one cached [`Interpreter`] (bundle +
//!   compiled plan) per distinct padded row count, so a service at the
//!   default knobs compiles at most twice (full batches + one partial
//!   size). [`ScoreService::compile_count`] exposes the cache behavior.
//! * **Scatter**: result rows are sliced per request straight off the
//!   resident output blocks (metadata-only blocked right-indexing — an
//!   `Arc` walk, never a collect); the emitted response bytes are
//!   charged as shuffle volume, modeling workers streaming responses
//!   back to clients.
//!
//! [`run_simulation`] drives all four stages end-to-end (optionally with
//! several micro-batches in flight on scoped threads) and reports
//! per-request latency in simulated ticks plus per-batch wall time —
//! the `serving` workload of `examples/dist_bench.rs` gates its p50/p99
//! ratio, its batched-vs-unbatched throughput, and the zero-collect
//! invariant in CI.

pub mod batcher;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::conf::SystemConfig;
use crate::runtime::dist::pool::run_scoped;
use crate::runtime::dist::{BlockedHandle, Cluster};
use crate::runtime::interp::{Interpreter, Scope, Value};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use batcher::{ArrivalProcess, FlushReason, MicroBatch, MicroBatcher};

/// A session-resident scoring service: one scoring script, one resident
/// model, one plan cache — shared by any number of concurrent
/// micro-batches (the service is `Sync`; `score_batch` takes `&self`).
/// Built by `MLContext::score_service`.
pub struct ScoreService {
    config: SystemConfig,
    cluster: Arc<Cluster>,
    source: String,
    /// Scope name the scoring script reads the batch matrix under.
    batch_input: String,
    /// Scope name of the scores matrix the script assigns.
    output: String,
    /// Feature count of every request row (= columns of the batch input).
    features: usize,
    /// Resident model state: blocked weight handles + passthrough
    /// scalars, cloned into every batch's scope. Handle clones are `Arc`
    /// bumps — the blocks themselves stay put on the cluster.
    state: HashMap<String, Value>,
    /// Plan cache, keyed by padded batch row count: one compiled
    /// interpreter per distinct padded geometry.
    interps: Mutex<HashMap<usize, Arc<Interpreter>>>,
    compiles: AtomicU64,
    batches: AtomicU64,
    rows_scored: AtomicU64,
}

impl ScoreService {
    /// Build a service from a session snapshot (see
    /// `MLContext::score_service`, the public entry point). `script`
    /// carries the scoring DML, the model inputs (driver matrices,
    /// scalars, or resident blocked handles from a training session) and
    /// the requested scores output; `batch_input` names the variable the
    /// per-batch feature matrix is bound under; `features` is its column
    /// count.
    ///
    /// Driver-local weight matrices are promoted to cluster-resident
    /// blocked handles here — replicated when they fit a single block
    /// (free force/gather, like allreduce products), plain blocked
    /// otherwise — and their bytes are recorded as ONE model broadcast.
    /// Values that are already blocked handles are resident by
    /// definition and move nothing.
    pub(crate) fn new(
        config: SystemConfig,
        cluster: Arc<Cluster>,
        session: HashMap<String, Value>,
        source: &str,
        inputs: &HashMap<String, Value>,
        outputs: &[String],
        batch_input: &str,
        features: usize,
    ) -> Result<ScoreService> {
        let output = outputs.first().cloned().ok_or_else(|| {
            DmlError::rt("score_service: the scoring script must request its scores output")
        })?;
        if features == 0 {
            return Err(DmlError::rt("score_service: features must be positive"));
        }
        // Model state = session carry-over ∪ explicit inputs (explicit
        // wins, mirroring execute()); the batch input is bound per call.
        let mut state = session;
        state.extend(inputs.clone());
        state.remove(batch_input);
        let bs = config.block_size;
        let mut broadcast_bytes = 0u64;
        for v in state.values_mut() {
            if let Value::Matrix(m) = v {
                let blocked = Arc::new(cluster.blockify(m)?);
                broadcast_bytes += blocked.size_in_bytes() as u64;
                let handle = if m.rows() <= bs && m.cols() <= bs {
                    BlockedHandle::replicated(Arc::clone(&cluster), blocked)
                } else {
                    BlockedHandle::new(Arc::clone(&cluster), blocked)
                };
                *v = Value::Blocked(handle);
            }
        }
        if broadcast_bytes > 0 {
            cluster.record_broadcast(broadcast_bytes);
        }
        Ok(ScoreService {
            config,
            cluster,
            source: source.to_string(),
            batch_input: batch_input.to_string(),
            output,
            features,
            state,
            interps: Mutex::new(HashMap::new()),
            compiles: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows_scored: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &SystemConfig {
        &self.config
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    pub fn features(&self) -> usize {
        self.features
    }

    /// Plan compilations so far — stays at the number of *distinct
    /// padded batch geometries* seen, not the number of batches.
    pub fn compile_count(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Micro-batches scored so far.
    pub fn batch_count(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Request rows scored so far.
    pub fn rows_scored(&self) -> u64 {
        self.rows_scored.load(Ordering::Relaxed)
    }

    /// Batch rows padded up to the next block-size multiple — the padded
    /// geometry that keys the plan cache.
    pub fn padded_rows(&self, n: usize) -> usize {
        let bs = self.config.block_size.max(1);
        n.max(1).div_ceil(bs) * bs
    }

    /// The cached interpreter for one padded geometry, compiling it on
    /// first sight. The lock is held across compilation so a distinct
    /// geometry compiles exactly once even under concurrent batches.
    fn interpreter_for(&self, padded: usize) -> Result<Arc<Interpreter>> {
        let mut cache = self.interps.lock().unwrap();
        if let Some(interp) = cache.get(&padded) {
            return Ok(Arc::clone(interp));
        }
        // Compile against the resident state plus a dense stand-in for
        // the batch shape (the plan only reads dims/sparsity).
        let mut inputs = HashMap::new();
        inputs.insert(
            self.batch_input.clone(),
            Value::Matrix(Matrix::Dense(DenseMatrix::filled(padded, self.features, 1.0))),
        );
        let compiled =
            crate::api::compile_source(&self.source, &self.config, &self.state, &inputs)?;
        let mut interp = Interpreter::with_cluster(
            compiled.bundle,
            self.config.clone(),
            Some(Arc::clone(&self.cluster)),
        );
        interp.plan = Some(Arc::new(compiled.plan));
        let interp = Arc::new(interp);
        self.compiles.fetch_add(1, Ordering::Relaxed);
        cache.insert(padded, Arc::clone(&interp));
        Ok(interp)
    }

    /// Score one micro-batch: pad to the block boundary, run the blocked
    /// forward pass against the resident model, and scatter one score
    /// row back per request. Zero-padded rows keep the forward pass
    /// row-independent, so each returned row is exactly what the request
    /// alone would have produced.
    pub fn score_batch(&self, rows: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        Ok(self.score_batch_timed(rows)?.0)
    }

    /// [`ScoreService::score_batch`] plus the batch's wall-clock phase
    /// split (execute vs scatter) for latency attribution.
    pub fn score_batch_timed(&self, rows: &[Vec<f64>]) -> Result<(Vec<Vec<f64>>, BatchPhases)> {
        let t0 = std::time::Instant::now();
        let n = rows.len();
        if n == 0 {
            return Ok((Vec::new(), BatchPhases::default()));
        }
        for (i, r) in rows.iter().enumerate() {
            if r.len() != self.features {
                return Err(DmlError::rt(format!(
                    "score_batch: request {i} has {} features, the service expects {}",
                    r.len(),
                    self.features
                )));
            }
        }
        let padded = self.padded_rows(n);
        let interp = self.interpreter_for(padded)?;
        let mut x = DenseMatrix::zeros(padded, self.features);
        for (i, r) in rows.iter().enumerate() {
            x.data[i * self.features..(i + 1) * self.features].copy_from_slice(r);
        }
        // Bind the batch as a first-class blocked value: every operator
        // touching it (or the blocked weights) resolves DIST, keeping
        // the pipeline on the worker pool with no CP↔DIST thrash.
        let blocked = Arc::new(self.cluster.blockify(&Matrix::Dense(x))?);
        let handle = BlockedHandle::new(Arc::clone(&self.cluster), blocked);
        let mut scope: Scope = self.state.clone();
        scope.insert(self.batch_input.clone(), Value::Blocked(handle));
        let final_scope = interp.run(scope)?;
        let scores = final_scope.get(&self.output).ok_or_else(|| {
            DmlError::rt(format!(
                "score_service: output '{}' was never assigned by the scoring script",
                self.output
            ))
        })?;
        let t1 = std::time::Instant::now();
        let out = self.scatter(scores, n)?;
        let t2 = std::time::Instant::now();
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows_scored.fetch_add(n as u64, Ordering::Relaxed);
        let phases = BatchPhases {
            exec_nanos: t1.duration_since(t0).as_nanos() as u64,
            scatter_nanos: t2.duration_since(t1).as_nanos() as u64,
            total_nanos: t2.duration_since(t0).as_nanos() as u64,
        };
        Ok((out, phases))
    }

    /// Per-request scatter: slice row `r` of the scores value for each
    /// of the `n` real (unpadded) requests.
    ///
    /// * A single-block result already returned with the job as a driver
    ///   matrix (the dispatch layer's free materialization) — slicing it
    ///   is pure driver work.
    /// * A multi-block result is read straight off the resident blocks:
    ///   each request row lives in exactly one block row, so the slice
    ///   is metadata-only blocked right-indexing (an `Arc` walk). The
    ///   emitted response bytes are charged as shuffle volume — workers
    ///   streaming responses to clients — never as a driver collect.
    fn scatter(&self, scores: &Value, n: usize) -> Result<Vec<Vec<f64>>> {
        match scores {
            Value::Matrix(m) => {
                if m.rows() < n {
                    return Err(DmlError::rt(format!(
                        "score_service: scores have {} rows for {} requests",
                        m.rows(),
                        n
                    )));
                }
                Ok((0..n).map(|r| (0..m.cols()).map(|c| m.get(r, c)).collect()).collect())
            }
            Value::Blocked(h) => {
                if h.rows() < n {
                    return Err(DmlError::rt(format!(
                        "score_service: scores have {} rows for {} requests",
                        h.rows(),
                        n
                    )));
                }
                let bm = h.blocked()?;
                let bs = bm.block_size();
                let mut out = Vec::with_capacity(n);
                for r in 0..n {
                    let (br, lr) = (r / bs, r % bs);
                    let mut row = Vec::with_capacity(bm.cols());
                    for bc in 0..bm.block_cols() {
                        let blk = bm.block(br, bc);
                        for c in 0..blk.cols() {
                            row.push(blk.get(lr, c));
                        }
                    }
                    out.push(row);
                }
                self.cluster.record_shuffle((n * bm.cols() * 8) as u64);
                Ok(out)
            }
            other => Err(DmlError::rt(format!(
                "score_service: output '{}' is not a matrix (found {})",
                self.output,
                other.type_name()
            ))),
        }
    }
}

/// Wall-clock phase split of one scored micro-batch. The three fields
/// are integer-nano differences over the same boundary instants, so
/// `total_nanos == exec_nanos + scatter_nanos` holds exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchPhases {
    /// Forward-pass time: padding, blockify, and the blocked run.
    pub exec_nanos: u64,
    /// Per-request scatter time (response rows off the resident blocks).
    pub scatter_nanos: u64,
    /// The batch end to end.
    pub total_nanos: u64,
}

/// Per-request latency attribution: where each request's end-to-end
/// latency went. Queue wait is simulated (deterministic per seed); the
/// two wall phases are those of the carrying batch, and
/// `exec_nanos + scatter_nanos == total_nanos` exactly (see
/// [`BatchPhases`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestPhases {
    /// Simulated ticks spent queued before the carrying batch flushed
    /// (`flush_tick - arrival_tick` — identical to `latency_ticks`).
    pub queue_ticks: u64,
    /// Wall nanos of the carrying batch's forward pass.
    pub exec_nanos: u64,
    /// Wall nanos of the carrying batch's scatter.
    pub scatter_nanos: u64,
    /// Wall nanos of the carrying batch end to end.
    pub total_nanos: u64,
}

/// End-to-end result of [`run_simulation`], indexed by request id.
#[derive(Debug)]
pub struct ServingReport {
    /// One score row per request.
    pub scores: Vec<Vec<f64>>,
    /// Queueing latency per request in simulated ticks
    /// (`flush_tick - arrival_tick`) — deterministic for a given
    /// (seed, knobs) pair.
    pub latency_ticks: Vec<u64>,
    /// Wall-clock execution time per request in seconds (the duration of
    /// the batch that carried it).
    pub wall_secs: Vec<f64>,
    /// Micro-batches flushed, with size and flush reason.
    pub flushes: Vec<(usize, FlushReason)>,
    /// Total wall-clock seconds spent executing batches (summed across
    /// in-flight groups; the sustained-throughput denominator).
    pub exec_secs: f64,
    /// Latency attribution per request: queue wait vs execute vs
    /// scatter (see [`RequestPhases`]).
    pub phases: Vec<RequestPhases>,
}

impl ServingReport {
    /// Nearest-rank percentile of the simulated-tick latencies
    /// (`p` in [0, 100]).
    pub fn latency_percentile_ticks(&self, p: f64) -> u64 {
        percentile_u64(&self.latency_ticks, p)
    }

    /// Nearest-rank percentile of the wall-clock latencies.
    pub fn wall_percentile_secs(&self, p: f64) -> f64 {
        let mut sorted = self.wall_secs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        nearest_rank(&sorted, p).copied().unwrap_or(0.0)
    }
}

/// Nearest-rank percentile over unsorted u64 samples.
pub fn percentile_u64(samples: &[u64], p: f64) -> u64 {
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    nearest_rank(&sorted, p).copied().unwrap_or(0)
}

fn nearest_rank<T>(sorted: &[T], p: f64) -> Option<&T> {
    if sorted.is_empty() {
        return None;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted.get(rank.max(1) - 1)
}

/// Drive the full admission → batch → forward → scatter dataflow for
/// `requests` seeded arrivals and return per-request scores + latencies.
///
/// The simulated clock advances tick by tick: arrivals are admitted at
/// their arrival tick, the batcher is polled every tick (size bound
/// first, then wait bound), and flushed batches execute on the service —
/// `inflight` of them concurrently on scoped threads (each in-flight
/// group joins in submission order, and scores depend only on the
/// requests of their own batch, so results are identical for any
/// `inflight`). Batch composition and tick latencies are a pure function
/// of (seed, max_gap, knobs); execution wall times are measured per
/// batch for the report.
pub fn run_simulation(
    service: &ScoreService,
    requests: usize,
    seed: u64,
    max_gap: u64,
    inflight: usize,
) -> Result<ServingReport> {
    let mut arrivals = ArrivalProcess::new(seed, service.features(), max_gap);
    let reqs: Vec<_> = (0..requests).map(|_| arrivals.next_request()).collect();

    // Phase 1 (pure, deterministic): admission + batching over the
    // simulated clock. Execution does not feed back into arrival times —
    // the admission process is open-loop, like an external client fleet.
    let mut batcher = MicroBatcher::from_config(service.config());
    let mut batches: Vec<MicroBatch> = Vec::new();
    let mut pending = reqs.into_iter().peekable();
    let mut now = 0u64;
    while pending.peek().is_some() || batcher.pending() > 0 {
        while pending.peek().map_or(false, |r| r.arrival_tick <= now) {
            batcher.admit(pending.next().unwrap());
            // A burst can hit the size bound several times in one tick.
            while let Some(b) = batcher.poll(now) {
                batches.push(b);
            }
        }
        while let Some(b) = batcher.poll(now) {
            batches.push(b);
        }
        now += 1;
    }

    // Phase 2: execute the flushed batches, `inflight` at a time.
    let mut scores: Vec<Option<Vec<f64>>> = (0..requests).map(|_| None).collect();
    let mut latency_ticks = vec![0u64; requests];
    let mut wall_secs = vec![0f64; requests];
    let mut phases = vec![RequestPhases::default(); requests];
    let mut exec_secs = 0f64;
    for group in batches.chunks(inflight.max(1)) {
        let group_start = std::time::Instant::now();
        let results: Vec<(Result<(Vec<Vec<f64>>, BatchPhases)>, f64)> = run_scoped(
            group
                .iter()
                .map(|b| {
                    let rows: Vec<Vec<f64>> = b.requests.iter().map(|r| r.row.clone()).collect();
                    move || {
                        let start = std::time::Instant::now();
                        let out = service.score_batch_timed(&rows);
                        (out, start.elapsed().as_secs_f64())
                    }
                })
                .collect(),
        );
        exec_secs += group_start.elapsed().as_secs_f64();
        for (batch, (result, batch_secs)) in group.iter().zip(results) {
            let (rows, bp) = result?;
            for (req, row) in batch.requests.iter().zip(rows) {
                let id = req.id as usize;
                scores[id] = Some(row);
                latency_ticks[id] = batch.flush_tick - req.arrival_tick;
                wall_secs[id] = batch_secs;
                phases[id] = RequestPhases {
                    queue_ticks: latency_ticks[id],
                    exec_nanos: bp.exec_nanos,
                    scatter_nanos: bp.scatter_nanos,
                    total_nanos: bp.total_nanos,
                };
            }
        }
    }
    let scores = scores
        .into_iter()
        .enumerate()
        .map(|(id, s)| s.ok_or_else(|| DmlError::rt(format!("request {id} was never scored"))))
        .collect::<Result<Vec<_>>>()?;
    Ok(ServingReport {
        scores,
        latency_ticks,
        wall_secs,
        flushes: batches.iter().map(|b| (b.requests.len(), b.reason)).collect(),
        exec_secs,
        phases,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_service_is_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<ScoreService>();
    }

    #[test]
    fn percentiles_nearest_rank() {
        let lat: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_u64(&lat, 50.0), 50);
        assert_eq!(percentile_u64(&lat, 99.0), 99);
        assert_eq!(percentile_u64(&lat, 100.0), 100);
        assert_eq!(percentile_u64(&[7], 50.0), 7);
        assert_eq!(percentile_u64(&[], 99.0), 0);
    }
}
