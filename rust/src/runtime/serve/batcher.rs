//! Admission queue + dynamic micro-batcher for the scoring service.
//!
//! Single-row scoring requests enter an admission queue in arrival
//! order; the [`MicroBatcher`] flushes them into block-aligned batches
//! under two knobs (`SystemConfig::{serve_max_batch, serve_max_wait_ticks}`),
//! on whichever bound hits first:
//!
//! * **Size bound** — the queue reached `serve_max_batch` rows: flush
//!   exactly that many (a full batch; one cached plan serves it).
//! * **Wait bound** — the *oldest* queued request has waited
//!   `serve_max_wait_ticks` simulated ticks: flush everything queued (a
//!   partial batch) so tail latency stays bounded under light load.
//!
//! Time is **simulated ticks** — the arrival process ([`ArrivalProcess`])
//! is a seeded deterministic generator (no wall clock, no global RNG), so
//! batch composition, per-request latency in ticks, and therefore scores
//! are reproducible bit-for-bit across runs, thread counts, and machines.

use std::collections::VecDeque;

use crate::conf::SystemConfig;
use crate::util::prng::Prng;

/// One single-row scoring request.
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    /// Dense request id in admission order (simulation results are
    /// indexed by it).
    pub id: u64,
    /// Simulated tick at which the request entered the admission queue.
    pub arrival_tick: u64,
    /// The feature row to score.
    pub row: Vec<f64>,
}

/// Why a batch left the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// The queue reached `serve_max_batch` rows.
    Size,
    /// The oldest queued request hit `serve_max_wait_ticks`.
    Wait,
    /// Shutdown drain of the final partial batch.
    Drain,
}

/// A flushed micro-batch: the requests it packs, the tick it left the
/// queue, and which bound triggered it.
#[derive(Debug)]
pub struct MicroBatch {
    pub requests: Vec<ScoreRequest>,
    pub flush_tick: u64,
    pub reason: FlushReason,
}

impl MicroBatch {
    /// Queueing latency of each packed request in ticks
    /// (`flush_tick - arrival_tick`, in request order).
    pub fn latencies(&self) -> Vec<u64> {
        self.requests.iter().map(|r| self.flush_tick - r.arrival_tick).collect()
    }
}

/// The dynamic micro-batcher: a FIFO admission queue flushed by the
/// first-hit of the size/wait bounds (module docs).
#[derive(Debug)]
pub struct MicroBatcher {
    max_batch: usize,
    max_wait_ticks: u64,
    queue: VecDeque<ScoreRequest>,
}

impl MicroBatcher {
    pub fn new(max_batch: usize, max_wait_ticks: u64) -> MicroBatcher {
        assert!(max_batch > 0, "serve_max_batch must be positive");
        MicroBatcher { max_batch, max_wait_ticks, queue: VecDeque::new() }
    }

    /// Batcher configured from the serving knobs.
    pub fn from_config(config: &SystemConfig) -> MicroBatcher {
        MicroBatcher::new(config.serve_max_batch, config.serve_max_wait_ticks)
    }

    /// Admit a request into the queue (FIFO).
    pub fn admit(&mut self, req: ScoreRequest) {
        self.queue.push_back(req);
    }

    /// Queued (not yet flushed) requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Flush check at tick `now`: a full batch if the size bound is hit,
    /// else everything queued if the oldest request hit the wait bound,
    /// else `None`. Call repeatedly until `None` — a burst can fill the
    /// size bound several times over within one tick.
    pub fn poll(&mut self, now: u64) -> Option<MicroBatch> {
        if self.queue.len() >= self.max_batch {
            return Some(self.take(self.max_batch, now, FlushReason::Size));
        }
        match self.queue.front() {
            Some(oldest) if now.saturating_sub(oldest.arrival_tick) >= self.max_wait_ticks => {
                let n = self.queue.len();
                Some(self.take(n, now, FlushReason::Wait))
            }
            _ => None,
        }
    }

    /// Shutdown flush: whatever is queued leaves as a final partial
    /// batch, regardless of either bound.
    pub fn drain(&mut self, now: u64) -> Option<MicroBatch> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.max_batch);
        Some(self.take(n, now, FlushReason::Drain))
    }

    fn take(&mut self, n: usize, now: u64, reason: FlushReason) -> MicroBatch {
        let requests: Vec<ScoreRequest> = self.queue.drain(..n).collect();
        MicroBatch { requests, flush_tick: now, reason }
    }
}

/// Deterministic simulated arrival process: seeded xoshiro256** gaps
/// (uniform integer ticks in `[0, max_gap]`) and seeded feature rows —
/// no wall clock, no global RNG, so a (seed, features, max_gap) triple
/// names one exact request stream forever.
#[derive(Debug)]
pub struct ArrivalProcess {
    prng: Prng,
    features: usize,
    max_gap: u64,
    tick: u64,
    next_id: u64,
}

impl ArrivalProcess {
    pub fn new(seed: u64, features: usize, max_gap: u64) -> ArrivalProcess {
        ArrivalProcess { prng: Prng::new(seed), features, max_gap, tick: 0, next_id: 0 }
    }

    /// Generate the next request: advance the clock by a seeded gap
    /// (the first request arrives at tick 0) and draw its feature row.
    /// Feature values are uniform in [0.5, 1.5) — strictly nonzero, so
    /// padded-batch forward passes never hit signed-zero edge cases and
    /// scores stay bit-comparable across batch geometries.
    pub fn next_request(&mut self) -> ScoreRequest {
        if self.next_id > 0 && self.max_gap > 0 {
            self.tick += self.prng.next_u64() % (self.max_gap + 1);
        }
        let row = (0..self.features).map(|_| self.prng.uniform(0.5, 1.5)).collect();
        let req = ScoreRequest { id: self.next_id, arrival_tick: self.tick, row };
        self.next_id += 1;
        req
    }

    /// The current simulated clock (arrival tick of the latest request).
    pub fn now(&self) -> u64 {
        self.tick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tick: u64) -> ScoreRequest {
        ScoreRequest { id, arrival_tick: tick, row: vec![1.0] }
    }

    #[test]
    fn flushes_full_batch_on_size_bound() {
        let mut b = MicroBatcher::new(4, 100);
        for i in 0..9 {
            b.admit(req(i, 0));
        }
        let first = b.poll(0).unwrap();
        assert_eq!(first.reason, FlushReason::Size);
        assert_eq!(first.requests.len(), 4);
        let second = b.poll(0).unwrap();
        assert_eq!(second.reason, FlushReason::Size);
        assert_eq!(second.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        // One leftover: neither bound hit yet.
        assert!(b.poll(0).is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn flushes_partial_batch_on_wait_bound() {
        let mut b = MicroBatcher::new(64, 8);
        b.admit(req(0, 3));
        b.admit(req(1, 5));
        assert!(b.poll(10).is_none(), "oldest has waited 7 < 8 ticks");
        let batch = b.poll(11).unwrap();
        assert_eq!(batch.reason, FlushReason::Wait);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.latencies(), vec![8, 6]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn drain_flushes_final_partial_batch() {
        let mut b = MicroBatcher::new(64, 1000);
        b.admit(req(0, 0));
        b.admit(req(1, 2));
        assert!(b.poll(3).is_none());
        let batch = b.drain(3).unwrap();
        assert_eq!(batch.reason, FlushReason::Drain);
        assert_eq!(batch.requests.len(), 2);
        assert!(b.drain(3).is_none(), "drain on an empty queue is None");
    }

    #[test]
    fn arrival_process_is_deterministic_and_monotone() {
        let mut a = ArrivalProcess::new(42, 3, 4);
        let mut b = ArrivalProcess::new(42, 3, 4);
        let mut last = 0;
        for _ in 0..50 {
            let ra = a.next_request();
            let rb = b.next_request();
            assert_eq!(ra.arrival_tick, rb.arrival_tick);
            assert_eq!(ra.row, rb.row);
            assert!(ra.arrival_tick >= last, "arrivals must be monotone");
            assert!(ra.row.iter().all(|v| (0.5..1.5).contains(v)));
            last = ra.arrival_tick;
        }
    }
}
