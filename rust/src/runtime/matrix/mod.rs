//! The matrix runtime: physical formats and operators.
//!
//! [`Matrix`] is the runtime value for DML's `matrix[double]`: a dense
//! row-major block or a CSR sparse block, with the format chosen by the
//! same sparsity rules SystemML uses (sparse iff sparsity < 0.4 and the
//! matrix is large enough for the overhead to pay off). `nnz` is
//! maintained by every operator so format decisions and sparse-safe FLOP
//! accounting (paper §3 "Sparse Operations") stay exact.

pub mod agg;
pub mod dense;
pub mod elementwise;
pub mod mult;
pub mod randgen;
pub mod reorg;
pub mod solve;
pub mod sparse;

use crate::util::error::{DmlError, Result};
pub use dense::DenseMatrix;
pub use sparse::{SparseCoo, SparseCsr, SparseMcsr};

/// SystemML's sparsity turn point: below this density, sparse formats win.
pub const SPARSITY_TURN_POINT: f64 = 0.4;
/// Minimum cell count before the sparse format is considered at all.
pub const MIN_SPARSE_CELLS: usize = 1024;

/// Runtime matrix value: dense or CSR block.
#[derive(Clone, Debug)]
pub enum Matrix {
    Dense(DenseMatrix),
    Sparse(SparseCsr),
}

impl Matrix {
    // ---- constructors ------------------------------------------------

    /// Zero matrix in the cheapest format (sparse if large).
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        if rows * cols >= MIN_SPARSE_CELLS {
            Matrix::Sparse(SparseCsr::zeros(rows, cols))
        } else {
            Matrix::Dense(DenseMatrix::zeros(rows, cols))
        }
    }

    /// Dense constant matrix (sparse zero-matrix if v == 0).
    pub fn filled(rows: usize, cols: usize, v: f64) -> Matrix {
        if v == 0.0 {
            Matrix::zeros(rows, cols)
        } else {
            Matrix::Dense(DenseMatrix::filled(rows, cols, v))
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Matrix> {
        Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)?))
    }

    pub fn from_rows(rows: &[&[f64]]) -> Matrix {
        Matrix::Dense(DenseMatrix::from_rows(rows))
    }

    /// 1x1 matrix (DML treats scalars and 1x1 matrices distinctly, but
    /// `as.matrix` produces these).
    pub fn scalar(v: f64) -> Matrix {
        Matrix::Dense(DenseMatrix::from_vec(1, 1, vec![v]).unwrap())
    }

    // ---- shape / format ------------------------------------------------

    pub fn rows(&self) -> usize {
        match self {
            Matrix::Dense(d) => d.rows,
            Matrix::Sparse(s) => s.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Matrix::Dense(d) => d.cols,
            Matrix::Sparse(s) => s.cols,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    pub fn len(&self) -> usize {
        self.rows() * self.cols()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Matrix::Sparse(_))
    }

    /// Exact number of non-zeros.
    pub fn nnz(&self) -> usize {
        match self {
            Matrix::Dense(d) => d.count_nnz(),
            Matrix::Sparse(s) => s.nnz(),
        }
    }

    /// nnz / (rows*cols); 0 for empty matrices.
    pub fn sparsity(&self) -> f64 {
        if self.len() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.len() as f64
        }
    }

    /// In-memory size estimate in bytes (mirrors SystemML's
    /// MatrixBlock::estimateSizeInMemory, simplified).
    pub fn size_in_bytes(&self) -> usize {
        match self {
            Matrix::Dense(d) => 8 * d.data.len() + 48,
            Matrix::Sparse(s) => 8 * s.values.len() + 4 * s.col_idx.len() + 8 * s.row_ptr.len() + 48,
        }
    }

    /// Would the sparse format be chosen for (rows, cols, nnz)?
    pub fn prefers_sparse(rows: usize, cols: usize, nnz: usize) -> bool {
        Matrix::prefers_sparse_with(rows, cols, nnz, SPARSITY_TURN_POINT)
    }

    /// [`Matrix::prefers_sparse`] with an explicit sparsity turn point —
    /// the blocked backend routes its per-block format decisions through
    /// here so `SystemConfig::sparsity_threshold` is honored. The
    /// `MIN_SPARSE_CELLS` floor always applies: tiny blocks never pay
    /// the CSR overhead regardless of the turn point.
    pub fn prefers_sparse_with(rows: usize, cols: usize, nnz: usize, turn_point: f64) -> bool {
        let cells = rows * cols;
        cells >= MIN_SPARSE_CELLS && (nnz as f64) < turn_point * cells as f64
    }

    /// Re-examine nnz and convert to the preferred format.
    pub fn examine_and_convert(self) -> Matrix {
        self.examine_and_convert_with(SPARSITY_TURN_POINT)
    }

    /// [`Matrix::examine_and_convert`] with an explicit sparsity turn
    /// point (see [`Matrix::prefers_sparse_with`]).
    pub fn examine_and_convert_with(self, turn_point: f64) -> Matrix {
        let (r, c) = self.shape();
        let nnz = self.nnz();
        if Matrix::prefers_sparse_with(r, c, nnz, turn_point) {
            self.into_sparse_format()
        } else {
            self.into_dense_format()
        }
    }

    /// Force dense representation.
    pub fn into_dense_format(self) -> Matrix {
        match self {
            Matrix::Dense(_) => self,
            Matrix::Sparse(s) => Matrix::Dense(s.to_dense()),
        }
    }

    /// Force sparse (CSR) representation.
    pub fn into_sparse_format(self) -> Matrix {
        match self {
            Matrix::Sparse(_) => self,
            Matrix::Dense(d) => Matrix::Sparse(SparseCsr::from_dense(&d)),
        }
    }

    /// Borrow as dense, converting if needed (clones when sparse).
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            Matrix::Dense(d) => d.clone(),
            Matrix::Sparse(s) => s.to_dense(),
        }
    }

    /// Borrow as CSR, converting if needed.
    pub fn to_csr(&self) -> SparseCsr {
        match self {
            Matrix::Dense(d) => SparseCsr::from_dense(d),
            Matrix::Sparse(s) => s.clone(),
        }
    }

    /// Point lookup.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        match self {
            Matrix::Dense(d) => d.get(r, c),
            Matrix::Sparse(s) => s.get(r, c),
        }
    }

    /// Copy out as a row-major Vec<f64>.
    pub fn to_row_major_vec(&self) -> Vec<f64> {
        match self {
            Matrix::Dense(d) => d.data.clone(),
            Matrix::Sparse(s) => s.to_dense().data,
        }
    }

    /// Check dims match, else a DimMismatch error tagged with `op`.
    pub fn check_same_dims(&self, other: &Matrix, op: &str) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(DmlError::DimMismatch {
                op: op.to_string(),
                lhs_rows: self.rows(),
                lhs_cols: self.cols(),
                rhs_rows: other.rows(),
                rhs_cols: other.cols(),
            });
        }
        Ok(())
    }
}

impl PartialEq for Matrix {
    /// Value equality irrespective of physical format.
    fn eq(&self, other: &Self) -> bool {
        if self.shape() != other.shape() {
            return false;
        }
        match (self, other) {
            (Matrix::Dense(a), Matrix::Dense(b)) => a == b,
            (Matrix::Sparse(a), Matrix::Sparse(b)) => a == b,
            _ => self.to_row_major_vec() == other.to_row_major_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_decision_thresholds() {
        assert!(!Matrix::prefers_sparse(10, 10, 1)); // too small
        assert!(Matrix::prefers_sparse(100, 100, 100)); // 1% density
        assert!(!Matrix::prefers_sparse(100, 100, 5000)); // 50% density
    }

    #[test]
    fn examine_and_convert_switches_format() {
        let mut d = DenseMatrix::zeros(64, 64);
        d.set(0, 0, 1.0);
        let m = Matrix::Dense(d).examine_and_convert();
        assert!(m.is_sparse());
        assert_eq!(m.nnz(), 1);

        let dense = Matrix::filled(64, 64, 2.0).examine_and_convert();
        assert!(!dense.is_sparse());
    }

    #[test]
    fn equality_across_formats() {
        let d = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let s = d.clone().into_sparse_format();
        assert_eq!(d, s);
    }

    #[test]
    fn size_in_bytes_sparse_smaller_when_sparse() {
        let mut d = DenseMatrix::zeros(100, 100);
        d.set(5, 5, 1.0);
        let dense = Matrix::Dense(d);
        let sparse = dense.clone().into_sparse_format();
        assert!(sparse.size_in_bytes() < dense.size_in_bytes() / 10);
    }
}
