//! Sparse matrix formats: COO, CSR, and MCSR (modified CSR).
//!
//! The paper (§3 Tensor Representation) lists COO, CSR and Modified CSR as
//! the physical sparse formats that tensor linearization lets DL ops reuse.
//! CSR is the read-optimized operational format; COO is the construction /
//! interchange format; MCSR (a vec of per-row arrays) supports cheap
//! incremental row updates and is used when building outputs row by row.

use crate::runtime::matrix::dense::DenseMatrix;

/// Coordinate-format sparse matrix (row, col, value) triples.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseCoo {
    pub rows: usize,
    pub cols: usize,
    /// Triples, not necessarily sorted.
    pub tuples: Vec<(u32, u32, f64)>,
}

impl SparseCoo {
    pub fn new(rows: usize, cols: usize) -> Self {
        SparseCoo { rows, cols, tuples: Vec::new() }
    }

    /// Append an entry (zeros are skipped).
    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        if v != 0.0 {
            self.tuples.push((r as u32, c as u32, v));
        }
    }

    pub fn nnz(&self) -> usize {
        self.tuples.len()
    }

    /// Sort triples into row-major order and convert to CSR.
    pub fn to_csr(mut self) -> SparseCsr {
        self.tuples.sort_unstable_by_key(|(r, c, _)| (*r, *c));
        let mut csr = SparseCsr::with_capacity(self.rows, self.cols, self.tuples.len());
        let mut cur_row = 0usize;
        for (r, c, v) in self.tuples {
            while cur_row <= r as usize {
                csr.row_ptr[cur_row] = csr.values.len();
                cur_row += 1;
            }
            csr.col_idx.push(c);
            csr.values.push(v);
        }
        while cur_row <= self.rows {
            csr.row_ptr[cur_row] = csr.values.len();
            cur_row += 1;
        }
        csr
    }
}

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseCsr {
    pub rows: usize,
    pub cols: usize,
    /// Length rows+1; row r occupies values[row_ptr[r]..row_ptr[r+1]].
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f64>,
}

impl SparseCsr {
    /// Empty CSR with reserved nnz capacity.
    pub fn with_capacity(rows: usize, cols: usize, nnz: usize) -> Self {
        SparseCsr {
            rows,
            cols,
            row_ptr: vec![0; rows + 1],
            col_idx: Vec::with_capacity(nnz),
            values: Vec::with_capacity(nnz),
        }
    }

    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::with_capacity(rows, cols, 0)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (col indices, values) of row r.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    /// Point lookup (binary search within the row).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&(c as u32)) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Densify.
    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let row = out.row_mut(r);
            for (c, v) in cols.iter().zip(vals) {
                row[*c as usize] = *v;
            }
        }
        out
    }

    /// Build CSR from a dense matrix, skipping zeros.
    pub fn from_dense(d: &DenseMatrix) -> SparseCsr {
        let mut csr = SparseCsr::with_capacity(d.rows, d.cols, 0);
        for r in 0..d.rows {
            csr.row_ptr[r] = csr.values.len();
            for (c, v) in d.row(r).iter().enumerate() {
                if *v != 0.0 {
                    csr.col_idx.push(c as u32);
                    csr.values.push(*v);
                }
            }
        }
        csr.row_ptr[d.rows] = csr.values.len();
        csr
    }

    /// CSR transpose via counting sort over columns — O(nnz + rows + cols).
    pub fn transpose(&self) -> SparseCsr {
        let mut out = SparseCsr::with_capacity(self.cols, self.rows, self.nnz());
        out.col_idx = vec![0; self.nnz()];
        out.values = vec![0.0; self.nnz()];
        // Count entries per output row (= input column).
        let mut counts = vec![0usize; self.cols + 1];
        for c in &self.col_idx {
            counts[*c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        out.row_ptr.copy_from_slice(&counts);
        let mut next = counts;
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                let pos = next[*c as usize];
                out.col_idx[pos] = r as u32;
                out.values[pos] = *v;
                next[*c as usize] += 1;
            }
        }
        out
    }

    /// Does this CSR carry explicit (stored) zero entries? Stored zeros
    /// corrupt `nnz()` — which the blocked backend now also uses for
    /// per-block format decisions — so value-mapping operators must
    /// [`SparseCsr::compact`] whenever a mapped value can hit 0.
    pub fn has_explicit_zeros(&self) -> bool {
        self.values.iter().any(|v| *v == 0.0)
    }

    /// Drop explicit zero entries in place, restoring the `nnz() ==
    /// values.len()` invariant. O(nnz); no-op when already compact.
    pub fn compact(&mut self) {
        if !self.has_explicit_zeros() {
            return;
        }
        let mut out = SparseCsr::with_capacity(self.rows, self.cols, self.nnz());
        for r in 0..self.rows {
            out.row_ptr[r] = out.values.len();
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                if *v != 0.0 {
                    out.col_idx.push(*c);
                    out.values.push(*v);
                }
            }
        }
        out.row_ptr[self.rows] = out.values.len();
        *self = out;
    }

    /// Row slice [rl, ru) as CSR (cheap: copies the row ranges).
    pub fn slice_rows(&self, rl: usize, ru: usize) -> SparseCsr {
        let (s, e) = (self.row_ptr[rl], self.row_ptr[ru]);
        let mut out = SparseCsr::with_capacity(ru - rl, self.cols, e - s);
        out.col_idx.extend_from_slice(&self.col_idx[s..e]);
        out.values.extend_from_slice(&self.values[s..e]);
        for r in rl..=ru {
            out.row_ptr[r - rl] = self.row_ptr[r] - s;
        }
        out
    }
}

/// Modified CSR: one growable array pair per row. Cheap single-row updates
/// (used when assembling outputs incrementally, e.g. left-indexing into a
/// sparse target or parfor result merge).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseMcsr {
    pub rows: usize,
    pub cols: usize,
    pub row_data: Vec<SparseRow>,
}

/// One sparse row: sorted column indices + values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseRow {
    pub idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl SparseRow {
    /// Set (insert/overwrite/delete-on-zero) a single entry.
    pub fn set(&mut self, c: u32, v: f64) {
        match self.idx.binary_search(&c) {
            Ok(i) => {
                if v == 0.0 {
                    self.idx.remove(i);
                    self.vals.remove(i);
                } else {
                    self.vals[i] = v;
                }
            }
            Err(i) => {
                if v != 0.0 {
                    self.idx.insert(i, c);
                    self.vals.insert(i, v);
                }
            }
        }
    }

    pub fn get(&self, c: u32) -> f64 {
        match self.idx.binary_search(&c) {
            Ok(i) => self.vals[i],
            Err(_) => 0.0,
        }
    }
}

impl SparseMcsr {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        SparseMcsr { rows, cols, row_data: vec![SparseRow::default(); rows] }
    }

    pub fn nnz(&self) -> usize {
        self.row_data.iter().map(|r| r.idx.len()).sum()
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.row_data[r].set(c as u32, v);
    }

    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.row_data[r].get(c as u32)
    }

    /// Replace a whole row from (cols, vals) slices.
    pub fn set_row(&mut self, r: usize, cols: &[u32], vals: &[f64]) {
        self.row_data[r] = SparseRow { idx: cols.to_vec(), vals: vals.to_vec() };
    }

    /// Compact into CSR.
    pub fn to_csr(&self) -> SparseCsr {
        let nnz = self.nnz();
        let mut csr = SparseCsr::with_capacity(self.rows, self.cols, nnz);
        for (r, row) in self.row_data.iter().enumerate() {
            csr.row_ptr[r] = csr.values.len();
            csr.col_idx.extend_from_slice(&row.idx);
            csr.values.extend_from_slice(&row.vals);
        }
        csr.row_ptr[self.rows] = csr.values.len();
        csr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dense() -> DenseMatrix {
        DenseMatrix::from_rows(&[
            &[1.0, 0.0, 2.0, 0.0],
            &[0.0, 0.0, 0.0, 0.0],
            &[3.0, 4.0, 0.0, 5.0],
        ])
    }

    #[test]
    fn coo_to_csr_sorted_and_unsorted() {
        let mut coo = SparseCoo::new(3, 4);
        coo.push(2, 3, 5.0);
        coo.push(0, 0, 1.0);
        coo.push(2, 0, 3.0);
        coo.push(0, 2, 2.0);
        coo.push(2, 1, 4.0);
        coo.push(1, 1, 0.0); // dropped
        let csr = coo.to_csr();
        assert_eq!(csr.nnz(), 5);
        assert_eq!(csr.to_dense(), sample_dense());
    }

    #[test]
    fn csr_from_to_dense_roundtrip() {
        let d = sample_dense();
        let csr = SparseCsr::from_dense(&d);
        assert_eq!(csr.nnz(), 5);
        assert_eq!(csr.get(2, 1), 4.0);
        assert_eq!(csr.get(1, 2), 0.0);
        assert_eq!(csr.to_dense(), d);
    }

    #[test]
    fn csr_transpose_matches_dense_transpose() {
        let d = sample_dense();
        let t = SparseCsr::from_dense(&d).transpose();
        assert_eq!(t.to_dense(), d.transpose());
        assert_eq!(t.rows, 4);
        assert_eq!(t.cols, 3);
    }

    #[test]
    fn csr_empty_rows_ok() {
        let csr = SparseCsr::zeros(5, 5);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.get(3, 3), 0.0);
        assert_eq!(csr.transpose().nnz(), 0);
    }

    #[test]
    fn csr_slice_rows() {
        let csr = SparseCsr::from_dense(&sample_dense());
        let s = csr.slice_rows(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.to_dense(), sample_dense().slice(1, 3, 0, 4).unwrap());
    }

    #[test]
    fn compact_drops_explicit_zeros() {
        let mut csr = SparseCsr::from_dense(&sample_dense());
        // Zero out one stored entry in place (what a careless value map
        // would do) and verify compact() restores the nnz invariant.
        csr.values[1] = 0.0;
        assert!(csr.has_explicit_zeros());
        assert_eq!(csr.nnz(), 5, "stored zero still counted");
        let dense = csr.to_dense();
        csr.compact();
        assert!(!csr.has_explicit_zeros());
        assert_eq!(csr.nnz(), 4);
        assert_eq!(csr.to_dense(), dense, "compaction preserves values");
        // Idempotent.
        let before = csr.clone();
        csr.compact();
        assert_eq!(csr, before);
    }

    #[test]
    fn mcsr_set_get_delete() {
        let mut m = SparseMcsr::zeros(2, 4);
        m.set(0, 2, 7.0);
        m.set(0, 1, 3.0);
        m.set(1, 0, 1.0);
        assert_eq!(m.get(0, 2), 7.0);
        assert_eq!(m.nnz(), 3);
        m.set(0, 2, 0.0); // delete
        assert_eq!(m.get(0, 2), 0.0);
        assert_eq!(m.nnz(), 2);
        m.set(0, 1, 9.0); // overwrite
        assert_eq!(m.get(0, 1), 9.0);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn mcsr_to_csr() {
        let mut m = SparseMcsr::zeros(3, 4);
        m.set(0, 0, 1.0);
        m.set(0, 2, 2.0);
        m.set(2, 0, 3.0);
        m.set(2, 1, 4.0);
        m.set(2, 3, 5.0);
        assert_eq!(m.to_csr().to_dense(), sample_dense());
    }
}
