//! Dense row-major matrix block (the CP dense physical representation).

use crate::util::error::{DmlError, Result};

/// Dense, row-major, f64 matrix. DML's value type is `double`, matching
/// SystemML's `MatrixBlock` dense layout.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    /// Allocate a zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant-filled matrix.
    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        DenseMatrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from a row-major vec; length must equal rows*cols.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(DmlError::rt(format!(
                "dense from_vec: {}x{} needs {} values, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(DenseMatrix { rows, cols, data })
    }

    /// Build from nested rows (used by tests).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Count non-zero entries (exact).
    pub fn count_nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Transpose with cache-friendly tiling.
    pub fn transpose(&self) -> DenseMatrix {
        const TILE: usize = 32;
        let mut out = DenseMatrix::zeros(self.cols, self.rows);
        for rb in (0..self.rows).step_by(TILE) {
            for cb in (0..self.cols).step_by(TILE) {
                let rmax = (rb + TILE).min(self.rows);
                let cmax = (cb + TILE).min(self.cols);
                for r in rb..rmax {
                    for c in cb..cmax {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Extract the sub-matrix rows [rl, ru) × cols [cl, cu) (0-based, exclusive).
    pub fn slice(&self, rl: usize, ru: usize, cl: usize, cu: usize) -> Result<DenseMatrix> {
        if ru > self.rows || cu > self.cols || rl > ru || cl > cu {
            return Err(DmlError::rt(format!(
                "slice [{rl}:{ru},{cl}:{cu}] out of bounds for {}x{}",
                self.rows, self.cols
            )));
        }
        let mut out = DenseMatrix::zeros(ru - rl, cu - cl);
        for (or, r) in (rl..ru).enumerate() {
            let src = &self.data[r * self.cols + cl..r * self.cols + cu];
            out.row_mut(or).copy_from_slice(src);
        }
        Ok(out)
    }

    /// In-place left-indexing assignment: self[rl.., cl..] = src.
    pub fn assign(&mut self, rl: usize, cl: usize, src: &DenseMatrix) -> Result<()> {
        if rl + src.rows > self.rows || cl + src.cols > self.cols {
            return Err(DmlError::rt(format!(
                "assign of {}x{} at ({rl},{cl}) out of bounds for {}x{}",
                src.rows, src.cols, self.rows, self.cols
            )));
        }
        for r in 0..src.rows {
            let dst = &mut self.data[(rl + r) * self.cols + cl..(rl + r) * self.cols + cl + src.cols];
            dst.copy_from_slice(src.row(r));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_len() {
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        let m = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = DenseMatrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_large_tiled() {
        let mut m = DenseMatrix::zeros(70, 45);
        for r in 0..70 {
            for c in 0..45 {
                m.set(r, c, (r * 1000 + c) as f64);
            }
        }
        let t = m.transpose();
        for r in 0..70 {
            for c in 0..45 {
                assert_eq!(t.get(c, r), m.get(r, c));
            }
        }
    }

    #[test]
    fn slice_and_assign() {
        let m = DenseMatrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0], &[7.0, 8.0, 9.0]]);
        let s = m.slice(1, 3, 0, 2).unwrap();
        assert_eq!(s, DenseMatrix::from_rows(&[&[4.0, 5.0], &[7.0, 8.0]]));
        let mut m2 = DenseMatrix::zeros(3, 3);
        m2.assign(1, 1, &DenseMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]])).unwrap();
        assert_eq!(m2.get(2, 2), 4.0);
        assert_eq!(m2.get(0, 0), 0.0);
        assert!(m.slice(0, 4, 0, 1).is_err());
    }

    #[test]
    fn nnz_counts_zeros() {
        let m = DenseMatrix::from_rows(&[&[0.0, 1.0], &[2.0, 0.0]]);
        assert_eq!(m.count_nnz(), 2);
    }
}
