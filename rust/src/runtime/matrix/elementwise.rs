//! Elementwise (cell-wise) binary, unary and scalar operators, including
//! row/column vector broadcasting — the cell-op subsystem of the runtime.
//!
//! Sparse-safety drives the physical operator choice, exactly as in
//! SystemML: a sparse-safe op (op(0,0)=0, e.g. `*`) over sparse inputs
//! touches only non-zeros; non-sparse-safe ops (e.g. `+ 1`) densify.

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::sparse::{SparseCoo, SparseCsr};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// Binary cell operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Min,
    Max,
    /// Comparison ops produce 0/1 matrices.
    Eq,
    Neq,
    Lt,
    Le,
    Gt,
    Ge,
    /// Logical ops treat nonzero as true.
    And,
    Or,
    /// Integer-style modulus / integer division (DML %% and %/%).
    Mod,
    IntDiv,
}

impl BinOp {
    /// Apply to two scalars.
    #[inline]
    pub fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Pow => a.powf(b),
            BinOp::Min => a.min(b),
            BinOp::Max => a.max(b),
            BinOp::Eq => (a == b) as i32 as f64,
            BinOp::Neq => (a != b) as i32 as f64,
            BinOp::Lt => (a < b) as i32 as f64,
            BinOp::Le => (a <= b) as i32 as f64,
            BinOp::Gt => (a > b) as i32 as f64,
            BinOp::Ge => (a >= b) as i32 as f64,
            BinOp::And => ((a != 0.0) && (b != 0.0)) as i32 as f64,
            BinOp::Or => ((a != 0.0) || (b != 0.0)) as i32 as f64,
            BinOp::Mod => a - (a / b).floor() * b,
            BinOp::IntDiv => (a / b).floor(),
        }
    }

    /// Is op(0, 0) == 0 (so an all-zero cell stays zero)?
    pub fn sparse_safe(self) -> bool {
        self.apply(0.0, 0.0) == 0.0
    }

    /// Is op(x, 0) == 0 for all x (true for Mul, And)? Enables
    /// intersection-style sparse-sparse execution.
    pub fn zero_absorbing(self) -> bool {
        matches!(self, BinOp::Mul | BinOp::And)
    }
}

/// Unary cell operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnaryOp {
    Exp,
    Log,
    Sqrt,
    Abs,
    Round,
    Floor,
    Ceil,
    Sign,
    Neg,
    Not,
    Sin,
    Cos,
    Tan,
    Sigmoid,
}

impl UnaryOp {
    /// Map a DML cellwise unary builtin name to its operator. The single
    /// source of truth shared by the interpreter's builtin dispatch and
    /// the planner's blocked-ness dataflow — adding a builtin here keeps
    /// both in sync.
    pub fn from_builtin_name(name: &str) -> Option<UnaryOp> {
        Some(match name {
            "exp" => UnaryOp::Exp,
            "log" => UnaryOp::Log,
            "sqrt" => UnaryOp::Sqrt,
            "abs" => UnaryOp::Abs,
            "round" => UnaryOp::Round,
            "floor" => UnaryOp::Floor,
            "ceil" | "ceiling" => UnaryOp::Ceil,
            "sign" => UnaryOp::Sign,
            "sin" => UnaryOp::Sin,
            "cos" => UnaryOp::Cos,
            "tan" => UnaryOp::Tan,
            "sigmoid" => UnaryOp::Sigmoid,
            _ => return None,
        })
    }

    #[inline]
    pub fn apply(self, a: f64) -> f64 {
        match self {
            UnaryOp::Exp => a.exp(),
            UnaryOp::Log => a.ln(),
            UnaryOp::Sqrt => a.sqrt(),
            UnaryOp::Abs => a.abs(),
            UnaryOp::Round => a.round(),
            UnaryOp::Floor => a.floor(),
            UnaryOp::Ceil => a.ceil(),
            UnaryOp::Sign => {
                if a > 0.0 {
                    1.0
                } else if a < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
            UnaryOp::Neg => -a,
            UnaryOp::Not => (a == 0.0) as i32 as f64,
            UnaryOp::Sin => a.sin(),
            UnaryOp::Cos => a.cos(),
            UnaryOp::Tan => a.tan(),
            UnaryOp::Sigmoid => 1.0 / (1.0 + (-a).exp()),
        }
    }

    /// op(0) == 0 → sparse inputs stay sparse.
    pub fn sparse_safe(self) -> bool {
        self.apply(0.0) == 0.0
    }
}

/// How the rhs broadcasts against the lhs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Broadcast {
    /// Same shape.
    Cell,
    /// rhs is a column vector (n×1) matched against lhs rows.
    ColVector,
    /// rhs is a row vector (1×m) matched against lhs cols.
    RowVector,
    /// rhs is 1×1.
    Scalar,
}

fn broadcast_kind(lhs: &Matrix, rhs: &Matrix, op: &str) -> Result<Broadcast> {
    let ((lr, lc), (rr, rc)) = (lhs.shape(), rhs.shape());
    if (rr, rc) == (1, 1) && (lr, lc) != (1, 1) {
        Ok(Broadcast::Scalar)
    } else if lr == rr && lc == rc {
        Ok(Broadcast::Cell)
    } else if lr == rr && rc == 1 {
        Ok(Broadcast::ColVector)
    } else if lc == rc && rr == 1 {
        Ok(Broadcast::RowVector)
    } else {
        Err(DmlError::DimMismatch {
            op: op.to_string(),
            lhs_rows: lr,
            lhs_cols: lc,
            rhs_rows: rr,
            rhs_cols: rc,
        })
    }
}

/// Matrix ⊕ matrix with broadcasting (matches DML cell-op semantics).
pub fn binary(lhs: &Matrix, rhs: &Matrix, op: BinOp) -> Result<Matrix> {
    let kind = broadcast_kind(lhs, rhs, &format!("{op:?}"))?;
    metrics::global().add_flops(lhs.len() as u64);
    let out = match kind {
        Broadcast::Scalar => return scalar_op(lhs, rhs.get(0, 0), op, false),
        Broadcast::Cell => binary_cell(lhs, rhs, op),
        Broadcast::ColVector | Broadcast::RowVector => {
            // Vector broadcasts densify (outputs are usually dense anyway).
            let ld = lhs.to_dense();
            let mut out = DenseMatrix::zeros(ld.rows, ld.cols);
            match kind {
                Broadcast::ColVector => {
                    for r in 0..ld.rows {
                        let v = rhs.get(r, 0);
                        let src = ld.row(r);
                        let dst = out.row_mut(r);
                        for c in 0..src.len() {
                            dst[c] = op.apply(src[c], v);
                        }
                    }
                }
                Broadcast::RowVector => {
                    let rv: Vec<f64> = (0..ld.cols).map(|c| rhs.get(0, c)).collect();
                    for r in 0..ld.rows {
                        let src = ld.row(r);
                        let dst = out.row_mut(r);
                        for c in 0..src.len() {
                            dst[c] = op.apply(src[c], rv[c]);
                        }
                    }
                }
                _ => unreachable!(),
            }
            Matrix::Dense(out)
        }
    };
    Ok(out.examine_and_convert())
}

/// Same-shape cell op with sparse-aware physical operators.
fn binary_cell(lhs: &Matrix, rhs: &Matrix, op: BinOp) -> Matrix {
    match (lhs, rhs) {
        (Matrix::Sparse(a), Matrix::Sparse(b)) if op.zero_absorbing() => {
            metrics::global().sparse_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            sparse_sparse_intersect(a, b, op)
        }
        (Matrix::Sparse(a), Matrix::Sparse(b)) if op.sparse_safe() => {
            metrics::global().sparse_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            sparse_sparse_union(a, b, op)
        }
        _ => {
            metrics::global().dense_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let a = lhs.to_dense();
            let b = rhs.to_dense();
            let mut out = DenseMatrix::zeros(a.rows, a.cols);
            for i in 0..a.data.len() {
                out.data[i] = op.apply(a.data[i], b.data[i]);
            }
            Matrix::Dense(out)
        }
    }
}

/// Sparse ∩ sparse for zero-absorbing ops (Mul/And): merge-join per row.
fn sparse_sparse_intersect(a: &SparseCsr, b: &SparseCsr, op: BinOp) -> Matrix {
    let mut out = SparseCoo::new(a.rows, a.cols);
    for r in 0..a.rows {
        let (ac, av) = a.row(r);
        let (bc, bv) = b.row(r);
        let (mut i, mut j) = (0, 0);
        while i < ac.len() && j < bc.len() {
            match ac[i].cmp(&bc[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    out.push(r, ac[i] as usize, op.apply(av[i], bv[j]));
                    i += 1;
                    j += 1;
                }
            }
        }
    }
    Matrix::Sparse(out.to_csr())
}

/// Sparse ∪ sparse for sparse-safe ops (Add/Sub/...): merge per row.
fn sparse_sparse_union(a: &SparseCsr, b: &SparseCsr, op: BinOp) -> Matrix {
    let mut out = SparseCoo::new(a.rows, a.cols);
    for r in 0..a.rows {
        let (ac, av) = a.row(r);
        let (bc, bv) = b.row(r);
        let (mut i, mut j) = (0, 0);
        while i < ac.len() || j < bc.len() {
            if j >= bc.len() || (i < ac.len() && ac[i] < bc[j]) {
                out.push(r, ac[i] as usize, op.apply(av[i], 0.0));
                i += 1;
            } else if i >= ac.len() || bc[j] < ac[i] {
                out.push(r, bc[j] as usize, op.apply(0.0, bv[j]));
                j += 1;
            } else {
                out.push(r, ac[i] as usize, op.apply(av[i], bv[j]));
                i += 1;
                j += 1;
            }
        }
    }
    Matrix::Sparse(out.to_csr())
}

/// Matrix ⊕ scalar. `swapped` means the scalar is the lhs (e.g. `2 - X`).
pub fn scalar_op(m: &Matrix, s: f64, op: BinOp, swapped: bool) -> Result<Matrix> {
    metrics::global().add_flops(m.len() as u64);
    let f = |x: f64| if swapped { op.apply(s, x) } else { op.apply(x, s) };
    // Sparse-safe iff f(0) == 0.
    let out = match m {
        Matrix::Sparse(sp) if f(0.0) == 0.0 => {
            let mut out = sp.clone();
            for v in out.values.iter_mut() {
                *v = f(*v);
            }
            // f may map nonzeros to zero (e.g. X * 0): recompact via COO.
            if out.values.iter().any(|v| *v == 0.0) {
                let mut coo = SparseCoo::new(out.rows, out.cols);
                for r in 0..out.rows {
                    let (cols, vals) = out.row(r);
                    for (c, v) in cols.iter().zip(vals) {
                        coo.push(r, *c as usize, *v);
                    }
                }
                Matrix::Sparse(coo.to_csr())
            } else {
                Matrix::Sparse(out)
            }
        }
        _ => {
            let d = m.to_dense();
            let mut out = DenseMatrix::zeros(d.rows, d.cols);
            for i in 0..d.data.len() {
                out.data[i] = f(d.data[i]);
            }
            Matrix::Dense(out)
        }
    };
    Ok(out.examine_and_convert())
}

/// Unary cell op.
pub fn unary(m: &Matrix, op: UnaryOp) -> Matrix {
    metrics::global().add_flops(m.len() as u64);
    let out = match m {
        Matrix::Sparse(sp) if op.sparse_safe() => {
            let mut out = sp.clone();
            for v in out.values.iter_mut() {
                *v = op.apply(*v);
            }
            // A sparse-safe op can still map a *nonzero* to zero (e.g.
            // round(0.4)); drop those entries so nnz stays exact.
            out.compact();
            Matrix::Sparse(out)
        }
        _ => {
            let d = m.to_dense();
            let mut out = DenseMatrix::zeros(d.rows, d.cols);
            for i in 0..d.data.len() {
                out.data[i] = op.apply(d.data[i]);
            }
            Matrix::Dense(out)
        }
    };
    out.examine_and_convert()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense(rows: &[&[f64]]) -> Matrix {
        Matrix::from_rows(rows)
    }

    #[test]
    fn add_cell() {
        let a = dense(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = dense(&[&[10.0, 20.0], &[30.0, 40.0]]);
        let c = binary(&a, &b, BinOp::Add).unwrap();
        assert_eq!(c, dense(&[&[11.0, 22.0], &[33.0, 44.0]]));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let a = dense(&[&[1.0, 2.0]]);
        let b = dense(&[&[1.0], &[2.0], &[3.0]]);
        assert!(binary(&a, &b, BinOp::Add).is_err());
    }

    #[test]
    fn broadcast_col_and_row_vectors() {
        let a = dense(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let col = dense(&[&[10.0], &[20.0]]);
        let row = dense(&[&[100.0, 200.0]]);
        assert_eq!(binary(&a, &col, BinOp::Add).unwrap(), dense(&[&[11.0, 12.0], &[23.0, 24.0]]));
        assert_eq!(
            binary(&a, &row, BinOp::Add).unwrap(),
            dense(&[&[101.0, 202.0], &[103.0, 204.0]])
        );
    }

    #[test]
    fn broadcast_scalar_1x1() {
        let a = dense(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let s = Matrix::scalar(5.0);
        assert_eq!(binary(&a, &s, BinOp::Mul).unwrap(), dense(&[&[5.0, 10.0], &[15.0, 20.0]]));
    }

    #[test]
    fn sparse_sparse_mul_intersection() {
        let a = dense(&[&[1.0, 0.0, 2.0], &[0.0, 3.0, 0.0]]).into_sparse_format();
        let b = dense(&[&[4.0, 5.0, 0.0], &[0.0, 6.0, 7.0]]).into_sparse_format();
        let c = binary(&a, &b, BinOp::Mul).unwrap();
        assert_eq!(c, dense(&[&[4.0, 0.0, 0.0], &[0.0, 18.0, 0.0]]));
    }

    #[test]
    fn sparse_sparse_add_union() {
        let a = dense(&[&[1.0, 0.0], &[0.0, 2.0]]).into_sparse_format();
        let b = dense(&[&[0.0, 3.0], &[0.0, 4.0]]).into_sparse_format();
        let c = binary(&a, &b, BinOp::Add).unwrap();
        assert_eq!(c, dense(&[&[1.0, 3.0], &[0.0, 6.0]]));
    }

    #[test]
    fn non_sparse_safe_densifies() {
        let a = dense(&[&[0.0, 1.0], &[0.0, 0.0]]).into_sparse_format();
        let c = scalar_op(&a, 1.0, BinOp::Add, false).unwrap();
        assert_eq!(c.get(0, 0), 1.0);
        assert_eq!(c.get(1, 1), 1.0);
    }

    #[test]
    fn scalar_swapped() {
        let a = dense(&[&[1.0, 2.0]]);
        let c = scalar_op(&a, 10.0, BinOp::Sub, true).unwrap(); // 10 - X
        assert_eq!(c, dense(&[&[9.0, 8.0]]));
    }

    #[test]
    fn scalar_mul_zero_recompacts() {
        let a = dense(&[&[1.0, 0.0], &[0.0, 2.0]]).into_sparse_format();
        let c = scalar_op(&a, 0.0, BinOp::Mul, false).unwrap();
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn comparisons_produce_indicators() {
        let a = dense(&[&[1.0, 5.0], &[3.0, 2.0]]);
        let c = scalar_op(&a, 2.5, BinOp::Gt, false).unwrap();
        assert_eq!(c, dense(&[&[0.0, 1.0], &[1.0, 0.0]]));
    }

    #[test]
    fn unary_sparse_safe_stays_sparse() {
        let a = dense(&[&[4.0, 0.0], &[0.0, 9.0]]).into_sparse_format();
        let c = unary(&a, UnaryOp::Sqrt);
        assert_eq!(c, dense(&[&[2.0, 0.0], &[0.0, 3.0]]));
    }

    #[test]
    fn unary_zero_producing_recompacts_sparse() {
        // round maps 0.4 → 0 while staying sparse-safe: the output must
        // not carry explicit zeros (nnz is load-bearing for format
        // decisions in the blocked backend).
        let a = dense(&[&[0.4, 0.0, 1.6], &[0.0, 0.3, 0.0]]).into_sparse_format();
        let c = unary(&a, UnaryOp::Round);
        assert_eq!(c, dense(&[&[0.0, 0.0, 2.0], &[0.0, 0.0, 0.0]]));
        assert_eq!(c.nnz(), 1, "explicit zeros must be compacted away");
        // sign() of a negative nonzero stays nonzero; sign(0) unreached.
        let s = unary(&a, UnaryOp::Sign);
        assert_eq!(s.nnz(), 3);
    }

    #[test]
    fn unary_exp_densifies() {
        let a = dense(&[&[0.0, 1.0]]);
        let c = unary(&a, UnaryOp::Exp);
        assert!((c.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((c.get(0, 1) - std::f64::consts::E).abs() < 1e-12);
    }

    #[test]
    fn sigmoid_and_relu_patterns() {
        let a = dense(&[&[-1.0, 0.0, 1.0]]);
        let s = unary(&a, UnaryOp::Sigmoid);
        assert!((s.get(0, 1) - 0.5).abs() < 1e-12);
        // relu = max(X, 0)
        let r = scalar_op(&a, 0.0, BinOp::Max, false).unwrap();
        assert_eq!(r, dense(&[&[0.0, 0.0, 1.0]]));
    }

    #[test]
    fn mod_intdiv_match_dml_semantics() {
        assert_eq!(BinOp::Mod.apply(7.0, 3.0), 1.0);
        assert_eq!(BinOp::Mod.apply(-7.0, 3.0), 2.0); // R-style mod
        assert_eq!(BinOp::IntDiv.apply(7.0, 2.0), 3.0);
    }
}
