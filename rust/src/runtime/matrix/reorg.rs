//! Reorganization ops: transpose, reshape, rev, indexing (right/left),
//! cbind/rbind, diag, outer, table, removeEmpty.

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::sparse::{SparseCoo, SparseMcsr};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::runtime::matrix::elementwise::BinOp;

/// `t(X)` with a format-preserving physical operator.
pub fn transpose(m: &Matrix) -> Matrix {
    match m {
        Matrix::Dense(d) => Matrix::Dense(d.transpose()),
        Matrix::Sparse(s) => Matrix::Sparse(s.transpose()),
    }
}

/// Row-major reshape (DML: matrix(X, rows=r, cols=c)).
pub fn reshape(m: &Matrix, rows: usize, cols: usize) -> Result<Matrix> {
    if rows * cols != m.len() {
        return Err(DmlError::rt(format!(
            "reshape: cannot reshape {}x{} into {rows}x{cols}",
            m.rows(),
            m.cols()
        )));
    }
    match m {
        Matrix::Dense(d) => {
            Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, d.data.clone())?))
        }
        Matrix::Sparse(s) => {
            let oc = m.cols();
            let mut coo = SparseCoo::new(rows, cols);
            for r in 0..s.rows {
                let (idx, vals) = s.row(r);
                for (c, v) in idx.iter().zip(vals) {
                    let linear = r * oc + *c as usize;
                    coo.push(linear / cols, linear % cols, *v);
                }
            }
            Ok(Matrix::Sparse(coo.to_csr()))
        }
    }
}

/// Reverse rows (DML rev).
pub fn rev(m: &Matrix) -> Matrix {
    let d = m.to_dense();
    let mut out = DenseMatrix::zeros(d.rows, d.cols);
    for r in 0..d.rows {
        out.row_mut(r).copy_from_slice(d.row(d.rows - 1 - r));
    }
    Matrix::Dense(out).examine_and_convert()
}

/// The canonical right-indexing range error. Shared by the CP kernel and
/// the blocked (distributed) slice so both paths fail identically — the
/// blocked path checks handle metadata and raises this *before* any
/// force/collect.
pub fn slice_range_error(
    rl: usize,
    ru: usize,
    cl: usize,
    cu: usize,
    rows: usize,
    cols: usize,
) -> DmlError {
    DmlError::rt(format!(
        "index [{}:{},{}:{}] out of range for {rows}x{cols} matrix",
        rl + 1,
        ru,
        cl + 1,
        cu
    ))
}

/// The canonical left-indexing bounds error (shared CP/blocked, see
/// [`slice_range_error`]).
pub fn left_index_range_error(
    src_rows: usize,
    src_cols: usize,
    rl: usize,
    cl: usize,
    rows: usize,
    cols: usize,
) -> DmlError {
    DmlError::rt(format!(
        "left-index of {src_rows}x{src_cols} at ({},{}) exceeds {rows}x{cols}",
        rl + 1,
        cl + 1
    ))
}

/// Right indexing X[rl:ru, cl:cu] — 0-based, half-open (callers translate
/// DML's 1-based inclusive ranges).
pub fn slice(m: &Matrix, rl: usize, ru: usize, cl: usize, cu: usize) -> Result<Matrix> {
    if ru > m.rows() || cu > m.cols() || rl >= ru || cl >= cu {
        return Err(slice_range_error(rl, ru, cl, cu, m.rows(), m.cols()));
    }
    match m {
        Matrix::Dense(d) => Ok(Matrix::Dense(d.slice(rl, ru, cl, cu)?)),
        Matrix::Sparse(s) => {
            if cl == 0 && cu == m.cols() {
                Ok(Matrix::Sparse(s.slice_rows(rl, ru)))
            } else {
                let mut coo = SparseCoo::new(ru - rl, cu - cl);
                for r in rl..ru {
                    let (idx, vals) = s.row(r);
                    for (c, v) in idx.iter().zip(vals) {
                        let c = *c as usize;
                        if c >= cl && c < cu {
                            coo.push(r - rl, c - cl, *v);
                        }
                    }
                }
                Ok(Matrix::Sparse(coo.to_csr()))
            }
        }
    }
}

/// Left indexing: returns a copy of `target` with `src` written at
/// (rl, cl). DML semantics: X[rl:ru, cl:cu] = src.
pub fn left_index(target: &Matrix, rl: usize, cl: usize, src: &Matrix) -> Result<Matrix> {
    if rl + src.rows() > target.rows() || cl + src.cols() > target.cols() {
        return Err(left_index_range_error(
            src.rows(),
            src.cols(),
            rl,
            cl,
            target.rows(),
            target.cols(),
        ));
    }
    match target {
        Matrix::Dense(d) => {
            let mut out = d.clone();
            out.assign(rl, cl, &src.to_dense())?;
            Ok(Matrix::Dense(out))
        }
        Matrix::Sparse(s) => {
            // MCSR supports cheap row updates — the paper's modified-CSR use.
            let mut m = SparseMcsr::zeros(s.rows, s.cols);
            for r in 0..s.rows {
                let (idx, vals) = s.row(r);
                m.set_row(r, idx, vals);
            }
            let sd = src.to_dense();
            for r in 0..sd.rows {
                for c in 0..sd.cols {
                    m.set(rl + r, cl + c, sd.get(r, c));
                }
            }
            Ok(Matrix::Sparse(m.to_csr()).examine_and_convert())
        }
    }
}

/// Column concatenation (DML cbind).
pub fn cbind(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.rows() != b.rows() {
        return Err(DmlError::rt(format!(
            "cbind: row mismatch {} vs {}",
            a.rows(),
            b.rows()
        )));
    }
    let (ad, bd) = (a.to_dense(), b.to_dense());
    let mut out = DenseMatrix::zeros(ad.rows, ad.cols + bd.cols);
    for r in 0..ad.rows {
        out.row_mut(r)[..ad.cols].copy_from_slice(ad.row(r));
        out.row_mut(r)[ad.cols..].copy_from_slice(bd.row(r));
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// Row concatenation (DML rbind).
pub fn rbind(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.cols() {
        return Err(DmlError::rt(format!(
            "rbind: col mismatch {} vs {}",
            a.cols(),
            b.cols()
        )));
    }
    let (ad, bd) = (a.to_dense(), b.to_dense());
    let mut data = ad.data;
    data.extend_from_slice(&bd.data);
    Ok(Matrix::Dense(DenseMatrix::from_vec(ad.rows + bd.rows, ad.cols, data)?)
        .examine_and_convert())
}

/// diag: vector→diagonal matrix, or matrix→diagonal column vector.
pub fn diag(m: &Matrix) -> Matrix {
    let (r, c) = m.shape();
    if c == 1 {
        let mut coo = SparseCoo::new(r, r);
        for i in 0..r {
            coo.push(i, i, m.get(i, 0));
        }
        Matrix::Sparse(coo.to_csr()).examine_and_convert()
    } else {
        let n = r.min(c);
        let mut out = DenseMatrix::zeros(n, 1);
        for i in 0..n {
            out.data[i] = m.get(i, i);
        }
        Matrix::Dense(out)
    }
}

/// outer(u, v, op): u is n×1, v is 1×m → n×m.
pub fn outer(u: &Matrix, v: &Matrix, op: BinOp) -> Result<Matrix> {
    if u.cols() != 1 || v.rows() != 1 {
        return Err(DmlError::rt("outer: requires column vector and row vector".to_string()));
    }
    let (n, m) = (u.rows(), v.cols());
    let mut out = DenseMatrix::zeros(n, m);
    for i in 0..n {
        let uv = u.get(i, 0);
        let row = out.row_mut(i);
        for j in 0..m {
            row[j] = op.apply(uv, v.get(0, j));
        }
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// table(i, j): contingency table of two column vectors of 1-based indices
/// (DML's one-hot building block: table(seq(1,n), y, n, k)).
pub fn table(i: &Matrix, j: &Matrix, out_rows: usize, out_cols: usize) -> Result<Matrix> {
    if i.cols() != 1 || j.cols() != 1 || i.rows() != j.rows() {
        return Err(DmlError::rt("table: arguments must be equal-length column vectors"));
    }
    let mut coo = SparseCoo::new(out_rows, out_cols);
    let mut m = SparseMcsr::zeros(out_rows, out_cols);
    for r in 0..i.rows() {
        let ri = i.get(r, 0).round() as isize - 1;
        let ci = j.get(r, 0).round() as isize - 1;
        if ri < 0 || ci < 0 {
            return Err(DmlError::rt("table: indices must be >= 1"));
        }
        let (ri, ci) = (ri as usize, ci as usize);
        if ri < out_rows && ci < out_cols {
            m.set(ri, ci, m.get(ri, ci) + 1.0);
        }
    }
    for r in 0..out_rows {
        let row = &m.row_data[r];
        for (c, v) in row.idx.iter().zip(&row.vals) {
            coo.push(r, *c as usize, *v);
        }
    }
    Ok(Matrix::Sparse(coo.to_csr()).examine_and_convert())
}

/// removeEmpty(target, margin="rows"): drop all-zero rows (or columns).
pub fn remove_empty(m: &Matrix, rows_margin: bool) -> Matrix {
    if rows_margin {
        let keep: Vec<usize> = (0..m.rows())
            .filter(|r| (0..m.cols()).any(|c| m.get(*r, c) != 0.0))
            .collect();
        if keep.is_empty() {
            return Matrix::zeros(1, m.cols());
        }
        let d = m.to_dense();
        let mut out = DenseMatrix::zeros(keep.len(), m.cols());
        for (or, r) in keep.iter().enumerate() {
            out.row_mut(or).copy_from_slice(d.row(*r));
        }
        Matrix::Dense(out).examine_and_convert()
    } else {
        let t = transpose(m);
        transpose(&remove_empty(&t, true))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]])
    }

    #[test]
    fn transpose_formats() {
        let d = m();
        let s = d.clone().into_sparse_format();
        assert_eq!(transpose(&d), transpose(&s));
        assert_eq!(transpose(&d).shape(), (3, 2));
    }

    #[test]
    fn reshape_row_major() {
        let r = reshape(&m(), 3, 2).unwrap();
        assert_eq!(r, Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]));
        assert!(reshape(&m(), 4, 2).is_err());
        // Sparse reshape agrees with dense.
        let s = m().into_sparse_format();
        assert_eq!(reshape(&s, 6, 1).unwrap(), reshape(&m(), 6, 1).unwrap());
    }

    #[test]
    fn rev_reverses_rows() {
        assert_eq!(rev(&m()), Matrix::from_rows(&[&[4.0, 5.0, 6.0], &[1.0, 2.0, 3.0]]));
    }

    #[test]
    fn slice_dense_sparse_agree() {
        let d = Matrix::from_rows(&[&[1.0, 0.0, 2.0], &[0.0, 3.0, 0.0], &[4.0, 0.0, 5.0]]);
        let s = d.clone().into_sparse_format();
        assert_eq!(slice(&d, 0, 2, 1, 3).unwrap(), slice(&s, 0, 2, 1, 3).unwrap());
        assert_eq!(slice(&s, 1, 3, 0, 3).unwrap(), slice(&d, 1, 3, 0, 3).unwrap());
        assert!(slice(&d, 0, 4, 0, 1).is_err());
    }

    #[test]
    fn left_index_dense_and_sparse() {
        let base = Matrix::zeros(64, 64); // sparse by construction
        let patch = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let out = left_index(&base, 10, 20, &patch).unwrap();
        assert_eq!(out.get(10, 20), 1.0);
        assert_eq!(out.get(11, 21), 4.0);
        assert_eq!(out.nnz(), 4);

        let based = Matrix::filled(8, 8, 1.0);
        let out2 = left_index(&based, 0, 0, &patch).unwrap();
        assert_eq!(out2.get(0, 1), 2.0);
        assert_eq!(out2.get(7, 7), 1.0);
        assert!(left_index(&patch, 1, 1, &based).is_err());
    }

    #[test]
    fn cbind_rbind() {
        let a = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let b = Matrix::from_rows(&[&[3.0], &[4.0]]);
        assert_eq!(cbind(&a, &b).unwrap(), Matrix::from_rows(&[&[1.0, 3.0], &[2.0, 4.0]]));
        assert_eq!(
            rbind(&a, &b).unwrap(),
            Matrix::from_rows(&[&[1.0], &[2.0], &[3.0], &[4.0]])
        );
        assert!(cbind(&a, &Matrix::zeros(3, 1)).is_err());
        assert!(rbind(&a, &Matrix::zeros(1, 2)).is_err());
    }

    #[test]
    fn diag_both_directions() {
        let v = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let d = diag(&v);
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(1, 1), 2.0);
        assert_eq!(d.get(0, 1), 0.0);
        let back = diag(&d);
        assert_eq!(back, v);
    }

    #[test]
    fn outer_product() {
        let u = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let v = Matrix::from_rows(&[&[3.0, 4.0]]);
        assert_eq!(
            outer(&u, &v, BinOp::Mul).unwrap(),
            Matrix::from_rows(&[&[3.0, 4.0], &[6.0, 8.0]])
        );
    }

    #[test]
    fn table_builds_one_hot() {
        // one-hot of labels y = [2, 1, 2] over 3 classes
        let i = Matrix::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        let y = Matrix::from_rows(&[&[2.0], &[1.0], &[2.0]]);
        let t = table(&i, &y, 3, 3).unwrap();
        assert_eq!(
            t,
            Matrix::from_rows(&[&[0.0, 1.0, 0.0], &[1.0, 0.0, 0.0], &[0.0, 1.0, 0.0]])
        );
    }

    #[test]
    fn remove_empty_rows_cols() {
        let x = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0], &[0.0, 0.0]]);
        assert_eq!(remove_empty(&x, true), Matrix::from_rows(&[&[1.0, 0.0]]));
        assert_eq!(remove_empty(&x, false), Matrix::from_rows(&[&[0.0], &[1.0], &[0.0]]));
    }
}
