//! Linear system solve (DML builtin `solve(A, b)`): Gaussian elimination
//! with partial pivoting. Also exposes `inverse` via repeated solve.

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// Solve A x = B for x, where A is n×n and B is n×m.
pub fn solve(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    if a.cols() != n {
        return Err(DmlError::rt(format!("solve: A must be square, got {}x{}", n, a.cols())));
    }
    if b.rows() != n {
        return Err(DmlError::rt(format!(
            "solve: dimension mismatch A {}x{} vs b {}x{}",
            n,
            n,
            b.rows(),
            b.cols()
        )));
    }
    let m = b.cols();
    metrics::global().add_flops((2 * n * n * n / 3 + n * n * m) as u64);
    let mut lu = a.to_dense();
    let mut x = b.to_dense();
    for col in 0..n {
        // Partial pivot.
        let mut piv = col;
        let mut max = lu.get(col, col).abs();
        for r in (col + 1)..n {
            let v = lu.get(r, col).abs();
            if v > max {
                max = v;
                piv = r;
            }
        }
        if max < 1e-300 {
            return Err(DmlError::rt("solve: matrix is singular"));
        }
        if piv != col {
            for c in 0..n {
                let t = lu.get(col, c);
                lu.set(col, c, lu.get(piv, c));
                lu.set(piv, c, t);
            }
            for c in 0..m {
                let t = x.get(col, c);
                x.set(col, c, x.get(piv, c));
                x.set(piv, c, t);
            }
        }
        // Eliminate below.
        let d = lu.get(col, col);
        for r in (col + 1)..n {
            let f = lu.get(r, col) / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                let v = lu.get(r, c) - f * lu.get(col, c);
                lu.set(r, c, v);
            }
            for c in 0..m {
                let v = x.get(r, c) - f * x.get(col, c);
                x.set(r, c, v);
            }
        }
    }
    // Back substitution.
    for col in (0..n).rev() {
        let d = lu.get(col, col);
        for c in 0..m {
            let mut s = x.get(col, c);
            for k in (col + 1)..n {
                s -= lu.get(col, k) * x.get(k, c);
            }
            x.set(col, c, s / d);
        }
    }
    Ok(Matrix::Dense(x))
}

/// Matrix inverse via solve(A, I).
pub fn inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    let mut eye = DenseMatrix::zeros(n, n);
    for i in 0..n {
        eye.set(i, i, 1.0);
    }
    solve(a, &Matrix::Dense(eye))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::mult::matmult;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::approx_eq_slice;

    #[test]
    fn solves_2x2() {
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let b = Matrix::from_rows(&[&[5.0], &[10.0]]);
        let x = solve(&a, &b).unwrap();
        assert!((x.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((x.get(1, 0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_requires_pivoting() {
        // Leading zero forces a row swap.
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let b = Matrix::from_rows(&[&[2.0], &[3.0]]);
        let x = solve(&a, &b).unwrap();
        assert!((x.get(0, 0) - 3.0).abs() < 1e-12);
        assert!((x.get(1, 0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_rejected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        let b = Matrix::from_rows(&[&[1.0], &[2.0]]);
        assert!(solve(&a, &b).is_err());
    }

    #[test]
    fn random_roundtrip_ax_equals_b() {
        let mut rng = Prng::new(3);
        let n = 12;
        let mut ad = crate::runtime::matrix::DenseMatrix::zeros(n, n);
        for v in ad.data.iter_mut() {
            *v = rng.uniform(-1.0, 1.0);
        }
        // Diagonal dominance to guarantee non-singularity.
        for i in 0..n {
            let v = ad.get(i, i) + 5.0;
            ad.set(i, i, v);
        }
        let a = Matrix::Dense(ad);
        let mut bd = crate::runtime::matrix::DenseMatrix::zeros(n, 3);
        for v in bd.data.iter_mut() {
            *v = rng.uniform(-1.0, 1.0);
        }
        let b = Matrix::Dense(bd);
        let x = solve(&a, &b).unwrap();
        let back = matmult(&a, &x).unwrap();
        assert!(approx_eq_slice(&back.to_row_major_vec(), &b.to_row_major_vec(), 1e-8));
    }

    #[test]
    fn inverse_times_self_is_identity() {
        let a = Matrix::from_rows(&[&[4.0, 7.0], &[2.0, 6.0]]);
        let inv = inverse(&a).unwrap();
        let eye = matmult(&a, &inv).unwrap();
        assert!((eye.get(0, 0) - 1.0).abs() < 1e-10);
        assert!((eye.get(0, 1)).abs() < 1e-10);
        assert!((eye.get(1, 0)).abs() < 1e-10);
        assert!((eye.get(1, 1) - 1.0).abs() < 1e-10);
    }
}
