//! Matrix multiplication: the four physical operators (dense×dense,
//! sparse×dense, dense×sparse, sparse×sparse) with selection by input
//! formats, plus output-format decision from a sparsity estimate —
//! mirroring SystemML's MatrixMult library (paper §3 Sparse Operations).

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::sparse::{SparseCoo, SparseCsr};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// Which physical matmult operator ran (exposed for tests/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmOperator {
    DenseDense,
    SparseDense,
    DenseSparse,
    SparseSparse,
}

/// `lhs %*% rhs` with automatic physical-operator selection.
pub fn matmult(lhs: &Matrix, rhs: &Matrix) -> Result<Matrix> {
    Ok(matmult_traced(lhs, rhs)?.0)
}

/// Like [`matmult`] but also reports which operator was selected.
pub fn matmult_traced(lhs: &Matrix, rhs: &Matrix) -> Result<(Matrix, MmOperator)> {
    if lhs.cols() != rhs.rows() {
        return Err(DmlError::DimMismatch {
            op: "%*%".into(),
            lhs_rows: lhs.rows(),
            lhs_cols: lhs.cols(),
            rhs_rows: rhs.rows(),
            rhs_cols: rhs.cols(),
        });
    }
    let m = metrics::global();
    let (out, op) = match (lhs, rhs) {
        (Matrix::Dense(a), Matrix::Dense(b)) => {
            m.dense_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            (Matrix::Dense(mm_dense_dense(a, b)), MmOperator::DenseDense)
        }
        (Matrix::Sparse(a), Matrix::Dense(b)) => {
            m.sparse_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            (Matrix::Dense(mm_sparse_dense(a, b)), MmOperator::SparseDense)
        }
        (Matrix::Dense(a), Matrix::Sparse(b)) => {
            m.sparse_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            (Matrix::Dense(mm_dense_sparse(a, b)), MmOperator::DenseSparse)
        }
        (Matrix::Sparse(a), Matrix::Sparse(b)) => {
            m.sparse_ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            (mm_sparse_sparse(a, b), MmOperator::SparseSparse)
        }
    };
    Ok((out.examine_and_convert(), op))
}

/// Like [`matmult`], but the caller has already *estimated* the product
/// sparse (the planner's worst-case matmult output-sparsity estimator
/// over operand metadata — see `hop::estimate::matmult_output_sparsity`):
/// the result comes back in CSR form with no dense materialization in
/// between. Sparse×sparse products flow straight out of the Gustavson
/// kernel's sparse accumulator, skipping [`matmult_traced`]'s
/// examine-and-convert (which would densify a ≥40%-full partial only for
/// the blocked accumulator chain to convert it back); mixed and dense
/// pairs still run their dense-output kernel — that output was going to
/// materialize dense regardless — and convert once at the end. Cell
/// values are bit-identical to [`matmult`]'s either way; only the
/// storage format of the returned block differs.
pub fn matmult_sparse_out(lhs: &Matrix, rhs: &Matrix) -> Result<Matrix> {
    if let (Matrix::Sparse(a), Matrix::Sparse(b)) = (lhs, rhs) {
        if lhs.cols() != rhs.rows() {
            return Err(DmlError::DimMismatch {
                op: "%*%".into(),
                lhs_rows: lhs.rows(),
                lhs_cols: lhs.cols(),
                rhs_rows: rhs.rows(),
                rhs_cols: rhs.cols(),
            });
        }
        metrics::global()
            .sparse_ops
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        return Ok(mm_sparse_sparse(a, b));
    }
    Ok(matmult_traced(lhs, rhs)?.0.into_sparse_format())
}

// Tile sizes shared by the packed kernel and the reference kernel. Tuned
// on the benchmark VM (see EXPERIMENTS.md §Perf): the packed B panel
// (KB x NB x 8B = 192 KB) stays L2-resident while an A micro-panel strip
// (MR x KB = 4 KB) streams from L1.
const MB: usize = 64;
const KB: usize = 128;
const NB: usize = 192;
/// Micro-kernel register tile: MR x NR accumulators live in registers for
/// the whole k-panel, so each FLOP touches packed memory only.
const MR: usize = 4;
const NR: usize = 4;

/// Dense×dense: packed, tiled GEMM (GotoBLAS-style). The CP hot path —
/// also reused by the conv2d im2col path and, per-block, by the blocked
/// backend's matmult tasks. See EXPERIMENTS.md §Perf for the iteration log.
///
/// Structure: for each NB column panel of B, for each KB k-panel, B is
/// packed once into contiguous kb×NR micro-panels; each MB×kb slab of A is
/// packed into MR×kb micro-panels; a 4×4 register micro-kernel then runs
/// `+=` rank-kb updates over C in ascending k0 order. A and B edges are
/// zero-padded in M/N inside the packs (never in K), and the writeback
/// clips the padded rows/cols, so odd sizes take the same code path.
pub fn mm_dense_dense(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    metrics::global().add_flops(2 * (m * k * n) as u64);
    let mut c = DenseMatrix::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    // Packing buffers, allocated once and reused across panels. Sized for
    // full tiles; edge tiles simply use a prefix.
    let mut apack = vec![0.0f64; MB * KB];
    let mut bpack = vec![0.0f64; KB * NB];
    for j0 in (0..n).step_by(NB) {
        let j1 = (j0 + NB).min(n);
        let nb = j1 - j0;
        let njr = nb.div_ceil(NR); // NR-wide micro-panels in this B panel
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            let kb = k1 - k0;
            pack_b_panel(b, k0, kb, j0, nb, &mut bpack);
            for i0 in (0..m).step_by(MB) {
                let i1 = (i0 + MB).min(m);
                let mb = i1 - i0;
                let nir = mb.div_ceil(MR); // MR-tall micro-panels in this A slab
                pack_a_panel(a, i0, mb, k0, kb, &mut apack);
                for ip in 0..nir {
                    let ap = &apack[ip * MR * kb..(ip + 1) * MR * kb];
                    for jp in 0..njr {
                        let bp = &bpack[jp * kb * NR..(jp + 1) * kb * NR];
                        let mut acc = [0.0f64; MR * NR];
                        micro_kernel_4x4(ap, bp, &mut acc);
                        // Writeback (`+=` across k0 panels), clipping the
                        // zero-padded edge rows/cols.
                        let rbase = i0 + ip * MR;
                        let cbase = j0 + jp * NR;
                        for r in 0..MR.min(m - rbase) {
                            let crow = &mut c.data[(rbase + r) * n..];
                            for cc in 0..NR.min(n - cbase) {
                                crow[cbase + cc] += acc[r * NR + cc];
                            }
                        }
                    }
                }
            }
        }
    }
    c
}

/// Pack `a[i0..i0+mb, k0..k0+kb]` into MR-tall micro-panels: panel `ip`
/// occupies `apack[ip*MR*kb ..]`, laid out k-major so the micro-kernel
/// reads MR values per k step contiguously. Rows past `mb` are zeroed.
fn pack_a_panel(a: &DenseMatrix, i0: usize, mb: usize, k0: usize, kb: usize, apack: &mut [f64]) {
    let lda = a.cols;
    for ip in 0..mb.div_ceil(MR) {
        let panel = &mut apack[ip * MR * kb..(ip + 1) * MR * kb];
        let rows = MR.min(mb - ip * MR);
        for r in 0..rows {
            let arow = &a.data[(i0 + ip * MR + r) * lda + k0..];
            for (p, av) in arow.iter().take(kb).enumerate() {
                panel[p * MR + r] = *av;
            }
        }
        for r in rows..MR {
            for p in 0..kb {
                panel[p * MR + r] = 0.0;
            }
        }
    }
}

/// Pack `b[k0..k0+kb, j0..j0+nb]` into NR-wide micro-panels: panel `jp`
/// occupies `bpack[jp*kb*NR ..]`, k-major (NR values per k step). Columns
/// past `nb` are zeroed.
fn pack_b_panel(b: &DenseMatrix, k0: usize, kb: usize, j0: usize, nb: usize, bpack: &mut [f64]) {
    let ldb = b.cols;
    for jp in 0..nb.div_ceil(NR) {
        let panel = &mut bpack[jp * kb * NR..(jp + 1) * kb * NR];
        let cols = NR.min(nb - jp * NR);
        for p in 0..kb {
            let brow = &b.data[(k0 + p) * ldb + j0 + jp * NR..];
            let dst = &mut panel[p * NR..p * NR + NR];
            dst[..cols].copy_from_slice(&brow[..cols]);
            for cv in dst.iter_mut().skip(cols) {
                *cv = 0.0;
            }
        }
    }
}

/// 4×4 register micro-kernel: 16 accumulators, one rank-1 update per k
/// step from the packed panels (`ap`: MR values/step, `bp`: NR values/
/// step). `chunks_exact` pairs the panels step-for-step, so `kb` is
/// implicit in the panel lengths.
#[inline(always)]
fn micro_kernel_4x4(ap: &[f64], bp: &[f64], acc: &mut [f64; MR * NR]) {
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        acc[0] += av[0] * bv[0];
        acc[1] += av[0] * bv[1];
        acc[2] += av[0] * bv[2];
        acc[3] += av[0] * bv[3];
        acc[4] += av[1] * bv[0];
        acc[5] += av[1] * bv[1];
        acc[6] += av[1] * bv[2];
        acc[7] += av[1] * bv[3];
        acc[8] += av[2] * bv[0];
        acc[9] += av[2] * bv[1];
        acc[10] += av[2] * bv[2];
        acc[11] += av[2] * bv[3];
        acc[12] += av[3] * bv[0];
        acc[13] += av[3] * bv[1];
        acc[14] += av[3] * bv[2];
        acc[15] += av[3] * bv[3];
    }
}

/// The previous dense×dense kernel (cache-blocked i-k-j with 4-wide
/// k-unrolling, no packing) — kept as the GFLOP/s baseline the bench
/// compares the packed kernel against, and as a correctness oracle.
pub fn mm_dense_dense_reference(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    metrics::global().add_flops(2 * (m * k * n) as u64);
    let mut c = DenseMatrix::zeros(m, n);
    for i0 in (0..m).step_by(MB) {
        let i1 = (i0 + MB).min(m);
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for j0 in (0..n).step_by(NB) {
                let j1 = (j0 + NB).min(n);
                for i in i0..i1 {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut c.data[i * n + j0..i * n + j1];
                    // k-unrolled by 4: one pass over the C row consumes four
                    // B rows, quartering C load/store traffic per FLOP.
                    let mut kk = k0;
                    while kk + 3 < k1 {
                        let a0 = arow[kk];
                        let a1 = arow[kk + 1];
                        let a2 = arow[kk + 2];
                        let a3 = arow[kk + 3];
                        let b0 = &b.data[kk * n + j0..kk * n + j1];
                        let b1 = &b.data[(kk + 1) * n + j0..(kk + 1) * n + j1];
                        let b2 = &b.data[(kk + 2) * n + j0..(kk + 2) * n + j1];
                        let b3 = &b.data[(kk + 3) * n + j0..(kk + 3) * n + j1];
                        for (i2, cv) in crow.iter_mut().enumerate() {
                            *cv += a0 * b0[i2] + a1 * b1[i2] + a2 * b2[i2] + a3 * b3[i2];
                        }
                        kk += 4;
                    }
                    while kk < k1 {
                        let aik = arow[kk];
                        if aik != 0.0 {
                            let bj = &b.data[kk * n + j0..kk * n + j1];
                            for (cv, bv) in crow.iter_mut().zip(bj) {
                                *cv += aik * *bv;
                            }
                        }
                        kk += 1;
                    }
                }
            }
        }
    }
    c
}

/// Sparse×dense: row-wise saxpy over the lhs non-zeros.
/// FLOPs scale with nnz(lhs)·ncol(rhs) — the sparse-safe claim of E2.
pub fn mm_sparse_dense(a: &SparseCsr, b: &DenseMatrix) -> DenseMatrix {
    let n = b.cols;
    metrics::global().add_flops(2 * (a.nnz() * n) as u64);
    let mut c = DenseMatrix::zeros(a.rows, n);
    for r in 0..a.rows {
        let (cols, vals) = a.row(r);
        let crow = &mut c.data[r * n..(r + 1) * n];
        for (ci, v) in cols.iter().zip(vals) {
            let brow = &b.data[*ci as usize * n..(*ci as usize + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += *v * *bv;
            }
        }
    }
    c
}

/// Dense×sparse: for each lhs row, scatter rhs rows scaled by a[i][k].
/// Implemented by iterating rhs in CSR row order for locality.
pub fn mm_dense_sparse(a: &DenseMatrix, b: &SparseCsr) -> DenseMatrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    metrics::global().add_flops(2 * (a.count_nnz() / k.max(1) * b.nnz()).max(m * b.nnz() / k.max(1)) as u64);
    let mut c = DenseMatrix::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (kk, aik) in arow.iter().enumerate() {
            if *aik == 0.0 {
                continue;
            }
            let (cols, vals) = b.row(kk);
            for (ci, v) in cols.iter().zip(vals) {
                crow[*ci as usize] += aik * v;
            }
        }
    }
    c
}

/// Sparse×sparse: classic Gustavson with a dense accumulator per output
/// row; output format decided from the result's actual sparsity.
pub fn mm_sparse_sparse(a: &SparseCsr, b: &SparseCsr) -> Matrix {
    let n = b.cols;
    let mut out = SparseCoo::new(a.rows, n);
    let mut acc = vec![0.0f64; n];
    let mut touched: Vec<u32> = Vec::new();
    let mut flops = 0u64;
    for r in 0..a.rows {
        let (cols, vals) = a.row(r);
        for (kk, av) in cols.iter().zip(vals) {
            let (bcols, bvals) = b.row(*kk as usize);
            flops += 2 * bcols.len() as u64;
            for (bc, bv) in bcols.iter().zip(bvals) {
                if acc[*bc as usize] == 0.0 {
                    touched.push(*bc);
                }
                acc[*bc as usize] += av * bv;
            }
        }
        touched.sort_unstable();
        for c in touched.drain(..) {
            out.push(r, c as usize, acc[c as usize]);
            acc[c as usize] = 0.0;
        }
    }
    metrics::global().add_flops(flops);
    Matrix::Sparse(out.to_csr())
}

/// Transpose-self matmult `t(X) %*% X` (tsmm), a common fused pattern.
pub fn tsmm(x: &Matrix) -> Result<Matrix> {
    // Exploit symmetry for the dense case; sparse falls back to matmult.
    match x {
        Matrix::Dense(d) => {
            let (m, n) = (d.rows, d.cols);
            metrics::global().add_flops((m * n * n) as u64);
            let mut c = DenseMatrix::zeros(n, n);
            for r in 0..m {
                let row = d.row(r);
                for i in 0..n {
                    let vi = row[i];
                    if vi == 0.0 {
                        continue;
                    }
                    let crow = &mut c.data[i * n..(i + 1) * n];
                    for j in i..n {
                        crow[j] += vi * row[j];
                    }
                }
            }
            // Mirror the upper triangle.
            for i in 0..n {
                for j in (i + 1)..n {
                    c.data[j * n + i] = c.data[i * n + j];
                }
            }
            Ok(Matrix::Dense(c).examine_and_convert())
        }
        Matrix::Sparse(s) => {
            let t = Matrix::Sparse(s.transpose());
            matmult(&t, x)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::approx_eq_slice;

    fn dense(rows: &[&[f64]]) -> Matrix {
        Matrix::from_rows(rows)
    }

    /// Random matrix with the given density.
    fn random(rng: &mut Prng, r: usize, c: usize, density: f64) -> Matrix {
        let mut d = DenseMatrix::zeros(r, c);
        for v in d.data.iter_mut() {
            if rng.next_f64() < density {
                *v = rng.uniform(-2.0, 2.0);
            }
        }
        Matrix::Dense(d)
    }

    fn naive_mm(a: &Matrix, b: &Matrix) -> Vec<f64> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let (ad, bd) = (a.to_dense(), b.to_dense());
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += ad.get(i, kk) * bd.get(kk, j);
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    #[test]
    fn basic_2x2() {
        let a = dense(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = dense(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = matmult(&a, &b).unwrap();
        assert_eq!(c, dense(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn dim_mismatch() {
        let a = dense(&[&[1.0, 2.0]]);
        assert!(matmult(&a, &a).is_err());
    }

    #[test]
    fn all_four_operators_agree() {
        let mut rng = Prng::new(99);
        for &(m, k, n) in &[(7usize, 5usize, 9usize), (33, 70, 17), (64, 64, 64)] {
            let a = random(&mut rng, m, k, 0.3);
            let b = random(&mut rng, k, n, 0.3);
            let expect = naive_mm(&a, &b);
            let variants = [
                (a.clone(), b.clone(), MmOperator::DenseDense),
                (a.clone().into_sparse_format(), b.clone(), MmOperator::SparseDense),
                (a.clone(), b.clone().into_sparse_format(), MmOperator::DenseSparse),
                (
                    a.clone().into_sparse_format(),
                    b.clone().into_sparse_format(),
                    MmOperator::SparseSparse,
                ),
            ];
            for (av, bv, want_op) in variants {
                let (c, op) = matmult_traced(&av, &bv).unwrap();
                assert_eq!(op, want_op);
                assert!(
                    approx_eq_slice(&c.to_row_major_vec(), &expect, 1e-9),
                    "operator {op:?} mismatch at {m}x{k}x{n}"
                );
            }
        }
    }

    #[test]
    fn blocked_kernel_matches_naive_on_odd_sizes() {
        let mut rng = Prng::new(5);
        let a = random(&mut rng, 130, 301, 1.0);
        let b = random(&mut rng, 301, 67, 1.0);
        let c = matmult(&a, &b).unwrap();
        assert!(approx_eq_slice(&c.to_row_major_vec(), &naive_mm(&a, &b), 1e-9));
    }

    #[test]
    fn packed_kernel_matches_reference_across_edge_geometries() {
        // Exercise every padding path of the packed kernel: sizes below one
        // micro-tile, exact tile multiples, one-past-tile edges, and tall/
        // wide/deep skew. Reference kernel is the oracle (both are exact
        // reorderings of the same products, so only summation order may
        // differ → approx compare).
        let mut rng = Prng::new(11);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 2),                // smaller than one MR x NR tile
            (4, 128, 4),              // exactly one micro-tile, one k-panel
            (64, 128, 192),           // exactly one MB x KB x NB macro-tile
            (65, 129, 193),           // one past every tile edge
            (130, 301, 67),           // odd everything
            (7, 400, 3),              // deep k: multiple k-panels, += writeback
            (200, 2, 9),              // shallow k
        ] {
            let a = random(&mut rng, m, k, 1.0);
            let b = random(&mut rng, k, n, 1.0);
            let (ad, bd) = (a.to_dense(), b.to_dense());
            let packed = mm_dense_dense(&ad, &bd);
            let reference = mm_dense_dense_reference(&ad, &bd);
            assert!(
                approx_eq_slice(&packed.data, &reference.data, 1e-9),
                "packed vs reference mismatch at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn packed_kernel_handles_empty_dims() {
        let a = DenseMatrix::zeros(0, 5);
        let b = DenseMatrix::zeros(5, 3);
        let c = mm_dense_dense(&a, &b);
        assert_eq!((c.rows, c.cols), (0, 3));
        let a = DenseMatrix::zeros(4, 0);
        let b = DenseMatrix::zeros(0, 3);
        let c = mm_dense_dense(&a, &b);
        assert_eq!((c.rows, c.cols), (4, 3));
        assert!(c.data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn sparse_sparse_output_stays_sparse_when_sparse() {
        let mut rng = Prng::new(6);
        let a = random(&mut rng, 100, 100, 0.01).into_sparse_format();
        let b = random(&mut rng, 100, 100, 0.01).into_sparse_format();
        let c = matmult(&a, &b).unwrap();
        assert!(c.is_sparse(), "1%×1% product should stay sparse");
        assert!(approx_eq_slice(&c.to_row_major_vec(), &naive_mm(&a, &b), 1e-9));
    }

    #[test]
    fn sparse_out_matches_matmult_bitwise() {
        let mut rng = Prng::new(77);
        let a = random(&mut rng, 64, 64, 0.05).into_sparse_format();
        let b = random(&mut rng, 64, 64, 0.05).into_sparse_format();
        let hinted = matmult_sparse_out(&a, &b).unwrap();
        assert!(hinted.is_sparse(), "sparse×sparse hinted product must come back CSR");
        let plain = matmult(&a, &b).unwrap();
        let (h, p) = (hinted.to_row_major_vec(), plain.to_row_major_vec());
        assert!(h.iter().zip(&p).all(|(x, y)| x.to_bits() == y.to_bits()));
        // Mixed pair: dense-output kernel runs, then a single conversion.
        let dense_lhs = random(&mut rng, 32, 64, 1.0);
        let mixed = matmult_sparse_out(&dense_lhs, &b).unwrap();
        assert!(mixed.is_sparse());
        let mixed_ref = matmult(&dense_lhs, &b).unwrap().to_row_major_vec();
        assert!(mixed
            .to_row_major_vec()
            .iter()
            .zip(&mixed_ref)
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn sparse_flops_scale_with_nnz() {
        let mut rng = Prng::new(7);
        let dense_a = random(&mut rng, 128, 128, 1.0);
        let sparse_a = random(&mut rng, 128, 128, 0.05).into_sparse_format();
        let b = random(&mut rng, 128, 128, 1.0);

        let m0 = metrics::global().snapshot();
        matmult(&dense_a, &b).unwrap();
        let dd = metrics::global().snapshot().delta(&m0).flops;

        let m1 = metrics::global().snapshot();
        matmult(&sparse_a, &b).unwrap();
        let sd = metrics::global().snapshot().delta(&m1).flops;

        assert!(sd * 5 < dd, "sparse-dense flops {sd} should be ≪ dense-dense {dd}");
    }

    #[test]
    fn tsmm_matches_explicit() {
        let mut rng = Prng::new(8);
        for density in [1.0, 0.1] {
            let x = random(&mut rng, 40, 23, density);
            let explicit = matmult(&x.clone().into_dense_format().to_dense().transpose().into(), &x)
                .unwrap()
                .to_row_major_vec();
            let fast = tsmm(&x).unwrap().to_row_major_vec();
            assert!(approx_eq_slice(&fast, &explicit, 1e-9));
            let xs = x.into_sparse_format();
            let fast_sparse = tsmm(&xs).unwrap().to_row_major_vec();
            assert!(approx_eq_slice(&fast_sparse, &explicit, 1e-9));
        }
    }

    #[test]
    fn vector_times_matrix() {
        let v = dense(&[&[1.0, 2.0, 3.0]]);
        let m = dense(&[&[1.0, 0.0], &[0.0, 1.0], &[1.0, 1.0]]);
        assert_eq!(matmult(&v, &m).unwrap(), dense(&[&[4.0, 5.0]]));
    }
}

impl From<DenseMatrix> for Matrix {
    fn from(d: DenseMatrix) -> Matrix {
        Matrix::Dense(d)
    }
}
