//! Data generation: rand (uniform/normal with target sparsity), seq, and
//! synthetic dataset helpers used by examples/benches.

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::sparse::SparseCoo;
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::prng::Prng;

/// Probability density function for `rand`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pdf {
    Uniform,
    Normal,
}

/// DML `rand(rows, cols, min, max, sparsity, pdf, seed)`.
///
/// With sparsity < 1 the non-zero positions are sampled uniformly; the
/// output format follows the usual sparsity rules.
pub fn rand(
    rows: usize,
    cols: usize,
    min: f64,
    max: f64,
    sparsity: f64,
    pdf: Pdf,
    seed: u64,
) -> Result<Matrix> {
    if !(0.0..=1.0).contains(&sparsity) {
        return Err(DmlError::rt(format!("rand: sparsity {sparsity} not in [0,1]")));
    }
    let mut rng = Prng::new(seed);
    let gen = |rng: &mut Prng| match pdf {
        Pdf::Uniform => rng.uniform(min, max),
        // DML: normal pdf ignores min/max (standard normal).
        Pdf::Normal => rng.normal(),
    };
    let cells = rows * cols;
    let target_nnz = (sparsity * cells as f64).round() as usize;
    if Matrix::prefers_sparse(rows, cols, target_nnz) {
        // Sample positions via per-cell Bernoulli to stay O(cells) once but
        // memory O(nnz) — matches SystemML's sparse randgen.
        let mut coo = SparseCoo::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.next_f64() < sparsity {
                    let mut v = gen(&mut rng);
                    if v == 0.0 {
                        v = f64::MIN_POSITIVE;
                    }
                    coo.push(r, c, v);
                }
            }
        }
        Ok(Matrix::Sparse(coo.to_csr()))
    } else {
        let mut d = DenseMatrix::zeros(rows, cols);
        if sparsity >= 1.0 {
            for v in d.data.iter_mut() {
                *v = gen(&mut rng);
            }
        } else {
            for v in d.data.iter_mut() {
                if rng.next_f64() < sparsity {
                    *v = gen(&mut rng);
                }
            }
        }
        Ok(Matrix::Dense(d))
    }
}

/// DML `seq(from, to, incr)` → column vector.
pub fn seq(from: f64, to: f64, incr: f64) -> Result<Matrix> {
    if incr == 0.0 {
        return Err(DmlError::rt("seq: increment must be nonzero"));
    }
    let n = ((to - from) / incr).floor();
    if n < 0.0 {
        return Err(DmlError::rt(format!("seq({from},{to},{incr}): empty range")));
    }
    let n = n as usize + 1;
    let data: Vec<f64> = (0..n).map(|i| from + i as f64 * incr).collect();
    Ok(Matrix::Dense(DenseMatrix::from_vec(n, 1, data)?))
}

/// Synthetic classification dataset: X ~ class-dependent Gaussians,
/// Y one-hot n×k. Deterministic for a seed. Used by examples/benches in
/// place of the paper's MNIST-style inputs (see DESIGN.md §Substitutions).
pub fn synthetic_classification(
    n: usize,
    d: usize,
    k: usize,
    seed: u64,
) -> (Matrix, Matrix) {
    let mut rng = Prng::new(seed);
    // Random class centroids scaled so classes are separable.
    let mut centroids = DenseMatrix::zeros(k, d);
    for v in centroids.data.iter_mut() {
        *v = rng.normal() * 2.0;
    }
    let mut x = DenseMatrix::zeros(n, d);
    let mut y = DenseMatrix::zeros(n, k);
    for r in 0..n {
        let class = rng.next_usize(k);
        let c = centroids.row(class);
        let row = x.row_mut(r);
        for j in 0..d {
            row[j] = c[j] + rng.normal() * 0.5;
        }
        y.set(r, class, 1.0);
    }
    (Matrix::Dense(x), Matrix::Dense(y))
}

/// Synthetic image-classification dataset shaped like MNIST: X is
/// n×(c*h*w) in [0,1] with class-dependent blob patterns, Y one-hot.
pub fn synthetic_images(
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    seed: u64,
) -> (Matrix, Matrix) {
    let mut rng = Prng::new(seed);
    let d = c * h * w;
    let mut x = DenseMatrix::zeros(n, d);
    let mut y = DenseMatrix::zeros(n, k);
    for r in 0..n {
        let class = rng.next_usize(k);
        y.set(r, class, 1.0);
        // A class-specific bright blob location + noise.
        let cy = (class * h / k.max(1)) % h;
        let cx = (class * w / k.max(1)) % w;
        let row = x.row_mut(r);
        for ch in 0..c {
            for i in 0..h {
                for j in 0..w {
                    let dy = i as f64 - cy as f64;
                    let dx = j as f64 - cx as f64;
                    let sig = (-(dy * dy + dx * dx) / 8.0).exp();
                    let noise = rng.next_f64() * 0.1;
                    row[ch * h * w + i * w + j] = (sig + noise).min(1.0);
                }
            }
        }
    }
    (Matrix::Dense(x), Matrix::Dense(y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rand_deterministic_and_in_range() {
        let a = rand(10, 10, -1.0, 1.0, 1.0, Pdf::Uniform, 42).unwrap();
        let b = rand(10, 10, -1.0, 1.0, 1.0, Pdf::Uniform, 42).unwrap();
        assert_eq!(a, b);
        for v in a.to_row_major_vec() {
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rand_sparsity_approximate() {
        let m = rand(100, 100, 0.0, 1.0, 0.1, Pdf::Uniform, 7).unwrap();
        assert!(m.is_sparse());
        let sp = m.sparsity();
        assert!((sp - 0.1).abs() < 0.03, "sparsity {sp}");
    }

    #[test]
    fn rand_rejects_bad_sparsity() {
        assert!(rand(2, 2, 0.0, 1.0, 1.5, Pdf::Uniform, 0).is_err());
    }

    #[test]
    fn seq_basics() {
        assert_eq!(
            seq(1.0, 4.0, 1.0).unwrap(),
            Matrix::from_rows(&[&[1.0], &[2.0], &[3.0], &[4.0]])
        );
        assert_eq!(seq(5.0, 1.0, -2.0).unwrap(), Matrix::from_rows(&[&[5.0], &[3.0], &[1.0]]));
        assert!(seq(1.0, 2.0, 0.0).is_err());
        assert!(seq(2.0, 1.0, 1.0).is_err());
    }

    #[test]
    fn synthetic_classification_shapes() {
        let (x, y) = synthetic_classification(50, 8, 3, 1);
        assert_eq!(x.shape(), (50, 8));
        assert_eq!(y.shape(), (50, 3));
        // one-hot rows
        for r in 0..50 {
            let s: f64 = (0..3).map(|c| y.get(r, c)).sum();
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn synthetic_images_bounded() {
        let (x, y) = synthetic_images(10, 1, 8, 8, 4, 2);
        assert_eq!(x.shape(), (10, 64));
        assert_eq!(y.shape(), (10, 4));
        for v in x.to_row_major_vec() {
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
