//! Aggregations: full, row-wise and column-wise reductions, index
//! aggregates, and cumulative aggregates.

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::Matrix;
use crate::util::metrics;

/// Reduction kinds shared by full/row/col aggregates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggOp {
    Sum,
    Mean,
    Min,
    Max,
    /// Sum of squares (used by var/sd and l2 norms).
    SumSq,
    /// Product of all cells.
    Prod,
}

impl AggOp {
    fn init(self) -> f64 {
        match self {
            AggOp::Sum | AggOp::Mean | AggOp::SumSq => 0.0,
            AggOp::Min => f64::INFINITY,
            AggOp::Max => f64::NEG_INFINITY,
            AggOp::Prod => 1.0,
        }
    }
    #[inline]
    fn fold(self, acc: f64, v: f64) -> f64 {
        match self {
            AggOp::Sum | AggOp::Mean => acc + v,
            AggOp::SumSq => acc + v * v,
            AggOp::Min => acc.min(v),
            AggOp::Max => acc.max(v),
            AggOp::Prod => acc * v,
        }
    }
    /// Does skipping zeros change the result (i.e. not sparse-safe)?
    fn needs_zeros(self) -> bool {
        matches!(self, AggOp::Min | AggOp::Max | AggOp::Prod)
    }
}

/// Full aggregate over all cells.
pub fn full_agg(m: &Matrix, op: AggOp) -> f64 {
    metrics::global().add_flops(m.len() as u64);
    let n = m.len() as f64;
    let mut acc = op.init();
    match m {
        Matrix::Dense(d) => {
            for v in &d.data {
                acc = op.fold(acc, *v);
            }
        }
        Matrix::Sparse(s) => {
            for v in &s.values {
                acc = op.fold(acc, *v);
            }
            if op.needs_zeros() && s.nnz() < m.len() {
                acc = op.fold(acc, 0.0);
                if op == AggOp::Prod {
                    acc = 0.0; // any implicit zero nullifies the product
                }
            }
        }
    }
    if op == AggOp::Mean {
        acc / n.max(1.0)
    } else {
        acc
    }
}

/// Row-wise aggregate → n×1 column vector.
pub fn row_agg(m: &Matrix, op: AggOp) -> Matrix {
    metrics::global().add_flops(m.len() as u64);
    let (rows, cols) = m.shape();
    let mut out = DenseMatrix::zeros(rows, 1);
    match m {
        Matrix::Dense(d) => {
            for r in 0..rows {
                let mut acc = op.init();
                for v in d.row(r) {
                    acc = op.fold(acc, *v);
                }
                out.data[r] = finish(op, acc, cols);
            }
        }
        Matrix::Sparse(s) => {
            for r in 0..rows {
                let (idx, vals) = s.row(r);
                let mut acc = op.init();
                for v in vals {
                    acc = op.fold(acc, *v);
                }
                if op.needs_zeros() && idx.len() < cols {
                    acc = op.fold(acc, 0.0);
                    if op == AggOp::Prod {
                        acc = 0.0;
                    }
                }
                out.data[r] = finish(op, acc, cols);
            }
        }
    }
    Matrix::Dense(out)
}

/// Column-wise aggregate → 1×m row vector.
pub fn col_agg(m: &Matrix, op: AggOp) -> Matrix {
    metrics::global().add_flops(m.len() as u64);
    let (rows, cols) = m.shape();
    let mut acc: Vec<f64> = vec![op.init(); cols];
    let mut counts = vec![0usize; if op.needs_zeros() { cols } else { 0 }];
    match m {
        Matrix::Dense(d) => {
            for r in 0..rows {
                for (c, v) in d.row(r).iter().enumerate() {
                    acc[c] = op.fold(acc[c], *v);
                }
            }
        }
        Matrix::Sparse(s) => {
            for r in 0..rows {
                let (idx, vals) = s.row(r);
                for (c, v) in idx.iter().zip(vals) {
                    acc[*c as usize] = op.fold(acc[*c as usize], *v);
                    if op.needs_zeros() {
                        counts[*c as usize] += 1;
                    }
                }
            }
            if op.needs_zeros() {
                for c in 0..cols {
                    if counts[c] < rows {
                        acc[c] = op.fold(acc[c], 0.0);
                        if op == AggOp::Prod {
                            acc[c] = 0.0;
                        }
                    }
                }
            }
        }
    }
    let data: Vec<f64> = acc.into_iter().map(|a| finish(op, a, rows)).collect();
    Matrix::Dense(DenseMatrix::from_vec(1, cols, data).unwrap())
}

#[inline]
fn finish(op: AggOp, acc: f64, n: usize) -> f64 {
    if op == AggOp::Mean {
        acc / n.max(1) as f64
    } else {
        acc
    }
}

/// rowIndexMax: 1-based index of the max entry per row (DML semantics).
pub fn row_index_max(m: &Matrix) -> Matrix {
    let (rows, cols) = m.shape();
    let d = m.to_dense();
    let mut out = DenseMatrix::zeros(rows, 1);
    for r in 0..rows {
        let row = d.row(r);
        let mut best = 0usize;
        for c in 1..cols {
            if row[c] > row[best] {
                best = c;
            }
        }
        out.data[r] = (best + 1) as f64;
    }
    Matrix::Dense(out)
}

/// Trace of a square matrix.
pub fn trace(m: &Matrix) -> f64 {
    let n = m.rows().min(m.cols());
    (0..n).map(|i| m.get(i, i)).sum()
}

/// Column-wise variance (1×m), using the two-pass algorithm.
pub fn col_var(m: &Matrix) -> Matrix {
    let (rows, cols) = m.shape();
    let means = col_agg(m, AggOp::Mean);
    let d = m.to_dense();
    let mut acc = vec![0.0f64; cols];
    for r in 0..rows {
        for (c, v) in d.row(r).iter().enumerate() {
            let dv = v - means.get(0, c);
            acc[c] += dv * dv;
        }
    }
    let denom = (rows.max(2) - 1) as f64;
    let data = acc.into_iter().map(|a| a / denom).collect();
    Matrix::Dense(DenseMatrix::from_vec(1, cols, data).unwrap())
}

/// Cumulative column-wise sum (cumsum, DML semantics: along rows).
pub fn cumsum(m: &Matrix) -> Matrix {
    let (rows, cols) = m.shape();
    let d = m.to_dense();
    let mut out = DenseMatrix::zeros(rows, cols);
    let mut acc = vec![0.0f64; cols];
    for r in 0..rows {
        for c in 0..cols {
            acc[c] += d.get(r, c);
            out.set(r, c, acc[c]);
        }
    }
    Matrix::Dense(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_rows(&[&[1.0, -2.0, 3.0], &[4.0, 0.0, -6.0]])
    }

    #[test]
    fn full_aggregates() {
        assert_eq!(full_agg(&m(), AggOp::Sum), 0.0);
        assert_eq!(full_agg(&m(), AggOp::Min), -6.0);
        assert_eq!(full_agg(&m(), AggOp::Max), 4.0);
        assert_eq!(full_agg(&m(), AggOp::Mean), 0.0);
        assert_eq!(full_agg(&m(), AggOp::SumSq), 1.0 + 4.0 + 9.0 + 16.0 + 36.0);
    }

    #[test]
    fn sparse_min_accounts_for_implicit_zeros() {
        let s = Matrix::from_rows(&[&[5.0, 0.0], &[0.0, 7.0]]).into_sparse_format();
        assert_eq!(full_agg(&s, AggOp::Min), 0.0);
        assert_eq!(full_agg(&s, AggOp::Max), 7.0);
        assert_eq!(full_agg(&s, AggOp::Prod), 0.0);
    }

    #[test]
    fn row_col_aggregates() {
        assert_eq!(row_agg(&m(), AggOp::Sum), Matrix::from_rows(&[&[2.0], &[-2.0]]));
        assert_eq!(col_agg(&m(), AggOp::Sum), Matrix::from_rows(&[&[5.0, -2.0, -3.0]]));
        assert_eq!(row_agg(&m(), AggOp::Max), Matrix::from_rows(&[&[3.0], &[4.0]]));
        assert_eq!(col_agg(&m(), AggOp::Min), Matrix::from_rows(&[&[1.0, -2.0, -6.0]]));
        assert_eq!(row_agg(&m(), AggOp::Mean), Matrix::from_rows(&[&[2.0 / 3.0], &[-2.0 / 3.0]]));
    }

    #[test]
    fn sparse_row_col_agree_with_dense() {
        let d = Matrix::from_rows(&[&[0.0, 2.0, 0.0], &[1.0, 0.0, 0.0], &[0.0, 0.0, 0.0]]);
        let s = d.clone().into_sparse_format();
        for op in [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Mean, AggOp::SumSq] {
            assert_eq!(row_agg(&d, op), row_agg(&s, op), "{op:?} row");
            assert_eq!(col_agg(&d, op), col_agg(&s, op), "{op:?} col");
            assert_eq!(full_agg(&d, op), full_agg(&s, op), "{op:?} full");
        }
    }

    #[test]
    fn row_index_max_is_one_based() {
        let x = Matrix::from_rows(&[&[0.1, 0.7, 0.2], &[0.9, 0.05, 0.05]]);
        assert_eq!(row_index_max(&x), Matrix::from_rows(&[&[2.0], &[1.0]]));
    }

    #[test]
    fn trace_square() {
        let x = Matrix::from_rows(&[&[1.0, 9.0], &[9.0, 2.0]]);
        assert_eq!(trace(&x), 3.0);
    }

    #[test]
    fn col_var_matches_manual() {
        let x = Matrix::from_rows(&[&[1.0], &[3.0], &[5.0]]);
        let v = col_var(&x);
        assert!((v.get(0, 0) - 4.0).abs() < 1e-12); // var([1,3,5]) = 4
    }

    #[test]
    fn cumsum_columns() {
        let x = Matrix::from_rows(&[&[1.0, 1.0], &[2.0, 3.0]]);
        assert_eq!(cumsum(&x), Matrix::from_rows(&[&[1.0, 1.0], &[3.0, 4.0]]));
    }
}
