//! Builtin NN functions (paper §3): conv2d (forward, backward_data,
//! backward_filter), pooling, and bias ops over linearized tensors.
//!
//! Tensors follow the paper's representation: an [N, C, H, W] tensor is an
//! N×(C·H·W) matrix. Convolution lowers to GEMM via im2col (the "lowering
//! technique [5]" — cuDNN), which is also how the L1 Pallas kernel is
//! structured. Four physical forward operators cover the
//! {dense,sparse} input × {dense,sparse} filter combinations
//! (paper §3 "Sparse Operations").

pub mod im2col;
pub mod pool;

use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::mult;
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};

pub use pool::{avg_pool2d, avg_pool2d_backward, max_pool2d, max_pool2d_backward};

/// The seven conv/pool builtins (paper §3) as one enum, shared by the
/// interpreter's builtin routing, the planner's `OpKind::Conv`
/// placement, and the distributed dispatch path, so the three layers can
/// never disagree about which names are NN operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvOpKind {
    Conv2d,
    Conv2dBackwardFilter,
    Conv2dBackwardData,
    MaxPool,
    MaxPoolBackward,
    AvgPool,
    AvgPoolBackward,
}

impl ConvOpKind {
    /// The builtin name (also the EXPLAIN label).
    pub fn name(&self) -> &'static str {
        match self {
            ConvOpKind::Conv2d => "conv2d",
            ConvOpKind::Conv2dBackwardFilter => "conv2d_backward_filter",
            ConvOpKind::Conv2dBackwardData => "conv2d_backward_data",
            ConvOpKind::MaxPool => "max_pool",
            ConvOpKind::MaxPoolBackward => "max_pool_backward",
            ConvOpKind::AvgPool => "avg_pool",
            ConvOpKind::AvgPoolBackward => "avg_pool_backward",
        }
    }

    /// Does this operator take a filter argument (conv family) rather
    /// than a pool window?
    pub fn needs_filter(&self) -> bool {
        matches!(
            self,
            ConvOpKind::Conv2d | ConvOpKind::Conv2dBackwardFilter | ConvOpKind::Conv2dBackwardData
        )
    }

    /// Does the operator take a second batch-shaped matrix operand
    /// (`dout`, one row per image) that must match the first operand's
    /// batch dimension?
    pub fn has_dout(&self) -> bool {
        matches!(
            self,
            ConvOpKind::Conv2dBackwardFilter
                | ConvOpKind::Conv2dBackwardData
                | ConvOpKind::MaxPoolBackward
                | ConvOpKind::AvgPoolBackward
        )
    }
}

/// Map a builtin name to its conv/pool operator, if it is one.
pub fn conv_builtin(name: &str) -> Option<ConvOpKind> {
    Some(match name {
        "conv2d" => ConvOpKind::Conv2d,
        "conv2d_backward_filter" => ConvOpKind::Conv2dBackwardFilter,
        "conv2d_backward_data" => ConvOpKind::Conv2dBackwardData,
        "max_pool" => ConvOpKind::MaxPool,
        "max_pool_backward" => ConvOpKind::MaxPoolBackward,
        "avg_pool" => ConvOpKind::AvgPool,
        "avg_pool_backward" => ConvOpKind::AvgPoolBackward,
        _ => return None,
    })
}

/// Convolution geometry. `N` is taken from the input matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    /// Input channels.
    pub c: usize,
    /// Input height / width.
    pub h: usize,
    pub w: usize,
    /// Number of filters (output channels).
    pub k: usize,
    /// Filter height / width.
    pub r: usize,
    pub s: usize,
    /// Stride (rows, cols).
    pub stride: (usize, usize),
    /// Zero padding (rows, cols).
    pub pad: (usize, usize),
}

impl ConvShape {
    /// Output spatial height.
    pub fn p(&self) -> usize {
        (self.h + 2 * self.pad.0 - self.r) / self.stride.0 + 1
    }
    /// Output spatial width.
    pub fn q(&self) -> usize {
        (self.w + 2 * self.pad.1 - self.s) / self.stride.1 + 1
    }
    /// Output spatial extent, with fully checked arithmetic (None when
    /// the window exceeds the padded input, a stride is zero, or the
    /// padded extent overflows). Compile-time shape inference uses this
    /// so adversarial literal geometry can never panic the planner.
    pub fn checked_pq(&self) -> Option<(usize, usize)> {
        if self.stride.0 == 0 || self.stride.1 == 0 {
            return None;
        }
        let ph = self.h.checked_add(self.pad.0.checked_mul(2)?)?;
        let pw = self.w.checked_add(self.pad.1.checked_mul(2)?)?;
        let p = ph.checked_sub(self.r)? / self.stride.0 + 1;
        let q = pw.checked_sub(self.s)? / self.stride.1 + 1;
        Some((p, q))
    }

    /// Validate the input's dims from metadata alone (no cell access):
    /// the blocked dispatch path raises the byte-identical error without
    /// forcing. `op` names the builtin in the message.
    pub fn validate_input_dims(&self, cols: usize, op: &str) -> Result<()> {
        if cols != self.c * self.h * self.w {
            return Err(DmlError::rt(format!(
                "{op}: input has {cols} cols, expected C*H*W = {}",
                self.c * self.h * self.w
            )));
        }
        Ok(())
    }

    /// Validate the filter's dims from metadata alone.
    pub fn validate_filter_dims(&self, rows: usize, cols: usize, op: &str) -> Result<()> {
        if rows != self.k || cols != self.c * self.r * self.s {
            return Err(DmlError::rt(format!(
                "{op}: filter is {rows}x{cols}, expected K x C*R*S = {}x{}",
                self.k,
                self.c * self.r * self.s
            )));
        }
        Ok(())
    }

    /// Validate that the window fits the padded input (shared by conv and
    /// pool operators — an oversized window would underflow `p()`/`q()`,
    /// and a zero stride would divide by zero).
    pub fn validate_window(&self, op: &str) -> Result<()> {
        if self.stride.0 == 0 || self.stride.1 == 0 {
            return Err(DmlError::rt(format!("{op}: stride must be positive")));
        }
        if self.checked_pq().is_none() {
            return Err(DmlError::rt(format!("{op}: filter larger than padded input")));
        }
        Ok(())
    }

    /// Validate a `dout` operand's dims — including the batch dimension
    /// against the companion operand's `n` — from metadata alone.
    /// `cols_expected` is K·P·Q for conv backwards, C·P·Q for pool
    /// backwards.
    pub fn validate_dout_dims(
        &self,
        n: usize,
        rows: usize,
        cols: usize,
        cols_expected: usize,
        op: &str,
    ) -> Result<()> {
        if rows != n || cols != cols_expected {
            return Err(DmlError::rt(format!(
                "{op}: dout is {rows}x{cols}, expected {n}x{cols_expected}"
            )));
        }
        Ok(())
    }

    /// Validate against input/filter matrix shapes.
    pub fn validate(&self, input: &Matrix, filter: &Matrix) -> Result<usize> {
        self.validate_input_dims(input.cols(), "conv2d")?;
        self.validate_filter_dims(filter.rows(), filter.cols(), "conv2d")?;
        self.validate_window("conv2d")?;
        Ok(input.rows())
    }
}

/// Which physical conv operator ran (the paper's four variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvOperator {
    DenseDense,
    SparseDense,
    DenseSparse,
    SparseSparse,
}

/// conv2d forward: input N×(CHW), filter K×(CRS) → output N×(K·P·Q).
pub fn conv2d(input: &Matrix, filter: &Matrix, shape: &ConvShape) -> Result<Matrix> {
    Ok(conv2d_traced(input, filter, shape)?.0)
}

/// conv2d forward that also reports the selected physical operator.
///
/// All four variants share the im2col→GEMM lowering; sparsity of the
/// input selects a sparse im2col (only non-zero input cells are
/// scattered), and sparsity of the filter selects the sparse GEMM side.
pub fn conv2d_traced(
    input: &Matrix,
    filter: &Matrix,
    shape: &ConvShape,
) -> Result<(Matrix, ConvOperator)> {
    let n = shape.validate(input, filter)?;
    let (p, q) = (shape.p(), shape.q());
    let k = shape.k;
    let op = match (input.is_sparse(), filter.is_sparse()) {
        (false, false) => ConvOperator::DenseDense,
        (true, false) => ConvOperator::SparseDense,
        (false, true) => ConvOperator::DenseSparse,
        (true, true) => ConvOperator::SparseSparse,
    };
    // Filter as (CRS)×K for a single GEMM per image: col-matrix %*% filter^T.
    let ft = crate::runtime::matrix::reorg::transpose(filter);
    let mut out = DenseMatrix::zeros(n, k * p * q);
    for img in 0..n {
        // 1. im2col: (P·Q)×(C·R·S) patch matrix (sparse-aware).
        let col = im2col::im2col(input, img, shape);
        // 2. GEMM: (P·Q)×(CRS) %*% (CRS)×K = (P·Q)×K.
        let prod = mult::matmult(&col, &ft)?;
        // 3. Transpose-scatter into the output row (K-major: [K, P, Q]).
        let pd = prod.to_dense();
        let orow = out.row_mut(img);
        for pq in 0..p * q {
            let prow = pd.row(pq);
            for kk in 0..k {
                orow[kk * p * q + pq] = prow[kk];
            }
        }
    }
    Ok((Matrix::Dense(out).examine_and_convert(), op))
}

/// conv2d_backward_filter: dFilter = Σ_img col(img)^T %*% dout(img).
pub fn conv2d_backward_filter(
    input: &Matrix,
    dout: &Matrix,
    shape: &ConvShape,
) -> Result<Matrix> {
    let n = input.rows();
    shape.validate_input_dims(input.cols(), "conv2d_backward_filter")?;
    shape.validate_window("conv2d_backward_filter")?;
    let (p, q) = (shape.p(), shape.q());
    let (k, crs) = (shape.k, shape.c * shape.r * shape.s);
    shape.validate_dout_dims(n, dout.rows(), dout.cols(), k * p * q, "conv2d_backward_filter")?;
    let mut df = DenseMatrix::zeros(k, crs);
    for img in 0..n {
        let col = im2col::im2col(input, img, shape); // (PQ)×(CRS)
        // dout image as (PQ)×K (stored K-major, so gather transposed).
        let dd = dout_image_as_pq_by_k(dout, img, k, p * q);
        // dF += dd^T %*% col → K×CRS
        let ddt = crate::runtime::matrix::reorg::transpose(&Matrix::Dense(dd));
        let contrib = mult::matmult(&ddt, &col)?.to_dense();
        for i in 0..k * crs {
            df.data[i] += contrib.data[i];
        }
    }
    Ok(Matrix::Dense(df))
}

/// conv2d_backward_data: dInput(img) = col2im( dout(img) %*% filter ).
pub fn conv2d_backward_data(
    filter: &Matrix,
    dout: &Matrix,
    shape: &ConvShape,
) -> Result<Matrix> {
    let n = dout.rows();
    // Full validation (the filter's column count included — an
    // unchecked narrow filter used to index past the dcol row in
    // col2im_accumulate and panic).
    shape.validate_filter_dims(filter.rows(), filter.cols(), "conv2d_backward_data")?;
    shape.validate_window("conv2d_backward_data")?;
    let (p, q) = (shape.p(), shape.q());
    let (k, chw) = (shape.k, shape.c * shape.h * shape.w);
    shape.validate_dout_dims(n, dout.rows(), dout.cols(), k * p * q, "conv2d_backward_data")?;
    let mut din = DenseMatrix::zeros(n, chw);
    for img in 0..n {
        let dd = dout_image_as_pq_by_k(dout, img, k, p * q); // (PQ)×K
        // dcol = dd %*% filter → (PQ)×(CRS)
        let dcol = mult::matmult(&Matrix::Dense(dd), filter)?.to_dense();
        im2col::col2im_accumulate(&dcol, din.row_mut(img), shape);
    }
    Ok(Matrix::Dense(din).examine_and_convert())
}

/// Gather one image of dout (stored K-major [K,P,Q]) as a (PQ)×K dense.
fn dout_image_as_pq_by_k(dout: &Matrix, img: usize, k: usize, pq: usize) -> DenseMatrix {
    let mut dd = DenseMatrix::zeros(pq, k);
    match dout {
        Matrix::Dense(d) => {
            let row = d.row(img);
            for kk in 0..k {
                for i in 0..pq {
                    dd.data[i * k + kk] = row[kk * pq + i];
                }
            }
        }
        Matrix::Sparse(s) => {
            let (cols, vals) = s.row(img);
            for (c, v) in cols.iter().zip(vals) {
                let c = *c as usize;
                let (kk, i) = (c / pq, c % pq);
                dd.data[i * k + kk] = *v;
            }
        }
    }
    dd
}

/// bias_add: out[n, k*pq + i] = input[n, k*pq + i] + bias[k] (bias K×1).
pub fn bias_add(input: &Matrix, bias: &Matrix, k: usize) -> Result<Matrix> {
    if k == 0 || bias.rows() != k || bias.cols() != 1 {
        return Err(DmlError::rt(format!(
            "bias_add: bias must be {}x1, got {}x{}",
            k,
            bias.rows(),
            bias.cols()
        )));
    }
    if input.cols() % k != 0 {
        return Err(DmlError::rt("bias_add: ncol(input) not divisible by K"));
    }
    let pq = input.cols() / k;
    let mut out = input.to_dense();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        for kk in 0..k {
            let b = bias.get(kk, 0);
            for i in 0..pq {
                row[kk * pq + i] += b;
            }
        }
    }
    Ok(Matrix::Dense(out))
}

/// bias_multiply: channel-wise scaling, same layout as bias_add.
pub fn bias_multiply(input: &Matrix, bias: &Matrix, k: usize) -> Result<Matrix> {
    if k == 0 || bias.rows() != k || bias.cols() != 1 {
        return Err(DmlError::rt("bias_multiply: bias must be Kx1"));
    }
    if input.cols() % k != 0 {
        // Same rule as bias_add — a silent partial scaling (the old
        // behavior) also diverged from the blocked kernel's error.
        return Err(DmlError::rt("bias_multiply: ncol(input) not divisible by K"));
    }
    let pq = input.cols() / k;
    let mut out = input.to_dense();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        for kk in 0..k {
            let b = bias.get(kk, 0);
            for i in 0..pq {
                row[kk * pq + i] *= b;
            }
        }
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::approx_eq_slice;

    /// Direct (naive) convolution oracle.
    fn conv2d_naive(input: &Matrix, filter: &Matrix, sh: &ConvShape) -> Vec<f64> {
        let n = input.rows();
        let (p, q) = (sh.p(), sh.q());
        let mut out = vec![0.0; n * sh.k * p * q];
        for img in 0..n {
            for kk in 0..sh.k {
                for op in 0..p {
                    for oq in 0..q {
                        let mut acc = 0.0;
                        for c in 0..sh.c {
                            for fr in 0..sh.r {
                                for fs in 0..sh.s {
                                    let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                                    let iw = (oq * sh.stride.1 + fs) as isize - sh.pad.1 as isize;
                                    if ih < 0 || iw < 0 || ih >= sh.h as isize || iw >= sh.w as isize
                                    {
                                        continue;
                                    }
                                    let iv = input
                                        .get(img, c * sh.h * sh.w + ih as usize * sh.w + iw as usize);
                                    let fv = filter.get(kk, c * sh.r * sh.s + fr * sh.s + fs);
                                    acc += iv * fv;
                                }
                            }
                        }
                        out[img * sh.k * p * q + kk * p * q + op * q + oq] = acc;
                    }
                }
            }
        }
        out
    }

    fn rand_matrix(rng: &mut Prng, r: usize, c: usize, density: f64) -> Matrix {
        let mut d = crate::runtime::matrix::DenseMatrix::zeros(r, c);
        for v in d.data.iter_mut() {
            if rng.next_f64() < density {
                *v = rng.uniform(-1.0, 1.0);
            }
        }
        Matrix::Dense(d)
    }

    fn shapes() -> Vec<ConvShape> {
        vec![
            ConvShape { c: 1, h: 5, w: 5, k: 2, r: 3, s: 3, stride: (1, 1), pad: (0, 0) },
            ConvShape { c: 2, h: 6, w: 5, k: 3, r: 3, s: 2, stride: (2, 1), pad: (1, 1) },
            ConvShape { c: 3, h: 8, w: 8, k: 4, r: 5, s: 5, stride: (1, 1), pad: (2, 2) },
        ]
    }

    #[test]
    fn conv2d_all_four_operators_match_naive() {
        let mut rng = Prng::new(21);
        for sh in shapes() {
            let n = 3;
            let input = rand_matrix(&mut rng, n, sh.c * sh.h * sh.w, 0.5);
            let filter = rand_matrix(&mut rng, sh.k, sh.c * sh.r * sh.s, 0.5);
            let expect = conv2d_naive(&input, &filter, &sh);
            let combos = [
                (input.clone(), filter.clone(), ConvOperator::DenseDense),
                (input.clone().into_sparse_format(), filter.clone(), ConvOperator::SparseDense),
                (input.clone(), filter.clone().into_sparse_format(), ConvOperator::DenseSparse),
                (
                    input.clone().into_sparse_format(),
                    filter.clone().into_sparse_format(),
                    ConvOperator::SparseSparse,
                ),
            ];
            for (iv, fv, want) in combos {
                let (out, op) = conv2d_traced(&iv, &fv, &sh).unwrap();
                assert_eq!(op, want);
                assert!(
                    approx_eq_slice(&out.to_row_major_vec(), &expect, 1e-9),
                    "operator {op:?} mismatch for {sh:?}"
                );
            }
        }
    }

    #[test]
    fn conv2d_rejects_bad_shapes() {
        let sh = ConvShape { c: 1, h: 4, w: 4, k: 1, r: 3, s: 3, stride: (1, 1), pad: (0, 0) };
        let input = Matrix::zeros(2, 99);
        let filter = Matrix::zeros(1, 9);
        assert!(conv2d(&input, &filter, &sh).is_err());
    }

    #[test]
    fn backward_filter_matches_numeric_gradient() {
        let mut rng = Prng::new(31);
        let sh = ConvShape { c: 1, h: 5, w: 5, k: 2, r: 3, s: 3, stride: (1, 1), pad: (1, 1) };
        let n = 2;
        let input = rand_matrix(&mut rng, n, sh.c * sh.h * sh.w, 1.0);
        let filter = rand_matrix(&mut rng, sh.k, 9, 1.0);
        // loss = sum(conv2d(input, filter)); dL/dout = ones.
        let (p, q) = (sh.p(), sh.q());
        let dout = Matrix::filled(n, sh.k * p * q, 1.0);
        let grad = conv2d_backward_filter(&input, &dout, &sh).unwrap();
        // Numeric check on a few filter weights.
        let eps = 1e-5;
        for &(kk, idx) in &[(0usize, 0usize), (1, 4), (0, 8)] {
            let mut fp = filter.to_dense();
            fp.set(kk, idx, fp.get(kk, idx) + eps);
            let lp: f64 = conv2d(&input, &Matrix::Dense(fp.clone()), &sh)
                .unwrap()
                .to_row_major_vec()
                .iter()
                .sum();
            fp.set(kk, idx, fp.get(kk, idx) - 2.0 * eps);
            let lm: f64 = conv2d(&input, &Matrix::Dense(fp), &sh)
                .unwrap()
                .to_row_major_vec()
                .iter()
                .sum();
            let num = (lp - lm) / (2.0 * eps);
            let ana = grad.get(kk, idx);
            assert!((num - ana).abs() < 1e-5, "dF[{kk},{idx}]: numeric {num} vs analytic {ana}");
        }
    }

    #[test]
    fn backward_data_matches_numeric_gradient() {
        let mut rng = Prng::new(32);
        let sh = ConvShape { c: 2, h: 4, w: 4, k: 2, r: 3, s: 3, stride: (1, 1), pad: (1, 1) };
        let input = rand_matrix(&mut rng, 1, sh.c * sh.h * sh.w, 1.0);
        let filter = rand_matrix(&mut rng, sh.k, sh.c * 9, 1.0);
        let (p, q) = (sh.p(), sh.q());
        let dout = Matrix::filled(1, sh.k * p * q, 1.0);
        let grad = conv2d_backward_data(&filter, &dout, &sh).unwrap();
        let eps = 1e-5;
        for &idx in &[0usize, 7, 20, 31] {
            let mut ip = input.to_dense();
            ip.set(0, idx, ip.get(0, idx) + eps);
            let lp: f64 =
                conv2d(&Matrix::Dense(ip.clone()), &filter, &sh).unwrap().to_row_major_vec().iter().sum();
            ip.set(0, idx, ip.get(0, idx) - 2.0 * eps);
            let lm: f64 =
                conv2d(&Matrix::Dense(ip), &filter, &sh).unwrap().to_row_major_vec().iter().sum();
            let num = (lp - lm) / (2.0 * eps);
            let ana = grad.get(0, idx);
            assert!((num - ana).abs() < 1e-5, "dX[{idx}]: numeric {num} vs analytic {ana}");
        }
    }

    #[test]
    fn bias_add_per_channel() {
        // 1 image, K=2, P*Q=2
        let x = Matrix::from_rows(&[&[1.0, 2.0, 3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[10.0], &[20.0]]);
        let out = bias_add(&x, &b, 2).unwrap();
        assert_eq!(out, Matrix::from_rows(&[&[11.0, 12.0, 23.0, 24.0]]));
        assert!(bias_add(&x, &b, 3).is_err());
    }

    #[test]
    fn bias_multiply_per_channel() {
        let x = Matrix::from_rows(&[&[1.0, 2.0, 3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[2.0], &[0.5]]);
        let out = bias_multiply(&x, &b, 2).unwrap();
        assert_eq!(out, Matrix::from_rows(&[&[2.0, 4.0, 1.5, 2.0]]));
    }
}
