//! im2col / col2im lowering for convolution (cuDNN-style [5]).
//!
//! `im2col` extracts a (P·Q)×(C·R·S) patch matrix per image. The sparse
//! variant walks only the non-zero input cells and scatters them into the
//! rows they contribute to — this is what makes the sparse-input physical
//! conv operators sparse-safe (FLOPs ∝ nnz).

use crate::runtime::conv::ConvShape;
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::sparse::SparseCoo;
use crate::runtime::matrix::Matrix;
use crate::util::metrics;

/// Extract the im2col matrix for image `img`: (P·Q)×(C·R·S).
pub fn im2col(input: &Matrix, img: usize, sh: &ConvShape) -> Matrix {
    match input {
        Matrix::Dense(d) => Matrix::Dense(im2col_dense(d, img, sh)),
        Matrix::Sparse(_) => im2col_sparse(input, img, sh),
    }
}

fn im2col_dense(input: &DenseMatrix, img: usize, sh: &ConvShape) -> DenseMatrix {
    let (p, q) = (sh.p(), sh.q());
    let crs = sh.c * sh.r * sh.s;
    let row = input.row(img);
    let mut out = DenseMatrix::zeros(p * q, crs);
    metrics::global().add_flops((p * q * crs) as u64 / 4); // data movement cost proxy
    for op in 0..p {
        for oq in 0..q {
            let orow = out.row_mut(op * q + oq);
            for c in 0..sh.c {
                let chan = &row[c * sh.h * sh.w..(c + 1) * sh.h * sh.w];
                for fr in 0..sh.r {
                    let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                    if ih < 0 || ih >= sh.h as isize {
                        continue;
                    }
                    let ih = ih as usize;
                    let base = c * sh.r * sh.s + fr * sh.s;
                    // Contiguous span when stride-1 and no horizontal clipping.
                    let iw0 = (oq * sh.stride.1) as isize - sh.pad.1 as isize;
                    for fs in 0..sh.s {
                        let iw = iw0 + fs as isize;
                        if iw < 0 || iw >= sh.w as isize {
                            continue;
                        }
                        orow[base + fs] = chan[ih * sh.w + iw as usize];
                    }
                }
            }
        }
    }
    out
}

/// Sparse im2col: iterate nnz of the image row; each non-zero input cell
/// (c, ih, iw) contributes to every output position whose receptive field
/// covers it.
fn im2col_sparse(input: &Matrix, img: usize, sh: &ConvShape) -> Matrix {
    let (p, q) = (sh.p(), sh.q());
    let crs = sh.c * sh.r * sh.s;
    let s = match input {
        Matrix::Sparse(s) => s,
        _ => unreachable!(),
    };
    let (cols, vals) = s.row(img);
    metrics::global().add_flops((cols.len() * sh.r * sh.s) as u64);
    let mut coo = SparseCoo::new(p * q, crs);
    for (cell, v) in cols.iter().zip(vals) {
        let cell = *cell as usize;
        let c = cell / (sh.h * sh.w);
        let rest = cell % (sh.h * sh.w);
        let (ih, iw) = (rest / sh.w, rest % sh.w);
        // Output rows op with op*stride - pad <= ih <= op*stride - pad + r-1.
        for fr in 0..sh.r {
            let num = ih as isize + sh.pad.0 as isize - fr as isize;
            if num < 0 || num % sh.stride.0 as isize != 0 {
                continue;
            }
            let op = (num / sh.stride.0 as isize) as usize;
            if op >= p {
                continue;
            }
            for fs in 0..sh.s {
                let num2 = iw as isize + sh.pad.1 as isize - fs as isize;
                if num2 < 0 || num2 % sh.stride.1 as isize != 0 {
                    continue;
                }
                let oq = (num2 / sh.stride.1 as isize) as usize;
                if oq >= q {
                    continue;
                }
                coo.push(op * q + oq, c * sh.r * sh.s + fr * sh.s + fs, *v);
            }
        }
    }
    Matrix::Sparse(coo.to_csr())
}

/// col2im with accumulation: scatter-add a (P·Q)×(C·R·S) gradient matrix
/// back into a C·H·W image row (used by conv2d_backward_data).
pub fn col2im_accumulate(dcol: &DenseMatrix, out_row: &mut [f64], sh: &ConvShape) {
    let (p, q) = (sh.p(), sh.q());
    for op in 0..p {
        for oq in 0..q {
            let row = dcol.row(op * q + oq);
            for c in 0..sh.c {
                for fr in 0..sh.r {
                    let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                    if ih < 0 || ih >= sh.h as isize {
                        continue;
                    }
                    for fs in 0..sh.s {
                        let iw = (oq * sh.stride.1 + fs) as isize - sh.pad.1 as isize;
                        if iw < 0 || iw >= sh.w as isize {
                            continue;
                        }
                        out_row[c * sh.h * sh.w + ih as usize * sh.w + iw as usize] +=
                            row[c * sh.r * sh.s + fr * sh.s + fs];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn shape() -> ConvShape {
        ConvShape { c: 2, h: 5, w: 4, k: 1, r: 3, s: 3, stride: (1, 1), pad: (1, 1) }
    }

    #[test]
    fn sparse_im2col_matches_dense() {
        let mut rng = Prng::new(77);
        let sh = shape();
        let mut d = DenseMatrix::zeros(2, sh.c * sh.h * sh.w);
        for v in d.data.iter_mut() {
            if rng.next_f64() < 0.3 {
                *v = rng.uniform(-1.0, 1.0);
            }
        }
        let dense_in = Matrix::Dense(d);
        let sparse_in = dense_in.clone().into_sparse_format();
        for img in 0..2 {
            let a = im2col(&dense_in, img, &sh);
            let b = im2col(&sparse_in, img, &sh);
            assert_eq!(a.to_row_major_vec(), b.to_row_major_vec(), "img {img}");
        }
    }

    #[test]
    fn strided_sparse_im2col_matches_dense() {
        let mut rng = Prng::new(78);
        let sh = ConvShape { c: 1, h: 7, w: 7, k: 1, r: 3, s: 3, stride: (2, 2), pad: (0, 0) };
        let mut d = DenseMatrix::zeros(1, 49);
        for v in d.data.iter_mut() {
            if rng.next_f64() < 0.4 {
                *v = rng.uniform(-1.0, 1.0);
            }
        }
        let dense_in = Matrix::Dense(d);
        let sparse_in = dense_in.clone().into_sparse_format();
        assert_eq!(
            im2col(&dense_in, 0, &sh).to_row_major_vec(),
            im2col(&sparse_in, 0, &sh).to_row_major_vec()
        );
    }

    #[test]
    fn im2col_identity_kernel_1x1() {
        // 1x1 kernel, no pad: im2col is just the flattened image per position.
        let sh = ConvShape { c: 1, h: 2, w: 2, k: 1, r: 1, s: 1, stride: (1, 1), pad: (0, 0) };
        let input = Matrix::from_rows(&[&[1.0, 2.0, 3.0, 4.0]]);
        let col = im2col(&input, 0, &sh);
        assert_eq!(col.to_row_major_vec(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(col.shape(), (4, 1));
    }

    #[test]
    fn col2im_inverts_im2col_for_disjoint_patches() {
        // stride == kernel size → patches disjoint → col2im(im2col(x)) == x.
        let sh = ConvShape { c: 1, h: 4, w: 4, k: 1, r: 2, s: 2, stride: (2, 2), pad: (0, 0) };
        let input =
            Matrix::from_rows(&[&(1..=16).map(|v| v as f64).collect::<Vec<_>>()[..]]);
        let col = im2col(&input, 0, &sh).to_dense();
        let mut back = vec![0.0; 16];
        col2im_accumulate(&col, &mut back, &sh);
        assert_eq!(back, input.to_row_major_vec());
    }
}
