//! Pooling builtins: max_pool / avg_pool forward and max_pool backward,
//! over the linearized N×(C·H·W) representation.

use crate::runtime::conv::ConvShape;
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::Matrix;
use crate::util::error::Result;

/// Pooling geometry: reuses [`ConvShape`] with r×s as the window and k
/// ignored (channels preserved). Validation goes through the shared
/// metadata validators so the blocked dispatch path raises byte-identical
/// errors without forcing (`op` names the builtin in the message).
fn validate_pool(input: &Matrix, sh: &ConvShape, op: &str) -> Result<usize> {
    sh.validate_input_dims(input.cols(), op)?;
    sh.validate_window(op)?;
    Ok(input.rows())
}

/// max_pool forward → N×(C·P·Q).
pub fn max_pool2d(input: &Matrix, sh: &ConvShape) -> Result<Matrix> {
    let n = validate_pool(input, sh, "max_pool")?;
    let (p, q) = (sh.p(), sh.q());
    let d = input.to_dense();
    let mut out = DenseMatrix::zeros(n, sh.c * p * q);
    for img in 0..n {
        let row = d.row(img);
        let orow = out.row_mut(img);
        for c in 0..sh.c {
            let chan = &row[c * sh.h * sh.w..(c + 1) * sh.h * sh.w];
            for op in 0..p {
                for oq in 0..q {
                    let mut best = f64::NEG_INFINITY;
                    for fr in 0..sh.r {
                        let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                        if ih < 0 || ih >= sh.h as isize {
                            // Padding contributes 0 (SystemML pads with -inf
                            // only for interior windows; DML nn uses 0-pad).
                            best = best.max(0.0);
                            continue;
                        }
                        for fs in 0..sh.s {
                            let iw = (oq * sh.stride.1 + fs) as isize - sh.pad.1 as isize;
                            if iw < 0 || iw >= sh.w as isize {
                                best = best.max(0.0);
                                continue;
                            }
                            best = best.max(chan[ih as usize * sh.w + iw as usize]);
                        }
                    }
                    orow[c * p * q + op * q + oq] = best;
                }
            }
        }
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// max_pool backward: route dout to the argmax input cell of each window.
pub fn max_pool2d_backward(input: &Matrix, dout: &Matrix, sh: &ConvShape) -> Result<Matrix> {
    let n = validate_pool(input, sh, "max_pool_backward")?;
    let (p, q) = (sh.p(), sh.q());
    sh.validate_dout_dims(n, dout.rows(), dout.cols(), sh.c * p * q, "max_pool_backward")?;
    let d = input.to_dense();
    let dd = dout.to_dense();
    let mut din = DenseMatrix::zeros(n, sh.c * sh.h * sh.w);
    for img in 0..n {
        let row = d.row(img);
        let dorow = dd.row(img);
        let dirow = din.row_mut(img);
        for c in 0..sh.c {
            let chan = &row[c * sh.h * sh.w..(c + 1) * sh.h * sh.w];
            for op in 0..p {
                for oq in 0..q {
                    // Find argmax (first max wins, matching nn library).
                    let mut best = f64::NEG_INFINITY;
                    let mut best_idx: Option<usize> = None;
                    for fr in 0..sh.r {
                        let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                        if ih < 0 || ih >= sh.h as isize {
                            continue;
                        }
                        for fs in 0..sh.s {
                            let iw = (oq * sh.stride.1 + fs) as isize - sh.pad.1 as isize;
                            if iw < 0 || iw >= sh.w as isize {
                                continue;
                            }
                            let idx = ih as usize * sh.w + iw as usize;
                            if chan[idx] > best {
                                best = chan[idx];
                                best_idx = Some(idx);
                            }
                        }
                    }
                    if let Some(idx) = best_idx {
                        dirow[c * sh.h * sh.w + idx] += dorow[c * p * q + op * q + oq];
                    }
                }
            }
        }
    }
    Ok(Matrix::Dense(din).examine_and_convert())
}

/// avg_pool forward → N×(C·P·Q). Divides by the full window size
/// (count_include_pad, matching SystemML).
pub fn avg_pool2d(input: &Matrix, sh: &ConvShape) -> Result<Matrix> {
    let n = validate_pool(input, sh, "avg_pool")?;
    let (p, q) = (sh.p(), sh.q());
    let d = input.to_dense();
    let win = (sh.r * sh.s) as f64;
    let mut out = DenseMatrix::zeros(n, sh.c * p * q);
    for img in 0..n {
        let row = d.row(img);
        let orow = out.row_mut(img);
        for c in 0..sh.c {
            let chan = &row[c * sh.h * sh.w..(c + 1) * sh.h * sh.w];
            for op in 0..p {
                for oq in 0..q {
                    let mut acc = 0.0;
                    for fr in 0..sh.r {
                        let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                        if ih < 0 || ih >= sh.h as isize {
                            continue;
                        }
                        for fs in 0..sh.s {
                            let iw = (oq * sh.stride.1 + fs) as isize - sh.pad.1 as isize;
                            if iw < 0 || iw >= sh.w as isize {
                                continue;
                            }
                            acc += chan[ih as usize * sh.w + iw as usize];
                        }
                    }
                    orow[c * p * q + op * q + oq] = acc / win;
                }
            }
        }
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// avg_pool backward: each output-cell gradient spreads uniformly over
/// its window's in-bounds input cells, scaled by 1/(r·s) — the exact
/// adjoint of the count_include_pad forward (padding cells receive their
/// share of nothing). `input` only contributes its batch dimension, kept
/// as an operand for symmetry with max_pool_backward (and so the same
/// shape validation applies).
pub fn avg_pool2d_backward(input: &Matrix, dout: &Matrix, sh: &ConvShape) -> Result<Matrix> {
    let n = validate_pool(input, sh, "avg_pool_backward")?;
    let (p, q) = (sh.p(), sh.q());
    sh.validate_dout_dims(n, dout.rows(), dout.cols(), sh.c * p * q, "avg_pool_backward")?;
    let dd = dout.to_dense();
    let win = (sh.r * sh.s) as f64;
    let mut din = DenseMatrix::zeros(n, sh.c * sh.h * sh.w);
    for img in 0..n {
        let dorow = dd.row(img);
        let dirow = din.row_mut(img);
        for c in 0..sh.c {
            for op in 0..p {
                for oq in 0..q {
                    let g = dorow[c * p * q + op * q + oq] / win;
                    for fr in 0..sh.r {
                        let ih = (op * sh.stride.0 + fr) as isize - sh.pad.0 as isize;
                        if ih < 0 || ih >= sh.h as isize {
                            continue;
                        }
                        for fs in 0..sh.s {
                            let iw = (oq * sh.stride.1 + fs) as isize - sh.pad.1 as isize;
                            if iw < 0 || iw >= sh.w as isize {
                                continue;
                            }
                            dirow[c * sh.h * sh.w + ih as usize * sh.w + iw as usize] += g;
                        }
                    }
                }
            }
        }
    }
    Ok(Matrix::Dense(din).examine_and_convert())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool_shape() -> ConvShape {
        ConvShape { c: 1, h: 4, w: 4, k: 1, r: 2, s: 2, stride: (2, 2), pad: (0, 0) }
    }

    #[test]
    fn max_pool_2x2() {
        let x = Matrix::from_rows(&[&[
            1.0, 2.0, 5.0, 6.0, //
            3.0, 4.0, 7.0, 8.0, //
            9.0, 10.0, 13.0, 14.0, //
            11.0, 12.0, 15.0, 16.0,
        ]]);
        let out = max_pool2d(&x, &pool_shape()).unwrap();
        assert_eq!(out, Matrix::from_rows(&[&[4.0, 8.0, 12.0, 16.0]]));
    }

    #[test]
    fn avg_pool_2x2() {
        let x = Matrix::from_rows(&[&[
            1.0, 2.0, 5.0, 6.0, //
            3.0, 4.0, 7.0, 8.0, //
            9.0, 10.0, 13.0, 14.0, //
            11.0, 12.0, 15.0, 16.0,
        ]]);
        let out = avg_pool2d(&x, &pool_shape()).unwrap();
        assert_eq!(out, Matrix::from_rows(&[&[2.5, 6.5, 10.5, 14.5]]));
    }

    #[test]
    fn max_pool_backward_routes_to_argmax() {
        let x = Matrix::from_rows(&[&[
            1.0, 2.0, 5.0, 6.0, //
            3.0, 4.0, 7.0, 8.0, //
            9.0, 10.0, 13.0, 14.0, //
            11.0, 12.0, 15.0, 16.0,
        ]]);
        let dout = Matrix::from_rows(&[&[1.0, 2.0, 3.0, 4.0]]);
        let din = max_pool2d_backward(&x, &dout, &pool_shape()).unwrap();
        // Max entries: 4 (idx 5), 8 (idx 7), 12 (idx 13), 16 (idx 15).
        let v = din.to_row_major_vec();
        assert_eq!(v[5], 1.0);
        assert_eq!(v[7], 2.0);
        assert_eq!(v[13], 3.0);
        assert_eq!(v[15], 4.0);
        assert_eq!(v.iter().filter(|x| **x != 0.0).count(), 4);
    }

    #[test]
    fn max_pool_backward_numeric_gradient() {
        // Distinct values so the argmax is unique and the numeric gradient valid.
        let x = Matrix::from_rows(&[&[
            0.11, 0.52, 0.23, 0.94, //
            0.35, 0.16, 0.87, 0.48, //
            0.69, 0.21, 0.33, 0.75, //
            0.14, 0.96, 0.57, 0.28,
        ]]);
        let sh = pool_shape();
        let dout = Matrix::filled(1, 4, 1.0);
        let grad = max_pool2d_backward(&x, &dout, &sh).unwrap();
        let eps = 1e-6;
        for idx in 0..16 {
            let mut xp = x.to_dense();
            xp.set(0, idx, xp.get(0, idx) + eps);
            let lp: f64 =
                max_pool2d(&Matrix::Dense(xp.clone()), &sh).unwrap().to_row_major_vec().iter().sum();
            xp.set(0, idx, xp.get(0, idx) - 2.0 * eps);
            let lm: f64 =
                max_pool2d(&Matrix::Dense(xp), &sh).unwrap().to_row_major_vec().iter().sum();
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - grad.get(0, idx)).abs() < 1e-6,
                "idx {idx}: numeric {num} vs {}",
                grad.get(0, idx)
            );
        }
    }

    #[test]
    fn avg_pool_backward_numeric_gradient() {
        let x = Matrix::from_rows(&[&[
            0.11, 0.52, 0.23, 0.94, //
            0.35, 0.16, 0.87, 0.48, //
            0.69, 0.21, 0.33, 0.75, //
            0.14, 0.96, 0.57, 0.28,
        ]]);
        // Overlapping, padded windows so the adjoint is non-trivial.
        let sh = ConvShape { c: 1, h: 4, w: 4, k: 1, r: 3, s: 3, stride: (2, 2), pad: (1, 1) };
        let (p, q) = (sh.p(), sh.q());
        let dout = Matrix::filled(1, p * q, 1.0);
        let grad = avg_pool2d_backward(&x, &dout, &sh).unwrap();
        let eps = 1e-6;
        for idx in 0..16 {
            let mut xp = x.to_dense();
            xp.set(0, idx, xp.get(0, idx) + eps);
            let lp: f64 =
                avg_pool2d(&Matrix::Dense(xp.clone()), &sh).unwrap().to_row_major_vec().iter().sum();
            xp.set(0, idx, xp.get(0, idx) - 2.0 * eps);
            let lm: f64 =
                avg_pool2d(&Matrix::Dense(xp), &sh).unwrap().to_row_major_vec().iter().sum();
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - grad.get(0, idx)).abs() < 1e-6,
                "idx {idx}: numeric {num} vs {}",
                grad.get(0, idx)
            );
        }
        // Batch-dim mismatch raises the shared metadata error.
        let bad = avg_pool2d_backward(&x, &Matrix::zeros(2, p * q), &sh).unwrap_err();
        assert!(bad.to_string().contains("avg_pool_backward: dout is 2x"), "{bad}");
    }

    #[test]
    fn padded_stride_pool_shapes() {
        let sh = ConvShape { c: 2, h: 5, w: 5, k: 1, r: 3, s: 3, stride: (2, 2), pad: (1, 1) };
        let x = Matrix::filled(3, 50, 1.0);
        let out = max_pool2d(&x, &sh).unwrap();
        assert_eq!(out.shape(), (3, 2 * sh.p() * sh.q()));
        assert_eq!(out.get(0, 0), 1.0);
    }

    #[test]
    fn pool_rejects_bad_input() {
        let sh = pool_shape();
        assert!(max_pool2d(&Matrix::zeros(1, 7), &sh).is_err());
        assert!(max_pool2d_backward(&Matrix::zeros(1, 16), &Matrix::zeros(1, 3), &sh).is_err());
    }
}
