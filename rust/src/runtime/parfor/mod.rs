//! Task-parallel `parfor` (paper §3): dependency analysis, a small
//! optimizer (degree of parallelism + local/remote mode), a multi-threaded
//! executor, and result merging.
//!
//! The "remote" mode corresponds to SystemML's remote-parfor Spark jobs:
//! iterations become cluster tasks (counted in the metrics, attributed to
//! workers for modeled scaling) and — crucially for the paper's ResNet-50
//! scoring claim — a row-partitioned plan that *never shuffles*.

pub mod deps;

use crate::dml::ast::{ParForOpts, Stmt};
use crate::runtime::dist::pool;
use crate::runtime::interp::{Ctx, Interpreter, Scope, Value};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// Chosen execution plan for one parfor loop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParForPlan {
    /// Worker threads (local) or simulated cluster tasks (remote).
    pub degree: usize,
    pub remote: bool,
    /// Result variables to merge (from dependency analysis).
    pub result_vars: Vec<String>,
}

/// The parfor optimizer: pick degree + mode from the loop size, the body's
/// estimated per-iteration work, and the cluster configuration.
pub fn optimize(
    interp: &Interpreter,
    niter: usize,
    opts: &ParForOpts,
    result_vars: Vec<String>,
) -> ParForPlan {
    let max_workers = interp.config.num_workers.max(1);
    let degree = if opts.par > 0 { opts.par } else { max_workers }.min(niter.max(1));
    let remote = match opts.mode.as_str() {
        "remote" => true,
        "local" => false,
        // Heuristic: many iterations + cluster enabled → remote tasks.
        _ => interp.cluster.is_some() && niter >= 2 * max_workers,
    };
    ParForPlan { degree, remote, result_vars }
}

/// Execute a parfor loop: analyze, optimize, run, merge.
pub fn execute_parfor(
    interp: &Interpreter,
    var: &str,
    iters: &[f64],
    body: &[Stmt],
    opts: &ParForOpts,
    scope: &mut Scope,
    ctx: &Ctx,
) -> Result<()> {
    if iters.is_empty() {
        return Ok(());
    }
    // 1. Dependency analysis (check=0 skips, like SystemML's expert mode).
    let result_vars = if opts.check {
        deps::analyze(var, body, scope)?.result_vars
    } else {
        // Without analysis, merge every outer matrix assigned in the body.
        collect_written_outer_matrices(body, scope)
    };
    let plan = optimize(interp, iters.len(), opts, result_vars);
    if interp.config.explain {
        interp.emit(format!(
            "EXPLAIN: parfor({} iters) -> {} degree={} results={:?}",
            iters.len(),
            if plan.remote { "REMOTE" } else { "LOCAL" },
            plan.degree,
            plan.result_vars
        ));
    }

    // Snapshot the originals of result vars for compare-based merge.
    // A blocked original is forced here: the merge compares driver cells,
    // so the parfor boundary is a legitimate driver sync point.
    let mut originals: Vec<(String, Matrix)> = Vec::new();
    for name in &plan.result_vars {
        if let Some(v) = scope.get(name) {
            if v.is_matrix() {
                originals.push((name.clone(), v.to_matrix()?));
            }
        }
    }

    // 2. Execute chunks. Workers get contiguous iteration ranges. The
    //    fork-join goes through the shared scoped-run helper in
    //    `dist::pool` (chunk bodies borrow the interpreter, so they use
    //    scoped threads rather than the cluster's 'static task pool);
    //    results come back in chunk order, making the merge below
    //    deterministic regardless of completion order. DIST ops issued
    //    inside the bodies submit batches to the cluster pool from these
    //    driver threads concurrently — the pool is built for that.
    let chunks: Vec<Vec<f64>> = split_chunks(iters, plan.degree);
    let plan_ref = &plan;
    let worker_scopes: Vec<Result<Scope>> = pool::run_scoped(
        chunks
            .iter()
            .enumerate()
            .map(|(wid, chunk)| {
                let base_scope = scope.clone();
                move || run_chunk(interp, var, chunk, body, base_scope, ctx, plan_ref, wid)
            })
            .collect(),
    );

    // 3. Merge: copy back cells that differ from the original (exact for
    //    disjoint writes, which the dependency analysis guarantees).
    let mut merged: Vec<(String, Matrix)> = originals.clone();
    for ws in worker_scopes {
        let ws = ws?;
        for (name, base) in merged.iter_mut() {
            if let Some(wv) = ws.get(name) {
                if wv.is_matrix() {
                    // Worker results may be blocked (the body ran DIST
                    // ops): force for the cell-compare merge.
                    let wm = wv.to_matrix()?;
                    *base = merge_compare(base, &interp_original(&originals, name), &wm)?;
                }
            }
        }
    }
    for (name, m) in merged {
        // Merged results are fresh bindings: stamp a new lineage version
        // and drop any block partitions cached against the old value.
        let version = interp.note_rebind(&name);
        if let Some(cl) = &interp.cluster {
            cl.cache().adopt(&name, version, &m);
        }
        scope.insert(name, Value::Matrix(m));
    }
    // Loop variable's final value is visible after the loop (DML for-loop
    // semantics).
    interp.note_rebind(var);
    scope.insert(var.to_string(), Value::Double(*iters.last().unwrap()));
    Ok(())
}

fn interp_original<'a>(originals: &'a [(String, Matrix)], name: &str) -> &'a Matrix {
    &originals.iter().find(|(n, _)| n == name).unwrap().1
}

/// Contiguous chunking of the iteration space (SystemML's static task
/// partitioner with task size = ceil(n/degree)).
fn split_chunks(iters: &[f64], degree: usize) -> Vec<Vec<f64>> {
    let chunk = iters.len().div_ceil(degree.max(1));
    iters.chunks(chunk.max(1)).map(|c| c.to_vec()).collect()
}

#[allow(clippy::too_many_arguments)]
fn run_chunk(
    interp: &Interpreter,
    var: &str,
    chunk: &[f64],
    body: &[Stmt],
    mut scope: Scope,
    ctx: &Ctx,
    plan: &ParForPlan,
    worker_id: usize,
) -> Result<Scope> {
    for v in chunk {
        metrics::global().parfor_tasks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if plan.remote {
            if let Some(cluster) = &interp.cluster {
                // One remote task per iteration; the work is attributed to
                // a worker for modeled scaling (no shuffle: row-partitioned).
                let f0 = metrics::global().snapshot().flops;
                scope.insert(var.to_string(), Value::Double(*v));
                interp.exec_block(body, &mut scope, ctx)?;
                let f1 = metrics::global().snapshot().flops;
                cluster.record_task(worker_id, f1.saturating_sub(f0));
                continue;
            }
        }
        scope.insert(var.to_string(), Value::Double(*v));
        interp.exec_block(body, &mut scope, ctx)?;
    }
    Ok(scope)
}

/// Compare-based merge: cells of `worker` that differ from `original` are
/// written into `acc`.
fn merge_compare(acc: &Matrix, original: &Matrix, worker: &Matrix) -> Result<Matrix> {
    if acc.shape() != worker.shape() {
        return Err(DmlError::rt(format!(
            "parfor result merge: shape changed {}x{} -> {}x{}",
            acc.rows(),
            acc.cols(),
            worker.rows(),
            worker.cols()
        )));
    }
    let mut out = acc.to_dense();
    let od = original.to_dense();
    let wd = worker.to_dense();
    for i in 0..out.data.len() {
        if wd.data[i] != od.data[i] {
            out.data[i] = wd.data[i];
        }
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// Fallback result-var collection when check=0.
fn collect_written_outer_matrices(body: &[Stmt], scope: &Scope) -> Vec<String> {
    use crate::dml::ast::AssignTarget;
    let mut out = Vec::new();
    fn walk(stmts: &[Stmt], scope: &Scope, out: &mut Vec<String>) {
        for s in stmts {
            match s {
                Stmt::Assign { target: AssignTarget::Indexed { name, .. }, .. } => {
                    if scope.get(name).is_some_and(|v| v.is_matrix()) {
                        out.push(name.clone());
                    }
                }
                Stmt::If { then_branch, else_branch, .. } => {
                    walk(then_branch, scope, out);
                    walk(else_branch, scope, out);
                }
                Stmt::For { body, .. } | Stmt::ParFor { body, .. } | Stmt::While { body, .. } => {
                    walk(body, scope, out);
                }
                _ => {}
            }
        }
    }
    walk(body, scope, &mut out);
    out.sort();
    out.dedup();
    out
}
