//! parfor loop-carried dependency analysis.
//!
//! Mirrors SystemML's linear-function analysis [3]: a candidate result
//! variable (defined before the loop and written inside it) is safe iff
//! every write is a left-indexing whose row (or column) range is an affine
//! function of the loop variable with disjoint footprints across
//! iterations; whole-variable rebinds of outer variables are loop-carried
//! dependencies and rejected (unless `check=0`).

use std::collections::HashSet;

use crate::dml::ast::*;
use crate::runtime::interp::{Scope, Value};
use crate::util::error::{DmlError, Result};

/// An affine form `a * i + b` of an index expression in the loop var.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Affine {
    pub a: f64,
    pub b: f64,
}

/// Try to express `e` as affine in `var`, resolving other variables from
/// the (pre-loop) scope as constants and loop-local scalar definitions
/// from `locals` (scalar propagation, as in SystemML's linear analysis:
/// `beg = (i-1)*bs + 1; P[beg:end,] = ...`). Returns None when non-affine.
pub fn affine_of(
    e: &Expr,
    var: &str,
    scope: &Scope,
    locals: &std::collections::HashMap<String, Affine>,
) -> Option<Affine> {
    match e {
        Expr::Num(v, _) => Some(Affine { a: 0.0, b: *v }),
        Expr::Int(v, _) => Some(Affine { a: 0.0, b: *v as f64 }),
        Expr::Var(name, _) if name == var => Some(Affine { a: 1.0, b: 0.0 }),
        Expr::Var(name, _) => {
            if let Some(f) = locals.get(name) {
                return Some(*f);
            }
            let v = scope.get(name)?;
            match v {
                Value::Double(d) => Some(Affine { a: 0.0, b: *d }),
                Value::Int(i) => Some(Affine { a: 0.0, b: *i as f64 }),
                _ => None,
            }
        }
        Expr::Unary { op: AstUnOp::Neg, operand, .. } => {
            let f = affine_of(operand, var, scope, locals)?;
            Some(Affine { a: -f.a, b: -f.b })
        }
        Expr::Binary { op, lhs, rhs, .. } => {
            let l = affine_of(lhs, var, scope, locals)?;
            let r = affine_of(rhs, var, scope, locals)?;
            match op {
                AstBinOp::Add => Some(Affine { a: l.a + r.a, b: l.b + r.b }),
                AstBinOp::Sub => Some(Affine { a: l.a - r.a, b: l.b - r.b }),
                AstBinOp::Mul => {
                    // Affine only when one side is constant.
                    if l.a == 0.0 {
                        Some(Affine { a: l.b * r.a, b: l.b * r.b })
                    } else if r.a == 0.0 {
                        Some(Affine { a: l.a * r.b, b: l.b * r.b })
                    } else {
                        None
                    }
                }
                AstBinOp::Div if r.a == 0.0 && r.b != 0.0 => {
                    Some(Affine { a: l.a / r.b, b: l.b / r.b })
                }
                _ => None,
            }
        }
        _ => None,
    }
}

/// The write footprint of one dimension of an indexed write, as affine
/// bounds [lo, hi] in the loop variable.
#[derive(Clone, Copy, Debug)]
pub enum DimFootprint {
    /// Entire dimension (e.g. `X[i, ]` columns).
    All,
    /// [lo(i), hi(i)] affine bounds.
    Span(Affine, Affine),
    /// Not analyzable.
    Unknown,
}

fn dim_footprint(
    r: &IndexRange,
    var: &str,
    scope: &Scope,
    locals: &std::collections::HashMap<String, Affine>,
) -> DimFootprint {
    match r {
        IndexRange::All => DimFootprint::All,
        IndexRange::Single(e) => match affine_of(e, var, scope, locals) {
            Some(f) => DimFootprint::Span(f, f),
            None => DimFootprint::Unknown,
        },
        IndexRange::Range(a, b) => {
            match (affine_of(a, var, scope, locals), affine_of(b, var, scope, locals)) {
                (Some(fa), Some(fb)) => DimFootprint::Span(fa, fb),
                _ => DimFootprint::Unknown,
            }
        }
    }
}

/// Is a span footprint disjoint across distinct iterations i != j?
/// [lo, hi] with lo = a·i + b1, hi = a·i + b2 (same slope required):
/// disjoint iff |a| > (b2 - b1)  i.e. the stride exceeds the span width.
fn span_disjoint(lo: Affine, hi: Affine) -> bool {
    if (lo.a - hi.a).abs() > 1e-9 {
        return false; // widths vary with i — give up conservatively
    }
    let width = hi.b - lo.b;
    if width < 0.0 {
        return false;
    }
    lo.a.abs() > width + 1e-9
}

/// Result of the dependency check.
#[derive(Clone, Debug, Default)]
pub struct DepReport {
    /// Matrix result variables safe to merge after the loop.
    pub result_vars: Vec<String>,
    /// Human-readable explanations for rejected loops.
    pub violations: Vec<String>,
}

/// Analyze a parfor body. `outer` is the pre-loop scope.
pub fn analyze(var: &str, body: &[Stmt], outer: &Scope) -> Result<DepReport> {
    let mut report = DepReport::default();
    let mut locals: HashSet<String> = HashSet::new();
    locals.insert(var.to_string());
    let mut result_vars: HashSet<String> = HashSet::new();
    let mut affine_locals: std::collections::HashMap<String, Affine> = Default::default();
    check_block(
        var,
        body,
        outer,
        &mut locals,
        &mut affine_locals,
        &mut result_vars,
        &mut report.violations,
    );
    report.result_vars = result_vars.into_iter().collect();
    report.result_vars.sort();
    if report.violations.is_empty() {
        Ok(report)
    } else {
        Err(DmlError::val(format!(
            "parfor dependency analysis failed:\n  {}",
            report.violations.join("\n  ")
        )))
    }
}

#[allow(clippy::too_many_arguments)]
fn check_block(
    var: &str,
    body: &[Stmt],
    outer: &Scope,
    locals: &mut HashSet<String>,
    affine_locals: &mut std::collections::HashMap<String, Affine>,
    result_vars: &mut HashSet<String>,
    violations: &mut Vec<String>,
) {
    for stmt in body {
        match stmt {
            Stmt::Assign { target, value, .. } => match target {
                AssignTarget::Var(name) => {
                    // Scalar propagation for the footprint analysis.
                    match affine_of(value, var, outer, affine_locals) {
                        Some(f) => {
                            affine_locals.insert(name.clone(), f);
                        }
                        None => {
                            affine_locals.remove(name);
                        }
                    }
                    if outer.contains_key(name) && !locals.contains(name) {
                        // Rebinding an outer variable — loop-carried.
                        violations.push(format!(
                            "line {}: variable '{name}' is defined before the loop and \
                             re-assigned as a whole inside it (loop-carried dependency)",
                            stmt.pos().line
                        ));
                    }
                    locals.insert(name.clone());
                }
                AssignTarget::Indexed { name, rows, cols } => {
                    if locals.contains(name) {
                        continue; // local accumulation is iteration-private
                    }
                    if !outer.contains_key(name) {
                        violations.push(format!(
                            "line {}: left-indexing into '{name}' which is not defined \
                             before the parfor",
                            stmt.pos().line
                        ));
                        continue;
                    }
                    let rfp = dim_footprint(rows, var, outer, affine_locals);
                    let cfp = dim_footprint(cols, var, outer, affine_locals);
                    let row_disjoint = matches!(rfp, DimFootprint::Span(lo, hi) if span_disjoint(lo, hi));
                    let col_disjoint = matches!(cfp, DimFootprint::Span(lo, hi) if span_disjoint(lo, hi));
                    let unknown = matches!(rfp, DimFootprint::Unknown)
                        || matches!(cfp, DimFootprint::Unknown);
                    if (row_disjoint || col_disjoint) && !unknown {
                        result_vars.insert(name.clone());
                    } else {
                        violations.push(format!(
                            "line {}: write footprint of '{name}' is not provably disjoint \
                             across iterations (index must be affine in '{var}' with stride \
                             exceeding the written span)",
                            stmt.pos().line
                        ));
                    }
                }
            },
            Stmt::MultiAssign { targets, .. } => {
                for t in targets {
                    if outer.contains_key(t) && !locals.contains(t) {
                        violations.push(format!(
                            "line {}: multi-assignment rebinds outer variable '{t}'",
                            stmt.pos().line
                        ));
                    }
                    locals.insert(t.clone());
                }
            }
            Stmt::If { then_branch, else_branch, .. } => {
                check_block(var, then_branch, outer, locals, affine_locals, result_vars, violations);
                check_block(var, else_branch, outer, locals, affine_locals, result_vars, violations);
            }
            Stmt::For { var: v2, body, .. } | Stmt::ParFor { var: v2, body, .. } => {
                locals.insert(v2.clone());
                // Inner loop vars are not affine in the outer loop var.
                affine_locals.remove(v2);
                check_block(var, body, outer, locals, affine_locals, result_vars, violations);
            }
            Stmt::While { body, .. } => {
                check_block(var, body, outer, locals, affine_locals, result_vars, violations);
            }
            Stmt::ExprStmt { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;
    use crate::runtime::matrix::Matrix;

    fn scope_with(names: &[(&str, Value)]) -> Scope {
        names.iter().map(|(n, v)| (n.to_string(), v.clone())).collect()
    }

    fn body_of(src: &str) -> (String, Vec<Stmt>) {
        let prog = parse(src).unwrap();
        match prog.body.into_iter().next().unwrap() {
            Stmt::ParFor { var, body, .. } => (var, body),
            other => panic!("expected parfor, got {other:?}"),
        }
    }

    #[test]
    fn row_partitioned_write_is_safe() {
        let (var, body) = body_of("parfor (i in 1:10) { P[i, ] = i }");
        let outer = scope_with(&[("P", Value::Matrix(Matrix::zeros(10, 3)))]);
        let rep = analyze(&var, &body, &outer).unwrap();
        assert_eq!(rep.result_vars, vec!["P".to_string()]);
    }

    #[test]
    fn strided_range_write_is_safe() {
        // rows (i-1)*4+1 : i*4 — stride 4, span 3 → disjoint.
        let (var, body) = body_of("parfor (i in 1:5) { P[(i-1)*4+1 : i*4, ] = 1 }");
        let outer = scope_with(&[("P", Value::Matrix(Matrix::zeros(20, 2)))]);
        assert!(analyze(&var, &body, &outer).is_ok());
    }

    #[test]
    fn overlapping_range_rejected() {
        // rows i : i+5 — stride 1, span 5 → overlapping.
        let (var, body) = body_of("parfor (i in 1:5) { P[i : i+5, ] = 1 }");
        let outer = scope_with(&[("P", Value::Matrix(Matrix::zeros(20, 2)))]);
        assert!(analyze(&var, &body, &outer).is_err());
    }

    #[test]
    fn scalar_accumulation_rejected() {
        let (var, body) = body_of("parfor (i in 1:5) { s = s + i }");
        let outer = scope_with(&[("s", Value::Double(0.0))]);
        assert!(analyze(&var, &body, &outer).is_err());
    }

    #[test]
    fn constant_index_rejected() {
        let (var, body) = body_of("parfor (i in 1:5) { P[1, ] = i }");
        let outer = scope_with(&[("P", Value::Matrix(Matrix::zeros(5, 2)))]);
        assert!(analyze(&var, &body, &outer).is_err());
    }

    #[test]
    fn local_temporaries_allowed() {
        let (var, body) = body_of("parfor (i in 1:5) { tmp = i * 2; P[i, ] = tmp }");
        let outer = scope_with(&[("P", Value::Matrix(Matrix::zeros(5, 2)))]);
        let rep = analyze(&var, &body, &outer).unwrap();
        assert_eq!(rep.result_vars, vec!["P".to_string()]);
    }

    #[test]
    fn column_partitioned_write_is_safe() {
        let (var, body) = body_of("parfor (j in 1:4) { P[, j] = j }");
        let outer = scope_with(&[("P", Value::Matrix(Matrix::zeros(3, 4)))]);
        assert!(analyze(&var, &body, &outer).is_ok());
    }

    #[test]
    fn affine_extraction() {
        let prog = parse("y = (i-1)*32 + 1").unwrap();
        let e = match &prog.body[0] {
            Stmt::Assign { value, .. } => value.clone(),
            _ => unreachable!(),
        };
        let f = affine_of(&e, "i", &Scope::new(), &Default::default()).unwrap();
        assert_eq!(f.a, 32.0);
        assert_eq!(f.b, -31.0);
    }
}
