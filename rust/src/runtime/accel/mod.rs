//! Accelerator backend (paper §3 "GPU Backend" / "Native BLAS
//! Exploitation"), reimplemented over XLA/PJRT.
//!
//! SystemML compiles an operator to the GPU when its inputs/intermediates/
//! outputs fit in device memory, invoking CuBLAS/CuDNN kernels with lazy
//! host↔device copies and LRU eviction. Here the "device" is the PJRT CPU
//! client executing **AOT-compiled JAX/Pallas artifacts** (HLO text lowered
//! by `python/compile/aot.py`; see DESIGN.md §Hardware-Adaptation): an
//! operator is offloaded when a compiled artifact matching its exact shape
//! exists and the buffers fit the configured device-memory budget. The
//! device-memory manager (LRU + dirty write-back, [`memory`]) reproduces
//! the paper's memory semantics.

pub mod memory;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::conf::SystemConfig;
use crate::runtime::conv::ConvShape;
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::json::Json;
use crate::util::metrics;
pub use memory::DeviceMemoryManager;

/// One AOT-compiled entry from the manifest.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    /// Operator kind: "matmul", "conv2d", "softmax_train_step", ...
    pub op: String,
    /// Op-specific integer attributes (shapes).
    pub attrs: HashMap<String, usize>,
    /// Input shapes (rows, cols) in call order.
    pub inputs: Vec<(usize, usize)>,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

/// The PJRT client plus its compile cache. The `xla` crate's wrappers use
/// `Rc` internally and are neither `Send` nor `Sync`; every access is
/// serialized through the mutex in [`AccelBackend`], and the PJRT CPU C
/// API itself is thread-safe, so confining the `Rc` refcounts inside the
/// lock is sound (see the `unsafe impl`s below).
struct AccelInner {
    client: xla::PjRtClient,
    /// name -> compiled executable (compile-once cache).
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// The PJRT accelerator backend.
pub struct AccelBackend {
    inner: Mutex<AccelInner>,
    artifacts: Vec<Artifact>,
    /// Simulated device memory with LRU + dirty write-back.
    pub memory: Mutex<DeviceMemoryManager>,
}

// SAFETY: all `Rc`-holding state (client, executables, literals) lives
// inside `inner` and is only touched while holding the Mutex; no Rc clone
// escapes `execute`. The underlying PJRT C API is thread-safe.
unsafe impl Send for AccelBackend {}
unsafe impl Sync for AccelBackend {}

impl AccelBackend {
    /// Open the backend: create the PJRT client and read the artifact
    /// manifest. Fails (gracefully handled by callers) when artifacts are
    /// missing — run `make artifacts` first.
    pub fn open(config: &SystemConfig) -> Result<AccelBackend> {
        let manifest_path = config.artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            DmlError::Accel(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let doc = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for e in doc.get("entries").as_arr().unwrap_or(&[]) {
            let mut attrs = HashMap::new();
            if let Some(obj) = e.get("attrs").as_obj() {
                for (k, v) in obj {
                    if let Some(n) = v.as_usize() {
                        attrs.insert(k.clone(), n);
                    }
                }
            }
            let inputs = e
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| {
                    let dims = s.as_arr()?;
                    Some((dims.first()?.as_usize()?, dims.get(1)?.as_usize()?))
                })
                .collect();
            artifacts.push(Artifact {
                name: e.get("name").as_str().unwrap_or_default().to_string(),
                file: config.artifacts_dir.join(e.get("file").as_str().unwrap_or_default()),
                op: e.get("op").as_str().unwrap_or_default().to_string(),
                attrs,
                inputs,
                num_outputs: e.get("num_outputs").as_usize().unwrap_or(1),
            });
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| DmlError::Accel(format!("PJRT client: {e}")))?;
        Ok(AccelBackend {
            inner: Mutex::new(AccelInner { client, compiled: HashMap::new() }),
            artifacts,
            memory: Mutex::new(DeviceMemoryManager::new(config.accel_memory)),
        })
    }

    /// All loaded artifact entries.
    pub fn artifacts(&self) -> &[Artifact] {
        &self.artifacts
    }

    fn find(&self, op: &str, pred: impl Fn(&Artifact) -> bool) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.op == op && pred(a))
    }

    /// Compile (cached) an artifact and execute it on the given inputs.
    pub fn execute(&self, art: &Artifact, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let mut inner = self.inner.lock().unwrap();
        // Ensure compiled.
        if !inner.compiled.contains_key(&art.name) {
            let proto = xla::HloModuleProto::from_text_file(
                art.file.to_str().ok_or_else(|| DmlError::Accel("bad path".into()))?,
            )
            .map_err(|e| DmlError::Accel(format!("load {}: {e}", art.file.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| DmlError::Accel(format!("compile {}: {e}", art.name)))?;
            inner.compiled.insert(art.name.clone(), exe);
        }
        // Host->device: build literals (f64; aot.py enables x64).
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, m) in inputs.iter().enumerate() {
            let expect = art.inputs.get(i).copied().unwrap_or(m.shape());
            if m.shape() != expect {
                return Err(DmlError::Accel(format!(
                    "{}: input {i} is {}x{}, artifact expects {}x{}",
                    art.name,
                    m.rows(),
                    m.cols(),
                    expect.0,
                    expect.1
                )));
            }
            let data = m.to_row_major_vec();
            metrics::global().h2d_bytes.fetch_add(
                (8 * data.len()) as u64,
                std::sync::atomic::Ordering::Relaxed,
            );
            let lit = xla::Literal::vec1(&data)
                .reshape(&[m.rows() as i64, m.cols() as i64])
                .map_err(|e| DmlError::Accel(format!("literal: {e}")))?;
            lits.push(lit);
        }
        let exe = inner.compiled.get(&art.name).unwrap();
        metrics::global().accel_launches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| DmlError::Accel(format!("execute {}: {e}", art.name)))?[0][0]
            .to_literal_sync()
            .map_err(|e| DmlError::Accel(format!("sync: {e}")))?;
        // aot.py lowers with return_tuple=True.
        let items = result
            .to_tuple()
            .map_err(|e| DmlError::Accel(format!("tuple: {e}")))?;
        let mut out = Vec::with_capacity(items.len());
        for lit in items {
            let shape = lit
                .array_shape()
                .map_err(|e| DmlError::Accel(format!("shape: {e}")))?;
            let dims = shape.dims();
            let (r, c) = match dims.len() {
                0 => (1, 1),
                1 => (1, dims[0] as usize),
                _ => (dims[0] as usize, dims[1] as usize),
            };
            let data: Vec<f64> = lit
                .to_vec()
                .map_err(|e| DmlError::Accel(format!("to_vec: {e}")))?;
            metrics::global()
                .d2h_bytes
                .fetch_add((8 * data.len()) as u64, std::sync::atomic::Ordering::Relaxed);
            out.push(Matrix::from_vec(r, c, data)?);
        }
        Ok(out)
    }

    /// Offload a matmult if a matching artifact exists and fits device
    /// memory. Returns Ok(None) to fall back to CP.
    pub fn try_matmult(&self, a: &Matrix, b: &Matrix) -> Result<Option<Matrix>> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let needed = 8 * (m * k + k * n + m * n);
        if needed > self.memory.lock().unwrap().capacity() {
            return Ok(None); // does not fit device memory → CP/dist
        }
        let art = match self.find("matmul", |art| {
            art.attrs.get("m") == Some(&m)
                && art.attrs.get("k") == Some(&k)
                && art.attrs.get("n") == Some(&n)
        }) {
            Some(a) => a.clone(),
            None => return Ok(None),
        };
        let out = self.execute(&art, &[a, b])?;
        Ok(out.into_iter().next())
    }

    /// Offload a conv2d forward if a matching artifact exists.
    pub fn try_conv2d(
        &self,
        input: &Matrix,
        filter: &Matrix,
        sh: &ConvShape,
    ) -> Result<Option<Matrix>> {
        let n = input.rows();
        let art = match self.find("conv2d", |art| {
            art.attrs.get("n") == Some(&n)
                && art.attrs.get("c") == Some(&sh.c)
                && art.attrs.get("h") == Some(&sh.h)
                && art.attrs.get("w") == Some(&sh.w)
                && art.attrs.get("k") == Some(&sh.k)
                && art.attrs.get("r") == Some(&sh.r)
                && art.attrs.get("s") == Some(&sh.s)
                && art.attrs.get("stride") == Some(&sh.stride.0)
                && art.attrs.get("pad") == Some(&sh.pad.0)
        }) {
            Some(a) => a.clone(),
            None => return Ok(None),
        };
        let out = self.execute(&art, &[input, filter])?;
        Ok(out.into_iter().next())
    }

    /// Run a named artifact (used by examples/benches for fused steps like
    /// `softmax_train_step`).
    pub fn run_named(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let art = self
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .cloned()
            .ok_or_else(|| DmlError::Accel(format!("no artifact named '{name}'")))?;
        self.execute(&art, inputs)
    }
}
