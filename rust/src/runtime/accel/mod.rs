//! Accelerator backend (paper §3 "GPU Backend" / "Native BLAS
//! Exploitation").
//!
//! SystemML compiles an operator to the GPU when its inputs/intermediates/
//! outputs fit in device memory, invoking CuBLAS/CuDNN kernels with lazy
//! host↔device copies and LRU eviction. Here the "device" executes
//! **AOT-compiled JAX/Pallas artifacts** (HLO text lowered by
//! `python/compile/aot.py`): an operator is offloaded when a compiled
//! artifact matching its exact shape exists and the buffers fit the
//! configured device-memory budget. The device-memory manager (LRU +
//! dirty write-back, [`memory`]) reproduces the paper's memory semantics.
//!
//! Offline build note: the PJRT client bindings (`xla` crate) are not
//! available in this environment, so artifact execution runs through a
//! built-in reference executor that interprets each artifact's operator
//! graph with the CP kernels — the same numerics the PJRT CPU client
//! produces (aot.py lowers with x64 enabled), with identical host↔device
//! transfer accounting. The manifest format, shape matching, and
//! device-memory budget checks are unchanged, so swapping the executor
//! back to PJRT is a local change to [`AccelBackend::execute`].

pub mod memory;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::conf::SystemConfig;
use crate::runtime::conv::{self, ConvShape};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::{mult, reorg, Matrix};
use crate::util::error::{DmlError, Result};
use crate::util::json::Json;
use crate::util::metrics;
pub use memory::DeviceMemoryManager;

/// Learning rate baked into the fused train-step artifacts (aot.py lowers
/// them with `lr=0.1`; it is part of the compiled graph, not an input).
const TRAIN_STEP_LR: f64 = 0.1;

/// One AOT-compiled entry from the manifest.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    /// Operator kind: "matmul", "conv2d", "softmax_train_step", ...
    pub op: String,
    /// Op-specific integer attributes (shapes).
    pub attrs: HashMap<String, usize>,
    /// Input shapes (rows, cols) in call order.
    pub inputs: Vec<(usize, usize)>,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

/// The accelerator backend: artifact registry + simulated device memory.
pub struct AccelBackend {
    artifacts: Vec<Artifact>,
    /// Simulated device memory with LRU + dirty write-back.
    pub memory: Mutex<DeviceMemoryManager>,
}

impl AccelBackend {
    /// Open the backend: read the artifact manifest. Fails (gracefully
    /// handled by callers) when artifacts are missing — run
    /// `make artifacts` first.
    pub fn open(config: &SystemConfig) -> Result<AccelBackend> {
        let manifest_path = config.artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            DmlError::Accel(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let doc = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for e in doc.get("entries").as_arr().unwrap_or(&[]) {
            let mut attrs = HashMap::new();
            if let Some(obj) = e.get("attrs").as_obj() {
                for (k, v) in obj {
                    if let Some(n) = v.as_usize() {
                        attrs.insert(k.clone(), n);
                    }
                }
            }
            let inputs = e
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| {
                    let dims = s.as_arr()?;
                    Some((dims.first()?.as_usize()?, dims.get(1)?.as_usize()?))
                })
                .collect();
            artifacts.push(Artifact {
                name: e.get("name").as_str().unwrap_or_default().to_string(),
                file: config.artifacts_dir.join(e.get("file").as_str().unwrap_or_default()),
                op: e.get("op").as_str().unwrap_or_default().to_string(),
                attrs,
                inputs,
                num_outputs: e.get("num_outputs").as_usize().unwrap_or(1),
            });
        }
        Ok(AccelBackend {
            artifacts,
            memory: Mutex::new(DeviceMemoryManager::new(config.accel_memory)),
        })
    }

    /// All loaded artifact entries.
    pub fn artifacts(&self) -> &[Artifact] {
        &self.artifacts
    }

    fn find(&self, op: &str, pred: impl Fn(&Artifact) -> bool) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.op == op && pred(a))
    }

    /// Execute an artifact on the given inputs: host→device copies, one
    /// device launch, device→host copies of the outputs.
    pub fn execute(&self, art: &Artifact, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        // Host->device: shape-check against the artifact signature and
        // account the copies (f64; aot.py enables x64).
        for (i, m) in inputs.iter().enumerate() {
            let expect = art.inputs.get(i).copied().unwrap_or(m.shape());
            if m.shape() != expect {
                return Err(DmlError::Accel(format!(
                    "{}: input {i} is {}x{}, artifact expects {}x{}",
                    art.name,
                    m.rows(),
                    m.cols(),
                    expect.0,
                    expect.1
                )));
            }
            metrics::global()
                .h2d_bytes
                .fetch_add((8 * m.len()) as u64, std::sync::atomic::Ordering::Relaxed);
        }
        metrics::global().accel_launches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // The `_pallas` twins lower the same graph through the Pallas
        // kernels; numerics are identical by construction.
        let base_op = art.op.strip_suffix("_pallas").unwrap_or(&art.op);
        let out = match base_op {
            "matmul" => {
                require_inputs(art, inputs, 2)?;
                vec![mult::matmult(inputs[0], inputs[1])?]
            }
            "conv2d" => {
                require_inputs(art, inputs, 2)?;
                let sh = conv_shape_from_attrs(art)?;
                vec![conv::conv2d(inputs[0], inputs[1], &sh)?]
            }
            "softmax_train_step" => {
                require_inputs(art, inputs, 4)?;
                softmax_train_step(inputs[0], inputs[1], inputs[2], inputs[3], TRAIN_STEP_LR)?
            }
            "mlp_train_step" => {
                require_inputs(art, inputs, 6)?;
                mlp_train_step(
                    inputs[0],
                    inputs[1],
                    inputs[2],
                    inputs[3],
                    inputs[4],
                    inputs[5],
                    TRAIN_STEP_LR,
                )?
            }
            other => {
                return Err(DmlError::Accel(format!(
                    "{}: no device executor for op '{other}'",
                    art.name
                )))
            }
        };
        // Device->host.
        for m in &out {
            metrics::global()
                .d2h_bytes
                .fetch_add((8 * m.len()) as u64, std::sync::atomic::Ordering::Relaxed);
        }
        Ok(out)
    }

    /// Offload a matmult if a matching artifact exists and fits device
    /// memory. Returns Ok(None) to fall back to CP.
    pub fn try_matmult(&self, a: &Matrix, b: &Matrix) -> Result<Option<Matrix>> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let needed = 8 * (m * k + k * n + m * n);
        if needed > self.memory.lock().unwrap().capacity() {
            return Ok(None); // does not fit device memory → CP/dist
        }
        let art = match self.find("matmul", |art| {
            art.attrs.get("m") == Some(&m)
                && art.attrs.get("k") == Some(&k)
                && art.attrs.get("n") == Some(&n)
        }) {
            Some(a) => a.clone(),
            None => return Ok(None),
        };
        let out = self.execute(&art, &[a, b])?;
        Ok(out.into_iter().next())
    }

    /// Offload a conv2d forward if a matching artifact exists.
    pub fn try_conv2d(
        &self,
        input: &Matrix,
        filter: &Matrix,
        sh: &ConvShape,
    ) -> Result<Option<Matrix>> {
        let n = input.rows();
        let art = match self.find("conv2d", |art| {
            art.attrs.get("n") == Some(&n)
                && art.attrs.get("c") == Some(&sh.c)
                && art.attrs.get("h") == Some(&sh.h)
                && art.attrs.get("w") == Some(&sh.w)
                && art.attrs.get("k") == Some(&sh.k)
                && art.attrs.get("r") == Some(&sh.r)
                && art.attrs.get("s") == Some(&sh.s)
                && art.attrs.get("stride") == Some(&sh.stride.0)
                && art.attrs.get("pad") == Some(&sh.pad.0)
        }) {
            Some(a) => a.clone(),
            None => return Ok(None),
        };
        let out = self.execute(&art, &[input, filter])?;
        Ok(out.into_iter().next())
    }

    /// Run a named artifact (used by examples/benches for fused steps like
    /// `softmax_train_step`).
    pub fn run_named(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let art = self
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .cloned()
            .ok_or_else(|| DmlError::Accel(format!("no artifact named '{name}'")))?;
        self.execute(&art, inputs)
    }
}

fn require_inputs(art: &Artifact, inputs: &[&Matrix], n: usize) -> Result<()> {
    if inputs.len() != n {
        return Err(DmlError::Accel(format!(
            "{}: expected {n} inputs, got {}",
            art.name,
            inputs.len()
        )));
    }
    Ok(())
}

fn conv_shape_from_attrs(art: &Artifact) -> Result<ConvShape> {
    let get = |k: &str| -> Result<usize> {
        art.attrs
            .get(k)
            .copied()
            .ok_or_else(|| DmlError::Accel(format!("{}: missing attr '{k}'", art.name)))
    };
    let stride = get("stride")?;
    let pad = get("pad")?;
    Ok(ConvShape {
        c: get("c")?,
        h: get("h")?,
        w: get("w")?,
        k: get("k")?,
        r: get("r")?,
        s: get("s")?,
        stride: (stride, stride),
        pad: (pad, pad),
    })
}

/// Row-softmax with the max-subtraction trick (matches model.py exactly).
fn softmax_rows(scores: &DenseMatrix) -> DenseMatrix {
    let (rows, cols) = (scores.rows, scores.cols);
    let mut out = DenseMatrix::zeros(rows, cols);
    for r in 0..rows {
        let src = scores.row(r);
        let mx = src.iter().fold(f64::NEG_INFINITY, |a, b| a.max(*b));
        let dst = out.row_mut(r);
        let mut sum = 0.0;
        for (d, v) in dst.iter_mut().zip(src) {
            *d = (v - mx).exp();
            sum += *d;
        }
        for d in dst.iter_mut() {
            *d /= sum;
        }
    }
    out
}

/// Cross-entropy of row-wise probabilities vs one-hot labels (mean over
/// the batch), as lowered in model.py: `-mean(sum(y * log(p + eps)))`.
fn cross_entropy(probs: &DenseMatrix, y: &DenseMatrix) -> f64 {
    let eps = 1e-12;
    let mut total = 0.0;
    for (p, t) in probs.data.iter().zip(&y.data) {
        total += t * (p + eps).ln();
    }
    -total / probs.rows as f64
}

/// `x @ w + b` with `b` a 1×k row vector.
fn affine(x: &Matrix, w: &Matrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let mut scores = mult::matmult(x, w)?.to_dense();
    for r in 0..scores.rows {
        let row = scores.row_mut(r);
        for (v, bv) in row.iter_mut().zip(&b.data) {
            *v += *bv;
        }
    }
    Ok(scores)
}

/// Column sums of a dense matrix → 1×cols.
fn col_sums(m: &DenseMatrix) -> DenseMatrix {
    let mut out = DenseMatrix::zeros(1, m.cols);
    for r in 0..m.rows {
        for (acc, v) in out.data.iter_mut().zip(m.row(r)) {
            *acc += *v;
        }
    }
    out
}

/// `a - lr*b` elementwise over dense data.
fn sgd_update(a: &DenseMatrix, grad: &DenseMatrix, lr: f64) -> DenseMatrix {
    let mut out = a.clone();
    for (v, g) in out.data.iter_mut().zip(&grad.data) {
        *v -= lr * g;
    }
    out
}

/// Fused softmax-classifier minibatch step (model.py `softmax_train_step`):
/// returns `(W', b', loss[1,1])`.
fn softmax_train_step(
    x: &Matrix,
    w: &Matrix,
    b: &Matrix,
    y: &Matrix,
    lr: f64,
) -> Result<Vec<Matrix>> {
    let nrows = x.rows() as f64;
    let scores = affine(x, w, &b.to_dense())?;
    let probs = softmax_rows(&scores);
    let yd = y.to_dense();
    let loss = cross_entropy(&probs, &yd);
    // dscores = (probs - y) / nrows
    let mut dscores = probs;
    for (d, t) in dscores.data.iter_mut().zip(&yd.data) {
        *d = (*d - *t) / nrows;
    }
    let xt = reorg::transpose(x);
    let dw = mult::matmult(&xt, &Matrix::Dense(dscores.clone()))?.to_dense();
    let db = col_sums(&dscores);
    Ok(vec![
        Matrix::Dense(sgd_update(&w.to_dense(), &dw, lr)),
        Matrix::Dense(sgd_update(&b.to_dense(), &db, lr)),
        Matrix::Dense(DenseMatrix::from_vec(1, 1, vec![loss])?),
    ])
}

/// Fused 2-layer relu MLP minibatch step (model.py `mlp_train_step`):
/// returns `(W1', b1', W2', b2', loss[1,1])`.
fn mlp_train_step(
    x: &Matrix,
    w1: &Matrix,
    b1: &Matrix,
    w2: &Matrix,
    b2: &Matrix,
    y: &Matrix,
    lr: f64,
) -> Result<Vec<Matrix>> {
    let nrows = x.rows() as f64;
    let h_pre = affine(x, w1, &b1.to_dense())?;
    let mut h = h_pre.clone();
    for v in h.data.iter_mut() {
        *v = v.max(0.0);
    }
    let hm = Matrix::Dense(h.clone());
    let scores = affine(&hm, w2, &b2.to_dense())?;
    let probs = softmax_rows(&scores);
    let yd = y.to_dense();
    let loss = cross_entropy(&probs, &yd);
    let mut dscores = probs;
    for (d, t) in dscores.data.iter_mut().zip(&yd.data) {
        *d = (*d - *t) / nrows;
    }
    let dscores_m = Matrix::Dense(dscores.clone());
    let dw2 = mult::matmult(&reorg::transpose(&hm), &dscores_m)?.to_dense();
    let db2 = col_sums(&dscores);
    // dh = (dscores @ w2.T) * (h_pre > 0)
    let mut dh = mult::matmult(&dscores_m, &reorg::transpose(w2))?.to_dense();
    for (d, hp) in dh.data.iter_mut().zip(&h_pre.data) {
        if *hp <= 0.0 {
            *d = 0.0;
        }
    }
    let dw1 = mult::matmult(&reorg::transpose(x), &Matrix::Dense(dh.clone()))?.to_dense();
    let db1 = col_sums(&dh);
    Ok(vec![
        Matrix::Dense(sgd_update(&w1.to_dense(), &dw1, lr)),
        Matrix::Dense(sgd_update(&b1.to_dense(), &db1, lr)),
        Matrix::Dense(sgd_update(&w2.to_dense(), &dw2, lr)),
        Matrix::Dense(sgd_update(&b2.to_dense(), &db2, lr)),
        Matrix::Dense(DenseMatrix::from_vec(1, 1, vec![loss])?),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};
    use crate::util::quickcheck::approx_eq_slice;

    #[test]
    fn softmax_rows_normalizes() {
        let s = DenseMatrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]).unwrap();
        let p = softmax_rows(&s);
        for r in 0..2 {
            let sum: f64 = p.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
        // Uniform logits → uniform probabilities.
        assert!(approx_eq_slice(&p.row(1).to_vec(), &[1.0 / 3.0; 3], 1e-12));
    }

    #[test]
    fn train_step_reduces_loss() {
        let x = rand(16, 8, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
        let w = rand(8, 3, -0.1, 0.1, 1.0, Pdf::Uniform, 2).unwrap();
        let b = Matrix::filled(1, 3, 0.0).into_dense_format();
        // One-hot labels on class 0.
        let mut y = DenseMatrix::zeros(16, 3);
        for r in 0..16 {
            y.set(r, 0, 1.0);
        }
        let y = Matrix::Dense(y);
        let step1 = softmax_train_step(&x, &w, &b, &y, 0.1).unwrap();
        let l1 = step1[2].get(0, 0);
        let step2 = softmax_train_step(&x, &step1[0], &step1[1], &y, 0.1).unwrap();
        let l2 = step2[2].get(0, 0);
        assert!(l2 < l1, "SGD step must reduce training loss: {l1} -> {l2}");
    }
}
