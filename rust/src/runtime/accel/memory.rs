//! Device memory manager: LRU eviction with dirty write-back and lazy
//! copies — the paper's GPU memory-management semantics (§3 "GPU
//! Backend": "Data is lazily copied back and forth ... evicted from the
//! GPU memory using an LRU strategy ... copied back to the host memory if
//! it was dirty when evicted").
//!
//! With a CPU PJRT plugin there is no physically separate device memory,
//! so the manager tracks a *budgeted* device-resident set with the same
//! policy and full metrics (h2d/d2h bytes, evictions); see DESIGN.md
//! §Substitutions.

use std::collections::HashMap;

use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// A device-resident buffer.
#[derive(Clone, Debug)]
struct DeviceBuffer {
    data: Matrix,
    bytes: usize,
    dirty: bool,
    /// Logical clock of last use (for LRU).
    last_used: u64,
}

/// LRU-managed device memory.
#[derive(Debug)]
pub struct DeviceMemoryManager {
    capacity: usize,
    used: usize,
    clock: u64,
    buffers: HashMap<String, DeviceBuffer>,
    /// Dirty buffers written back to host on eviction (host shadow store).
    host_store: HashMap<String, Matrix>,
}

impl DeviceMemoryManager {
    pub fn new(capacity: usize) -> Self {
        DeviceMemoryManager {
            capacity,
            used: 0,
            clock: 0,
            buffers: HashMap::new(),
            host_store: HashMap::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
    pub fn used(&self) -> usize {
        self.used
    }
    pub fn resident(&self) -> usize {
        self.buffers.len()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Lazily place a matrix on the device under `key`. If already
    /// resident, only the LRU clock advances (no copy — the lazy part).
    pub fn put(&mut self, key: &str, m: &Matrix) -> Result<()> {
        let bytes = 8 * m.len();
        if bytes > self.capacity {
            return Err(DmlError::Accel(format!(
                "buffer '{key}' ({bytes} B) exceeds device memory ({} B)",
                self.capacity
            )));
        }
        let t = self.tick();
        if let Some(buf) = self.buffers.get_mut(key) {
            buf.last_used = t;
            return Ok(());
        }
        self.make_room(bytes)?;
        metrics::global().h2d_bytes.fetch_add(bytes as u64, std::sync::atomic::Ordering::Relaxed);
        self.buffers
            .insert(key.to_string(), DeviceBuffer { data: m.clone(), bytes, dirty: false, last_used: t });
        self.used += bytes;
        Ok(())
    }

    /// Get a device-resident matrix (advances LRU). None if evicted/absent.
    pub fn get(&mut self, key: &str) -> Option<Matrix> {
        let t = self.tick();
        let buf = self.buffers.get_mut(key)?;
        buf.last_used = t;
        Some(buf.data.clone())
    }

    /// Overwrite a device buffer (marks dirty — will be written back on
    /// eviction).
    pub fn update(&mut self, key: &str, m: &Matrix) -> Result<()> {
        let t = self.tick();
        match self.buffers.get_mut(key) {
            Some(buf) => {
                let new_bytes = 8 * m.len();
                self.used = self.used - buf.bytes + new_bytes;
                buf.data = m.clone();
                buf.bytes = new_bytes;
                buf.dirty = true;
                buf.last_used = t;
                Ok(())
            }
            None => {
                self.put(key, m)?;
                if let Some(buf) = self.buffers.get_mut(key) {
                    buf.dirty = true;
                }
                Ok(())
            }
        }
    }

    /// Read back from the device or the host shadow (after eviction).
    pub fn fetch(&mut self, key: &str) -> Option<Matrix> {
        if let Some(m) = self.get(key) {
            return Some(m);
        }
        self.host_store.get(key).cloned()
    }

    /// Evict LRU buffers until `bytes` fit.
    fn make_room(&mut self, bytes: usize) -> Result<()> {
        while self.used + bytes > self.capacity {
            let victim = self
                .buffers
                .iter()
                .min_by_key(|(_, b)| b.last_used)
                .map(|(k, _)| k.clone())
                .ok_or_else(|| {
                    DmlError::Accel("device memory exhausted with no evictable buffers".into())
                })?;
            self.evict(&victim);
        }
        Ok(())
    }

    /// Evict one buffer; dirty data is copied back to the host store.
    pub fn evict(&mut self, key: &str) {
        if let Some(buf) = self.buffers.remove(key) {
            self.used -= buf.bytes;
            metrics::global()
                .device_evictions
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if buf.dirty {
                metrics::global()
                    .d2h_bytes
                    .fetch_add(buf.bytes as u64, std::sync::atomic::Ordering::Relaxed);
                self.host_store.insert(key.to_string(), buf.data);
            }
        }
    }

    /// Drop everything (end of script).
    pub fn clear(&mut self) {
        let keys: Vec<String> = self.buffers.keys().cloned().collect();
        for k in keys {
            self.evict(&k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(n: usize, v: f64) -> Matrix {
        Matrix::filled(n, 1, v)
    }

    #[test]
    fn put_get_roundtrip() {
        let mut m = DeviceMemoryManager::new(1024);
        m.put("a", &mat(4, 1.0)).unwrap();
        assert_eq!(m.get("a").unwrap(), mat(4, 1.0));
        assert_eq!(m.resident(), 1);
        assert_eq!(m.used(), 32);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut m = DeviceMemoryManager::new(100); // fits 3 x 32B
        m.put("a", &mat(4, 1.0)).unwrap();
        m.put("b", &mat(4, 2.0)).unwrap();
        m.put("c", &mat(4, 3.0)).unwrap();
        m.get("a"); // refresh a — b is now LRU
        m.put("d", &mat(4, 4.0)).unwrap(); // evicts b
        assert!(m.get("b").is_none());
        assert!(m.get("a").is_some());
        assert!(m.get("d").is_some());
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let mut m = DeviceMemoryManager::new(64); // fits 2 x 32B
        m.put("w", &mat(4, 1.0)).unwrap();
        m.update("w", &mat(4, 9.0)).unwrap(); // dirty
        m.put("x", &mat(4, 0.0)).unwrap();
        m.put("y", &mat(4, 0.0)).unwrap(); // evicts w (dirty → host)
        assert!(m.get("w").is_none());
        assert_eq!(m.fetch("w").unwrap(), mat(4, 9.0)); // from host shadow
    }

    #[test]
    fn clean_eviction_discards() {
        let mut m = DeviceMemoryManager::new(32);
        m.put("a", &mat(4, 1.0)).unwrap();
        m.put("b", &mat(4, 2.0)).unwrap(); // evicts clean a
        assert!(m.fetch("a").is_none());
    }

    #[test]
    fn oversized_rejected() {
        let mut m = DeviceMemoryManager::new(16);
        assert!(m.put("big", &mat(100, 1.0)).is_err());
    }

    #[test]
    fn eviction_metrics_counted() {
        let before = metrics::global().snapshot();
        let mut m = DeviceMemoryManager::new(32);
        m.put("a", &mat(4, 1.0)).unwrap();
        m.put("b", &mat(4, 2.0)).unwrap();
        let d = metrics::global().snapshot().delta(&before);
        assert!(d.device_evictions >= 1);
        assert!(d.h2d_bytes >= 64);
    }
}
