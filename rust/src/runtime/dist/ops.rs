//! Blocked physical operators over the simulated cluster: matmult
//! (broadcast-based `mapmm` vs shuffle-based `rmm`, chosen by a
//! communication cost model exactly like SystemML's SparkExecutionContext),
//! cellwise binary ops, row/col/full aggregates, block-range indexing
//! (right-index selection/trim and touched-block left-index rewrite) and
//! the map-side broadcast cellwise join for row/col-vector operands.
//!
//! Every operator assigns block tasks to workers deterministically,
//! accounts per-worker FLOPs and broadcast/shuffle bytes on the
//! [`Cluster`], and bumps the global `dist_tasks` metric — that is how
//! benches and tests observe which physical plan ran. Since PR 6 the
//! tasks are *executed* on the cluster's worker thread pool too
//! ([`Cluster::run_tasks`]): each operator builds one `'static` closure
//! per output block over `Arc<Matrix>` block clones, and all reductions
//! fold driver-side in the serial iteration order, keeping results
//! byte-identical to `threads = 1` (see [`super::pool`]).
//!
//! Communication accounting is **cache-aware**: an operand whose blocked
//! partitions are already resident on the workers (a block-cache hit —
//! see [`crate::runtime::dist::cache`]) is not re-broadcast / re-shuffled,
//! so the cluster's communication totals reflect reuse exactly like
//! Spark's cached-RDD + reused-broadcast behavior. The [`Residency`]
//! flags carry that information from the dispatch layer.
//!
//! Gradient-shaped matmults — a **single-block output folded over a
//! multi-block inner dimension** (`t(X) %*% y`, `t(H) %*% dout`) — run as
//! a modeled **tree-allreduce** instead of mapmm/rmm
//! ([`is_allreduce_matmult`]): one task per inner block k computes its
//! partial product where both operand blocks live (`(0,k)` and `(k,0)`
//! share worker `k % n` under the symmetric placement — a narrow
//! dependency, no operand movement), and the partials fold in ascending
//! k — the same summation order as the previous in-task fold, so results
//! are byte-identical — while the reduction is charged as
//! `log2(workers)` rounds of the result's bytes
//! ([`Cluster::record_allreduce`]). The dispatch layer binds the product
//! replicated on every worker, which is what keeps model state resident
//! across a whole training job.

use std::sync::Arc;

use crate::hop::estimate::matmult_output_sparsity;
use crate::runtime::dist::pool::DistTask;
use crate::runtime::dist::{BlockedMatrix, Cluster};
use crate::runtime::matrix::agg::{self, AggOp};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::elementwise::{self, BinOp, UnaryOp};
use crate::runtime::matrix::{mult, reorg, Matrix};
use crate::util::error::{DmlError, Result};

// ---- sparse-aware per-block costing -------------------------------------
//
// Blocks carry their own dense/CSR format (see the module docs' CSR block
// lifecycle), so task FLOPs are charged by what the format-aware CP
// kernels actually execute, mirroring the formulas in `matrix::mult` —
// not by dense dimensions. Communication is charged by encoded bytes
// (`size_in_bytes` of the actual representation) throughout.

/// FLOPs of one per-block matmult `a %*% b`, matching the CP kernel the
/// operand formats select: 2·m·k·n dense×dense, 2·nnz(a)·n for a sparse
/// lhs, 2·m·nnz(b) for a sparse rhs, and for sparse×sparse the Gustavson
/// bound 2·nnz(a)·(nnz(b)/k) (lhs entries × average rhs row length).
fn mm_block_flops(a: &Matrix, b: &Matrix) -> u64 {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    match (a.is_sparse(), b.is_sparse()) {
        (false, false) => 2 * (m * k * n) as u64,
        (true, false) => 2 * (a.nnz() * n) as u64,
        (false, true) => 2 * (m * b.nnz()) as u64,
        (true, true) => 2 * (a.nnz() as u64) * (b.nnz() as u64) / (k.max(1) as u64),
    }
}

/// Upfront output-format decision for a blocked matmult, made from
/// operand *metadata* before any block materializes: feed the aggregate
/// operand sparsities through the planner's worst-case estimator
/// (`1 - (1 - sA·sB)^k`, [`matmult_output_sparsity`]) and ask whether a
/// block of the given extent with that estimated nnz clears the CSR turn
/// point. When it does, per-k partial products are produced straight in
/// CSR ([`mult::matmult_sparse_out`]) and the k-accumulation runs as
/// sparse unions — no dense allocate-then-convert for sparse×sparse
/// chains. Values are bit-identical either way (only the storage format
/// of intermediates differs), and the final
/// `examine_and_convert_with(thr)` still corrects estimate misses.
fn estimate_sparse_output(
    a: &BlockedMatrix,
    b: &BlockedMatrix,
    rows: usize,
    cols: usize,
    turn_point: f64,
) -> bool {
    let sa = a.nnz() as f64 / (a.rows() * a.cols()).max(1) as f64;
    let sb = b.nnz() as f64 / (b.rows() * b.cols()).max(1) as f64;
    let est = matmult_output_sparsity(sa, sb, a.cols());
    let est_nnz = (est * (rows * cols) as f64).ceil() as usize;
    Matrix::prefers_sparse_with(rows, cols, est_nnz, turn_point)
}

/// Cost (cell visits) of a cellwise map over one block: a sparse-safe op
/// touches only the stored entries of a CSR block; anything else scans
/// every cell. Dense blocks always cost their full cell count, so dense
/// accounting is unchanged.
#[inline]
fn block_work(m: &Matrix, sparse_safe: bool) -> u64 {
    if sparse_safe && m.is_sparse() {
        m.nnz() as u64
    } else {
        m.len() as u64
    }
}

/// Distributed `a %*% b` over local inputs: blockify, run the blocked
/// matmult, collect the result to the driver.
pub fn matmult(cluster: &Cluster, a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.rows() {
        return Err(DmlError::DimMismatch {
            op: "%*% (dist)".into(),
            lhs_rows: a.rows(),
            lhs_cols: a.cols(),
            rhs_rows: b.rows(),
            rhs_cols: b.cols(),
        });
    }
    let ab = cluster.blockify(a)?;
    let bb = cluster.blockify(b)?;
    matmult_blocked(cluster, &ab, &bb)?.to_local()
}

/// Which physical distributed matmult operator ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistMmOperator {
    /// Map-side matmult: broadcast the smaller input, no shuffle.
    MapMm,
    /// Replication-based matmult: shuffle both inputs.
    Rmm,
}

/// Which operands are already resident on the workers (block-cache
/// hits). Resident operands incur no fresh broadcast/shuffle volume.
#[derive(Clone, Copy, Debug, Default)]
pub struct Residency {
    pub lhs: bool,
    pub rhs: bool,
}

/// Blocked matmult with cost-based mapmm/rmm selection (both operands
/// treated as freshly distributed).
pub fn matmult_blocked(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
) -> Result<BlockedMatrix> {
    matmult_blocked_reuse(cluster, a, b, Residency::default())
}

/// Blocked matmult with cache-aware communication accounting: resident
/// operands are not re-broadcast (mapmm) or re-replicated (rmm).
pub fn matmult_blocked_reuse(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
    resident: Residency,
) -> Result<BlockedMatrix> {
    if a.cols() != b.rows() || a.block_size() != b.block_size() {
        return Err(DmlError::rt(format!(
            "blocked matmult: incompatible operands {}x{} (block {}) @ {}x{} (block {})",
            a.rows(),
            a.cols(),
            a.block_size(),
            b.rows(),
            b.cols(),
            b.block_size()
        )));
    }
    if is_allreduce_matmult(a, b) {
        return matmult_allreduce(cluster, a, b);
    }
    let (op, _) = choose_mm_operator(cluster, a, b);
    // Communication accounting per the chosen plan, skipping operands
    // whose partitions are already resident on the workers.
    match op {
        DistMmOperator::MapMm => {
            // Broadcast the smaller side to every worker — unless its
            // blocks are resident from a previous broadcast.
            let a_small = a.size_in_bytes() <= b.size_in_bytes();
            let (small, small_resident) = if a_small {
                (a.size_in_bytes(), resident.lhs)
            } else {
                (b.size_in_bytes(), resident.rhs)
            };
            if !small_resident {
                cluster.record_broadcast(small as u64);
            }
        }
        DistMmOperator::Rmm => {
            // Each block of A is replicated across B's block columns and
            // vice versa (SystemML's replication-based matmult); resident
            // sides keep their replicated copies.
            let mut shuffled = 0u64;
            if !resident.lhs {
                shuffled += a.size_in_bytes() as u64 * b.block_cols() as u64;
            }
            if !resident.rhs {
                shuffled += b.size_in_bytes() as u64 * a.block_rows() as u64;
            }
            cluster.record_shuffle(shuffled);
        }
    }
    // The arithmetic is identical for both plans: out(i,j) = Σ_k A(i,k)B(k,j).
    // One task per output block; the k-accumulation runs *inside* the
    // task in ascending k order, so the summation order is exactly the
    // serial loop's and results are byte-identical to threads=1.
    let bs = a.block_size();
    let thr = cluster.sparsity_threshold();
    let (brows, bcols, bk) = (a.block_rows(), b.block_cols(), a.block_cols());
    let mut tasks: Vec<DistTask<Result<(Matrix, u64)>>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let lhs: Vec<Arc<Matrix>> = (0..bk).map(|k| a.shared_block(i, k)).collect();
            let rhs: Vec<Arc<Matrix>> = (0..bk).map(|k| b.shared_block(k, j)).collect();
            let r = (a.rows() - i * bs).min(bs);
            let c = (b.cols() - j * bs).min(bs);
            // Decide the accumulator format *before* materializing any
            // partial: a sparse-estimated output block accumulates in CSR
            // from the first product on (no dense detour).
            let sparse_out = estimate_sparse_output(a, b, r, c, thr);
            tasks.push((
                cluster.worker_for(i, j),
                Box::new(move || {
                    let mut acc: Option<Matrix> = None;
                    let mut flops = 0u64;
                    for (lb, rb) in lhs.iter().zip(rhs.iter()) {
                        flops += mm_block_flops(lb, rb);
                        let p = if sparse_out {
                            mult::matmult_sparse_out(lb, rb)?
                        } else {
                            mult::matmult(lb, rb)?
                        };
                        acc = Some(match acc {
                            None => p,
                            Some(q) => elementwise::binary(&q, &p, BinOp::Add)?,
                        });
                    }
                    // An empty k extent (0-column lhs) contributes an
                    // all-zero product block — empty matrices flow
                    // legally from indexing.
                    let out = match acc {
                        Some(m) => m,
                        None => Matrix::zeros(r, c),
                    };
                    Ok((out.examine_and_convert_with(thr), flops))
                }),
            ));
        }
    }
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (out, flops) = res?;
        cluster.record_task(cluster.worker_for(idx / bcols, idx % bcols), flops);
        blocks.push(out);
    }
    Ok(BlockedMatrix::from_blocks(a.rows(), b.cols(), bs, blocks))
}

/// Is `a %*% b` a gradient-shaped **allreduce matmult**: single-block
/// output folded over a multi-block inner dimension? Shared by the
/// operator (which routes it through [`matmult_allreduce`]) and the
/// dispatch layer (which binds the product replicated), so the two can
/// never disagree.
pub fn is_allreduce_matmult(a: &BlockedMatrix, b: &BlockedMatrix) -> bool {
    a.block_rows() <= 1 && b.block_cols() <= 1 && a.block_cols() > 1
}

/// Tree-allreduce matmult for a single-block output over a multi-block
/// inner dimension: one task per inner block k computes the partial
/// product `A(0,k) %*% B(k,0)` on worker `k % n` — where *both* operand
/// blocks already live under the symmetric placement, so no operand
/// moves — and the partials fold in **ascending k**, the exact summation
/// order of the general operator's in-task fold (byte-identical results,
/// independent of worker/thread counts). The reduction is charged as
/// `log2(workers)` rounds of the result's bytes.
fn matmult_allreduce(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
) -> Result<BlockedMatrix> {
    let bk = a.block_cols();
    let sparse_out =
        estimate_sparse_output(a, b, a.rows(), b.cols(), cluster.sparsity_threshold());
    let mut tasks: Vec<DistTask<Result<(Matrix, u64)>>> = Vec::with_capacity(bk);
    for k in 0..bk {
        let lb = a.shared_block(0, k);
        let rb = b.shared_block(k, 0);
        tasks.push((
            cluster.worker_for(0, k),
            Box::new(move || {
                let flops = mm_block_flops(&lb, &rb);
                let p = if sparse_out {
                    mult::matmult_sparse_out(&lb, &rb)?
                } else {
                    mult::matmult(&lb, &rb)?
                };
                Ok((p, flops))
            }),
        ));
    }
    let mut acc: Option<Matrix> = None;
    for (k, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (p, flops) = res?;
        cluster.record_task(cluster.worker_for(0, k), flops);
        acc = Some(match acc {
            None => p,
            Some(q) => elementwise::binary(&q, &p, BinOp::Add)?,
        });
    }
    let out = acc
        .ok_or_else(|| DmlError::rt("allreduce matmult: empty inner dimension"))?
        .examine_and_convert_with(cluster.sparsity_threshold());
    // The reduction moves the result's *encoded* bytes — a sparse
    // gradient allreduces at CSR size.
    cluster.record_allreduce(out.size_in_bytes() as u64);
    Ok(BlockedMatrix::from_blocks(a.rows(), b.cols(), a.block_size(), vec![out]))
}

/// Cost-based operator selection: mapmm broadcasts the smaller input to
/// all workers; rmm replicates both sides through a shuffle. Returns the
/// chosen operator and its modeled communication volume.
pub fn choose_mm_operator(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
) -> (DistMmOperator, u64) {
    let mapmm_cost =
        a.size_in_bytes().min(b.size_in_bytes()) as u64 * cluster.num_workers() as u64;
    let rmm_cost = a.size_in_bytes() as u64 * b.block_cols() as u64
        + b.size_in_bytes() as u64 * a.block_rows() as u64;
    if mapmm_cost <= rmm_cost {
        (DistMmOperator::MapMm, mapmm_cost)
    } else {
        (DistMmOperator::Rmm, rmm_cost)
    }
}

/// Blocked cellwise binary op; operands must have identical shapes.
/// Re-blockifies if the block grids disagree.
pub fn binary_blocked(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
    op: BinOp,
) -> Result<BlockedMatrix> {
    if a.shape() != b.shape() {
        return Err(DmlError::DimMismatch {
            op: format!("{op:?} (dist)"),
            lhs_rows: a.rows(),
            lhs_cols: a.cols(),
            rhs_rows: b.rows(),
            rhs_cols: b.cols(),
        });
    }
    if a.block_size() != b.block_size() {
        // Align the right side to the left grid (one shuffle).
        cluster.record_shuffle(b.size_in_bytes() as u64);
        let rb = cluster.blockify(&b.to_local()?)?;
        return binary_blocked(cluster, a, &rb, op);
    }
    let (brows, bcols) = (a.block_rows(), a.block_cols());
    let mut tasks: Vec<DistTask<Result<Matrix>>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let lb = a.shared_block(i, j);
            let rb = b.shared_block(i, j);
            tasks.push((
                cluster.worker_for(i, j),
                Box::new(move || elementwise::binary(&lb, &rb, op)),
            ));
        }
    }
    let safe = op.sparse_safe();
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        let cost = block_work(a.block(i, j), safe).max(block_work(b.block(i, j), safe));
        cluster.record_task(cluster.worker_for(i, j), cost);
        blocks.push(res?);
    }
    Ok(BlockedMatrix::from_blocks(a.rows(), a.cols(), a.block_size(), blocks))
}

/// Distributed cellwise binary over local inputs.
pub fn binary(cluster: &Cluster, a: &Matrix, b: &Matrix, op: BinOp) -> Result<Matrix> {
    let ab = cluster.blockify(a)?;
    let bb = cluster.blockify(b)?;
    binary_blocked(cluster, &ab, &bb, op)?.to_local()
}

/// Distributed transpose (`t(X)`) as a real blocked reorg: the output
/// grid swaps block indices ((i,j) → (j,i)) and every block transposes
/// locally on its worker. With the symmetric hash placement
/// (`worker_for(i,j) = (i+j) % n`), block (i,j) and its transposed
/// position (j,i) land on the *same* worker, so the reorg is
/// shuffle-free — a narrow dependency, like Spark transpose over a
/// symmetric partitioner. No collect, no re-blockify.
pub fn transpose_blocked(cluster: &Cluster, m: &BlockedMatrix) -> BlockedMatrix {
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut tasks: Vec<DistTask<Matrix>> = Vec::with_capacity(brows * bcols);
    // Output grid is bcols × brows, row-major over the swapped indices.
    for j in 0..bcols {
        for i in 0..brows {
            let b = m.shared_block(i, j);
            tasks.push((cluster.worker_for(i, j), Box::new(move || reorg::transpose(&b))));
        }
    }
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, out) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (j, i) = (idx / brows, idx % brows);
        // CSR transpose is a counting sort over stored entries.
        cluster.record_task(cluster.worker_for(i, j), block_work(m.block(i, j), true));
        blocks.push(out);
    }
    BlockedMatrix::from_blocks(m.cols(), m.rows(), m.block_size(), blocks)
}

/// Blocked matrix∘scalar cellwise op: a map over resident blocks (no
/// communication). `swapped` computes `s op x` instead of `x op s`.
pub fn scalar_blocked(
    cluster: &Cluster,
    m: &BlockedMatrix,
    s: f64,
    op: BinOp,
    swapped: bool,
) -> Result<BlockedMatrix> {
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut tasks: Vec<DistTask<Result<Matrix>>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            tasks.push((
                cluster.worker_for(i, j),
                Box::new(move || elementwise::scalar_op(&b, s, op, swapped)),
            ));
        }
    }
    // Sparse-safe iff the op maps an untouched zero cell to zero.
    let safe = if swapped { op.apply(s, 0.0) == 0.0 } else { op.apply(0.0, s) == 0.0 };
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        cluster.record_task(cluster.worker_for(i, j), block_work(m.block(i, j), safe));
        blocks.push(res?);
    }
    Ok(BlockedMatrix::from_blocks(m.rows(), m.cols(), m.block_size(), blocks))
}

/// Blocked unary cellwise op (exp, sqrt, neg, ...): a map over blocks.
pub fn unary_blocked(cluster: &Cluster, m: &BlockedMatrix, op: UnaryOp) -> BlockedMatrix {
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut tasks: Vec<DistTask<Matrix>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            tasks.push((cluster.worker_for(i, j), Box::new(move || elementwise::unary(&b, op))));
        }
    }
    let safe = op.sparse_safe();
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, out) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        cluster.record_task(cluster.worker_for(i, j), block_work(m.block(i, j), safe));
        blocks.push(out);
    }
    BlockedMatrix::from_blocks(m.rows(), m.cols(), m.block_size(), blocks)
}

/// Blocked full aggregate: per-block partials on the workers, combined on
/// the driver (the classic map + reduce aggregate).
pub fn full_agg_blocked(cluster: &Cluster, m: &BlockedMatrix, op: AggOp) -> f64 {
    // Partial op per block: Mean aggregates via Sum (weighted by count).
    let partial_op = match op {
        AggOp::Mean => AggOp::Sum,
        other => other,
    };
    let bcols = m.block_cols();
    let mut tasks: Vec<DistTask<f64>> = Vec::with_capacity(m.block_rows() * bcols);
    for i in 0..m.block_rows() {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            tasks.push((cluster.worker_for(i, j), Box::new(move || agg::full_agg(&b, partial_op))));
        }
    }
    // Per-block partials come back in grid order; the driver-side folds
    // below consume them in exactly the serial iteration order.
    let partials = cluster.run_tasks(tasks);
    for (idx, _) in partials.iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        cluster.record_task(cluster.worker_for(i, j), m.block(i, j).len() as u64);
    }
    match op {
        AggOp::Sum | AggOp::SumSq => partials.iter().sum(),
        AggOp::Mean => partials.iter().sum::<f64>() / (m.rows() * m.cols()).max(1) as f64,
        AggOp::Min => partials.iter().fold(f64::INFINITY, |a, b| a.min(*b)),
        AggOp::Max => partials.iter().fold(f64::NEG_INFINITY, |a, b| a.max(*b)),
        AggOp::Prod => partials.iter().product(),
    }
}

/// Distributed full aggregate over a local input.
pub fn full_agg(cluster: &Cluster, m: &Matrix, op: AggOp) -> Result<f64> {
    Ok(full_agg_blocked(cluster, &cluster.blockify(m)?, op))
}

/// Blocked row aggregate → rows×1 vector: per-block row partials combined
/// across the block columns of each block row.
pub fn row_agg_blocked(cluster: &Cluster, m: &BlockedMatrix, op: AggOp) -> Result<Matrix> {
    let partial_op = match op {
        AggOp::Mean => AggOp::Sum,
        other => other,
    };
    let combine = combine_binop(op);
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut tasks: Vec<DistTask<Matrix>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            tasks.push((cluster.worker_for(i, j), Box::new(move || agg::row_agg(&b, partial_op))));
        }
    }
    // Partials fold on the driver in ascending j per block row — the
    // serial order, so the combine is byte-identical to threads=1.
    let mut partials = cluster.run_tasks(tasks).into_iter();
    let mut out = DenseMatrix::zeros(m.rows(), 1);
    for i in 0..brows {
        let mut acc: Option<Matrix> = None;
        for j in 0..bcols {
            let p = partials.next().expect("row-agg partial per block");
            cluster.record_task(cluster.worker_for(i, j), m.block(i, j).len() as u64);
            acc = Some(match acc {
                None => p,
                Some(q) => elementwise::binary(&q, &p, combine)?,
            });
        }
        let mut block_vec =
            acc.ok_or_else(|| DmlError::rt("blocked row agg: empty grid"))?.to_dense();
        if op == AggOp::Mean {
            for v in block_vec.data.iter_mut() {
                *v /= m.cols() as f64;
            }
        }
        out.assign(i * m.block_size(), 0, &block_vec)?;
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// Blocked column aggregate → 1×cols vector.
pub fn col_agg_blocked(cluster: &Cluster, m: &BlockedMatrix, op: AggOp) -> Result<Matrix> {
    let partial_op = match op {
        AggOp::Mean => AggOp::Sum,
        other => other,
    };
    let combine = combine_binop(op);
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    // Tasks in the serial iteration order (j outer, i inner) so the
    // driver-side fold below consumes partials in the same order.
    let mut tasks: Vec<DistTask<Matrix>> = Vec::with_capacity(brows * bcols);
    for j in 0..bcols {
        for i in 0..brows {
            let b = m.shared_block(i, j);
            tasks.push((cluster.worker_for(i, j), Box::new(move || agg::col_agg(&b, partial_op))));
        }
    }
    let mut partials = cluster.run_tasks(tasks).into_iter();
    let mut out = DenseMatrix::zeros(1, m.cols());
    for j in 0..bcols {
        let mut acc: Option<Matrix> = None;
        for i in 0..brows {
            let p = partials.next().expect("col-agg partial per block");
            cluster.record_task(cluster.worker_for(i, j), m.block(i, j).len() as u64);
            acc = Some(match acc {
                None => p,
                Some(q) => elementwise::binary(&q, &p, combine)?,
            });
        }
        let mut block_vec =
            acc.ok_or_else(|| DmlError::rt("blocked col agg: empty grid"))?.to_dense();
        if op == AggOp::Mean {
            for v in block_vec.data.iter_mut() {
                *v /= m.rows() as f64;
            }
        }
        out.assign(0, j * m.block_size(), &block_vec)?;
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// Distributed row aggregate over a local input.
pub fn row_agg(cluster: &Cluster, m: &Matrix, op: AggOp) -> Result<Matrix> {
    row_agg_blocked(cluster, &cluster.blockify(m)?, op)
}

/// Distributed column aggregate over a local input.
pub fn col_agg(cluster: &Cluster, m: &Matrix, op: AggOp) -> Result<Matrix> {
    col_agg_blocked(cluster, &cluster.blockify(m)?, op)
}

// ---- indexing ----------------------------------------------------------

/// Blocked right-index `X[rl:ru, cl:cu]` (0-based, half-open): pure block
/// **selection** plus edge-block **trim**. When the slice origin is
/// block-aligned (`rl % bs == 0 && cl % bs == 0` — every mini-batch
/// `X[beg:end,]` with a batch size that is a multiple of the block size)
/// each output block is one input block, possibly trimmed at the edges:
/// a narrow dependency, no shuffle. A non-aligned origin re-aligns cells
/// across block boundaries, which is accounted as a shuffle of the
/// output's bytes (SystemML's general `rightIndex` Spark instruction).
/// Is a slice a pure block **selection/trim** — every output block drawn
/// from a single source block (a narrow, shuffle-free dependency)? Per
/// axis that holds when the origin is block-aligned, or when the whole
/// extent fits inside one source block (an interior trim). Shared by the
/// slice operator's shuffle accounting and the dispatch layer's `IDX`
/// EXPLAIN line so the two can never disagree.
pub fn slice_selection_only(bs: usize, rl: usize, ru: usize, cl: usize, cu: usize) -> bool {
    let axis = |off: usize, len: usize| off % bs == 0 || off % bs + len <= bs;
    axis(rl, ru - rl) && axis(cl, cu - cl)
}

pub fn slice_blocked(
    cluster: &Cluster,
    m: &BlockedMatrix,
    rl: usize,
    ru: usize,
    cl: usize,
    cu: usize,
) -> Result<BlockedMatrix> {
    if ru > m.rows() || cu > m.cols() || rl >= ru || cl >= cu {
        return Err(reorg::slice_range_error(rl, ru, cl, cu, m.rows(), m.cols()));
    }
    let bs = m.block_size();
    let thr = cluster.sparsity_threshold();
    let (orows, ocols) = (ru - rl, cu - cl);
    let (obr, obc) = (super::ceil_div(orows, bs), super::ceil_div(ocols, bs));
    // Tasks share the source grid (`Arc` bumps) so the gathers can run
    // concurrently without borrowing `m`.
    let src = Arc::new(m.clone());
    let mut tasks: Vec<DistTask<Result<Arc<Matrix>>>> = Vec::with_capacity(obr * obc);
    let mut workers = Vec::with_capacity(obr * obc);
    for i in 0..obr {
        let grl = rl + i * bs;
        let gru = (grl + bs).min(ru);
        for j in 0..obc {
            let gcl = cl + j * bs;
            let gcu = (gcl + bs).min(cu);
            // Task attribution: a single-source selection/trim is a
            // narrow dependency executed where the source block lives
            // (that is what makes the aligned case genuinely
            // shuffle-free); a straddling gather is charged as a
            // shuffle below and lands on the output block's owner.
            let (sbi, sbj) = (grl / bs, gcl / bs);
            let single_source = sbi == (gru - 1) / bs && sbj == (gcu - 1) / bs;
            let worker = if single_source {
                cluster.worker_for(sbi, sbj)
            } else {
                cluster.worker_for(i, j)
            };
            workers.push(worker);
            let src = Arc::clone(&src);
            tasks.push((worker, Box::new(move || gather_region(&src, thr, grl, gru, gcl, gcu))));
        }
    }
    let mut blocks = Vec::with_capacity(obr * obc);
    for (res, worker) in cluster.run_tasks(tasks).into_iter().zip(workers) {
        let out = res?;
        cluster.record_task(worker, out.len() as u64);
        blocks.push(out);
    }
    let out = BlockedMatrix::from_shared_blocks(orows, ocols, bs, blocks);
    // A non-aligned slice re-aligns cells across block boundaries: one
    // shuffle of the output's *encoded* bytes — a 1%-dense mini-batch
    // slice moves CSR bytes, not dense dims (SystemML's general
    // `rightIndex` Spark instruction, sparse-sized).
    if !slice_selection_only(bs, rl, ru, cl, cu) {
        cluster.record_shuffle(out.size_in_bytes() as u64);
    }
    Ok(out)
}

/// Assemble the cells of global region [grl,gru)×[gcl,gcu) from the
/// source blocks covering it (one block when aligned; up to four when the
/// region straddles block boundaries). Whole-block selection shares the
/// source block (an `Arc` bump, no copy).
fn gather_region(
    m: &BlockedMatrix,
    thr: f64,
    grl: usize,
    gru: usize,
    gcl: usize,
    gcu: usize,
) -> Result<Arc<Matrix>> {
    let bs = m.block_size();
    let (bi0, bi1) = (grl / bs, (gru - 1) / bs);
    let (bj0, bj1) = (gcl / bs, (gcu - 1) / bs);
    if bi0 == bi1 && bj0 == bj1 {
        // Single source block: whole-block selection (shared — no copy,
        // no nnz rescan) or an edge trim.
        let b = m.block(bi0, bj0);
        let (r0, c0) = (grl - bi0 * bs, gcl - bj0 * bs);
        let (r1, c1) = (gru - bi0 * bs, gcu - bj0 * bs);
        if (r0, c0) == (0, 0) && (r1, c1) == b.shape() {
            return Ok(m.shared_block(bi0, bj0));
        }
        return Ok(Arc::new(reorg::slice(b, r0, r1, c0, c1)?.examine_and_convert_with(thr)));
    }
    // Straddling region: gather from each overlapping source block.
    let mut out = DenseMatrix::zeros(gru - grl, gcu - gcl);
    for bi in bi0..=bi1 {
        for bj in bj0..=bj1 {
            let b = m.block(bi, bj);
            let br0 = (bi * bs).max(grl);
            let br1 = (bi * bs + b.rows()).min(gru);
            let bc0 = (bj * bs).max(gcl);
            let bc1 = (bj * bs + b.cols()).min(gcu);
            if br0 >= br1 || bc0 >= bc1 {
                continue;
            }
            let piece =
                reorg::slice(b, br0 - bi * bs, br1 - bi * bs, bc0 - bj * bs, bc1 - bj * bs)?;
            out.assign(br0 - grl, bc0 - gcl, &piece.to_dense())?;
        }
    }
    Ok(Arc::new(Matrix::Dense(out).examine_and_convert_with(thr)))
}

/// Blocked left-index write `X[rl.., cl..] = src`: only the blocks the
/// region touches are *rewritten* (tasks and FLOP accounting cover just
/// those); untouched blocks are carried over unchanged, so the target
/// never leaves the cluster. Note the carry-over is a by-value block
/// copy in this simulation (`Vec<Matrix>` grid) — refcounted block
/// sharing is a listed refinement. The patch ships as a cluster-wide
/// broadcast variable (the broadcast primitive charges every worker,
/// like Spark's) unless `src_resident` says its cells already live
/// cluster-side (a gathered blocked rhs).
pub fn left_index_blocked(
    cluster: &Cluster,
    target: &BlockedMatrix,
    rl: usize,
    cl: usize,
    src: &Matrix,
    src_resident: bool,
) -> Result<BlockedMatrix> {
    let (sr, sc) = src.shape();
    if rl + sr > target.rows() || cl + sc > target.cols() {
        return Err(reorg::left_index_range_error(sr, sc, rl, cl, target.rows(), target.cols()));
    }
    if sr == 0 || sc == 0 {
        return Ok(target.clone());
    }
    if !src_resident {
        cluster.record_broadcast(src.size_in_bytes() as u64);
    }
    rewrite_touched_blocks(cluster, target, rl, rl + sr, cl, cl + sc, |gr0, gr1, gc0, gc1| {
        reorg::slice(src, gr0 - rl, gr1 - rl, gc0 - cl, gc1 - cl)
    })
}

/// Blocked left-index **fill** `X[rl:ru, cl:cu] = scalar`: the touched
/// blocks build their constant patch worker-side — the scalar rides the
/// task, so there is no region-sized broadcast and no driver
/// materialization of the region (the whole point of keeping the target
/// blocked).
pub fn left_index_fill_blocked(
    cluster: &Cluster,
    target: &BlockedMatrix,
    rl: usize,
    ru: usize,
    cl: usize,
    cu: usize,
    v: f64,
) -> Result<BlockedMatrix> {
    if ru > target.rows() || cu > target.cols() || rl >= ru || cl >= cu {
        return Err(reorg::slice_range_error(rl, ru, cl, cu, target.rows(), target.cols()));
    }
    rewrite_touched_blocks(cluster, target, rl, ru, cl, cu, |gr0, gr1, gc0, gc1| {
        Ok(Matrix::filled(gr1 - gr0, gc1 - gc0, v))
    })
}

/// Shared touched-block rewrite: carry every block of `target` over and
/// replace only the blocks intersecting [rl,ru)×[cl,cu), each rewritten
/// with the patch produced by `patch_for(gr0, gr1, gc0, gc1)` (global
/// half-open cell bounds of the intersection). Tasks cover touched
/// blocks only.
fn rewrite_touched_blocks(
    cluster: &Cluster,
    target: &BlockedMatrix,
    rl: usize,
    ru: usize,
    cl: usize,
    cu: usize,
    mut patch_for: impl FnMut(usize, usize, usize, usize) -> Result<Matrix>,
) -> Result<BlockedMatrix> {
    let bs = target.block_size();
    let (brows, bcols) = (target.block_rows(), target.block_cols());
    let (bi0, bi1) = (rl / bs, (ru - 1) / bs);
    let (bj0, bj1) = (cl / bs, (cu - 1) / bs);
    // One pass over the grid: untouched blocks are *shared* with the
    // source grid (an `Arc` bump — the write is O(touched) in memory
    // traffic); touched blocks are rewritten by pool tasks, never cloned
    // first. The patches are cut driver-side (`patch_for` borrows the
    // broadcast source), then each rewrite runs on the touched block's
    // worker.
    let mut blocks: Vec<Option<Arc<Matrix>>> = Vec::with_capacity(brows * bcols);
    let mut tasks: Vec<DistTask<Result<Matrix>>> = Vec::new();
    let mut touched_meta: Vec<(usize, usize, u64)> = Vec::new(); // (grid idx, worker, flops)
    for i in 0..brows {
        for j in 0..bcols {
            let b = target.block(i, j);
            let touched =
                (bi0..=bi1).contains(&i) && (bj0..=bj1).contains(&j);
            if !touched {
                blocks.push(Some(target.shared_block(i, j)));
                continue;
            }
            let gr0 = (i * bs).max(rl);
            let gr1 = (i * bs + b.rows()).min(ru);
            let gc0 = (j * bs).max(cl);
            let gc1 = (j * bs + b.cols()).min(cu);
            if gr0 >= gr1 || gc0 >= gc1 {
                blocks.push(Some(target.shared_block(i, j)));
                continue;
            }
            let patch = patch_for(gr0, gr1, gc0, gc1)?;
            let block = target.shared_block(i, j);
            let (r0, c0) = (gr0 - i * bs, gc0 - j * bs);
            let worker = cluster.worker_for(i, j);
            touched_meta.push((blocks.len(), worker, ((gr1 - gr0) * (gc1 - gc0)) as u64));
            tasks.push((worker, Box::new(move || reorg::left_index(&block, r0, c0, &patch))));
            blocks.push(None);
        }
    }
    for ((idx, worker, flops), res) in
        touched_meta.into_iter().zip(cluster.run_tasks(tasks).into_iter())
    {
        cluster.record_task(worker, flops);
        // Rewritten blocks re-examine their exact nnz: a write of zeros
        // into a sparse block (or of dense data into one) crosses the
        // representation threshold here.
        blocks[idx] = Some(Arc::new(res?.examine_and_convert_with(cluster.sparsity_threshold())));
    }
    let blocks = blocks.into_iter().map(|b| b.expect("every grid slot filled")).collect();
    Ok(BlockedMatrix::from_shared_blocks(target.rows(), target.cols(), bs, blocks))
}

// ---- broadcast cellwise -------------------------------------------------

/// Map-side broadcast cellwise join: the row/col-vector rhs `v` is
/// broadcast to every worker (charged to broadcast accounting unless
/// already resident) and joined against each resident block of `m` —
/// `X - mu` / `X / sigma` run without collecting `X`. Mirrors the CP
/// kernel exactly: only a rhs vector broadcasts, and a true shape
/// mismatch raises the same `DimMismatch`.
pub fn binary_broadcast_blocked(
    cluster: &Cluster,
    m: &BlockedMatrix,
    v: &Matrix,
    op: BinOp,
    v_resident: bool,
) -> Result<BlockedMatrix> {
    let ((mr, mc), (vr, vc)) = (m.shape(), v.shape());
    let col = vr == mr && vc == 1;
    let row = vc == mc && vr == 1;
    if !(col || row) {
        return Err(DmlError::DimMismatch {
            op: format!("{op:?}"),
            lhs_rows: mr,
            lhs_cols: mc,
            rhs_rows: vr,
            rhs_cols: vc,
        });
    }
    if !v_resident {
        cluster.record_broadcast(v.size_in_bytes() as u64);
    }
    let bs = m.block_size();
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    // Each worker slices the matching vector segment out of its broadcast
    // copy and joins it against the resident block.
    let bv = Arc::new(v.clone());
    let mut tasks: Vec<DistTask<Result<Matrix>>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            let bv = Arc::clone(&bv);
            tasks.push((
                cluster.worker_for(i, j),
                Box::new(move || {
                    let seg = if col {
                        reorg::slice(&bv, i * bs, i * bs + b.rows(), 0, 1)?
                    } else {
                        reorg::slice(&bv, 0, 1, j * bs, j * bs + b.cols())?
                    };
                    elementwise::binary(&b, &seg, op)
                }),
            ));
        }
    }
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        cluster.record_task(cluster.worker_for(i, j), m.block(i, j).len() as u64);
        blocks.push(res?);
    }
    Ok(BlockedMatrix::from_blocks(mr, mc, bs, blocks))
}

/// Blocked rowIndexMax: each worker scans its block's rows into per-row
/// **candidates** and the driver folds them across the row's column
/// groups in ascending j (the rows×1 output returns with the job, like
/// the axis aggregates). The composition reproduces **CP's exact
/// left-to-right strict-`>` scan** (`agg::row_index_max`):
///
/// * the j=0 block scans with CP's initialization — the row's first cell
///   is the initial best, so a leading NaN sticks (no cell compares `>`
///   against NaN);
/// * later blocks scan against `-inf` and produce `Some((value, global
///   column))` only for cells that could displace *some* running best —
///   NaN/`-inf` cells never can, so an all-NaN block yields `None`
///   instead of a poisoned local argmax;
/// * the driver takes a j>0 candidate only on strict `>`, preserving
///   first-occurrence ties.
///
/// A block's chained scan ends at the leftmost occurrence of its maximum,
/// which is exactly what the block-local scan emits — so the fold is
/// byte-identical to the serial chained scan for every NaN/tie layout.
pub fn row_index_max_blocked(cluster: &Cluster, m: &BlockedMatrix) -> Result<Matrix> {
    let rows = m.rows();
    let bs = m.block_size();
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut tasks: Vec<DistTask<Vec<Option<(f64, f64)>>>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            tasks.push((
                cluster.worker_for(i, j),
                Box::new(move || {
                    let d = b.to_dense();
                    let mut cands = Vec::with_capacity(d.rows);
                    for r in 0..d.rows {
                        let row = d.row(r);
                        if j == 0 {
                            // CP's initial best: the row's first cell,
                            // NaN included (a NaN best is never
                            // displaced within this block).
                            let mut bv = row[0];
                            let mut bi = 1.0f64;
                            for (c, v) in row.iter().enumerate().skip(1) {
                                if *v > bv {
                                    bv = *v;
                                    bi = (c + 1) as f64;
                                }
                            }
                            cands.push(Some((bv, bi)));
                        } else {
                            // Leftmost strict maximum vs -inf; NaN/-inf
                            // cells never become candidates.
                            let mut cand: Option<(f64, f64)> = None;
                            for (c, v) in row.iter().enumerate() {
                                let wins = match cand {
                                    None => *v > f64::NEG_INFINITY,
                                    Some((bv, _)) => *v > bv,
                                };
                                if wins {
                                    cand = Some((*v, (j * bs + c + 1) as f64));
                                }
                            }
                            cands.push(cand);
                        }
                    }
                    cands
                }),
            ));
        }
    }
    let results = cluster.run_tasks(tasks);
    let mut best_val = vec![f64::NEG_INFINITY; rows];
    let mut best_idx = vec![1.0f64; rows];
    for (idx, cands) in results.iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        cluster.record_task(cluster.worker_for(i, j), m.block(i, j).len() as u64);
        for (r, cand) in cands.iter().enumerate() {
            let g = i * bs + r;
            if j == 0 {
                let (v, ix) = cand.expect("j=0 scan always yields a best");
                best_val[g] = v;
                best_idx[g] = ix;
            } else if let Some((v, ix)) = cand {
                if *v > best_val[g] {
                    best_val[g] = *v;
                    best_idx[g] = *ix;
                }
            }
        }
    }
    let mut out = DenseMatrix::zeros(rows, 1);
    out.data.copy_from_slice(&best_idx);
    Ok(Matrix::Dense(out))
}

/// How block-row/-column partial aggregates are merged across blocks.
fn combine_binop(op: AggOp) -> BinOp {
    match op {
        AggOp::Sum | AggOp::Mean | AggOp::SumSq => BinOp::Add,
        AggOp::Min => BinOp::Min,
        AggOp::Max => BinOp::Max,
        AggOp::Prod => BinOp::Mul,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};
    use crate::util::quickcheck::approx_eq_slice;

    #[test]
    fn blocked_matmult_odd_shapes_match_local() {
        let cluster = Cluster::new(3, 16);
        let a = rand(45, 37, -1.0, 1.0, 1.0, Pdf::Uniform, 21).unwrap();
        let b = rand(37, 29, -1.0, 1.0, 0.3, Pdf::Uniform, 22).unwrap();
        let local = mult::matmult(&a, &b).unwrap();
        let dist = matmult(&cluster, &a, &b).unwrap();
        assert!(approx_eq_slice(&dist.to_row_major_vec(), &local.to_row_major_vec(), 1e-9));
    }

    #[test]
    fn row_col_aggs_match_local() {
        let cluster = Cluster::new(2, 8);
        let m = rand(21, 13, -2.0, 2.0, 0.6, Pdf::Uniform, 23).unwrap();
        for op in [AggOp::Sum, AggOp::Mean, AggOp::Min, AggOp::Max] {
            let r_local = agg::row_agg(&m, op);
            let r_dist = row_agg(&cluster, &m, op).unwrap();
            assert!(
                approx_eq_slice(&r_dist.to_row_major_vec(), &r_local.to_row_major_vec(), 1e-12),
                "row {op:?}"
            );
            let c_local = agg::col_agg(&m, op);
            let c_dist = col_agg(&cluster, &m, op).unwrap();
            assert!(
                approx_eq_slice(&c_dist.to_row_major_vec(), &c_local.to_row_major_vec(), 1e-12),
                "col {op:?}"
            );
        }
    }

    #[test]
    fn sparse_sparse_blocked_estimates_csr_upfront() {
        let cluster = Cluster::new(3, 64);
        let a = rand(160, 160, -1.0, 1.0, 0.02, Pdf::Uniform, 91).unwrap();
        let b = rand(160, 160, -1.0, 1.0, 0.02, Pdf::Uniform, 92).unwrap();
        let ab = BlockedMatrix::from_local(&a, 64).unwrap();
        let bb = BlockedMatrix::from_local(&b, 64).unwrap();
        // The metadata-only estimate commits to CSR accumulators before
        // any partial product materializes (2%×2% over k=160 stays well
        // under the turn point)...
        assert!(estimate_sparse_output(&ab, &bb, 64, 64, cluster.sparsity_threshold()));
        // ...and values match the local kernel (approx: blocked splits k,
        // so summation order differs from the unblocked reference).
        let out = matmult_blocked(&cluster, &ab, &bb).unwrap();
        let local = mult::matmult(&a, &b).unwrap();
        assert!(approx_eq_slice(
            &out.to_local().unwrap().to_row_major_vec(),
            &local.to_row_major_vec(),
            1e-9
        ));
        // Dense operands at full density keep the dense path.
        let d = BlockedMatrix::from_local(
            &rand(160, 160, -1.0, 1.0, 1.0, Pdf::Uniform, 93).unwrap(),
            64,
        )
        .unwrap();
        assert!(!estimate_sparse_output(&d, &d, 64, 64, cluster.sparsity_threshold()));
    }

    #[test]
    fn allreduce_matmult_byte_identical_across_workers_and_threads() {
        let am = rand(8, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 81).unwrap();
        let bm = rand(96, 8, -1.0, 1.0, 0.6, Pdf::Uniform, 82).unwrap();
        let a = BlockedMatrix::from_local(&am, 16).unwrap();
        let b = BlockedMatrix::from_local(&bm, 16).unwrap();
        assert!(is_allreduce_matmult(&a, &b), "1x6 @ 6x1 grid folds over k");
        let reference = matmult_blocked(&Cluster::with_threads(1, 16, 1), &a, &b)
            .unwrap()
            .to_row_major_vec();
        for workers in [1usize, 2, 4, 7] {
            for threads in [1usize, 4] {
                let cluster = Cluster::with_threads(workers, 16, threads);
                let out = matmult_blocked(&cluster, &a, &b).unwrap().to_row_major_vec();
                let same = out.len() == reference.len()
                    && out.iter().zip(&reference).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "workers={workers} threads={threads}");
            }
        }
    }

    #[test]
    fn allreduce_matmult_charges_log2_rounds_not_broadcast() {
        let am = rand(8, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 83).unwrap();
        let bm = rand(96, 8, -1.0, 1.0, 1.0, Pdf::Uniform, 84).unwrap();
        let a = BlockedMatrix::from_local(&am, 16).unwrap();
        let b = BlockedMatrix::from_local(&bm, 16).unwrap();
        for (workers, rounds) in [(2usize, 1u64), (4, 2), (8, 3)] {
            let cluster = Cluster::new(workers, 16);
            let out = matmult_blocked(&cluster, &a, &b).unwrap();
            assert_eq!(cluster.allreduce_round_count(), rounds, "workers={workers}");
            assert_eq!(
                cluster.allreduce_byte_count(),
                rounds * out.size_in_bytes() as u64,
                "workers={workers}"
            );
            // No mapmm broadcast / rmm shuffle beyond the allreduce: the
            // per-k partials are computed where the operands live.
            assert_eq!(cluster.comm_bytes(), cluster.allreduce_byte_count());
        }
    }

    #[test]
    fn mapmm_chosen_for_small_rhs() {
        let cluster = Cluster::new(4, 32);
        let a = BlockedMatrix::from_local(
            &rand(256, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 24).unwrap(),
            32,
        )
        .unwrap();
        let b = BlockedMatrix::from_local(
            &rand(128, 16, -1.0, 1.0, 1.0, Pdf::Uniform, 25).unwrap(),
            32,
        )
        .unwrap();
        assert_eq!(choose_mm_operator(&cluster, &a, &b).0, DistMmOperator::MapMm);
    }

    #[test]
    fn transpose_blocked_matches_local_without_shuffle() {
        let cluster = Cluster::new(3, 16);
        let m = rand(45, 70, -1.0, 1.0, 0.4, Pdf::Uniform, 28).unwrap();
        let t = transpose_blocked(&cluster, &BlockedMatrix::from_local(&m, 16).unwrap());
        assert_eq!(t.shape(), (70, 45));
        // Exact: transpose moves cells without arithmetic.
        assert_eq!(
            t.to_local().unwrap().to_row_major_vec(),
            crate::runtime::matrix::reorg::transpose(&m).to_row_major_vec()
        );
        // Symmetric placement (i+j) keeps (i,j) and (j,i) on one worker.
        assert_eq!(cluster.comm_bytes(), 0);
        assert!(cluster.tasks() > 0);
    }

    #[test]
    fn scalar_and_unary_blocked_match_local() {
        let cluster = Cluster::new(2, 8);
        let m = rand(20, 14, -2.0, 2.0, 0.6, Pdf::Uniform, 29).unwrap();
        let b = BlockedMatrix::from_local(&m, 8).unwrap();
        let s = scalar_blocked(&cluster, &b, 3.5, BinOp::Mul, false)
            .unwrap()
            .to_local()
            .unwrap();
        let s_local = elementwise::scalar_op(&m, 3.5, BinOp::Mul, false).unwrap();
        assert_eq!(s.to_row_major_vec(), s_local.to_row_major_vec());
        // Swapped form: s op x.
        let d = scalar_blocked(&cluster, &b, 1.0, BinOp::Sub, true)
            .unwrap()
            .to_local()
            .unwrap();
        let d_local = elementwise::scalar_op(&m, 1.0, BinOp::Sub, true).unwrap();
        assert_eq!(d.to_row_major_vec(), d_local.to_row_major_vec());
        let u = unary_blocked(&cluster, &b, UnaryOp::Abs).to_local().unwrap();
        let u_local = elementwise::unary(&m, UnaryOp::Abs);
        assert_eq!(u.to_row_major_vec(), u_local.to_row_major_vec());
    }

    #[test]
    fn slice_blocked_aligned_is_shuffle_free_and_exact() {
        let cluster = Cluster::new(3, 16);
        let m = rand(70, 48, -1.0, 1.0, 0.5, Pdf::Uniform, 61).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        // Block-aligned batch slice: rows 17..48 (0-based 16..48).
        let s = slice_blocked(&cluster, &b, 16, 48, 0, 48).unwrap();
        assert_eq!(s.shape(), (32, 48));
        assert_eq!(
            s.to_local().unwrap(),
            reorg::slice(&m, 16, 48, 0, 48).unwrap()
        );
        assert_eq!(cluster.comm_bytes(), 0, "aligned selection must not shuffle");
        assert!(cluster.tasks() > 0);
    }

    #[test]
    fn slice_blocked_straddling_matches_local_and_shuffles() {
        let cluster = Cluster::new(3, 16);
        let m = rand(70, 48, -1.0, 1.0, 1.0, Pdf::Uniform, 62).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        for (rl, ru, cl, cu) in [(5usize, 37usize, 3usize, 45usize), (1, 2, 0, 48), (0, 70, 7, 8)]
        {
            let s = slice_blocked(&cluster, &b, rl, ru, cl, cu).unwrap();
            assert_eq!(
                s.to_local().unwrap(),
                reorg::slice(&m, rl, ru, cl, cu).unwrap(),
                "[{rl}:{ru},{cl}:{cu}]"
            );
        }
        assert!(cluster.comm_bytes() > 0, "non-aligned slices re-align through a shuffle");
    }

    #[test]
    fn slice_blocked_bounds_errors_match_cp() {
        let cluster = Cluster::new(2, 16);
        let m = rand(20, 20, -1.0, 1.0, 1.0, Pdf::Uniform, 63).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        for (rl, ru, cl, cu) in [(0usize, 21usize, 0usize, 20usize), (5, 5, 0, 20), (3, 2, 0, 20)]
        {
            let cp = reorg::slice(&m, rl, ru, cl, cu).unwrap_err().to_string();
            let dist = slice_blocked(&cluster, &b, rl, ru, cl, cu).unwrap_err().to_string();
            assert_eq!(cp, dist, "[{rl}:{ru},{cl}:{cu}]");
        }
    }

    #[test]
    fn left_index_blocked_rewrites_touched_blocks_only() {
        let cluster = Cluster::new(2, 16);
        let m = rand(48, 48, -1.0, 1.0, 1.0, Pdf::Uniform, 64).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        let patch = rand(8, 8, 5.0, 6.0, 1.0, Pdf::Uniform, 65).unwrap();
        cluster.reset_accounting();
        let out = left_index_blocked(&cluster, &b, 12, 12, &patch, false).unwrap();
        // The 8x8 patch at (12,12) straddles a 2x2 block neighborhood of
        // 16-blocks: exactly 4 touched-block tasks, never the whole grid.
        assert_eq!(cluster.tasks(), 4, "only touched blocks are rewritten");
        assert!(cluster.comm_bytes() > 0, "the patch is broadcast");
        assert_eq!(
            out.to_local().unwrap(),
            reorg::left_index(&m, 12, 12, &patch).unwrap()
        );
        // Out-of-range writes raise the CP error.
        let cp = reorg::left_index(&m, 45, 45, &patch).unwrap_err().to_string();
        let dist =
            left_index_blocked(&cluster, &b, 45, 45, &patch, false).unwrap_err().to_string();
        assert_eq!(cp, dist);
    }

    #[test]
    fn left_index_shares_untouched_blocks_by_refcount() {
        let cluster = Cluster::new(2, 16);
        let m = rand(48, 48, -1.0, 1.0, 1.0, Pdf::Uniform, 75).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        let patch = rand(4, 4, 5.0, 6.0, 1.0, Pdf::Uniform, 76).unwrap();
        // Touches only block (0,0) of the 3x3 grid.
        let out = left_index_blocked(&cluster, &b, 2, 2, &patch, false).unwrap();
        // Untouched blocks are shared with the source grid (refcount 2),
        // the rewritten block is fresh (refcount 1).
        assert_eq!(out.block_refcount(0, 0), 1, "touched block is rewritten");
        for (i, j) in [(0, 1), (0, 2), (1, 0), (1, 1), (2, 2)] {
            assert_eq!(out.block_refcount(i, j), 2, "block ({i},{j}) must be shared");
        }
        // Whole-block slice selection shares too.
        let s = slice_blocked(&cluster, &b, 16, 48, 16, 48).unwrap();
        assert_eq!(s.block_refcount(0, 0), 3, "selected block shared by b, out and s");
    }

    #[test]
    fn left_index_fill_blocked_matches_cp_without_communication() {
        let cluster = Cluster::new(2, 16);
        let m = rand(48, 40, -1.0, 1.0, 1.0, Pdf::Uniform, 73).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        cluster.reset_accounting();
        let out = left_index_fill_blocked(&cluster, &b, 5, 30, 3, 20, 2.5).unwrap();
        // The constant rides the tasks: no broadcast of the region.
        assert_eq!(cluster.comm_bytes(), 0, "scalar fill must not broadcast the region");
        assert!(cluster.tasks() > 0);
        let cp = reorg::left_index(&m, 5, 3, &Matrix::filled(25, 17, 2.5)).unwrap();
        assert_eq!(out.to_local().unwrap(), cp);
        // Bounds errors are the canonical range error.
        assert!(left_index_fill_blocked(&cluster, &b, 0, 49, 0, 40, 1.0).is_err());
    }

    #[test]
    fn broadcast_join_matches_cp_for_row_and_col_vectors() {
        let cluster = Cluster::new(3, 16);
        let m = rand(40, 28, -2.0, 2.0, 0.7, Pdf::Uniform, 66).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        let colv = rand(40, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 67).unwrap();
        let rowv = rand(1, 28, 0.5, 1.5, 1.0, Pdf::Uniform, 68).unwrap();
        for op in [BinOp::Add, BinOp::Sub, BinOp::Div, BinOp::Mul] {
            let d = binary_broadcast_blocked(&cluster, &b, &colv, op, false)
                .unwrap()
                .to_local()
                .unwrap();
            let l = elementwise::binary(&m, &colv, op).unwrap();
            assert_eq!(d.to_row_major_vec(), l.to_row_major_vec(), "col {op:?}");
            let d2 = binary_broadcast_blocked(&cluster, &b, &rowv, op, false)
                .unwrap()
                .to_local()
                .unwrap();
            let l2 = elementwise::binary(&m, &rowv, op).unwrap();
            assert_eq!(d2.to_row_major_vec(), l2.to_row_major_vec(), "row {op:?}");
        }
        // A true mismatch raises the CP DimMismatch verbatim.
        let bad = rand(3, 2, 0.0, 1.0, 1.0, Pdf::Uniform, 69).unwrap();
        let cp = elementwise::binary(&m, &bad, BinOp::Add).unwrap_err().to_string();
        let dist = binary_broadcast_blocked(&cluster, &b, &bad, BinOp::Add, false)
            .unwrap_err()
            .to_string();
        assert_eq!(cp, dist);
    }

    #[test]
    fn broadcast_join_charges_broadcast_bytes() {
        let cluster = Cluster::new(4, 16);
        let m = rand(32, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 70).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        let v = rand(1, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 71).unwrap();
        cluster.reset_accounting();
        binary_broadcast_blocked(&cluster, &b, &v, BinOp::Sub, false).unwrap();
        let charged = cluster.comm_bytes();
        assert_eq!(charged, v.size_in_bytes() as u64 * 4, "vector bytes x workers");
        // Resident vectors are not re-broadcast.
        binary_broadcast_blocked(&cluster, &b, &v, BinOp::Sub, true).unwrap();
        assert_eq!(cluster.comm_bytes(), charged);
    }

    #[test]
    fn row_index_max_blocked_matches_cp_including_ties() {
        let cluster = Cluster::new(3, 8);
        // Ties across block boundaries: constant rows must pick column 1.
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for r in 0..20 {
            rows.push((0..20).map(|c| if r == c { 2.0 } else { 1.0 }).collect());
        }
        rows.push(vec![1.0; 20]);
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let m = Matrix::from_rows(&refs);
        let b = BlockedMatrix::from_local(&m, 8).unwrap();
        let local = agg::row_index_max(&m);
        let dist = row_index_max_blocked(&cluster, &b).unwrap();
        assert_eq!(dist.to_row_major_vec(), local.to_row_major_vec());
        // And on random data.
        let m2 = rand(37, 23, -3.0, 3.0, 0.6, Pdf::Uniform, 72).unwrap();
        let b2 = BlockedMatrix::from_local(&m2, 8).unwrap();
        assert_eq!(
            row_index_max_blocked(&cluster, &b2).unwrap().to_row_major_vec(),
            agg::row_index_max(&m2).to_row_major_vec()
        );
        // NaN parity with the CP kernel, wherever the NaN lands: leading
        // the row (sticks), leading a later block (must not poison that
        // block's real maximum), or trailing.
        let nan = f64::NAN;
        let m3 = Matrix::from_rows(&[
            &[nan, 5.0, 1.0, 2.0],
            &[1.0, 2.0, nan, 9.0],
            &[1.0, 9.0, 2.0, nan],
            &[3.0, nan, nan, 3.0],
        ]);
        let b3 = BlockedMatrix::from_local(&m3, 2).unwrap();
        assert_eq!(
            row_index_max_blocked(&cluster, &b3).unwrap().to_row_major_vec(),
            agg::row_index_max(&m3).to_row_major_vec()
        );
    }

    #[test]
    fn slice_selection_only_predicate() {
        // Aligned origin: selection whatever the extent.
        assert!(slice_selection_only(16, 16, 48, 0, 40));
        // Interior trim inside one block: selection despite misalignment.
        assert!(slice_selection_only(16, 5, 10, 3, 8));
        // Extent crossing a source boundary from a misaligned origin.
        assert!(!slice_selection_only(16, 5, 37, 0, 16));
        // Interior single-block trims must not be charged as shuffles.
        let cluster = Cluster::new(2, 16);
        let m = rand(32, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 74).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        cluster.reset_accounting();
        let s = slice_blocked(&cluster, &b, 5, 10, 3, 8).unwrap();
        assert_eq!(cluster.comm_bytes(), 0, "interior trim is a narrow dependency");
        assert_eq!(
            s.to_local().unwrap(),
            reorg::slice(&m, 5, 10, 3, 8).unwrap()
        );
    }

    #[test]
    fn binary_blocked_realigns_grids() {
        let cluster = Cluster::new(2, 8);
        let x = rand(20, 20, -1.0, 1.0, 1.0, Pdf::Uniform, 26).unwrap();
        let y = rand(20, 20, -1.0, 1.0, 1.0, Pdf::Uniform, 27).unwrap();
        let xb = BlockedMatrix::from_local(&x, 8).unwrap();
        let yb = BlockedMatrix::from_local(&y, 5).unwrap();
        let out = binary_blocked(&cluster, &xb, &yb, BinOp::Add).unwrap().to_local().unwrap();
        let local = elementwise::binary(&x, &y, BinOp::Add).unwrap();
        assert!(approx_eq_slice(&out.to_row_major_vec(), &local.to_row_major_vec(), 1e-12));
    }
}
