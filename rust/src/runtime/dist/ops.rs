//! Blocked physical operators over the simulated cluster: matmult
//! (broadcast-based `mapmm` vs shuffle-based `rmm`, chosen by a
//! communication cost model exactly like SystemML's SparkExecutionContext),
//! cellwise binary ops, and row/col/full aggregates.
//!
//! Every operator assigns block tasks to workers deterministically,
//! accounts per-worker FLOPs and broadcast/shuffle bytes on the
//! [`Cluster`], and bumps the global `dist_tasks` metric — that is how
//! benches and tests observe which physical plan ran.
//!
//! Communication accounting is **cache-aware**: an operand whose blocked
//! partitions are already resident on the workers (a block-cache hit —
//! see [`crate::runtime::dist::cache`]) is not re-broadcast / re-shuffled,
//! so the cluster's communication totals reflect reuse exactly like
//! Spark's cached-RDD + reused-broadcast behavior. The [`Residency`]
//! flags carry that information from the dispatch layer.

use crate::runtime::dist::{BlockedMatrix, Cluster};
use crate::runtime::matrix::agg::{self, AggOp};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::elementwise::{self, BinOp, UnaryOp};
use crate::runtime::matrix::{mult, reorg, Matrix};
use crate::util::error::{DmlError, Result};

/// Distributed `a %*% b` over local inputs: blockify, run the blocked
/// matmult, collect the result to the driver.
pub fn matmult(cluster: &Cluster, a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.rows() {
        return Err(DmlError::DimMismatch {
            op: "%*% (dist)".into(),
            lhs_rows: a.rows(),
            lhs_cols: a.cols(),
            rhs_rows: b.rows(),
            rhs_cols: b.cols(),
        });
    }
    let ab = cluster.blockify(a)?;
    let bb = cluster.blockify(b)?;
    matmult_blocked(cluster, &ab, &bb)?.to_local()
}

/// Which physical distributed matmult operator ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistMmOperator {
    /// Map-side matmult: broadcast the smaller input, no shuffle.
    MapMm,
    /// Replication-based matmult: shuffle both inputs.
    Rmm,
}

/// Which operands are already resident on the workers (block-cache
/// hits). Resident operands incur no fresh broadcast/shuffle volume.
#[derive(Clone, Copy, Debug, Default)]
pub struct Residency {
    pub lhs: bool,
    pub rhs: bool,
}

/// Blocked matmult with cost-based mapmm/rmm selection (both operands
/// treated as freshly distributed).
pub fn matmult_blocked(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
) -> Result<BlockedMatrix> {
    matmult_blocked_reuse(cluster, a, b, Residency::default())
}

/// Blocked matmult with cache-aware communication accounting: resident
/// operands are not re-broadcast (mapmm) or re-replicated (rmm).
pub fn matmult_blocked_reuse(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
    resident: Residency,
) -> Result<BlockedMatrix> {
    if a.cols() != b.rows() || a.block_size() != b.block_size() {
        return Err(DmlError::rt(format!(
            "blocked matmult: incompatible operands {}x{} (block {}) @ {}x{} (block {})",
            a.rows(),
            a.cols(),
            a.block_size(),
            b.rows(),
            b.cols(),
            b.block_size()
        )));
    }
    let (op, _) = choose_mm_operator(cluster, a, b);
    // Communication accounting per the chosen plan, skipping operands
    // whose partitions are already resident on the workers.
    match op {
        DistMmOperator::MapMm => {
            // Broadcast the smaller side to every worker — unless its
            // blocks are resident from a previous broadcast.
            let a_small = a.size_in_bytes() <= b.size_in_bytes();
            let (small, small_resident) = if a_small {
                (a.size_in_bytes(), resident.lhs)
            } else {
                (b.size_in_bytes(), resident.rhs)
            };
            if !small_resident {
                cluster.record_broadcast(small as u64);
            }
        }
        DistMmOperator::Rmm => {
            // Each block of A is replicated across B's block columns and
            // vice versa (SystemML's replication-based matmult); resident
            // sides keep their replicated copies.
            let mut shuffled = 0u64;
            if !resident.lhs {
                shuffled += a.size_in_bytes() as u64 * b.block_cols() as u64;
            }
            if !resident.rhs {
                shuffled += b.size_in_bytes() as u64 * a.block_rows() as u64;
            }
            cluster.record_shuffle(shuffled);
        }
    }
    // The arithmetic is identical for both plans: out(i,j) = Σ_k A(i,k)B(k,j).
    let bs = a.block_size();
    let (brows, bcols, bk) = (a.block_rows(), b.block_cols(), a.block_cols());
    let mut blocks = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let mut acc: Option<Matrix> = None;
            let mut flops = 0u64;
            for k in 0..bk {
                let (lb, rb) = (a.block(i, k), b.block(k, j));
                flops += 2 * (lb.rows() * lb.cols() * rb.cols()) as u64;
                let p = mult::matmult(lb, rb)?;
                acc = Some(match acc {
                    None => p,
                    Some(q) => elementwise::binary(&q, &p, BinOp::Add)?,
                });
            }
            // An empty k extent (0-column lhs) contributes an all-zero
            // product block — empty matrices flow legally from indexing.
            let out = match acc {
                Some(m) => m,
                None => {
                    let r = (a.rows() - i * bs).min(bs);
                    let c = (b.cols() - j * bs).min(bs);
                    Matrix::zeros(r, c)
                }
            };
            cluster.record_task(cluster.worker_for(i, j), flops);
            blocks.push(out.examine_and_convert());
        }
    }
    Ok(BlockedMatrix::from_blocks(a.rows(), b.cols(), bs, blocks))
}

/// Cost-based operator selection: mapmm broadcasts the smaller input to
/// all workers; rmm replicates both sides through a shuffle. Returns the
/// chosen operator and its modeled communication volume.
pub fn choose_mm_operator(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
) -> (DistMmOperator, u64) {
    let mapmm_cost =
        a.size_in_bytes().min(b.size_in_bytes()) as u64 * cluster.num_workers() as u64;
    let rmm_cost = a.size_in_bytes() as u64 * b.block_cols() as u64
        + b.size_in_bytes() as u64 * a.block_rows() as u64;
    if mapmm_cost <= rmm_cost {
        (DistMmOperator::MapMm, mapmm_cost)
    } else {
        (DistMmOperator::Rmm, rmm_cost)
    }
}

/// Blocked cellwise binary op; operands must have identical shapes.
/// Re-blockifies if the block grids disagree.
pub fn binary_blocked(
    cluster: &Cluster,
    a: &BlockedMatrix,
    b: &BlockedMatrix,
    op: BinOp,
) -> Result<BlockedMatrix> {
    if a.shape() != b.shape() {
        return Err(DmlError::DimMismatch {
            op: format!("{op:?} (dist)"),
            lhs_rows: a.rows(),
            lhs_cols: a.cols(),
            rhs_rows: b.rows(),
            rhs_cols: b.cols(),
        });
    }
    if a.block_size() != b.block_size() {
        // Align the right side to the left grid (one shuffle).
        cluster.record_shuffle(b.size_in_bytes() as u64);
        let rb = cluster.blockify(&b.to_local()?)?;
        return binary_blocked(cluster, a, &rb, op);
    }
    let (brows, bcols) = (a.block_rows(), a.block_cols());
    let mut blocks = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let lb = a.block(i, j);
            let out = elementwise::binary(lb, b.block(i, j), op)?;
            cluster.record_task(cluster.worker_for(i, j), lb.len() as u64);
            blocks.push(out);
        }
    }
    Ok(BlockedMatrix::from_blocks(a.rows(), a.cols(), a.block_size(), blocks))
}

/// Distributed cellwise binary over local inputs.
pub fn binary(cluster: &Cluster, a: &Matrix, b: &Matrix, op: BinOp) -> Result<Matrix> {
    let ab = cluster.blockify(a)?;
    let bb = cluster.blockify(b)?;
    binary_blocked(cluster, &ab, &bb, op)?.to_local()
}

/// Distributed transpose (`t(X)`) as a real blocked reorg: the output
/// grid swaps block indices ((i,j) → (j,i)) and every block transposes
/// locally on its worker. With the symmetric hash placement
/// (`worker_for(i,j) = (i+j) % n`), block (i,j) and its transposed
/// position (j,i) land on the *same* worker, so the reorg is
/// shuffle-free — a narrow dependency, like Spark transpose over a
/// symmetric partitioner. No collect, no re-blockify.
pub fn transpose_blocked(cluster: &Cluster, m: &BlockedMatrix) -> BlockedMatrix {
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut blocks = Vec::with_capacity(brows * bcols);
    // Output grid is bcols × brows, row-major over the swapped indices.
    for j in 0..bcols {
        for i in 0..brows {
            let b = m.block(i, j);
            cluster.record_task(cluster.worker_for(i, j), b.len() as u64);
            blocks.push(reorg::transpose(b));
        }
    }
    BlockedMatrix::from_blocks(m.cols(), m.rows(), m.block_size(), blocks)
}

/// Blocked matrix∘scalar cellwise op: a map over resident blocks (no
/// communication). `swapped` computes `s op x` instead of `x op s`.
pub fn scalar_blocked(
    cluster: &Cluster,
    m: &BlockedMatrix,
    s: f64,
    op: BinOp,
    swapped: bool,
) -> Result<BlockedMatrix> {
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut blocks = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.block(i, j);
            cluster.record_task(cluster.worker_for(i, j), b.len() as u64);
            blocks.push(elementwise::scalar_op(b, s, op, swapped)?);
        }
    }
    Ok(BlockedMatrix::from_blocks(m.rows(), m.cols(), m.block_size(), blocks))
}

/// Blocked unary cellwise op (exp, sqrt, neg, ...): a map over blocks.
pub fn unary_blocked(cluster: &Cluster, m: &BlockedMatrix, op: UnaryOp) -> BlockedMatrix {
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    let mut blocks = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.block(i, j);
            cluster.record_task(cluster.worker_for(i, j), b.len() as u64);
            blocks.push(elementwise::unary(b, op));
        }
    }
    BlockedMatrix::from_blocks(m.rows(), m.cols(), m.block_size(), blocks)
}

/// Blocked full aggregate: per-block partials on the workers, combined on
/// the driver (the classic map + reduce aggregate).
pub fn full_agg_blocked(cluster: &Cluster, m: &BlockedMatrix, op: AggOp) -> f64 {
    // Partial op per block: Mean aggregates via Sum (weighted by count).
    let partial_op = match op {
        AggOp::Mean => AggOp::Sum,
        other => other,
    };
    let bcols = m.block_cols();
    let mut partials = Vec::with_capacity(m.block_rows() * bcols);
    for i in 0..m.block_rows() {
        for j in 0..bcols {
            let b = m.block(i, j);
            partials.push(agg::full_agg(b, partial_op));
            cluster.record_task(cluster.worker_for(i, j), b.len() as u64);
        }
    }
    match op {
        AggOp::Sum | AggOp::SumSq => partials.iter().sum(),
        AggOp::Mean => partials.iter().sum::<f64>() / (m.rows() * m.cols()).max(1) as f64,
        AggOp::Min => partials.iter().fold(f64::INFINITY, |a, b| a.min(*b)),
        AggOp::Max => partials.iter().fold(f64::NEG_INFINITY, |a, b| a.max(*b)),
        AggOp::Prod => partials.iter().product(),
    }
}

/// Distributed full aggregate over a local input.
pub fn full_agg(cluster: &Cluster, m: &Matrix, op: AggOp) -> Result<f64> {
    Ok(full_agg_blocked(cluster, &cluster.blockify(m)?, op))
}

/// Blocked row aggregate → rows×1 vector: per-block row partials combined
/// across the block columns of each block row.
pub fn row_agg_blocked(cluster: &Cluster, m: &BlockedMatrix, op: AggOp) -> Result<Matrix> {
    let partial_op = match op {
        AggOp::Mean => AggOp::Sum,
        other => other,
    };
    let combine = combine_binop(op);
    let mut out = DenseMatrix::zeros(m.rows(), 1);
    for i in 0..m.block_rows() {
        let mut acc: Option<Matrix> = None;
        for j in 0..m.block_cols() {
            let b = m.block(i, j);
            let p = agg::row_agg(b, partial_op);
            cluster.record_task(cluster.worker_for(i, j), b.len() as u64);
            acc = Some(match acc {
                None => p,
                Some(q) => elementwise::binary(&q, &p, combine)?,
            });
        }
        let mut block_vec =
            acc.ok_or_else(|| DmlError::rt("blocked row agg: empty grid"))?.to_dense();
        if op == AggOp::Mean {
            for v in block_vec.data.iter_mut() {
                *v /= m.cols() as f64;
            }
        }
        out.assign(i * m.block_size(), 0, &block_vec)?;
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// Blocked column aggregate → 1×cols vector.
pub fn col_agg_blocked(cluster: &Cluster, m: &BlockedMatrix, op: AggOp) -> Result<Matrix> {
    let partial_op = match op {
        AggOp::Mean => AggOp::Sum,
        other => other,
    };
    let combine = combine_binop(op);
    let mut out = DenseMatrix::zeros(1, m.cols());
    for j in 0..m.block_cols() {
        let mut acc: Option<Matrix> = None;
        for i in 0..m.block_rows() {
            let b = m.block(i, j);
            let p = agg::col_agg(b, partial_op);
            cluster.record_task(cluster.worker_for(i, j), b.len() as u64);
            acc = Some(match acc {
                None => p,
                Some(q) => elementwise::binary(&q, &p, combine)?,
            });
        }
        let mut block_vec =
            acc.ok_or_else(|| DmlError::rt("blocked col agg: empty grid"))?.to_dense();
        if op == AggOp::Mean {
            for v in block_vec.data.iter_mut() {
                *v /= m.rows() as f64;
            }
        }
        out.assign(0, j * m.block_size(), &block_vec)?;
    }
    Ok(Matrix::Dense(out).examine_and_convert())
}

/// Distributed row aggregate over a local input.
pub fn row_agg(cluster: &Cluster, m: &Matrix, op: AggOp) -> Result<Matrix> {
    row_agg_blocked(cluster, &cluster.blockify(m)?, op)
}

/// Distributed column aggregate over a local input.
pub fn col_agg(cluster: &Cluster, m: &Matrix, op: AggOp) -> Result<Matrix> {
    col_agg_blocked(cluster, &cluster.blockify(m)?, op)
}

/// How block-row/-column partial aggregates are merged across blocks.
fn combine_binop(op: AggOp) -> BinOp {
    match op {
        AggOp::Sum | AggOp::Mean | AggOp::SumSq => BinOp::Add,
        AggOp::Min => BinOp::Min,
        AggOp::Max => BinOp::Max,
        AggOp::Prod => BinOp::Mul,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};
    use crate::util::quickcheck::approx_eq_slice;

    #[test]
    fn blocked_matmult_odd_shapes_match_local() {
        let cluster = Cluster::new(3, 16);
        let a = rand(45, 37, -1.0, 1.0, 1.0, Pdf::Uniform, 21).unwrap();
        let b = rand(37, 29, -1.0, 1.0, 0.3, Pdf::Uniform, 22).unwrap();
        let local = mult::matmult(&a, &b).unwrap();
        let dist = matmult(&cluster, &a, &b).unwrap();
        assert!(approx_eq_slice(&dist.to_row_major_vec(), &local.to_row_major_vec(), 1e-9));
    }

    #[test]
    fn row_col_aggs_match_local() {
        let cluster = Cluster::new(2, 8);
        let m = rand(21, 13, -2.0, 2.0, 0.6, Pdf::Uniform, 23).unwrap();
        for op in [AggOp::Sum, AggOp::Mean, AggOp::Min, AggOp::Max] {
            let r_local = agg::row_agg(&m, op);
            let r_dist = row_agg(&cluster, &m, op).unwrap();
            assert!(
                approx_eq_slice(&r_dist.to_row_major_vec(), &r_local.to_row_major_vec(), 1e-12),
                "row {op:?}"
            );
            let c_local = agg::col_agg(&m, op);
            let c_dist = col_agg(&cluster, &m, op).unwrap();
            assert!(
                approx_eq_slice(&c_dist.to_row_major_vec(), &c_local.to_row_major_vec(), 1e-12),
                "col {op:?}"
            );
        }
    }

    #[test]
    fn mapmm_chosen_for_small_rhs() {
        let cluster = Cluster::new(4, 32);
        let a = BlockedMatrix::from_local(
            &rand(256, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 24).unwrap(),
            32,
        )
        .unwrap();
        let b = BlockedMatrix::from_local(
            &rand(128, 16, -1.0, 1.0, 1.0, Pdf::Uniform, 25).unwrap(),
            32,
        )
        .unwrap();
        assert_eq!(choose_mm_operator(&cluster, &a, &b).0, DistMmOperator::MapMm);
    }

    #[test]
    fn transpose_blocked_matches_local_without_shuffle() {
        let cluster = Cluster::new(3, 16);
        let m = rand(45, 70, -1.0, 1.0, 0.4, Pdf::Uniform, 28).unwrap();
        let t = transpose_blocked(&cluster, &BlockedMatrix::from_local(&m, 16).unwrap());
        assert_eq!(t.shape(), (70, 45));
        // Exact: transpose moves cells without arithmetic.
        assert_eq!(
            t.to_local().unwrap().to_row_major_vec(),
            crate::runtime::matrix::reorg::transpose(&m).to_row_major_vec()
        );
        // Symmetric placement (i+j) keeps (i,j) and (j,i) on one worker.
        assert_eq!(cluster.comm_bytes(), 0);
        assert!(cluster.tasks() > 0);
    }

    #[test]
    fn scalar_and_unary_blocked_match_local() {
        let cluster = Cluster::new(2, 8);
        let m = rand(20, 14, -2.0, 2.0, 0.6, Pdf::Uniform, 29).unwrap();
        let b = BlockedMatrix::from_local(&m, 8).unwrap();
        let s = scalar_blocked(&cluster, &b, 3.5, BinOp::Mul, false)
            .unwrap()
            .to_local()
            .unwrap();
        let s_local = elementwise::scalar_op(&m, 3.5, BinOp::Mul, false).unwrap();
        assert_eq!(s.to_row_major_vec(), s_local.to_row_major_vec());
        // Swapped form: s op x.
        let d = scalar_blocked(&cluster, &b, 1.0, BinOp::Sub, true)
            .unwrap()
            .to_local()
            .unwrap();
        let d_local = elementwise::scalar_op(&m, 1.0, BinOp::Sub, true).unwrap();
        assert_eq!(d.to_row_major_vec(), d_local.to_row_major_vec());
        let u = unary_blocked(&cluster, &b, UnaryOp::Abs).to_local().unwrap();
        let u_local = elementwise::unary(&m, UnaryOp::Abs);
        assert_eq!(u.to_row_major_vec(), u_local.to_row_major_vec());
    }

    #[test]
    fn binary_blocked_realigns_grids() {
        let cluster = Cluster::new(2, 8);
        let x = rand(20, 20, -1.0, 1.0, 1.0, Pdf::Uniform, 26).unwrap();
        let y = rand(20, 20, -1.0, 1.0, 1.0, Pdf::Uniform, 27).unwrap();
        let xb = BlockedMatrix::from_local(&x, 8).unwrap();
        let yb = BlockedMatrix::from_local(&y, 5).unwrap();
        let out = binary_blocked(&cluster, &xb, &yb, BinOp::Add).unwrap().to_local().unwrap();
        let local = elementwise::binary(&x, &y, BinOp::Add).unwrap();
        assert!(approx_eq_slice(&out.to_row_major_vec(), &local.to_row_major_vec(), 1e-12));
    }
}
