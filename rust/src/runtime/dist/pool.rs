//! Hand-rolled worker thread pool for the blocked backend.
//!
//! SystemML's distributed operators execute as Spark tasks: one task per
//! block (or band), placed on the executor that holds the partition, with
//! a barrier at the stage boundary before the driver combines partial
//! results. This module reproduces that execution model with plain OS
//! threads and zero dependencies:
//!
//! * [`WorkerPool::new`] spawns `threads` long-lived workers, each owning
//!   a private FIFO job queue (a `Mutex<VecDeque>` + `Condvar` pair — not
//!   an mpsc channel, so any number of driver threads can submit
//!   concurrently without cloning senders).
//! * [`WorkerPool::run_tasks`] takes a batch of `(worker, closure)` tasks,
//!   enqueues each closure on the queue of `worker % threads` (the caller
//!   passes `Cluster::worker_for(i, j)`, so tasks land on the thread that
//!   "owns" the block, like partition-local Spark tasks), and blocks at a
//!   barrier until every task in the batch has finished. Results come back
//!   in **submission order**, regardless of completion order — the driver
//!   then folds them exactly as the serial loop did, which is what keeps
//!   parallel results byte-identical to serial execution.
//! * A pool built with `threads <= 1` spawns nothing: `run_tasks` runs
//!   every closure inline on the caller thread. That is the `threads = 1`
//!   escape hatch (`SystemConfig::dist_threads = 1`) restoring fully
//!   serial execution for debugging.
//!
//! Safety/correctness notes:
//! * Task closures must be `'static`: operators capture `Arc<Matrix>`
//!   block clones (refcount bumps), never borrows of the block grid.
//! * Tasks are pure compute — they must not submit nested batches to the
//!   same pool or take driver-side locks ([`super::cache::BlockCache`]'s
//!   mutex is only touched at dispatch time, before tasks are built).
//!   A task that blocked on its own pool could deadlock; nothing in
//!   `dist/ops.rs` / `dist/nn.rs` does.
//! * A panicking task is caught on the worker (so the barrier still
//!   completes and the pool survives) and re-raised on the submitting
//!   driver thread, preserving the serial panic behavior.
//! * Batches from concurrent drivers (parfor bodies issuing DIST ops) may
//!   interleave on the worker queues; each batch tracks its own
//!   remaining-task count, so the barriers are independent.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::metrics;

/// A unit of work bound for one worker thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// One task of a batch: the owning worker index and the compute closure.
/// The closure's return value is surfaced by [`WorkerPool::run_tasks`] in
/// submission order.
pub type DistTask<R> = (usize, Box<dyn FnOnce() -> R + Send + 'static>);

/// One worker's job queue.
struct Queue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

impl Queue {
    fn new() -> Queue {
        Queue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
        }
    }

    fn push(&self, job: Job) {
        let mut st = self.state.lock().unwrap();
        st.jobs.push_back(job);
        drop(st);
        self.ready.notify_one();
    }
}

/// Per-batch barrier state: one result slot per task plus a countdown the
/// submitting driver waits on.
struct Batch<R> {
    slots: Vec<Mutex<Option<std::thread::Result<R>>>>,
    remaining: Mutex<usize>,
    done: Condvar,
}

/// The long-lived worker pool owned by a `Cluster`.
pub struct WorkerPool {
    /// One queue per worker thread; empty in serial (`threads <= 1`) mode.
    queues: Vec<Arc<Queue>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` long-lived workers. `threads <= 1` spawns nothing
    /// and makes [`run_tasks`](WorkerPool::run_tasks) execute inline.
    pub fn new(threads: usize) -> WorkerPool {
        if threads <= 1 {
            return WorkerPool { queues: Vec::new(), workers: Vec::new() };
        }
        let queues: Vec<Arc<Queue>> = (0..threads).map(|_| Arc::new(Queue::new())).collect();
        let workers = queues
            .iter()
            .enumerate()
            .map(|(i, q)| {
                let q = Arc::clone(q);
                std::thread::Builder::new()
                    .name(format!("dist-worker-{i}"))
                    .spawn(move || worker_loop(&q))
                    .expect("spawn dist worker thread")
            })
            .collect();
        WorkerPool { queues, workers }
    }

    /// Number of concurrent task lanes (1 in serial mode).
    pub fn threads(&self) -> usize {
        self.queues.len().max(1)
    }

    /// True when the pool runs every task inline on the caller thread.
    pub fn is_serial(&self) -> bool {
        self.queues.is_empty()
    }

    /// Execute a batch of tasks and return their results in submission
    /// order. Each task runs on the thread `worker % threads`; the call
    /// blocks at a barrier until the whole batch has completed. A task
    /// panic is re-raised here after the barrier (the pool survives).
    pub fn run_tasks<R: Send + 'static>(&self, tasks: Vec<DistTask<R>>) -> Vec<R> {
        if self.queues.is_empty() {
            // Serial escape hatch: the caller thread is the one worker.
            return tasks.into_iter().map(|(_, f)| f()).collect();
        }
        let n = tasks.len();
        metrics::global().pool_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        metrics::global().pool_tasks.fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
        let batch = Arc::new(Batch {
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: Mutex::new(n),
            done: Condvar::new(),
        });
        for (idx, (worker, f)) in tasks.into_iter().enumerate() {
            let b = Arc::clone(&batch);
            self.queues[worker % self.queues.len()].push(Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(f));
                *b.slots[idx].lock().unwrap() = Some(out);
                let mut rem = b.remaining.lock().unwrap();
                *rem -= 1;
                if *rem == 0 {
                    b.done.notify_all();
                }
            }));
        }
        // Barrier: wait for the batch countdown to hit zero.
        let mut rem = batch.remaining.lock().unwrap();
        while *rem > 0 {
            rem = batch.done.wait(rem).unwrap();
        }
        drop(rem);
        batch
            .slots
            .iter()
            .map(|slot| match slot.lock().unwrap().take().expect("dist task completed") {
                Ok(r) => r,
                Err(panic) => resume_unwind(panic),
            })
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for q in &self.queues {
            q.state.lock().unwrap().shutdown = true;
            q.ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WorkerPool(threads={})", self.threads())
    }
}

fn worker_loop(q: &Queue) {
    loop {
        let job = {
            let mut st = q.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break Some(j);
                }
                if st.shutdown {
                    break None;
                }
                st = q.ready.wait(st).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// Run one closure per entry on scoped threads and return the results in
/// spawn order, re-raising the first panic. This is the shared
/// fork-join helper for drivers whose bodies *borrow* caller state (the
/// `runtime/parfor` executor runs interpreter chunks over `&Interpreter`
/// and so cannot use the `'static` pool above); block-level DIST tasks
/// use the long-lived [`WorkerPool`] instead.
pub fn run_scoped<T, F>(fns: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    std::thread::scope(|s| {
        let handles: Vec<_> = fns.into_iter().map(|f| s.spawn(f)).collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(panic) => resume_unwind(panic),
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert!(pool.is_serial());
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        let out = pool.run_tasks::<bool>(vec![
            (0, Box::new(move || std::thread::current().id() == caller) as Box<_>),
        ]);
        assert_eq!(out, vec![true], "threads=1 must execute on the caller");
    }

    #[test]
    fn results_come_back_in_submission_order() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<DistTask<usize>> = (0..64)
            .map(|i| {
                (
                    i % 4,
                    Box::new(move || {
                        // Stagger completion so order would scramble
                        // without the ordered result slots.
                        if i % 4 == 0 {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        i
                    }) as Box<_>,
                )
            })
            .collect();
        let out = pool.run_tasks(tasks);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn tasks_run_on_their_assigned_worker() {
        let pool = WorkerPool::new(3);
        let names = pool.run_tasks::<String>(
            (0..9)
                .map(|i| {
                    (
                        i % 3,
                        Box::new(|| std::thread::current().name().unwrap_or("").to_string())
                            as Box<_>,
                    )
                })
                .collect(),
        );
        for (i, name) in names.iter().enumerate() {
            assert_eq!(name, &format!("dist-worker-{}", i % 3));
        }
    }

    #[test]
    fn concurrent_batches_do_not_interfere() {
        let pool = Arc::new(WorkerPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let drivers: Vec<_> = (0..4)
            .map(|d| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    for _ in 0..10 {
                        let out = pool.run_tasks::<usize>(
                            (0..8).map(|i| (i, Box::new(move || d * 100 + i) as Box<_>)).collect(),
                        );
                        assert_eq!(out, (0..8).map(|i| d * 100 + i).collect::<Vec<_>>());
                        total.fetch_add(out.len(), Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for d in drivers {
            d.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 8);
    }

    #[test]
    fn task_panic_propagates_but_pool_survives() {
        let pool = WorkerPool::new(2);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks::<usize>(vec![
                (0, Box::new(|| panic!("boom")) as Box<_>),
                (1, Box::new(|| 7) as Box<_>),
            ]);
        }));
        assert!(res.is_err(), "task panic must reach the driver");
        // The pool is still usable after the panic.
        let out = pool.run_tasks::<usize>(vec![(0, Box::new(|| 42) as Box<_>)]);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn empty_batch_returns_immediately() {
        let pool = WorkerPool::new(2);
        let out: Vec<usize> = pool.run_tasks(Vec::new());
        assert!(out.is_empty());
    }

    #[test]
    fn run_scoped_keeps_spawn_order() {
        let vals = vec![3usize, 1, 4, 1, 5];
        let fns: Vec<_> = vals.iter().map(|&v| move || v * 2).collect();
        assert_eq!(run_scoped(fns), vec![6, 2, 8, 2, 10]);
    }
}
