//! Blocked NN operators: distributed conv2d / pooling over row-partitioned
//! mini-batches (the paper's LeNet/ResNet scenarios on the blocked
//! backend, mirroring BigDL's recipe — parameters broadcast, data stays
//! partitioned, gradients aggregated driver-side).
//!
//! # Layout contract
//!
//! The batch operand is an N×(C·H·W) blocked matrix whose **rows are
//! whole flattened NCHW images**. The unit of distribution is the *row
//! band*: all blocks of one block-row. When the grid has a single block
//! column (C·H·W ≤ block size, the common mini-batch case) every image
//! is already complete inside its resident block and the band *is* that
//! block — a narrow dependency, no data movement. A multi-column grid
//! splits each image's cells across blocks on different workers, so
//! assembling complete images first re-partitions the operand into row
//! bands — charged as **one shuffle of the operand's bytes** per op
//! (SystemML's general repartition-to-rows case).
//!
//! # Dataflow
//!
//! * **Forward / data-gradient ops** (`conv2d`, `conv2d_backward_data`,
//!   `max_pool`, `avg_pool`, both pool backwards): each row band runs the
//!   corresponding CP kernel from [`crate::runtime::conv`] on its owning
//!   worker — per-image im2col + filter GEMM, byte-identical to the CP
//!   path because every image is processed independently — and the band's
//!   output splits back into `block_size` column blocks of the blocked
//!   result. The filter ships as a **broadcast variable** (charged to
//!   broadcast accounting unless already resident on the workers).
//! * **`conv2d_backward_filter`**: every band computes its *partial*
//!   filter gradient (a small K×(C·R·S) matrix); the partials are
//!   combined via a modeled **tree-allreduce** — charged as
//!   `log2(workers)` rounds of the gradient's bytes
//!   ([`Cluster::record_allreduce`]), **not** a collect of the batch —
//!   with the arithmetic fold running in ascending band order (a fixed
//!   order that depends only on the block grid, so results are
//!   byte-identical across worker/thread counts). Note the fold
//!   associates per band, so multi-band gradients match CP up to
//!   floating-point summation order (single-band batches are
//!   byte-identical); everything else in this module is exact. The
//!   dispatch layer binds the gradient **replicated** on every worker, so
//!   the optimizer update consumes it cluster-side.
//! * **`bias_add` / `bias_multiply`**: pure per-block maps — each block
//!   derives its channel index from its global column offset, so the
//!   K×1 bias broadcast joins map-side without band assembly.
//!
//! # Per-block representation
//!
//! Every broadcast/shuffle/allreduce above is charged by the operand's
//! **encoded** bytes (`size_in_bytes()`), so CSR blocks move CSR-sized
//! traffic. Output blocks re-examine their format against the cluster's
//! sparsity threshold (`Cluster::sparsity_threshold`) when split back
//! into the grid, matching the lifecycle contract in the module docs of
//! [`crate::runtime::dist`].

use std::sync::Arc;

use crate::runtime::conv::{self, ConvShape};
use crate::runtime::dist::pool::DistTask;
use crate::runtime::dist::{BlockedMatrix, Cluster};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::{reorg, Matrix};
use crate::util::error::{DmlError, Result};

/// Assemble block-row `i` into one driver-format band (all columns of
/// rows `i·bs .. min((i+1)·bs, rows)`). A single-column grid shares the
/// resident block (an `Arc` bump); a multi-column grid concatenates the
/// row's blocks (the band re-partition charged by
/// [`charge_band_shuffle`]).
fn row_band(m: &BlockedMatrix, i: usize) -> Result<Arc<Matrix>> {
    let bcols = m.block_cols();
    if bcols == 1 {
        return Ok(m.shared_block(i, 0));
    }
    let rows = m.block(i, 0).rows();
    let mut out = DenseMatrix::zeros(rows, m.cols());
    for j in 0..bcols {
        let b = m.block(i, j);
        out.assign(0, j * m.block_size(), &b.to_dense())?;
    }
    Ok(Arc::new(Matrix::Dense(out).examine_and_convert()))
}

/// Charge the row-band re-partition of a batch operand: free on a
/// single-column grid (rows are already complete per block), one shuffle
/// of the operand's bytes otherwise.
fn charge_band_shuffle(cluster: &Cluster, m: &BlockedMatrix) {
    if m.block_cols() > 1 {
        cluster.record_shuffle(m.size_in_bytes() as u64);
    }
}

/// Split a band's output (rows of one block-row, all `out_cols` columns)
/// into `block_size`-column blocks, appending them in grid order. Each
/// block re-examines its format against the cluster's sparsity turn
/// point `thr` — sparse conv outputs (post-ReLU activations at high
/// sparsity) land as CSR blocks.
fn split_band(
    band_out: Matrix,
    bs: usize,
    out_cols: usize,
    thr: f64,
    blocks: &mut Vec<Arc<Matrix>>,
) -> Result<()> {
    let obc = super::ceil_div(out_cols, bs);
    if obc == 0 {
        // 0-column output (degenerate K=0 / C=0 geometry): the grid has
        // no blocks, matching CP's clean N×0 result.
        return Ok(());
    }
    if obc == 1 {
        blocks.push(Arc::new(band_out.examine_and_convert_with(thr)));
        return Ok(());
    }
    let rows = band_out.rows();
    for j in 0..obc {
        let cl = j * bs;
        let cu = (cl + bs).min(out_cols);
        blocks.push(Arc::new(
            reorg::slice(&band_out, 0, rows, cl, cu)?.examine_and_convert_with(thr),
        ));
    }
    Ok(())
}

/// Align a second batch operand (`dout`) to the first operand's grid so
/// their row bands pair up. Grids built by the same cluster share a block
/// size; a mismatched one (foreign handle) re-partitions through a
/// shuffle, like the blocked cellwise realign.
fn align_batch_grid(
    cluster: &Cluster,
    x: &BlockedMatrix,
    dout: &BlockedMatrix,
) -> Result<Option<BlockedMatrix>> {
    if x.block_size() == dout.block_size() {
        return Ok(None);
    }
    cluster.record_shuffle(dout.size_in_bytes() as u64);
    Ok(Some(BlockedMatrix::from_local(&dout.to_local()?, x.block_size())?))
}

/// im2col-expanded FLOPs of one image's conv GEMM: 2·(P·Q)·(C·R·S)·K.
fn conv_image_flops(sh: &ConvShape) -> u64 {
    let (p, q) = (sh.p(), sh.q());
    2 * (p * q) as u64 * (sh.c * sh.r * sh.s) as u64 * sh.k as u64
}

/// Window-sweep FLOPs of one image's pooling pass: C·P·Q·R·S.
fn pool_image_flops(sh: &ConvShape) -> u64 {
    let (p, q) = (sh.p(), sh.q());
    (sh.c * p * q * sh.r * sh.s) as u64
}

/// Shared band-map skeleton for the forward / data-gradient operators:
/// validate, charge the filter broadcast (when present) and the band
/// re-partition, run `kernel` per band on the band's owning worker (one
/// pool task per band — bands are independent images, so the blocked
/// output is byte-identical however the tasks interleave), and
/// reassemble the blocked output of `out_cols` columns.
fn band_map(
    cluster: &Cluster,
    x: &BlockedMatrix,
    out_cols: usize,
    flops_per_image: u64,
    kernel: impl Fn(&Matrix) -> Result<Matrix> + Send + Sync + 'static,
) -> Result<BlockedMatrix> {
    charge_band_shuffle(cluster, x);
    let bs = x.block_size();
    let thr = cluster.sparsity_threshold();
    let obc = super::ceil_div(out_cols, bs);
    let src = Arc::new(x.clone());
    let kernel = Arc::new(kernel);
    let mut tasks: Vec<DistTask<Result<(Vec<Arc<Matrix>>, u64)>>> =
        Vec::with_capacity(x.block_rows());
    for i in 0..x.block_rows() {
        let src = Arc::clone(&src);
        let kernel = Arc::clone(&kernel);
        tasks.push((
            cluster.worker_for(i, 0),
            Box::new(move || {
                let band = row_band(&src, i)?;
                let mut out = Vec::with_capacity(obc);
                split_band(kernel(&band)?, bs, out_cols, thr, &mut out)?;
                Ok((out, band.rows() as u64))
            }),
        ));
    }
    let mut blocks = Vec::with_capacity(x.block_rows() * obc);
    for (i, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (band_blocks, band_rows) = res?;
        cluster.record_task(cluster.worker_for(i, 0), flops_per_image * band_rows);
        blocks.extend(band_blocks);
    }
    Ok(BlockedMatrix::from_shared_blocks(x.rows(), out_cols, bs, blocks))
}

/// Blocked conv2d forward: input N×(C·H·W) blocked, filter K×(C·R·S)
/// broadcast → N×(K·P·Q) blocked. Reuses the CP im2col→GEMM kernel per
/// band, so results are byte-identical to CP.
pub fn conv2d_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    filter: &Matrix,
    sh: &ConvShape,
    filter_resident: bool,
) -> Result<BlockedMatrix> {
    sh.validate_input_dims(x.cols(), "conv2d")?;
    sh.validate_filter_dims(filter.rows(), filter.cols(), "conv2d")?;
    sh.validate_window("conv2d")?;
    if !filter_resident {
        cluster.record_broadcast(filter.size_in_bytes() as u64);
    }
    let (p, q) = (sh.p(), sh.q());
    // The tasks read the broadcast copy of the filter (owned clone; the
    // blocked batch itself is shared, never copied).
    let bf = filter.clone();
    let sh = *sh;
    band_map(cluster, x, sh.k * p * q, conv_image_flops(&sh), move |band| {
        conv::conv2d(band, &bf, &sh)
    })
}

/// Blocked conv2d_backward_data: dout N×(K·P·Q) blocked, filter
/// broadcast → dInput N×(C·H·W) blocked.
pub fn conv2d_backward_data_blocked(
    cluster: &Cluster,
    filter: &Matrix,
    dout: &BlockedMatrix,
    sh: &ConvShape,
    filter_resident: bool,
) -> Result<BlockedMatrix> {
    sh.validate_filter_dims(filter.rows(), filter.cols(), "conv2d_backward_data")?;
    sh.validate_window("conv2d_backward_data")?;
    let (p, q) = (sh.p(), sh.q());
    sh.validate_dout_dims(
        dout.rows(),
        dout.rows(),
        dout.cols(),
        sh.k * p * q,
        "conv2d_backward_data",
    )?;
    if !filter_resident {
        cluster.record_broadcast(filter.size_in_bytes() as u64);
    }
    let bf = filter.clone();
    let sh = *sh;
    band_map(cluster, dout, sh.c * sh.h * sh.w, conv_image_flops(&sh), move |band| {
        conv::conv2d_backward_data(&bf, band, &sh)
    })
}

/// Blocked conv2d_backward_filter: per-band **partial** filter gradients
/// (each a small K×(C·R·S) matrix) combined via a modeled tree-allreduce
/// — `log2(workers)` rounds of the gradient's bytes, never a collect of
/// the batch — with the arithmetic fold in ascending band order.
/// Single-band batches are byte-identical to CP; multi-band gradients
/// match up to summation order (documented in the module docs).
pub fn conv2d_backward_filter_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    dout: &BlockedMatrix,
    sh: &ConvShape,
) -> Result<Matrix> {
    sh.validate_input_dims(x.cols(), "conv2d_backward_filter")?;
    sh.validate_window("conv2d_backward_filter")?;
    let (p, q) = (sh.p(), sh.q());
    let (k, crs) = (sh.k, sh.c * sh.r * sh.s);
    sh.validate_dout_dims(x.rows(), dout.rows(), dout.cols(), k * p * q, "conv2d_backward_filter")?;
    let realigned = align_batch_grid(cluster, x, dout)?;
    let dout = realigned.as_ref().unwrap_or(dout);
    charge_band_shuffle(cluster, x);
    charge_band_shuffle(cluster, dout);
    // One task per band computes its partial gradient; the partials fold
    // at the driver in ascending band order — the serial fold order, so
    // multi-band results are byte-identical to threads=1.
    let xs = Arc::new(x.clone());
    let ds = Arc::new(dout.clone());
    let sh = *sh;
    let mut tasks: Vec<DistTask<Result<(Matrix, u64)>>> = Vec::with_capacity(x.block_rows());
    for i in 0..x.block_rows() {
        let xs = Arc::clone(&xs);
        let ds = Arc::clone(&ds);
        tasks.push((
            cluster.worker_for(i, 0),
            Box::new(move || {
                let xb = row_band(&xs, i)?;
                let db = row_band(&ds, i)?;
                let partial = conv::conv2d_backward_filter(&xb, &db, &sh)?;
                Ok((partial, xb.rows() as u64))
            }),
        ));
    }
    let mut acc: Option<DenseMatrix> = None;
    for (i, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (partial, band_rows) = res?;
        cluster.record_task(cluster.worker_for(i, 0), conv_image_flops(&sh) * band_rows);
        acc = Some(match acc {
            // First band's partial is adopted as-is (byte-identical for
            // single-band batches).
            None => partial.to_dense(),
            Some(mut df) => {
                let pd = partial.to_dense();
                for (o, v) in df.data.iter_mut().zip(pd.data.iter()) {
                    *o += *v;
                }
                df
            }
        });
    }
    let out = Matrix::Dense(acc.unwrap_or_else(|| DenseMatrix::zeros(k, crs)));
    // The reduction of band partials (and the replication of the summed
    // gradient to every worker) is a tree-allreduce: log2(workers)
    // rounds of the gradient's bytes, charged to shuffle accounting.
    cluster.record_allreduce(out.size_in_bytes() as u64);
    Ok(out)
}

/// Blocked max_pool forward → N×(C·P·Q) blocked.
pub fn max_pool_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    sh: &ConvShape,
) -> Result<BlockedMatrix> {
    sh.validate_input_dims(x.cols(), "max_pool")?;
    sh.validate_window("max_pool")?;
    let (p, q) = (sh.p(), sh.q());
    let sh = *sh;
    band_map(cluster, x, sh.c * p * q, pool_image_flops(&sh), move |band| {
        conv::max_pool2d(band, &sh)
    })
}

/// Blocked avg_pool forward → N×(C·P·Q) blocked.
pub fn avg_pool_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    sh: &ConvShape,
) -> Result<BlockedMatrix> {
    sh.validate_input_dims(x.cols(), "avg_pool")?;
    sh.validate_window("avg_pool")?;
    let (p, q) = (sh.p(), sh.q());
    let sh = *sh;
    band_map(cluster, x, sh.c * p * q, pool_image_flops(&sh), move |band| {
        conv::avg_pool2d(band, &sh)
    })
}

/// Blocked pool backward (shared by max and avg): `x` and `dout` are both
/// batch-shaped blocked operands whose bands pair up worker-side.
fn pool_backward_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    dout: &BlockedMatrix,
    sh: &ConvShape,
    op: &str,
    kernel: impl Fn(&Matrix, &Matrix, &ConvShape) -> Result<Matrix> + Send + Sync + 'static,
) -> Result<BlockedMatrix> {
    sh.validate_input_dims(x.cols(), op)?;
    sh.validate_window(op)?;
    let (p, q) = (sh.p(), sh.q());
    sh.validate_dout_dims(x.rows(), dout.rows(), dout.cols(), sh.c * p * q, op)?;
    let realigned = align_batch_grid(cluster, x, dout)?;
    let dout = realigned.as_ref().unwrap_or(dout);
    charge_band_shuffle(cluster, x);
    charge_band_shuffle(cluster, dout);
    let bs = x.block_size();
    let thr = cluster.sparsity_threshold();
    let out_cols = sh.c * sh.h * sh.w;
    let obc = super::ceil_div(out_cols, bs);
    let xs = Arc::new(x.clone());
    let ds = Arc::new(dout.clone());
    let sh = *sh;
    let kernel = Arc::new(kernel);
    let mut tasks: Vec<DistTask<Result<(Vec<Arc<Matrix>>, u64)>>> =
        Vec::with_capacity(x.block_rows());
    for i in 0..x.block_rows() {
        let xs = Arc::clone(&xs);
        let ds = Arc::clone(&ds);
        let kernel = Arc::clone(&kernel);
        tasks.push((
            cluster.worker_for(i, 0),
            Box::new(move || {
                let xb = row_band(&xs, i)?;
                let db = row_band(&ds, i)?;
                let mut out = Vec::with_capacity(obc);
                split_band(kernel(&xb, &db, &sh)?, bs, out_cols, thr, &mut out)?;
                Ok((out, xb.rows() as u64))
            }),
        ));
    }
    let mut blocks = Vec::with_capacity(x.block_rows() * obc);
    for (i, res) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (band_blocks, band_rows) = res?;
        cluster.record_task(cluster.worker_for(i, 0), pool_image_flops(&sh) * band_rows);
        blocks.extend(band_blocks);
    }
    Ok(BlockedMatrix::from_shared_blocks(x.rows(), out_cols, bs, blocks))
}

/// Blocked max_pool backward → dInput N×(C·H·W) blocked.
pub fn max_pool_backward_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    dout: &BlockedMatrix,
    sh: &ConvShape,
) -> Result<BlockedMatrix> {
    pool_backward_blocked(cluster, x, dout, sh, "max_pool_backward", conv::max_pool2d_backward)
}

/// Blocked avg_pool backward → dInput N×(C·H·W) blocked.
pub fn avg_pool_backward_blocked(
    cluster: &Cluster,
    x: &BlockedMatrix,
    dout: &BlockedMatrix,
    sh: &ConvShape,
) -> Result<BlockedMatrix> {
    pool_backward_blocked(cluster, x, dout, sh, "avg_pool_backward", conv::avg_pool2d_backward)
}

/// Blocked bias_add / bias_multiply: a per-block map — block (i,j) holds
/// global columns `j·bs ..`, so each cell's channel is
/// `(j·bs + local) / (P·Q)` and the K×1 bias broadcast joins map-side
/// without any band assembly. `mul` selects multiply over add.
pub fn bias_op_blocked(
    cluster: &Cluster,
    m: &BlockedMatrix,
    bias: &Matrix,
    k: usize,
    mul: bool,
    bias_resident: bool,
) -> Result<BlockedMatrix> {
    let op = if mul { "bias_multiply" } else { "bias_add" };
    if k == 0 || bias.rows() != k || bias.cols() != 1 {
        // The CP kernels' exact messages.
        if mul {
            return Err(DmlError::rt("bias_multiply: bias must be Kx1"));
        }
        return Err(DmlError::rt(format!(
            "bias_add: bias must be {}x1, got {}x{}",
            k,
            bias.rows(),
            bias.cols()
        )));
    }
    if m.cols() % k != 0 {
        return Err(DmlError::rt(format!("{op}: ncol(input) not divisible by K")));
    }
    if !bias_resident {
        cluster.record_broadcast(bias.size_in_bytes() as u64);
    }
    let pq = m.cols() / k;
    let bs = m.block_size();
    let thr = cluster.sparsity_threshold();
    let (brows, bcols) = (m.block_rows(), m.block_cols());
    // Each task joins its block against the broadcast bias copy.
    let bias = Arc::new(bias.clone());
    let mut tasks: Vec<DistTask<Arc<Matrix>>> = Vec::with_capacity(brows * bcols);
    for i in 0..brows {
        for j in 0..bcols {
            let b = m.shared_block(i, j);
            let bias = Arc::clone(&bias);
            tasks.push((
                cluster.worker_for(i, j),
                Box::new(move || {
                    let mut d = b.to_dense();
                    for r in 0..d.rows {
                        let row = d.row_mut(r);
                        for (local, cell) in row.iter_mut().enumerate() {
                            let kk = (j * bs + local) / pq;
                            let bv = bias.get(kk, 0);
                            if mul {
                                *cell *= bv;
                            } else {
                                *cell += bv;
                            }
                        }
                    }
                    Arc::new(Matrix::Dense(d).examine_and_convert_with(thr))
                }),
            ));
        }
    }
    let mut blocks = Vec::with_capacity(brows * bcols);
    for (idx, out) in cluster.run_tasks(tasks).into_iter().enumerate() {
        let (i, j) = (idx / bcols, idx % bcols);
        cluster.record_task(cluster.worker_for(i, j), m.block(i, j).len() as u64);
        blocks.push(out);
    }
    Ok(BlockedMatrix::from_shared_blocks(m.rows(), m.cols(), bs, blocks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};
    use crate::util::quickcheck::approx_eq_slice;

    fn conv_shape() -> ConvShape {
        ConvShape { c: 2, h: 6, w: 5, k: 3, r: 3, s: 2, stride: (2, 1), pad: (1, 1) }
    }

    fn batch(n: usize, cols: usize, seed: u64) -> Matrix {
        rand(n, cols, -1.0, 1.0, 0.7, Pdf::Uniform, seed).unwrap()
    }

    #[test]
    fn conv2d_blocked_matches_cp_bytewise_across_bands() {
        let sh = conv_shape();
        let chw = sh.c * sh.h * sh.w; // 60
        // block 16 < 60: multi-column grid (band shuffle) AND the batch
        // straddles several row blocks.
        let cluster = Cluster::new(3, 16);
        let x = batch(40, chw, 81);
        let f = batch(sh.k, sh.c * sh.r * sh.s, 82);
        let xb = cluster.blockify(&x).unwrap();
        cluster.reset_accounting();
        let out = conv2d_blocked(&cluster, &xb, &f, &sh, false).unwrap();
        let cp = conv::conv2d(&x, &f, &sh).unwrap();
        assert_eq!(out.to_local().unwrap(), cp, "per-image kernel reuse is byte-identical");
        assert_eq!(out.shape(), (40, sh.k * sh.p() * sh.q()));
        // Filter broadcast charged; multi-column grid charges the band
        // re-partition as a shuffle.
        let d = cluster.comm_bytes();
        assert!(d >= f.size_in_bytes() as u64 * 3, "filter must broadcast: {d}");
        assert_eq!(cluster.collect_count(), 0);
    }

    #[test]
    fn conv2d_blocked_single_column_grid_is_shuffle_free() {
        let sh = ConvShape { c: 1, h: 5, w: 5, k: 2, r: 3, s: 3, stride: (1, 1), pad: (0, 0) };
        let cluster = Cluster::new(2, 32); // 25 cols < 32: one block column
        let x = batch(50, 25, 83);
        let f = batch(2, 9, 84);
        let xb = cluster.blockify(&x).unwrap();
        cluster.reset_accounting();
        let out = conv2d_blocked(&cluster, &xb, &f, &sh, true).unwrap();
        assert_eq!(cluster.comm_bytes(), 0, "resident filter + banded rows: no traffic");
        assert_eq!(out.to_local().unwrap(), conv::conv2d(&x, &f, &sh).unwrap());
    }

    #[test]
    fn backward_data_and_pools_match_cp_bytewise() {
        let sh = conv_shape();
        let chw = sh.c * sh.h * sh.w;
        let (p, q) = (sh.p(), sh.q());
        let cluster = Cluster::new(3, 16);
        let x = batch(21, chw, 85);
        let f = batch(sh.k, sh.c * sh.r * sh.s, 86);
        let dout = batch(21, sh.k * p * q, 87);
        let xb = cluster.blockify(&x).unwrap();
        let doutb = cluster.blockify(&dout).unwrap();
        let dx = conv2d_backward_data_blocked(&cluster, &f, &doutb, &sh, false).unwrap();
        assert_eq!(dx.to_local().unwrap(), conv::conv2d_backward_data(&f, &dout, &sh).unwrap());
        // Pools (window reuses r×s with k ignored).
        let dpool = batch(21, sh.c * p * q, 88);
        let dpoolb = cluster.blockify(&dpool).unwrap();
        let mp = max_pool_blocked(&cluster, &xb, &sh).unwrap();
        assert_eq!(mp.to_local().unwrap(), conv::max_pool2d(&x, &sh).unwrap());
        let ap = avg_pool_blocked(&cluster, &xb, &sh).unwrap();
        assert_eq!(ap.to_local().unwrap(), conv::avg_pool2d(&x, &sh).unwrap());
        let mb = max_pool_backward_blocked(&cluster, &xb, &dpoolb, &sh).unwrap();
        assert_eq!(mb.to_local().unwrap(), conv::max_pool2d_backward(&x, &dpool, &sh).unwrap());
        let ab = avg_pool_backward_blocked(&cluster, &xb, &dpoolb, &sh).unwrap();
        assert_eq!(ab.to_local().unwrap(), conv::avg_pool2d_backward(&x, &dpool, &sh).unwrap());
        assert_eq!(cluster.collect_count(), 0, "nothing above may collect");
    }

    #[test]
    fn backward_filter_partials_combine_without_collect() {
        let sh = conv_shape();
        let chw = sh.c * sh.h * sh.w;
        let (p, q) = (sh.p(), sh.q());
        let cluster = Cluster::new(3, 16);
        let x = batch(40, chw, 89);
        let dout = batch(40, sh.k * p * q, 90);
        let xb = cluster.blockify(&x).unwrap();
        let doutb = cluster.blockify(&dout).unwrap();
        cluster.reset_accounting();
        let df = conv2d_backward_filter_blocked(&cluster, &xb, &doutb, &sh).unwrap();
        let cp = conv::conv2d_backward_filter(&x, &dout, &sh).unwrap();
        assert_eq!(df.shape(), (sh.k, sh.c * sh.r * sh.s));
        // Multi-band: partials fold per band — equal up to summation order.
        assert!(approx_eq_slice(&df.to_row_major_vec(), &cp.to_row_major_vec(), 1e-9));
        assert_eq!(cluster.collect_count(), 0, "partials return with the job");
        // Single-band batch: byte-identical.
        let cluster2 = Cluster::new(2, 64);
        let x1 = batch(8, chw, 91);
        let d1 = batch(8, sh.k * p * q, 92);
        let df1 = conv2d_backward_filter_blocked(
            &cluster2,
            &cluster2.blockify(&x1).unwrap(),
            &cluster2.blockify(&d1).unwrap(),
            &sh,
        )
        .unwrap();
        assert_eq!(df1, conv::conv2d_backward_filter(&x1, &d1, &sh).unwrap());
    }

    #[test]
    fn blocked_errors_match_cp_bytewise() {
        let sh = conv_shape();
        let chw = sh.c * sh.h * sh.w;
        let (p, q) = (sh.p(), sh.q());
        let cluster = Cluster::new(2, 16);
        let x = batch(10, chw, 93);
        let xb = cluster.blockify(&x).unwrap();
        // Batch-dim mismatch in dout (the two-operand validation bugfix).
        let bad = batch(7, sh.c * p * q, 94);
        let badb = cluster.blockify(&bad).unwrap();
        let cp = conv::max_pool2d_backward(&x, &bad, &sh).unwrap_err().to_string();
        let dist =
            max_pool_backward_blocked(&cluster, &xb, &badb, &sh).unwrap_err().to_string();
        assert_eq!(cp, dist);
        // Wrong input width.
        let sh_bad = ConvShape { c: 3, ..sh };
        let cp2 = conv::max_pool2d(&x, &sh_bad).unwrap_err().to_string();
        let dist2 = max_pool_blocked(&cluster, &xb, &sh_bad).unwrap_err().to_string();
        assert_eq!(cp2, dist2);
        // Narrow filter in backward_data (the former panic path).
        let narrow = batch(sh.k, 3, 95);
        let dout = batch(10, sh.k * p * q, 96);
        let doutb = cluster.blockify(&dout).unwrap();
        let cp3 = conv::conv2d_backward_data(&narrow, &dout, &sh).unwrap_err().to_string();
        let dist3 = conv2d_backward_data_blocked(&cluster, &narrow, &doutb, &sh, false)
            .unwrap_err()
            .to_string();
        assert_eq!(cp3, dist3);
        assert_eq!(cluster.collect_count(), 0, "validation must never collect");
    }

    #[test]
    fn bias_ops_match_cp_map_side() {
        let cluster = Cluster::new(2, 16);
        // K=3 channels, P*Q=20 → 60 cols over 16-blocks: channel
        // boundaries straddle blocks.
        let x = batch(20, 60, 97);
        let bias = batch(3, 1, 98);
        let xb = cluster.blockify(&x).unwrap();
        let add = bias_op_blocked(&cluster, &xb, &bias, 3, false, false).unwrap();
        assert_eq!(add.to_local().unwrap(), conv::bias_add(&x, &bias, 3).unwrap());
        let mul = bias_op_blocked(&cluster, &xb, &bias, 3, true, false).unwrap();
        assert_eq!(mul.to_local().unwrap(), conv::bias_multiply(&x, &bias, 3).unwrap());
        // Bad bias raises the CP error.
        let cp = conv::bias_add(&x, &bias, 4).unwrap_err().to_string();
        let dist = bias_op_blocked(&cluster, &xb, &bias, 4, false, false)
            .unwrap_err()
            .to_string();
        assert_eq!(cp, dist);
    }

    #[test]
    fn empty_batch_yields_empty_blocked_outputs() {
        let sh = ConvShape { c: 1, h: 4, w: 4, k: 2, r: 3, s: 3, stride: (1, 1), pad: (1, 1) };
        let cluster = Cluster::new(2, 8);
        let xb = cluster.blockify(&Matrix::zeros(0, 16)).unwrap();
        let f = batch(2, 9, 99);
        let out = conv2d_blocked(&cluster, &xb, &f, &sh, false).unwrap();
        assert_eq!(out.shape(), (0, 2 * 16));
        let df = conv2d_backward_filter_blocked(
            &cluster,
            &xb,
            &cluster.blockify(&Matrix::zeros(0, 2 * 16)).unwrap(),
            &sh,
        )
        .unwrap();
        assert_eq!(df, Matrix::zeros(2, 9));
    }
}
