//! The distributed blocked backend (paper §3 "Distributed Operations").
//!
//! SystemML's distributed runtime represents a matrix as an RDD of
//! `(blockIndex, MatrixBlock)` pairs and compiles heavy operators to
//! block-parallel Spark jobs. This module reproduces that design over a
//! **simulated cluster**: [`BlockedMatrix`] is the block-partitioned
//! matrix value (each block an ordinary dense/sparse [`Matrix`], so all
//! sparse-aware physical operators apply per block), and [`Cluster`]
//! models the executors — blocks are deterministically assigned to
//! workers, per-worker FLOPs and broadcast/shuffle volumes are accounted,
//! and [`Cluster::modeled_time_seconds`] turns the accounting into the
//! paper's modeled-scaling numbers (E3). The actual arithmetic runs
//! locally and exactly, so distributed plans are numerically equivalent
//! to CP plans up to floating-point summation order.
//!
//! The blocked operators live in [`ops`]; the compiler's ExecType
//! assignment (see `hop::plan`) decides when the interpreter routes an
//! operator here instead of CP.

pub mod cache;
pub mod ops;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::dist::cache::{BlockCache, CacheOutcome, LineageRef};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::{reorg, Matrix};
use crate::util::error::{DmlError, Result};
use crate::util::metrics;

/// Ceiling division for block-grid extents.
#[inline]
pub(crate) fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// The simulated cluster: a worker pool with per-worker accounting.
///
/// All counters use interior mutability so a shared `&Cluster` can be
/// handed to concurrent parfor workers.
#[derive(Debug)]
pub struct Cluster {
    num_workers: usize,
    /// Block size (rows/cols) used when blockifying local matrices.
    pub block_size: usize,
    worker_flops: Vec<AtomicU64>,
    broadcast_bytes: AtomicU64,
    shuffle_bytes: AtomicU64,
    tasks: AtomicU64,
    blockify_ops: AtomicU64,
    collects: AtomicU64,
    /// Resident block-partition cache (lineage-keyed reuse).
    cache: BlockCache,
}

impl Cluster {
    /// A cluster of `num_workers` executors using `block_size` blocks and
    /// an unbounded block-partition cache.
    pub fn new(num_workers: usize, block_size: usize) -> Cluster {
        Cluster::with_storage(num_workers, block_size, usize::MAX)
    }

    /// A cluster with an explicit total storage budget (bytes) for the
    /// resident block-partition cache; 0 disables caching.
    pub fn with_storage(num_workers: usize, block_size: usize, storage: usize) -> Cluster {
        let workers = num_workers.max(1);
        Cluster {
            num_workers: workers,
            block_size: block_size.max(1),
            worker_flops: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            broadcast_bytes: AtomicU64::new(0),
            shuffle_bytes: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            blockify_ops: AtomicU64::new(0),
            collects: AtomicU64::new(0),
            cache: BlockCache::new(storage),
        }
    }

    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// The resident block-partition cache.
    pub fn cache(&self) -> &BlockCache {
        &self.cache
    }

    /// Partition a driver matrix into blocks, counting the repartition on
    /// this cluster and in the global metrics. All blockifies of this
    /// cluster flow through here so reuse is observable per cluster.
    pub fn blockify(&self, m: &Matrix) -> Result<BlockedMatrix> {
        let b = BlockedMatrix::from_local(m, self.block_size)?;
        self.blockify_ops.fetch_add(1, Ordering::Relaxed);
        metrics::global().blockify_ops.fetch_add(1, Ordering::Relaxed);
        Ok(b)
    }

    /// Resolve an operand to blocked form through the cache (see
    /// [`BlockCache::acquire`]).
    pub fn acquire_blocked(
        &self,
        hint: Option<&LineageRef>,
        m: &Matrix,
    ) -> Result<(Arc<BlockedMatrix>, CacheOutcome)> {
        self.cache.acquire(self, hint, m)
    }

    /// Collect a blocked matrix to the driver, counting the collect.
    pub fn collect(&self, b: &BlockedMatrix) -> Result<Matrix> {
        self.collects.fetch_add(1, Ordering::Relaxed);
        metrics::global().dist_collects.fetch_add(1, Ordering::Relaxed);
        b.to_local()
    }

    /// Blockify operations performed on this cluster since creation.
    pub fn blockify_count(&self) -> u64 {
        self.blockify_ops.load(Ordering::Relaxed)
    }

    /// Collect-to-driver operations performed on this cluster.
    pub fn collect_count(&self) -> u64 {
        self.collects.load(Ordering::Relaxed)
    }

    /// Zero all per-cluster accounting (benches call this between runs).
    pub fn reset_accounting(&self) {
        for w in &self.worker_flops {
            w.store(0, Ordering::Relaxed);
        }
        self.broadcast_bytes.store(0, Ordering::Relaxed);
        self.shuffle_bytes.store(0, Ordering::Relaxed);
        self.tasks.store(0, Ordering::Relaxed);
        self.blockify_ops.store(0, Ordering::Relaxed);
        self.collects.store(0, Ordering::Relaxed);
    }

    /// FLOPs executed per worker since the last reset.
    pub fn worker_flops(&self) -> Vec<u64> {
        self.worker_flops.iter().map(|w| w.load(Ordering::Relaxed)).collect()
    }

    /// Total distributed tasks launched since the last reset.
    pub fn tasks(&self) -> u64 {
        self.tasks.load(Ordering::Relaxed)
    }

    /// Communication volume (broadcast + shuffle) since the last reset.
    pub fn comm_bytes(&self) -> u64 {
        self.broadcast_bytes.load(Ordering::Relaxed) + self.shuffle_bytes.load(Ordering::Relaxed)
    }

    /// Modeled wallclock for the recorded work: the makespan of the
    /// slowest worker at `flops_per_sec`, plus communication time at
    /// `bytes_per_sec` (0 = communication not modeled).
    pub fn modeled_time_seconds(&self, flops_per_sec: f64, bytes_per_sec: u64) -> f64 {
        let max_flops =
            self.worker_flops.iter().map(|w| w.load(Ordering::Relaxed)).max().unwrap_or(0);
        let mut t = max_flops as f64 / flops_per_sec.max(1.0);
        if bytes_per_sec > 0 {
            t += self.comm_bytes() as f64 / bytes_per_sec as f64;
        }
        t
    }

    /// Deterministic block→worker placement (hash partitioning on the
    /// block index, like Spark's default partitioner).
    #[inline]
    pub fn worker_for(&self, block_row: usize, block_col: usize) -> usize {
        (block_row + block_col) % self.num_workers
    }

    /// Record one executed task on `worker` costing `flops`.
    pub(crate) fn record_task(&self, worker: usize, flops: u64) {
        self.worker_flops[worker % self.num_workers].fetch_add(flops, Ordering::Relaxed);
        self.tasks.fetch_add(1, Ordering::Relaxed);
        metrics::global().dist_tasks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a broadcast of `bytes` to every worker.
    pub(crate) fn record_broadcast(&self, bytes: u64) {
        let total = bytes * self.num_workers as u64;
        self.broadcast_bytes.fetch_add(total, Ordering::Relaxed);
        metrics::global().add_broadcast(total);
    }

    /// Record `bytes` moved through a shuffle.
    pub(crate) fn record_shuffle(&self, bytes: u64) {
        self.shuffle_bytes.fetch_add(bytes, Ordering::Relaxed);
        metrics::global().add_shuffle(bytes);
    }
}

/// A block-partitioned matrix: an `rbrows × rbcols` grid of dense/sparse
/// blocks of at most `block_size × block_size` cells, mirroring
/// SystemML's binary-block RDD representation.
#[derive(Clone, Debug)]
pub struct BlockedMatrix {
    rows: usize,
    cols: usize,
    block_size: usize,
    /// Blocks in row-major grid order.
    blocks: Vec<Matrix>,
}

impl BlockedMatrix {
    /// Partition a local matrix into blocks (SystemML's "blockify").
    ///
    /// A 0-row/0-column matrix (legal in DML — e.g. the result of an
    /// empty indexing range) yields an empty blocked handle with a 0-extent
    /// grid rather than an error.
    pub fn from_local(m: &Matrix, block_size: usize) -> Result<BlockedMatrix> {
        if block_size == 0 {
            return Err(DmlError::rt("blockify: block size must be positive"));
        }
        let (rows, cols) = m.shape();
        if rows == 0 || cols == 0 {
            return Ok(BlockedMatrix { rows, cols, block_size, blocks: Vec::new() });
        }
        let brows = ceil_div(rows, block_size);
        let bcols = ceil_div(cols, block_size);
        let mut blocks = Vec::with_capacity(brows * bcols);
        for br in 0..brows {
            let rl = br * block_size;
            let ru = (rl + block_size).min(rows);
            for bc in 0..bcols {
                let cl = bc * block_size;
                let cu = (cl + block_size).min(cols);
                blocks.push(reorg::slice(m, rl, ru, cl, cu)?.examine_and_convert());
            }
        }
        Ok(BlockedMatrix { rows, cols, block_size, blocks })
    }

    /// Assemble a blocked matrix from a pre-computed grid of blocks.
    pub(crate) fn from_blocks(
        rows: usize,
        cols: usize,
        block_size: usize,
        blocks: Vec<Matrix>,
    ) -> BlockedMatrix {
        debug_assert_eq!(
            blocks.len(),
            ceil_div(rows, block_size) * ceil_div(cols, block_size)
        );
        BlockedMatrix { rows, cols, block_size, blocks }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Grid extent in block rows.
    pub fn block_rows(&self) -> usize {
        ceil_div(self.rows, self.block_size)
    }

    /// Grid extent in block columns.
    pub fn block_cols(&self) -> usize {
        ceil_div(self.cols, self.block_size)
    }

    /// Borrow the block at grid position (br, bc).
    pub fn block(&self, br: usize, bc: usize) -> &Matrix {
        &self.blocks[br * self.block_cols() + bc]
    }

    /// Exact number of non-zeros across all blocks.
    pub fn nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).sum()
    }

    /// Total in-memory size across blocks.
    pub fn size_in_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.size_in_bytes()).sum()
    }

    /// Collect to a local matrix (SystemML's "collect to driver").
    pub fn to_local(&self) -> Result<Matrix> {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        let bcols = self.block_cols();
        for (i, b) in self.blocks.iter().enumerate() {
            let (br, bc) = (i / bcols, i % bcols);
            out.assign(br * self.block_size, bc * self.block_size, &b.to_dense())?;
        }
        Ok(Matrix::Dense(out).examine_and_convert())
    }

    /// Collect to a row-major dense vector.
    pub fn to_row_major_vec(&self) -> Vec<f64> {
        match self.to_local() {
            Ok(m) => m.to_row_major_vec(),
            Err(_) => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};

    #[test]
    fn blockify_grid_shapes() {
        let m = rand(70, 33, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
        let b = BlockedMatrix::from_local(&m, 32).unwrap();
        assert_eq!(b.block_rows(), 3);
        assert_eq!(b.block_cols(), 2);
        assert_eq!(b.block(0, 0).shape(), (32, 32));
        assert_eq!(b.block(2, 1).shape(), (6, 1));
        assert_eq!(b.to_local().unwrap(), m);
    }

    #[test]
    fn blockify_preserves_nnz() {
        let m = rand(50, 50, -1.0, 1.0, 0.1, Pdf::Uniform, 2).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        assert_eq!(b.nnz(), m.nnz());
    }

    #[test]
    fn cluster_accounting_resets() {
        let c = Cluster::new(3, 8);
        c.record_task(0, 100);
        c.record_task(1, 50);
        c.record_broadcast(10);
        assert_eq!(c.worker_flops(), vec![100, 50, 0]);
        assert_eq!(c.tasks(), 2);
        assert_eq!(c.comm_bytes(), 30);
        c.reset_accounting();
        assert_eq!(c.worker_flops(), vec![0, 0, 0]);
        assert_eq!(c.comm_bytes(), 0);
    }

    #[test]
    fn modeled_time_scales_with_makespan() {
        let c = Cluster::new(2, 8);
        c.record_task(0, 1_000_000);
        c.record_task(1, 2_000_000);
        let t = c.modeled_time_seconds(1e6, 0);
        assert!((t - 2.0).abs() < 1e-9, "{t}");
    }
}
