//! The distributed blocked backend (paper §3 "Distributed Operations").
//!
//! SystemML's distributed runtime represents a matrix as an RDD of
//! `(blockIndex, MatrixBlock)` pairs and compiles heavy operators to
//! block-parallel Spark jobs. This module reproduces that design over a
//! **simulated cluster**: [`BlockedMatrix`] is the block-partitioned
//! matrix value (each block an ordinary dense/sparse [`Matrix`], so all
//! sparse-aware physical operators apply per block), and [`Cluster`]
//! models the executors — blocks are deterministically assigned to
//! workers, per-worker FLOPs and broadcast/shuffle volumes are accounted,
//! and [`Cluster::modeled_time_seconds`] turns the accounting into the
//! paper's modeled-scaling numbers (E3). The actual arithmetic runs
//! locally and exactly, so distributed plans are numerically equivalent
//! to CP plans up to floating-point summation order.
//!
//! The blocked operators live in [`ops`] — matmult, cellwise (including
//! the map-side broadcast join for row/col-vector operands), aggregates,
//! transpose, and block-range right-/left-indexing, so iterative
//! mini-batch loops (`X[beg:end,]` → normalize → matmult → aggregate)
//! stay blocked end-to-end — and in [`nn`], the blocked conv2d / pooling
//! operators that run CNN training worker-side over row-partitioned
//! mini-batches (filters broadcast, filter gradients combined as small
//! driver-side partials). The compiler's ExecType assignment (see
//! `hop::plan`) decides when the interpreter routes an operator here
//! instead of CP.
//!
//! # Execution model (thread-level parallelism)
//!
//! Since PR 6 the per-block work is *actually* concurrent, not just
//! accounted: every blocked operator builds a **task batch** — one
//! `'static` closure per block (or per row band for the NN operators),
//! capturing `Arc<Matrix>` block clones — and hands it to the [`pool`]
//! owned by this cluster via [`Cluster::run_tasks`]. Each task executes
//! on the long-lived worker thread matching [`Cluster::worker_for`]`(i,j)`
//! (the same placement the FLOP accounting attributes), the batch joins
//! at a barrier, and the results come back in **submission order**. All
//! reductions — the k-accumulation inside a matmult task, aggregate
//! partial folds, conv2d filter-gradient band folds — happen either
//! inside a single task or on the driver in the original serial order, so
//! results are **byte-identical** to serial execution regardless of the
//! thread count.
//!
//! The thread count comes from `SystemConfig::dist_threads` (default: one
//! thread per simulated worker). Setting `dist_threads = 1` is the escape
//! hatch that restores fully serial in-line execution for debugging —
//! same results, zero threads spawned. Tasks are pure compute: all
//! cache/handle bookkeeping (the [`cache::BlockCache`] mutex, live-value
//! registration) happens at dispatch time on the driver thread, so tasks
//! never contend on a lock.
//!
//! # Tree-allreduce and replicated values (resident training)
//!
//! Gradient-shaped results — a single-block matmult output folded over a
//! multi-block inner dimension, a `conv2d_backward_filter` band fold, a
//! single-block axis aggregate of a blocked operand — used to be the one
//! value class that returned to the driver every iteration. They are now
//! aggregated via a modeled **tree-allreduce**
//! ([`Cluster::record_allreduce`]): `ceil(log2(num_workers))` reduction
//! rounds, each moving the result's bytes, charged to shuffle accounting
//! (and attributed separately as `allreduce_rounds`/`allreduce_bytes`).
//! The arithmetic fold itself stays sequential in a **fixed partial
//! order** (ascending inner-block / band index) that depends only on the
//! block grid, never on the worker or thread count — so results are
//! byte-identical across `num_workers` and `dist_threads`.
//!
//! The product of an allreduce is a **replicated** blocked value
//! ([`BlockedHandle::replicated`]): a single-block value resident on
//! *every* worker, the shape model state takes during training. A
//! replicated handle forces ([`BlockedHandle::force`]) and gathers for
//! free — the value arrives with the job, like SystemML's SINGLE_BLOCK
//! aggregation, never as a collect — and its storage charge is
//! `bytes × num_workers`. Optimizer updates (`W - lr*dW`, momentum
//! maps) on replicated operands produce replicated outputs, so weights
//! and moment buffers stay cluster-resident for a whole multi-epoch job
//! at **0 driver collects total**. A spilled replicated value re-enters
//! the cluster as a broadcast (it must reach every worker again).
//!
//! # CSR block lifecycle (per-block representation)
//!
//! Every block of a [`BlockedMatrix`] is an ordinary [`Matrix`] and so
//! carries its own physical format — dense row-major or CSR — chosen
//! per block, exactly as SystemML's binary-block RDDs mix dense and
//! sparse `MatrixBlock`s within one matrix:
//!
//! 1. **Blockify** inspects each block's exact nnz and stores it CSR
//!    when `nnz/cells` is below the cluster's sparsity turn point
//!    ([`Cluster::sparsity_threshold`], from
//!    `SystemConfig::sparsity_threshold`, default 0.4) and the block
//!    has at least `MIN_SPARSE_CELLS` cells. A mostly-empty stripe of
//!    an otherwise dense matrix blockifies sparse on its own.
//! 2. **Operators** run format-aware CP kernels per block (sparse×dense
//!    / dense×sparse / sparse×sparse matmult, intersect/union cellwise,
//!    counting-sort transpose, row-range CSR slice) and re-examine each
//!    *output* block against the same threshold, so representation
//!    follows the data through a plan: a `*` that annihilates a block
//!    crosses to CSR; an `exp` map densifies. Worker tasks build the
//!    CSR blocks; all driver-side folds keep the serial block order, so
//!    results stay byte-identical across `dist_threads`.
//! 3. **Accounting** charges communication and storage by *encoded*
//!    bytes (`Matrix::size_in_bytes` of the actual representation), so
//!    broadcast/shuffle/allreduce volumes, live-value budgets, cache
//!    charges and the planner's comm costing all shrink with sparsity.
//! 4. **Cache guards** hash content format-independently (see
//!    [`cache`]), so a dense↔CSR representation change of equal values
//!    still hits, while any value change misses.

pub mod cache;
pub mod nn;
pub mod ops;
pub mod pool;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::runtime::dist::cache::{BlockCache, CacheOutcome, LineageRef};
use crate::runtime::matrix::dense::DenseMatrix;
use crate::runtime::matrix::{reorg, Matrix, SPARSITY_TURN_POINT};
use crate::util::error::{DmlError, Result};
use crate::util::metrics;
use crate::util::stats::{Stats, WorkerSlot};

/// Ceiling division for block-grid extents.
#[inline]
pub(crate) fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// The simulated cluster: a worker pool with per-worker accounting.
///
/// All counters use interior mutability so a shared `&Cluster` can be
/// handed to concurrent parfor workers.
#[derive(Debug)]
pub struct Cluster {
    num_workers: usize,
    /// Block size (rows/cols) used when blockifying local matrices.
    pub block_size: usize,
    /// Per-block sparsity turn point: blocks below this density are
    /// stored CSR (see the module docs' CSR block lifecycle).
    sparsity_threshold: f64,
    worker_flops: Vec<AtomicU64>,
    broadcast_bytes: AtomicU64,
    shuffle_bytes: AtomicU64,
    allreduce_rounds: AtomicU64,
    allreduce_bytes: AtomicU64,
    tasks: AtomicU64,
    blockify_ops: AtomicU64,
    collects: AtomicU64,
    spills: AtomicU64,
    /// Live first-class blocked values ([`BlockedHandle`]s), oldest
    /// first. Their resident bytes are charged to the storage budget
    /// through the cache's reserved-bytes accounting; under pressure the
    /// oldest live value is *spilled* to the driver (materialize + drop
    /// blocks) instead of erroring. Dead weak refs are pruned lazily.
    live: Mutex<Vec<(u64, Weak<HandleInner>)>>,
    live_seq: AtomicU64,
    /// Storage budget for resident data overall (cache entries + live
    /// blocked values); may exceed the cache's own budget when partition
    /// caching is disabled but blocked values are not.
    live_budget: usize,
    /// Resident block-partition cache (lineage-keyed reuse).
    cache: BlockCache,
    /// Long-lived worker threads executing block tasks (see [`pool`]).
    pool: pool::WorkerPool,
    /// Session statistics (`-stats`); `None` means disabled and every
    /// stats check on the hot paths is a single pointer test.
    stats: Option<Arc<Stats>>,
    /// Per-worker utilization slots fetched once from `stats` at
    /// construction (empty when stats are off), stamped per task by
    /// [`Cluster::run_tasks`].
    worker_slots: Vec<Arc<WorkerSlot>>,
}

impl Cluster {
    /// A cluster of `num_workers` executors using `block_size` blocks and
    /// an unbounded block-partition cache.
    pub fn new(num_workers: usize, block_size: usize) -> Cluster {
        Cluster::with_storage(num_workers, block_size, usize::MAX)
    }

    /// A cluster with an explicit total storage budget (bytes) shared by
    /// the resident block-partition cache and live blocked values; a
    /// budget of 0 disables caching and spills every live value.
    pub fn with_storage(num_workers: usize, block_size: usize, storage: usize) -> Cluster {
        Cluster::with_budgets(num_workers, block_size, storage, storage)
    }

    /// A cluster with separate budgets for the lineage cache
    /// (`cache_storage`; 0 disables partition caching) and for live
    /// blocked values (`live_storage`). The interpreter uses this so
    /// turning the partition cache off does **not** also collapse the
    /// blocked-value budget to zero (which would spill every chained
    /// DIST result straight back to the driver).
    pub fn with_budgets(
        num_workers: usize,
        block_size: usize,
        cache_storage: usize,
        live_storage: usize,
    ) -> Cluster {
        let threads = num_workers.max(1);
        Cluster::with_budgets_threads(num_workers, block_size, cache_storage, live_storage, threads)
    }

    /// [`Cluster::with_budgets`] with an explicit worker-thread count.
    /// `threads = 1` restores serial in-line task execution (the
    /// debugging escape hatch); the default elsewhere is one thread per
    /// simulated worker so `num_workers` means actual concurrency.
    pub fn with_budgets_threads(
        num_workers: usize,
        block_size: usize,
        cache_storage: usize,
        live_storage: usize,
        threads: usize,
    ) -> Cluster {
        let workers = num_workers.max(1);
        Cluster {
            num_workers: workers,
            block_size: block_size.max(1),
            sparsity_threshold: SPARSITY_TURN_POINT,
            worker_flops: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            broadcast_bytes: AtomicU64::new(0),
            shuffle_bytes: AtomicU64::new(0),
            allreduce_rounds: AtomicU64::new(0),
            allreduce_bytes: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            blockify_ops: AtomicU64::new(0),
            collects: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            live: Mutex::new(Vec::new()),
            live_seq: AtomicU64::new(0),
            live_budget: live_storage,
            cache: BlockCache::new(cache_storage),
            pool: pool::WorkerPool::new(threads.max(1)),
            stats: None,
            worker_slots: Vec::new(),
        }
    }

    /// A cluster with an explicit thread count and unbounded storage
    /// (test/bench hook for serial-vs-parallel comparisons).
    pub fn with_threads(num_workers: usize, block_size: usize, threads: usize) -> Cluster {
        Cluster::with_budgets_threads(num_workers, block_size, usize::MAX, usize::MAX, threads)
    }

    /// Consuming setter for the per-block sparsity turn point (applied
    /// before the cluster is shared behind an `Arc`): blocks whose
    /// density falls strictly below `t` — and that clear the
    /// `MIN_SPARSE_CELLS` floor — are stored CSR by blockify and by
    /// every blocked operator's output re-examination.
    pub fn with_sparsity_threshold(mut self, t: f64) -> Cluster {
        self.sparsity_threshold = t.clamp(0.0, 1.0);
        self
    }

    /// The per-block sparsity turn point in effect (default 0.4).
    pub fn sparsity_threshold(&self) -> f64 {
        self.sparsity_threshold
    }

    /// Consuming setter wiring the session's statistics object in
    /// (applied before the cluster is shared behind an `Arc`, like
    /// [`Cluster::with_sparsity_threshold`]). Fetches the per-worker
    /// utilization slots once so the per-task stamping path touches
    /// only atomics the cluster already holds. `None` leaves stats off.
    pub fn with_stats(mut self, stats: Option<Arc<Stats>>) -> Cluster {
        self.worker_slots = match &stats {
            Some(s) => s.worker_slots(self.num_workers),
            None => Vec::new(),
        };
        self.stats = stats;
        self
    }

    /// The session statistics object, if stats are enabled.
    pub fn stats(&self) -> Option<&Arc<Stats>> {
        self.stats.as_ref()
    }

    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// Worker threads executing block tasks (1 = serial in-line mode).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Execute a batch of per-block tasks on the worker pool and return
    /// the results in submission order (see [`pool::WorkerPool::run_tasks`]).
    /// Operators in [`ops`]/[`nn`] place each task with
    /// [`Cluster::worker_for`] so execution matches the accounting.
    /// Public so tests and benches can probe the execution backend
    /// directly (e.g. asserting inline vs pool-thread execution).
    pub fn run_tasks<R: Send + 'static>(&self, tasks: Vec<pool::DistTask<R>>) -> Vec<R> {
        if self.worker_slots.is_empty() {
            return self.pool.run_tasks(tasks);
        }
        // Stats enabled: stamp each task's wall time and count against
        // its simulated worker's utilization slot. The stamping runs on
        // the executing thread (pool worker, or the caller in serial
        // mode); counts depend only on block placement, so they are
        // identical across `dist_threads` settings — busy time is wall
        // time and is not.
        let tasks = tasks
            .into_iter()
            .map(|(worker, f)| {
                let slot = Arc::clone(&self.worker_slots[worker % self.num_workers]);
                let timed: Box<dyn FnOnce() -> R + Send + 'static> = Box::new(move || {
                    let t0 = std::time::Instant::now();
                    let r = f();
                    slot.busy_nanos
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    slot.tasks.fetch_add(1, Ordering::Relaxed);
                    r
                });
                (worker, timed)
            })
            .collect();
        self.pool.run_tasks(tasks)
    }

    /// The resident block-partition cache.
    pub fn cache(&self) -> &BlockCache {
        &self.cache
    }

    /// Partition a driver matrix into blocks, counting the repartition on
    /// this cluster and in the global metrics. All blockifies of this
    /// cluster flow through here so reuse is observable per cluster.
    pub fn blockify(&self, m: &Matrix) -> Result<BlockedMatrix> {
        let b = BlockedMatrix::from_local_with(m, self.block_size, self.sparsity_threshold)?;
        self.blockify_ops.fetch_add(1, Ordering::Relaxed);
        metrics::global().blockify_ops.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = &self.stats {
            s.event("blockify", b.size_in_bytes() as u64);
        }
        Ok(b)
    }

    /// Resolve an operand to blocked form through the cache (see
    /// [`BlockCache::acquire`]).
    pub fn acquire_blocked(
        &self,
        hint: Option<&LineageRef>,
        m: &Matrix,
    ) -> Result<(Arc<BlockedMatrix>, CacheOutcome)> {
        self.cache.acquire(self, hint, m)
    }

    /// Collect a blocked matrix to the driver, counting the collect.
    pub fn collect(&self, b: &BlockedMatrix) -> Result<Matrix> {
        self.collects.fetch_add(1, Ordering::Relaxed);
        metrics::global().dist_collects.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = &self.stats {
            s.event("collect", b.size_in_bytes() as u64);
        }
        b.to_local()
    }

    /// Blockify operations performed on this cluster since creation.
    pub fn blockify_count(&self) -> u64 {
        self.blockify_ops.load(Ordering::Relaxed)
    }

    /// Collect-to-driver operations performed on this cluster.
    pub fn collect_count(&self) -> u64 {
        self.collects.load(Ordering::Relaxed)
    }

    /// Live blocked values spilled to the driver under storage pressure.
    pub fn spill_count(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    /// Total resident bytes held by live blocked values.
    pub fn live_blocked_bytes(&self) -> usize {
        self.cache.reserved_bytes()
    }

    /// Register a live blocked value: charge its bytes to the storage
    /// budget (shared with the block-partition cache) and relieve
    /// pressure by first evicting unpinned cache entries, then spilling
    /// the *oldest other* live value to the driver. Never errors — the
    /// worst case is that everything older is spilled and the newest
    /// value alone exceeds the budget, which we tolerate (the data has to
    /// live somewhere).
    fn register_live(&self, inner: &Arc<HandleInner>) {
        self.cache.reserve(inner.charged_bytes());
        {
            let mut live = self.live.lock().unwrap();
            live.retain(|(_, w)| w.strong_count() > 0);
            live.push((inner.seq, Arc::downgrade(inner)));
        }
        self.enforce_storage(inner.seq);
    }

    /// Spill oldest-first until resident (cache + live) bytes fit the
    /// budget; `keep_seq` is the just-registered value, never spilled.
    fn enforce_storage(&self, keep_seq: u64) {
        let budget = self.live_budget;
        loop {
            let over = self
                .cache
                .resident_and_reserved_bytes()
                .saturating_sub(budget);
            if over == 0 {
                return;
            }
            // 1. Unpinned cache entries go first (re-blockify is cheaper
            //    than a driver round trip for a live value).
            if self.cache.reclaim(over) > 0 {
                continue;
            }
            // 2. Spill the oldest live value that is still resident.
            let victim: Option<Arc<HandleInner>> = {
                let mut live = self.live.lock().unwrap();
                live.retain(|(_, w)| w.strong_count() > 0);
                live.iter()
                    .filter(|(seq, _)| *seq != keep_seq)
                    .filter_map(|(_, w)| w.upgrade())
                    .find(|h| h.is_resident())
            };
            match victim {
                Some(h) => {
                    if !h.spill(self) {
                        return; // raced with a concurrent spill/drop
                    }
                }
                None => return, // nothing left to spill
            }
        }
    }

    /// Zero all per-cluster accounting (benches call this between runs).
    pub fn reset_accounting(&self) {
        for w in &self.worker_flops {
            w.store(0, Ordering::Relaxed);
        }
        self.broadcast_bytes.store(0, Ordering::Relaxed);
        self.shuffle_bytes.store(0, Ordering::Relaxed);
        self.allreduce_rounds.store(0, Ordering::Relaxed);
        self.allreduce_bytes.store(0, Ordering::Relaxed);
        self.tasks.store(0, Ordering::Relaxed);
        self.blockify_ops.store(0, Ordering::Relaxed);
        self.collects.store(0, Ordering::Relaxed);
        self.spills.store(0, Ordering::Relaxed);
    }

    /// FLOPs executed per worker since the last reset.
    pub fn worker_flops(&self) -> Vec<u64> {
        self.worker_flops.iter().map(|w| w.load(Ordering::Relaxed)).collect()
    }

    /// Total distributed tasks launched since the last reset.
    pub fn tasks(&self) -> u64 {
        self.tasks.load(Ordering::Relaxed)
    }

    /// Communication volume (broadcast + shuffle) since the last reset.
    pub fn comm_bytes(&self) -> u64 {
        self.broadcast_bytes.load(Ordering::Relaxed) + self.shuffle_bytes.load(Ordering::Relaxed)
    }

    /// Modeled wallclock for the recorded work: the makespan of the
    /// slowest worker at `flops_per_sec`, plus communication time at
    /// `bytes_per_sec` (0 = communication not modeled).
    pub fn modeled_time_seconds(&self, flops_per_sec: f64, bytes_per_sec: u64) -> f64 {
        let max_flops =
            self.worker_flops.iter().map(|w| w.load(Ordering::Relaxed)).max().unwrap_or(0);
        let mut t = max_flops as f64 / flops_per_sec.max(1.0);
        if bytes_per_sec > 0 {
            t += self.comm_bytes() as f64 / bytes_per_sec as f64;
        }
        t
    }

    /// Deterministic block→worker placement (hash partitioning on the
    /// block index, like Spark's default partitioner).
    #[inline]
    pub fn worker_for(&self, block_row: usize, block_col: usize) -> usize {
        (block_row + block_col) % self.num_workers
    }

    /// Record one executed task on `worker` costing `flops`.
    pub(crate) fn record_task(&self, worker: usize, flops: u64) {
        self.worker_flops[worker % self.num_workers].fetch_add(flops, Ordering::Relaxed);
        self.tasks.fetch_add(1, Ordering::Relaxed);
        metrics::global().dist_tasks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a broadcast of `bytes` to every worker.
    pub(crate) fn record_broadcast(&self, bytes: u64) {
        let total = bytes * self.num_workers as u64;
        self.broadcast_bytes.fetch_add(total, Ordering::Relaxed);
        metrics::global().add_broadcast(total);
        if let Some(s) = &self.stats {
            s.event("broadcast", total);
        }
    }

    /// Record `bytes` moved through a shuffle.
    pub(crate) fn record_shuffle(&self, bytes: u64) {
        self.shuffle_bytes.fetch_add(bytes, Ordering::Relaxed);
        metrics::global().add_shuffle(bytes);
        if let Some(s) = &self.stats {
            s.event("shuffle", bytes);
        }
    }

    /// Record a modeled tree-allreduce of a `bytes`-sized result:
    /// `ceil(log2(num_workers))` reduction rounds, each moving the result
    /// once, charged to shuffle accounting and attributed separately to
    /// the allreduce counters. One worker needs no reduction — 0 rounds,
    /// 0 bytes — so allreduce traffic grows exactly ∝ log2(workers).
    pub(crate) fn record_allreduce(&self, bytes: u64) {
        let rounds = (usize::BITS - (self.num_workers - 1).leading_zeros()) as u64;
        if rounds == 0 {
            return;
        }
        let total = rounds * bytes;
        self.allreduce_rounds.fetch_add(rounds, Ordering::Relaxed);
        self.allreduce_bytes.fetch_add(total, Ordering::Relaxed);
        self.shuffle_bytes.fetch_add(total, Ordering::Relaxed);
        let g = metrics::global();
        g.allreduce_rounds.fetch_add(rounds, Ordering::Relaxed);
        g.allreduce_bytes.fetch_add(total, Ordering::Relaxed);
        g.add_shuffle(total);
        if let Some(s) = &self.stats {
            s.event("allreduce", total);
        }
    }

    /// Tree-allreduce reduction rounds executed since the last reset.
    pub fn allreduce_round_count(&self) -> u64 {
        self.allreduce_rounds.load(Ordering::Relaxed)
    }

    /// Bytes moved by tree-allreduce rounds since the last reset (a
    /// subset of the shuffle volume).
    pub fn allreduce_byte_count(&self) -> u64 {
        self.allreduce_bytes.load(Ordering::Relaxed)
    }
}

/// A block-partitioned matrix: an `rbrows × rbcols` grid of dense/sparse
/// blocks of at most `block_size × block_size` cells, mirroring
/// SystemML's binary-block RDD representation.
///
/// Blocks are refcounted (`Arc<Matrix>`): operators that carry blocks
/// over unchanged — left-index writes outside the touched region,
/// whole-block slice selection, row-band assembly on a one-column grid —
/// share them instead of copying, so a touched-block rewrite is
/// O(touched) in memory traffic.
#[derive(Clone, Debug)]
pub struct BlockedMatrix {
    rows: usize,
    cols: usize,
    block_size: usize,
    /// Blocks in row-major grid order.
    blocks: Vec<Arc<Matrix>>,
}

impl BlockedMatrix {
    /// Partition a local matrix into blocks (SystemML's "blockify").
    ///
    /// A 0-row/0-column matrix (legal in DML — e.g. the result of an
    /// empty indexing range) yields an empty blocked handle with a 0-extent
    /// grid rather than an error.
    pub fn from_local(m: &Matrix, block_size: usize) -> Result<BlockedMatrix> {
        BlockedMatrix::from_local_with(m, block_size, SPARSITY_TURN_POINT)
    }

    /// [`BlockedMatrix::from_local`] with an explicit per-block sparsity
    /// turn point: each block is cut out and stored dense or CSR
    /// according to its *own* exact nnz (see the module docs' CSR block
    /// lifecycle), so one matrix can mix formats across its grid.
    pub fn from_local_with(
        m: &Matrix,
        block_size: usize,
        sparsity_threshold: f64,
    ) -> Result<BlockedMatrix> {
        if block_size == 0 {
            return Err(DmlError::rt("blockify: block size must be positive"));
        }
        let (rows, cols) = m.shape();
        if rows == 0 || cols == 0 {
            return Ok(BlockedMatrix { rows, cols, block_size, blocks: Vec::new() });
        }
        let brows = ceil_div(rows, block_size);
        let bcols = ceil_div(cols, block_size);
        let mut blocks = Vec::with_capacity(brows * bcols);
        for br in 0..brows {
            let rl = br * block_size;
            let ru = (rl + block_size).min(rows);
            for bc in 0..bcols {
                let cl = bc * block_size;
                let cu = (cl + block_size).min(cols);
                blocks.push(Arc::new(
                    reorg::slice(m, rl, ru, cl, cu)?
                        .examine_and_convert_with(sparsity_threshold),
                ));
            }
        }
        Ok(BlockedMatrix { rows, cols, block_size, blocks })
    }

    /// Assemble a blocked matrix from a pre-computed grid of fresh blocks.
    pub(crate) fn from_blocks(
        rows: usize,
        cols: usize,
        block_size: usize,
        blocks: Vec<Matrix>,
    ) -> BlockedMatrix {
        BlockedMatrix::from_shared_blocks(
            rows,
            cols,
            block_size,
            blocks.into_iter().map(Arc::new).collect(),
        )
    }

    /// Assemble a blocked matrix from a grid that may share (`Arc` bump)
    /// blocks with its sources — the carry-over path of left-index writes
    /// and whole-block slice selection.
    pub(crate) fn from_shared_blocks(
        rows: usize,
        cols: usize,
        block_size: usize,
        blocks: Vec<Arc<Matrix>>,
    ) -> BlockedMatrix {
        debug_assert_eq!(
            blocks.len(),
            ceil_div(rows, block_size) * ceil_div(cols, block_size)
        );
        BlockedMatrix { rows, cols, block_size, blocks }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Grid extent in block rows.
    pub fn block_rows(&self) -> usize {
        ceil_div(self.rows, self.block_size)
    }

    /// Grid extent in block columns.
    pub fn block_cols(&self) -> usize {
        ceil_div(self.cols, self.block_size)
    }

    /// Borrow the block at grid position (br, bc).
    pub fn block(&self, br: usize, bc: usize) -> &Matrix {
        self.blocks[br * self.block_cols() + bc].as_ref()
    }

    /// Strong-count of the block at (br, bc) — test hook observing
    /// carry-over sharing.
    #[cfg(test)]
    pub(crate) fn block_refcount(&self, br: usize, bc: usize) -> usize {
        Arc::strong_count(&self.blocks[br * self.block_cols() + bc])
    }

    /// Share the block at grid position (br, bc) — an `Arc` bump, used by
    /// operators that carry blocks over unchanged.
    pub(crate) fn shared_block(&self, br: usize, bc: usize) -> Arc<Matrix> {
        self.blocks[br * self.block_cols() + bc].clone()
    }

    /// Exact number of non-zeros across all blocks.
    pub fn nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).sum()
    }

    /// Total in-memory size across blocks.
    pub fn size_in_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.size_in_bytes()).sum()
    }

    /// Collect to a local matrix (SystemML's "collect to driver").
    pub fn to_local(&self) -> Result<Matrix> {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        let bcols = self.block_cols();
        for (i, b) in self.blocks.iter().enumerate() {
            let (br, bc) = (i / bcols, i % bcols);
            out.assign(br * self.block_size, bc * self.block_size, &b.to_dense())?;
        }
        Ok(Matrix::Dense(out).examine_and_convert())
    }

    /// Collect to a row-major dense vector.
    pub fn to_row_major_vec(&self) -> Vec<f64> {
        match self.to_local() {
            Ok(m) => m.to_row_major_vec(),
            Err(_) => Vec::new(),
        }
    }
}

// ---- first-class blocked values ---------------------------------------

/// Shared state of one first-class blocked value.
///
/// The blocked representation lives on the cluster until it is *spilled*
/// (driver copy materialized, blocks dropped); the driver copy is
/// memoized the first time any CP consumer forces it. Invariant: at
/// least one of `blocks` / `forced` is always populated.
pub struct HandleInner {
    cluster: Arc<Cluster>,
    rows: usize,
    cols: usize,
    nnz: usize,
    /// Resident size of the blocked representation (one copy).
    bytes: usize,
    block_size: usize,
    /// Replicated values live on *every* worker (allreduce products,
    /// model/optimizer state): force and gather are free — the value
    /// arrives with the job, never as a collect — and the storage charge
    /// is `bytes × num_workers`.
    replicated: bool,
    /// Registration order on the cluster (spill is oldest-first).
    seq: u64,
    /// The resident blocked representation; `None` after a spill.
    blocks: Mutex<Option<Arc<BlockedMatrix>>>,
    /// Memoized driver materialization (the lazy collect).
    forced: OnceLock<Matrix>,
    /// Memoized worker-side gather (rhs use: broadcast-join vector,
    /// left-index patch, conv filter). Charged as one shuffle on first
    /// use — never a collect — so a loop-invariant blocked rhs is
    /// gathered once per loop, not once per op.
    gathered: OnceLock<Matrix>,
    /// Bytes the memoized gather charged to the storage budget (0 until
    /// the gather is memoized; released when the handle drops). Keeps
    /// many small memoized copies — the serving scatter case — from
    /// pinning driver memory outside any accounting.
    gathered_charge: AtomicUsize,
    /// Serializes the first force so concurrent parfor readers perform
    /// exactly one driver collect.
    force_lock: Mutex<()>,
}

impl HandleInner {
    fn is_resident(&self) -> bool {
        self.blocks.lock().unwrap().is_some()
    }

    /// Bytes this value charges against the storage budget: one copy for
    /// a distributed value, one copy *per worker* for a replicated one.
    fn charged_bytes(&self) -> usize {
        if self.replicated {
            self.bytes.saturating_mul(self.cluster.num_workers)
        } else {
            self.bytes
        }
    }

    /// Spill to the driver: make sure the dense copy exists, then drop
    /// the blocked representation and release its storage charge.
    /// A replicated value materializes for free (the driver already
    /// receives it with the job — dropping the worker copies moves no
    /// data), so spilling resident optimizer state never charges a
    /// collect. Returns false if the value was already spilled (racing
    /// callers).
    fn spill(&self, cluster: &Cluster) -> bool {
        if self.forced.get().is_none() {
            let _g = self.force_lock.lock().unwrap();
            if self.forced.get().is_none() {
                let resident = self.blocks.lock().unwrap().clone();
                let Some(b) = resident else { return false };
                let collected =
                    if self.replicated { b.to_local() } else { cluster.collect(&b) };
                match collected {
                    Ok(m) => {
                        let _ = self.forced.set(m);
                    }
                    Err(_) => return false,
                }
            }
        }
        let taken = self.blocks.lock().unwrap().take();
        match taken {
            Some(_) => {
                cluster.cache.unreserve(self.charged_bytes());
                cluster.spills.fetch_add(1, Ordering::Relaxed);
                metrics::global().dist_spills.fetch_add(1, Ordering::Relaxed);
                if let Some(s) = &cluster.stats {
                    s.event("spill", self.charged_bytes() as u64);
                }
                true
            }
            None => false,
        }
    }
}

impl Drop for HandleInner {
    fn drop(&mut self) {
        // Last reference gone: release the storage charge if the blocked
        // representation is still resident.
        if self.blocks.get_mut().map(|b| b.is_some()).unwrap_or(false) {
            let bytes = self.charged_bytes();
            self.cluster.cache.unreserve(bytes);
        }
        // ...and the memoized gather's charge, if one was taken.
        let gathered = self.gathered_charge.load(Ordering::Relaxed);
        if gathered > 0 {
            self.cluster.cache.unreserve(gathered);
        }
    }
}

/// A first-class blocked matrix value (`Value::Blocked`): a refcounted
/// handle into the distributed backend, carrying cached dims/nnz
/// metadata so shape queries never touch the driver. Cloning is an `Arc`
/// bump — scopes, function frames and parfor workers share one resident
/// value. Dropping the last handle releases the cluster-side storage.
#[derive(Clone)]
pub struct BlockedHandle {
    inner: Arc<HandleInner>,
}

impl std::fmt::Debug for BlockedHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "BlockedHandle({}x{}, nnz {}, {}, {}{})",
            self.inner.rows,
            self.inner.cols,
            self.inner.nnz,
            if self.is_resident() { "resident" } else { "spilled" },
            if self.is_forced() { "forced" } else { "lazy" },
            if self.inner.replicated { ", replicated" } else { "" }
        )
    }
}

impl BlockedHandle {
    /// Bind a DIST operator's blocked output as a live value. Registers
    /// the resident bytes against the cluster's storage budget (which may
    /// spill *older* live values to the driver — never this one).
    pub fn new(cluster: Arc<Cluster>, blocked: Arc<BlockedMatrix>) -> BlockedHandle {
        BlockedHandle::bind(cluster, blocked, false)
    }

    /// Bind an allreduce product (or derived model state) as a
    /// **replicated** live value: a single-block value resident on every
    /// worker. Forcing and gathering it are free — the value arrives with
    /// the job, never as a collect — and it charges
    /// `bytes × num_workers` to the storage budget.
    pub fn replicated(cluster: Arc<Cluster>, blocked: Arc<BlockedMatrix>) -> BlockedHandle {
        debug_assert!(
            blocked.block_rows() * blocked.block_cols() <= 1,
            "replicated values are single-block by construction"
        );
        BlockedHandle::bind(cluster, blocked, true)
    }

    fn bind(
        cluster: Arc<Cluster>,
        blocked: Arc<BlockedMatrix>,
        replicated: bool,
    ) -> BlockedHandle {
        let (rows, cols) = blocked.shape();
        let inner = Arc::new(HandleInner {
            rows,
            cols,
            nnz: blocked.nnz(),
            bytes: blocked.size_in_bytes(),
            block_size: blocked.block_size(),
            replicated,
            seq: cluster.live_seq.fetch_add(1, Ordering::Relaxed),
            blocks: Mutex::new(Some(blocked)),
            forced: OnceLock::new(),
            gathered: OnceLock::new(),
            gathered_charge: AtomicUsize::new(0),
            force_lock: Mutex::new(()),
            cluster: cluster.clone(),
        });
        cluster.register_live(&inner);
        BlockedHandle { inner }
    }

    /// Is this value replicated on every worker (allreduce product /
    /// resident model state)?
    pub fn is_replicated(&self) -> bool {
        self.inner.replicated
    }

    pub fn rows(&self) -> usize {
        self.inner.rows
    }

    pub fn cols(&self) -> usize {
        self.inner.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.inner.rows, self.inner.cols)
    }

    pub fn nnz(&self) -> usize {
        self.inner.nnz
    }

    /// Resident size of the blocked representation in bytes.
    pub fn size_in_bytes(&self) -> usize {
        self.inner.bytes
    }

    pub fn block_size(&self) -> usize {
        self.inner.block_size
    }

    /// The cluster this value lives on.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.inner.cluster
    }

    /// Is the blocked representation still resident (not spilled)?
    pub fn is_resident(&self) -> bool {
        self.inner.is_resident()
    }

    /// Has the driver copy been materialized?
    pub fn is_forced(&self) -> bool {
        self.inner.forced.get().is_some()
    }

    /// The blocked representation, for DIST consumers. Resident handles
    /// return their shared blocks; a spilled handle re-blockifies from
    /// the (guaranteed-present) driver copy and becomes resident again.
    /// A spilled *replicated* value instead re-enters as a broadcast
    /// (charged as such — it must reach every worker again) without
    /// bumping the blockify counters.
    pub fn blocked(&self) -> Result<Arc<BlockedMatrix>> {
        if let Some(b) = self.inner.blocks.lock().unwrap().clone() {
            return Ok(b);
        }
        // Spilled: rebuild from the forced driver copy.
        let m = self.inner.forced.get().ok_or_else(|| {
            DmlError::rt("blocked value lost both its blocks and its driver copy")
        })?;
        let b = if self.inner.replicated {
            let b = BlockedMatrix::from_local(m, self.inner.block_size)?;
            self.inner.cluster.record_broadcast(self.inner.bytes as u64);
            Arc::new(b)
        } else {
            Arc::new(self.inner.cluster.blockify(m)?)
        };
        // Reserve *before* publishing the blocks: a concurrent spill can
        // only unreserve after it observes the slot populated, so the
        // accounting can never transiently go negative.
        self.inner.cluster.cache.reserve(self.inner.charged_bytes());
        let mut slot = self.inner.blocks.lock().unwrap();
        if let Some(existing) = slot.clone() {
            drop(slot);
            self.inner.cluster.cache.unreserve(self.inner.charged_bytes());
            return Ok(existing); // raced with another rebuild
        }
        *slot = Some(b.clone());
        drop(slot);
        self.inner.cluster.enforce_storage(self.inner.seq);
        Ok(b)
    }

    /// Force the driver materialization (the lazy collect), memoized:
    /// the first CP consumer pays one `Cluster::collect`, every later
    /// consumer reads the cached dense copy. A **replicated** value
    /// forces for free — it arrived at the driver with the job, like
    /// SINGLE_BLOCK aggregation, so no collect is charged.
    pub fn force(&self) -> Result<&Matrix> {
        if let Some(m) = self.inner.forced.get() {
            return Ok(m);
        }
        let _g = self.inner.force_lock.lock().unwrap();
        if self.inner.forced.get().is_none() {
            let resident = self.inner.blocks.lock().unwrap().clone();
            let b = resident.ok_or_else(|| {
                DmlError::rt("blocked value lost both its blocks and its driver copy")
            })?;
            let m = if self.inner.replicated {
                b.to_local()?
            } else {
                self.inner.cluster.collect(&b)?
            };
            let _ = self.inner.forced.set(m);
        }
        Ok(self.inner.forced.get().unwrap())
    }

    /// Spill this value's blocked representation to the driver (test and
    /// storage-pressure hook). Returns true if a spill happened.
    pub fn spill(&self) -> bool {
        self.inner.spill(&self.inner.cluster)
    }

    /// Driver-format copy of this value for *rhs* use on the workers
    /// (broadcast-join vector, left-index patch, conv filter): gathered
    /// worker-side — charged as **one shuffle** of the value's bytes, not
    /// a collect — and memoized on the handle, so a loop-invariant
    /// blocked rhs is gathered once per loop rather than once per op
    /// (the ROADMAP `gather_blocked_rhs` refinement). A handle whose
    /// driver copy already exists (forced) serves that copy without any
    /// communication charge.
    ///
    /// The memoized copy pins driver memory for as long as the handle
    /// lives, so it is charged to the cluster's **storage budget** like
    /// any resident representation (released when the handle drops) —
    /// many small memoized gathers (the serving scatter case) surface as
    /// storage pressure instead of silently pinning unbounded memory.
    /// The memoize-vs-transient decision itself lives with the caller
    /// (`SystemConfig::gather_memo_bytes`).
    pub fn gathered(&self) -> Result<&Matrix> {
        if let Some(m) = self.inner.gathered.get() {
            return Ok(m);
        }
        let mut charged = 0usize;
        {
            let _g = self.inner.force_lock.lock().unwrap();
            if self.inner.gathered.get().is_none() {
                let m = match self.inner.forced.get() {
                    // The lazy collect already materialized a driver copy:
                    // reuse it, nothing moves.
                    Some(m) => m.clone(),
                    None => {
                        let resident = self.inner.blocks.lock().unwrap().clone();
                        let b = resident.ok_or_else(|| {
                            DmlError::rt("blocked value lost both its blocks and its driver copy")
                        })?;
                        // A replicated value already lives on every worker —
                        // a worker-side gather of it moves nothing.
                        if !self.inner.replicated {
                            self.inner.cluster.record_shuffle(self.inner.bytes as u64);
                        }
                        b.to_local()?
                    }
                };
                charged = m.size_in_bytes();
                self.inner.cluster.cache.reserve(charged);
                self.inner.gathered_charge.store(charged, Ordering::Relaxed);
                let _ = self.inner.gathered.set(m);
            }
        }
        // Relieve any pressure the new charge created — outside the
        // force lock, since spilling a victim takes *its* force lock.
        if charged > 0 {
            self.inner.cluster.enforce_storage(self.inner.seq);
        }
        Ok(self.inner.gathered.get().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};

    #[test]
    fn blockify_grid_shapes() {
        let m = rand(70, 33, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
        let b = BlockedMatrix::from_local(&m, 32).unwrap();
        assert_eq!(b.block_rows(), 3);
        assert_eq!(b.block_cols(), 2);
        assert_eq!(b.block(0, 0).shape(), (32, 32));
        assert_eq!(b.block(2, 1).shape(), (6, 1));
        assert_eq!(b.to_local().unwrap(), m);
    }

    #[test]
    fn blockify_preserves_nnz() {
        let m = rand(50, 50, -1.0, 1.0, 0.1, Pdf::Uniform, 2).unwrap();
        let b = BlockedMatrix::from_local(&m, 16).unwrap();
        assert_eq!(b.nnz(), m.nnz());
    }

    #[test]
    fn blockify_mixes_block_formats_per_nnz() {
        // Left half dense, right half nearly empty: the per-block nnz
        // inspection stores them in different formats within one grid.
        let mut d = crate::runtime::matrix::dense::DenseMatrix::zeros(64, 128);
        for r in 0..64 {
            for c in 0..64 {
                d.set(r, c, 1.0 + (r * 64 + c) as f64);
            }
        }
        d.set(0, 100, 5.0);
        let m = Matrix::Dense(d);
        let b = BlockedMatrix::from_local(&m, 64).unwrap();
        assert!(!b.block(0, 0).is_sparse(), "fully dense block stays dense");
        assert!(b.block(0, 1).is_sparse(), "1-nnz block goes CSR");
        assert_eq!(b.nnz(), m.nnz());
        assert_eq!(b.to_local().unwrap(), m);
        // Encoded size accounting reflects the mixed representation.
        assert!(b.size_in_bytes() < m.len() * 8 + 96);
    }

    #[test]
    fn sparsity_threshold_knob_controls_block_format() {
        let m = rand(64, 64, -1.0, 1.0, 0.05, Pdf::Uniform, 9).unwrap();
        // Turn point 0.0: nothing qualifies as sparse, even at 5% density.
        let dense_only = Cluster::new(2, 64).with_sparsity_threshold(0.0);
        let bd = dense_only.blockify(&m).unwrap();
        assert!(!bd.block(0, 0).is_sparse());
        // Default turn point (0.4): a 5%-dense block is CSR.
        let default = Cluster::new(2, 64);
        assert_eq!(default.sparsity_threshold(), crate::runtime::matrix::SPARSITY_TURN_POINT);
        let bs = default.blockify(&m).unwrap();
        assert!(bs.block(0, 0).is_sparse());
        assert_eq!(bd.to_local().unwrap(), bs.to_local().unwrap());
    }

    #[test]
    fn cluster_accounting_resets() {
        let c = Cluster::new(3, 8);
        c.record_task(0, 100);
        c.record_task(1, 50);
        c.record_broadcast(10);
        assert_eq!(c.worker_flops(), vec![100, 50, 0]);
        assert_eq!(c.tasks(), 2);
        assert_eq!(c.comm_bytes(), 30);
        c.reset_accounting();
        assert_eq!(c.worker_flops(), vec![0, 0, 0]);
        assert_eq!(c.comm_bytes(), 0);
    }

    #[test]
    fn modeled_time_scales_with_makespan() {
        let c = Cluster::new(2, 8);
        c.record_task(0, 1_000_000);
        c.record_task(1, 2_000_000);
        let t = c.modeled_time_seconds(1e6, 0);
        assert!((t - 2.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn handle_forces_lazily_and_memoizes() {
        let cluster = Arc::new(Cluster::new(2, 16));
        let m = rand(40, 40, -1.0, 1.0, 1.0, Pdf::Uniform, 3).unwrap();
        let b = Arc::new(cluster.blockify(&m).unwrap());
        let h = BlockedHandle::new(cluster.clone(), b);
        assert_eq!(h.shape(), (40, 40));
        assert_eq!(h.nnz(), m.nnz());
        assert!(h.is_resident() && !h.is_forced());
        assert_eq!(cluster.collect_count(), 0);
        assert_eq!(*h.force().unwrap(), m);
        assert_eq!(*h.force().unwrap(), m);
        assert_eq!(cluster.collect_count(), 1, "force is memoized");
    }

    #[test]
    fn handle_spills_and_rebuilds_correctly() {
        let cluster = Arc::new(Cluster::new(2, 16));
        let m = rand(40, 40, -1.0, 1.0, 0.3, Pdf::Uniform, 4).unwrap();
        let b = Arc::new(cluster.blockify(&m).unwrap());
        let h = BlockedHandle::new(cluster.clone(), b);
        let charged = cluster.live_blocked_bytes();
        assert!(charged > 0, "live value must be charged to storage");
        assert!(h.spill(), "first spill succeeds");
        assert!(!h.spill(), "second spill is a no-op");
        assert!(!h.is_resident() && h.is_forced());
        assert_eq!(cluster.live_blocked_bytes(), charged - h.size_in_bytes());
        // DIST re-use after a spill rebuilds the blocks from the driver
        // copy and re-charges the budget.
        let rebuilt = h.blocked().unwrap();
        assert_eq!(rebuilt.to_local().unwrap(), m);
        assert!(h.is_resident());
        assert_eq!(cluster.live_blocked_bytes(), charged);
        // Dropping the last handle releases the charge.
        drop(h);
        assert_eq!(cluster.live_blocked_bytes(), 0);
    }

    #[test]
    fn gathered_rhs_is_memoized_and_never_a_collect() {
        let cluster = Arc::new(Cluster::new(2, 16));
        let m = rand(40, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 7).unwrap();
        let h = BlockedHandle::new(
            cluster.clone(),
            Arc::new(cluster.blockify(&m).unwrap()),
        );
        cluster.reset_accounting();
        assert_eq!(*h.gathered().unwrap(), m);
        let first = cluster.comm_bytes();
        assert!(first > 0, "first gather is charged as a shuffle");
        // Repeated gathers reuse the memoized copy: no new traffic, and
        // never a collect.
        assert_eq!(*h.gathered().unwrap(), m);
        assert_eq!(*h.gathered().unwrap(), m);
        assert_eq!(cluster.comm_bytes(), first, "gather must be memoized");
        assert_eq!(cluster.collect_count(), 0);
        assert!(!h.is_forced(), "a gather is not a force");
        // An already-forced handle gathers from the driver copy for free.
        let h2 = BlockedHandle::new(
            cluster.clone(),
            Arc::new(cluster.blockify(&m).unwrap()),
        );
        h2.force().unwrap();
        cluster.reset_accounting();
        assert_eq!(*h2.gathered().unwrap(), m);
        assert_eq!(cluster.comm_bytes(), 0, "forced handles gather for free");
    }

    #[test]
    fn allreduce_accounting_scales_log2_workers() {
        for (workers, rounds) in [(1usize, 0u64), (2, 1), (4, 2), (7, 3), (8, 3)] {
            let c = Cluster::new(workers, 16);
            c.record_allreduce(100);
            assert_eq!(c.allreduce_round_count(), rounds, "workers={workers}");
            assert_eq!(c.allreduce_byte_count(), rounds * 100, "workers={workers}");
            // Allreduce traffic is charged to shuffle accounting.
            assert_eq!(c.comm_bytes(), rounds * 100, "workers={workers}");
        }
    }

    #[test]
    fn replicated_handle_forces_and_gathers_free() {
        let cluster = Arc::new(Cluster::new(4, 64));
        let m = rand(8, 8, -1.0, 1.0, 1.0, Pdf::Uniform, 11).unwrap();
        let b = Arc::new(BlockedMatrix::from_local(&m, 64).unwrap());
        let h = BlockedHandle::replicated(cluster.clone(), b);
        assert!(h.is_replicated());
        // One copy per worker is charged to storage.
        assert_eq!(cluster.live_blocked_bytes(), h.size_in_bytes() * 4);
        cluster.reset_accounting();
        assert_eq!(*h.force().unwrap(), m);
        assert_eq!(*h.gathered().unwrap(), m);
        assert_eq!(cluster.collect_count(), 0, "replicated force is free");
        assert_eq!(cluster.comm_bytes(), 0, "replicated gather moves nothing");
    }

    #[test]
    fn replicated_spill_is_collect_free_and_rebuild_broadcasts() {
        let cluster = Arc::new(Cluster::new(4, 64));
        let m = rand(8, 8, -1.0, 1.0, 1.0, Pdf::Uniform, 12).unwrap();
        let b = Arc::new(BlockedMatrix::from_local(&m, 64).unwrap());
        let h = BlockedHandle::replicated(cluster.clone(), b);
        cluster.reset_accounting();
        assert!(h.spill());
        assert_eq!(cluster.spill_count(), 1);
        assert_eq!(cluster.collect_count(), 0, "spilling replicated state never collects");
        assert_eq!(cluster.live_blocked_bytes(), 0);
        // Re-entering the cluster is a broadcast of one copy to every
        // worker, with no blockify op counted.
        let blockifies = cluster.blockify_count();
        let rebuilt = h.blocked().unwrap();
        assert_eq!(rebuilt.to_local().unwrap(), m);
        assert_eq!(cluster.blockify_count(), blockifies);
        assert_eq!(
            cluster.comm_bytes(),
            h.size_in_bytes() as u64 * 4,
            "rebuild is charged as a broadcast"
        );
        assert_eq!(cluster.live_blocked_bytes(), h.size_in_bytes() * 4);
    }

    #[test]
    fn storage_pressure_spills_oldest_live_value() {
        let m = rand(32, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 5).unwrap();
        let bytes = BlockedMatrix::from_local(&m, 16).unwrap().size_in_bytes();
        // Budget fits one live value (plus slack), not two.
        let cluster = Arc::new(Cluster::with_storage(2, 16, bytes + bytes / 2));
        let h1 = BlockedHandle::new(
            cluster.clone(),
            Arc::new(cluster.blockify(&m).unwrap()),
        );
        let m2 = rand(32, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 6).unwrap();
        let h2 = BlockedHandle::new(
            cluster.clone(),
            Arc::new(cluster.blockify(&m2).unwrap()),
        );
        assert_eq!(cluster.spill_count(), 1, "oldest live value spills");
        assert!(!h1.is_resident() && h1.is_forced(), "{h1:?}");
        assert!(h2.is_resident(), "newest value is never spilled: {h2:?}");
        // The spilled value still reads back correctly.
        assert_eq!(*h1.force().unwrap(), m);
    }
}
