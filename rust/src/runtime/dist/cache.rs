//! Lineage-keyed block-partition cache (the paper's "RDDs kept resident
//! across statements", SystemML's lineage caching in miniature).
//!
//! Every DIST operator needs its operands in blocked form. Without a
//! cache, each operator re-blockifies from the driver copy — an O(cells)
//! repartition per op that dominates iterative algorithms whose big
//! operand (the feature matrix) never changes. The [`BlockCache`] owned
//! by [`Cluster`](super::Cluster) maps **lineage keys** — a variable name
//! plus the version stamped by the interpreter's lineage table at binding
//! time — to resident [`BlockedMatrix`] handles:
//!
//! * **Guard-checked reuse.** A hit is only served when the live driver
//!   value still matches the resident blocks (dims, nnz, and a content
//!   fingerprint), so a stale entry can never change a result — at worst
//!   it degrades to a miss. Small matrices fingerprint every nonzero
//!   (an O(cells) scan); above [`GUARD_SAMPLE_CUTOFF_CELLS`] the guard
//!   switches to exact nnz plus a strided sample of cell values, capping
//!   the per-adoption cost. It is what makes the globally versioned
//!   lineage table safe across function frames and parfor workers. Since
//!   first-class blocked values (`Value::Blocked`) bypass the cache
//!   entirely — the value *is* the handle — this scan is only paid when
//!   **adopting a driver-resident matrix** into blocked form, not on the
//!   hot blocked-to-blocked path.
//! * **Live-value reservations.** Live blocked values charge their
//!   resident bytes here ([`BlockCache::reserve`]); the eviction sweep
//!   counts them against the same budget, and the cluster spills the
//!   oldest live value to the driver when eviction alone cannot make
//!   room. *Replicated* values (PR 7: allreduce results — weights and
//!   optimizer state living on every worker) reserve bytes × cluster
//!   size; spilling one is collect-free (the driver copy travels with
//!   the allreduce), and its next DIST use re-enters as a broadcast
//!   rebuild — so a session-long training job survives storage
//!   pressure on its resident model state without ever collecting.
//! * **Memory-budgeted LRU.** Resident bytes are bounded by the
//!   per-worker storage budget × cluster size; least-recently-used
//!   unpinned entries are evicted to make room.
//! * **Write invalidation.** The interpreter calls [`BlockCache::invalidate`]
//!   whenever a variable is rebound or mutated; entries *derived from*
//!   that variable (e.g. the cached blocks of `t(X)`) are dropped too via
//!   their recorded dependencies.
//! * **Pinning.** Loop bodies pin the names they read so loop-carried
//!   blocked matrices survive eviction pressure for the whole loop —
//!   iterative algorithms blockify their invariant operand once.
//! * **Pending-result reuse.** A DIST operator's blocked output is kept
//!   as a dirty pending handle; a directly-nested consumer (or the
//!   assignment that names it) picks it up without a round trip through
//!   the driver. The driver copy is only materialized on CP demand by
//!   the dispatch layer (the lazy `to_dense` flush).
//!
//! # Lock granularity (thread-pool audit, PR 6)
//!
//! The cache is guarded by one `Mutex<Inner>`, and that is fine for the
//! parallel execution path: **pool tasks never touch this lock.** All
//! cache traffic — `acquire`, `get_keyed`/`put_keyed`, `adopt`,
//! reservations — happens at *dispatch* time on the driver thread(s),
//! before task closures are built over `Arc<Matrix>` block clones.
//! Hit/miss/eviction counters are atomics outside the mutex. The only
//! O(cells) work near the lock were the guard fingerprints: `acquire`
//! already computed its fingerprint before locking, and `adopt` now does
//! too, so concurrent parfor drivers serialize only on O(entries) map
//! operations.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::dist::{BlockedMatrix, Cluster};
use crate::runtime::matrix::Matrix;
use crate::util::error::Result;
use crate::util::metrics;

/// Runtime lineage reference of an operand: the cache key plus the base
/// variables the blocked value was derived from (for invalidation).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct LineageRef {
    /// Cache key name: a variable name (`X`) or a derived form (`t(X)`).
    pub name: String,
    /// Lineage version stamped when the base variable was last bound.
    pub version: u64,
    /// Base variable names this value depends on (invalidation targets).
    pub deps: Vec<String>,
}

impl LineageRef {
    /// Reference for a plain variable read.
    pub fn var(name: &str, version: u64) -> LineageRef {
        LineageRef { name: name.to_string(), version, deps: vec![name.to_string()] }
    }

    /// Reference for a derived value (e.g. `t(X)`): keyed under `name`,
    /// invalidated whenever any of `deps` is rebound.
    pub fn derived(name: String, version: u64, deps: Vec<String>) -> LineageRef {
        LineageRef { name, version, deps }
    }

    /// Render like `X#4` for EXPLAIN lines.
    pub fn render(&self) -> String {
        format!("{}#{}", self.name, self.version)
    }
}

/// Full-fingerprint cutoff: matrices above this many cells use a sampled
/// guard (dims + exact nnz + a strided sample of cell values) instead of
/// hashing every nonzero — capping the cost that every pending-result
/// adoption and guarded acquire pays. The sampling scheme is a pure
/// function of the dims, so the guard computed at offer time and the one
/// computed at adoption time always agree.
const GUARD_SAMPLE_CUTOFF_CELLS: usize = 1 << 16;
/// Strided cell samples in a sampled guard.
const GUARD_SAMPLES: usize = 1024;

/// Content guard of a resident entry: reuse is only legal while the live
/// driver value still matches what was blockified. Below the sampling
/// cutoff the fingerprint covers every non-zero cell (position and bit
/// pattern); above it the guard carries exact dims/nnz plus a strided
/// sample of cell values. Either way dense/sparse representations of the
/// same content agree — format changes never produce a false hit — and a
/// collision requires matching dims, nnz and (sampled) cell content.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Guard {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub fingerprint: u64,
}

impl Guard {
    /// Guard of a local (driver) matrix: one pass over the cells below
    /// the sampling cutoff, dims + nnz + a strided sample above it.
    pub fn of(m: &Matrix) -> Guard {
        let (rows, cols) = m.shape();
        let cells = rows.saturating_mul(cols);
        if cells > GUARD_SAMPLE_CUTOFF_CELLS {
            // Strided sample over row-major positions, zeros included:
            // the stride depends only on the dims, so dense and sparse
            // walks visit identical positions (`Matrix::get` is
            // representation-agnostic). nnz stays exact, so any change
            // in the nonzero count is caught even off the sample grid.
            let stride = (cells / GUARD_SAMPLES).max(1);
            let mut h = FNV_OFFSET;
            let mut idx = 0usize;
            while idx < cells {
                h = fnv_cell(h, idx as u64, m.get(idx / cols, idx % cols));
                idx += stride;
            }
            return Guard { rows, cols, nnz: m.nnz(), fingerprint: h };
        }
        let mut nnz = 0usize;
        let mut h = FNV_OFFSET;
        match m {
            Matrix::Dense(d) => {
                for (idx, v) in d.data.iter().enumerate() {
                    if *v != 0.0 {
                        nnz += 1;
                        h = fnv_cell(h, idx as u64, *v);
                    }
                }
            }
            Matrix::Sparse(s) => {
                for r in 0..rows {
                    let (cis, vs) = s.row(r);
                    for (ci, v) in cis.iter().zip(vs) {
                        if *v != 0.0 {
                            nnz += 1;
                            h = fnv_cell(h, (r * cols + *ci as usize) as u64, *v);
                        }
                    }
                }
            }
        }
        Guard { rows, cols, nnz, fingerprint: h }
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over a (row-major cell index, value bits) pair. Cells must be
/// visited in row-major order for dense and sparse walks to agree.
#[inline]
fn fnv_cell(mut h: u64, idx: u64, v: f64) -> u64 {
    for b in idx.to_le_bytes().into_iter().chain(v.to_bits().to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Outcome of one cache acquisition, surfaced through EXPLAIN.
#[derive(Clone, Debug)]
pub enum CacheOutcome {
    /// Resident blocks reused (lineage hit or pending-result adoption).
    Hit { key: String },
    /// Blockify was required; `evicted`/`evicted_bytes` report the LRU
    /// evictions performed to make room (0 when none).
    Miss { key: String, evicted: usize, evicted_bytes: usize },
}

impl CacheOutcome {
    pub fn is_hit(&self) -> bool {
        matches!(self, CacheOutcome::Hit { .. })
    }
}

/// One resident entry.
struct Entry {
    blocked: Arc<BlockedMatrix>,
    /// Content guard of the driver copy this entry was built from; None
    /// for handle-verified derived entries (e.g. the blocked transpose
    /// of a guard-verified base), which are only served through
    /// [`BlockCache::get_keyed`] and never through guarded `acquire`.
    guard: Option<Guard>,
    deps: Vec<String>,
    bytes: usize,
    last_used: u64,
    /// Produced by a DIST operator (the authoritative copy lives on the
    /// cluster); kept for statistics/EXPLAIN.
    dirty: bool,
}

/// The blocked output of the most recent DIST operator, not yet adopted
/// under a lineage key. Serves directly-nested consumers.
struct Pending {
    blocked: Arc<BlockedMatrix>,
    guard: Guard,
}

#[derive(Default)]
struct Inner {
    entries: HashMap<(String, u64), Entry>,
    /// Pin counts per base variable name (loop nesting).
    pins: HashMap<String, usize>,
    pending: Option<Pending>,
    clock: u64,
    total_bytes: usize,
}

/// Statistics snapshot of a [`BlockCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub invalidations: u64,
    pub resident_bytes: usize,
    pub resident_entries: usize,
}

/// Lineage-keyed cache of resident block partitions; owned by `Cluster`.
pub struct BlockCache {
    inner: Mutex<Inner>,
    /// Total storage budget in bytes (per-worker budget × workers).
    /// A budget of 0 disables caching entirely (every acquire misses and
    /// nothing is kept resident) — used for cache-off parity runs.
    budget: usize,
    /// Bytes reserved by live blocked values (`BlockedHandle`s): they
    /// share the storage budget with resident cache entries, so the
    /// eviction sweep makes room for them too.
    reserved: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    invalidations: AtomicU64,
}

impl std::fmt::Debug for BlockCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(f, "BlockCache(budget {} B, {s:?})", self.budget)
    }
}

impl BlockCache {
    pub fn new(budget: usize) -> BlockCache {
        BlockCache {
            inner: Mutex::new(Inner::default()),
            budget,
            reserved: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Charge `bytes` of a live blocked value against the budget.
    pub(crate) fn reserve(&self, bytes: usize) {
        self.reserved.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Release a previous [`BlockCache::reserve`].
    pub(crate) fn unreserve(&self, bytes: usize) {
        self.reserved.fetch_sub(bytes as u64, Ordering::Relaxed);
    }

    /// Bytes currently reserved by live blocked values.
    pub fn reserved_bytes(&self) -> usize {
        self.reserved.load(Ordering::Relaxed) as usize
    }

    /// Resident cache bytes plus live-value reservations (what the
    /// storage budget is compared against).
    pub fn resident_and_reserved_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.total_bytes.saturating_add(self.reserved_bytes())
    }

    /// Evict unpinned LRU entries until at least `need` bytes are freed
    /// (or nothing evictable remains); returns the bytes freed. Used by
    /// the cluster to make room for live blocked values before spilling.
    pub(crate) fn reclaim(&self, need: usize) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let floor = inner.total_bytes.saturating_sub(need);
        self.evict_lru_while(&mut inner, |i| i.total_bytes > floor).1
    }

    /// Shared pin-aware LRU eviction loop: pop the least-recently-used
    /// entry with no pinned dependency while `over` holds (or until
    /// nothing evictable remains). Returns (evictions, bytes freed) and
    /// bumps the eviction counters.
    fn evict_lru_while(
        &self,
        inner: &mut Inner,
        over: impl Fn(&Inner) -> bool,
    ) -> (usize, usize) {
        let mut count = 0usize;
        let mut freed = 0usize;
        while over(inner) {
            let victim = inner
                .entries
                .iter()
                .filter(|(_, e)| !e.deps.iter().any(|d| inner.pins.contains_key(d)))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = inner.entries.remove(&k).unwrap();
                    inner.total_bytes -= e.bytes;
                    count += 1;
                    freed += e.bytes;
                }
                None => break,
            }
        }
        if count > 0 {
            self.evictions.fetch_add(count as u64, Ordering::Relaxed);
            metrics::global().cache_evictions.fetch_add(count as u64, Ordering::Relaxed);
        }
        (count, freed)
    }

    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            resident_bytes: inner.total_bytes,
            resident_entries: inner.entries.len(),
        }
    }

    /// Resolve an operand to blocked form: guarded lineage lookup, then
    /// pending-result adoption, then blockify-and-insert (with LRU
    /// eviction under the budget). `m` is the live driver value.
    pub fn acquire(
        &self,
        cluster: &Cluster,
        hint: Option<&LineageRef>,
        m: &Matrix,
    ) -> Result<(Arc<BlockedMatrix>, CacheOutcome)> {
        if !self.enabled() {
            let b = Arc::new(cluster.blockify(m)?);
            self.misses.fetch_add(1, Ordering::Relaxed);
            metrics::global().cache_misses.fetch_add(1, Ordering::Relaxed);
            let key = hint.map(|h| h.render()).unwrap_or_else(|| "(anon)".into());
            return Ok((b, CacheOutcome::Miss { key, evicted: 0, evicted_bytes: 0 }));
        }
        let guard = Guard::of(m);
        // 1. Guarded lineage lookup.
        if let Some(h) = hint {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let clock = inner.clock;
            let key = (h.name.clone(), h.version);
            let fresh = inner.entries.get(&key).map(|e| e.guard == Some(guard));
            match fresh {
                Some(true) => {
                    let e = inner.entries.get_mut(&key).unwrap();
                    e.last_used = clock;
                    let blocked = e.blocked.clone();
                    drop(inner);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    metrics::global().cache_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((blocked, CacheOutcome::Hit { key: h.render() }));
                }
                Some(false) => {
                    // Stale: the live value diverged from the resident
                    // blocks (e.g. same name rebound in another frame).
                    let e = inner.entries.remove(&key).unwrap();
                    inner.total_bytes -= e.bytes;
                }
                None => {}
            }
        }
        // 2. Pending DIST output whose content matches this operand.
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.pending.as_ref().is_some_and(|p| p.guard == guard) {
                let p = inner.pending.take().unwrap();
                let blocked = p.blocked.clone();
                // Promote under the lineage key so later statements hit too.
                if let Some(h) = hint {
                    self.insert_locked(&mut inner, h, blocked.clone(), Some(p.guard), true);
                }
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                metrics::global().cache_hits.fetch_add(1, Ordering::Relaxed);
                let key =
                    hint.map(|h| h.render()).unwrap_or_else(|| "(dist-intermediate)".into());
                return Ok((blocked, CacheOutcome::Hit { key }));
            }
        }
        // 3. Miss: blockify outside the lock, then insert.
        let blocked = Arc::new(cluster.blockify(m)?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        metrics::global().cache_misses.fetch_add(1, Ordering::Relaxed);
        let (mut evicted, mut evicted_bytes) = (0, 0);
        let key = match hint {
            Some(h) => {
                let mut inner = self.inner.lock().unwrap();
                let (n, b) =
                    self.insert_locked(&mut inner, h, blocked.clone(), Some(guard), false);
                evicted = n;
                evicted_bytes = b;
                h.render()
            }
            None => "(anon)".to_string(),
        };
        Ok((blocked, CacheOutcome::Miss { key, evicted, evicted_bytes }))
    }

    /// Insert a resident entry, evicting LRU unpinned entries to respect
    /// the budget. Entries larger than the whole budget (after evicting
    /// everything evictable) are not kept. Returns (evictions, bytes).
    fn insert_locked(
        &self,
        inner: &mut Inner,
        h: &LineageRef,
        blocked: Arc<BlockedMatrix>,
        guard: Option<Guard>,
        dirty: bool,
    ) -> (usize, usize) {
        let bytes = blocked.size_in_bytes();
        // An entry that can never fit must not wipe the resident working
        // set on a doomed eviction sweep — serve it unkeyed instead.
        if bytes.saturating_add(self.reserved_bytes()) > self.budget {
            return (0, 0);
        }
        inner.clock += 1;
        let clock = inner.clock;
        let (evicted, evicted_bytes) = self.evict_to_fit(inner, bytes);
        if inner
            .total_bytes
            .saturating_add(self.reserved_bytes())
            .saturating_add(bytes)
            > self.budget
        {
            return (evicted, evicted_bytes); // does not fit; serve unkeyed
        }
        inner.total_bytes += bytes;
        let displaced = inner.entries.insert(
            (h.name.clone(), h.version),
            Entry {
                blocked,
                guard,
                deps: h.deps.clone(),
                bytes,
                last_used: clock,
                dirty,
            },
        );
        if let Some(old) = displaced {
            // Concurrent acquires of the same key (parfor workers share
            // the cluster) can both miss and insert; the replaced entry's
            // bytes must leave the accounting.
            inner.total_bytes -= old.bytes;
        }
        (evicted, evicted_bytes)
    }

    /// Evict least-recently-used unpinned entries until `need` more bytes
    /// fit in the budget (or nothing evictable remains).
    fn evict_to_fit(&self, inner: &mut Inner, need: usize) -> (usize, usize) {
        self.evict_lru_while(inner, |i| {
            i.total_bytes
                .saturating_add(self.reserved_bytes())
                .saturating_add(need)
                > self.budget
        })
    }

    /// Is an entry resident under this exact lineage key? Diagnostic /
    /// test hook — touches neither the LRU clock nor the hit counters.
    pub fn resident_keyed(&self, h: &LineageRef) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&(h.name.clone(), h.version))
    }

    /// Resident entry under an exact lineage key, *without* a driver
    /// guard check. Only sound when the caller has just guard-verified
    /// the base value at the same version (e.g. the blocked transpose
    /// `t(X)#v` or the blocked slice `X[1:64,1:32]#v` after a guarded
    /// hit on `X#v` — any rebind or left-index write of `X` would have
    /// both bumped the version and invalidated the derived entry).
    pub fn get_keyed(&self, h: &LineageRef) -> Option<Arc<BlockedMatrix>> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        let e = inner.entries.get_mut(&(h.name.clone(), h.version))?;
        e.last_used = clock;
        let blocked = e.blocked.clone();
        drop(inner);
        self.hits.fetch_add(1, Ordering::Relaxed);
        metrics::global().cache_hits.fetch_add(1, Ordering::Relaxed);
        Some(blocked)
    }

    /// Keep a derived blocked result (e.g. a distributed transpose)
    /// resident under its lineage key. The entry carries no driver
    /// guard — it is only served through [`BlockCache::get_keyed`];
    /// guarded `acquire` treats it as stale and replaces it.
    pub fn put_keyed(&self, h: &LineageRef, blocked: Arc<BlockedMatrix>) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        self.insert_locked(&mut inner, h, blocked, None, true);
    }

    /// Keep a DIST operator's blocked output as the pending result so a
    /// directly-nested consumer (or the adopting assignment) reuses it
    /// without re-blockifying the collected driver copy.
    pub fn offer_result(&self, blocked: Arc<BlockedMatrix>, guard: Guard) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.pending = Some(Pending { blocked, guard });
    }

    /// Adopt the pending DIST output under `name#version` if it matches
    /// the value being bound — the interpreter calls this on assignment,
    /// making the statement's distributed result resident under its
    /// variable's lineage key (the flush to the driver already happened
    /// lazily on CP demand).
    pub fn adopt(&self, name: &str, version: u64, m: &Matrix) {
        if !self.enabled() {
            return;
        }
        // Cheap pre-filter before the O(cells) content fingerprint: most
        // assignments bind CP results while no DIST output is pending.
        {
            let inner = self.inner.lock().unwrap();
            let dims_match = match inner.pending.as_ref() {
                Some(p) => p.guard.rows == m.rows() && p.guard.cols == m.cols(),
                None => return,
            };
            if !dims_match {
                return;
            }
        }
        // The O(cells) fingerprint runs *outside* the mutex so concurrent
        // parfor drivers adopting results don't serialize on it; the
        // pending slot is re-checked under the lock below (it may have
        // been claimed or replaced while we scanned).
        let guard = Guard::of(m);
        let mut inner = self.inner.lock().unwrap();
        if inner.pending.as_ref().is_some_and(|p| p.guard == guard) {
            let p = inner.pending.take().unwrap();
            let h = LineageRef::var(name, version);
            self.insert_locked(&mut inner, &h, p.blocked, Some(p.guard), true);
        }
    }

    /// Drop every entry keyed by or derived from `name` (called when the
    /// interpreter rebinds or mutates the variable).
    pub fn invalidate(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap();
        let stale: Vec<(String, u64)> = inner
            .entries
            .iter()
            .filter(|((n, _), e)| n == name || e.deps.iter().any(|d| d == name))
            .map(|(k, _)| k.clone())
            .collect();
        if stale.is_empty() {
            return;
        }
        self.invalidations.fetch_add(stale.len() as u64, Ordering::Relaxed);
        for k in stale {
            let e = inner.entries.remove(&k).unwrap();
            inner.total_bytes -= e.bytes;
        }
    }

    /// Pin base variable names for the duration of a loop: entries that
    /// depend on a pinned name are never evicted. Pins nest.
    pub fn pin(&self, names: &[String]) {
        let mut inner = self.inner.lock().unwrap();
        for n in names {
            *inner.pins.entry(n.clone()).or_insert(0) += 1;
        }
    }

    /// Release a previous [`BlockCache::pin`].
    pub fn unpin(&self, names: &[String]) {
        let mut inner = self.inner.lock().unwrap();
        for n in names {
            if let Some(c) = inner.pins.get_mut(n) {
                *c -= 1;
                if *c == 0 {
                    inner.pins.remove(n);
                }
            }
        }
    }

    /// Number of dirty resident entries (blocked outputs of DIST ops).
    pub fn dirty_entries(&self) -> usize {
        self.inner.lock().unwrap().entries.values().filter(|e| e.dirty).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::matrix::randgen::{rand, Pdf};

    fn cluster_with(budget: usize) -> Cluster {
        Cluster::with_storage(2, 16, budget)
    }

    #[test]
    fn guard_agrees_across_formats() {
        let m = rand(40, 40, -1.0, 1.0, 0.2, Pdf::Uniform, 7).unwrap();
        let dense = Matrix::Dense(m.to_dense());
        let sparse = m.clone().into_sparse_format();
        assert_eq!(Guard::of(&dense), Guard::of(&sparse));
    }

    #[test]
    fn guard_distinguishes_content() {
        let a = rand(10, 10, -1.0, 1.0, 1.0, Pdf::Uniform, 8).unwrap();
        let b = rand(10, 10, -1.0, 1.0, 1.0, Pdf::Uniform, 9).unwrap();
        assert_ne!(Guard::of(&a).fingerprint, Guard::of(&b).fingerprint);
    }

    #[test]
    fn sampled_guard_formats_agree_and_detect_drift() {
        // 90_000 cells — above the sampling cutoff, so this exercises the
        // strided-sample path end to end.
        let m = rand(300, 300, -1.0, 1.0, 0.05, Pdf::Uniform, 21).unwrap();
        let dense = Matrix::Dense(m.to_dense());
        let sparse = m.clone().into_sparse_format();
        assert_eq!(Guard::of(&dense), Guard::of(&sparse));
        // Deterministic: recomputing yields the identical guard.
        assert_eq!(Guard::of(&dense), Guard::of(&dense));
        // nnz stays exact in the sampled guard: zeroing one cell is
        // caught even when it falls off the sample grid.
        let mut d = m.to_dense();
        let idx = d.data.iter().position(|v| *v != 0.0).unwrap();
        d.data[idx] = 0.0;
        assert_ne!(Guard::of(&Matrix::Dense(d)), Guard::of(&dense));
    }

    #[test]
    fn sampled_guard_serves_cache_hits() {
        let cl = cluster_with(usize::MAX);
        let m = rand(300, 300, -1.0, 1.0, 0.05, Pdf::Uniform, 22).unwrap();
        let h = LineageRef::var("X", 1);
        let (_, o1) = cl.cache().acquire(&cl, Some(&h), &m).unwrap();
        assert!(!o1.is_hit());
        let (_, o2) = cl.cache().acquire(&cl, Some(&h), &m).unwrap();
        assert!(o2.is_hit());
        // A different matrix of the same shape must still guard-miss.
        let m2 = rand(300, 300, -1.0, 1.0, 0.05, Pdf::Uniform, 23).unwrap();
        let (_, o3) = cl.cache().acquire(&cl, Some(&h), &m2).unwrap();
        assert!(!o3.is_hit());
    }

    #[test]
    fn hit_after_miss_and_stale_guard_misses() {
        let cl = cluster_with(usize::MAX);
        let m = rand(30, 30, -1.0, 1.0, 1.0, Pdf::Uniform, 10).unwrap();
        let h = LineageRef::var("X", 1);
        let (_, o1) = cl.cache().acquire(&cl, Some(&h), &m).unwrap();
        assert!(!o1.is_hit());
        let (_, o2) = cl.cache().acquire(&cl, Some(&h), &m).unwrap();
        assert!(o2.is_hit());
        // Same key, different live content -> guarded miss, entry replaced.
        let m2 = rand(30, 30, -1.0, 1.0, 1.0, Pdf::Uniform, 11).unwrap();
        let (_, o3) = cl.cache().acquire(&cl, Some(&h), &m2).unwrap();
        assert!(!o3.is_hit());
        assert_eq!(cl.blockify_count(), 2);
    }

    #[test]
    fn invalidate_drops_derived_entries() {
        let cl = cluster_with(usize::MAX);
        let m = rand(20, 20, -1.0, 1.0, 1.0, Pdf::Uniform, 12).unwrap();
        let hx = LineageRef::var("X", 1);
        let ht = LineageRef::derived("t(X)".into(), 1, vec!["X".into()]);
        cl.cache().acquire(&cl, Some(&hx), &m).unwrap();
        cl.cache().acquire(&cl, Some(&ht), &m).unwrap();
        assert_eq!(cl.cache().stats().resident_entries, 2);
        cl.cache().invalidate("X");
        assert_eq!(cl.cache().stats().resident_entries, 0);
        assert_eq!(cl.cache().stats().resident_bytes, 0);
    }

    #[test]
    fn lru_eviction_respects_budget_and_pins() {
        let m1 = rand(32, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 13).unwrap();
        let m2 = rand(32, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 14).unwrap();
        let one = m1.size_in_bytes() + m1.size_in_bytes() / 2; // fits one, not two
        let cl = cluster_with(one);
        let h1 = LineageRef::var("A", 1);
        let h2 = LineageRef::var("B", 1);
        cl.cache().acquire(&cl, Some(&h1), &m1).unwrap();
        cl.cache().acquire(&cl, Some(&h2), &m2).unwrap();
        let s = cl.cache().stats();
        assert_eq!(s.evictions, 1, "{s:?}");
        assert!(s.resident_bytes <= one, "{s:?}");
        // Re-acquire A: the earlier eviction means a miss.
        let (_, o) = cl.cache().acquire(&cl, Some(&h1), &m1).unwrap();
        assert!(!o.is_hit());
        // Pin B: now A cannot evict it, so A is served unkeyed.
        cl.cache().pin(&["B".to_string()]);
        cl.cache().acquire(&cl, Some(&h2), &m2).unwrap();
        cl.cache().acquire(&cl, Some(&h1), &m1).unwrap();
        let (_, ob) = cl.cache().acquire(&cl, Some(&h2), &m2).unwrap();
        assert!(ob.is_hit(), "pinned entry must survive pressure");
        cl.cache().unpin(&["B".to_string()]);
    }

    #[test]
    fn budget_zero_disables_caching() {
        let cl = cluster_with(0);
        let m = rand(16, 16, -1.0, 1.0, 1.0, Pdf::Uniform, 15).unwrap();
        let h = LineageRef::var("X", 1);
        for _ in 0..3 {
            let (_, o) = cl.cache().acquire(&cl, Some(&h), &m).unwrap();
            assert!(!o.is_hit());
        }
        assert_eq!(cl.blockify_count(), 3);
        assert_eq!(cl.cache().stats().resident_entries, 0);
    }
}
