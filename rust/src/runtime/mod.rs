//! The SystemML runtime: matrix engine, NN builtins, interpreter,
//! distributed blocked backend, parfor, the micro-batched scoring
//! service, and the PJRT accelerator backend.

pub mod accel;
pub mod conv;
pub mod dist;
pub mod interp;
pub mod matrix;
pub mod parfor;
pub mod serve;
