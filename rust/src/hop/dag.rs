//! High-level operator (HOP) DAGs.
//!
//! Each validated statement's expression tree is lowered into a typed
//! operator DAG, mirroring SystemML's HOP layer: nodes are operators
//! (reads, literals, cellwise ops, matmult, aggregates, reorgs, calls),
//! edges are data dependencies, and every node carries a worst-case
//! shape/sparsity estimate propagated from the bound inputs. Lowering
//! hash-conses structurally identical subtrees, so common subexpressions
//! become shared nodes (DAG-level CSE), and scalar-literal subtrees fold
//! to literal nodes. The DAG is the substrate the planner
//! (`hop::plan`) annotates with per-operator execution types and that
//! `EXPLAIN` renders, like SystemML's `explain(hops)`.

use std::collections::HashMap;

use crate::dml::ast::*;
use crate::runtime::matrix::agg::AggOp;
use crate::runtime::matrix::elementwise::BinOp;

/// Node identifier within one [`HopDag`].
pub type NodeId = usize;

/// Aggregation direction of an `Agg` HOP.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggDir {
    Full,
    Row,
    Col,
}

/// HOP operator kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum HopOp {
    /// Scalar literal.
    Lit(f64),
    /// String literal (flows into builtin arguments only).
    LitStr(String),
    /// Variable / bound-input read.
    Read(String),
    /// Cellwise or scalar binary operator.
    Binary(AstBinOp),
    /// Cellwise or scalar unary operator.
    Unary(AstUnOp),
    /// Matrix multiplication.
    MatMul,
    /// Transpose.
    Transpose,
    /// Unary aggregate (sum, rowSums, colMaxs, ...).
    Agg { op: AggOp, dir: AggDir },
    /// Right indexing.
    Index,
    /// Any other builtin or user-function call.
    Call(String),
    /// List literal (shape arguments of NN builtins).
    List,
}

impl HopOp {
    /// Short operator mnemonic for explain output (SystemML style).
    pub fn mnemonic(&self) -> String {
        match self {
            HopOp::Lit(v) => format!("lit {v}"),
            HopOp::LitStr(s) => format!("lit {s:?}"),
            HopOp::Read(n) => format!("read {n}"),
            HopOp::Binary(AstBinOp::MatMul) | HopOp::MatMul => "ba(%*%)".to_string(),
            HopOp::Binary(op) => format!("b({})", binop_symbol(*op)),
            HopOp::Unary(AstUnOp::Neg) => "u(-)".to_string(),
            HopOp::Unary(AstUnOp::Not) => "u(!)".to_string(),
            HopOp::Transpose => "r(t)".to_string(),
            HopOp::Agg { op, dir } => {
                let d = match dir {
                    AggDir::Full => "ua",
                    AggDir::Row => "uar",
                    AggDir::Col => "uac",
                };
                format!("{d}({})", agg_name(*op))
            }
            HopOp::Index => "rix".to_string(),
            HopOp::Call(name) => format!("fn({name})"),
            HopOp::List => "list".to_string(),
        }
    }
}

fn binop_symbol(op: AstBinOp) -> &'static str {
    match op {
        AstBinOp::Add => "+",
        AstBinOp::Sub => "-",
        AstBinOp::Mul => "*",
        AstBinOp::Div => "/",
        AstBinOp::Pow => "^",
        AstBinOp::Mod => "%%",
        AstBinOp::IntDiv => "%/%",
        AstBinOp::MatMul => "%*%",
        AstBinOp::Eq => "==",
        AstBinOp::Neq => "!=",
        AstBinOp::Lt => "<",
        AstBinOp::Le => "<=",
        AstBinOp::Gt => ">",
        AstBinOp::Ge => ">=",
        AstBinOp::And => "&",
        AstBinOp::Or => "|",
    }
}

/// Canonical short name of an aggregate op (shared by explain rendering
/// and the runtime dispatch's EXPLAIN lines).
pub fn agg_name(op: AggOp) -> &'static str {
    match op {
        AggOp::Sum => "sum",
        AggOp::Mean => "mean",
        AggOp::Min => "min",
        AggOp::Max => "max",
        AggOp::SumSq => "sumsq",
        AggOp::Prod => "prod",
    }
}

/// Map an AST binary operator to the runtime cell operator (None for
/// matmult, which is not a cell op).
pub fn ast_to_cell_op(op: AstBinOp) -> Option<BinOp> {
    Some(match op {
        AstBinOp::Add => BinOp::Add,
        AstBinOp::Sub => BinOp::Sub,
        AstBinOp::Mul => BinOp::Mul,
        AstBinOp::Div => BinOp::Div,
        AstBinOp::Pow => BinOp::Pow,
        AstBinOp::Mod => BinOp::Mod,
        AstBinOp::IntDiv => BinOp::IntDiv,
        AstBinOp::Eq => BinOp::Eq,
        AstBinOp::Neq => BinOp::Neq,
        AstBinOp::Lt => BinOp::Lt,
        AstBinOp::Le => BinOp::Le,
        AstBinOp::Gt => BinOp::Gt,
        AstBinOp::Ge => BinOp::Ge,
        AstBinOp::And => BinOp::And,
        AstBinOp::Or => BinOp::Or,
        AstBinOp::MatMul => return None,
    })
}

/// Compile-time shape/sparsity knowledge about a value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShapeInfo {
    /// Known row count (None = unknown at compile time).
    pub rows: Option<usize>,
    /// Known column count.
    pub cols: Option<usize>,
    /// Worst-case sparsity estimate (1.0 when unknown).
    pub sparsity: f64,
    /// True when the value is a scalar (not a 1×1 matrix).
    pub scalar: bool,
}

impl ShapeInfo {
    pub fn unknown() -> ShapeInfo {
        ShapeInfo { rows: None, cols: None, sparsity: 1.0, scalar: false }
    }

    pub fn scalar_value() -> ShapeInfo {
        ShapeInfo { rows: Some(1), cols: Some(1), sparsity: 1.0, scalar: true }
    }

    pub fn matrix(rows: usize, cols: usize, sparsity: f64) -> ShapeInfo {
        ShapeInfo { rows: Some(rows), cols: Some(cols), sparsity, scalar: false }
    }

    /// Both dimensions known (and the value is a matrix)?
    pub fn known_dims(&self) -> Option<(usize, usize)> {
        if self.scalar {
            return None;
        }
        match (self.rows, self.cols) {
            (Some(r), Some(c)) => Some((r, c)),
            _ => None,
        }
    }

    /// Worst-case in-memory size, when the dims are known.
    pub fn mem_estimate(&self) -> Option<usize> {
        let (r, c) = self.known_dims()?;
        Some(crate::hop::estimate::estimate_size(r, c, self.sparsity))
    }

    /// Render like `[96x96, sp 0.40]` / `[?x?]` / `[scalar]`.
    pub fn render(&self) -> String {
        if self.scalar {
            return "[scalar]".to_string();
        }
        let d = |v: Option<usize>| v.map(|x| x.to_string()).unwrap_or_else(|| "?".to_string());
        if self.sparsity < 1.0 {
            format!("[{}x{}, sp {:.2}]", d(self.rows), d(self.cols), self.sparsity)
        } else {
            format!("[{}x{}]", d(self.rows), d(self.cols))
        }
    }
}

/// One HOP node.
#[derive(Clone, Debug)]
pub struct Hop {
    pub id: NodeId,
    pub op: HopOp,
    pub inputs: Vec<NodeId>,
    pub shape: ShapeInfo,
    pub pos: Pos,
}

/// The operator DAG of one statement expression.
#[derive(Clone, Debug, Default)]
pub struct HopDag {
    pub nodes: Vec<Hop>,
    /// Root node (the statement's value).
    pub root: NodeId,
}

impl HopDag {
    pub fn shape_of(&self, id: NodeId) -> ShapeInfo {
        self.nodes[id].shape
    }

    /// Number of consumers per node (shared nodes = CSE hits).
    pub fn use_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for i in &n.inputs {
                counts[*i] += 1;
            }
        }
        counts
    }
}

/// DAG builder: lowers expressions with hash-consing and shape
/// propagation against a symbol table of known variable shapes.
pub struct DagBuilder<'a> {
    symbols: &'a HashMap<String, ShapeInfo>,
    nodes: Vec<Hop>,
    /// Structural key -> existing node (hash-consing / CSE).
    interned: HashMap<String, NodeId>,
}

impl<'a> DagBuilder<'a> {
    pub fn new(symbols: &'a HashMap<String, ShapeInfo>) -> DagBuilder<'a> {
        DagBuilder { symbols, nodes: Vec::new(), interned: HashMap::new() }
    }

    /// Lower an expression to a DAG.
    pub fn build(mut self, expr: &Expr) -> HopDag {
        let root = self.lower(expr);
        HopDag { nodes: self.nodes, root }
    }

    /// Infer just the shape of an expression (used by the chain rewriter).
    pub fn infer_shape(symbols: &HashMap<String, ShapeInfo>, expr: &Expr) -> ShapeInfo {
        let mut b = DagBuilder::new(symbols);
        let id = b.lower(expr);
        b.nodes[id].shape
    }

    fn intern(&mut self, op: HopOp, inputs: Vec<NodeId>, shape: ShapeInfo, pos: Pos) -> NodeId {
        self.intern_salted(op, inputs, shape, pos, "")
    }

    /// Hash-consing with an extra structural salt for operators whose
    /// semantics are not captured by (op, inputs) alone (e.g. indexing
    /// ranges).
    fn intern_salted(
        &mut self,
        op: HopOp,
        inputs: Vec<NodeId>,
        shape: ShapeInfo,
        pos: Pos,
        salt: &str,
    ) -> NodeId {
        let key = format!(
            "{}|{}|{salt}",
            op.mnemonic(),
            inputs.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
        );
        if let Some(id) = self.interned.get(&key) {
            return *id;
        }
        let id = self.nodes.len();
        self.nodes.push(Hop { id, op, inputs, shape, pos });
        self.interned.insert(key, id);
        id
    }

    fn lit(&mut self, v: f64, pos: Pos) -> NodeId {
        self.intern(HopOp::Lit(v), Vec::new(), ShapeInfo::scalar_value(), pos)
    }

    pub fn lower(&mut self, expr: &Expr) -> NodeId {
        match expr {
            Expr::Num(v, pos) => self.lit(*v, *pos),
            Expr::Int(v, pos) => self.lit(*v as f64, *pos),
            Expr::Bool(b, pos) => self.lit(*b as i32 as f64, *pos),
            Expr::Str(s, pos) => self.intern(
                HopOp::LitStr(s.clone()),
                Vec::new(),
                ShapeInfo::scalar_value(),
                *pos,
            ),
            Expr::Var(name, pos) => {
                let shape =
                    self.symbols.get(name).copied().unwrap_or_else(ShapeInfo::unknown);
                self.intern(HopOp::Read(name.clone()), Vec::new(), shape, *pos)
            }
            Expr::List(items, pos) => {
                let ids: Vec<NodeId> = items.iter().map(|e| self.lower(e)).collect();
                self.intern(HopOp::List, ids, ShapeInfo::unknown(), *pos)
            }
            Expr::Unary { op, operand, pos } => {
                let i = self.lower(operand);
                // Fold literal operands.
                if let HopOp::Lit(v) = &self.nodes[i].op {
                    let folded = match op {
                        AstUnOp::Neg => -*v,
                        AstUnOp::Not => (*v == 0.0) as i32 as f64,
                    };
                    return self.lit(folded, *pos);
                }
                let mut shape = self.nodes[i].shape;
                if *op == AstUnOp::Not {
                    shape.sparsity = 1.0; // !0 = 1 densifies
                }
                self.intern(HopOp::Unary(*op), vec![i], shape, *pos)
            }
            Expr::Binary { op, lhs, rhs, pos } => {
                let l = self.lower(lhs);
                let r = self.lower(rhs);
                // Fold scalar-literal arithmetic.
                if let (HopOp::Lit(a), HopOp::Lit(b)) =
                    (self.nodes[l].op.clone(), self.nodes[r].op.clone())
                {
                    if let Some(v) = fold_scalar(*op, a, b) {
                        return self.lit(v, *pos);
                    }
                }
                let shape = self.binary_shape(*op, l, r);
                self.intern(HopOp::Binary(*op), vec![l, r], shape, *pos)
            }
            Expr::Index { base, rows, cols, pos } => {
                let b = self.lower(base);
                let base_shape = self.nodes[b].shape;
                let rdim = self.index_extent(rows, base_shape.rows);
                let cdim = self.index_extent(cols, base_shape.cols);
                // Indexing keeps the base's sparsity estimate: a slice of
                // a sparse matrix is planned sparse (SystemML's rix
                // worst-case estimate), so placement costs shrink instead
                // of snapping back to dense.
                let shape = ShapeInfo {
                    rows: rdim,
                    cols: cdim,
                    sparsity: base_shape.sparsity,
                    scalar: false,
                };
                // Distinct index ranges must not hash-cons together: salt
                // the key with the printed ranges.
                let salt = format!("{}|{}", render_range(rows), render_range(cols));
                self.intern_salted(HopOp::Index, vec![b], shape, *pos, &salt)
            }
            Expr::Call { namespace, name, args, pos } => {
                let ids: Vec<NodeId> = args.iter().map(|a| self.lower(&a.value)).collect();
                if namespace.is_none() {
                    if let Some(node) = self.lower_builtin(name, args, &ids, *pos) {
                        return node;
                    }
                }
                let full = match namespace {
                    Some(ns) => format!("{ns}::{name}"),
                    None => name.clone(),
                };
                self.intern(HopOp::Call(full), ids, ShapeInfo::unknown(), *pos)
            }
        }
    }

    /// Extent of one indexing dimension, when statically known.
    fn index_extent(&mut self, r: &IndexRange, whole: Option<usize>) -> Option<usize> {
        match r {
            IndexRange::All => whole,
            IndexRange::Single(_) => Some(1),
            IndexRange::Range(a, b) => {
                let la = literal_int(&**a)?;
                let lb = literal_int(&**b)?;
                if lb >= la {
                    Some((lb - la + 1) as usize)
                } else {
                    None
                }
            }
        }
    }

    /// Shape of a binary op from its operand shapes.
    fn binary_shape(&self, op: AstBinOp, l: NodeId, r: NodeId) -> ShapeInfo {
        let (ls, rs) = (self.nodes[l].shape, self.nodes[r].shape);
        if op == AstBinOp::MatMul {
            let sparsity = match (ls.known_dims(), rs.known_dims()) {
                (Some((_, k)), Some(_)) => crate::hop::estimate::matmult_output_sparsity(
                    ls.sparsity,
                    rs.sparsity,
                    k,
                ),
                _ => 1.0,
            };
            return ShapeInfo { rows: ls.rows, cols: rs.cols, sparsity, scalar: false };
        }
        if ls.scalar && rs.scalar {
            return ShapeInfo::scalar_value();
        }
        // Cell op: the matrix operand (or the larger under broadcasting)
        // determines the output shape.
        let base = if ls.scalar { rs } else { ls };
        let sparsity = match ast_to_cell_op(op) {
            Some(BinOp::Mul) | Some(BinOp::And) => ls.sparsity.min(rs.sparsity),
            Some(BinOp::Add) | Some(BinOp::Sub) => (ls.sparsity + rs.sparsity).min(1.0),
            _ => 1.0,
        };
        ShapeInfo { rows: base.rows, cols: base.cols, sparsity, scalar: false }
    }

    /// Lower the builtins whose shapes the compiler understands; returns
    /// None to fall through to an opaque `Call` node.
    fn lower_builtin(
        &mut self,
        name: &str,
        args: &[Arg],
        ids: &[NodeId],
        pos: Pos,
    ) -> Option<NodeId> {
        let arg0 = ids.first().copied();
        let shape0 = arg0.map(|i| self.nodes[i].shape);
        match name {
            // Full aggregates → scalar.
            "sum" | "mean" | "prod" | "min" | "max" if ids.len() == 1 => {
                let op = match name {
                    "sum" => AggOp::Sum,
                    "mean" => AggOp::Mean,
                    "prod" => AggOp::Prod,
                    "min" => AggOp::Min,
                    _ => AggOp::Max,
                };
                Some(self.intern(
                    HopOp::Agg { op, dir: AggDir::Full },
                    ids.to_vec(),
                    ShapeInfo::scalar_value(),
                    pos,
                ))
            }
            // Row/col aggregates → vectors.
            "rowSums" | "rowMeans" | "rowMaxs" | "rowMins" | "colSums" | "colMeans"
            | "colMaxs" | "colMins" => {
                let op = match name {
                    "rowSums" | "colSums" => AggOp::Sum,
                    "rowMeans" | "colMeans" => AggOp::Mean,
                    "rowMaxs" | "colMaxs" => AggOp::Max,
                    _ => AggOp::Min,
                };
                let row_wise = name.starts_with("row");
                let dir = if row_wise { AggDir::Row } else { AggDir::Col };
                let s = shape0.unwrap_or_else(ShapeInfo::unknown);
                let shape = if row_wise {
                    ShapeInfo { rows: s.rows, cols: Some(1), sparsity: 1.0, scalar: false }
                } else {
                    ShapeInfo { rows: Some(1), cols: s.cols, sparsity: 1.0, scalar: false }
                };
                Some(self.intern(HopOp::Agg { op, dir }, ids.to_vec(), shape, pos))
            }
            "t" => {
                let s = shape0.unwrap_or_else(ShapeInfo::unknown);
                let shape =
                    ShapeInfo { rows: s.cols, cols: s.rows, sparsity: s.sparsity, scalar: false };
                Some(self.intern(HopOp::Transpose, ids.to_vec(), shape, pos))
            }
            // Scalar-producing builtins.
            "nrow" | "ncol" | "length" | "nnz" | "trace" | "var" | "sd" | "as.scalar"
            | "as.integer" | "as.double" | "as.logical" => Some(self.intern(
                HopOp::Call(name.to_string()),
                ids.to_vec(),
                ShapeInfo::scalar_value(),
                pos,
            )),
            // Shape-preserving cellwise builtins; sparse-safe ones keep
            // the input sparsity, the rest densify.
            "exp" | "log" | "sqrt" | "abs" | "round" | "floor" | "ceil" | "ceiling" | "sign"
            | "sin" | "cos" | "tan" | "sigmoid" => {
                let mut s = shape0.unwrap_or_else(ShapeInfo::unknown);
                if !matches!(
                    name,
                    "sqrt" | "abs" | "round" | "floor" | "ceil" | "ceiling" | "sign" | "sin"
                        | "tan"
                ) {
                    s.sparsity = 1.0;
                }
                Some(self.intern(HopOp::Call(name.to_string()), ids.to_vec(), s, pos))
            }
            // Channel-wise bias ops are shape-preserving (bias_add
            // densifies; keep 1.0 conservatively for both).
            "bias_add" | "bias_multiply" => {
                let mut s = shape0.unwrap_or_else(ShapeInfo::unknown);
                s.sparsity = 1.0;
                Some(self.intern(HopOp::Call(name.to_string()), ids.to_vec(), s, pos))
            }
            // NN builtins: output shapes follow from the literal shape
            // lists (the batch dimension comes from the batch operand, so
            // `input_shape=[bsize,...]` with a dynamic N still yields a
            // known column count). Non-literal geometry stays unknown.
            _ if crate::runtime::conv::conv_builtin(name).is_some() => {
                let op = crate::runtime::conv::conv_builtin(name).unwrap();
                let shape =
                    self.conv_call_shape(op, args, ids).unwrap_or_else(ShapeInfo::unknown);
                // Canonicalize the input order to [batch, companion?,
                // shape args...] so the planner's role-positional rules
                // (blocked-ness from batch operands only) hold for
                // named-argument call styles too.
                let ordered = conv_ordered_ids(op, args, ids);
                Some(self.intern(HopOp::Call(name.to_string()), ordered, shape, pos))
            }
            // Construction with statically-known shape arguments.
            "matrix" | "rand" => {
                let rows = named_or_positional(args, if name == "rand" { 0 } else { 1 }, "rows")
                    .and_then(literal_int);
                let cols = named_or_positional(args, if name == "rand" { 1 } else { 2 }, "cols")
                    .and_then(literal_int);
                let sparsity = if name == "rand" {
                    named_or_positional(args, 4, "sparsity")
                        .and_then(literal_num)
                        .unwrap_or(1.0)
                } else {
                    1.0
                };
                let shape = ShapeInfo {
                    rows: rows.map(|v| v as usize),
                    cols: cols.map(|v| v as usize),
                    sparsity: sparsity.clamp(0.0, 1.0),
                    scalar: false,
                };
                Some(self.intern(HopOp::Call(name.to_string()), ids.to_vec(), shape, pos))
            }
            _ => None,
        }
    }

    /// Static output shape of one conv/pool builtin call, when its
    /// geometry is literal. The batch dimension (rows) comes from the
    /// batch operand's inferred shape — `input` for most operators,
    /// `dout` for conv2d_backward_data, and the literal K for
    /// conv2d_backward_filter (whose output is the K×CRS gradient).
    /// All arithmetic is checked: adversarial literals yield None
    /// (unknown), never a panic.
    fn conv_call_shape(
        &self,
        op: crate::runtime::conv::ConvOpKind,
        args: &[Arg],
        ids: &[NodeId],
    ) -> Option<ShapeInfo> {
        use crate::runtime::conv::{ConvOpKind as K, ConvShape};
        let named =
            |nm: &str| args.iter().find(|a| a.name.as_deref() == Some(nm)).map(|a| &a.value);
        // C,H,W from input_shape's tail; its N entry may be dynamic.
        let ins = match named("input_shape")? {
            Expr::List(items, _) if items.len() == 4 => items,
            _ => return None,
        };
        let as_usize = |e: &Expr| literal_int(e).and_then(|v| usize::try_from(v).ok());
        let (c, h, w) = (as_usize(&ins[1])?, as_usize(&ins[2])?, as_usize(&ins[3])?);
        let (k, r, s) = if op.needs_filter() {
            let fs = match named("filter_shape")? {
                Expr::List(items, _) if items.len() == 4 => items,
                _ => return None,
            };
            (as_usize(&fs[0])?, as_usize(&fs[2])?, as_usize(&fs[3])?)
        } else {
            let ps = match named("pool_size")? {
                Expr::List(items, _) if !items.is_empty() => items,
                _ => return None,
            };
            let r = as_usize(&ps[0])?;
            let s = match ps.get(1) {
                Some(e) => as_usize(e)?,
                None => r,
            };
            (c, r, s)
        };
        // Absent stride/padding default like the runtime; present but
        // non-literal geometry bails to unknown (never a wrong shape).
        let pair = |nm: &str, dflt: usize| -> Option<(usize, usize)> {
            match named(nm) {
                None => Some((dflt, dflt)),
                Some(Expr::List(items, _)) if !items.is_empty() => {
                    let a = as_usize(&items[0])?;
                    let b = match items.get(1) {
                        Some(e) => as_usize(e)?,
                        None => a,
                    };
                    Some((a, b))
                }
                Some(_) => None,
            }
        };
        let stride = pair("stride", 1)?;
        let pad = pair("padding", 0)?;
        let sh = ConvShape { c, h, w, k, r, s, stride, pad };
        let (p, q) = sh.checked_pq()?;
        let batch_rows = |pos: usize, nm: &str| -> Option<usize> {
            self.nodes[*ids.get(conv_arg_index(args, pos, nm)?)?].shape.rows
        };
        let rows = match op {
            K::Conv2dBackwardFilter => Some(k),
            K::Conv2dBackwardData => batch_rows(1, "dout"),
            _ => batch_rows(0, "input"),
        };
        let cols = match op {
            K::Conv2d => k.checked_mul(p)?.checked_mul(q)?,
            K::Conv2dBackwardFilter => c.checked_mul(r)?.checked_mul(s)?,
            K::Conv2dBackwardData | K::MaxPoolBackward | K::AvgPoolBackward => {
                c.checked_mul(h)?.checked_mul(w)?
            }
            K::MaxPool | K::AvgPool => c.checked_mul(p)?.checked_mul(q)?,
        };
        Some(ShapeInfo { rows, cols: Some(cols), sparsity: 1.0, scalar: false })
    }
}

/// Evaluate a scalar binary op over literals (folding semantics match
/// `hop::rewrite::fold_constants`: division by zero stays a runtime op).
fn fold_scalar(op: AstBinOp, a: f64, b: f64) -> Option<f64> {
    Some(match op {
        AstBinOp::Add => a + b,
        AstBinOp::Sub => a - b,
        AstBinOp::Mul => a * b,
        AstBinOp::Div => {
            if b == 0.0 {
                return None;
            }
            a / b
        }
        AstBinOp::Pow => a.powf(b),
        _ => return None,
    })
}

/// Literal integer value of an expression, if it is one.
fn literal_int(e: &Expr) -> Option<i64> {
    match e {
        Expr::Int(v, _) => Some(*v),
        Expr::Num(v, _) if v.fract() == 0.0 => Some(*v as i64),
        _ => None,
    }
}

/// Literal numeric value of an expression, if it is one.
fn literal_num(e: &Expr) -> Option<f64> {
    match e {
        Expr::Int(v, _) => Some(*v as f64),
        Expr::Num(v, _) => Some(*v),
        _ => None,
    }
}

/// Index of a conv builtin's argument: by name, else the `pos`-th
/// unnamed argument (the interpreter's binding rule).
fn conv_arg_index(args: &[Arg], pos: usize, name: &str) -> Option<usize> {
    args.iter().position(|a| a.name.as_deref() == Some(name)).or_else(|| {
        args.iter().enumerate().filter(|(_, a)| a.name.is_none()).nth(pos).map(|(i, _)| i)
    })
}

/// Conv-call inputs in canonical role order: the batch operand first,
/// the companion (filter or dout) second, every remaining argument in
/// source order. The planner's blocked-ness rules index by role, so the
/// order must not depend on whether the call used named arguments.
fn conv_ordered_ids(
    op: crate::runtime::conv::ConvOpKind,
    args: &[Arg],
    ids: &[NodeId],
) -> Vec<NodeId> {
    use crate::runtime::conv::ConvOpKind as K;
    let (batch, aux) = match op {
        K::Conv2d => (conv_arg_index(args, 0, "input"), conv_arg_index(args, 1, "filter")),
        K::Conv2dBackwardFilter | K::MaxPoolBackward | K::AvgPoolBackward => {
            (conv_arg_index(args, 0, "input"), conv_arg_index(args, 1, "dout"))
        }
        K::Conv2dBackwardData => {
            (conv_arg_index(args, 1, "dout"), conv_arg_index(args, 0, "filter"))
        }
        K::MaxPool | K::AvgPool => (conv_arg_index(args, 0, "input"), None),
    };
    let aux = if aux == batch { None } else { aux };
    let mut ordered = Vec::with_capacity(ids.len());
    for i in batch.iter().chain(aux.iter()) {
        if let Some(id) = ids.get(*i) {
            ordered.push(*id);
        }
    }
    for (i, id) in ids.iter().enumerate() {
        if Some(i) != batch && Some(i) != aux {
            ordered.push(*id);
        }
    }
    ordered
}

/// Stable rendering of one index range (hash-consing salt).
fn render_range(r: &IndexRange) -> String {
    match r {
        IndexRange::All => String::new(),
        IndexRange::Single(e) => crate::hop::rewrite::print_expr(e),
        IndexRange::Range(a, b) => format!(
            "{}:{}",
            crate::hop::rewrite::print_expr(a),
            crate::hop::rewrite::print_expr(b)
        ),
    }
}

/// Resolve a call argument by name, else by unnamed position.
fn named_or_positional<'e>(args: &'e [Arg], pos: usize, name: &str) -> Option<&'e Expr> {
    for a in args {
        if a.name.as_deref() == Some(name) {
            return Some(&a.value);
        }
    }
    args.iter().filter(|a| a.name.is_none()).nth(pos).map(|a| &a.value)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;
    use crate::runtime::matrix::SPARSITY_TURN_POINT;

    fn lower_first(src: &str, symbols: &HashMap<String, ShapeInfo>) -> HopDag {
        let prog = parse(src).unwrap();
        match &prog.body[0] {
            Stmt::Assign { value, .. } => DagBuilder::new(symbols).build(value),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn matmult_shape_propagates() {
        let mut syms = HashMap::new();
        syms.insert("X".to_string(), ShapeInfo::matrix(100, 50, 1.0));
        syms.insert("W".to_string(), ShapeInfo::matrix(50, 10, 1.0));
        let dag = lower_first("Y = X %*% W", &syms);
        let root = dag.shape_of(dag.root);
        assert_eq!(root.known_dims(), Some((100, 10)));
        assert!(matches!(dag.nodes[dag.root].op, HopOp::Binary(AstBinOp::MatMul)));
    }

    #[test]
    fn cse_shares_subtrees() {
        let syms = HashMap::new();
        let dag = lower_first("y = exp(q) + exp(q)", &syms);
        // read q + exp(q) shared: nodes are read, exp, plus — not 5.
        let n_exp = dag
            .nodes
            .iter()
            .filter(|n| matches!(&n.op, HopOp::Call(c) if c == "exp"))
            .count();
        assert_eq!(n_exp, 1, "{:?}", dag.nodes);
        let uses = dag.use_counts();
        let exp_id =
            dag.nodes.iter().find(|n| matches!(&n.op, HopOp::Call(c) if c == "exp")).unwrap().id;
        assert_eq!(uses[exp_id], 2);
    }

    #[test]
    fn literals_fold_in_dag() {
        let syms = HashMap::new();
        let dag = lower_first("y = (1 + 2) * 4", &syms);
        assert!(matches!(dag.nodes[dag.root].op, HopOp::Lit(v) if v == 12.0));
    }

    #[test]
    fn agg_and_rand_shapes() {
        let mut syms = HashMap::new();
        syms.insert("X".to_string(), ShapeInfo::matrix(30, 7, 0.5));
        let dag = lower_first("s = sum(X)", &syms);
        assert!(dag.shape_of(dag.root).scalar);
        let dag2 = lower_first("R = rand(rows=20, cols=5, sparsity=0.1)", &syms);
        let s = dag2.shape_of(dag2.root);
        assert_eq!(s.known_dims(), Some((20, 5)));
        assert!((s.sparsity - 0.1).abs() < 1e-12);
        let dag3 = lower_first("v = rowSums(X)", &syms);
        assert_eq!(dag3.shape_of(dag3.root).known_dims(), Some((30, 1)));
    }

    #[test]
    fn transpose_swaps_dims() {
        let mut syms = HashMap::new();
        syms.insert("X".to_string(), ShapeInfo::matrix(9, 4, 0.2));
        let dag = lower_first("Y = t(X)", &syms);
        assert_eq!(dag.shape_of(dag.root).known_dims(), Some((4, 9)));
    }

    #[test]
    fn unknown_vars_stay_unknown() {
        let syms = HashMap::new();
        let dag = lower_first("Y = X %*% W", &syms);
        assert_eq!(dag.shape_of(dag.root).known_dims(), None);
        assert!(dag.shape_of(dag.root).mem_estimate().is_none());
    }

    #[test]
    fn index_carries_base_sparsity() {
        let mut syms = HashMap::new();
        syms.insert("X".to_string(), ShapeInfo::matrix(1000, 200, 0.01));
        let dag = lower_first("B = X[1:100,]", &syms);
        let s = dag.shape_of(dag.root);
        assert_eq!(s.known_dims(), Some((100, 200)));
        assert!((s.sparsity - 0.01).abs() < 1e-12, "{}", s.sparsity);
        // ceil is sparse-safe: ceil(0) = 0 keeps the input sparsity.
        let dag2 = lower_first("C = ceil(X)", &syms);
        assert!((dag2.shape_of(dag2.root).sparsity - 0.01).abs() < 1e-12);
    }

    #[test]
    fn sparsity_estimator_used_for_matmult() {
        let mut syms = HashMap::new();
        syms.insert("X".to_string(), ShapeInfo::matrix(400, 400, 0.01));
        let dag = lower_first("Y = X %*% X", &syms);
        let s = dag.shape_of(dag.root);
        // 1-(1-1e-4)^400 ≈ 0.039 — far below the dense turn point.
        assert!(s.sparsity < SPARSITY_TURN_POINT, "{}", s.sparsity);
    }
}
