//! Execution-type plan compilation (the tentpole of paper §3): lower each
//! statement to a HOP DAG, propagate worst-case shape/sparsity estimates
//! from the bound inputs, reorder matmult chains, and assign every heavy
//! operator an [`ExecType`] — CP when its estimate fits the driver
//! budget, DIST when it does not and the distributed backend is enabled,
//! ACCEL when the accelerator is enabled and the buffers fit device
//! memory.
//!
//! The compiled [`Plan`] is consulted by the interpreter's unified
//! dispatch (`runtime::interp::dispatch`) through per-operator
//! placements keyed by source position, and rendered by `EXPLAIN` like
//! SystemML's `explain(hops)` — including `ALLREDUCE` markers on
//! aggregation-shaped DIST outputs (gradient matmults, backward-filter
//! gradients, single-block axis aggregates) that are tree-allreduced and
//! stay replicated on the workers, which is what lets blocked-ness flow
//! through a whole optimizer update chain. Operators whose shapes are unknown at
//! compile time (loop-carried dims, user-function results) carry no
//! placement and are decided at runtime with the same cost model
//! ([`choose_exec`]) — SystemML's dynamic recompilation, in miniature.

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::conf::SystemConfig;
use crate::dml::ast::*;
use crate::dml::validate::Bundle;
use crate::hop::dag::{DagBuilder, HopDag, HopOp, NodeId, ShapeInfo};
use crate::hop::estimate;
use crate::hop::rewrite::matmult_chain_split;

/// Where an operator executes (paper §3's CP / SPARK / GPU).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecType {
    /// Single-node "control program" on the driver.
    CP,
    /// Distributed blocked backend (simulated cluster).
    Dist,
    /// Accelerator (PJRT artifacts).
    Accel,
}

impl fmt::Display for ExecType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecType::CP => write!(f, "CP"),
            ExecType::Dist => write!(f, "DIST"),
            ExecType::Accel => write!(f, "ACCEL"),
        }
    }
}

/// Heavy-operator categories the planner places.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    MatMult,
    CellBinary,
    Agg,
    /// Reorganization operators (today: transpose).
    Reorg,
    /// Right indexing (`X[r1:r2, c1:c2]`): block-range selection on DIST.
    RightIndex,
    /// Left-index write (`X[r1:r2, c1:c2] = ...`): touched-block rewrite
    /// on DIST — the target stays blocked.
    LeftIndex,
    /// NN operators (conv2d / pooling builtins): row-banded worker-side
    /// execution on DIST, filter shipped as a broadcast variable.
    Conv,
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpKind::MatMult => write!(f, "%*%"),
            OpKind::CellBinary => write!(f, "cellwise"),
            OpKind::Agg => write!(f, "agg"),
            OpKind::Reorg => write!(f, "reorg"),
            OpKind::RightIndex => write!(f, "rix"),
            OpKind::LeftIndex => write!(f, "lix"),
            OpKind::Conv => write!(f, "conv"),
        }
    }
}

/// One placement decision.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub exec: ExecType,
    /// Worst-case memory estimate the decision was made against.
    pub est: usize,
}

/// A heavy operator the planner placed, with its DAG node.
#[derive(Clone, Debug)]
pub struct PlannedOp {
    pub node: NodeId,
    pub kind: OpKind,
    pub pos: Pos,
    pub exec: Option<ExecType>,
    pub est: Option<usize>,
    /// Vector-broadcast cellwise pair (rendered as `BCAST` in EXPLAIN):
    /// the rhs is a row/col vector joined map-side on DIST placements.
    pub bcast: bool,
    /// Aggregation-shaped DIST output (rendered as `ALLREDUCE` in
    /// EXPLAIN): a gradient matmult with a multi-block inner dimension, a
    /// `conv2d_backward_filter` gradient, or a single-block axis
    /// aggregate — combined in log2(workers) tree-allreduce rounds and
    /// bound replicated on the workers.
    pub allreduce: bool,
    /// Sparse-sized estimate (rendered as `SPARSE` in EXPLAIN): the
    /// output or a matrix input is estimated below the sparsity turn
    /// point at CSR-eligible size, so the placement decision above was
    /// made against encoded (CSR) bytes rather than dense bytes.
    pub sparse: bool,
}

/// Plan of one statement: its DAG plus the heavy operators found in it.
#[derive(Clone, Debug)]
pub struct StmtPlan {
    pub pos: Pos,
    /// Assignment target (or a descriptive label for non-assignments).
    pub target: String,
    pub dag: HopDag,
    pub ops: Vec<PlannedOp>,
    /// Chain-reordering note, when the rewriter fired for this statement.
    pub note: Option<String>,
    /// Left-index write placement, when this statement is an indexed
    /// assignment with a known target shape (rendered as an `IDX` line).
    pub lix: Option<Placement>,
}

/// The compiled execution plan of a program's straight-line main body.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub stmts: Vec<StmtPlan>,
    /// (line, col, kind) -> placement, for the interpreter's dispatch.
    placements: HashMap<(usize, usize, OpKind), Placement>,
    /// Variables the planner marked `Cached`: DIST operands whose
    /// consumers span statements (or repeat across loop iterations), so
    /// their blocked partitions should stay resident. Sorted.
    pub cached_vars: Vec<String>,
    /// Statement positions at which each variable feeds a DIST operator.
    dist_read_sites: HashMap<String, HashSet<(usize, usize)>>,
    /// Variables feeding DIST operators inside loop bodies.
    dist_loop_reads: HashSet<String>,
    driver_memory: usize,
    num_workers: usize,
    block_size: usize,
    accel_enabled: bool,
}

impl Plan {
    /// Placement compiled for the operator at `pos`, if shapes were known.
    pub fn placement(&self, pos: Pos, kind: OpKind) -> Option<Placement> {
        self.placements.get(&(pos.line, pos.col, kind)).copied()
    }

    /// Did the planner mark this variable's blocked partitions `Cached`?
    pub fn is_cached(&self, name: &str) -> bool {
        self.cached_vars.iter().any(|n| n == name)
    }

    /// All (kind, exec) pairs that received a placement, in program order.
    pub fn placed_execs(&self, kind: OpKind) -> Vec<ExecType> {
        let mut out = Vec::new();
        for s in &self.stmts {
            for op in &s.ops {
                if op.kind == kind {
                    if let Some(e) = op.exec {
                        out.push(e);
                    }
                }
            }
        }
        out
    }

    /// Render the annotated HOP plan (SystemML's `explain(hops)`).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        writeln!(
            s,
            "# HOP PLAN (driver {} B | workers {} | block {} | accel {})",
            self.driver_memory,
            self.num_workers,
            self.block_size,
            if self.accel_enabled { "on" } else { "off" }
        )
        .unwrap();
        if !self.cached_vars.is_empty() {
            writeln!(
                s,
                "# CACHE plan: keep resident (cross-statement/loop DIST operands): {}",
                self.cached_vars.join(", ")
            )
            .unwrap();
        }
        for sp in &self.stmts {
            writeln!(s, "--HOPS line {}: {}", sp.pos.line, sp.target).unwrap();
            if let Some(note) = &sp.note {
                writeln!(s, "  ^ {note}").unwrap();
            }
            if let Some(p) = &sp.lix {
                writeln!(
                    s,
                    "  lix {} est {} B -> {} IDX (touched-block write)",
                    sp.target, p.est, p.exec
                )
                .unwrap();
            }
            let uses = sp.dag.use_counts();
            // ops indexed by node for annotation.
            let mut by_node: HashMap<NodeId, &PlannedOp> = HashMap::new();
            for op in &sp.ops {
                by_node.insert(op.node, op);
            }
            for n in &sp.dag.nodes {
                let ins = if n.inputs.is_empty() {
                    String::new()
                } else {
                    format!(
                        " ({})",
                        n.inputs.iter().map(|i| format!("h{i}")).collect::<Vec<_>>().join(",")
                    )
                };
                let mut line = format!("  h{} {}{} {}", n.id, n.op.mnemonic(), ins, n.shape.render());
                if let HopOp::Read(name) = &n.op {
                    if self.is_cached(name) {
                        line.push_str(" CACHE");
                    }
                }
                if let Some(op) = by_node.get(&n.id) {
                    match (op.exec, op.est) {
                        (Some(exec), Some(est)) => {
                            line.push_str(&format!(" est {est} B -> {exec}"));
                        }
                        _ => line.push_str(" est ? -> runtime"),
                    }
                    if op.kind == OpKind::RightIndex {
                        line.push_str(" IDX");
                    }
                    if op.kind == OpKind::Conv {
                        line.push_str(" CONV");
                    }
                    if op.bcast {
                        line.push_str(" BCAST");
                    }
                    if op.allreduce {
                        line.push_str(" ALLREDUCE");
                    }
                    if op.sparse {
                        line.push_str(" SPARSE");
                    }
                }
                if uses[n.id] > 1 {
                    line.push_str(&format!(" (shared x{})", uses[n.id]));
                }
                writeln!(s, "{line}").unwrap();
            }
        }
        s
    }
}

/// The single cost-model decision shared by the compile-time planner and
/// the runtime dispatch: where does an operator with worst-case memory
/// `est` run?
pub fn choose_exec(est: usize, config: &SystemConfig, accel_capable: bool) -> ExecType {
    if accel_capable && config.accel_enabled && est <= config.accel_memory {
        return ExecType::Accel;
    }
    if est > config.driver_memory && config.dist_enabled {
        return ExecType::Dist;
    }
    ExecType::CP
}

/// Shared planning context across the main body and (call-site
/// specialized) user-function bodies.
struct PlanCtx<'a> {
    config: &'a SystemConfig,
    /// Main-file user functions, plannable by call site. Namespaced
    /// (sourced) functions are excluded: their source positions can
    /// collide with the main file's, and placements are keyed by
    /// position.
    funcs: HashMap<String, FunctionDef>,
    /// (function, argument-shape signature) pairs already planned.
    planned_sigs: HashSet<String>,
    /// Call-stack guard (recursive functions are planned once per cycle).
    fn_stack: Vec<String>,
    /// Placement keys that received conflicting ExecTypes (e.g. the same
    /// function line planned from call sites with different shapes):
    /// dropped, so the runtime estimate decides.
    conflicted: HashSet<(usize, usize, OpKind)>,
    /// Variables whose current binding is modeled as a first-class
    /// blocked value (a multi-block DIST output). Reads of these carry
    /// zero blockify cost and force their consumers DIST, mirroring the
    /// runtime's blocked-operand rule.
    blocked_vars: HashSet<String>,
}

/// Compile the plan for a bundle's main body and, per call site, the
/// bodies of main-file user functions (with parameter shapes bound from
/// the call arguments). Rewrites matmult chains in place (the
/// interpreter executes the rewritten AST) and returns the annotated
/// plan. `inputs` seeds the symbol table with the shapes of bound
/// script inputs.
pub fn compile_plan(
    bundle: &mut Bundle,
    inputs: &HashMap<String, ShapeInfo>,
    config: &SystemConfig,
) -> Plan {
    let mut plan = Plan {
        stmts: Vec::new(),
        placements: HashMap::new(),
        cached_vars: Vec::new(),
        dist_read_sites: HashMap::new(),
        dist_loop_reads: HashSet::new(),
        driver_memory: config.driver_memory,
        num_workers: config.num_workers,
        block_size: config.block_size,
        accel_enabled: config.accel_enabled,
    };
    let mut ctx = PlanCtx {
        config,
        funcs: bundle
            .main
            .functions
            .iter()
            .map(|f| (f.name.clone(), f.clone()))
            .collect(),
        planned_sigs: HashSet::new(),
        fn_stack: Vec::new(),
        conflicted: HashSet::new(),
        blocked_vars: HashSet::new(),
    };
    let mut symbols = inputs.clone();
    let mut body = std::mem::take(&mut bundle.main.body);
    plan_block(&mut body, &mut symbols, &mut ctx, &mut plan, true, 0, None);
    bundle.main.body = body;
    // A DIST operand read at more than one statement — or repeatedly
    // inside a loop body — benefits from staying resident: mark it
    // `Cached` so EXPLAIN surfaces the planner's caching intent.
    let mut cached: Vec<String> = plan
        .dist_read_sites
        .iter()
        .filter(|(name, sites)| sites.len() > 1 || plan.dist_loop_reads.contains(*name))
        .map(|(name, _)| name.clone())
        .collect();
    cached.sort();
    plan.cached_vars = cached;
    plan
}

/// Plan a statement block, updating `symbols` as assignments execute.
/// When `record` is false this is a shape-propagation dry run (loop
/// fixpoint pass) and nothing is added to the plan. `fn_label` names the
/// user function this block belongs to (None for the main body).
#[allow(clippy::too_many_arguments)]
fn plan_block(
    stmts: &mut [Stmt],
    symbols: &mut HashMap<String, ShapeInfo>,
    ctx: &mut PlanCtx,
    plan: &mut Plan,
    record: bool,
    loop_depth: usize,
    fn_label: Option<&str>,
) {
    for stmt in stmts.iter_mut() {
        match stmt {
            Stmt::Assign { target, value, pos } => {
                let (expr, note) = reorder_matmult_chains(value, symbols);
                *value = expr;
                if record {
                    plan_user_calls(value, symbols, ctx, plan, loop_depth);
                }
                let dag = DagBuilder::new(symbols).build(value);
                let shape = dag.shape_of(dag.root);
                let mut lix: Option<Placement> = None;
                // Post-write residency of a left-index target, applied
                // only *after* the rhs DAG is planned (the rhs reads the
                // pre-write binding).
                let mut indexed_residency: Option<(String, bool)> = None;
                let (name, bound_var) = match target {
                    AssignTarget::Var(n) => {
                        symbols.insert(n.clone(), shape);
                        (n.clone(), Some(n.clone()))
                    }
                    AssignTarget::Indexed { name, .. } => {
                        // Left-index write: on DIST only the touched
                        // blocks are rewritten, so a blocked target
                        // **stays blocked** (it no longer forces to the
                        // driver). CP (or unknown-shape) writes are
                        // driver-resident.
                        let target_blocked = ctx.blocked_vars.contains(name);
                        let tgt = symbols.get(name).copied();
                        let est = tgt
                            .and_then(|s| s.mem_estimate())
                            .map(|m| m.saturating_mul(2));
                        let exec = if target_blocked && ctx.config.dist_enabled {
                            Some(ExecType::Dist)
                        } else {
                            est.map(|e| choose_exec(e, ctx.config, false))
                        };
                        lix = exec.map(|x| Placement { exec: x, est: est.unwrap_or(0) });
                        if record {
                            if let Some(p) = lix {
                                place_key(
                                    plan,
                                    ctx,
                                    (pos.line, pos.col, OpKind::LeftIndex),
                                    p.exec,
                                    p.est,
                                );
                            }
                        }
                        let stays_blocked = exec == Some(ExecType::Dist)
                            && tgt
                                .map(|s| multi_block(s, ctx.config.block_size.max(1)))
                                .unwrap_or(target_blocked);
                        indexed_residency = Some((name.clone(), stays_blocked));
                        (format!("{name}[...]"), None)
                    }
                };
                let root_blocked =
                    record_stmt(plan, ctx, *pos, name, dag, note, loop_depth, record, fn_label);
                if record {
                    if let Some(sp) = plan.stmts.last_mut() {
                        sp.lix = lix;
                    }
                }
                if let Some((n, stays)) = indexed_residency {
                    if stays {
                        ctx.blocked_vars.insert(n);
                    } else {
                        ctx.blocked_vars.remove(&n);
                    }
                }
                if let Some(n) = bound_var {
                    if root_blocked {
                        ctx.blocked_vars.insert(n);
                    } else {
                        ctx.blocked_vars.remove(&n);
                    }
                }
            }
            Stmt::MultiAssign { targets, value, pos } => {
                if record {
                    plan_user_calls(value, symbols, ctx, plan, loop_depth);
                }
                let dag = DagBuilder::new(symbols).build(value);
                for t in targets.iter() {
                    symbols.insert(t.clone(), ShapeInfo::unknown());
                    // Function results have unknown residency.
                    ctx.blocked_vars.remove(t);
                }
                record_stmt(
                    plan,
                    ctx,
                    *pos,
                    format!("[{}]", targets.join(",")),
                    dag,
                    None,
                    loop_depth,
                    record,
                    fn_label,
                );
            }
            Stmt::ExprStmt { expr, pos } => {
                let (e, note) = reorder_matmult_chains(expr, symbols);
                *expr = e;
                if record {
                    plan_user_calls(expr, symbols, ctx, plan, loop_depth);
                }
                let dag = DagBuilder::new(symbols).build(expr);
                record_stmt(
                    plan,
                    ctx,
                    *pos,
                    "(expr)".to_string(),
                    dag,
                    note,
                    loop_depth,
                    record,
                    fn_label,
                );
            }
            Stmt::If { then_branch, else_branch, .. } => {
                // Plan both branches from the same entry state; variables
                // whose shapes disagree afterwards become unknown, and a
                // variable is only modeled blocked after the If when
                // *both* branches leave it blocked (intersection — the
                // residency analogue of merge_symbols).
                let entry_blocked = ctx.blocked_vars.clone();
                let mut then_syms = symbols.clone();
                plan_block(then_branch, &mut then_syms, ctx, plan, record, loop_depth, fn_label);
                let then_blocked =
                    std::mem::replace(&mut ctx.blocked_vars, entry_blocked);
                let mut else_syms = symbols.clone();
                plan_block(else_branch, &mut else_syms, ctx, plan, record, loop_depth, fn_label);
                let merged: HashSet<String> =
                    ctx.blocked_vars.intersection(&then_blocked).cloned().collect();
                ctx.blocked_vars = merged;
                merge_symbols(symbols, &then_syms, &else_syms);
            }
            Stmt::For { var, body, .. } | Stmt::ParFor { var, body, .. } => {
                symbols.insert(var.clone(), ShapeInfo::scalar_value());
                plan_loop_body(body, symbols, ctx, plan, record, loop_depth + 1, fn_label);
            }
            Stmt::While { body, .. } => {
                plan_loop_body(body, symbols, ctx, plan, record, loop_depth + 1, fn_label);
            }
        }
    }
}

/// Loop bodies: a dry pass discovers loop-carried variables whose shapes
/// change across iterations (those become unknown), then the real pass
/// plans against the stabilized shapes.
#[allow(clippy::too_many_arguments)]
fn plan_loop_body(
    body: &mut [Stmt],
    symbols: &mut HashMap<String, ShapeInfo>,
    ctx: &mut PlanCtx,
    plan: &mut Plan,
    record: bool,
    loop_depth: usize,
    fn_label: Option<&str>,
) {
    let mut probe = symbols.clone();
    plan_block(body, &mut probe, ctx, plan, false, loop_depth, fn_label);
    for (name, shape) in probe.iter() {
        match symbols.get(name) {
            Some(prev) if prev == shape => {}
            Some(_) => {
                symbols.insert(name.clone(), ShapeInfo::unknown());
            }
            // Defined only inside the loop: trust the first-iteration
            // shape only if a second probe agrees.
            None => {
                symbols.insert(name.clone(), *shape);
            }
        }
    }
    // Second probe from the merged state catches shapes that keep
    // changing (e.g. X = cbind(X, v)).
    let mut probe2 = symbols.clone();
    plan_block(body, &mut probe2, ctx, plan, false, loop_depth, fn_label);
    for (name, shape) in probe2.iter() {
        if symbols.get(name).is_some_and(|prev| prev != shape) {
            symbols.insert(name.clone(), ShapeInfo::unknown());
        }
    }
    plan_block(body, symbols, ctx, plan, record, loop_depth, fn_label);
}

/// Plan the bodies of main-file user functions called in `expr`, with
/// parameter shapes (and blocked-ness) bound from the call-site
/// arguments. Each (function, shape-signature) pair is planned once;
/// placements that disagree across call sites are dropped as conflicted
/// so the runtime estimate decides there.
fn plan_user_calls(
    expr: &Expr,
    symbols: &HashMap<String, ShapeInfo>,
    ctx: &mut PlanCtx,
    plan: &mut Plan,
    loop_depth: usize,
) {
    match expr {
        Expr::Call { namespace: None, name, args, .. } => {
            for a in args {
                plan_user_calls(&a.value, symbols, ctx, plan, loop_depth);
            }
            let Some(f) = ctx.funcs.get(name).cloned() else { return };
            if ctx.fn_stack.iter().any(|n| n == name) || ctx.planned_sigs.len() > 64 {
                return;
            }
            // Bind parameter shapes positionally / by name, like the
            // interpreter's argument binding.
            let mut fsyms: HashMap<String, ShapeInfo> = HashMap::new();
            let mut fblocked: HashSet<String> = HashSet::new();
            let mut positional = 0usize;
            for a in args {
                let pname = match &a.name {
                    None => {
                        let p = f.params.get(positional).map(|p| p.name.clone());
                        positional += 1;
                        p
                    }
                    Some(n) => Some(n.clone()),
                };
                let Some(pname) = pname else { continue };
                let shape = DagBuilder::infer_shape(symbols, &a.value);
                fsyms.insert(pname.clone(), shape);
                if let Expr::Var(v, _) = &a.value {
                    if ctx.blocked_vars.contains(v) {
                        fblocked.insert(pname);
                    }
                }
            }
            for p in &f.params {
                fsyms.entry(p.name.clone()).or_insert_with(ShapeInfo::unknown);
            }
            let sig = format!(
                "{name}({})",
                f.params
                    .iter()
                    .map(|p| {
                        let s = fsyms[&p.name];
                        let b = if fblocked.contains(&p.name) { "B" } else { "" };
                        format!("{}{b}", s.render())
                    })
                    .collect::<Vec<_>>()
                    .join(",")
            );
            if !ctx.planned_sigs.insert(sig) {
                return;
            }
            ctx.fn_stack.push(name.clone());
            let outer_blocked = std::mem::replace(&mut ctx.blocked_vars, fblocked);
            let mut body = f.body.clone();
            plan_block(&mut body, &mut fsyms, ctx, plan, true, loop_depth, Some(name));
            ctx.blocked_vars = outer_blocked;
            ctx.fn_stack.pop();
        }
        Expr::Call { args, .. } => {
            for a in args {
                plan_user_calls(&a.value, symbols, ctx, plan, loop_depth);
            }
        }
        Expr::Binary { lhs, rhs, .. } => {
            plan_user_calls(lhs, symbols, ctx, plan, loop_depth);
            plan_user_calls(rhs, symbols, ctx, plan, loop_depth);
        }
        Expr::Unary { operand, .. } => plan_user_calls(operand, symbols, ctx, plan, loop_depth),
        Expr::Index { base, .. } => plan_user_calls(base, symbols, ctx, plan, loop_depth),
        Expr::List(items, _) => {
            for i in items {
                plan_user_calls(i, symbols, ctx, plan, loop_depth);
            }
        }
        _ => {}
    }
}

/// Keep shapes that agree across both branches; discard the rest.
fn merge_symbols(
    out: &mut HashMap<String, ShapeInfo>,
    a: &HashMap<String, ShapeInfo>,
    b: &HashMap<String, ShapeInfo>,
) {
    let mut names: Vec<&String> = a.keys().collect();
    names.extend(b.keys());
    for name in names {
        match (a.get(name), b.get(name)) {
            (Some(x), Some(y)) if x == y => {
                out.insert(name.clone(), *x);
            }
            _ => {
                if a.contains_key(name) || b.contains_key(name) {
                    out.insert(name.clone(), ShapeInfo::unknown());
                }
            }
        }
    }
}

/// Extract the heavy operators of a DAG, place them, and (when `record`)
/// add the statement plan. Models first-class blocked values: a read of
/// a blocked variable carries zero blockify cost and forces its consumer
/// DIST; multi-block DIST outputs flow blocked through cellwise/unary
/// operators. Returns whether the statement's root value is modeled as
/// blocked (so the bound variable joins `PlanCtx::blocked_vars`).
#[allow(clippy::too_many_arguments)]
fn record_stmt(
    plan: &mut Plan,
    ctx: &mut PlanCtx,
    pos: Pos,
    target: String,
    dag: HopDag,
    note: Option<String>,
    loop_depth: usize,
    record: bool,
    fn_label: Option<&str>,
) -> bool {
    let config = ctx.config;
    let bs = config.block_size.max(1);
    let mut ops = Vec::new();
    // Keys written by this statement, to detect position collisions
    // (reordered matmult chains stamp every rebuilt node with one Pos).
    let mut written: HashMap<(usize, usize, OpKind), usize> = HashMap::new();
    // Per node: does its value flow as a first-class blocked value?
    // (Inputs always have smaller ids than their consumers.)
    let mut blocked = vec![false; dag.nodes.len()];
    for n in &dag.nodes {
        let in_blocked = n.inputs.iter().any(|i| blocked[*i]);
        // Conv/pool builtin calls are placed operators (`OpKind::Conv`).
        let conv_op = match &n.op {
            HopOp::Call(c) => crate::runtime::conv::conv_builtin(c),
            _ => None,
        };
        let kind = match &n.op {
            HopOp::Binary(AstBinOp::MatMul) | HopOp::MatMul => OpKind::MatMult,
            HopOp::Binary(_) if !n.shape.scalar => OpKind::CellBinary,
            HopOp::Agg { .. } => OpKind::Agg,
            HopOp::Transpose => OpKind::Reorg,
            // Right indexing is a placed operator: block-range selection
            // on DIST, with blocked-ness flowing through it.
            HopOp::Index => OpKind::RightIndex,
            HopOp::Call(_) if conv_op.is_some() => OpKind::Conv,
            HopOp::Read(name) => {
                blocked[n.id] = ctx.blocked_vars.contains(name);
                continue;
            }
            // Unary cell ops map over resident blocks at runtime.
            HopOp::Unary(_) => {
                blocked[n.id] = in_blocked;
                continue;
            }
            HopOp::Call(name) if is_cellwise_unary_builtin(name) => {
                blocked[n.id] = in_blocked;
                continue;
            }
            // Channel-wise bias ops map over resident blocks at runtime
            // (dispatch_bias_value): residency follows the matrix input.
            HopOp::Call(name) if name == "bias_add" || name == "bias_multiply" => {
                blocked[n.id] = n.inputs.first().map(|i| blocked[*i]).unwrap_or(false);
                continue;
            }
            // Literals and opaque calls produce driver values.
            _ => continue,
        };
        let mut bcast = false;
        if kind == OpKind::CellBinary {
            let any_scalar = n.inputs.iter().any(|i| dag.nodes[*i].shape.scalar);
            let out_dims = n.shape.known_dims();
            let rhs_dims =
                n.inputs.get(1).and_then(|i| dag.nodes[*i].shape.known_dims());
            if any_scalar || rhs_dims == Some((1, 1)) {
                // Matrix∘scalar (including 1x1-rhs promotion) follows its
                // matrix operand's residency (a blocked operand maps
                // cluster-side, no placement) — single-block included,
                // since single-block blocked values are replicated and a
                // per-block map keeps them so.
                blocked[n.id] = in_blocked;
                continue;
            }
            let mismatch = n.inputs.iter().any(|i| {
                let s = dag.nodes[*i].shape;
                s.known_dims().is_some() && s.known_dims() != out_dims
            });
            if mismatch {
                // Vector-broadcast pair: DIST-eligible as a map-side
                // broadcast join when the *rhs* is the row/col vector and
                // the lhs carries the output shape — mirroring the
                // runtime kernel, which broadcasts only rhs vectors. The
                // communication cost is the broadcast vector's bytes;
                // blockify cost is zero when the lhs is already blocked.
                let rhs_vec = n.inputs.len() == 2
                    && matches!(rhs_dims, Some((r, c)) if (r == 1) ^ (c == 1));
                let lhs_out = n
                    .inputs
                    .first()
                    .map(|i| dag.nodes[*i].shape.known_dims() == out_dims)
                    .unwrap_or(false);
                if !(rhs_vec && lhs_out) {
                    // Any other mismatched pair stays CP (forcing
                    // blocked operands) — or is a runtime shape error.
                    continue;
                }
                bcast = true;
            }
        }
        let est = op_mem_estimate(&dag, n.id, kind);
        // "Operand already blocked" models zero blockify cost: the
        // operator runs DIST regardless of its memory estimate, because
        // collecting a resident operand to run CP is strictly worse.
        // This is the compile-time mirror of the runtime dispatch rule.
        // For a broadcast pair only the *lhs* (the big operand) decides —
        // the runtime never collects it to honor a CP placement. For a
        // conv/pool call only the *batch* operands decide (input, and the
        // dout companion; conv2d_backward_data's batch is its second
        // argument) — a blocked filter is gathered worker-side, it never
        // forces the op DIST.
        let eff_blocked = if let Some(cop) = conv_op {
            use crate::runtime::conv::ConvOpKind as CK;
            // The DAG lowering canonicalizes conv inputs to
            // [batch, companion?, ...], so roles are positional here
            // even for named-argument call styles. The companion is a
            // second batch operand (dout) for every has_dout op except
            // backward_data, whose companion is the filter.
            let mut e = n.inputs.first().map(|i| blocked[*i]).unwrap_or(false);
            if cop.has_dout() && cop != CK::Conv2dBackwardData {
                e |= n.inputs.get(1).map(|i| blocked[*i]).unwrap_or(false);
            }
            e
        } else if bcast {
            n.inputs.first().map(|i| blocked[*i]).unwrap_or(false)
        } else {
            in_blocked
        };
        let exec = if eff_blocked && config.dist_enabled {
            Some(ExecType::Dist)
        } else {
            est.map(|e| choose_exec(e, config, kind == OpKind::MatMult))
        };
        let mut allreduce = false;
        if exec == Some(ExecType::Dist) {
            use crate::runtime::conv::ConvOpKind as CK;
            // Multi-block DIST outputs bind as blocked values.
            // Single-block outputs split two ways (mirroring the runtime
            // dispatch): an *aggregation-shaped* result tree-allreduces
            // and stays **replicated** on the workers (blocked), while
            // any other single-block output returns to the driver with
            // the job.
            let single = !multi_block(n.shape, bs);
            blocked[n.id] = match kind {
                OpKind::Agg => {
                    // colSums-style gradients: single-block axis
                    // aggregates replicate; scalars and multi-block
                    // aggregate vectors return to the driver.
                    allreduce = single && !n.shape.scalar && n.shape.known_dims().is_some();
                    allreduce
                }
                OpKind::Conv if conv_op == Some(CK::Conv2dBackwardFilter) => {
                    // The K×CRS gradient is always allreduce-combined;
                    // it stays replicated when it fits one block.
                    allreduce = true;
                    single && n.shape.known_dims().is_some()
                }
                OpKind::MatMult if single => {
                    // Gradient-shaped product (t(X) %*% dout): a
                    // multi-block inner dimension reduced into one block.
                    allreduce = n
                        .inputs
                        .first()
                        .and_then(|i| dag.nodes[*i].shape.known_dims())
                        .map(|(_, k)| k > bs)
                        .unwrap_or(false);
                    allreduce
                }
                // Cellwise maps and transposes over a replicated operand
                // keep it replicated (the optimizer update chain).
                OpKind::CellBinary | OpKind::Reorg if single => eff_blocked,
                _ => multi_block(n.shape, bs),
            };
        }
        if record {
            if let (Some(e), Some(x)) = (est, exec) {
                let key = (n.pos.line, n.pos.col, kind);
                *written.entry(key).or_insert(0) += 1;
                // Collision rule shared with left-index placements: a key
                // that ever receives two different ExecTypes (another
                // call site of the same function body) is dropped, so
                // the runtime estimate decides there.
                place_key(plan, ctx, key, x, e);
            }
            if exec == Some(ExecType::Dist) {
                // Track which variables feed this DIST operator (directly
                // or through a transpose) for the `Cached` marking.
                for name in dist_read_names(&dag, n.id) {
                    plan.dist_read_sites
                        .entry(name.clone())
                        .or_default()
                        .insert((pos.line, pos.col));
                    if loop_depth > 0 {
                        plan.dist_loop_reads.insert(name);
                    }
                }
            }
            // Sparse-sized decision: the estimate above was charged CSR
            // bytes for the output or a matrix input, so EXPLAIN flags it.
            let sparse = shape_plans_sparse(n.shape)
                || n.inputs.iter().any(|i| shape_plans_sparse(dag.nodes[*i].shape));
            ops.push(PlannedOp {
                node: n.id,
                kind,
                pos: n.pos,
                exec,
                est,
                bcast,
                allreduce,
                sparse,
            });
        }
    }
    let root_blocked = blocked[dag.root];
    if record {
        // A key claimed by more than one distinct operator is ambiguous
        // at runtime (same source position): drop it permanently.
        for (key, count) in written {
            if count > 1 {
                plan.placements.remove(&key);
                ctx.conflicted.insert(key);
            }
        }
        let target = match fn_label {
            Some(f) => format!("fn {f}: {target}"),
            None => target,
        };
        plan.stmts.push(StmtPlan { pos, target, dag, ops, note, lix: None });
    }
    root_blocked
}

/// Insert a placement under `key` with the same position-collision rule
/// record_stmt applies: a key that ever receives two different ExecTypes
/// is dropped as conflicted, so the runtime estimate decides there.
fn place_key(
    plan: &mut Plan,
    ctx: &mut PlanCtx,
    key: (usize, usize, OpKind),
    exec: ExecType,
    est: usize,
) {
    if ctx.conflicted.contains(&key) {
        return;
    }
    match plan.placements.get(&key) {
        Some(p) if p.exec != exec => {
            plan.placements.remove(&key);
            ctx.conflicted.insert(key);
        }
        _ => {
            plan.placements.insert(key, Placement { exec, est });
        }
    }
}

/// Is this shape estimated in the sparse (CSR) size regime — below the
/// sparsity turn point and large enough for the CSR overhead to pay off?
/// Mirrors `hop::estimate::estimate_size`'s format choice so the EXPLAIN
/// `SPARSE` marker agrees with the bytes the placement was costed at.
fn shape_plans_sparse(shape: ShapeInfo) -> bool {
    use crate::runtime::matrix::{MIN_SPARSE_CELLS, SPARSITY_TURN_POINT};
    match shape.known_dims() {
        Some((r, c)) => {
            r.saturating_mul(c) >= MIN_SPARSE_CELLS && shape.sparsity < SPARSITY_TURN_POINT
        }
        None => false,
    }
}

/// Does a DIST output of this shape span more than one block (and so
/// bind as a first-class blocked value)? Unknown matrix dims are assumed
/// multi-block — the conservative direction for placement, and the
/// runtime's blocked-operand rule corrects any mismatch.
fn multi_block(shape: ShapeInfo, block_size: usize) -> bool {
    match shape.known_dims() {
        Some((r, c)) => r > block_size || c > block_size,
        None => !shape.scalar,
    }
}

/// Shape-preserving cellwise unary builtins (runtime maps them over
/// resident blocks when the operand is blocked). Shares the name table
/// with the interpreter's builtin dispatch so the planner's blocked-ness
/// dataflow can never drift from runtime behavior.
fn is_cellwise_unary_builtin(name: &str) -> bool {
    crate::runtime::matrix::elementwise::UnaryOp::from_builtin_name(name).is_some()
}

/// Variable reads feeding a DAG node, looking through one transpose
/// level (`t(X)` keeps `X`'s blocked partitions interesting too).
fn dist_read_names(dag: &HopDag, node: NodeId) -> Vec<String> {
    let mut out = Vec::new();
    for i in &dag.nodes[node].inputs {
        match &dag.nodes[*i].op {
            HopOp::Read(name) => out.push(name.clone()),
            HopOp::Transpose => {
                if let Some(j) = dag.nodes[*i].inputs.first() {
                    if let HopOp::Read(name) = &dag.nodes[*j].op {
                        out.push(name.clone());
                    }
                }
            }
            _ => {}
        }
    }
    out
}

/// Worst-case memory estimate of one heavy operator: inputs plus output.
fn op_mem_estimate(dag: &HopDag, node: NodeId, kind: OpKind) -> Option<usize> {
    let n = &dag.nodes[node];
    let mut total = 0usize;
    for i in &n.inputs {
        let s = dag.nodes[*i].shape;
        if s.scalar {
            continue;
        }
        // A conv/pool call's shape-argument lists are not data operands;
        // the matrix operands (batch, filter) must still be known.
        if kind == OpKind::Conv && matches!(dag.nodes[*i].op, HopOp::List | HopOp::LitStr(_)) {
            continue;
        }
        total = total.saturating_add(s.mem_estimate()?);
    }
    total = match kind {
        OpKind::Agg => {
            // Aggregate outputs are vectors/scalars — negligible next to
            // the input, but still accounted.
            let (r, c) = match n.shape.known_dims() {
                Some(d) => d,
                None if n.shape.scalar => (1, 1),
                None => return None,
            };
            total.saturating_add(estimate::dense_size(r, c))
        }
        // Conv accounts the output twice: once for the result, once as a
        // proxy for the im2col-expanded patch matrix built per image.
        OpKind::Conv => total.saturating_add(n.shape.mem_estimate()?.saturating_mul(2)),
        _ => total.saturating_add(n.shape.mem_estimate()?),
    };
    Some(total)
}

/// Matrix-multiplication chain reordering at the plan level: flatten
/// `((A %*% B) %*% C)` chains, and when every operand shape is known,
/// rebuild the tree in the FLOP-optimal association (classic DP). The
/// rewritten expression is what the interpreter executes. Returns the
/// (possibly unchanged) expression and an explain note when it fired.
pub fn reorder_matmult_chains(
    expr: &Expr,
    symbols: &HashMap<String, ShapeInfo>,
) -> (Expr, Option<String>) {
    let mut note = None;
    let out = reorder_expr(expr, symbols, &mut note);
    (out, note)
}

fn reorder_expr(
    expr: &Expr,
    symbols: &HashMap<String, ShapeInfo>,
    note: &mut Option<String>,
) -> Expr {
    match expr {
        Expr::Binary { op: AstBinOp::MatMul, pos, .. } => {
            // Flatten the chain, recursively rewriting the operands.
            let mut operands = Vec::new();
            flatten_chain(expr, symbols, note, &mut operands);
            if operands.len() >= 3 {
                if let Some(dims) = chain_dims(&operands, symbols) {
                    let (cost, split) = matmult_chain_split(&dims);
                    let left_deep = left_deep_cost(&dims);
                    if cost < left_deep {
                        let rendered =
                            crate::hop::rewrite::render_chain_split(&split, 0, operands.len() - 1);
                        *note = Some(format!(
                            "matmult chain x{} reordered {rendered}: {cost} FLOPs vs {left_deep} left-deep",
                            operands.len()
                        ));
                        return build_chain(&operands, &split, 0, operands.len() - 1, *pos);
                    }
                }
            }
            // Not rewritable: rebuild left-deep over the (rewritten)
            // operands only if the original was left-deep; otherwise keep
            // the original association.
            rebuild_binary(expr, symbols, note)
        }
        _ => rebuild_binary(expr, symbols, note),
    }
}

/// Rebuild an expression node, recursing into children.
fn rebuild_binary(
    expr: &Expr,
    symbols: &HashMap<String, ShapeInfo>,
    note: &mut Option<String>,
) -> Expr {
    match expr {
        Expr::Binary { op, lhs, rhs, pos } => Expr::Binary {
            op: *op,
            lhs: Box::new(reorder_expr(lhs, symbols, note)),
            rhs: Box::new(reorder_expr(rhs, symbols, note)),
            pos: *pos,
        },
        Expr::Unary { op, operand, pos } => Expr::Unary {
            op: *op,
            operand: Box::new(reorder_expr(operand, symbols, note)),
            pos: *pos,
        },
        Expr::Call { namespace, name, args, pos } => Expr::Call {
            namespace: namespace.clone(),
            name: name.clone(),
            args: args
                .iter()
                .map(|a| Arg { name: a.name.clone(), value: reorder_expr(&a.value, symbols, note) })
                .collect(),
            pos: *pos,
        },
        Expr::Index { base, rows, cols, pos } => Expr::Index {
            base: Box::new(reorder_expr(base, symbols, note)),
            rows: rows.clone(),
            cols: cols.clone(),
            pos: *pos,
        },
        Expr::List(items, pos) => {
            Expr::List(items.iter().map(|e| reorder_expr(e, symbols, note)).collect(), *pos)
        }
        other => other.clone(),
    }
}

/// Flatten nested matmults into an operand list (associativity lets the
/// planner regroup freely), rewriting non-matmult operands recursively.
fn flatten_chain(
    expr: &Expr,
    symbols: &HashMap<String, ShapeInfo>,
    note: &mut Option<String>,
    out: &mut Vec<Expr>,
) {
    match expr {
        Expr::Binary { op: AstBinOp::MatMul, lhs, rhs, .. } => {
            flatten_chain(lhs, symbols, note, out);
            flatten_chain(rhs, symbols, note, out);
        }
        other => out.push(rebuild_binary(other, symbols, note)),
    }
}

/// The dims vector d0..dn of a chain, when every operand shape is known
/// and the inner dimensions agree.
fn chain_dims(operands: &[Expr], symbols: &HashMap<String, ShapeInfo>) -> Option<Vec<usize>> {
    let mut dims = Vec::with_capacity(operands.len() + 1);
    let mut prev_cols: Option<usize> = None;
    for o in operands {
        let s = DagBuilder::infer_shape(symbols, o);
        let (r, c) = s.known_dims()?;
        if let Some(pc) = prev_cols {
            if pc != r {
                return None; // dim mismatch — leave for runtime to report
            }
        } else {
            dims.push(r);
        }
        dims.push(c);
        prev_cols = Some(c);
    }
    Some(dims)
}

/// FLOP cost of evaluating the chain left-to-right (the parser's default
/// association). Saturating: declared shapes can be adversarially large.
fn left_deep_cost(dims: &[usize]) -> u64 {
    let mut cost = 0u64;
    for i in 1..dims.len() - 1 {
        let term = 2u64
            .saturating_mul(dims[0] as u64)
            .saturating_mul(dims[i] as u64)
            .saturating_mul(dims[i + 1] as u64);
        cost = cost.saturating_add(term);
    }
    cost
}

/// Build the optimally-associated expression tree from the split table.
fn build_chain(operands: &[Expr], split: &[Vec<usize>], i: usize, j: usize, pos: Pos) -> Expr {
    if i == j {
        return operands[i].clone();
    }
    let k = split[i][j];
    Expr::Binary {
        op: AstBinOp::MatMul,
        lhs: Box::new(build_chain(operands, split, i, k, pos)),
        rhs: Box::new(build_chain(operands, split, k + 1, j, pos)),
        pos,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;
    use crate::hop::rewrite::print_expr;
    use crate::runtime::interp::registry::build_bundle;

    fn plan_src(src: &str, inputs: &[(&str, ShapeInfo)], config: &SystemConfig) -> Plan {
        let prog = parse(src).unwrap();
        let mut bundle = build_bundle(prog, config).unwrap();
        let syms: HashMap<String, ShapeInfo> =
            inputs.iter().map(|(n, s)| (n.to_string(), *s)).collect();
        compile_plan(&mut bundle, &syms, config)
    }

    #[test]
    fn small_matmult_planned_cp() {
        let config = SystemConfig::default();
        let plan = plan_src(
            "Y = X %*% X\ns = sum(Y)",
            &[("X", ShapeInfo::matrix(64, 64, 1.0))],
            &config,
        );
        assert_eq!(plan.placed_execs(OpKind::MatMult), vec![ExecType::CP]);
        assert_eq!(plan.placed_execs(OpKind::Agg), vec![ExecType::CP]);
        assert!(plan.render().contains("-> CP"), "{}", plan.render());
    }

    #[test]
    fn tiny_budget_flips_to_dist() {
        let config = SystemConfig::tiny_driver(32 * 1024);
        let plan = plan_src(
            "Y = X %*% X\ns = sum(Y)",
            &[("X", ShapeInfo::matrix(96, 96, 1.0))],
            &config,
        );
        assert_eq!(plan.placed_execs(OpKind::MatMult), vec![ExecType::Dist]);
        assert_eq!(plan.placed_execs(OpKind::Agg), vec![ExecType::Dist]);
        assert!(plan.render().contains("-> DIST"), "{}", plan.render());
    }

    #[test]
    fn chain_reorder_rewrites_ast() {
        let config = SystemConfig::default();
        let prog = parse("y = A %*% B %*% v").unwrap();
        let mut bundle = build_bundle(prog, &config).unwrap();
        let syms: HashMap<String, ShapeInfo> = [
            ("A".to_string(), ShapeInfo::matrix(500, 500, 1.0)),
            ("B".to_string(), ShapeInfo::matrix(500, 500, 1.0)),
            ("v".to_string(), ShapeInfo::matrix(500, 1, 1.0)),
        ]
        .into_iter()
        .collect();
        let plan = compile_plan(&mut bundle, &syms, &config);
        // The AST the interpreter will execute is right-associated now.
        match &bundle.main.body[0] {
            Stmt::Assign { value, .. } => {
                assert_eq!(print_expr(value), "(A %*% (B %*% v))");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(
            plan.stmts[0].note.as_deref().unwrap_or("").contains("reordered"),
            "{:?}",
            plan.stmts[0].note
        );
    }

    #[test]
    fn loop_carried_growth_goes_unknown() {
        let config = SystemConfig::default();
        let plan = plan_src(
            "for (i in 1:3) { X = cbind(X, X) }\nY = X %*% t(X)",
            &[("X", ShapeInfo::matrix(8, 8, 1.0))],
            &config,
        );
        // X's shape is loop-carried and growing: the matmult must carry
        // no placement (decided at runtime).
        let mm: Vec<&PlannedOp> = plan
            .stmts
            .iter()
            .flat_map(|s| s.ops.iter())
            .filter(|o| o.kind == OpKind::MatMult)
            .collect();
        assert!(!mm.is_empty());
        assert!(mm.iter().all(|o| o.exec.is_none()), "{mm:?}");
    }

    #[test]
    fn stable_loop_shapes_stay_planned() {
        let config = SystemConfig::tiny_driver(64 * 1024);
        let plan = plan_src(
            "for (i in 1:3) { w = w - 0.1 * (X %*% w) }",
            &[
                ("X", ShapeInfo::matrix(200, 200, 1.0)),
                ("w", ShapeInfo::matrix(200, 1, 1.0)),
            ],
            &config,
        );
        // w's shape is loop-stable, so the matmult inside the loop is
        // planned (to DIST: X alone is 320 KB > 64 KB).
        assert_eq!(plan.placed_execs(OpKind::MatMult), vec![ExecType::Dist]);
    }

    #[test]
    fn right_index_is_planned_and_propagates_blockedness() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        // The 96x96 base does not fit the driver: the slice places DIST,
        // its multi-block output flows blocked into the matmult, and the
        // render carries the IDX marker.
        let plan = plan_src(
            "B = X[1:64, 1:96]\nY = B %*% t(B)\ns = sum(Y)",
            &[("X", ShapeInfo::matrix(96, 96, 1.0))],
            &config,
        );
        assert_eq!(plan.placed_execs(OpKind::RightIndex), vec![ExecType::Dist]);
        assert_eq!(plan.placed_execs(OpKind::MatMult), vec![ExecType::Dist]);
        assert!(plan.render().contains(" IDX"), "{}", plan.render());
    }

    #[test]
    fn broadcast_cellwise_is_dist_eligible_and_marked() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        // X - colMeans-style row vector: the pair is placed DIST (est
        // over budget) instead of being skipped to CP, and renders BCAST.
        let plan = plan_src(
            "Y = X - mu\ns = sum(Y)",
            &[
                ("X", ShapeInfo::matrix(96, 96, 1.0)),
                ("mu", ShapeInfo::matrix(1, 96, 1.0)),
            ],
            &config,
        );
        assert_eq!(plan.placed_execs(OpKind::CellBinary), vec![ExecType::Dist]);
        assert!(plan.render().contains(" BCAST"), "{}", plan.render());
        // A vector *lhs* mirrors the runtime kernel: not DIST-eligible.
        let plan2 = plan_src(
            "Y = mu - X\ns = 1",
            &[
                ("X", ShapeInfo::matrix(96, 96, 1.0)),
                ("mu", ShapeInfo::matrix(1, 96, 1.0)),
            ],
            &config,
        );
        assert!(plan2.placed_execs(OpKind::CellBinary).is_empty(), "{}", plan2.render());
    }

    #[test]
    fn left_index_keeps_blocked_target_blocked() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        // Y is a DIST matmult output (blocked). The left-index write is
        // planned DIST (touched-block rewrite), Y stays blocked, and the
        // following consumer still sees a blocked operand.
        let plan = plan_src(
            "Y = X %*% X\nY[1:8, 1:8] = Z\ns = sum(Y)",
            &[
                ("X", ShapeInfo::matrix(96, 96, 1.0)),
                ("Z", ShapeInfo::matrix(8, 8, 1.0)),
            ],
            &config,
        );
        let lix = plan
            .stmts
            .iter()
            .find_map(|s| s.lix)
            .expect("left-index write must carry a placement");
        assert_eq!(lix.exec, ExecType::Dist);
        assert!(plan.render().contains("lix"), "{}", plan.render());
        // The aggregate after the write is DIST because Y is still
        // blocked (zero blockify), not merely because of its estimate.
        assert_eq!(plan.placed_execs(OpKind::Agg), vec![ExecType::Dist]);
    }

    #[test]
    fn conv_builtins_are_planned_and_propagate_blockedness() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        // X (96x64) is over budget → conv2d places DIST with a CONV
        // marker; its 96x256 output flows blocked into max_pool, whose
        // 96x64 output flows blocked through the bias map into the
        // aggregate.
        let plan = plan_src(
            "C = conv2d(X, W, input_shape=[96,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])\nH = max_pool(C, input_shape=[96,4,8,8], pool_size=[2,2], stride=[2,2])\nHb = bias_add(H, bv)\ns = sum(Hb)",
            &[
                ("X", ShapeInfo::matrix(96, 64, 1.0)),
                ("W", ShapeInfo::matrix(4, 9, 1.0)),
                ("bv", ShapeInfo::matrix(4, 1, 1.0)),
            ],
            &config,
        );
        assert_eq!(
            plan.placed_execs(OpKind::Conv),
            vec![ExecType::Dist, ExecType::Dist],
            "{}",
            plan.render()
        );
        assert_eq!(plan.placed_execs(OpKind::Agg), vec![ExecType::Dist], "{}", plan.render());
        assert!(plan.render().contains(" CONV"), "{}", plan.render());
    }

    #[test]
    fn conv_backward_filter_gradient_is_allreduce_and_stays_blocked() {
        let mut config = SystemConfig::tiny_driver(32 * 1024);
        config.block_size = 32;
        let plan = plan_src(
            "dW = conv2d_backward_filter(X, dC, input_shape=[96,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])\nW = W - 0.05 * dW\ns = sum(W)",
            &[
                ("X", ShapeInfo::matrix(96, 64, 1.0)),
                ("dC", ShapeInfo::matrix(96, 256, 1.0)),
                ("W", ShapeInfo::matrix(4, 9, 1.0)),
            ],
            &config,
        );
        assert_eq!(plan.placed_execs(OpKind::Conv), vec![ExecType::Dist], "{}", plan.render());
        assert!(plan.render().contains(" ALLREDUCE"), "{}", plan.render());
        // The K×CRS gradient tree-allreduces and stays replicated on the
        // workers, so the weight-update chain consuming dW is modeled
        // blocked: the cellwise update is forced DIST (zero blockify) and
        // the aggregate over the updated weights stays DIST too.
        assert_eq!(
            plan.placed_execs(OpKind::CellBinary),
            vec![ExecType::Dist],
            "{}",
            plan.render()
        );
        assert_eq!(plan.placed_execs(OpKind::Agg), vec![ExecType::Dist], "{}", plan.render());
    }

    #[test]
    fn gradient_matmult_is_allreduce_and_update_chain_stays_blocked() {
        let mut config = SystemConfig::tiny_driver(8 * 1024);
        config.block_size = 32;
        // t(X) %*% y: 8x96 @ 96x8 -> 8x8 single block with a multi-block
        // inner dimension — the allreduce shape. The SGD update chain on
        // the replicated gradient stays blocked end to end.
        let plan = plan_src(
            "g = t(X) %*% y\nw = w - 0.1 * g\ns = sum(w)",
            &[
                ("X", ShapeInfo::matrix(96, 8, 1.0)),
                ("y", ShapeInfo::matrix(96, 8, 1.0)),
                ("w", ShapeInfo::matrix(8, 8, 1.0)),
            ],
            &config,
        );
        assert_eq!(plan.placed_execs(OpKind::MatMult), vec![ExecType::Dist], "{}", plan.render());
        assert!(plan.render().contains(" ALLREDUCE"), "{}", plan.render());
        assert_eq!(
            plan.placed_execs(OpKind::CellBinary),
            vec![ExecType::Dist],
            "{}",
            plan.render()
        );
        assert_eq!(plan.placed_execs(OpKind::Agg), vec![ExecType::Dist], "{}", plan.render());
    }

    #[test]
    fn sparse_estimates_shrink_placement_and_render_sparse() {
        let config = SystemConfig::tiny_driver(256 * 1024);
        // Dense 400x400: ~3.8 MB estimate flips the matmult to DIST.
        let dense = plan_src(
            "Y = X %*% X\ns = sum(Y)",
            &[("X", ShapeInfo::matrix(400, 400, 1.0))],
            &config,
        );
        assert_eq!(dense.placed_execs(OpKind::MatMult), vec![ExecType::Dist]);
        assert!(!dense.render().contains(" SPARSE"), "{}", dense.render());
        // Same shapes at 1% density: CSR-sized estimates fit the driver,
        // so the placement stays CP and EXPLAIN carries SPARSE.
        let sparse = plan_src(
            "Y = X %*% X\ns = sum(Y)",
            &[("X", ShapeInfo::matrix(400, 400, 0.01))],
            &config,
        );
        assert_eq!(
            sparse.placed_execs(OpKind::MatMult),
            vec![ExecType::CP],
            "{}",
            sparse.render()
        );
        assert!(sparse.render().contains(" SPARSE"), "{}", sparse.render());
    }

    #[test]
    fn choose_exec_respects_budgets() {
        let mut config = SystemConfig::tiny_driver(1000);
        assert_eq!(choose_exec(999, &config, false), ExecType::CP);
        assert_eq!(choose_exec(1001, &config, false), ExecType::Dist);
        config.dist_enabled = false;
        assert_eq!(choose_exec(1001, &config, false), ExecType::CP);
        config.accel_enabled = true;
        config.accel_memory = 2000;
        assert_eq!(choose_exec(1500, &config, true), ExecType::Accel);
        assert_eq!(choose_exec(2500, &config, true), ExecType::CP);
    }
}
