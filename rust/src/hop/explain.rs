//! Plan explanation (`sysml explain`, SystemML's `-explain`): program
//! structure, per-statement operator summary, CSE opportunities, the
//! execution-type thresholds in force, and the annotated HOP plan
//! (per-operator ExecType assignments, SystemML's `explain(hops)`).

use std::fmt::Write as _;

use crate::conf::SystemConfig;
use crate::dml::ast::*;
use crate::dml::validate::Bundle;
use crate::hop::plan::Plan;
use crate::hop::rewrite::{cse_candidates, print_expr};

/// Render the compiled HOP plan with per-operator ExecType annotations.
pub fn explain_plan(plan: &Plan) -> String {
    plan.render()
}

/// Render a human-readable plan for a compiled bundle.
pub fn explain_bundle(bundle: &Bundle, config: &SystemConfig) -> String {
    let mut s = String::new();
    writeln!(s, "# PROGRAM").unwrap();
    writeln!(
        s,
        "# driver budget: {} B | workers: {} | block: {} | accel: {}",
        config.driver_memory, config.num_workers, config.block_size, config.accel_enabled
    )
    .unwrap();
    for imp in &bundle.main.imports {
        writeln!(s, "# source {:?} as {}", imp.path, imp.namespace).unwrap();
    }
    for (ns, funcs) in &bundle.namespaces {
        writeln!(s, "--FUNCTIONS namespace {ns}: {} functions", funcs.len()).unwrap();
    }
    for f in &bundle.main.functions {
        writeln!(
            s,
            "--FUNCTION {} ({} params, {} returns, {} stmts)",
            f.name,
            f.params.len(),
            f.returns.len(),
            f.body.len()
        )
        .unwrap();
        explain_stmts(&f.body, 1, &mut s);
    }
    writeln!(s, "--MAIN ({} stmts)", bundle.main.body.len()).unwrap();
    explain_stmts(&bundle.main.body, 1, &mut s);
    s
}

fn explain_stmts(stmts: &[Stmt], depth: usize, s: &mut String) {
    let ind = "  ".repeat(depth);
    for st in stmts {
        match st {
            Stmt::Assign { target, value, .. } => {
                let tgt = match target {
                    AssignTarget::Var(n) => n.clone(),
                    AssignTarget::Indexed { name, .. } => format!("{name}[...]"),
                };
                writeln!(s, "{ind}ASSIGN {tgt} <- {}", print_expr(value)).unwrap();
                for (expr, count) in cse_candidates(value) {
                    writeln!(s, "{ind}  ^ CSE candidate x{count}: {expr}").unwrap();
                }
            }
            Stmt::MultiAssign { targets, value, .. } => {
                writeln!(s, "{ind}MASSIGN [{}] <- {}", targets.join(","), print_expr(value))
                    .unwrap();
            }
            Stmt::If { then_branch, else_branch, cond, .. } => {
                writeln!(s, "{ind}IF {}", print_expr(cond)).unwrap();
                explain_stmts(then_branch, depth + 1, s);
                if !else_branch.is_empty() {
                    writeln!(s, "{ind}ELSE").unwrap();
                    explain_stmts(else_branch, depth + 1, s);
                }
            }
            Stmt::For { var, body, .. } => {
                writeln!(s, "{ind}FOR {var}").unwrap();
                explain_stmts(body, depth + 1, s);
            }
            Stmt::ParFor { var, body, opts, .. } => {
                writeln!(
                    s,
                    "{ind}PARFOR {var} (check={}, par={}, mode={})",
                    opts.check,
                    opts.par,
                    if opts.mode.is_empty() { "auto" } else { &opts.mode }
                )
                .unwrap();
                explain_stmts(body, depth + 1, s);
            }
            Stmt::While { cond, body, .. } => {
                writeln!(s, "{ind}WHILE {}", print_expr(cond)).unwrap();
                explain_stmts(body, depth + 1, s);
            }
            Stmt::ExprStmt { expr, .. } => {
                writeln!(s, "{ind}EXPR {}", print_expr(expr)).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;

    #[test]
    fn explain_renders_structure() {
        let bundle = Bundle {
            main: parse(
                "s = 0\nfor (i in 1:3) { s = s + i }\nparfor (j in 1:4, par=2) { P = j }",
            )
            .unwrap(),
            namespaces: Default::default(),
        };
        let out = explain_bundle(&bundle, &SystemConfig::default());
        assert!(out.contains("--MAIN (3 stmts)"));
        assert!(out.contains("FOR i"));
        assert!(out.contains("PARFOR j (check=true, par=2, mode=auto)"));
        assert!(out.contains("ASSIGN s <- (s + i)"));
    }

    #[test]
    fn explain_flags_cse() {
        let bundle = Bundle {
            main: parse("y = exp(q * 2) + exp(q * 2)").unwrap(),
            namespaces: Default::default(),
        };
        let out = explain_bundle(&bundle, &SystemConfig::default());
        assert!(out.contains("CSE candidate x2"), "{out}");
    }
}
