//! Static rewrites (the HOP-level simplifications of SystemML's
//! compiler): constant folding, common-subexpression detection, and
//! matrix-multiplication chain reordering.

use std::collections::HashMap;

use crate::dml::ast::*;

/// Fold scalar-literal subtrees: `(1+2)*x` → `3*x`, `-(2^3)` → `-8`.
/// Semantics-preserving for IEEE doubles because DML evaluates eagerly.
pub fn fold_constants(e: &Expr) -> Expr {
    match e {
        Expr::Binary { op, lhs, rhs, pos } => {
            let l = fold_constants(lhs);
            let r = fold_constants(rhs);
            if let (Some(a), Some(b)) = (literal_of(&l), literal_of(&r)) {
                if let Some(v) = eval_scalar(*op, a, b) {
                    return num_expr(v, *pos);
                }
            }
            Expr::Binary { op: *op, lhs: Box::new(l), rhs: Box::new(r), pos: *pos }
        }
        Expr::Unary { op, operand, pos } => {
            let o = fold_constants(operand);
            if let Some(a) = literal_of(&o) {
                match op {
                    AstUnOp::Neg => return num_expr(-a, *pos),
                    AstUnOp::Not => return Expr::Bool(a == 0.0, *pos),
                }
            }
            Expr::Unary { op: *op, operand: Box::new(o), pos: *pos }
        }
        Expr::Call { namespace, name, args, pos } => Expr::Call {
            namespace: namespace.clone(),
            name: name.clone(),
            args: args
                .iter()
                .map(|a| Arg { name: a.name.clone(), value: fold_constants(&a.value) })
                .collect(),
            pos: *pos,
        },
        Expr::Index { base, rows, cols, pos } => Expr::Index {
            base: Box::new(fold_constants(base)),
            rows: fold_range(rows),
            cols: fold_range(cols),
            pos: *pos,
        },
        Expr::List(items, pos) => {
            Expr::List(items.iter().map(fold_constants).collect(), *pos)
        }
        other => other.clone(),
    }
}

fn fold_range(r: &IndexRange) -> IndexRange {
    match r {
        IndexRange::All => IndexRange::All,
        IndexRange::Single(e) => IndexRange::Single(Box::new(fold_constants(e))),
        IndexRange::Range(a, b) => {
            IndexRange::Range(Box::new(fold_constants(a)), Box::new(fold_constants(b)))
        }
    }
}

fn literal_of(e: &Expr) -> Option<f64> {
    match e {
        Expr::Num(v, _) => Some(*v),
        Expr::Int(v, _) => Some(*v as f64),
        Expr::Bool(b, _) => Some(*b as i32 as f64),
        _ => None,
    }
}

fn num_expr(v: f64, pos: Pos) -> Expr {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        Expr::Int(v as i64, pos)
    } else {
        Expr::Num(v, pos)
    }
}

fn eval_scalar(op: AstBinOp, a: f64, b: f64) -> Option<f64> {
    Some(match op {
        AstBinOp::Add => a + b,
        AstBinOp::Sub => a - b,
        AstBinOp::Mul => a * b,
        AstBinOp::Div => {
            if b == 0.0 {
                return None; // preserve the runtime inf/nan semantics visibly
            }
            a / b
        }
        AstBinOp::Pow => a.powf(b),
        AstBinOp::Mod => a - (a / b).floor() * b,
        AstBinOp::IntDiv => (a / b).floor(),
        _ => return None, // comparisons/logicals stay for readability
    })
}

/// Apply constant folding to every expression in a program.
pub fn fold_program(prog: &mut Program) {
    for f in &mut prog.functions {
        fold_stmts(&mut f.body);
    }
    fold_stmts(&mut prog.body);
}

fn fold_stmts(stmts: &mut [Stmt]) {
    for s in stmts {
        match s {
            Stmt::Assign { value, .. } => *value = fold_constants(value),
            Stmt::MultiAssign { value, .. } => *value = fold_constants(value),
            Stmt::If { cond, then_branch, else_branch, .. } => {
                *cond = fold_constants(cond);
                fold_stmts(then_branch);
                fold_stmts(else_branch);
            }
            Stmt::For { range, body, .. } | Stmt::ParFor { range, body, .. } => {
                range.from = Box::new(fold_constants(&range.from));
                range.to = Box::new(fold_constants(&range.to));
                if let Some(st) = &range.step {
                    range.step = Some(Box::new(fold_constants(st)));
                }
                fold_stmts(body);
            }
            Stmt::While { cond, body, .. } => {
                *cond = fold_constants(cond);
                fold_stmts(body);
            }
            Stmt::ExprStmt { expr, .. } => *expr = fold_constants(expr),
        }
    }
}

/// Count syntactically-identical subexpressions (CSE opportunities) in an
/// expression tree — surfaced by `sysml explain`.
pub fn cse_candidates(e: &Expr) -> Vec<(String, usize)> {
    let mut counts: HashMap<String, usize> = HashMap::new();
    fn walk(e: &Expr, counts: &mut HashMap<String, usize>) {
        let key = print_expr(e);
        // Only count non-trivial subtrees.
        if matches!(e, Expr::Binary { .. } | Expr::Call { .. } | Expr::Index { .. }) {
            *counts.entry(key).or_insert(0) += 1;
        }
        match e {
            Expr::Binary { lhs, rhs, .. } => {
                walk(lhs, counts);
                walk(rhs, counts);
            }
            Expr::Unary { operand, .. } => walk(operand, counts),
            Expr::Call { args, .. } => {
                for a in args {
                    walk(&a.value, counts);
                }
            }
            Expr::Index { base, .. } => walk(base, counts),
            Expr::List(items, _) => {
                for i in items {
                    walk(i, counts);
                }
            }
            _ => {}
        }
    }
    walk(e, &mut counts);
    let mut out: Vec<(String, usize)> =
        counts.into_iter().filter(|(_, c)| *c > 1).collect();
    out.sort();
    out
}

/// Pretty-print an expression (stable key for CSE + explain output).
pub fn print_expr(e: &Expr) -> String {
    match e {
        Expr::Num(v, _) => format!("{v}"),
        Expr::Int(v, _) => format!("{v}"),
        Expr::Str(s, _) => format!("{s:?}"),
        Expr::Bool(b, _) => format!("{b}"),
        Expr::Var(n, _) => n.clone(),
        Expr::List(items, _) => {
            format!("[{}]", items.iter().map(print_expr).collect::<Vec<_>>().join(","))
        }
        Expr::Unary { op, operand, .. } => match op {
            AstUnOp::Neg => format!("-({})", print_expr(operand)),
            AstUnOp::Not => format!("!({})", print_expr(operand)),
        },
        Expr::Binary { op, lhs, rhs, .. } => {
            let o = match op {
                AstBinOp::Add => "+",
                AstBinOp::Sub => "-",
                AstBinOp::Mul => "*",
                AstBinOp::Div => "/",
                AstBinOp::Pow => "^",
                AstBinOp::Mod => "%%",
                AstBinOp::IntDiv => "%/%",
                AstBinOp::MatMul => "%*%",
                AstBinOp::Eq => "==",
                AstBinOp::Neq => "!=",
                AstBinOp::Lt => "<",
                AstBinOp::Le => "<=",
                AstBinOp::Gt => ">",
                AstBinOp::Ge => ">=",
                AstBinOp::And => "&",
                AstBinOp::Or => "|",
            };
            format!("({} {o} {})", print_expr(lhs), print_expr(rhs))
        }
        Expr::Call { namespace, name, args, .. } => {
            let ns = namespace.as_ref().map(|n| format!("{n}::")).unwrap_or_default();
            let a: Vec<String> = args
                .iter()
                .map(|x| match &x.name {
                    Some(n) => format!("{n}={}", print_expr(&x.value)),
                    None => print_expr(&x.value),
                })
                .collect();
            format!("{ns}{name}({})", a.join(", "))
        }
        Expr::Index { base, rows, cols, .. } => {
            let pr = |r: &IndexRange| match r {
                IndexRange::All => String::new(),
                IndexRange::Single(e) => print_expr(e),
                IndexRange::Range(a, b) => format!("{}:{}", print_expr(a), print_expr(b)),
            };
            format!("{}[{},{}]", print_expr(base), pr(rows), pr(cols))
        }
    }
}

/// Optimal matrix-chain parenthesization (classic DP, SystemML's
/// `RewriteMatrixMultChainOptimization`): given the dims d0×d1, d1×d2, ...
/// returns (min FLOPs, split table). `split[i][j]` is the index after
/// which the optimal plan splits the product of matrices i..=j; the
/// planner uses it to rebuild the expression tree.
pub fn matmult_chain_split(dims: &[usize]) -> (u64, Vec<Vec<usize>>) {
    let n = dims.len() - 1; // number of matrices
    assert!(n >= 1);
    let mut cost = vec![vec![0u64; n]; n];
    let mut split = vec![vec![0usize; n]; n];
    for len in 2..=n {
        for i in 0..=(n - len) {
            let j = i + len - 1;
            cost[i][j] = u64::MAX;
            for k in i..j {
                // Saturating: the planner feeds declared (possibly
                // adversarially large) shapes through this DP.
                let term = 2u64
                    .saturating_mul(dims[i] as u64)
                    .saturating_mul(dims[k + 1] as u64)
                    .saturating_mul(dims[j + 1] as u64);
                let c = cost[i][k].saturating_add(cost[k + 1][j]).saturating_add(term);
                if c < cost[i][j] {
                    cost[i][j] = c;
                    split[i][j] = k;
                }
            }
        }
    }
    (cost[0][n - 1], split)
}

/// Render an optimal split table as a parenthesization string.
pub fn render_chain_split(split: &[Vec<usize>], i: usize, j: usize) -> String {
    if i == j {
        format!("M{i}")
    } else {
        let k = split[i][j];
        format!(
            "({} {})",
            render_chain_split(split, i, k),
            render_chain_split(split, k + 1, j)
        )
    }
}

/// Like [`matmult_chain_split`] but renders the plan as a string
/// (`((M0 M1) M2)`), for explain output and tests.
pub fn matmult_chain_order(dims: &[usize]) -> (u64, String) {
    let (cost, split) = matmult_chain_split(dims);
    let n = dims.len() - 1;
    (cost, render_chain_split(&split, 0, n - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;

    fn first_expr(src: &str) -> Expr {
        match parse(src).unwrap().body.into_iter().next().unwrap() {
            Stmt::Assign { value, .. } => value,
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn folds_scalar_arithmetic() {
        let e = fold_constants(&first_expr("y = (1 + 2) * 4 - 2^3"));
        assert_eq!(print_expr(&e), "4");
        let e2 = fold_constants(&first_expr("y = x * (3 - 1)"));
        assert_eq!(print_expr(&e2), "(x * 2)");
    }

    #[test]
    fn folding_preserves_division_by_zero() {
        let e = fold_constants(&first_expr("y = 1 / 0"));
        assert!(matches!(e, Expr::Binary { .. }), "1/0 must stay for runtime semantics");
    }

    #[test]
    fn folds_inside_calls_and_indexing() {
        let e = fold_constants(&first_expr("y = sum(X[1 + 1, 2 * 3])"));
        assert_eq!(print_expr(&e), "sum(X[2,6])");
    }

    #[test]
    fn cse_detects_repeats() {
        let e = first_expr("y = exp(X) / (1 + exp(X))");
        let cands = cse_candidates(&e);
        assert!(cands.iter().any(|(k, c)| k == "exp(X)" && *c == 2), "{cands:?}");
    }

    #[test]
    fn matmult_chain_classic_case() {
        // dims 10x30, 30x5, 5x60: optimal ((M0 M1) M2) = 2*(1500 + 3000).
        let (cost, plan) = matmult_chain_order(&[10, 30, 5, 60]);
        assert_eq!(cost, 2 * (10 * 30 * 5 + 10 * 5 * 60) as u64);
        assert_eq!(plan, "((M0 M1) M2)");
    }

    #[test]
    fn matmult_chain_prefers_vector_end() {
        // A(1000x1000) B(1000x1000) v(1000x1): right-to-left wins.
        let (_, plan) = matmult_chain_order(&[1000, 1000, 1000, 1]);
        assert_eq!(plan, "(M0 (M1 M2))");
    }

    #[test]
    fn fold_program_rewrites_in_place() {
        let mut prog = parse("f = function(int n) return (int y) { y = n + (2*3) }\nz = 1 + 1").unwrap();
        fold_program(&mut prog);
        match &prog.body[0] {
            Stmt::Assign { value, .. } => assert_eq!(print_expr(value), "2"),
            other => panic!("unexpected {other:?}"),
        }
        match &prog.functions[0].body[0] {
            Stmt::Assign { value, .. } => assert_eq!(print_expr(value), "(n + 6)"),
            other => panic!("unexpected {other:?}"),
        }
    }
}
