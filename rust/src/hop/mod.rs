//! The compiler layer (HOPs): typed operator DAGs, memory/sparsity
//! estimates, algebraic rewrites, execution-type plan compilation
//! (CP / DIST / ACCEL selection of paper §3), and plan explanation.
//!
//! Compilation pipeline: parse → validate → HOP DAG ([`dag`]) → rewrites
//! ([`rewrite`], applied at both AST and DAG level) → ExecType plan
//! ([`plan`]) → hybrid runtime (`runtime::interp::dispatch`).

pub mod dag;
pub mod estimate;
pub mod explain;
pub mod plan;
pub mod rewrite;
