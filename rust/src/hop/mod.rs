//! The compiler layer (HOPs): memory/sparsity estimates, algebraic
//! rewrites, plan explanation, and (via the interpreter's dispatch) the
//! CP / DIST / ACCEL execution-type selection of paper §3.

pub mod estimate;
pub mod explain;
pub mod rewrite;
