//! Worst-case memory and sparsity estimates — the inputs to the
//! compiler's execution-type decisions (paper §3: an operation runs on the
//! driver only if inputs, intermediates and output fit in the driver JVM;
//! on the GPU only if they fit in device memory).

use crate::runtime::matrix::{Matrix, SPARSITY_TURN_POINT};

/// Bytes for a dense block of the given shape. Saturating: planning over
/// adversarially large declared shapes must not overflow/panic — a
/// saturated estimate simply never fits any budget.
pub fn dense_size(rows: usize, cols: usize) -> usize {
    rows.saturating_mul(cols).saturating_mul(8).saturating_add(48)
}

/// Bytes for a sparse (CSR) block with the given nnz (saturating).
pub fn sparse_size(rows: usize, nnz: usize) -> usize {
    nnz.saturating_mul(12)
        .saturating_add(rows.saturating_add(1).saturating_mul(8))
        .saturating_add(48)
}

/// Worst-case size of a matrix with given shape and sparsity estimate.
/// Overflow-safe for huge symbolic dims (saturates at `usize::MAX`).
pub fn estimate_size(rows: usize, cols: usize, sparsity: f64) -> usize {
    let cells = rows.saturating_mul(cols);
    if sparsity < SPARSITY_TURN_POINT && cells >= 1024 {
        // f64 product of huge dims can exceed usize::MAX; clamp before
        // the cast (`as usize` would saturate too, but only since Rust
        // 1.45 — be explicit).
        let nnz_f = (sparsity * rows as f64 * cols as f64).ceil();
        let nnz = if nnz_f >= usize::MAX as f64 { usize::MAX } else { nnz_f.max(0.0) as usize };
        sparse_size(rows, nnz)
    } else {
        dense_size(rows, cols)
    }
}

/// Worst-case output sparsity of matmult (SystemML's estimator):
/// 1 - (1 - sA·sB)^k, the probability a cell has at least one
/// contributing nonzero product.
pub fn matmult_output_sparsity(sa: f64, sb: f64, k: usize) -> f64 {
    let p = (sa * sb).clamp(0.0, 1.0);
    1.0 - (1.0 - p).powi(k.min(10_000) as i32)
}

/// Total memory estimate for running `a %*% b` in CP: both inputs plus the
/// (worst-case) output must fit. The parts form is shared by the runtime
/// dispatch, whose operands may be blocked handles rather than driver
/// matrices — keeping planner and runtime on one estimator.
#[allow(clippy::too_many_arguments)]
pub fn matmult_mem_parts(
    a_bytes: usize,
    a_rows: usize,
    a_cols: usize,
    a_sparsity: f64,
    b_bytes: usize,
    b_cols: usize,
    b_sparsity: f64,
) -> usize {
    let out_sp = matmult_output_sparsity(a_sparsity, b_sparsity, a_cols);
    a_bytes
        .saturating_add(b_bytes)
        .saturating_add(estimate_size(a_rows, b_cols, out_sp))
}

/// [`matmult_mem_parts`] over driver matrices.
pub fn matmult_mem_estimate(a: &Matrix, b: &Matrix) -> usize {
    matmult_mem_parts(
        a.size_in_bytes(),
        a.rows(),
        a.cols(),
        a.sparsity(),
        b.size_in_bytes(),
        b.cols(),
        b.sparsity(),
    )
}

/// Memory estimate for an elementwise binary op (parts form shared with
/// the runtime dispatch).
pub fn binary_mem_parts(a_bytes: usize, b_bytes: usize, rows: usize, cols: usize) -> usize {
    a_bytes.saturating_add(b_bytes).saturating_add(estimate_size(rows, cols, 1.0))
}

/// [`binary_mem_parts`] over driver matrices.
pub fn binary_mem_estimate(a: &Matrix, b: &Matrix) -> usize {
    binary_mem_parts(a.size_in_bytes(), b.size_in_bytes(), a.rows(), a.cols())
}

/// Memory estimate for conv2d forward in CP, including the im2col
/// intermediate ((P·Q)×(C·R·S) per image).
pub fn conv2d_mem_estimate(
    n: usize,
    chw: usize,
    krs_filter: usize,
    pq: usize,
    crs: usize,
    k: usize,
) -> usize {
    dense_size(n, chw) + dense_size(k, krs_filter) + dense_size(pq, crs) + dense_size(n, k * pq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_vs_sparse_size() {
        assert!(sparse_size(100, 100) < dense_size(100, 100));
        // At full density sparse is bigger (12 vs 8 bytes per cell).
        assert!(sparse_size(100, 100 * 100) > dense_size(100, 100));
    }

    #[test]
    fn matmult_sparsity_estimator_monotone() {
        let s1 = matmult_output_sparsity(0.01, 0.01, 100);
        let s2 = matmult_output_sparsity(0.1, 0.1, 100);
        assert!(s1 < s2);
        assert!(matmult_output_sparsity(1.0, 1.0, 5) == 1.0);
        assert!(matmult_output_sparsity(0.0, 0.5, 5) == 0.0);
    }

    #[test]
    fn huge_shapes_saturate_instead_of_panicking() {
        // rows * cols would overflow usize; the estimator must saturate.
        let huge = estimate_size(usize::MAX / 2, usize::MAX / 2, 1.0);
        assert_eq!(huge, usize::MAX);
        let huge_sparse = estimate_size(usize::MAX / 2, usize::MAX / 2, 0.001);
        assert_eq!(huge_sparse, usize::MAX);
        assert_eq!(dense_size(usize::MAX, usize::MAX), usize::MAX);
        assert_eq!(sparse_size(usize::MAX, usize::MAX), usize::MAX);
    }

    #[test]
    fn matmult_estimate_includes_output() {
        let a = Matrix::filled(100, 50, 1.0);
        let b = Matrix::filled(50, 200, 1.0);
        let est = matmult_mem_estimate(&a, &b);
        assert!(est >= dense_size(100, 50) + dense_size(50, 200) + dense_size(100, 200));
    }
}
