//! systemml — a Rust reproduction of "Deep Learning with Apache SystemML"
//! (Pansare et al., 2018).
//!
//! The crate implements a declarative machine-learning system in three layers:
//!
//! * **L3 (this crate)** — the DML language (lexer/parser/AST), a cost-based
//!   compiler that produces hybrid single-node / distributed / accelerator
//!   execution plans, a matrix runtime with dense and sparse physical
//!   operators, a task-parallel `parfor` optimizer/executor, a simulated
//!   blocked distributed backend, and a PJRT accelerator backend.
//! * **L2 (python/compile/model.py)** — JAX compute graphs for the
//!   compute-intensive fused operators, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (tiled matmul,
//!   im2col convolution) called by the L2 graphs.
//!
//! The public entry point is [`api::MLContext`], mirroring SystemML's
//! MLContext API: create a context, bind inputs, execute a DML
//! [`api::Script`], fetch outputs.

pub mod api;
pub mod conf;
pub mod dml;
pub mod hop;
pub mod nn;
pub mod runtime;
pub mod util;

pub use api::{MLContext, Script};
pub use conf::SystemConfig;
pub use util::error::{DmlError, Result};
