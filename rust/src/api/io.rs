//! Matrix I/O: CSV and a simple binary block format (SystemML's
//! read/write with format="csv" / "binary").
//!
//! Every error raised here — open/create failures included — names the
//! offending path, so a failing `read()` deep inside a script is
//! diagnosable from the message alone.

use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

use crate::runtime::matrix::{DenseMatrix, Matrix};
use crate::util::error::{DmlError, Result};

/// Wrap an I/O-layer error with the file path it concerns.
fn at_path(path: &Path, what: &str, e: impl std::fmt::Display) -> DmlError {
    DmlError::rt(format!("{what} '{}': {e}", path.display()))
}

/// Write a matrix as CSV.
pub fn write_csv(m: &Matrix, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let f = std::fs::File::create(path).map_err(|e| at_path(path, "cannot create csv", e))?;
    let mut w = BufWriter::new(f);
    let d = m.to_dense();
    for r in 0..d.rows {
        let row: Vec<String> = d.row(r).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(",")).map_err(|e| at_path(path, "csv write failed", e))?;
    }
    Ok(())
}

/// Read a CSV matrix.
pub fn read_csv(path: impl AsRef<Path>) -> Result<Matrix> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).map_err(|e| at_path(path, "cannot open csv", e))?;
    let reader = std::io::BufReader::new(f);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for line in reader.lines() {
        let line = line.map_err(|e| at_path(path, "csv read failed", e))?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Vec<f64> = line
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|e| at_path(path, &format!("csv parse error at row {rows}"), e))?;
        if rows == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            return Err(DmlError::rt(format!(
                "csv '{}': row {rows} has {} columns, expected {cols}",
                path.display(),
                vals.len()
            )));
        }
        data.extend(vals);
        rows += 1;
    }
    Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)?).examine_and_convert())
}

/// Binary format: magic, dims, then row-major f64 little-endian.
const MAGIC: &[u8; 8] = b"SYSMLMB1";

/// Write the binary block format.
pub fn write_binary(m: &Matrix, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let f = std::fs::File::create(path).map_err(|e| at_path(path, "cannot create binary", e))?;
    let mut w = BufWriter::new(f);
    let write_err = |e| at_path(path, "binary write failed", e);
    w.write_all(MAGIC).map_err(write_err)?;
    w.write_all(&(m.rows() as u64).to_le_bytes()).map_err(write_err)?;
    w.write_all(&(m.cols() as u64).to_le_bytes()).map_err(write_err)?;
    for v in m.to_row_major_vec() {
        w.write_all(&v.to_le_bytes()).map_err(write_err)?;
    }
    Ok(())
}

/// Read the binary block format.
pub fn read_binary(path: impl AsRef<Path>) -> Result<Matrix> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).map_err(|e| at_path(path, "cannot open binary", e))?;
    let read_err = |e| at_path(path, "binary read failed", e);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).map_err(read_err)?;
    if &magic != MAGIC {
        return Err(DmlError::rt(format!(
            "'{}' is not a systemml binary matrix file",
            path.display()
        )));
    }
    let mut dims = [0u8; 16];
    f.read_exact(&mut dims).map_err(read_err)?;
    let rows = u64::from_le_bytes(dims[..8].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(dims[8..].try_into().unwrap()) as usize;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).map_err(read_err)?;
    if buf.len() != rows * cols * 8 {
        return Err(DmlError::rt(format!(
            "binary matrix '{}': expected {} bytes of data, found {}",
            path.display(),
            rows * cols * 8,
            buf.len()
        )));
    }
    let data: Vec<f64> =
        buf.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
    Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)?).examine_and_convert())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sysml_io_{}_{name}", std::process::id()))
    }

    #[test]
    fn csv_roundtrip() {
        let m = Matrix::from_rows(&[&[1.5, -2.0, 0.0], &[3.25, 4.0, 1e-3]]);
        let p = tmpfile("a.csv");
        write_csv(&m, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let m = Matrix::from_rows(&[&[std::f64::consts::PI, f64::MIN_POSITIVE], &[-0.0, 1e300]]);
        let p = tmpfile("b.bin");
        write_binary(&m, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(back.to_row_major_vec(), m.to_row_major_vec());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpfile("bad.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmpfile("bad.bin");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn io_errors_name_the_path() {
        let p = std::env::temp_dir().join("sysml_io_definitely_missing.csv");
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("sysml_io_definitely_missing.csv"), "got: {err}");
        let err = read_binary(&p).unwrap_err().to_string();
        assert!(err.contains("sysml_io_definitely_missing.csv"), "got: {err}");
    }
}
