//! Matrix I/O: CSV and a simple binary block format (SystemML's
//! read/write with format="csv" / "binary").

use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

use crate::runtime::matrix::{DenseMatrix, Matrix};
use crate::util::error::{DmlError, Result};

/// Write a matrix as CSV.
pub fn write_csv(m: &Matrix, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let d = m.to_dense();
    for r in 0..d.rows {
        let row: Vec<String> = d.row(r).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a CSV matrix.
pub fn read_csv(path: impl AsRef<Path>) -> Result<Matrix> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Vec<f64> = line
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|e| DmlError::rt(format!("csv parse error at row {rows}: {e}")))?;
        if rows == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            return Err(DmlError::rt(format!(
                "csv: row {rows} has {} columns, expected {cols}",
                vals.len()
            )));
        }
        data.extend(vals);
        rows += 1;
    }
    Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)?).examine_and_convert())
}

/// Binary format: magic, dims, then row-major f64 little-endian.
const MAGIC: &[u8; 8] = b"SYSMLMB1";

/// Write the binary block format.
pub fn write_binary(m: &Matrix, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.cols() as u64).to_le_bytes())?;
    for v in m.to_row_major_vec() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary block format.
pub fn read_binary(path: impl AsRef<Path>) -> Result<Matrix> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(DmlError::rt("not a systemml binary matrix file".to_string()));
    }
    let mut dims = [0u8; 16];
    f.read_exact(&mut dims)?;
    let rows = u64::from_le_bytes(dims[..8].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(dims[8..].try_into().unwrap()) as usize;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    if buf.len() != rows * cols * 8 {
        return Err(DmlError::rt(format!(
            "binary matrix: expected {} bytes of data, found {}",
            rows * cols * 8,
            buf.len()
        )));
    }
    let data: Vec<f64> =
        buf.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
    Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)?).examine_and_convert())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sysml_io_{}_{name}", std::process::id()))
    }

    #[test]
    fn csv_roundtrip() {
        let m = Matrix::from_rows(&[&[1.5, -2.0, 0.0], &[3.25, 4.0, 1e-3]]);
        let p = tmpfile("a.csv");
        write_csv(&m, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let m = Matrix::from_rows(&[&[std::f64::consts::PI, f64::MIN_POSITIVE], &[-0.0, 1e300]]);
        let p = tmpfile("b.bin");
        write_binary(&m, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(back.to_row_major_vec(), m.to_row_major_vec());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpfile("bad.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmpfile("bad.bin");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
