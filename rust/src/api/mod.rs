//! MLContext-style public API (paper §2): build a [`Script`], bind inputs,
//! execute, fetch outputs.
//!
//! ```no_run
//! use systemml::api::{MLContext, Script};
//! use systemml::runtime::matrix::Matrix;
//!
//! let ctx = MLContext::new();
//! let script = Script::from_str("Y = X %*% t(X)\ns = sum(Y)")
//!     .input("X", Matrix::filled(4, 4, 1.0))
//!     .output("s");
//! let results = ctx.execute(script).unwrap();
//! assert_eq!(results.double("s").unwrap(), 64.0);
//! ```
//!
//! # Session semantics
//!
//! An [`MLContext`] is a **session**: every `execute` call runs against
//! the same simulated cluster (created lazily from the context's config
//! on first use), and each script's *requested outputs* are retained by
//! name. The next `execute` sees them as pre-bound inputs — explicit
//! [`Script`] inputs win on a name clash. That makes the resident-state
//! training loop compose across scripts with **zero collects**:
//!
//! * a training script's blocked weight outputs (`W1`, `vW1`, ...) stay
//!   resident on the cluster between calls — [`Results::blocked`] and
//!   [`Results::value`] hand them back without forcing, and the session
//!   carries them into the next script (another epoch, or a scoring
//!   call) with no blockify and no collect;
//! * [`Results::matrix`] **forces** the value to the driver (a collect
//!   for multi-block values; free for replicated allreduce results) —
//!   use it only when a driver-local copy is actually wanted;
//! * [`Script::input_value`] binds any runtime value, including a
//!   `Value::Blocked` handle from a previous execution (valid only with
//!   the context that produced it — handles are tied to the session
//!   cluster);
//! * [`MLContext::clear_session`] drops the retained values (and with
//!   them the resident partitions' storage reservation).
//!
//! Config changes made after the first `execute` do not rebuild the
//! session cluster — create a new context for a new cluster shape.

pub mod io;

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use crate::conf::SystemConfig;
use crate::dml::parser::parse;
use crate::dml::validate::{self, Bundle};
use crate::hop::dag::ShapeInfo;
use crate::hop::plan::{compile_plan, Plan};
use crate::runtime::dist::{BlockedHandle, Cluster};
use crate::runtime::interp::registry::build_bundle;
use crate::runtime::interp::{build_cluster_with_stats, Interpreter, Scope, Value};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::stats::{Stats, StatsReport};

/// A DML script plus its input bindings and requested outputs.
#[derive(Clone, Debug, Default)]
pub struct Script {
    pub source: String,
    pub inputs: HashMap<String, Value>,
    pub outputs: Vec<String>,
}

impl Script {
    /// Script from DML source text.
    pub fn from_str(src: impl Into<String>) -> Script {
        Script { source: src.into(), inputs: HashMap::new(), outputs: Vec::new() }
    }

    /// Script from a .dml file.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Script> {
        Ok(Script::from_str(std::fs::read_to_string(path)?))
    }

    /// Bind a matrix input.
    pub fn input(mut self, name: &str, m: Matrix) -> Script {
        self.inputs.insert(name.to_string(), Value::Matrix(m));
        self
    }

    /// Bind a scalar input.
    pub fn input_scalar(mut self, name: &str, v: f64) -> Script {
        self.inputs.insert(name.to_string(), Value::Double(v));
        self
    }

    /// Bind a string input.
    pub fn input_str(mut self, name: &str, v: &str) -> Script {
        self.inputs.insert(name.to_string(), Value::Str(v.to_string()));
        self
    }

    /// Bind any runtime value — including a `Value::Blocked` handle taken
    /// from a previous execution's [`Results::blocked`]. The handle stays
    /// cluster-resident; binding it never forces a collect. Blocked
    /// handles are only valid with the [`MLContext`] that produced them.
    pub fn input_value(mut self, name: &str, v: Value) -> Script {
        self.inputs.insert(name.to_string(), v);
        self
    }

    /// Request an output variable.
    pub fn output(mut self, name: &str) -> Script {
        self.outputs.push(name.to_string());
        self
    }
}

/// Execution results: the requested outputs plus captured print output.
#[derive(Clone, Debug, Default)]
pub struct Results {
    values: HashMap<String, Value>,
    pub stdout: Vec<String>,
}

impl Results {
    pub fn get(&self, name: &str) -> Option<&Value> {
        self.values.get(name)
    }

    /// The raw output value, **without** forcing a collect: blocked
    /// outputs come back as `Value::Blocked` handles that stay resident
    /// on the cluster.
    pub fn value(&self, name: &str) -> Result<&Value> {
        self.values
            .get(name)
            .ok_or_else(|| DmlError::rt(format!("no output '{name}'")))
    }

    /// The output as a cluster-resident blocked handle, **without**
    /// forcing a collect. Errors if the output is missing or was
    /// driver-resident (use [`Results::matrix`] for those).
    pub fn blocked(&self, name: &str) -> Result<BlockedHandle> {
        match self.value(name)? {
            Value::Blocked(h) => Ok(h.clone()),
            v => Err(DmlError::rt(format!(
                "output '{name}' is not blocked (found {})",
                v.type_name()
            ))),
        }
    }

    /// The output as a driver-local matrix. **Forces** blocked values:
    /// multi-block outputs pay a collect; replicated (allreduce) outputs
    /// materialize free. Prefer [`Results::blocked`] to keep training
    /// state resident.
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        Ok(self.value(name)?.as_matrix()?.clone())
    }

    pub fn double(&self, name: &str) -> Result<f64> {
        self.value(name)?.as_double()
    }
}

/// The MLContext: configuration + execution entry point. A context is a
/// **session** — see the module docs: one lazily-created cluster shared
/// by every `execute`, and requested outputs retained by name as inputs
/// for the next script.
#[derive(Default)]
pub struct MLContext {
    pub config: SystemConfig,
    /// Echo DML print() output to stdout.
    pub echo: bool,
    /// The session cluster, created from `config` on first execute and
    /// reused for every subsequent script so blocked values stay valid
    /// across calls.
    cluster: RefCell<Option<Arc<Cluster>>>,
    /// Values retained from previous executions' requested outputs;
    /// seeded into the next script's scope (explicit inputs win).
    session: RefCell<HashMap<String, Value>>,
    /// The session's statistics/trace registry (SystemML `-stats`),
    /// created lazily from `config` like the cluster; `None` when both
    /// stats knobs are off.
    stats: RefCell<Option<Arc<Stats>>>,
}

impl MLContext {
    /// Context with default configuration.
    pub fn new() -> MLContext {
        MLContext::with_config(SystemConfig::default())
    }

    /// Context with explicit configuration.
    pub fn with_config(config: SystemConfig) -> MLContext {
        MLContext {
            config,
            echo: false,
            cluster: RefCell::new(None),
            session: RefCell::new(HashMap::new()),
            stats: RefCell::new(None),
        }
    }

    /// The session's stats registry, building it from the current config
    /// on first use. `None` when both stats knobs are off — the
    /// zero-cost path.
    fn session_stats(&self) -> Option<Arc<Stats>> {
        let mut slot = self.stats.borrow_mut();
        if slot.is_none() {
            *slot = Stats::from_config(&self.config);
        }
        slot.clone()
    }

    /// The session cluster, building it from the current config on first
    /// use. `None` when distributed execution is disabled.
    fn session_cluster(&self) -> Option<Arc<Cluster>> {
        if !self.config.dist_enabled {
            return None;
        }
        let stats = self.session_stats();
        let mut slot = self.cluster.borrow_mut();
        if slot.is_none() {
            *slot = build_cluster_with_stats(&self.config, stats);
        }
        slot.clone()
    }

    /// The session cluster (building it from the current config on first
    /// use): exposes the backend's per-session accounting — collects,
    /// spills, allreduce rounds — to benchmarks and tests. `None` when
    /// distributed execution is disabled.
    pub fn cluster(&self) -> Option<Arc<Cluster>> {
        self.session_cluster()
    }

    /// A value retained in the session (a previous execution's output).
    pub fn session_value(&self, name: &str) -> Option<Value> {
        self.session.borrow().get(name).cloned()
    }

    /// Drop all session-retained values, releasing their cluster-resident
    /// partitions' storage reservation.
    pub fn clear_session(&self) {
        self.session.borrow_mut().clear();
    }

    /// SystemML's `-stats` output for the session so far: the top-10
    /// heavy-hitter instruction table and per-worker utilization /
    /// skew. A one-line placeholder when statistics are disabled.
    pub fn statistics(&self) -> String {
        match self.session_stats() {
            Some(s) => s.render(10),
            None => "SystemML Statistics: disabled (set stats_enabled)\n".to_string(),
        }
    }

    /// Structured statistics snapshot for programmatic consumers
    /// (benches, tests), or `None` when statistics are disabled.
    pub fn stats(&self) -> Option<StatsReport> {
        self.session_stats().map(|s| s.report())
    }

    /// Clear the heavy-hitter table and per-worker counters (the trace
    /// file, if any, keeps appending).
    pub fn reset_stats(&self) {
        if let Some(s) = self.session_stats() {
            s.reset();
        }
    }

    /// Parse, validate, and plan a script without executing (SystemML
    /// `-explain`): constant folding, bundle construction, validation,
    /// then HOP-DAG lowering + ExecType plan compilation against the
    /// bound input shapes. The returned bundle reflects plan-driven AST
    /// rewrites (e.g. matmult chain reordering).
    pub fn compile(&self, script: &Script) -> Result<Compilation> {
        self.compile_with_session(script, &self.session.borrow())
    }

    /// Compile against a session snapshot: session values and explicit
    /// inputs are both pre-defined for validation and shape inference
    /// (explicit inputs win on a name clash).
    fn compile_with_session(
        &self,
        script: &Script,
        session: &HashMap<String, Value>,
    ) -> Result<Compilation> {
        compile_source(&script.source, &self.config, session, &script.inputs)
    }

    /// Execute a script and collect its outputs. The interpreter runs
    /// against the compiled plan's per-operator ExecType placements on the
    /// shared session cluster; with `explain` enabled the annotated HOP
    /// plan is printed first. Requested outputs are retained in the
    /// session for the next script — blocked outputs stay resident.
    pub fn execute(&self, script: Script) -> Result<Results> {
        let session = self.session.borrow().clone();
        let Compilation { bundle, plan, .. } = self.compile_with_session(&script, &session)?;
        let mut interp = Interpreter::with_cluster_and_stats(
            bundle,
            self.config.clone(),
            self.session_cluster(),
            self.session_stats(),
        );
        interp.echo = self.echo;
        if self.config.explain {
            for line in plan.render().lines() {
                interp.emit(line.to_string());
            }
        }
        interp.plan = Some(Arc::new(plan));
        // Session values seed the scope; explicit script inputs win.
        let mut scope: Scope = session.into_iter().collect();
        scope.extend(script.inputs.clone());
        let run_started = std::time::Instant::now();
        if let Some(s) = &interp.stats {
            s.span_open("script", "execute");
        }
        let run_result = interp.run(scope);
        if let Some(s) = &interp.stats {
            // Balance the script span on success AND failure, then flush
            // so the trace is readable without dropping the context.
            s.span_close("script", "execute", run_started.elapsed().as_nanos() as u64);
            s.flush_trace();
        }
        let final_scope = run_result?;
        let mut out = Results { values: HashMap::new(), stdout: interp.output() };
        for name in &script.outputs {
            let v = final_scope.get(name).ok_or_else(|| {
                DmlError::rt(format!("requested output '{name}' was never assigned"))
            })?;
            out.values.insert(name.clone(), v.clone());
        }
        // Carry-over: requested outputs stay warm for the next script.
        self.session
            .borrow_mut()
            .extend(out.values.iter().map(|(k, v)| (k.clone(), v.clone())));
        Ok(out)
    }

    /// Turn this session into a scoring service
    /// ([`crate::runtime::serve::ScoreService`]): the script's inputs
    /// plus the session's retained values become the resident model
    /// (driver matrices are promoted to cluster-resident blocked handles
    /// with ONE recorded model broadcast; blocked training outputs stay
    /// where they are), `batch_input` names the variable each
    /// micro-batch is bound under (`features` columns), and the script's
    /// requested output is the scores matrix. Plans are cached inside
    /// the service per padded batch geometry — compilation happens once
    /// per distinct padded batch size, not per request.
    ///
    /// The returned service is `Sync` and detached from this context's
    /// `RefCell` state: concurrent micro-batches score against it
    /// directly while the context remains usable for further `execute`
    /// calls on the same session cluster.
    pub fn score_service(
        &self,
        script: &Script,
        batch_input: &str,
        features: usize,
    ) -> Result<crate::runtime::serve::ScoreService> {
        let cluster = self.session_cluster().ok_or_else(|| {
            DmlError::rt("score_service requires the distributed backend (dist_enabled)")
        })?;
        let session = self.session.borrow().clone();
        crate::runtime::serve::ScoreService::new(
            self.config.clone(),
            cluster,
            session,
            &script.source,
            &script.inputs,
            &script.outputs,
            batch_input,
            features,
        )
    }
}

/// Result of [`MLContext::compile`]: the validated (and plan-rewritten)
/// bundle, the compiled execution plan, and validation warnings.
#[derive(Clone, Debug)]
pub struct Compilation {
    pub bundle: Bundle,
    pub plan: Plan,
    pub warnings: Vec<String>,
}

/// The full compile pipeline (parse → constant folding → bundle →
/// validation → plan) against two layers of pre-bound values: a session
/// snapshot and explicit inputs (explicit wins on a name clash). Shared
/// by [`MLContext::compile`]/[`MLContext::execute`] and the scoring
/// service's per-geometry plan cache
/// ([`crate::runtime::serve::ScoreService`]), which compiles the same
/// scoring script once per distinct padded batch shape.
pub(crate) fn compile_source(
    source: &str,
    config: &SystemConfig,
    session: &HashMap<String, Value>,
    inputs: &HashMap<String, Value>,
) -> Result<Compilation> {
    let mut prog = parse(source)?;
    // Static rewrites (HOP-level): constant folding.
    crate::hop::rewrite::fold_program(&mut prog);
    let mut bundle = build_bundle(prog, config)?;
    let warnings = validate_with_inputs(&bundle, session.keys().chain(inputs.keys()))?;
    let mut shapes = input_shapes(session);
    shapes.extend(input_shapes(inputs));
    let plan = compile_plan(&mut bundle, &shapes, config);
    Ok(Compilation { bundle, plan, warnings })
}

/// Compile-time shapes of the bound inputs (rows/cols/sparsity for
/// matrices, scalar markers otherwise).
fn input_shapes(inputs: &HashMap<String, Value>) -> HashMap<String, ShapeInfo> {
    let mut out = HashMap::new();
    for (name, v) in inputs {
        let shape = match v {
            Value::Matrix(m) => ShapeInfo::matrix(m.rows(), m.cols(), m.sparsity()),
            Value::Blocked(h) => {
                let cells = h.rows() * h.cols();
                let sp = if cells == 0 { 0.0 } else { h.nnz() as f64 / cells as f64 };
                ShapeInfo::matrix(h.rows(), h.cols(), sp)
            }
            _ => ShapeInfo::scalar_value(),
        };
        out.insert(name.clone(), shape);
    }
    out
}

/// Validate, treating bound inputs as pre-defined variables.
fn validate_with_inputs<'a>(
    bundle: &Bundle,
    inputs: impl Iterator<Item = &'a String>,
) -> Result<Vec<String>> {
    // Wrap: synthesize `name = name` wouldn't parse; instead reuse the
    // validator by injecting the inputs into a shadow program whose body
    // starts with assignments from a reserved literal.
    let mut shadow = bundle.clone();
    let mut pre: Vec<crate::dml::ast::Stmt> = Vec::new();
    for name in inputs {
        pre.push(crate::dml::ast::Stmt::Assign {
            target: crate::dml::ast::AssignTarget::Var(name.clone()),
            value: crate::dml::ast::Expr::Num(0.0, crate::dml::ast::Pos::default()),
            pos: crate::dml::ast::Pos::default(),
        });
    }
    pre.extend(shadow.main.body);
    shadow.main.body = pre;
    validate::validate(&shadow)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn execute_simple_script() {
        let ctx = MLContext::new();
        let script = Script::from_str("Y = X * 2\ns = sum(Y)")
            .input("X", Matrix::filled(3, 3, 1.0))
            .output("s")
            .output("Y");
        let res = ctx.execute(script).unwrap();
        assert_eq!(res.double("s").unwrap(), 18.0);
        assert_eq!(res.matrix("Y").unwrap(), Matrix::filled(3, 3, 2.0));
    }

    #[test]
    fn missing_output_is_error() {
        let ctx = MLContext::new();
        let script = Script::from_str("x = 1").output("nope");
        assert!(ctx.execute(script).is_err());
    }

    #[test]
    fn validation_catches_undefined_vars() {
        let ctx = MLContext::new();
        let script = Script::from_str("y = undefined_thing + 1");
        assert!(ctx.execute(script).is_err());
    }

    #[test]
    fn inputs_are_visible_to_validator() {
        let ctx = MLContext::new();
        let script = Script::from_str("y = sum(X)").input("X", Matrix::filled(2, 2, 1.0));
        assert!(ctx.execute(script).is_ok());
    }

    #[test]
    fn print_output_captured() {
        let ctx = MLContext::new();
        let script = Script::from_str("print(\"hello \" + 42)");
        let res = ctx.execute(script).unwrap();
        assert_eq!(res.stdout, vec!["hello 42"]);
    }

    fn dist_config() -> SystemConfig {
        let mut config = SystemConfig::tiny_driver(8 * 1024);
        config.block_size = 32;
        config.num_workers = 4;
        config
    }

    #[test]
    fn session_carries_blocked_outputs_without_collect() {
        let ctx = MLContext::with_config(dist_config());
        let train = Script::from_str("Y = X %*% t(X)")
            .input("X", Matrix::filled(96, 8, 0.5))
            .output("Y");
        let res1 = ctx.execute(train).unwrap();
        // The multi-block product is handed back as a resident handle.
        let y = res1.blocked("Y").unwrap();
        assert_eq!((y.rows(), y.cols()), (96, 96));
        assert!(matches!(res1.value("Y").unwrap(), Value::Blocked(_)));
        assert!(ctx.session_value("Y").is_some());

        // The next script sees Y without re-binding it; the whole
        // two-script session never collects to the driver (checked on
        // the session cluster's own counter, so concurrent tests can't
        // interfere).
        let score = Script::from_str("s = sum(Y)").output("s");
        let res2 = ctx.execute(score).unwrap();
        assert_eq!(res2.double("s").unwrap(), 96.0 * 96.0 * 2.0);
        assert_eq!(
            ctx.cluster().unwrap().collect_count(),
            0,
            "session carry-over must not collect"
        );

        ctx.clear_session();
        assert!(ctx.session_value("Y").is_none());
    }

    #[test]
    fn explicit_inputs_shadow_session_values() {
        let ctx = MLContext::new();
        let first = Script::from_str("x = 7").output("x");
        ctx.execute(first).unwrap();
        // `x` comes from the session here...
        let reuse = Script::from_str("y = x + 1").output("y");
        assert_eq!(ctx.execute(reuse).unwrap().double("y").unwrap(), 8.0);
        // ...but an explicit input takes precedence over it.
        let shadow = Script::from_str("y = x + 1").input_scalar("x", 100.0).output("y");
        assert_eq!(ctx.execute(shadow).unwrap().double("y").unwrap(), 101.0);
    }

    #[test]
    fn input_value_accepts_blocked_handles() {
        let ctx = MLContext::with_config(dist_config());
        let make = Script::from_str("Y = X %*% t(X)")
            .input("X", Matrix::filled(96, 8, 0.5))
            .output("Y");
        let y = ctx.execute(make).unwrap().blocked("Y").unwrap();
        ctx.clear_session();
        // Rebind the handle under a fresh name: no blockify, no collect
        // until `matrix` forces it.
        let use_it = Script::from_str("s = sum(Z)")
            .input_value("Z", Value::Blocked(y))
            .output("s");
        let res = ctx.execute(use_it).unwrap();
        assert_eq!(res.double("s").unwrap(), 96.0 * 96.0 * 2.0);
    }
}
