//! MLContext-style public API (paper §2): build a [`Script`], bind inputs,
//! execute, fetch outputs.
//!
//! ```no_run
//! use systemml::api::{MLContext, Script};
//! use systemml::runtime::matrix::Matrix;
//!
//! let ctx = MLContext::new();
//! let script = Script::from_str("Y = X %*% t(X)\ns = sum(Y)")
//!     .input("X", Matrix::filled(4, 4, 1.0))
//!     .output("s");
//! let results = ctx.execute(script).unwrap();
//! assert_eq!(results.double("s").unwrap(), 64.0);
//! ```

pub mod io;

use std::collections::HashMap;
use std::sync::Arc;

use crate::conf::SystemConfig;
use crate::dml::parser::parse;
use crate::dml::validate::{self, Bundle};
use crate::hop::dag::ShapeInfo;
use crate::hop::plan::{compile_plan, Plan};
use crate::runtime::interp::registry::build_bundle;
use crate::runtime::interp::{Interpreter, Scope, Value};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};

/// A DML script plus its input bindings and requested outputs.
#[derive(Clone, Debug, Default)]
pub struct Script {
    pub source: String,
    pub inputs: HashMap<String, Value>,
    pub outputs: Vec<String>,
}

impl Script {
    /// Script from DML source text.
    pub fn from_str(src: impl Into<String>) -> Script {
        Script { source: src.into(), inputs: HashMap::new(), outputs: Vec::new() }
    }

    /// Script from a .dml file.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Script> {
        Ok(Script::from_str(std::fs::read_to_string(path)?))
    }

    /// Bind a matrix input.
    pub fn input(mut self, name: &str, m: Matrix) -> Script {
        self.inputs.insert(name.to_string(), Value::Matrix(m));
        self
    }

    /// Bind a scalar input.
    pub fn input_scalar(mut self, name: &str, v: f64) -> Script {
        self.inputs.insert(name.to_string(), Value::Double(v));
        self
    }

    /// Bind a string input.
    pub fn input_str(mut self, name: &str, v: &str) -> Script {
        self.inputs.insert(name.to_string(), Value::Str(v.to_string()));
        self
    }

    /// Request an output variable.
    pub fn output(mut self, name: &str) -> Script {
        self.outputs.push(name.to_string());
        self
    }
}

/// Execution results: the requested outputs plus captured print output.
#[derive(Clone, Debug, Default)]
pub struct Results {
    values: HashMap<String, Value>,
    pub stdout: Vec<String>,
}

impl Results {
    pub fn get(&self, name: &str) -> Option<&Value> {
        self.values.get(name)
    }
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        Ok(self
            .values
            .get(name)
            .ok_or_else(|| DmlError::rt(format!("no output '{name}'")))?
            .as_matrix()?
            .clone())
    }
    pub fn double(&self, name: &str) -> Result<f64> {
        self.values
            .get(name)
            .ok_or_else(|| DmlError::rt(format!("no output '{name}'")))?
            .as_double()
    }
}

/// The MLContext: configuration + execution entry point.
#[derive(Default)]
pub struct MLContext {
    pub config: SystemConfig,
    /// Echo DML print() output to stdout.
    pub echo: bool,
}

impl MLContext {
    /// Context with default configuration.
    pub fn new() -> MLContext {
        MLContext { config: SystemConfig::default(), echo: false }
    }

    /// Context with explicit configuration.
    pub fn with_config(config: SystemConfig) -> MLContext {
        MLContext { config, echo: false }
    }

    /// Parse, validate, and plan a script without executing (SystemML
    /// `-explain`): constant folding, bundle construction, validation,
    /// then HOP-DAG lowering + ExecType plan compilation against the
    /// bound input shapes. The returned bundle reflects plan-driven AST
    /// rewrites (e.g. matmult chain reordering).
    pub fn compile(&self, script: &Script) -> Result<Compilation> {
        let mut prog = parse(&script.source)?;
        // Static rewrites (HOP-level): constant folding.
        crate::hop::rewrite::fold_program(&mut prog);
        let mut bundle = build_bundle(prog, &self.config)?;
        // Validation treats bound inputs as pre-defined.
        let warnings = validate_with_inputs(&bundle, script.inputs.keys())?;
        let shapes = input_shapes(&script.inputs);
        let plan = compile_plan(&mut bundle, &shapes, &self.config);
        Ok(Compilation { bundle, plan, warnings })
    }

    /// Execute a script and collect its outputs. The interpreter runs
    /// against the compiled plan's per-operator ExecType placements; with
    /// `explain` enabled the annotated HOP plan is printed first.
    pub fn execute(&self, script: Script) -> Result<Results> {
        let Compilation { bundle, plan, .. } = self.compile(&script)?;
        let mut interp = Interpreter::new(bundle, self.config.clone());
        interp.echo = self.echo;
        if self.config.explain {
            for line in plan.render().lines() {
                interp.emit(line.to_string());
            }
        }
        interp.plan = Some(Arc::new(plan));
        let scope: Scope = script.inputs.clone().into_iter().collect();
        let final_scope = interp.run(scope)?;
        let mut out = Results { values: HashMap::new(), stdout: interp.output() };
        for name in &script.outputs {
            let v = final_scope.get(name).ok_or_else(|| {
                DmlError::rt(format!("requested output '{name}' was never assigned"))
            })?;
            out.values.insert(name.clone(), v.clone());
        }
        Ok(out)
    }
}

/// Result of [`MLContext::compile`]: the validated (and plan-rewritten)
/// bundle, the compiled execution plan, and validation warnings.
#[derive(Clone, Debug)]
pub struct Compilation {
    pub bundle: Bundle,
    pub plan: Plan,
    pub warnings: Vec<String>,
}

/// Compile-time shapes of the bound inputs (rows/cols/sparsity for
/// matrices, scalar markers otherwise).
fn input_shapes(inputs: &HashMap<String, Value>) -> HashMap<String, ShapeInfo> {
    let mut out = HashMap::new();
    for (name, v) in inputs {
        let shape = match v {
            Value::Matrix(m) => ShapeInfo::matrix(m.rows(), m.cols(), m.sparsity()),
            _ => ShapeInfo::scalar_value(),
        };
        out.insert(name.clone(), shape);
    }
    out
}

/// Validate, treating bound inputs as pre-defined variables.
fn validate_with_inputs<'a>(
    bundle: &Bundle,
    inputs: impl Iterator<Item = &'a String>,
) -> Result<Vec<String>> {
    // Wrap: synthesize `name = name` wouldn't parse; instead reuse the
    // validator by injecting the inputs into a shadow program whose body
    // starts with assignments from a reserved literal.
    let mut shadow = bundle.clone();
    let mut pre: Vec<crate::dml::ast::Stmt> = Vec::new();
    for name in inputs {
        pre.push(crate::dml::ast::Stmt::Assign {
            target: crate::dml::ast::AssignTarget::Var(name.clone()),
            value: crate::dml::ast::Expr::Num(0.0, crate::dml::ast::Pos::default()),
            pos: crate::dml::ast::Pos::default(),
        });
    }
    pre.extend(shadow.main.body);
    shadow.main.body = pre;
    validate::validate(&shadow)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn execute_simple_script() {
        let ctx = MLContext::new();
        let script = Script::from_str("Y = X * 2\ns = sum(Y)")
            .input("X", Matrix::filled(3, 3, 1.0))
            .output("s")
            .output("Y");
        let res = ctx.execute(script).unwrap();
        assert_eq!(res.double("s").unwrap(), 18.0);
        assert_eq!(res.matrix("Y").unwrap(), Matrix::filled(3, 3, 2.0));
    }

    #[test]
    fn missing_output_is_error() {
        let ctx = MLContext::new();
        let script = Script::from_str("x = 1").output("nope");
        assert!(ctx.execute(script).is_err());
    }

    #[test]
    fn validation_catches_undefined_vars() {
        let ctx = MLContext::new();
        let script = Script::from_str("y = undefined_thing + 1");
        assert!(ctx.execute(script).is_err());
    }

    #[test]
    fn inputs_are_visible_to_validator() {
        let ctx = MLContext::new();
        let script = Script::from_str("y = sum(X)").input("X", Matrix::filled(2, 2, 1.0));
        assert!(ctx.execute(script).is_ok());
    }

    #[test]
    fn print_output_captured() {
        let ctx = MLContext::new();
        let script = Script::from_str("print(\"hello \" + 42)");
        let res = ctx.execute(script).unwrap();
        assert_eq!(res.stdout, vec!["hello 42"]);
    }
}
