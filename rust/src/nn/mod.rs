//! Model-import APIs: the Keras2DML analog (paper §2).

pub mod keras2dml;
