//! Keras2DML (paper §2): accept a sequential model description (JSON,
//! mirroring a Keras `Sequential` config), generate the equivalent DML
//! training/scoring script, and expose a scikit-learn-like `fit`/`predict`
//! API on top of [`MLContext`].
//!
//! The `train_algo`/`test_algo` knobs reproduce the paper's §3
//! "Distributed Operations": `train_algo="minibatch"` emits a for-loop
//! over batches; `train_algo="batch"` emits full-batch updates (which the
//! compiler sends to the distributed backend when over budget);
//! `test_algo="allreduce"` emits a row-partitioned `parfor` scoring loop
//! (the shuffle-free plan of the ResNet-50 claim).

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::api::{MLContext, Script};
use crate::runtime::matrix::Matrix;
use crate::util::error::{DmlError, Result};
use crate::util::json::Json;

/// Supported layer kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum Layer {
    /// Fully-connected with `units` outputs and an optional activation.
    Dense { units: usize, activation: Activation },
    /// 2D convolution (stride-1x1 "same"/"valid" padding) + activation.
    Conv2d { filters: usize, kernel: (usize, usize), same_pad: bool, activation: Activation },
    /// Max pooling.
    MaxPool2d { pool: (usize, usize), stride: (usize, usize) },
    /// Flatten a conv volume into a dense vector (no-op on the linearized
    /// representation; only changes tracked shape).
    Flatten,
    /// Inverted dropout with retain probability 1-rate.
    Dropout { rate: f64 },
}

/// Activation functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Sigmoid,
    Tanh,
    Softmax,
}

impl Activation {
    fn parse(s: &str) -> Result<Activation> {
        Ok(match s {
            "" | "linear" | "none" => Activation::None,
            "relu" => Activation::Relu,
            "sigmoid" => Activation::Sigmoid,
            "tanh" => Activation::Tanh,
            "softmax" => Activation::Softmax,
            other => return Err(DmlError::val(format!("unknown activation '{other}'"))),
        })
    }
}

/// Optimizer configs (the six of the paper's NN library).
#[derive(Clone, Debug, PartialEq)]
pub enum Optimizer {
    Sgd { lr: f64 },
    Momentum { lr: f64, mu: f64 },
    Nesterov { lr: f64, mu: f64 },
    Adagrad { lr: f64 },
    Rmsprop { lr: f64, decay: f64 },
    Adam { lr: f64, beta1: f64, beta2: f64 },
}

/// Input shape: flat features or a conv volume.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InputShape {
    Flat(usize),
    Volume { c: usize, h: usize, w: usize },
}

/// A sequential model (the Keras2DML input).
#[derive(Clone, Debug)]
pub struct SequentialModel {
    pub name: String,
    pub input: InputShape,
    pub layers: Vec<Layer>,
    pub optimizer: Optimizer,
}

/// Training hyper-parameters and the paper's execution knobs.
#[derive(Clone, Debug)]
pub struct FitConfig {
    /// "minibatch" or "batch" (paper §3 train_algo).
    pub train_algo: String,
    /// "naive" (plain loop) or "allreduce" (row-partitioned parfor).
    pub test_algo: String,
    pub batch_size: usize,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig {
            train_algo: "minibatch".into(),
            test_algo: "allreduce".into(),
            batch_size: 32,
            epochs: 1,
            seed: 42,
        }
    }
}

impl SequentialModel {
    /// Parse the JSON model descriptor.
    pub fn from_json(text: &str) -> Result<SequentialModel> {
        let doc = Json::parse(text)?;
        let name = doc.get("name").as_str().unwrap_or("model").to_string();
        let input = if let Some(d) = doc.get("input_dim").as_usize() {
            InputShape::Flat(d)
        } else if let Some(shape) = doc.get("input_shape").as_arr() {
            if shape.len() != 3 {
                return Err(DmlError::val("input_shape must be [C,H,W]".to_string()));
            }
            InputShape::Volume {
                c: shape[0].as_usize().unwrap_or(1),
                h: shape[1].as_usize().unwrap_or(1),
                w: shape[2].as_usize().unwrap_or(1),
            }
        } else {
            return Err(DmlError::val("model needs input_dim or input_shape".to_string()));
        };
        let mut layers = Vec::new();
        for l in doc.get("layers").as_arr().unwrap_or(&[]) {
            let ty = l.get("type").as_str().unwrap_or_default();
            let act = Activation::parse(l.get("activation").as_str().unwrap_or(""))?;
            match ty {
                "dense" => layers.push(Layer::Dense {
                    units: l
                        .get("units")
                        .as_usize()
                        .ok_or_else(|| DmlError::val("dense needs units".to_string()))?,
                    activation: act,
                }),
                "conv2d" => {
                    let kernel = l.get("kernel").as_arr().map(|a| {
                        (
                            a[0].as_usize().unwrap_or(3),
                            a.get(1).and_then(|v| v.as_usize()).unwrap_or(3),
                        )
                    });
                    layers.push(Layer::Conv2d {
                        filters: l
                            .get("filters")
                            .as_usize()
                            .ok_or_else(|| DmlError::val("conv2d needs filters".to_string()))?,
                        kernel: kernel.unwrap_or((3, 3)),
                        same_pad: l.get("padding").as_str().unwrap_or("same") == "same",
                        activation: act,
                    });
                }
                "maxpool2d" => {
                    let pool = l
                        .get("pool")
                        .as_arr()
                        .map(|a| {
                            (
                                a[0].as_usize().unwrap_or(2),
                                a.get(1).and_then(|v| v.as_usize()).unwrap_or(2),
                            )
                        })
                        .unwrap_or((2, 2));
                    let stride = l
                        .get("stride")
                        .as_arr()
                        .map(|a| {
                            (
                                a[0].as_usize().unwrap_or(pool.0),
                                a.get(1).and_then(|v| v.as_usize()).unwrap_or(pool.1),
                            )
                        })
                        .unwrap_or(pool);
                    layers.push(Layer::MaxPool2d { pool, stride });
                }
                "flatten" => layers.push(Layer::Flatten),
                "dropout" => {
                    layers.push(Layer::Dropout { rate: l.get("rate").as_f64().unwrap_or(0.5) })
                }
                other => return Err(DmlError::val(format!("unknown layer type '{other}'"))),
            }
        }
        if layers.is_empty() {
            return Err(DmlError::val("model has no layers".to_string()));
        }
        let opt = doc.get("optimizer");
        let lr = opt.get("lr").as_f64().unwrap_or(0.01);
        let optimizer = match opt.get("type").as_str().unwrap_or("sgd") {
            "sgd" => Optimizer::Sgd { lr },
            "momentum" | "sgd_momentum" => {
                Optimizer::Momentum { lr, mu: opt.get("momentum").as_f64().unwrap_or(0.9) }
            }
            "nesterov" | "sgd_nesterov" => {
                Optimizer::Nesterov { lr, mu: opt.get("momentum").as_f64().unwrap_or(0.9) }
            }
            "adagrad" => Optimizer::Adagrad { lr },
            "rmsprop" => {
                Optimizer::Rmsprop { lr, decay: opt.get("decay").as_f64().unwrap_or(0.99) }
            }
            "adam" => Optimizer::Adam {
                lr,
                beta1: opt.get("beta1").as_f64().unwrap_or(0.9),
                beta2: opt.get("beta2").as_f64().unwrap_or(0.999),
            },
            other => return Err(DmlError::val(format!("unknown optimizer '{other}'"))),
        };
        Ok(SequentialModel { name, input, layers, optimizer })
    }

    /// Parameterized layers (those with weights) with their shapes.
    /// Returns (layer_index, W_shape, b_shape) per parameterized layer.
    pub fn param_shapes(&self) -> Result<Vec<(usize, (usize, usize), (usize, usize))>> {
        let mut shapes = Vec::new();
        let mut cur = self.input;
        for (i, l) in self.layers.iter().enumerate() {
            match l {
                Layer::Dense { units, .. } => {
                    let d = match cur {
                        InputShape::Flat(d) => d,
                        InputShape::Volume { c, h, w } => c * h * w, // implicit flatten
                    };
                    shapes.push((i, (d, *units), (1, *units)));
                    cur = InputShape::Flat(*units);
                }
                Layer::Conv2d { filters, kernel, same_pad, .. } => {
                    let InputShape::Volume { c, h, w } = cur else {
                        return Err(DmlError::val(format!(
                            "layer {i}: conv2d requires a volume input"
                        )));
                    };
                    let (kh, kw) = *kernel;
                    shapes.push((i, (*filters, c * kh * kw), (*filters, 1)));
                    let (ph, pw) = if *same_pad { (kh / 2, kw / 2) } else { (0, 0) };
                    cur = InputShape::Volume {
                        c: *filters,
                        h: h + 2 * ph - kh + 1,
                        w: w + 2 * pw - kw + 1,
                    };
                }
                Layer::MaxPool2d { pool, stride } => {
                    let InputShape::Volume { c, h, w } = cur else {
                        return Err(DmlError::val(format!(
                            "layer {i}: maxpool2d requires a volume input"
                        )));
                    };
                    cur = InputShape::Volume {
                        c,
                        h: (h - pool.0) / stride.0 + 1,
                        w: (w - pool.1) / stride.1 + 1,
                    };
                }
                Layer::Flatten => {
                    if let InputShape::Volume { c, h, w } = cur {
                        cur = InputShape::Flat(c * h * w);
                    }
                }
                Layer::Dropout { .. } => {}
            }
        }
        Ok(shapes)
    }

    /// Generate the DML **forward pass** from variable `Xb`, writing
    /// statements into `out` and returning the output variable name.
    fn gen_forward(&self, out: &mut String, training: bool, indent: &str) -> Result<String> {
        let mut cur = self.input;
        let mut var = "Xb".to_string();
        for (i, l) in self.layers.iter().enumerate() {
            match l {
                Layer::Dense { units, activation } => {
                    // A volume input is implicitly flattened (layout no-op).
                    let pre = format!("pre_{i}");
                    writeln!(out, "{indent}{pre} = {var} %*% W_{i} + b_{i}").unwrap();
                    var = self.gen_activation(out, activation, &pre, i, indent);
                    cur = InputShape::Flat(*units);
                }
                Layer::Conv2d { filters, kernel, same_pad, activation } => {
                    let InputShape::Volume { c, h, w } = cur else {
                        return Err(DmlError::val("conv2d over flat input".to_string()));
                    };
                    let (kh, kw) = *kernel;
                    let (ph, pw) = if *same_pad { (kh / 2, kw / 2) } else { (0, 0) };
                    let pre = format!("pre_{i}");
                    writeln!(
                        out,
                        "{indent}{pre} = bias_add(conv2d({var}, W_{i}, input_shape=[nrow({var}),{c},{h},{w}], filter_shape=[{filters},{c},{kh},{kw}], stride=[1,1], padding=[{ph},{pw}]), b_{i})"
                    )
                    .unwrap();
                    var = self.gen_activation(out, activation, &pre, i, indent);
                    cur = InputShape::Volume {
                        c: *filters,
                        h: h + 2 * ph - kh + 1,
                        w: w + 2 * pw - kw + 1,
                    };
                }
                Layer::MaxPool2d { pool, stride } => {
                    let InputShape::Volume { c, h, w } = cur else {
                        return Err(DmlError::val("maxpool2d over flat input".to_string()));
                    };
                    let nv = format!("out_{i}");
                    writeln!(
                        out,
                        "{indent}{nv} = max_pool({var}, input_shape=[nrow({var}),{c},{h},{w}], pool_size=[{},{}], stride=[{},{}], padding=[0,0])",
                        pool.0, pool.1, stride.0, stride.1
                    )
                    .unwrap();
                    var = nv;
                    cur = InputShape::Volume {
                        c,
                        h: (h - pool.0) / stride.0 + 1,
                        w: (w - pool.1) / stride.1 + 1,
                    };
                }
                Layer::Flatten => {
                    if let InputShape::Volume { c, h, w } = cur {
                        #[allow(unused_assignments)]
                        {
                            cur = InputShape::Flat(c * h * w);
                        }
                    }
                }
                Layer::Dropout { rate } => {
                    if training {
                        let nv = format!("out_{i}");
                        writeln!(
                            out,
                            "{indent}[{nv}, mask_{i}] = dropout::forward({var}, {}, {i} + iter * 131)",
                            1.0 - rate
                        )
                        .unwrap();
                        var = nv;
                    }
                    // scoring: identity (inverted dropout)
                }
            }
        }
        Ok(var)
    }

    fn gen_activation(
        &self,
        out: &mut String,
        act: &Activation,
        pre: &str,
        i: usize,
        indent: &str,
    ) -> String {
        let nv = format!("out_{i}");
        match act {
            Activation::None => return pre.to_string(),
            Activation::Relu => writeln!(out, "{indent}{nv} = max({pre}, 0)").unwrap(),
            Activation::Sigmoid => writeln!(out, "{indent}{nv} = 1 / (1 + exp(-{pre}))").unwrap(),
            Activation::Tanh => {
                writeln!(out, "{indent}{nv} = (exp(2*{pre}) - 1) / (exp(2*{pre}) + 1)").unwrap()
            }
            Activation::Softmax => writeln!(out, "{indent}{nv} = softmax::forward({pre})").unwrap(),
        }
        nv
    }

    /// Generate the full training script (the Keras2DML codegen product).
    pub fn to_dml(&self, fit: &FitConfig) -> Result<String> {
        let shapes = self.param_shapes()?;
        let mut s = String::new();
        writeln!(s, "# ---- generated by Keras2DML for model '{}' ----", self.name).unwrap();
        writeln!(s, "source(\"nn/layers/softmax.dml\") as softmax").unwrap();
        writeln!(s, "source(\"nn/layers/cross_entropy_loss.dml\") as ce").unwrap();
        writeln!(s, "source(\"nn/layers/dropout.dml\") as dropout").unwrap();
        for opt_file in ["sgd", "sgd_momentum", "sgd_nesterov", "adagrad", "rmsprop", "adam"] {
            writeln!(s, "source(\"nn/optim/{opt_file}.dml\") as {opt_file}").unwrap();
        }
        writeln!(s).unwrap();
        // Param init.
        for (i, wsh, bsh) in &shapes {
            writeln!(
                s,
                "W_{i} = rand(rows={}, cols={}, min=-1, max=1, seed={}) * sqrt(2.0 / {})",
                wsh.0,
                wsh.1,
                fit.seed + *i as u64,
                wsh.0
            )
            .unwrap();
            writeln!(s, "b_{i} = matrix(0, rows={}, cols={})", bsh.0, bsh.1).unwrap();
            match self.optimizer {
                Optimizer::Sgd { .. } => {}
                Optimizer::Adam { .. } => {
                    writeln!(s, "mW_{i} = matrix(0, rows={}, cols={})", wsh.0, wsh.1).unwrap();
                    writeln!(s, "vW_{i} = matrix(0, rows={}, cols={})", wsh.0, wsh.1).unwrap();
                    writeln!(s, "mb_{i} = matrix(0, rows={}, cols={})", bsh.0, bsh.1).unwrap();
                    writeln!(s, "vb_{i} = matrix(0, rows={}, cols={})", bsh.0, bsh.1).unwrap();
                }
                _ => {
                    writeln!(s, "vW_{i} = matrix(0, rows={}, cols={})", wsh.0, wsh.1).unwrap();
                    writeln!(s, "vb_{i} = matrix(0, rows={}, cols={})", bsh.0, bsh.1).unwrap();
                }
            }
        }
        writeln!(s).unwrap();
        // Training loop.
        let n_batches = match fit.train_algo.as_str() {
            "minibatch" => format!("nrow(X) %/% {}", fit.batch_size),
            "batch" => "1".to_string(),
            other => return Err(DmlError::val(format!("unknown train_algo '{other}'"))),
        };
        writeln!(s, "nbatches = {n_batches}").unwrap();
        writeln!(s, "loss_curve = matrix(0, rows={} * nbatches, cols=1)", fit.epochs).unwrap();
        writeln!(s, "iter = 0").unwrap();
        writeln!(s, "for (ep in 1:{}) {{", fit.epochs).unwrap();
        writeln!(s, "  for (bi in 1:nbatches) {{").unwrap();
        writeln!(s, "    iter = iter + 1").unwrap();
        if fit.train_algo == "minibatch" {
            writeln!(s, "    beg = (bi-1)*{} + 1; end = bi*{}", fit.batch_size, fit.batch_size)
                .unwrap();
            writeln!(s, "    Xb = X[beg:end,]; Yb = Y[beg:end,]").unwrap();
        } else {
            writeln!(s, "    Xb = X; Yb = Y").unwrap();
        }
        let out_var = self.gen_forward(&mut s, true, "    ")?;
        writeln!(s, "    probs = {out_var}").unwrap();
        writeln!(s, "    loss = ce::forward(probs, Yb)").unwrap();
        writeln!(s, "    loss_curve[iter, 1] = loss").unwrap();
        self.gen_backward(&mut s, "    ")?;
        for (i, ..) in &shapes {
            self.gen_update(&mut s, *i, "    ");
        }
        writeln!(s, "  }}").unwrap();
        writeln!(s, "}}").unwrap();
        Ok(s)
    }

    /// Backward pass (reverse layer order), softmax+CE head fused.
    fn gen_backward(&self, s: &mut String, ind: &str) -> Result<()> {
        writeln!(s, "{ind}d_cur = (probs - Yb) / nrow(Yb)").unwrap();
        let mut cur_shapes = self.shapes_per_layer()?;
        for (i, l) in self.layers.iter().enumerate().rev() {
            let (in_shape, _out_shape) = cur_shapes.pop().unwrap();
            match l {
                Layer::Dense { activation, .. } => {
                    match activation {
                        Activation::Relu => {
                            writeln!(s, "{ind}d_cur = d_cur * (pre_{i} > 0)").unwrap()
                        }
                        Activation::Sigmoid => {
                            writeln!(s, "{ind}sig_{i} = 1 / (1 + exp(-pre_{i}))").unwrap();
                            writeln!(s, "{ind}d_cur = d_cur * sig_{i} * (1 - sig_{i})").unwrap();
                        }
                        Activation::Tanh => {
                            writeln!(
                                s,
                                "{ind}th_{i} = (exp(2*pre_{i}) - 1) / (exp(2*pre_{i}) + 1)"
                            )
                            .unwrap();
                            writeln!(s, "{ind}d_cur = d_cur * (1 - th_{i} * th_{i})").unwrap();
                        }
                        // Softmax head gradient already fused with CE.
                        Activation::Softmax | Activation::None => {}
                    }
                    let src = self.input_var_of(i);
                    writeln!(s, "{ind}dW_{i} = t({src}) %*% d_cur").unwrap();
                    writeln!(s, "{ind}db_{i} = colSums(d_cur)").unwrap();
                    writeln!(s, "{ind}d_cur = d_cur %*% t(W_{i})").unwrap();
                }
                Layer::Conv2d { filters, kernel, same_pad, activation } => {
                    match activation {
                        Activation::Relu => {
                            writeln!(s, "{ind}d_cur = d_cur * (pre_{i} > 0)").unwrap()
                        }
                        Activation::None => {}
                        _ => {
                            return Err(DmlError::val(
                                "conv2d codegen supports relu/linear activations".to_string(),
                            ))
                        }
                    }
                    let InputShape::Volume { c, h, w } = in_shape else {
                        return Err(DmlError::val("conv backward over flat".to_string()));
                    };
                    let (kh, kw) = *kernel;
                    let (ph, pw) = if *same_pad { (kh / 2, kw / 2) } else { (0, 0) };
                    let src = self.input_var_of(i);
                    writeln!(
                        s,
                        "{ind}dW_{i} = conv2d_backward_filter({src}, d_cur, input_shape=[nrow({src}),{c},{h},{w}], filter_shape=[{filters},{c},{kh},{kw}], stride=[1,1], padding=[{ph},{pw}])"
                    )
                    .unwrap();
                    let p = h + 2 * ph - kh + 1;
                    let q = w + 2 * pw - kw + 1;
                    writeln!(s, "{ind}db_{i} = matrix(0, rows={filters}, cols=1)").unwrap();
                    writeln!(s, "{ind}for (kk in 1:{filters}) {{").unwrap();
                    writeln!(
                        s,
                        "{ind}  db_{i}[kk, 1] = sum(d_cur[, ((kk-1)*{0}+1):(kk*{0})])",
                        p * q
                    )
                    .unwrap();
                    writeln!(s, "{ind}}}").unwrap();
                    writeln!(
                        s,
                        "{ind}d_cur = conv2d_backward_data(W_{i}, d_cur, input_shape=[nrow({src}),{c},{h},{w}], filter_shape=[{filters},{c},{kh},{kw}], stride=[1,1], padding=[{ph},{pw}])"
                    )
                    .unwrap();
                }
                Layer::MaxPool2d { pool, stride } => {
                    let InputShape::Volume { c, h, w } = in_shape else {
                        return Err(DmlError::val("pool backward over flat".to_string()));
                    };
                    let src = self.input_var_of(i);
                    writeln!(
                        s,
                        "{ind}d_cur = max_pool_backward({src}, d_cur, input_shape=[nrow({src}),{c},{h},{w}], pool_size=[{},{}], stride=[{},{}], padding=[0,0])",
                        pool.0, pool.1, stride.0, stride.1
                    )
                    .unwrap();
                }
                Layer::Flatten => {}
                Layer::Dropout { .. } => {
                    writeln!(s, "{ind}d_cur = dropout::backward(d_cur, mask_{i})").unwrap();
                }
            }
        }
        Ok(())
    }

    /// The variable feeding layer i in the generated forward code.
    fn input_var_of(&self, i: usize) -> String {
        for j in (0..i).rev() {
            match &self.layers[j] {
                Layer::Flatten => continue,
                Layer::Dense { activation, .. } | Layer::Conv2d { activation, .. } => {
                    return if *activation == Activation::None {
                        format!("pre_{j}")
                    } else {
                        format!("out_{j}")
                    }
                }
                _ => return format!("out_{j}"),
            }
        }
        "Xb".to_string()
    }

    /// (input_shape, output_shape) per layer.
    fn shapes_per_layer(&self) -> Result<Vec<(InputShape, InputShape)>> {
        let mut out = Vec::new();
        let mut cur = self.input;
        for l in &self.layers {
            let inp = cur;
            match l {
                Layer::Dense { units, .. } => cur = InputShape::Flat(*units),
                Layer::Conv2d { filters, kernel, same_pad, .. } => {
                    let InputShape::Volume { h, w, .. } = cur else {
                        return Err(DmlError::val("conv over flat".to_string()));
                    };
                    let (kh, kw) = *kernel;
                    let (ph, pw) = if *same_pad { (kh / 2, kw / 2) } else { (0, 0) };
                    cur = InputShape::Volume {
                        c: *filters,
                        h: h + 2 * ph - kh + 1,
                        w: w + 2 * pw - kw + 1,
                    };
                }
                Layer::MaxPool2d { pool, stride } => {
                    let InputShape::Volume { c, h, w } = cur else {
                        return Err(DmlError::val("pool over flat".to_string()));
                    };
                    cur = InputShape::Volume {
                        c,
                        h: (h - pool.0) / stride.0 + 1,
                        w: (w - pool.1) / stride.1 + 1,
                    };
                }
                Layer::Flatten => {
                    if let InputShape::Volume { c, h, w } = cur {
                        cur = InputShape::Flat(c * h * w);
                    }
                }
                Layer::Dropout { .. } => {}
            }
            out.push((inp, cur));
        }
        Ok(out)
    }

    fn gen_update(&self, s: &mut String, i: usize, ind: &str) {
        match &self.optimizer {
            Optimizer::Sgd { lr } => {
                writeln!(s, "{ind}W_{i} = sgd::update(W_{i}, dW_{i}, {lr})").unwrap();
                writeln!(s, "{ind}b_{i} = sgd::update(b_{i}, db_{i}, {lr})").unwrap();
            }
            Optimizer::Momentum { lr, mu } => {
                writeln!(s, "{ind}[W_{i}, vW_{i}] = sgd_momentum::update(W_{i}, dW_{i}, {lr}, {mu}, vW_{i})").unwrap();
                writeln!(s, "{ind}[b_{i}, vb_{i}] = sgd_momentum::update(b_{i}, db_{i}, {lr}, {mu}, vb_{i})").unwrap();
            }
            Optimizer::Nesterov { lr, mu } => {
                writeln!(s, "{ind}[W_{i}, vW_{i}] = sgd_nesterov::update(W_{i}, dW_{i}, {lr}, {mu}, vW_{i})").unwrap();
                writeln!(s, "{ind}[b_{i}, vb_{i}] = sgd_nesterov::update(b_{i}, db_{i}, {lr}, {mu}, vb_{i})").unwrap();
            }
            Optimizer::Adagrad { lr } => {
                writeln!(s, "{ind}[W_{i}, vW_{i}] = adagrad::update(W_{i}, dW_{i}, {lr}, 1e-8, vW_{i})").unwrap();
                writeln!(s, "{ind}[b_{i}, vb_{i}] = adagrad::update(b_{i}, db_{i}, {lr}, 1e-8, vb_{i})").unwrap();
            }
            Optimizer::Rmsprop { lr, decay } => {
                writeln!(s, "{ind}[W_{i}, vW_{i}] = rmsprop::update(W_{i}, dW_{i}, {lr}, {decay}, 1e-8, vW_{i})").unwrap();
                writeln!(s, "{ind}[b_{i}, vb_{i}] = rmsprop::update(b_{i}, db_{i}, {lr}, {decay}, 1e-8, vb_{i})").unwrap();
            }
            Optimizer::Adam { lr, beta1, beta2 } => {
                writeln!(s, "{ind}[W_{i}, mW_{i}, vW_{i}] = adam::update(W_{i}, dW_{i}, {lr}, {beta1}, {beta2}, 1e-8, iter, mW_{i}, vW_{i})").unwrap();
                writeln!(s, "{ind}[b_{i}, mb_{i}, vb_{i}] = adam::update(b_{i}, db_{i}, {lr}, {beta1}, {beta2}, 1e-8, iter, mb_{i}, vb_{i})").unwrap();
            }
        }
    }

    /// Generate the scoring script (respects `test_algo`).
    pub fn to_predict_dml(&self, fit: &FitConfig) -> Result<String> {
        let k_out = match self.layers.iter().rev().find_map(|l| match l {
            Layer::Dense { units, .. } => Some(*units),
            _ => None,
        }) {
            Some(k) => k,
            None => return Err(DmlError::val("predict: model has no dense output".to_string())),
        };
        let mut s = String::new();
        writeln!(s, "source(\"nn/layers/softmax.dml\") as softmax").unwrap();
        writeln!(s, "source(\"nn/layers/dropout.dml\") as dropout").unwrap();
        writeln!(s, "iter = 0").unwrap();
        match fit.test_algo.as_str() {
            "allreduce" => {
                // Row-partitioned parfor over row blocks (paper §3: avoids
                // shuffling, scales linearly). Row count must divide into
                // full blocks for the disjointness analysis.
                writeln!(s, "n = nrow(X)").unwrap();
                writeln!(s, "bs = {}", fit.batch_size).unwrap();
                writeln!(s, "nb = n %/% bs").unwrap();
                writeln!(s, "P = matrix(0, rows=n, cols={k_out})").unwrap();
                writeln!(s, "parfor (pi in 1:nb, mode=remote) {{").unwrap();
                writeln!(s, "  beg = (pi-1)*bs + 1; end = pi*bs").unwrap();
                writeln!(s, "  Xb = X[beg:end,]").unwrap();
                let v = self.gen_forward(&mut s, false, "  ")?;
                writeln!(s, "  P[beg:end, ] = {v}").unwrap();
                writeln!(s, "}}").unwrap();
            }
            _ => {
                writeln!(s, "Xb = X").unwrap();
                let v = self.gen_forward(&mut s, false, "")?;
                writeln!(s, "P = {v}").unwrap();
            }
        }
        Ok(s)
    }
}

/// Scikit-learn-like wrapper (the paper's `Keras2DML(spark, model, ...)`).
pub struct Keras2DML {
    pub model: SequentialModel,
    pub fit_config: FitConfig,
    pub ctx: MLContext,
}

/// Trained parameters + the loss curve.
pub struct Trained {
    pub params: HashMap<String, Matrix>,
    pub loss_curve: Vec<f64>,
}

impl Keras2DML {
    pub fn new(ctx: MLContext, model: SequentialModel) -> Keras2DML {
        Keras2DML { model, fit_config: FitConfig::default(), ctx }
    }

    /// Set the execution knobs (`train_algo`, `test_algo`), mirroring
    /// `sysml_model.set(train_algo=..., test_algo=...)` from the paper.
    pub fn set(&mut self, train_algo: &str, test_algo: &str) -> &mut Self {
        self.fit_config.train_algo = train_algo.to_string();
        self.fit_config.test_algo = test_algo.to_string();
        self
    }

    /// Train; returns trained params and the per-iteration loss curve.
    pub fn fit(&self, x: Matrix, y: Matrix) -> Result<Trained> {
        let dml = self.model.to_dml(&self.fit_config)?;
        let mut script = Script::from_str(dml).input("X", x).input("Y", y).output("loss_curve");
        for (i, ..) in self.model.param_shapes()? {
            script = script.output(&format!("W_{i}")).output(&format!("b_{i}"));
        }
        let res = self.ctx.execute(script)?;
        let mut params = HashMap::new();
        for (i, ..) in self.model.param_shapes()? {
            params.insert(format!("W_{i}"), res.matrix(&format!("W_{i}"))?);
            params.insert(format!("b_{i}"), res.matrix(&format!("b_{i}"))?);
        }
        let lc = res.matrix("loss_curve")?;
        let loss_curve = (0..lc.rows()).map(|r| lc.get(r, 0)).collect();
        Ok(Trained { params, loss_curve })
    }

    /// Score with trained params (respects `test_algo`).
    pub fn predict(&self, trained: &Trained, x: Matrix) -> Result<Matrix> {
        let dml = self.model.to_predict_dml(&self.fit_config)?;
        let mut script = Script::from_str(dml).input("X", x).output("P");
        for (name, m) in &trained.params {
            script = script.input(name, m.clone());
        }
        self.ctx.execute(script)?.matrix("P")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MLP_JSON: &str = r#"{
        "name": "mlp",
        "input_dim": 16,
        "layers": [
            {"type": "dense", "units": 32, "activation": "relu"},
            {"type": "dense", "units": 4, "activation": "softmax"}
        ],
        "optimizer": {"type": "sgd", "lr": 0.1}
    }"#;

    #[test]
    fn parses_model_json() {
        let m = SequentialModel::from_json(MLP_JSON).unwrap();
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.input, InputShape::Flat(16));
        assert_eq!(m.optimizer, Optimizer::Sgd { lr: 0.1 });
        let shapes = m.param_shapes().unwrap();
        assert_eq!(shapes[0].1, (16, 32));
        assert_eq!(shapes[1].1, (32, 4));
    }

    #[test]
    fn rejects_bad_models() {
        assert!(SequentialModel::from_json("{}").is_err());
        assert!(SequentialModel::from_json(r#"{"input_dim": 4, "layers": []}"#).is_err());
        assert!(
            SequentialModel::from_json(r#"{"input_dim": 4, "layers": [{"type": "warp"}]}"#)
                .is_err()
        );
        // conv over flat input is a shape error
        let m = SequentialModel::from_json(
            r#"{"input_dim": 4, "layers": [{"type": "conv2d", "filters": 2}]}"#,
        )
        .unwrap();
        assert!(m.param_shapes().is_err());
    }

    #[test]
    fn generated_dml_parses() {
        let m = SequentialModel::from_json(MLP_JSON).unwrap();
        let dml = m.to_dml(&FitConfig::default()).unwrap();
        crate::dml::parser::parse(&dml).expect("generated train DML must parse");
        let pdml = m.to_predict_dml(&FitConfig::default()).unwrap();
        crate::dml::parser::parse(&pdml).expect("generated predict DML must parse");
    }

    #[test]
    fn conv_model_shapes_and_codegen() {
        let json = r#"{
            "name": "cnn",
            "input_shape": [1, 8, 8],
            "layers": [
                {"type": "conv2d", "filters": 4, "kernel": [3,3], "padding": "same", "activation": "relu"},
                {"type": "maxpool2d", "pool": [2,2]},
                {"type": "flatten"},
                {"type": "dense", "units": 3, "activation": "softmax"}
            ],
            "optimizer": {"type": "adam", "lr": 0.01}
        }"#;
        let m = SequentialModel::from_json(json).unwrap();
        let shapes = m.param_shapes().unwrap();
        assert_eq!(shapes[0].1, (4, 9)); // K x C*R*S
        assert_eq!(shapes[1].1, (4 * 4 * 4, 3)); // flatten of 4x4x4
        let dml = m.to_dml(&FitConfig::default()).unwrap();
        crate::dml::parser::parse(&dml).expect("generated CNN DML must parse");
    }

    #[test]
    fn batch_vs_minibatch_codegen_differs() {
        let m = SequentialModel::from_json(MLP_JSON).unwrap();
        let mini = m.to_dml(&FitConfig::default()).unwrap();
        let full = m
            .to_dml(&FitConfig { train_algo: "batch".into(), ..FitConfig::default() })
            .unwrap();
        assert!(mini.contains("X[beg:end,]"));
        assert!(full.contains("Xb = X"));
        assert!(!full.contains("X[beg:end,]"));
    }
}
