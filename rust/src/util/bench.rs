//! Hand-rolled benchmark harness.
//!
//! `criterion` is not available in the offline registry, so the
//! `rust/benches/*.rs` targets (built with `harness = false`) use this
//! module: warmup + timed iterations, robust summary statistics, and a
//! paper-style table printer. Benches also report [`crate::util::metrics`]
//! deltas (FLOPs, shuffle bytes, ...) next to wallclock, which is how the
//! sparse/distributed experiments express their headline numbers.

use std::time::{Duration, Instant};

use crate::util::metrics::{self, MetricsSnapshot};

/// Result of one measured configuration.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Configuration label (one table row).
    pub label: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Median per-iteration wallclock.
    pub median: Duration,
    /// Mean per-iteration wallclock.
    pub mean: Duration,
    /// Min / max per-iteration wallclock.
    pub min: Duration,
    pub max: Duration,
    /// Metrics delta across all timed iterations (divide by `iters`).
    pub metrics: MetricsSnapshot,
}

impl Measurement {
    /// FLOPs per iteration (from the global metrics counters).
    pub fn flops_per_iter(&self) -> f64 {
        self.metrics.flops as f64 / self.iters.max(1) as f64
    }
    /// GFLOP/s based on median time.
    pub fn gflops(&self) -> f64 {
        let s = self.median.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.flops_per_iter() / s / 1e9
    }
}

/// Run `f` repeatedly: `warmup` untimed runs, then timed runs until either
/// `min_iters` iterations and `min_time` elapsed (whichever is later),
/// capped at `max_iters`.
pub fn bench<F: FnMut()>(label: &str, mut f: F) -> Measurement {
    bench_config(label, BenchConfig::default(), &mut f)
}

/// Tunable harness parameters.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(200),
        }
    }
}

/// Fully configurable variant of [`bench`].
pub fn bench_config<F: FnMut()>(label: &str, cfg: BenchConfig, f: &mut F) -> Measurement {
    for _ in 0..cfg.warmup {
        f();
    }
    let m0 = metrics::global().snapshot();
    let mut times = Vec::new();
    let started = Instant::now();
    while times.len() < cfg.max_iters
        && (times.len() < cfg.min_iters || started.elapsed() < cfg.min_time)
    {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    let m1 = metrics::global().snapshot();
    times.sort();
    let iters = times.len();
    let median = times[iters / 2];
    let mean = times.iter().sum::<Duration>() / iters as u32;
    Measurement {
        label: label.to_string(),
        iters,
        median,
        mean,
        min: times[0],
        max: times[iters - 1],
        metrics: m1.delta(&m0),
    }
}

/// Format a duration compactly (µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Print a paper-style results table.
///
/// `columns` are header names for the per-row extra values produced by
/// `extra(m)`; the harness prints label, median, and the extras.
pub fn print_table(
    title: &str,
    rows: &[Measurement],
    columns: &[&str],
    extra: impl Fn(&Measurement) -> Vec<String>,
) {
    println!("\n=== {title} ===");
    let mut header = vec!["config".to_string(), "median".to_string(), "iters".to_string()];
    header.extend(columns.iter().map(|s| s.to_string()));
    let mut table: Vec<Vec<String>> = vec![header];
    for m in rows {
        let mut row = vec![m.label.clone(), fmt_duration(m.median), m.iters.to_string()];
        row.extend(extra(m));
        table.push(row);
    }
    let ncols = table.iter().map(|r| r.len()).max().unwrap_or(0);
    let mut widths = vec![0usize; ncols];
    for row in &table {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    for (ri, row) in table.iter().enumerate() {
        let line: Vec<String> =
            row.iter().enumerate().map(|(i, c)| format!("{:<w$}", c, w = widths[i])).collect();
        println!("  {}", line.join("  "));
        if ri == 0 {
            println!("  {}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0usize;
        let cfg = BenchConfig {
            warmup: 1,
            min_iters: 3,
            max_iters: 5,
            min_time: Duration::from_millis(1),
        };
        let m = bench_config("t", cfg, &mut || {
            count += 1;
            std::thread::sleep(Duration::from_micros(200));
        });
        assert!(m.iters >= 3);
        assert!(count >= 4); // warmup + timed
        assert!(m.median >= Duration::from_micros(100));
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
