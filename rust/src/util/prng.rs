//! Deterministic PRNG: xoshiro256** plus normal/uniform helpers.
//!
//! The offline crate registry ships no `rand` crate, so we implement the
//! well-known xoshiro256** generator (Blackman & Vigna). DML's `rand(...)`
//! semantics (seeded, uniform/normal pdf, target sparsity) are built on top.

/// xoshiro256** generator. Deterministic for a given seed.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    cached_normal: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// splitmix64 — used to expand a single u64 seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s, cached_normal: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Modulo bias is negligible for n << 2^64 (all our uses).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (with caching of the paired variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fisher-Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let v = p.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_approx() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments_approx() {
        let mut p = Prng::new(13);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
