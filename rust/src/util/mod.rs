//! Utility modules shared across the stack: error types, a deterministic
//! PRNG (the offline registry ships no `rand` crate), a minimal JSON
//! parser (no `serde`), a bench harness (no `criterion`), and a small
//! property-testing helper (no `proptest`). See DESIGN.md §Substitutions.

pub mod bench;
pub mod error;
pub mod json;
pub mod metrics;
pub mod prng;
pub mod quickcheck;
pub mod stats;

pub use error::{DmlError, Result};
