//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate builds
//! offline with zero external dependencies.

use std::fmt;

/// Errors produced by the DML compiler and runtime.
#[derive(Debug)]
pub enum DmlError {
    /// Lexical error with source position.
    Lex { line: usize, col: usize, msg: String },

    /// Parse error with source position.
    Parse { line: usize, col: usize, msg: String },

    /// Semantic validation error (types, shapes, unknown identifiers).
    Validate(String),

    /// Runtime error raised while executing a program.
    Runtime(String),

    /// Dimension mismatch in a matrix operation.
    DimMismatch { op: String, lhs_rows: usize, lhs_cols: usize, rhs_rows: usize, rhs_cols: usize },

    /// I/O error (script files, matrix files, artifacts).
    Io(std::io::Error),

    /// Accelerator backend error (PJRT compile/execute).
    Accel(String),
}

impl fmt::Display for DmlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DmlError::Lex { line, col, msg } => {
                write!(f, "lex error at line {line}, col {col}: {msg}")
            }
            DmlError::Parse { line, col, msg } => {
                write!(f, "parse error at line {line}, col {col}: {msg}")
            }
            DmlError::Validate(msg) => write!(f, "validation error: {msg}"),
            DmlError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            DmlError::DimMismatch { op, lhs_rows, lhs_cols, rhs_rows, rhs_cols } => write!(
                f,
                "dimension mismatch in {op}: lhs {lhs_rows}x{lhs_cols}, rhs {rhs_rows}x{rhs_cols}"
            ),
            DmlError::Io(e) => write!(f, "io error: {e}"),
            DmlError::Accel(msg) => write!(f, "accelerator error: {msg}"),
        }
    }
}

impl std::error::Error for DmlError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DmlError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DmlError {
    fn from(e: std::io::Error) -> Self {
        DmlError::Io(e)
    }
}

impl DmlError {
    /// Shorthand constructor for runtime errors.
    pub fn rt(msg: impl Into<String>) -> Self {
        DmlError::Runtime(msg.into())
    }
    /// Shorthand constructor for validation errors.
    pub fn val(msg: impl Into<String>) -> Self {
        DmlError::Validate(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DmlError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = DmlError::rt("boom");
        assert_eq!(e.to_string(), "runtime error: boom");
        let d = DmlError::DimMismatch {
            op: "%*%".into(),
            lhs_rows: 2,
            lhs_cols: 3,
            rhs_rows: 4,
            rhs_cols: 5,
        };
        assert!(d.to_string().contains("lhs 2x3, rhs 4x5"));
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: DmlError = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
