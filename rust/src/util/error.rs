//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the DML compiler and runtime.
#[derive(Error, Debug)]
pub enum DmlError {
    /// Lexical error with source position.
    #[error("lex error at line {line}, col {col}: {msg}")]
    Lex { line: usize, col: usize, msg: String },

    /// Parse error with source position.
    #[error("parse error at line {line}, col {col}: {msg}")]
    Parse { line: usize, col: usize, msg: String },

    /// Semantic validation error (types, shapes, unknown identifiers).
    #[error("validation error: {0}")]
    Validate(String),

    /// Runtime error raised while executing a program.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Dimension mismatch in a matrix operation.
    #[error("dimension mismatch in {op}: lhs {lhs_rows}x{lhs_cols}, rhs {rhs_rows}x{rhs_cols}")]
    DimMismatch { op: String, lhs_rows: usize, lhs_cols: usize, rhs_rows: usize, rhs_cols: usize },

    /// I/O error (script files, matrix files, artifacts).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Accelerator backend error (PJRT compile/execute).
    #[error("accelerator error: {0}")]
    Accel(String),
}

impl DmlError {
    /// Shorthand constructor for runtime errors.
    pub fn rt(msg: impl Into<String>) -> Self {
        DmlError::Runtime(msg.into())
    }
    /// Shorthand constructor for validation errors.
    pub fn val(msg: impl Into<String>) -> Self {
        DmlError::Validate(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DmlError>;
