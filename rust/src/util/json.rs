//! Minimal JSON parser + writer.
//!
//! Used for the Keras2DML model descriptors and the AOT artifact manifest.
//! The offline registry has no `serde`/`serde_json`, so this is a small,
//! strict, recursive-descent implementation.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::error::{DmlError, Result};

/// A JSON value. Objects use a BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(DmlError::val(format!("trailing JSON content at byte {}", p.pos)));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> DmlError {
        DmlError::val(format!("JSON error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"layers":[{"type":"dense","units":10}],"lr":0.01}"#).unwrap();
        assert_eq!(v.get("lr").as_f64(), Some(0.01));
        let layers = v.get("layers").as_arr().unwrap();
        assert_eq!(layers[0].get("type").as_str(), Some("dense"));
        assert_eq!(layers[0].get("units").as_usize(), Some(10));
    }

    #[test]
    fn roundtrip_display() {
        let text = r#"{"a":[1,2,{"b":"x"}],"c":true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
